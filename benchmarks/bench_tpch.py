"""Paper Table 2 analogue: TPC-H query runtimes on the JAX engine.

Runs Q1 / Q6 / Q17 / Q3 single-device (jit wall time on this host) and
verifies each against the numpy oracle; the distributed 8-shard versions
run in the multi-device subprocess (same engine, exchange plans) — wall
time on fake CPU devices is NOT a network measurement, so the distributed
section reports bytes shuffled (the paper's "data shuffled" row) instead.
"""

import jax
import numpy as np

from repro.relational import datagen, oracle, queries
from .common import emit, time_jit

SF = 0.02


def run():
    tabs = datagen.gen_all(SF)
    li, part = tabs["lineitem"], tabs["part"]
    cust, orders = tabs["customer"], tabs["orders"]

    q1 = jax.jit(lambda t, v: queries.q1_local(
        type(li)(t, v, li.dictionaries), 90))
    t = time_jit(q1, li.columns, li.valid)
    got = queries.q1_finalize(q1(li.columns, li.valid))
    want = oracle.q1_oracle(li)
    ok = all(
        np.allclose(np.asarray(got[k]), want[k], rtol=1e-4) for k in want
    )
    emit("tpch/q1", f"{t*1e3:.2f}", "ms", f"SF={SF} correct={ok}")

    q6 = jax.jit(lambda t, v: queries.q6_local(type(li)(t, v, li.dictionaries)))
    t = time_jit(q6, li.columns, li.valid)
    ok = np.allclose(float(q6(li.columns, li.valid)), oracle.q6_oracle(li), rtol=1e-4)
    emit("tpch/q6", f"{t*1e3:.2f}", "ms", f"SF={SF} correct={ok}")

    q17 = jax.jit(
        lambda lc, lv, pc, pv: queries.q17_local(
            type(li)(lc, lv, li.dictionaries), type(part)(pc, pv, part.dictionaries)
        )
    )
    t = time_jit(q17, li.columns, li.valid, part.columns, part.valid)
    ok = np.allclose(
        float(q17(li.columns, li.valid, part.columns, part.valid)),
        oracle.q17_oracle(li, part), rtol=1e-3,
    )
    emit("tpch/q17", f"{t*1e3:.2f}", "ms", f"SF={SF} correct={ok}")

    q3 = jax.jit(
        lambda cc, cv, oc, ov, lc, lv: queries.q3_local(
            type(li)(cc, cv), type(li)(oc, ov), type(li)(lc, lv)
        )["revenue"]
    )
    t = time_jit(q3, cust.columns, cust.valid, orders.columns, orders.valid,
                 li.columns, li.valid)
    emit("tpch/q3", f"{t*1e3:.2f}", "ms", f"SF={SF}")

    q14 = jax.jit(
        lambda lc, lv, pc, pv: queries.q14_finalize(
            *queries.q14_local(
                type(li)(lc, lv, li.dictionaries), type(part)(pc, pv, part.dictionaries)
            )
        )
    )
    t = time_jit(q14, li.columns, li.valid, part.columns, part.valid)
    ok = np.allclose(
        float(q14(li.columns, li.valid, part.columns, part.valid)),
        oracle.q14_oracle(li, part), rtol=1e-3,
    )
    emit("tpch/q14", f"{t*1e3:.2f}", "ms", f"SF={SF} correct={ok}")

    q19 = jax.jit(
        lambda lc, lv, pc, pv: queries.q19_local(
            type(li)(lc, lv, li.dictionaries), type(part)(pc, pv, part.dictionaries)
        )
    )
    t = time_jit(q19, li.columns, li.valid, part.columns, part.valid)
    ok = np.allclose(
        float(q19(li.columns, li.valid, part.columns, part.valid)),
        oracle.q19_oracle(li, part), rtol=1e-3,
    )
    emit("tpch/q19", f"{t*1e3:.2f}", "ms", f"SF={SF} correct={ok}")

    # ---- "data shuffled" row (paper Table 2): bytes each plan exchanges ----
    n = 16
    li_rows = int(li.num_valid())
    row_q17 = 3 * 4  # partkey, quantity, extendedprice (int32)
    part_rows = int(part.num_valid())
    emit("tpch/q17_shuffle_bytes", li_rows * row_q17, "B",
         f"partition lineitem over {n} units")
    emit("tpch/q17_broadcast_bytes", part_rows * 3 * 4 * (n - 1), "B",
         "part broadcast (hybrid: once per remote unit)")
    emit("tpch/q1_shuffle_bytes", 6 * 6 * 4 * n, "B",
         "pre-aggregated group table only")


if __name__ == "__main__":
    run()
