"""Paper Table 2 analogue: TPC-H through the query planner.

Every query — the six ported ones AND the plan-only Q4/Q12/Q18 — runs
through the declarative planner (logical IR -> cost-based exchange placement
-> shard_map executor) and is verified against the numpy oracle.  For the
queries that still have a hand-written local pipeline (``queries.py``) the
bench reports planned-vs-handwritten jit wall time on this host — the "does
the abstraction cost anything" number.  Wall time on fake CPU devices is NOT
a network measurement, so the distributed dimension is reported as the
planner's modeled exchange profile instead: shuffle/broadcast edge counts
and wire bytes per query at 8 shards (the paper's "data shuffled" row),
straight from the physical plan that the golden snapshots pin down.

The modeled numbers are checked against measurement: the bench shells out
to ``repro.obs.model_check`` (a traced run on an 8-fake-device mesh — the
XLA device-count flag must precede jax init, hence a subprocess) and
records each edge's ``byte_model_err`` — measured wire bytes from the
in-jit destination histograms vs the planner's estimate-priced model.
``--compare`` gates those leaves lower-is-better at the usual 2x.

``run(smoke=True)`` returns the record the CI ``bench-smoke`` job writes to
``BENCH_tpch.json`` — the per-PR perf trajectory for the relational engine.
"""

import jax
import numpy as np

from repro.relational import datagen, oracle, queries
from repro.relational.planner import compile_plan, tpch as T
from .common import emit, time_jit

SF = 0.02
PLAN_SHARDS = 8  # the exchange-profile mesh (modeled, no devices needed)

# model-vs-measured subprocess runs: (query, streamed) — q17 streamed is
# the hardest case (selective semi-join upstream, two passes over the
# shared shuffle); q3 exercises the resident-side traversal accounting.
MODEL_CHECKS = (("q17", True), ("q3", True))
SMOKE_MODEL_CHECKS = (("q17", True),)


def _model_check(query: str, streamed: bool, trace_dir: str | None) -> dict:
    """One traced query under ``repro.obs.model_check`` on fake devices."""
    import json
    import os
    import subprocess
    import sys

    cmd = [sys.executable, "-m", "repro.obs.model_check",
           "--query", query, "--shards", str(PLAN_SHARDS)]
    if streamed:
        cmd.append("--streamed")
    if trace_dir:
        cmd += ["--trace-dir", trace_dir]
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={PLAN_SHARDS}"
    out = subprocess.run(cmd, capture_output=True, text=True, env=env)
    if out.returncode != 0:
        raise RuntimeError(
            f"model_check {query} exited {out.returncode}:\n"
            f"{out.stderr[-2000:]}"
        )
    return json.loads(out.stdout)


def _handwritten_runners(tabs):
    """jit'd hand-written local pipelines, keyed by query name."""
    li, part = tabs["lineitem"], tabs["part"]
    cust, orders = tabs["customer"], tabs["orders"]
    mk = type(li)
    return {
        "q1": (
            jax.jit(lambda t, v: queries.q1_local(mk(t, v, li.dictionaries), 90)),
            (li.columns, li.valid),
        ),
        "q6": (
            jax.jit(lambda t, v: queries.q6_local(mk(t, v, li.dictionaries))),
            (li.columns, li.valid),
        ),
        "q17": (
            jax.jit(lambda lc, lv, pc, pv: queries.q17_local(
                mk(lc, lv, li.dictionaries), mk(pc, pv, part.dictionaries))),
            (li.columns, li.valid, part.columns, part.valid),
        ),
        "q3": (
            jax.jit(lambda cc, cv, oc, ov, lc, lv: queries.q3_local(
                mk(cc, cv), mk(oc, ov), mk(lc, lv))["revenue"]),
            (cust.columns, cust.valid, orders.columns, orders.valid,
             li.columns, li.valid),
        ),
        "q14": (
            jax.jit(lambda lc, lv, pc, pv: queries.q14_finalize(
                *queries.q14_local(mk(lc, lv, li.dictionaries),
                                   mk(pc, pv, part.dictionaries)))),
            (li.columns, li.valid, part.columns, part.valid),
        ),
        "q19": (
            jax.jit(lambda lc, lv, pc, pv: queries.q19_local(
                mk(lc, lv, li.dictionaries), mk(pc, pv, part.dictionaries))),
            (li.columns, li.valid, part.columns, part.valid),
        ),
    }


def _correct(name, got, tabs) -> bool:
    li, part = tabs["lineitem"], tabs["part"]
    cust, orders = tabs["customer"], tabs["orders"]
    if name == "q1":
        want = oracle.q1_oracle(li)
        return all(
            np.allclose(np.asarray(got[k]), want[k], rtol=1e-4) for k in want
        )
    if name == "q6":
        return np.allclose(float(got), oracle.q6_oracle(li), rtol=1e-4)
    if name == "q17":
        return np.allclose(float(got), oracle.q17_oracle(li, part), rtol=1e-3)
    if name == "q3":
        want = oracle.q3_oracle(cust, orders, li)
        return [int(k) for k in got["o_orderkey"]] == \
            [int(k) for k in want["o_orderkey"]]
    if name == "q14":
        return np.allclose(float(got), oracle.q14_oracle(li, part), rtol=1e-3)
    if name == "q19":
        return np.allclose(float(got), oracle.q19_oracle(li, part), rtol=1e-3)
    if name == "q4":
        return np.allclose(
            np.asarray(got["order_count"]), oracle.q4_oracle(li, orders)
        )
    if name == "q12":
        want = oracle.q12_oracle(li, orders)
        return np.allclose(
            got["high_line_count"], want["high_line_count"]
        ) and np.allclose(got["low_line_count"], want["low_line_count"])
    if name == "q18":
        want = oracle.q18_oracle(li, orders, cust)
        gm = dict(zip(got["o_orderkey"].tolist(),
                      got["o_totalprice"].tolist()))
        wm = dict(zip(want["o_orderkey"].tolist(),
                      want["o_totalprice"].tolist()))
        return gm == wm
    raise KeyError(name)


def run(smoke: bool = False, trace_dir: str | None = None):
    sf = 0.01 if smoke else SF
    iters = 3 if smoke else 5
    tabs = datagen.gen_all(sf)
    all_tables = {
        "lineitem": tabs["lineitem"], "part": tabs["part"],
        "orders": tabs["orders"], "customer": tabs["customer"],
    }
    hand = _handwritten_runners(tabs)
    record = {"sf": sf, "plan_shards": PLAN_SHARDS, "queries": {}}

    for name, factory in T.ALL_QUERIES.items():
        pq = factory()
        catalog = {t: all_tables[t].capacity for t in pq.tables}
        # the planner's distributed exchange profile (modeled at 8 shards)
        plan8 = pq.plan(catalog, PLAN_SHARDS)
        summary = plan8.exchange_summary()
        wire = plan8.total_wire_bytes()

        # planned single-device wall time + correctness (same host as the
        # hand-written baseline, so the numbers are comparable)
        plan1 = pq.plan(catalog, 1)
        runner = compile_plan(plan1, all_tables)
        t_planned = time_jit(runner, iters=iters)
        raw = runner()
        got = pq.finalize(raw) if pq.finalize else raw
        ok = _correct(name, got, tabs)

        t_hand = None
        if name in hand:
            fn, args = hand[name]
            t_hand = time_jit(fn, *args, iters=iters)
            emit(f"tpch/{name}_handwritten", f"{t_hand*1e3:.2f}", "ms",
                 f"SF={sf} local pipeline")
        emit(f"tpch/{name}_planned", f"{t_planned*1e3:.2f}", "ms",
             f"SF={sf} correct={ok}" + (
                 f" vs_handwritten={t_planned/t_hand:.2f}x" if t_hand else
                 " plan-only"))
        emit(f"tpch/{name}_wire_bytes", wire, "B",
             f"{len(plan8.shuffle_stats)} shuffle + "
             f"{len(plan8.broadcast_stats)} broadcast edges @ "
             f"{PLAN_SHARDS} shards")
        record["queries"][name] = {
            "correct": bool(ok),
            "planned_ms": round(t_planned * 1e3, 3),
            "handwritten_ms": round(t_hand * 1e3, 3) if t_hand else None,
            "wire_bytes": int(wire),
            "exchanges": summary,
        }

    record["model_check"] = {}
    for qname, streamed in (SMOKE_MODEL_CHECKS if smoke else MODEL_CHECKS):
        rep = _model_check(qname, streamed, trace_dir)
        worst = rep.get("worst_byte_model_err")
        record["model_check"][qname] = {
            "worst_byte_model_err": worst,
            "edges": {
                k: e["byte_model_err"] for k, e in rep["edges"].items()
            },
        }
        emit(f"tpch/{qname}_byte_model_err",
             f"{worst:.3f}" if worst is not None else "n/a", "x",
             f"measured vs modeled wire bytes @ {PLAN_SHARDS} fake devices"
             + (" (streamed)" if streamed else ""))
    return record


if __name__ == "__main__":
    run()
