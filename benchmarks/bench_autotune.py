"""Autotuner validation: modeled vs measured shuffle time across a size sweep.

    PYTHONPATH=src python benchmarks/bench_autotune.py      # 8 fake devices
    PYTHONPATH=src python -m benchmarks.run --only autotune

For each message size the sweep

1. prices every candidate multiplexer config with the topology cost model
   *calibrated to this host* (``calibrate_chip`` fits effective link
   bandwidth / launch latency / HBM bandwidth from four micro-benchmarks, so
   the model's absolute numbers are comparable to wall-clock here — on CPU
   fake devices in CI just as on real ICI),
2. measures a bracket of manual configs plus the tuned argmin on the live
   mesh, and
3. emits, per size: modeled and measured time per config, the tuned choice,
   ``tuned_vs_worst`` (tuned measured / worst manual measured — must be
   <= 1: the tuner never loses to the worst hand-set knob), and
   ``model_accuracy`` (modeled / measured for the tuned config — the
   acceptance bar is within 2x).

The pallas pack runs in interpret mode on CPU, so its *measured* walls are
pessimistic there; the calibrated model prices the xla pack law, and the
tuned config is re-tuned against a candidate set restricted to what the
backend executes natively when ``--native-only`` semantics apply (here:
measured configs use the xla pack on non-TPU backends).
"""

import os
import sys

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.autotune import (
    TableStats,
    calibrate_chip,
    exchange_makespan,
    measure_shuffle_config,
    tune_multiplexer,
)

try:
    from .common import emit
except ImportError:  # run as a script: benchmarks/ itself is on sys.path
    from common import emit

ROW_BYTES = 16
# Swept sizes stay inside the calibrated range (calibrate_chip fits the
# affine laws at 1024 and 65536 rows); extrapolating the model below the
# smallest calibration point is not meaningful.
SWEEP_ROWS = (1024, 4096, 16384, 65536)

# The manual bracket: PR 1's hand-set default, the unscheduled baseline, and
# two chunked variants — the knobs an operator might plausibly hand-pick.
MANUAL_CONFIGS = (
    ("round_robin", "xla", 1, 1),
    ("xla", "xla", 1, 1),
    ("round_robin", "xla", 4, 1),
    ("round_robin", "xla", 2, 2),
)


def _cfg_name(impl, pack, C, t):
    short = {"round_robin": "rr", "one_factorization": "of", "xla": "xla"}
    return f"{short[impl]}/{pack}/C{C}/t{t}"


def run():
    from repro.compat import make_mesh

    n = min(8, jax.device_count())
    mesh = make_mesh((n,), ("x",))
    if n < 2:
        emit("autotune/skipped", "true", "", f"need >= 2 devices, have {n}")
        return

    chip = calibrate_chip(mesh, "x", row_bytes=ROW_BYTES)
    emit("autotune/calib/link_bw", f"{chip.ici_link_bandwidth/1e9:.3f}", "GB/s",
         "effective, this host")
    emit("autotune/calib/launch", f"{chip.ici_launch_latency*1e6:.1f}", "us", "")
    emit("autotune/calib/hbm_bw", f"{chip.hbm_bandwidth/1e9:.3f}", "GB/s", "")
    emit("autotune/calib/kernel_launch",
         f"{chip.kernel_launch_latency*1e6:.1f}", "us", "")

    # CPU executes the pallas kernel in interpret mode — measured walls there
    # say nothing about the TPU kernel, so measure with the xla pack law the
    # calibration fitted.  On TPU both packs are native and stay in play.
    native_packs = ("xla", "pallas") if jax.default_backend() == "tpu" else ("xla",)

    for rows in SWEEP_ROWS:
        stats = TableStats(rows=rows, row_bytes=ROW_BYTES)
        tuned = tune_multiplexer(mesh, stats, chip=chip)
        best = next(
            c for c in tuned.candidates if c[1] in native_packs
        )
        t_impl, t_pack, t_C, t_t, t_modeled = best
        emit(f"autotune/rows{rows}/tuned",
             _cfg_name(t_impl, t_pack, t_C, t_t), "",
             f"modeled {t_modeled*1e6:.1f}us")

        # measure each distinct config exactly once — a repeat measurement
        # later in the run only samples machine drift, not the config
        bracket = dict.fromkeys(MANUAL_CONFIGS + ((t_impl, t_pack, t_C, t_t),))
        measured = {}
        for impl, pack, C, t in bracket:
            if pack not in native_packs or rows % (C * t):
                continue
            modeled = exchange_makespan(
                stats, n, impl, pack, C, t, chip=chip
            )
            wall = measure_shuffle_config(
                mesh, "x", stats, impl=impl, pack_impl=pack,
                pipeline_chunks=C, transport_chunks=t, max_rows=rows,
            )
            measured[(impl, pack, C, t)] = wall
            emit(f"autotune/rows{rows}/modeled/{_cfg_name(impl, pack, C, t)}",
                 f"{modeled*1e6:.1f}", "us", "")
            emit(f"autotune/rows{rows}/measured/{_cfg_name(impl, pack, C, t)}",
                 f"{wall*1e6:.1f}", "us", "")

        tuned_wall = measured[(t_impl, t_pack, t_C, t_t)]
        worst_manual = max(
            w for cfg, w in measured.items() if cfg != (t_impl, t_pack, t_C, t_t)
        )
        emit(f"autotune/rows{rows}/tuned_vs_worst",
             f"{tuned_wall / worst_manual:.3f}", "x",
             "tuned measured / worst manual measured (must be <= 1)")
        accuracy = max(t_modeled / tuned_wall, tuned_wall / t_modeled)
        emit(f"autotune/rows{rows}/model_accuracy",
             f"{accuracy:.3f}", "x",
             "modeled-vs-measured gap for the tuned config (bar: <= 2x)")


if __name__ == "__main__":
    print("name,value,unit,note")
    run()
