"""Kernel-level microbenchmarks: Pallas (interpret) vs jnp reference.

CPU wall time of interpret-mode Pallas is NOT TPU performance; what this
bench reports that matters is the *memory-traffic model*: bytes the kernel
touches vs bytes the unfused reference materializes (the VMEM-fusion win
the kernels exist for), plus correctness deltas.
"""

import jax
import jax.numpy as jnp

from repro.kernels import ref
from .common import emit


def flash_traffic():
    B, H, KH, S, D = 1, 8, 2, 2048, 128
    f32 = 4
    logits_bytes = B * H * S * S * f32          # materialized by naive sdpa
    flash_bytes = B * (H + 2 * KH) * S * D * 2  # q,k,v streamed once (bf16)
    emit("kern/flash/naive_logits", f"{logits_bytes/1e6:.0f}", "MB", f"S={S}")
    emit("kern/flash/streamed", f"{flash_bytes/1e6:.0f}", "MB", "q+k+v bf16")
    emit("kern/flash/traffic_ratio", f"{logits_bytes/flash_bytes:.1f}", "x", "")


def ssd_traffic():
    B, L, H, P, N, Q = 1, 4096, 64, 64, 128, 256
    f32 = 4
    ref_decay = B * (L // Q) * Q * Q * H * f32  # per-chunk decay, all chunks
    kern_live = Q * Q * 8 * f32                 # one chunk x head-block in VMEM
    emit("kern/ssd/ref_decay_total", f"{ref_decay/1e9:.2f}", "GB", f"L={L}")
    emit("kern/ssd/kernel_vmem_live", f"{kern_live/1e6:.2f}", "MB", "hb=8")


def dispatch_traffic():
    T_, E = 1_048_576, 64
    i32 = 4
    ref_cumsum = T_ * (E + 1) * i32 * 2  # [T,E] onehot + cumsum read/write
    kern_bytes = T_ * i32 * 2 + E * i32  # dest in, slot out, counters in VMEM
    emit("kern/dispatch/ref_bytes", f"{ref_cumsum/1e9:.2f}", "GB", "olmoe train cell")
    emit("kern/dispatch/kernel_bytes", f"{kern_bytes/1e6:.1f}", "MB", "")
    emit("kern/dispatch/traffic_ratio", f"{ref_cumsum/kern_bytes:.0f}", "x", "")


def correctness_spot():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 256, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 256, 64))
    from repro.kernels.flash_attention import flash_attention

    out = flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    err = float(jnp.abs(out - want).max())
    emit("kern/flash/max_abs_err", f"{err:.2e}", "", "f32 256x256")


def run():
    flash_traffic()
    ssd_traffic()
    dispatch_traffic()
    correctness_spot()


if __name__ == "__main__":
    run()
