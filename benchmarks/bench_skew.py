"""Paper §3.1 skew analysis: parallel-unit count vs straggler overload.

The paper's argument for hybrid parallelism: Zipf z=0.84 overloads the
largest of 240 thread-level partitions by >2x but the largest of 6
server-level partitions by only ~2.8 %.  We reproduce the numbers
analytically and add the salting mitigation's effect.
"""

import numpy as np

from repro.core import skew
from .common import emit


def paper_table():
    for parts, label in ((240, "classic n*t=240"), (6, "hybrid n=6"),
                         (256, "one pod, chips"), (16, "exchange axis")):
        over = skew.zipf_partition_overload_analytic(parts, z=0.84)
        emit("skew/overload", f"{(over - 1) * 100:.1f}", "%", f"z=0.84 {label}")


def z_sweep():
    for z in (0.5, 0.7, 0.84, 1.0, 1.2):
        o240 = skew.zipf_partition_overload_analytic(240, z=z)
        o6 = skew.zipf_partition_overload_analytic(6, z=z)
        emit("skew/overload_240", f"{o240:.3f}", "x-fair", f"z={z}")
        emit("skew/overload_6", f"{o6:.3f}", "x-fair", f"z={z}")


def salting():
    rng = np.random.default_rng(0)
    keys = (rng.zipf(1.5, size=200_000) % 10_000).astype(np.int64)
    loads = np.bincount(skew._hash_keys(keys, 0) % np.uint64(16), minlength=16)
    base = skew.straggler_excess(loads)
    counts = np.bincount(keys)
    heavy = np.argsort(counts)[-16:]
    salted = skew.salt_keys(keys, heavy_keys=heavy, num_salts=16)
    after = skew.straggler_excess(
        np.bincount(skew._hash_keys(salted, 0) % np.uint64(16), minlength=16)
    )
    emit("skew/straggler_excess_base", f"{base*100:.1f}", "%", "16 shards, zipf1.5")
    emit("skew/straggler_excess_salted", f"{after*100:.1f}", "%", "16 hot keys salted")


def run():
    paper_table()
    z_sweep()
    salting()


if __name__ == "__main__":
    run()
