"""Paper §3.1 skew analysis: parallel-unit count vs straggler overload.

The paper's argument for hybrid parallelism: Zipf z=0.84 overloads the
largest of 240 thread-level partitions by >2x but the largest of 6
server-level partitions by only ~2.8 %.  We reproduce the numbers
analytically and add the salting mitigation's effect.

``run(smoke=True)`` is the CI bench-smoke lane: it records the adaptive
optimizer's view of the Zipf(1.2) TPC-H scenario — estimated plain vs
salted overload of Q17's lineitem shuffle (as ``*_balance_fraction``,
higher is better, gated by ``run.py --compare``) and the measured wall
time of the plain vs salted plan shape (``*_s``, lower is better) — into
``BENCH_skew.json``, so salting-decision or salted-shape regressions
show up in the perf trajectory.
"""

import numpy as np

from repro.core import skew
from .common import emit, time_jit


def paper_table():
    for parts, label in ((240, "classic n*t=240"), (6, "hybrid n=6"),
                         (256, "one pod, chips"), (16, "exchange axis")):
        over = skew.zipf_partition_overload_analytic(parts, z=0.84)
        emit("skew/overload", f"{(over - 1) * 100:.1f}", "%", f"z=0.84 {label}")


def z_sweep():
    for z in (0.5, 0.7, 0.84, 1.0, 1.2):
        o240 = skew.zipf_partition_overload_analytic(240, z=z)
        o6 = skew.zipf_partition_overload_analytic(6, z=z)
        emit("skew/overload_240", f"{o240:.3f}", "x-fair", f"z={z}")
        emit("skew/overload_6", f"{o6:.3f}", "x-fair", f"z={z}")


def _shard_of(keys: np.ndarray, n: int) -> np.ndarray:
    # int64 cast only for bincount (it refuses uint64); modulus keeps
    # values < n, far below 2**63
    return (skew._hash_keys(keys, 0) % np.uint64(n)).astype(np.int64)


def salting():
    rng = np.random.default_rng(0)
    keys = (rng.zipf(1.5, size=200_000) % 10_000).astype(np.int64)
    base = skew.straggler_excess(np.bincount(_shard_of(keys, 16), minlength=16))
    counts = np.bincount(keys)
    heavy = np.argsort(counts)[-16:]
    # salt_keys returns uint64 (the widened salted key space)
    salted = skew.salt_keys(keys, heavy_keys=heavy, num_salts=16)
    after = skew.straggler_excess(
        np.bincount(_shard_of(salted, 16), minlength=16)
    )
    emit("skew/straggler_excess_base", f"{base*100:.1f}", "%", "16 shards, zipf1.5")
    emit("skew/straggler_excess_salted", f"{after*100:.1f}", "%", "16 hot keys salted")


def adaptive_q17(smoke: bool = False) -> dict:
    """The adaptive optimizer's Zipf(1.2) scenario, recorded for CI.

    Estimated overloads come from the SAME stats/pricing path the planner
    uses (deterministic — seeded sample, analytic placement).  Wall times
    execute both plan shapes on the host device: the salted shape pays a
    partial + broadcast + combine group-by, and this records that overhead
    next to the balance it buys.
    """
    from repro.relational import datagen
    from repro.relational import stats as rstats
    from repro.relational.planner import tpch
    from repro.relational.planner.executor import compile_plan

    z, sf, shards = 1.2, 0.01, 8
    tabs = datagen.gen_all(sf, zipf_partkey=z)
    pq = tpch.q17(brand=11, container=25)  # selects the heaviest part
    catalog = {t: tabs[t].capacity for t in pq.tables}
    stats = rstats.collect_stats({t: tabs[t] for t in pq.tables})

    cs = stats["lineitem"].columns["l_partkey"]
    heavy = rstats.salting_keys(cs, shards)
    num_salts = rstats.choose_num_salts(heavy, shards)
    over_plain = rstats.partition_overload(cs.heavy_hitters, shards)
    over_salted = rstats.partition_overload(
        cs.heavy_hitters, shards, num_salts=num_salts, salted=heavy
    )

    iters = 3 if smoke else 5
    plan_salted = pq.plan(catalog, 1, stats=stats)
    plan_plain = pq.plan(catalog, 1)
    t_salted = time_jit(compile_plan(plan_salted, tabs), iters=iters)
    t_plain = time_jit(compile_plan(plan_plain, tabs), iters=iters)

    emit("skew/q17_overload_plain", f"{over_plain:.2f}", "x-fair",
         f"zipf{z} l_partkey, {shards} shards")
    emit("skew/q17_overload_salted", f"{over_salted:.2f}", "x-fair",
         f"{len(heavy)} heavy keys x {num_salts} salts")
    emit("skew/q17_plan_plain", f"{t_plain*1e3:.2f}", "ms", f"SF={sf} host")
    emit("skew/q17_plan_salted", f"{t_salted*1e3:.2f}", "ms",
         f"SF={sf} host, salted shape overhead "
         f"{t_salted/t_plain:.2f}x")
    return {
        "z": z, "sf": sf, "num_shards": shards,
        "q17": {
            # informational (no gated suffix): the raw overload factors
            "overload_plain_x": over_plain,
            "overload_salted_x": over_salted,
            "num_salts": num_salts,
            "heavy_keys": len(heavy),
            # gated, higher is better: fair_share / max_load in (0, 1]
            "plain_balance_fraction": 1.0 / over_plain,
            "salted_balance_fraction": 1.0 / over_salted,
            # gated, lower is better: wall time of each plan shape
            "planned_plain_s": t_plain,
            "planned_salted_s": t_salted,
        },
    }


def run(smoke: bool = False) -> dict:
    record = {}
    if not smoke:
        paper_table()
        z_sweep()
        salting()
    record.update(adaptive_q17(smoke=smoke))
    return record


if __name__ == "__main__":
    run()
