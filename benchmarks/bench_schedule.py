"""Paper Fig 10(b)/(c): network scheduling vs switch contention.

(b) all-to-all throughput, unscheduled vs round-robin scheduled, as cluster
    size grows — from the max-min-fairness contention simulator
    (core.topology), the same mechanism the paper measures on its 8-port
    InfiniBand switch (+40 %).
(c) synchronization-cost amortization vs message size (the paper's ~1 µs
    phase barrier against the per-phase transfer time).
"""

from repro.core import topology as T
from repro.core.schedule import schedule_link_time
from .common import emit


def fig10b():
    for n in (2, 4, 6, 8, 12, 16, 32, 64, 128, 256):
        factor = T.contention_factor(n)
        speedup = 1.0 / factor
        emit("fig10b/contention_factor", f"{factor:.3f}", "x", f"n={n}")
        emit("fig10b/scheduled_speedup", f"{speedup:.3f}", "x", f"n={n}")
    s8 = 1.0 / T.contention_factor(8)
    emit("fig10b/paper_claim_8servers", f"{s8:.2f}", "x",
         "paper measures ~1.40x at n=8")


def fig10c():
    for msg_kb in (16, 64, 128, 256, 512, 1024, 4096):
        eff = T.sync_amortization(message_bytes=msg_kb * 1024)
        emit("fig10c/sync_efficiency", f"{eff:.4f}", "frac", f"msg={msg_kb}KB")


def roofline_cross_check():
    """Scheduled vs unscheduled all-to-all time on the v5e ICI numbers."""
    for n in (16, 256):
        bytes_per_pair = 8 * 2**20
        t_s = schedule_link_time(n, bytes_per_pair, T.V5E.ici_link_bandwidth, True)
        t_u = schedule_link_time(n, bytes_per_pair, T.V5E.ici_link_bandwidth, False)
        emit("fig10b/v5e_a2a_scheduled", f"{t_s*1e3:.2f}", "ms", f"n={n}, 8MiB/pair")
        emit("fig10b/v5e_a2a_unscheduled", f"{t_u*1e3:.2f}", "ms", f"n={n}")


def run():
    fig10b()
    fig10c()
    roofline_cross_check()


if __name__ == "__main__":
    run()
