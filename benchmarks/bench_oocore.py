"""Out-of-core morsel streaming: streamed vs in-memory runtime + overlap.

The tentpole claim of the out-of-core PR, as checked numbers:

* ``q1.streamed_s`` / ``q1.in_memory_s`` (and the same for Q17) — wall
  time of the morsel-streamed run against the one-shot in-memory run of
  the identical plan on the identical data (both warm: the measured pass
  re-uses compiled steps).  Streaming pays per-morsel dispatch, so it is
  slower; CI gates it from *regressing*, not from existing.
* ``prefetch_overlap_fraction`` — the share of host->device transfer
  latency hidden behind device compute by the double-buffered
  ``data/pipeline.Prefetcher`` (higher is better, gated).
* Both streamed runs execute under a ``device_row_budget`` below the
  full lineitem capacity — the configuration the in-memory path refuses —
  so the numbers describe the out-of-core regime, not a degenerate one.

``run(smoke=True)`` returns the record written to ``BENCH_oocore.json``
and gated by ``benchmarks.run --compare``.
"""

from __future__ import annotations

import time

from .common import emit


def _streamed(pq, plan, sources, ctx):
    from repro.relational.planner.stream import compile_plan_streamed

    run = compile_plan_streamed(plan, sources, ctx)
    pq.finalize(run())  # warm: compile every pass/morsel step
    t0 = time.perf_counter()
    out = pq.finalize(run())
    return time.perf_counter() - t0, out, run.stats


def _in_memory(pq, plan, tables):
    from repro.relational.planner.executor import compile_plan

    run = compile_plan(plan, tables)
    pq.finalize(run())  # warm
    t0 = time.perf_counter()
    out = pq.finalize(run())
    return time.perf_counter() - t0, out


def bench_oocore(sf: float, morsel_rows: int) -> dict:
    import numpy as np

    from repro.relational import datagen
    from repro.relational.context import ExecutionContext
    from repro.relational.planner import tpch
    from repro.relational.source import MorselView, as_source

    tabs = datagen.gen_all(sf)
    li = tabs["lineitem"]
    budget = li.capacity // 2
    ctx = ExecutionContext(num_shards=1, device_row_budget=budget)
    rec: dict = {"sf": sf, "morsel_rows": morsel_rows,
                 "device_row_budget": budget,
                 "lineitem_capacity": li.capacity}
    assert li.capacity > budget  # out-of-core regime, not a toy

    overlaps = []
    for qname in ("q1", "q17"):
        pq = tpch.ALL_QUERIES[qname]()
        sources = {t: as_source(tabs[t]) for t in pq.tables}
        sources["lineitem"] = MorselView(li, morsel_rows=morsel_rows)
        catalog = {t: sources[t].capacity for t in pq.tables}
        plan = pq.plan(catalog, 1, morsel_rows=morsel_rows)
        mem_s, want = _in_memory(
            pq, pq.plan(catalog, 1),
            {t: sources[t].materialize() for t in pq.tables})
        str_s, got, stats = _streamed(pq, plan, sources, ctx)
        want = want if isinstance(want, dict) else {"result": want}
        got = got if isinstance(got, dict) else {"result": got}
        for k in want:
            w, g = np.asarray(want[k]), np.asarray(got[k])
            if w.dtype.kind == "f":
                np.testing.assert_allclose(g, w, rtol=1e-3, err_msg=k)
            else:
                np.testing.assert_array_equal(g, w, err_msg=k)
        overlaps.append(stats["prefetch_overlap_fraction"])
        rec[qname] = dict(
            streamed_s=str_s,
            in_memory_s=mem_s,
            passes=stats["passes"],
            morsels=stats["morsels"],
        )
        emit(f"oocore_{qname}_streamed", f"{str_s:.4f}", "s",
             f"{stats['morsels']} morsels x {morsel_rows} rows")
        emit(f"oocore_{qname}_in_memory", f"{mem_s:.4f}", "s",
             "one-shot, full table resident")
    rec["prefetch_overlap_fraction"] = float(min(overlaps))
    emit("oocore_prefetch_overlap", f"{rec['prefetch_overlap_fraction']:.3f}",
         "", "transfer latency hidden behind compute (min over queries)")
    return rec


def run(smoke: bool = False) -> dict:
    if smoke:
        return bench_oocore(sf=0.004, morsel_rows=1024)
    return bench_oocore(sf=0.01, morsel_rows=4096)


if __name__ == "__main__":
    run()
