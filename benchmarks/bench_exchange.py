"""Paper Fig 5 / Fig 12(b) analogue + the MoE exchange A/B.

Fig 12(b): how much each engine speeds up as link bandwidth rises —
transports that burn CPU per byte (TCP-like) or suffer contention
(unscheduled) cannot convert bandwidth into query throughput; the
scheduled zero-copy transport can.  Same model as bench_scaling.

MoE A/B: per-device collective bytes of the expert-parallel dispatch from
the dry-run artifacts — scheduled round-robin phases (collective-permute)
vs XLA's monolithic all-to-all, modeled at ICI rates with/without the
contention factor.  This is the paper's technique applied to its LM-era
workload (DESIGN.md §4).
"""

import glob
import json
import os

from repro.core import topology as T
from .bench_scaling import query_time
from .common import emit


def fig12b():
    n = 6
    for name, sched, cpu in (
        ("memsql_like_tcp", False, 0.45),
        ("vortex_like_tcp", False, 0.20),
        ("hyper_rdma_sched", True, 0.02),
    ):
        base = query_time(n, 0.125, sched, cpu)
        for gbps in (0.125, 1.0, 2.0, 4.0):
            s = base / query_time(n, gbps, sched, cpu)
            emit(f"fig12b/{name}", f"{s:.2f}", "x", f"link={gbps}GB/s")
    emit("fig12b/paper_claim", "12", "x", "HyPer RDMA 4xQDR vs GbE (paper)")


def moe_exchange_ab(art_dir: str = "artifacts/dryrun_final"):
    """Scheduled (ppermute phases) vs unscheduled (monolithic a2a) dispatch."""
    for arch in ("olmoe-1b-7b", "deepseek-v2-lite-16b"):
        f = os.path.join(art_dir, f"{arch}__train_4k__16x16.json")
        if not os.path.exists(f):
            continue
        art = json.load(open(f))
        coll = art["collective_bytes"]
        cp = coll.get("collective-permute", 0)  # the scheduled phases
        a2a = coll.get("all-to-all", 0)
        link = T.V5E.ici_link_bandwidth
        contention = T.contention_factor(16)
        t_sched = cp / link
        t_unsched = (cp + a2a) / link / contention
        emit(f"moe_ab/{arch}/sched_dispatch", f"{t_sched*1e3:.1f}", "ms/step",
             f"{cp/1e9:.1f}GB ppermute phases")
        emit(f"moe_ab/{arch}/unsched_dispatch", f"{t_unsched*1e3:.1f}", "ms/step",
             f"contention={contention:.2f}")
        if t_sched > 0:
            emit(f"moe_ab/{arch}/sched_gain", f"{t_unsched/t_sched:.2f}", "x", "")


def run():
    fig12b()
    moe_exchange_ab()


if __name__ == "__main__":
    run()
