"""Paper Fig 5 / Fig 12(b) analogue + the MoE exchange A/B.

Fig 12(b): how much each engine speeds up as link bandwidth rises —
transports that burn CPU per byte (TCP-like) or suffer contention
(unscheduled) cannot convert bandwidth into query throughput; the
scheduled zero-copy transport can.  Same model as bench_scaling.

MoE A/B: per-device collective bytes of the expert-parallel dispatch from
the dry-run artifacts — scheduled round-robin phases (collective-permute)
vs XLA's monolithic all-to-all, modeled at ICI rates with/without the
contention factor.  This is the paper's technique applied to its LM-era
workload (DESIGN.md §4).

Pack A/B (``pack_ab``): the partition/pack hot path — XLA one-hot/cumsum
reference vs the fused Pallas partition+pack kernel — with HLO-level
evidence that the fused path's intermediates are independent of the
destination count (no ``[rows, num_dest]`` one-hot), plus a bit-exactness
check between the two implementations.
"""

import json
import os
import re

from repro.core import topology as T
from .bench_scaling import query_time
from .common import emit, time_jit


def fig12b() -> list[dict]:
    n = 6
    records = []
    for name, sched, cpu in (
        ("memsql_like_tcp", False, 0.45),
        ("vortex_like_tcp", False, 0.20),
        ("hyper_rdma_sched", True, 0.02),
    ):
        base = query_time(n, 0.125, sched, cpu)
        for gbps in (0.125, 1.0, 2.0, 4.0):
            s = base / query_time(n, gbps, sched, cpu)
            emit(f"fig12b/{name}", f"{s:.2f}", "x", f"link={gbps}GB/s")
            records.append({"engine": name, "link_gbps": gbps,
                            "speedup_x": round(s, 2)})
    emit("fig12b/paper_claim", "12", "x", "HyPer RDMA 4xQDR vs GbE (paper)")
    return records


def moe_exchange_ab(art_dir: str = "artifacts/dryrun_final"):
    """Scheduled (ppermute phases) vs unscheduled (monolithic a2a) dispatch."""
    for arch in ("olmoe-1b-7b", "deepseek-v2-lite-16b"):
        f = os.path.join(art_dir, f"{arch}__train_4k__16x16.json")
        if not os.path.exists(f):
            continue
        art = json.load(open(f))
        coll = art["collective_bytes"]
        cp = coll.get("collective-permute", 0)  # the scheduled phases
        a2a = coll.get("all-to-all", 0)
        link = T.V5E.ici_link_bandwidth
        contention = T.contention_factor(16)
        t_sched = cp / link
        t_unsched = (cp + a2a) / link / contention
        emit(f"moe_ab/{arch}/sched_dispatch", f"{t_sched*1e3:.1f}", "ms/step",
             f"{cp/1e9:.1f}GB ppermute phases")
        emit(f"moe_ab/{arch}/unsched_dispatch", f"{t_unsched*1e3:.1f}", "ms/step",
             f"contention={contention:.2f}")
        if t_sched > 0:
            emit(f"moe_ab/{arch}/sched_gain", f"{t_unsched/t_sched:.2f}", "x", "")


def pack_ab(rows: int = 8192, width: int = 4,
            dests: tuple = (8, 64, 256)) -> list[dict]:
    """Partition/pack hot path: XLA one-hot vs the fused Pallas kernel.

    The XLA reference ranks rows with a ``[rows, num_dest + 1]``
    one-hot/cumsum — O(rows x destinations).  The Pallas path's largest
    intermediate is the per-block ``[block, bins]`` tile plus the
    ``[nblocks, bins]`` histogram.  Evidence emitted per destination count:

    * whether the optimized HLO materializes a ``[rows, num_dest + 1]``
      tensor (it must for xla, must NOT for pallas),
    * the largest 2-D s32 intermediate in the program,
    * compiled cost analysis (flops), wall time, and a bit-exactness check.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import exchange

    key = jax.random.PRNGKey(0)
    keys = jax.random.randint(key, (rows,), 0, 1 << 30)
    data = jax.random.randint(
        jax.random.fold_in(key, 1), (rows, width), 0, 1000, dtype=jnp.int32
    )
    records = []
    for n_dest in dests:
        cap = max(rows // n_dest * 2, 16)  # 2x fair share
        dest = (keys % n_dest).astype(jnp.int32)
        outs = {}
        for impl in ("xla", "pallas"):
            fn = jax.jit(
                lambda d, r, impl=impl: exchange.pack_by_destination(
                    d, r, n_dest, cap, impl=impl
                )
            )
            compiled = fn.lower(dest, data).compile()
            hlo = compiled.as_text()
            onehot_shape = f"[{rows},{n_dest + 1}]"
            materializes = onehot_shape in hlo
            two_d = [
                int(a) * int(b) for a, b in re.findall(r"s32\[(\d+),(\d+)\]", hlo)
            ]
            peak2d = max(two_d, default=0)
            try:
                flops = (compiled.cost_analysis() or {}).get("flops", float("nan"))
            except Exception:
                flops = float("nan")
            wall = time_jit(fn, dest, data)
            outs[impl] = fn(dest, data)
            emit(f"pack_ab/ndest{n_dest}/{impl}/materializes_onehot",
                 str(materializes).lower(), "", f"shape s32{onehot_shape}")
            emit(f"pack_ab/ndest{n_dest}/{impl}/peak_2d_s32", peak2d, "elements", "")
            emit(f"pack_ab/ndest{n_dest}/{impl}/flops", f"{flops:.0f}", "", "")
            emit(f"pack_ab/ndest{n_dest}/{impl}/wall", f"{wall*1e3:.2f}", "ms",
                 "CPU interpret mode — HLO shape evidence is the signal")
            records.append({
                "rows": rows, "n_dest": n_dest, "impl": impl,
                "materializes_onehot": materializes, "peak_2d_s32": peak2d,
                "wall_ms": round(wall * 1e3, 3),
            })
        import numpy as np

        for a, b in zip(outs["xla"], outs["pallas"]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        emit(f"pack_ab/ndest{n_dest}/bit_exact", "true", "", "xla == pallas")
    return records


def overlap_audit(rows: int = 512, d: int = 32) -> list[dict]:
    """Overlap audit of the two-level EP dispatch, from lowered HLO.

    Compiles dispatch -> expert matmul -> combine through the two-level
    fabric on a (pod, model) mesh, then extracts per-collective bytes and
    dot FLOPs from the optimized HLO (``launch.hlo_cost``) and reports the
    roofline ``overlap_fraction``: the share of collective time the
    latency-hiding scheduler may hide behind compute (async -start/-done
    pairs, capped by available compute).  Needs >= 4 devices (real or
    ``--xla_force_host_platform_device_count`` fakes); on a single-device
    run it emits a skip marker — the modeled audit in
    ``bench_serve.ep_overlap_audit`` is then the signal.
    """
    import jax

    n = jax.device_count()
    if n < 4:
        emit("overlap_audit/hlo", "skipped", "",
             f"{n} device(s) — modeled audit in bench_serve is the signal")
        return []

    import numpy as np
    import jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.compat import shard_map
    from repro.core import exchange
    from repro.launch import hlo_cost
    from repro.launch.roofline import RooflineTerms

    records = []
    for pods in (1, 2):
        mesh = Mesh(
            np.array(jax.devices()[:n]).reshape(pods, n // pods),
            ("pod", "model"),
        )
        pod = "pod" if pods > 1 else None

        def body(x, w, pod=pod):
            # leading dim of the exchanged tensor == joint unit count n
            t = x.reshape((n, x.shape[0] // n) + x.shape[1:])
            if pod is None:
                y = exchange.all_to_all(t, "model")
            else:
                y = exchange.dispatch_two_level(t, "model", pod)
            y = jnp.einsum("ncd,df->ncf", y, w)  # the expert FFN stand-in
            if pod is None:
                y = exchange.all_to_all(y, "model")
            else:
                y = exchange.combine_two_level(y, "model", pod)
            return y.reshape(x.shape)

        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P(("pod", "model")), P()),
            out_specs=P(("pod", "model")),
            axis_names={"pod", "model"}, check_vma=False,
        )
        x = jnp.zeros((rows, d), jnp.float32)
        w = jnp.zeros((d, d), jnp.float32)
        sh = NamedSharding(mesh, P(("pod", "model")))
        compiled = (
            jax.jit(fn, in_shardings=(sh, NamedSharding(mesh, P())))
            .lower(x, w).compile()
        )
        cost = hlo_cost.analyze(compiled.as_text())
        terms = RooflineTerms(
            arch="two_level_ep", shape=f"{rows}x{d}",
            mesh=f"{pods}x{n // pods}",
            flops_per_chip=cost["flops"], bytes_per_chip=cost["bytes"],
            coll_bytes_per_chip=cost["collective_bytes"],
            model_flops_global=0.0, chips=n,
            async_coll_bytes_per_chip=cost["async_collective_bytes"],
        )
        coll_total = sum(cost["collective_bytes"].values())
        coll_async = sum(cost["async_collective_bytes"].values())
        emit(f"overlap_audit/{terms.mesh}/collective_bytes", coll_total, "B",
             ",".join(sorted(cost["collective_bytes"])))
        emit(f"overlap_audit/{terms.mesh}/async_bytes", coll_async, "B",
             "-start/-done pairs the scheduler may overlap")
        emit(f"overlap_audit/{terms.mesh}/overlap_fraction",
             f"{terms.overlap_fraction:.3f}", "",
             "HLO-derived; 0 when the backend lowers collectives sync")
        records.append({
            "mesh": terms.mesh,
            "collective_bytes": cost["collective_bytes"],
            "async_collective_bytes": cost["async_collective_bytes"],
            "flops_per_chip": cost["flops"],
            "overlap_fraction": round(terms.overlap_fraction, 4),
        })
    return records


def run(smoke: bool = False) -> dict:
    """Full mode emits CSV only; smoke mode also returns the JSON record
    (reduced sizes) that ``benchmarks.run --smoke`` writes to
    ``BENCH_exchange.json``."""
    if smoke:
        return {
            "fig12b": fig12b(),
            "pack_ab": pack_ab(rows=2048, dests=(8, 64)),
            "overlap_audit": overlap_audit(),
        }
    fig12b()
    moe_exchange_ab()
    pack_ab()
    overlap_audit()
    return {}


if __name__ == "__main__":
    run()
