"""Static vs continuous batching: the serving face of the flexible exchange.

The paper's critique of the classic exchange operator — a FIXED assignment
of work to workers load-imbalances no matter how fast the network is — is
exactly what static-batch decoding does to cache slots: the batch retires
at the pace of its longest sequence.  This bench runs the SAME mixed-length
workload through both engines (fake CPU devices; smoke-sized models) and
reports the slot-occupancy and latency trajectory CI records per PR:

* ``slot_steps``   — decode steps x batch slots, the occupancy currency
  (strictly fewer for continuous is the acceptance bar);
* ``ttft``         — per-request time to first token (continuous admits as
  slots free instead of waiting for a full bucket);
* ``tok_s``        — end-to-end generated-token throughput.

``run(smoke=True)`` returns the JSON record written to ``BENCH_serve.json``
by ``benchmarks.run --smoke`` and uploaded by the CI ``bench-smoke`` job.
"""

from __future__ import annotations

import time

import numpy as np

from .common import emit


def bench_serve(
    arch: str = "minicpm-2b",
    requests: int = 12,
    batch: int = 4,
    prompt_len: int = 16,
    max_new: int = 12,
    seed: int = 0,
) -> dict:
    import jax

    from repro.configs import get_smoke_config
    from repro.models import registry as R
    from repro.serve import (
        ContinuousEngine, Request, ServeEngine, engine_record,
        generate_bucketed, make_mixed_workload,
    )

    cfg = get_smoke_config(arch)
    api = R.build(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    capacity = prompt_len + max_new + 1

    reqs_c = make_mixed_workload(
        cfg.vocab_size, requests, [max(prompt_len // 2, 4), prompt_len],
        max_new, np.random.default_rng(seed),
    )
    reqs_s = [Request(prompt=r.prompt.copy(), max_new_tokens=r.max_new_tokens)
              for r in reqs_c]

    cont = ContinuousEngine(api, batch_size=batch, capacity=capacity, seed=seed)
    t0 = time.perf_counter()
    cont.serve(params, reqs_c)
    rec_c = engine_record(reqs_c, cont.stats, time.perf_counter() - t0)

    static = ServeEngine(api, batch_size=batch, capacity=capacity, seed=seed)
    t0 = time.perf_counter()
    generate_bucketed(static, params, reqs_s)
    rec_s = engine_record(reqs_s, static.stats, time.perf_counter() - t0)

    for name, rec in (("static", rec_s), ("continuous", rec_c)):
        emit(f"serve/{arch}/{name}/slot_steps", rec["slot_steps"], "slot*steps", "")
        emit(f"serve/{arch}/{name}/tok_s", rec["tok_s"], "tok/s",
             "CPU smoke — compile dominates wall; slot_steps is the signal")
        if "ttft_mean_s" in rec:
            emit(f"serve/{arch}/{name}/ttft_mean", f"{rec['ttft_mean_s']*1e3:.0f}",
                 "ms", "")
    ratio = rec_s["slot_steps"] / max(rec_c["slot_steps"], 1)
    emit(f"serve/{arch}/slot_steps_ratio", f"{ratio:.2f}", "x",
         "static / continuous (higher = continuous wins)")
    assert rec_c["slot_steps"] < rec_s["slot_steps"], (
        f"continuous must use strictly fewer slot-steps: {rec_c['slot_steps']} "
        f"vs {rec_s['slot_steps']}"
    )
    return {
        "arch": arch,
        "workload": {
            "requests": requests, "batch": batch, "prompt_lens":
            sorted({int(r.prompt.shape[0]) for r in reqs_c}),
            "max_new": max_new, "seed": seed,
        },
        "static": rec_s,
        "continuous": rec_c,
        "slot_steps_ratio": round(ratio, 3),
    }


def ep_overlap_audit(
    arch: str = "olmoe-1b-7b",
    batch: int = 128,
    units: int = 8,
    pods: int = 2,
) -> dict:
    """Roofline audit of the async EP dispatch/combine pipeline.

    Prices one decode step's expert dispatch on the tuned config via
    ``tune_ep_dispatch``: the serialized makespan (dispatch, compute, and
    combine back-to-back) vs the chunked double-buffered pipeline, on a flat
    mesh and on a two-pod mesh routed through the two-level fabric.  The
    ``overlap_fraction`` is the share of exchange time hidden behind expert
    compute — the same quantity the HLO-level audit in ``bench_exchange``
    measures from async -start/-done pairs.  Asserts the async path is
    strictly faster than serialized on every audited topology.

    The modeled terms are pure arithmetic (no compile), so this audits the
    FULL-SIZE config at the assigned ``decode_32k`` batch — the smoke
    engines above only shrink what has to be compiled.  The small fractions
    it reports are the finding, not a bug: decode-time expert dispatch is
    overwhelmingly exchange-bound (4 KB rows over the interconnect vs a
    3-matmul FFN per row), so only ~compute's worth of the exchange can
    hide — the paper's network-is-the-bottleneck regime.
    """
    from repro.configs import get_config
    from repro.core.autotune import tune_ep_dispatch

    cfg = get_config(arch).scaled(moe_impl="ep_shardmap")
    out = {}
    for p in (1, pods):
        r = tune_ep_dispatch(cfg, batch, units, num_pods=p)
        mesh = f"{p}x{units // p}" if p > 1 else f"{units}"
        emit(f"ep_overlap/{arch}/{mesh}/serial", f"{r['serial_s']*1e6:.2f}",
             "us/step", "dispatch+compute+combine back-to-back")
        emit(f"ep_overlap/{arch}/{mesh}/async", f"{r['async_s']*1e6:.2f}",
             "us/step", f"chunks={r['chunks']}")
        emit(f"ep_overlap/{arch}/{mesh}/overlap_fraction",
             f"{r['overlap_fraction']:.3f}", "",
             "exchange time hidden behind expert compute")
        assert r["async_s"] < r["serial_s"], (
            f"async EP dispatch must beat serialized on {mesh}: "
            f"{r['async_s']:.3g} vs {r['serial_s']:.3g}"
        )
        out[mesh] = {
            "chunks": r["chunks"],
            "serial_s": r["serial_s"],
            "async_s": r["async_s"],
            "overlap_fraction": round(r["overlap_fraction"], 4),
        }
    return out


def run(smoke: bool = False) -> dict:
    if smoke:
        rec = bench_serve(requests=12, batch=4, prompt_len=16, max_new=12)
        rec["ep_overlap"] = ep_overlap_audit()
        return rec
    rec = bench_serve(arch="qwen2.5-3b", requests=16, batch=4,
                      prompt_len=32, max_new=16)
    rec["ep_overlap"] = ep_overlap_audit()
    return rec


if __name__ == "__main__":
    print("name,value,unit,note")
    run(smoke=True)
