"""Paper Fig 3 / Fig 11 analogue: scale-out behaviour per transport.

The paper's experiment: fix the dataset, add servers, compare GbE vs
IPoIB-TCP vs RDMA+scheduling.  The TPU rendition models one TPC-H-like
shuffle-heavy query (Q17 volumes from bench_tpch) across cluster sizes for
three transports:

* ``gbe``      — 0.125 GB/s links, unscheduled (contention),
* ``ib_tcp``   — 4 GB/s links, unscheduled + per-byte CPU overhead
  (the paper's 100-190 % core utilisation -> compute stolen from the query),
* ``ib_rdma``  — 4 GB/s links, round-robin scheduled, ~4 % CPU overhead.

Speedup is vs 1 server with local compute time fixed per tuple — the same
presentation as Fig 3 (their numbers: GbE 0.17x, RDMA+sched 3.5x at n=6).
"""

from repro.core import topology as T
from .common import emit

COMPUTE_S = 1.0           # single-node compute time for the query
SHUFFLE_BYTES = 0.6e9     # bytes a full shuffle moves at SF 100 (Q17-ish)
TCP_CPU_PER_GB = 0.45     # seconds of core time stolen per GB (paper's 190 %)


def query_time(n: int, link_gbps: float, scheduled: bool, cpu_per_gb: float) -> float:
    compute = COMPUTE_S / n
    if n == 1:
        return compute
    per_pair = SHUFFLE_BYTES / n / max(n - 1, 1)
    link_bw = link_gbps * 1e9
    net = (n - 1) * per_pair / link_bw
    if not scheduled:
        net /= T.contention_factor(n)
    cpu = cpu_per_gb * (SHUFFLE_BYTES / n) / 1e9
    return compute + net + cpu


def run():
    for n in (1, 2, 3, 4, 5, 6, 8, 16, 64, 256):
        base = query_time(1, 4, True, 0)
        for name, gbps, sched, cpu in (
            ("gbe", 0.125, False, TCP_CPU_PER_GB),
            ("ib_tcp", 4.0, False, TCP_CPU_PER_GB),
            ("ib_rdma_sched", 4.0, True, 0.02),
            ("tpu_ici_sched", 50.0, True, 0.0),
        ):
            s = base / query_time(n, gbps, sched, cpu)
            emit(f"fig3/speedup_{name}", f"{s:.2f}", "x", f"n={n}")
    emit("fig3/paper_claim", "3.5", "x", "RDMA+sched at n=6 (paper)")
    emit("fig3/paper_claim_gbe", "0.17", "x", "GbE at n=6 (paper ~6x slower)")


if __name__ == "__main__":
    run()
