"""Query serving: warm-vs-cold plan-cache latency and multi-tenant QPS.

The serving claim of this PR, as checked numbers:

* ``cold_over_warm_ratio`` — for a repeated Q3/Q17 template, the first
  request pays plan + trace + compile while the second rides the plan +
  compile cache; the acceptance bar is warm TTFR < 0.2x cold (in practice
  it is orders of magnitude under it).
* ``engine_vs_serial_qps_ratio`` — a seeded multi-tenant TPC-H mix served
  by :class:`~repro.serve.QueryServeEngine` (fair-share admission, shared
  tuned multiplexer, cached plans/executors) must sustain STRICTLY higher
  QPS than serial one-at-a-time execution of the same stream on the same
  mesh, where every request replans and retraces (``run_query`` — the
  status quo this PR replaces).
* ``ttfr_p50_s`` / ``ttfr_p99_s`` and ``cache_hit_fraction`` — the tail
  latency and hit-rate trajectory CI records per PR.

``run(smoke=True)`` returns the record written to ``BENCH_qserve.json``
and gated by ``benchmarks.run --compare``.
"""

from __future__ import annotations

import time

from .common import emit

WARM_TTFR_BAR = 0.2  # acceptance: warm TTFR < 0.2x cold


def _ctx1():
    from repro.relational.context import ExecutionContext

    return ExecutionContext(num_shards=1)


def bench_qserve(
    sf: float, requests: int, seed: int = 0, trace_dir: str | None = None
) -> dict:
    import numpy as np

    from repro.obs.trace import Tracer
    from repro.relational import datagen
    from repro.relational.planner import tpch
    from repro.relational.planner.plan_cache import PlanCache
    from repro.serve import QueryRequest, QueryServeEngine, make_query_mix

    tabs = datagen.gen_all(sf)
    mix_names = ("q1", "q3", "q6", "q17")
    templates = {n: tpch.ALL_QUERIES[n]() for n in mix_names}
    names = sorted({t for pq in templates.values() for t in pq.tables})
    tables = {name: tabs[name] for name in names}
    rec: dict = {"sf": sf, "num_requests": requests}
    _CTX1 = _ctx1()

    # -- repeated template: cold (plan+trace+compile) vs warm (cache) ------
    for qname in ("q3", "q17"):
        engine = QueryServeEngine(
            tables, _CTX1, num_slots=2, cache=PlanCache()
        )
        (cold,) = engine.serve([QueryRequest("t0", templates[qname])])
        (warm,) = engine.serve([QueryRequest("t0", templates[qname])])
        assert warm.plan_cache_hit and warm.executor_cache_hit
        assert warm.ttfr_s < WARM_TTFR_BAR * cold.ttfr_s, (
            f"{qname}: warm TTFR {warm.ttfr_s:.4f}s not under "
            f"{WARM_TTFR_BAR}x cold {cold.ttfr_s:.4f}s"
        )
        rec[qname] = dict(
            cold_ttfr_s=cold.ttfr_s,
            warm_ttfr_s=warm.ttfr_s,
            cold_over_warm_ratio=cold.ttfr_s / warm.ttfr_s,
        )
        emit(f"qserve_{qname}_cold_ttfr", f"{cold.ttfr_s:.4f}", "s",
             "plan+trace+compile")
        emit(f"qserve_{qname}_warm_ttfr", f"{warm.ttfr_s:.4f}", "s",
             "plan cache + executor memo")

    # -- multi-tenant mix: engine vs serial one-at-a-time ------------------
    # Traced: every admission round and request lands in one tracer, and
    # each request's QueryTrace carries its measured-vs-modeled exchange
    # bytes — the serving-side model-error trajectory CI records.
    tracer = Tracer()
    stream = make_query_mix(
        list(templates.values()), ("alice", "bob", "carol"), requests,
        seed=seed,
    )
    engine = QueryServeEngine(
        tables, _CTX1.with_(trace=tracer), num_slots=4, cache=PlanCache(),
        templates=list(templates.values()),
    )
    t0 = time.perf_counter()
    engine.serve(stream)
    qps_engine = requests / (time.perf_counter() - t0)

    # Serial baseline: the same stream, one query at a time, each paying
    # the full plan + trace + compile latency (what every request cost
    # before this engine existed).
    t0 = time.perf_counter()
    for r in stream:
        tpch.run_query(r.query, tables, _CTX1)
    qps_serial = requests / (time.perf_counter() - t0)

    assert qps_engine > qps_serial, (qps_engine, qps_serial)
    erec = engine.record()
    tt = np.asarray([r.ttfr_s for r in stream], dtype=np.float64)
    byte_errs = [
        e.byte_model_err
        for qt in tracer.query_traces
        for e in qt.edges
        if e.byte_model_err is not None
    ]
    rec["mix"] = dict(
        qps=qps_engine,
        serial_qps=qps_serial,
        engine_vs_serial_qps_ratio=qps_engine / qps_serial,
        ttfr_p50_s=float(np.percentile(tt, 50)),
        ttfr_p99_s=float(np.percentile(tt, 99)),
        cache_hit_fraction=erec["cache"]["hit_fraction"],
        traced_requests=len(tracer.query_traces),
        worst_byte_model_err=max(byte_errs) if byte_errs else None,
    )
    if byte_errs:
        emit("qserve_worst_byte_model_err",
             f"{rec['mix']['worst_byte_model_err']:.3f}", "x",
             f"across {len(tracer.query_traces)} traced requests")
    if trace_dir:
        from repro.obs.export import write_trace_dir

        rec["mix"]["trace_path"] = write_trace_dir(
            tracer, trace_dir, basename="qserve_mix"
        )
    emit("qserve_mix_qps", f"{qps_engine:.3f}", "q/s",
         f"{requests} reqs, 3 tenants, 4 slots")
    emit("qserve_mix_serial_qps", f"{qps_serial:.3f}", "q/s",
         "one-at-a-time replan+retrace")
    emit("qserve_mix_qps_ratio", f"{qps_engine / qps_serial:.2f}", "x",
         "engine vs serial")
    emit("qserve_mix_ttfr_p99", f"{rec['mix']['ttfr_p99_s']:.4f}", "s", "")
    emit("qserve_cache_hit_fraction",
         f"{rec['mix']['cache_hit_fraction']:.3f}", "", "plan-level hits")
    return rec


def run(smoke: bool = False, trace_dir: str | None = None) -> dict:
    if smoke:
        return bench_qserve(sf=0.004, requests=10, trace_dir=trace_dir)
    return bench_qserve(sf=0.01, requests=24, trace_dir=trace_dir)


if __name__ == "__main__":
    run()
