"""Paper §3.1: connection/buffer scaling, classic vs hybrid exchange.

Classic: every thread-level exchange operator talks to every other
(n²t² − t connections).  Hybrid: one multiplexer per server (n(n−1)).
The table reproduces the paper's 6×40 numbers and extends to pod scale —
the reason the decoupled-multiplexer design is the only one that survives
512+ chips.
"""

from repro.core import hybrid as H
from .common import emit


def run():
    rows = [
        (6, 40, "paper cluster"),
        (16, 8, "1 exchange axis x 8 lanes"),
        (256, 8, "one pod as servers"),
        (512, 8, "two pods"),
        (1024, 8, "4k-chip fleet"),
    ]
    for n, t, label in rows:
        emit("connections/classic", H.classic_connections(n, t), "", f"{label} n={n},t={t}")
        emit("connections/hybrid", H.hybrid_connections(n, t), "", label)
        emit("buffers/classic", H.classic_buffers_per_operator(n, t), "/op", label)
        emit("buffers/hybrid", H.hybrid_buffers_per_operator(n, t), "/op", label)
        emit("broadcast_threshold/classic", H.broadcast_threshold(n, t, False), "x", label)
        emit("broadcast_threshold/hybrid", H.broadcast_threshold(n, t, True), "x", label)


if __name__ == "__main__":
    run()
