"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig10|skew|conn|tpch|fig3|fig12|kern|serve|qserve|oocore|roofline]
    PYTHONPATH=src python -m benchmarks.run --smoke [--json-dir artifacts/bench]
    PYTHONPATH=src python -m benchmarks.run --compare BASELINE[.json] [--json-dir artifacts/bench]

Emits ``name,value,unit,note`` CSV lines.  ``--smoke`` runs the reduced
CI lane — the static-vs-continuous serve comparison, the exchange pack
A/B, the planned-TPC-H sweep, the adaptive-optimizer skew scenario, the
query-serving warm-vs-cold + multi-tenant QPS check, and the out-of-core
streamed-vs-resident comparison — and writes ``BENCH_serve.json`` /
``BENCH_exchange.json`` / ``BENCH_tpch.json`` / ``BENCH_skew.json`` /
``BENCH_qserve.json`` / ``BENCH_oocore.json`` under ``--json-dir``; the CI
``bench-smoke`` job uploads those as artifacts, so the perf trajectory is
recorded per PR instead of living only in logs.

``--compare`` turns the trajectory into a gate: it diffs the fresh
records in ``--json-dir`` against a baseline (the previous run's uploaded
``BENCH_*.json``, a file or a directory of them) and exits nonzero if any
recorded metric regressed by more than ``--compare-threshold`` (default
2x — wide enough for shared-runner noise, narrow enough to catch a real
slowdown).  Direction is inferred from the metric name: times / bytes /
slot-steps are lower-is-better; ``tok_s`` and the ``*_ratio`` /
``*_fraction`` scores are higher-is-better; everything else (counts,
flags, tuned knobs) is informational and not gated.
The roofline section reads the dry-run artifacts (run
``python -m repro.launch.dryrun`` first).
"""

import argparse
import glob as _glob
import json
import os
import sys

from . import (
    bench_autotune,
    bench_connections,
    bench_exchange,
    bench_kernels,
    bench_oocore,
    bench_qserve,
    bench_scaling,
    bench_schedule,
    bench_serve,
    bench_skew,
    bench_tpch,
)

SECTIONS = {
    "fig10": bench_schedule.run,     # Fig 10(b)/(c): scheduling vs contention
    "skew": bench_skew.run,          # §3.1 skew table
    "conn": bench_connections.run,   # §3.1 connection/buffer scaling
    "tpch": bench_tpch.run,          # Table 2: query runtimes + shuffle bytes
    "fig3": bench_scaling.run,       # Fig 3/11: scale-out per transport
    "fig12": bench_exchange.run,     # Fig 5/12(b) + MoE exchange A/B
    "kern": bench_kernels.run,       # kernel traffic models
    "autotune": bench_autotune.run,  # modeled vs measured multiplexer tuning
    "serve": bench_serve.run,        # static vs continuous batching
    "qserve": bench_qserve.run,      # multi-tenant query serving + plan cache
    "oocore": bench_oocore.run,      # out-of-core morsel streaming + prefetch
}


def roofline():
    import glob

    from repro.launch.roofline import format_table, from_artifact

    rows = []
    art_dir = "artifacts/dryrun_final" if glob.glob("artifacts/dryrun_final/*.json") else "artifacts/dryrun_v2"
    for f in sorted(glob.glob(art_dir + "/*.json")):
        art = json.load(open(f))
        if art.get("status") == "ok" and not art.get("tag"):
            rows.append(from_artifact(art))
    if rows:
        order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
        rows.sort(key=lambda r: (r.mesh, r.arch, order[r.shape]))
        print(format_table(rows))
    else:
        print("roofline: no artifacts found (run repro.launch.dryrun first)")


def smoke(json_dir: str, trace_dir: str | None = None) -> None:
    """The CI bench lane: serve + exchange + tpch records -> BENCH_*.json."""
    os.makedirs(json_dir, exist_ok=True)
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
    print("# --- serve (smoke) ---")
    serve_rec = bench_serve.run(smoke=True)
    print("# --- fig12 (smoke) ---")
    exchange_rec = bench_exchange.run(smoke=True)
    print("# --- tpch (smoke) ---")
    tpch_rec = bench_tpch.run(smoke=True, trace_dir=trace_dir)
    print("# --- skew (smoke) ---")
    skew_rec = bench_skew.run(smoke=True)
    print("# --- qserve (smoke) ---")
    qserve_rec = bench_qserve.run(smoke=True, trace_dir=trace_dir)
    print("# --- oocore (smoke) ---")
    oocore_rec = bench_oocore.run(smoke=True)
    for name, rec in (("BENCH_serve.json", serve_rec),
                      ("BENCH_exchange.json", exchange_rec),
                      ("BENCH_tpch.json", tpch_rec),
                      ("BENCH_skew.json", skew_rec),
                      ("BENCH_qserve.json", qserve_rec),
                      ("BENCH_oocore.json", oocore_rec)):
        path = os.path.join(json_dir, name)
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
        print(f"# wrote {path}")


# Metric-direction inference for --compare.  Checked against the LEAF key
# of each dotted path; higher-is-better wins ties (tok_s ends in "_s" but
# is a throughput).  Unmatched keys (counts, knobs, flags) are not gated.
_HIGHER_IS_BETTER = ("tok_s", "_ratio", "_fraction")
# _model_err: measured-vs-modeled exchange-byte ratio (>= 1, 1 = perfect
# model) — a growing ratio means the planner's estimates are drifting from
# what the devices ship, so it gates lower-is-better like a latency.
_LOWER_IS_BETTER = (
    "_s", "_ms", "_us", "_bytes", "slot_steps", "_steps", "_model_err"
)


def _direction(path: str) -> str | None:
    leaf = path.rsplit(".", 1)[-1]
    if any(leaf.endswith(s) for s in _HIGHER_IS_BETTER):
        return "higher"
    if any(leaf.endswith(s) for s in _LOWER_IS_BETTER):
        return "lower"
    return None


def _numeric_leaves(obj, prefix: str = "") -> dict:
    """Flatten a JSON record to {dotted.path: float} over numeric leaves."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_numeric_leaves(v, f"{prefix}{k}."))
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            out.update(_numeric_leaves(v, f"{prefix}{i}."))
    elif isinstance(obj, bool):
        pass
    elif isinstance(obj, (int, float)):
        out[prefix.rstrip(".")] = float(obj)
    return out


def compare(baseline: str, json_dir: str, threshold: float = 2.0) -> int:
    """Gate the fresh BENCH_*.json in json_dir against a recorded baseline.

    ``baseline`` is either one BENCH_*.json file or a directory of them
    (the previous CI run's artifact).  Returns the number of regressions:
    gated metrics present in BOTH records whose ratio worsened past
    ``threshold``.  Metrics only in one side are reported but never fail —
    benches may be added or retired without poisoning the gate.
    """
    if os.path.isdir(baseline):
        base_files = sorted(_glob.glob(os.path.join(baseline, "BENCH_*.json")))
    else:
        base_files = [baseline]
    if not base_files:
        print(f"# compare: no BENCH_*.json under {baseline!r} — nothing to gate")
        return 0

    regressions = []
    for bf in base_files:
        name = os.path.basename(bf)
        ff = os.path.join(json_dir, name)
        if not os.path.exists(ff):
            print(f"# compare: {name}: no fresh record in {json_dir} — skipped")
            continue
        with open(bf) as f:
            base_leaves = _numeric_leaves(json.load(f))
        with open(ff) as f:
            fresh_leaves = _numeric_leaves(json.load(f))
        gated = checked = 0
        for path, bval in sorted(base_leaves.items()):
            d = _direction(path)
            if d is None or path not in fresh_leaves:
                continue
            checked += 1
            fval = fresh_leaves[path]
            if bval <= 0.0:
                continue  # ratio undefined; nothing sane to gate against
            ratio = fval / bval
            worse = ratio > threshold if d == "lower" else ratio < 1.0 / threshold
            if worse:
                gated += 1
                regressions.append((name, path, d, bval, fval, ratio))
        print(f"# compare: {name}: {checked} metrics checked, {gated} regressed")

    for name, path, d, bval, fval, ratio in regressions:
        print(f"REGRESSION {name}:{path} ({d} is better): "
              f"{bval:.6g} -> {fval:.6g} ({ratio:.2f}x)")
    if not regressions:
        print(f"# compare: OK — no metric regressed past {threshold}x")
    return len(regressions)


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--only", default="all")
    p.add_argument("--smoke", action="store_true",
                   help="reduced CI lane; writes BENCH_*.json to --json-dir")
    p.add_argument("--json-dir", default=os.path.join("artifacts", "bench"))
    p.add_argument("--trace-dir", default=None,
                   help="also write Perfetto/JSON traces of the traced "
                        "smoke benches here (uploaded as CI artifacts)")
    p.add_argument("--compare", default=None, metavar="BASELINE",
                   help="BENCH_*.json file or directory to gate --json-dir "
                        "against; exits nonzero on any regression")
    p.add_argument("--compare-threshold", type=float, default=2.0,
                   help="worsening ratio that counts as a regression")
    args = p.parse_args()
    print("name,value,unit,note")
    if args.smoke:
        smoke(args.json_dir, trace_dir=args.trace_dir)
    if args.compare is not None:
        n = compare(args.compare, args.json_dir, args.compare_threshold)
        sys.exit(1 if n else 0)
    if args.smoke:
        return
    for name, fn in SECTIONS.items():
        if args.only in ("all", name):
            print(f"# --- {name} ---")
            fn()
    if args.only in ("all", "roofline"):
        print("# --- roofline (from dry-run artifacts) ---")
        roofline()


if __name__ == "__main__":
    main()
