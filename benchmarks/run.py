"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig10|skew|conn|tpch|fig3|fig12|kern|roofline]

Emits ``name,value,unit,note`` CSV lines.  The roofline section reads the
dry-run artifacts (run ``python -m repro.launch.dryrun`` first).
"""

import argparse

from . import (
    bench_autotune,
    bench_connections,
    bench_exchange,
    bench_kernels,
    bench_scaling,
    bench_schedule,
    bench_skew,
    bench_tpch,
)

SECTIONS = {
    "fig10": bench_schedule.run,     # Fig 10(b)/(c): scheduling vs contention
    "skew": bench_skew.run,          # \u00a73.1 skew table
    "conn": bench_connections.run,   # \u00a73.1 connection/buffer scaling
    "tpch": bench_tpch.run,          # Table 2: query runtimes + shuffle bytes
    "fig3": bench_scaling.run,       # Fig 3/11: scale-out per transport
    "fig12": bench_exchange.run,     # Fig 5/12(b) + MoE exchange A/B
    "kern": bench_kernels.run,       # kernel traffic models
    "autotune": bench_autotune.run,  # modeled vs measured multiplexer tuning
}


def roofline():
    import glob
    import json

    from repro.launch.roofline import format_table, from_artifact

    rows = []
    art_dir = "artifacts/dryrun_final" if glob.glob("artifacts/dryrun_final/*.json") else "artifacts/dryrun_v2"
    for f in sorted(glob.glob(art_dir + "/*.json")):
        art = json.load(open(f))
        if art.get("status") == "ok" and not art.get("tag"):
            rows.append(from_artifact(art))
    if rows:
        order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
        rows.sort(key=lambda r: (r.mesh, r.arch, order[r.shape]))
        print(format_table(rows))
    else:
        print("roofline: no artifacts found (run repro.launch.dryrun first)")


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--only", default="all")
    args = p.parse_args()
    print("name,value,unit,note")
    for name, fn in SECTIONS.items():
        if args.only in ("all", name):
            print(f"# --- {name} ---")
            fn()
    if args.only in ("all", "roofline"):
        print("# --- roofline (from dry-run artifacts) ---")
        roofline()


if __name__ == "__main__":
    main()
