"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig10|skew|conn|tpch|fig3|fig12|kern|serve|roofline]
    PYTHONPATH=src python -m benchmarks.run --smoke [--json-dir artifacts/bench]

Emits ``name,value,unit,note`` CSV lines.  ``--smoke`` runs the reduced
CI lane — the static-vs-continuous serve comparison, the exchange pack
A/B, and the planned-TPC-H sweep — and writes ``BENCH_serve.json`` /
``BENCH_exchange.json`` / ``BENCH_tpch.json`` under ``--json-dir``; the CI
``bench-smoke`` job uploads those as artifacts, so the perf trajectory is
recorded per PR instead of living only in logs.
The roofline section reads the dry-run artifacts (run
``python -m repro.launch.dryrun`` first).
"""

import argparse
import json
import os

from . import (
    bench_autotune,
    bench_connections,
    bench_exchange,
    bench_kernels,
    bench_scaling,
    bench_schedule,
    bench_serve,
    bench_skew,
    bench_tpch,
)

SECTIONS = {
    "fig10": bench_schedule.run,     # Fig 10(b)/(c): scheduling vs contention
    "skew": bench_skew.run,          # §3.1 skew table
    "conn": bench_connections.run,   # §3.1 connection/buffer scaling
    "tpch": bench_tpch.run,          # Table 2: query runtimes + shuffle bytes
    "fig3": bench_scaling.run,       # Fig 3/11: scale-out per transport
    "fig12": bench_exchange.run,     # Fig 5/12(b) + MoE exchange A/B
    "kern": bench_kernels.run,       # kernel traffic models
    "autotune": bench_autotune.run,  # modeled vs measured multiplexer tuning
    "serve": bench_serve.run,        # static vs continuous batching
}


def roofline():
    import glob

    from repro.launch.roofline import format_table, from_artifact

    rows = []
    art_dir = "artifacts/dryrun_final" if glob.glob("artifacts/dryrun_final/*.json") else "artifacts/dryrun_v2"
    for f in sorted(glob.glob(art_dir + "/*.json")):
        art = json.load(open(f))
        if art.get("status") == "ok" and not art.get("tag"):
            rows.append(from_artifact(art))
    if rows:
        order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
        rows.sort(key=lambda r: (r.mesh, r.arch, order[r.shape]))
        print(format_table(rows))
    else:
        print("roofline: no artifacts found (run repro.launch.dryrun first)")


def smoke(json_dir: str) -> None:
    """The CI bench lane: serve + exchange + tpch records -> BENCH_*.json."""
    os.makedirs(json_dir, exist_ok=True)
    print("# --- serve (smoke) ---")
    serve_rec = bench_serve.run(smoke=True)
    print("# --- fig12 (smoke) ---")
    exchange_rec = bench_exchange.run(smoke=True)
    print("# --- tpch (smoke) ---")
    tpch_rec = bench_tpch.run(smoke=True)
    for name, rec in (("BENCH_serve.json", serve_rec),
                      ("BENCH_exchange.json", exchange_rec),
                      ("BENCH_tpch.json", tpch_rec)):
        path = os.path.join(json_dir, name)
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
        print(f"# wrote {path}")


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--only", default="all")
    p.add_argument("--smoke", action="store_true",
                   help="reduced CI lane; writes BENCH_*.json to --json-dir")
    p.add_argument("--json-dir", default=os.path.join("artifacts", "bench"))
    args = p.parse_args()
    print("name,value,unit,note")
    if args.smoke:
        smoke(args.json_dir)
        return
    for name, fn in SECTIONS.items():
        if args.only in ("all", name):
            print(f"# --- {name} ---")
            fn()
    if args.only in ("all", "roofline"):
        print("# --- roofline (from dry-run artifacts) ---")
        roofline()


if __name__ == "__main__":
    main()
