"""Shared benchmark helpers: timing, CSV emission."""

from __future__ import annotations

import time

import jax


def time_jit(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall seconds of a jit'd callable (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, value, unit: str = "", note: str = ""):
    print(f"{name},{value},{unit},{note}")


__all__ = ["time_jit", "emit"]
