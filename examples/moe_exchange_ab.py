"""A/B the paper's scheduled exchange inside a real MoE layer (8 devices).

    python examples/moe_exchange_ab.py

Runs the expert-parallel dispatch with (a) the round-robin phase schedule
(paper), (b) the one-factorization schedule, and (c) XLA's monolithic
all-to-all, verifying all three produce identical outputs, and prints the
per-variant collective op mix from the compiled HLO.
"""

import os, sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import MeshContext, default_rules, mesh_context
from repro.launch.hlo_cost import analyze
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M


def main():
    cfg = ModelConfig(
        name="ab", family="moe", num_layers=1, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=64, num_experts=32, top_k=4,
        moe_d_ff=96, capacity_factor=4.0, dtype="float32", moe_impl="ep_shardmap",
    )
    params = M.init_moe_layer(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (512, cfg.d_model))
    mesh = make_test_mesh((2, 4), ("data", "model"))
    outs = {}
    for impl in ("round_robin", "one_factorization", "xla"):
        c = cfg.scaled(exchange_impl=impl)
        ctx = MeshContext(mesh=mesh, rules=default_rules(False),
                          exchange_axis="model", exchange_impl=impl)
        with mesh_context(ctx):
            fn = jax.jit(lambda p, x: M.moe_ep(p, c, x))
            outs[impl] = np.asarray(fn(params, x))
            cost = analyze(fn.lower(params, x).compile().as_text())
        mix = {k: f"{v/1e6:.2f}MB" for k, v in cost["collective_bytes"].items()}
        print(f"{impl:18s} collectives: {mix}")
    np.testing.assert_allclose(outs["round_robin"], outs["xla"], rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(outs["one_factorization"], outs["xla"], rtol=2e-4, atol=1e-5)
    print("all three transports produce identical expert outputs ✓")


if __name__ == "__main__":
    main()
