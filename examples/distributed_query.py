"""The paper end-to-end: distributed TPC-H over the scheduled exchange.

    python examples/distributed_query.py          # 8 fake devices

Runs Q1/Q6/Q17/Q3 through the decoupled-exchange engine on an 8-way mesh
(the paper's 6-server cluster, rounded up to a power of two) and checks
every result against the numpy oracle.  Q17 is the paper's own worked
example (Fig 6): partition lineitem by l_partkey + broadcast the filtered
part side, per the hybrid planner's broadcast threshold.
"""

import os, sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.relational import datagen, distributed as D, oracle


def main():
    sf = 0.02
    print(f"generating TPC-H SF={sf} ...")
    tabs = datagen.gen_all(sf)
    li, part = tabs["lineitem"], tabs["part"]
    cust, orders = tabs["customer"], tabs["orders"]
    n = 8

    r1 = D.q1_distributed(li, n)
    o1 = oracle.q1_oracle(li)
    ok1 = all(np.allclose(np.asarray(r1[k]), o1[k], rtol=1e-4) for k in o1)
    print(f"Q1  (pre-aggregation, no shuffle)      ok={ok1}")

    r6 = float(D.q6_distributed(li, n))
    print(f"Q6  (filter+sum)                       ok={np.isclose(r6, oracle.q6_oracle(li), rtol=1e-4)}")

    r17 = float(D.q17_distributed(li, part, n))
    o17 = oracle.q17_oracle(li, part)
    print(f"Q17 (partition+broadcast, paper Fig 6) ok={np.isclose(r17, o17, rtol=1e-3)}  value={r17:,.0f}")

    r3 = D.q3_distributed(cust, orders, li, n)
    o3 = oracle.q3_oracle(cust, orders, li)
    got = dict(zip(np.asarray(r3["o_orderkey"]).tolist(), np.asarray(r3["revenue"]).tolist()))
    ok3 = set(got) == set(o3["o_orderkey"].tolist())
    print(f"Q3  (two-stage shuffle + top-10)       ok={ok3}")
    print("top-3:", sorted(got.items(), key=lambda kv: -kv[1])[:3])


if __name__ == "__main__":
    main()
