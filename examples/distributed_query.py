"""The paper end-to-end: distributed TPC-H over the scheduled exchange.

    python examples/distributed_query.py          # 8 fake devices

Runs Q1/Q6/Q17/Q3 plus the plan-only Q4/Q12/Q18 through the cost-based
query planner on an 8-way mesh (the paper's 6-server cluster, rounded up
to a power of two) and checks every result against the numpy oracle.  Q17
is the paper's own worked example (Fig 6): the planner broadcasts the
filtered part side per the hybrid threshold and shares one lineitem
shuffle — its ``explain()`` is printed first.
"""

import os, sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.relational import datagen, distributed as D, oracle
from repro.relational.context import ExecutionContext


def main():
    sf = 0.02
    print(f"generating TPC-H SF={sf} ...")
    tabs = datagen.gen_all(sf)
    li, part = tabs["lineitem"], tabs["part"]
    cust, orders = tabs["customer"], tabs["orders"]
    n = ExecutionContext(num_shards=8)

    # the cost-based planner's view of Q17 (the paper's Fig 6 example)
    from repro.relational.planner import tpch

    print(tpch.explain_query(tpch.q17(), tpch.tpch_catalog(sf), n))

    r1 = D.q1_distributed(li, n)
    o1 = oracle.q1_oracle(li)
    ok1 = all(np.allclose(np.asarray(r1[k]), o1[k], rtol=1e-4) for k in o1)
    print(f"Q1  (pre-aggregation, no shuffle)      ok={ok1}")

    r6 = float(D.q6_distributed(li, n))
    print(f"Q6  (filter+sum)                       ok={np.isclose(r6, oracle.q6_oracle(li), rtol=1e-4)}")

    r17 = float(D.q17_distributed(li, part, n))
    o17 = oracle.q17_oracle(li, part)
    print(f"Q17 (partition+broadcast, paper Fig 6) ok={np.isclose(r17, o17, rtol=1e-3)}  value={r17:,.0f}")

    r3 = D.q3_distributed(cust, orders, li, n)
    o3 = oracle.q3_oracle(cust, orders, li)
    got = dict(zip(np.asarray(r3["o_orderkey"]).tolist(), np.asarray(r3["revenue"]).tolist()))
    ok3 = set(got) == set(o3["o_orderkey"].tolist())
    print(f"Q3  (broadcast customer + shuffle + top-10) ok={ok3}")
    print("top-3:", sorted(got.items(), key=lambda kv: -kv[1])[:3])

    r4 = D.q4_distributed(li, orders, n)
    ok4 = np.allclose(np.asarray(r4["order_count"]), oracle.q4_oracle(li, orders))
    print(f"Q4  (EXISTS via distinct-keys build)   ok={ok4}")

    r12 = D.q12_distributed(li, orders, n)
    o12 = oracle.q12_oracle(li, orders)
    ok12 = np.allclose(r12["high_line_count"], o12["high_line_count"]) and \
        np.allclose(r12["low_line_count"], o12["low_line_count"])
    print(f"Q12 (co-partition + dense group-by)    ok={ok12}")

    r18 = D.q18_distributed(li, orders, cust, n)
    o18 = oracle.q18_oracle(li, orders, cust)
    ok18 = sorted(np.asarray(r18["o_orderkey"]).tolist()) == \
        sorted(o18["o_orderkey"].tolist())
    print(f"Q18 (HAVING + two joins + top-100)     ok={ok18}")


if __name__ == "__main__":
    main()
