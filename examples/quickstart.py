"""Quickstart: train a small LM for a few steps, checkpoint, and generate.

    PYTHONPATH=src python examples/quickstart.py

Exercises the public API end-to-end on CPU: config -> model -> data ->
train step -> checkpoint -> serving engine.
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.checkpoint import CheckpointManager
from repro.data import Prefetcher, make_batch_iterator
from repro.models import registry as R
from repro.serve import Request, ServeEngine
from repro.train import AdamWConfig, make_train_step
from repro.train.step import TrainState


def main():
    # 1. pick an assigned architecture at smoke scale (same code paths)
    cfg = C.get_smoke_config("qwen2.5-3b")
    api = R.build(cfg)
    print(f"arch={cfg.name}  params={R.param_count(cfg):,}")

    # 2. deterministic data pipeline with background prefetch
    shape = C.ShapeSpec("quickstart", seq_len=64, global_batch=8, kind="train")
    batches = Prefetcher(make_batch_iterator(cfg, shape, seed=0), depth=2)

    # 3. train a few steps with WSD/cosine AdamW
    state = TrainState.create(api, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(api, AdamWConfig(lr=1e-3, warmup_steps=5,
                                                    total_steps=40)))
    mgr = CheckpointManager("/tmp/repro_quickstart", every=10)
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        state, m = step(state, batch)
        mgr.maybe_save(i + 1, state)
        if (i + 1) % 10 == 0:
            print(f"step {i+1:3d}  loss {float(m['loss']):.4f}  lr {float(m['lr']):.2e}")

    # 4. resume from the checkpoint (fault-tolerance path)
    restored_step, state = mgr.restore_latest(jax.eval_shape(lambda: state))
    print(f"restored from step {restored_step}")

    # 5. generate with the serving engine
    eng = ServeEngine(api, batch_size=2, capacity=96)
    reqs = [Request(prompt=np.arange(16, dtype=np.int32) + i, max_new_tokens=8)
            for i in range(2)]
    eng.generate(state.params, reqs)
    for r in reqs:
        print("generated:", r.out_tokens)


if __name__ == "__main__":
    main()
