"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

A scaled llama-family config (~100M params) on the synthetic Markov
corpus — loss drops from ~ln(V) toward the stream's entropy.  Uses the
same launcher as the production path (microbatching, WSD, checkpoints).
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.launch import train as T


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    args = p.parse_args()
    T.main([
        "--arch", "train100m", "--steps", str(args.steps),
        "--seq-len", "256", "--batch", "16", "--microbatches", "2",
        "--lr", "6e-4", "--warmup", "30",
        "--ckpt-dir", "/tmp/repro_100m", "--ckpt-every", "100",
    ])


if __name__ == "__main__":
    main()
