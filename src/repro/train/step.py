"""The jit-able train step: microbatched grad accumulation + AdamW.

Gradient synchronization follows the paper's hybrid two-level policy
*structurally*: parameters are FSDP-sharded over the ``data`` axis and
TP-sharded over ``model``, the batch over ``(pod, data)``.  XLA's SPMD
partitioner then lowers the gradient reduction as reduce-scatter on the
fast in-pod network + all-reduce of the 1/16-size shards across pods +
all-gather in-pod — exactly the hierarchical schedule of
``core.exchange.hierarchical_psum`` (verified from the dry-run HLO in
EXPERIMENTS.md §Dry-run).  ``grad_sync="hierarchical"`` instead calls the
explicit shard_map implementation, used for A/B comparison.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (
    MeshContext,
    build_shardings,
    current_mesh_context,
)
from repro.models import registry
from .optim import AdamWConfig, adamw_init, adamw_update


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jax.Array  # int32 scalar

    @staticmethod
    def create(api: registry.ModelApi, key) -> "TrainState":
        params = api.init(key)
        return TrainState(params=params, opt=adamw_init(params), step=jnp.zeros((), jnp.int32))


def train_state_specs(api: registry.ModelApi) -> Any:
    """Logical-axis tree matching TrainState (for shardings/checkpoint)."""
    p = api.param_specs
    return TrainState(
        params=p,
        opt={"m": p, "v": p, "count": ()},
        step=(),
    )


def _microbatch(batch: Any, num: int) -> Any:
    def split(x):
        B = x.shape[0]
        assert B % num == 0, f"batch {B} not divisible by {num} microbatches"
        return x.reshape((num, B // num) + x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(
    api: registry.ModelApi,
    opt_cfg: AdamWConfig,
) -> Callable[[TrainState, Any], tuple[TrainState, dict]]:
    """Builds ``step(state, batch) -> (state, metrics)`` (jit it yourself).

    Microbatching: the global batch is split into ``cfg.num_microbatches``
    slices scanned sequentially, gradients accumulated in f32.  With remat
    enabled the live activation set is one microbatch × one layer.
    """
    cfg = api.cfg
    num_mb = max(cfg.num_microbatches, 1)

    def loss_fn(params, mb):
        return api.train_loss(params, mb)

    def _pin(grads):
        """§Perf: pin gradients to the parameter sharding so XLA lowers the
        data-parallel reduction as reduce-scatter of shards instead of
        all-reduce of full replicas (cfg.grad_shard_constraint)."""
        if not cfg.grad_shard_constraint:
            return grads
        from repro.distributed.sharding import is_spec_leaf, logical_sharding

        def one(spec, g):
            s = logical_sharding(g.shape, *spec)
            return g if s is None else jax.lax.with_sharding_constraint(g, s)

        return jax.tree.map(one, api.param_specs, grads, is_leaf=is_spec_leaf)

    def step(state: TrainState, batch: Any) -> tuple[TrainState, dict]:
        if num_mb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
            grads = _pin(grads)
        else:
            mbs = _microbatch(batch, num_mb)

            def accum(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(state.params, mb)
                grads = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grads_acc, _pin(grads)
                )
                return (loss_acc + loss, _pin(grads)), None

            zero_grads = _pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            ))
            # overlap_unroll > 1 interleaves consecutive microbatches' HLO so
            # the latency-hiding scheduler can overlap microbatch k+1's MoE
            # dispatch with microbatch k's expert compute (same knob as the
            # transformer's layer scans; numerics-neutral).
            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.float32(0), zero_grads), mbs,
                unroll=max(int(getattr(cfg, "overlap_unroll", 1) or 1), 1),
            )
            loss = loss / num_mb
            grads = jax.tree.map(lambda g: g / num_mb, grads)

        if cfg.grad_sync == "hierarchical":
            ctx = current_mesh_context()
            if ctx is not None and ctx.pod_axis is not None:
                from repro.core.exchange import hierarchical_psum_tree
                from jax.sharding import PartitionSpec as P

                # explicit two-level sync of the (replicated-view) grads
                from repro.compat import shard_map

                grads = shard_map(
                    lambda g: hierarchical_psum_tree(g, "data", ctx.pod_axis),
                    mesh=ctx.mesh,
                    in_specs=P(),
                    out_specs=P(),
                    axis_names={"data", ctx.pod_axis},
                    check_vma=False,
                )(grads)

        new_params, new_opt, metrics = adamw_update(opt_cfg, grads, state.opt, state.params)
        metrics["loss"] = loss
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return step


def state_shardings(api: registry.ModelApi, ctx: MeshContext | None = None):
    """NamedSharding tree for TrainState on the active mesh (None off-mesh)."""
    ctx = ctx or current_mesh_context()
    if ctx is None:
        return None
    spec_tree = train_state_specs(api)
    shapes = jax.eval_shape(lambda k: TrainState.create(api, k), jax.random.PRNGKey(0))
    return build_shardings(spec_tree, shapes, ctx)


__all__ = ["TrainState", "train_state_specs", "make_train_step", "state_shardings"]
