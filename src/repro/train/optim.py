"""AdamW (decoupled weight decay) + WSD / cosine learning-rate schedules.

Implemented from scratch (no optax dependency).  Moments are stored in f32
with the same sharding specs as the parameters — on the production mesh the
optimizer state is FSDP×TP sharded exactly like the master weights, so the
update step is fully local (no collective traffic, paper's "keep fine
work on the fast level" rule applied to the optimizer).

WSD (warmup-stable-decay) is the schedule MiniCPM trains with (assignment
sheet): linear warmup, long constant plateau, short exponential-ish decay.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"  # "cosine" | "wsd" | "constant"
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1  # WSD: fraction of steps spent decaying


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Schedule value at ``step`` (traceable)."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    if cfg.schedule == "wsd":
        decay_steps = jnp.maximum(cfg.total_steps * cfg.decay_frac, 1.0)
        decay_start = cfg.total_steps - decay_steps
        frac = jnp.clip((s - decay_start) / decay_steps, 0.0, 1.0)
        # MiniCPM-style: sqrt-shaped anneal to 10 % of peak
        decay = 1.0 - (1.0 - 0.1) * jnp.sqrt(frac)
        return cfg.lr * warm * decay
    # cosine to 10 % of peak
    frac = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    return cfg.lr * warm * (0.1 + 0.9 * 0.5 * (1.0 + jnp.cos(jnp.pi * frac)))


def adamw_init(params: Any) -> Any:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    cfg: AdamWConfig, grads: Any, opt_state: Any, params: Any
) -> tuple[Any, Any, dict]:
    """One AdamW step; returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, count)

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0  # no decay on norms/biases
        p32 = p32 - lr * (step + decay * p32)
        return p32.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics


__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_at"]
