"""Training substrate: optimizer, schedules, microbatched train step."""

from .optim import AdamWConfig, adamw_init, adamw_update, lr_at
from .step import TrainState, make_train_step, train_state_specs

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "lr_at",
    "TrainState",
    "make_train_step",
    "train_state_specs",
]
