"""Round-robin network schedules (paper §3.2.3, Fig 10a).

The paper decomposes an all-to-all data shuffle among ``n`` servers into
``n - 1`` *conflict-free phases*: in every phase each server sends to exactly
one target and receives from exactly one source, so no switch output port
(InfiniBand) / no torus link (TPU ICI) is shared within a phase.  This is what
buys the +40 % all-to-all throughput of Fig 10(b).

On a TPU torus the same idea maps onto ``jax.lax.ppermute``: a phase is a
permutation of devices, and a *cyclic shift* permutation routes along disjoint
ring links, so phases are contention-free by construction.

Two schedule families are provided:

* ``shift_schedule(n)`` — phase ``k`` sends ``i -> (i + k) mod n``; works for
  any ``n`` and is the schedule the paper uses (their Fig 10(a) is exactly the
  ``n = 4`` instance).
* ``one_factorization(n)`` — for even ``n``, a round-robin-tournament pairing
  where traffic in each phase is bidirectional between disjoint pairs; useful
  on full-duplex links when send and receive volumes are symmetric.

Both satisfy the invariants checked by :func:`verify_schedule` (and by the
hypothesis property tests):

1. every phase is a perfect matching of senders to receivers
   (a permutation with no fixed points),
2. over all phases, every ordered pair ``(i, j)``, ``i != j`` appears exactly
   once — the union is the complete directed graph, i.e. a full all-to-all.
"""

from __future__ import annotations

import dataclasses

Phase = tuple[tuple[int, int], ...]  # ((src, dst), ...)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A communication schedule: an ordered list of conflict-free phases."""

    n: int
    phases: tuple[Phase, ...]
    name: str = "shift"

    @property
    def num_phases(self) -> int:
        return len(self.phases)

    def phase_permutation(self, k: int) -> list[tuple[int, int]]:
        """Phase ``k`` as a ppermute-style ``[(src, dst), ...]`` list."""
        return list(self.phases[k])

    def sources_for(self, device: int) -> list[int]:
        """The source device ``device`` receives from, per phase."""
        out = []
        for phase in self.phases:
            src = [s for (s, d) in phase if d == device]
            assert len(src) == 1, "schedule not a perfect matching"
            out.append(src[0])
        return out

    def targets_for(self, device: int) -> list[int]:
        """The target device ``device`` sends to, per phase."""
        out = []
        for phase in self.phases:
            dst = [d for (s, d) in phase if s == device]
            assert len(dst) == 1, "schedule not a perfect matching"
            out.append(dst[0])
        return out


def shift_schedule(n: int) -> Schedule:
    """The paper's round-robin schedule: phase ``k`` routes ``i -> i + k``.

    ``n - 1`` phases; each phase is a single cyclic shift, which on a ring /
    torus uses every link in the same direction exactly once -> conflict-free.
    """
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    phases = []
    for k in range(1, n):
        phases.append(tuple((i, (i + k) % n) for i in range(n)))
    return Schedule(n=n, phases=tuple(phases), name="shift")


def one_factorization(n: int) -> Schedule:
    """Round-robin tournament pairing for even ``n`` (circle method).

    ``n - 1`` phases; in each phase the devices form ``n/2`` disjoint pairs and
    exchange bidirectionally.  Each unordered pair appears exactly once, so
    each *ordered* pair appears exactly once as well (both directions in the
    same phase).
    """
    if n < 2 or n % 2 != 0:
        raise ValueError(f"one_factorization requires even n >= 2, got {n}")
    phases = []
    # Circle method: fix device n-1, rotate the rest.
    ring = list(range(n - 1))
    for _ in range(n - 1):
        pairs = [(ring[0], n - 1)]
        for i in range(1, n // 2):
            pairs.append((ring[i], ring[n - 1 - i]))
        phase = []
        for a, b in pairs:
            phase.append((a, b))
            phase.append((b, a))
        phases.append(tuple(sorted(phase)))
        ring = [ring[-1]] + ring[:-1]
    return Schedule(n=n, phases=tuple(phases), name="one_factorization")


def verify_schedule(schedule: Schedule) -> None:
    """Raise ``AssertionError`` unless the schedule is conflict-free and full.

    Checks the two invariants from the module docstring.  Used by the property
    tests and (cheaply, once per program) by the exchange layer.
    """
    n = schedule.n
    seen: set[tuple[int, int]] = set()
    for phase in schedule.phases:
        srcs = [s for (s, _) in phase]
        dsts = [d for (_, d) in phase]
        assert sorted(srcs) == list(range(n)), f"senders not a permutation: {srcs}"
        assert sorted(dsts) == list(range(n)), f"receivers not a permutation: {dsts}"
        for s, d in phase:
            assert s != d, f"self-send {s}->{d} wastes a phase slot"
            assert (s, d) not in seen, f"duplicate pair {(s, d)}"
            seen.add((s, d))
    assert len(seen) == n * (n - 1), (
        f"schedule covers {len(seen)} ordered pairs, expected {n * (n - 1)}"
    )


def make_schedule(n: int, kind: str = "shift") -> Schedule:
    if kind == "shift":
        return shift_schedule(n)
    if kind == "one_factorization":
        return one_factorization(n)
    raise ValueError(f"unknown schedule kind {kind!r}")


def ring_hops(n: int, shift: int) -> int:
    """Number of unidirectional ring hops a cyclic shift by ``shift`` takes.

    Used by the topology cost model: on a bidirectional ring the effective
    hop count of shift ``k`` is ``min(k, n - k)`` (route the short way).
    """
    shift %= n
    return min(shift, n - shift)


def ring_phase_load(phase: Phase, n: int) -> int:
    """Peak link load of one phase on a bidirectional ring, short-way routed.

    Each message ``(s, d)`` occupies every link on its minimal ring path
    (clockwise if ``(d - s) mod n <= n/2``, counter-clockwise otherwise; ties
    go clockwise).  Links are directed, so the two directions don't contend.
    The returned value is the number of messages sharing the busiest link —
    the factor by which that phase's wire time stretches relative to a
    contention-free hop (load 1).  A cyclic shift by ``+-1`` has load 1; a
    shift by ``k`` has load ``min(k, n - k)`` (= :func:`ring_hops`).
    """
    cw = [0] * n  # cw[i]: link i -> i+1
    ccw = [0] * n  # ccw[i]: link i -> i-1
    for s, d in phase:
        fwd = (d - s) % n
        if fwd == 0:
            continue
        if fwd <= n - fwd:
            for h in range(fwd):
                cw[(s + h) % n] += 1
        else:
            for h in range(n - fwd):
                ccw[(s - h) % n] += 1
    return max(max(cw), max(ccw))


def schedule_ring_loads(schedule: Schedule) -> list[int]:
    """Per-phase peak ring-link loads (see :func:`ring_phase_load`)."""
    return [ring_phase_load(p, schedule.n) for p in schedule.phases]


def schedule_link_time(
    n: int,
    bytes_per_pair: float,
    link_bandwidth: float,
    scheduled: bool,
    contention_factor: float | None = None,
) -> float:
    """Analytic all-to-all time on an ``n``-port non-blocking switch.

    Scheduled: ``n - 1`` phases, each phase moves ``bytes_per_pair`` per link
    at full ``link_bandwidth``.  Unscheduled: same total bytes but effective
    bandwidth is degraded by switch contention (HOL blocking / credit
    starvation, paper §3.2.3); the degradation factor defaults to the one
    measured by :mod:`repro.core.topology`'s simulator (~0.71 for n = 8,
    matching the paper's "+40 %").
    """
    total = (n - 1) * bytes_per_pair / link_bandwidth
    if scheduled:
        return total
    if contention_factor is None:
        from .topology import contention_factor as _cf

        contention_factor = _cf(n)
    return total / contention_factor


__all__ = [
    "Phase",
    "Schedule",
    "shift_schedule",
    "one_factorization",
    "verify_schedule",
    "make_schedule",
    "ring_hops",
    "ring_phase_load",
    "schedule_ring_loads",
    "schedule_link_time",
]
