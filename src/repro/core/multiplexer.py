"""The communication multiplexer (paper §3.2.2).

The paper gives each server ONE dedicated network endpoint that all local
exchange operators talk to; only multiplexers are interconnected
(``n(n-1)`` connections instead of ``n^2 t^2 - t``), messages come from a
reusable registered pool (zero-copy RDMA), are NUMA-local, and are sent
according to the round-robin schedule.

The JAX rendition is a thin object that carries the per-mesh communication
*policy* — which schedule, which collective strategy per network level —
so that models and the relational engine never choose transports themselves
(they are "decoupled": they see only this interface).  Concretely:

* message pool / zero-copy  -> ``donate_buffers`` jit wrapper + the
  streaming ``shuffle_consume`` (one chunk in flight, reused accumulator);
* NUMA-aware allocation     -> chunk layouts are kept shard-local; nothing
  is gathered to a single device;
* dedicated network thread  -> XLA's async DMA engine; phases are issued
  back-to-back so the DMA engine stays busy while the VPU/MXU computes.

Beyond the transport (``impl``), the multiplexer carries the partition/pack
policy for :meth:`CommMultiplexer.hash_shuffle`:

* ``pack_impl`` — ``"xla"`` (one-hot/cumsum reference) or ``"pallas"`` (the
  fused partition+pack kernel; no ``[rows, num_dest]`` intermediate).  Both
  produce bit-identical buffers, counts, and drop counts.
* ``pipeline_chunks`` — split the shuffle into this many row chunks and
  double-buffer: pack chunk ``k + 1`` while chunk ``k``'s phases ship.
  Must divide both the row count and the capacity of every shuffle routed
  through this multiplexer; a shuffle it does not divide runs unchunked
  (with a warning) rather than failing.
* ``transport_chunks`` — split each scheduled phase's message into this many
  independent ppermutes (finer-grained DMA pipelining).  Must divide the
  per-chunk capacity; falls back to whole messages (with a warning)
  otherwise.  The monolithic ``"xla"`` transport ignores it.

None of the knobs changes *what* is delivered — only how it is packed and
phased; ``tests/test_exchange_equiv.py`` holds every combination to the same
results.  Capacity overflow is likewise policy-free: ``hash_shuffle``
returns a psum'd ``dropped`` count and the relational layer raises on any
nonzero value (PR 1's overflow-raises contract) — rows are never silently
lost.

Knob values come from one of two places: explicit arguments to
:func:`make_multiplexer` (benchmarks, A/B tests), or — the default on the
query paths — the topology-driven autotuner
(:func:`repro.core.autotune.tune_multiplexer` via
``make_multiplexer(auto=True, table_stats=...)``), which minimizes the
modeled pack+shuffle makespan for the actual message sizes and mesh.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import warnings
from typing import Any, Callable, Sequence

import jax

from . import exchange
from .hybrid import HybridPlan, plan_for_mesh
from .schedule import make_schedule, verify_schedule
from .topology import ChipSpec, V5E


@dataclasses.dataclass(frozen=True)
class CommMultiplexer:
    """Per-mesh communication policy object.

    ``impl`` selects the shuffle transport: ``"round_robin"`` (the paper's
    scheduled phases), ``"one_factorization"`` (bidirectional pairing), or
    ``"xla"`` (monolithic all-to-all baseline — the 'unscheduled' transport
    the paper improves on).  ``pack_impl``/``pipeline_chunks``/
    ``transport_chunks`` tune the partition+pack hot path (module docstring).
    """

    plan: HybridPlan
    impl: exchange.AllToAllImpl = "round_robin"
    pack_impl: exchange.PackImpl = "xla"
    pipeline_chunks: int = 1
    transport_chunks: int = 1
    # Two-level meshes: how broadcast-style build sides cross the pod axis
    # ("broadcast" replicates over DCI, "reshard" hash-exchanges them like
    # the probe side).  Set by the autotuner; ignored on single-pod meshes.
    cross_pod: str = "broadcast"

    def describe(self) -> dict:
        """JSON-able knob summary — what actually carries the traffic.
        Trace exports attach this so a Perfetto timeline (or a bench
        record) names the transport/pack schedule its exchanges rode."""
        return dict(
            impl=str(self.impl),
            pack_impl=str(self.pack_impl),
            pipeline_chunks=int(self.pipeline_chunks),
            transport_chunks=int(self.transport_chunks),
            cross_pod=str(self.cross_pod),
            small_axes=list(self.plan.small_axes),
            large_axes=list(self.plan.large_axes),
            num_pods=int(self.plan.num_pods),
        )

    # -- exchange-operator entry points (must be inside shard_map) ---------

    def all_to_all(self, x: jax.Array, axis_name: str) -> jax.Array:
        self.plan.validate_axis_for_alltoall(axis_name)
        transport = self.transport_chunks
        if transport > 1 and (x.ndim < 2 or x.shape[1] % transport):
            warnings.warn(
                f"transport_chunks={transport} does not divide message dim of "
                f"shape {x.shape}; shipping whole messages",
                stacklevel=2,
            )
            transport = 1
        return exchange.all_to_all(
            x, axis_name, impl=self.impl, num_chunks=transport
        )

    def _resolve_transport(self, message_dim: int) -> int:
        """Transport sub-chunking that divides ``message_dim`` (else 1)."""
        transport = self.transport_chunks
        if transport > 1 and message_dim % transport:
            warnings.warn(
                f"transport_chunks={transport} does not divide message dim "
                f"{message_dim}; shipping whole messages",
                stacklevel=3,
            )
            transport = 1
        return transport

    # -- token routing: the one exchange fabric -----------------------------

    def dispatch(self, x: jax.Array, axis_name: str) -> jax.Array:
        """All-to-all token dispatch over the WHOLE mesh, pod axis included.

        On a single-level mesh this is exactly :meth:`all_to_all` over
        ``axis_name``.  On a two-level mesh the leading dim must span the
        JOINT ``(pod, axis_name)`` axis (``N = P * n``, mesh device order)
        and the route is :func:`repro.core.exchange.dispatch_two_level`:
        one coarse message per peer pod over the slow network, then the
        fine in-pod scheduled all-to-all — the same two hops as
        :meth:`hash_shuffle_global`, generalized beyond hash keys to any
        caller-assigned destination layout (MoE expert dispatch).  Both
        hops are pure permutations, so the result is bit-identical to a
        flat all-to-all over the joint axis.
        """
        pod = self.plan.pod_axis
        if pod is None:
            return self.all_to_all(x, axis_name)
        self.plan.validate_axis_for_alltoall(axis_name)
        transport = self._resolve_transport(
            self.plan.num_pods * math.prod(x.shape[1:])
        )
        return exchange.dispatch_two_level(
            x, axis_name, pod, impl=self.impl, num_chunks=transport
        )

    def combine(self, x: jax.Array, axis_name: str) -> jax.Array:
        """The return trip of :meth:`dispatch` (fine in-pod hop first, then
        one coarse message per peer pod).  Same flat-all-to-all contract,
        same bit-identity guarantee."""
        pod = self.plan.pod_axis
        if pod is None:
            return self.all_to_all(x, axis_name)
        self.plan.validate_axis_for_alltoall(axis_name)
        transport = self._resolve_transport(
            self.plan.num_pods * math.prod(x.shape[1:])
        )
        return exchange.combine_two_level(
            x, axis_name, pod, impl=self.impl, num_chunks=transport
        )

    def shuffle_consume(
        self,
        x: jax.Array,
        axis_name: str,
        consume: Callable[[Any, jax.Array, jax.Array], Any],
        init: Any,
    ) -> Any:
        """Streaming shuffle; overlaps phase k+1 comm with phase k compute."""
        self.plan.validate_axis_for_alltoall(axis_name)
        if self.impl == "xla":
            # No phases to stream over: materialize then fold.
            y = exchange.xla_all_to_all(x, axis_name)
            acc = init
            for j in range(x.shape[0]):
                acc = consume(acc, y[j], j)
            return acc
        sched = "shift" if self.impl == "round_robin" else self.impl
        return exchange.scheduled_all_to_all_consume(
            x, axis_name, consume, init, schedule=sched
        )

    def _resolve_chunks(self, rows: int, capacity: int) -> tuple[int, int]:
        """Chunk knobs that actually divide this shuffle's shapes, warning
        and falling back (unchunked / whole messages) where they do not."""
        chunks = self.pipeline_chunks
        if chunks > 1 and (rows % chunks or capacity % chunks):
            warnings.warn(
                f"pipeline_chunks={chunks} does not divide rows={rows} / "
                f"capacity={capacity}; running this shuffle unchunked",
                stacklevel=3,
            )
            chunks = 1
        transport = self.transport_chunks
        if transport > 1 and (capacity // chunks) % transport:
            warnings.warn(
                f"transport_chunks={transport} does not divide per-chunk "
                f"capacity {capacity // chunks}; shipping whole messages",
                stacklevel=3,
            )
            transport = 1
        return chunks, transport

    def hash_shuffle(
        self,
        keys: jax.Array,
        rows: jax.Array,
        axis_name: str,
        capacity: int,
        valid: jax.Array | None = None,
    ):
        self.plan.validate_axis_for_alltoall(axis_name)
        chunks, transport = self._resolve_chunks(keys.shape[0], capacity)
        return exchange.hash_shuffle(
            keys, rows, axis_name, capacity, impl=self.impl, valid=valid,
            pack_impl=self.pack_impl, num_chunks=chunks,
            transport_chunks=transport,
        )

    def hash_shuffle_spill(
        self,
        keys: jax.Array,
        rows: jax.Array,
        axis_name: str,
        capacity: int,
        valid: jax.Array | None = None,
    ):
        """Capacity-bounded exchange that flags overflow instead of dropping.

        Returns ``(rows_out, valid_out, spilled)`` with ``spilled`` a
        sender-local per-row mask; the caller parks those rows in a
        host-memory overflow partition and drains them later
        (``relational.planner.stream``).  Single-level meshes only: on a pod
        mesh the streamed executor sizes messages for zero drop instead,
        because the two-level hop re-packs rows mid-flight and the sender
        can no longer name its spilled rows.
        """
        if self.plan.pod_axis is not None:
            raise NotImplementedError(
                "spill-capable exchange is single-level only; pod meshes "
                "must size streamed exchanges for zero drop"
            )
        self.plan.validate_axis_for_alltoall(axis_name)
        return exchange.hash_shuffle_spill(
            keys, rows, axis_name, capacity, impl=self.impl, valid=valid,
            pack_impl=self.pack_impl,
        )

    def broadcast(self, x: jax.Array, axis_name: str) -> jax.Array:
        impl = "xla" if self.impl == "xla" else "ring"
        return exchange.broadcast_exchange(x, axis_name, impl=impl)

    # -- global (two-level) exchange entry points ---------------------------

    def hash_shuffle_global(
        self,
        keys: jax.Array,
        rows: jax.Array,
        axis_name: str,
        capacity: int,
        valid: jax.Array | None = None,
    ):
        """Repartition by key hash over the WHOLE mesh, pod axis included.

        On a single-level mesh this is exactly :meth:`hash_shuffle`.  On a
        two-level mesh it runs the sanctioned coarse route
        (:func:`repro.core.exchange.hash_shuffle_two_level`): one message
        per peer pod over the slow network, then the fine in-pod shuffle
        over ``axis_name`` — the multiplexer-granularity exchange of paper
        §3.2.2.  The plan still rejects ``axis_name`` being the pod axis
        itself (that would be a fine-grained DCI shuffle).
        """
        pod = self.plan.pod_axis
        if pod is None:
            return self.hash_shuffle(keys, rows, axis_name, capacity, valid)
        self.plan.validate_axis_for_alltoall(axis_name)
        chunks, transport = self._resolve_chunks(
            keys.shape[0] * self.plan.num_pods, capacity * self.plan.num_pods
        )
        return exchange.hash_shuffle_two_level(
            keys, rows, axis_name, pod, capacity, impl=self.impl,
            valid=valid, pack_impl=self.pack_impl, num_chunks=chunks,
            transport_chunks=transport,
        )

    def broadcast_global(self, x: jax.Array, axis_name: str) -> jax.Array:
        """Every device ends with every device's chunk, pods included.

        In-pod ring all-gather first (fast network), then one coarse
        all-gather of the pod-aggregated block over the pod axis — each byte
        crosses DCI once per remote pod, at pod granularity.  Result leading
        dims are ``[num_pods, n]`` on a two-level mesh, ``[n]`` otherwise
        (callers flatten; every device holds an identical copy either way).
        """
        y = self.broadcast(x, axis_name)
        pod = self.plan.pod_axis
        if pod is None:
            return y
        impl = "xla" if self.impl == "xla" else "ring"
        return exchange.broadcast_exchange(y, pod, impl=impl)

    # -- gradient sync (hybrid two-level vs flat) ---------------------------

    def psum_tree(self, tree: Any, data_axes: tuple[str, ...]) -> Any:
        """All-reduce a gradient tree over the data-parallel axes.

        Hierarchical (RS-in-pod -> AR-cross-pod -> AG-in-pod) when the plan
        has a large-network axis; flat otherwise.
        """
        if self.plan.grad_sync == "hierarchical" and len(data_axes) >= 2:
            outer = [a for a in data_axes if a in self.plan.large_axes]
            inner = [a for a in data_axes if a not in self.plan.large_axes]
            if outer and inner:
                return exchange.hierarchical_psum_tree(tree, inner[0], outer[0])
        return exchange.flat_psum_tree(tree, data_axes)


# one_factorization->shift downgrade warnings already issued, keyed by the
# offending axis sizes — a long-lived process builds a multiplexer per query,
# and repeating the identical warning every time is pure noise.  (Tests that
# assert the warning clear this set first.)
_warned_odd_axis_sizes: set[tuple[int, ...]] = set()


def resolve_schedule_impl(
    impl: exchange.AllToAllImpl, small_axis_sizes: Sequence[int]
) -> exchange.AllToAllImpl:
    """Downgrade an impl that cannot run on the given shuffle-axis sizes.

    ``one_factorization`` (the round-robin-tournament pairing) only exists
    for even ``n``; on a mesh with an odd-sized shuffle axis the schedule
    constructor would raise at trace time, *inside* the first query.  Fall
    back to the ``shift`` schedule (valid for every ``n``, and what the
    paper itself uses) at multiplexer-build time instead, with a warning —
    issued once per distinct set of odd axis sizes, not per call.
    """
    if impl == "one_factorization" and any(
        s > 1 and s % 2 for s in small_axis_sizes
    ):
        odd = tuple(s for s in small_axis_sizes if s > 1 and s % 2)
        if odd not in _warned_odd_axis_sizes:
            _warned_odd_axis_sizes.add(odd)
            warnings.warn(
                f"one_factorization schedules need even axis sizes, got "
                f"{list(odd)}; falling back to the round_robin (shift) "
                "schedule",
                stacklevel=3,
            )
        return "round_robin"
    return impl


def make_multiplexer(
    mesh: jax.sharding.Mesh,
    impl: exchange.AllToAllImpl = "round_robin",
    pack_impl: exchange.PackImpl = "xla",
    pipeline_chunks: int = 1,
    transport_chunks: int = 1,
    auto: bool = False,
    table_stats=None,
    chip: ChipSpec = V5E,
    topology: str = "ring",
    refine: bool = False,
    broadcast_stats=None,
    cross_pod: str = "broadcast",
) -> CommMultiplexer:
    """Build the multiplexer for a mesh; verifies the schedule once (cheap).

    Mirrors the paper's startup step of establishing the multiplexer
    connections before query processing begins.  Every small (shuffle-
    eligible) axis's schedule is verified here — an impl the mesh cannot
    support is downgraded by :func:`resolve_schedule_impl` rather than
    letting an invalid config reach the runtime.

    With ``auto=True`` (or ``impl="auto"``) every knob — transport,
    ``pack_impl``, ``pipeline_chunks``, ``transport_chunks``, and on
    two-level meshes the ``cross_pod`` build-side strategy — is derived
    from the :mod:`repro.core.topology` cost model by
    :func:`repro.core.autotune.tune_multiplexer` instead of taken from the
    arguments.  ``table_stats`` (one :class:`repro.core.autotune.TableStats`
    per exchange the multiplexer will carry) is required;
    ``broadcast_stats`` optionally describes a broadcast-style join's build
    side so the tuner can price cross-pod broadcast vs reshard; ``chip`` /
    ``topology`` select the hardware model and ``refine=True`` additionally
    micro-benchmarks the best modeled candidates on the live mesh.
    """
    if auto or impl == "auto":
        from .autotune import tune_multiplexer

        if table_stats is None:
            raise ValueError(
                "make_multiplexer(auto=True) needs table_stats — the "
                "rows/row_bytes of the exchanges this multiplexer will carry"
            )
        tuned = tune_multiplexer(
            mesh, table_stats, chip=chip, topology=topology, refine=refine,
            broadcast_stats=broadcast_stats,
        )
        impl = tuned.impl
        pack_impl = tuned.pack_impl
        pipeline_chunks = tuned.pipeline_chunks
        transport_chunks = tuned.transport_chunks
        if tuned.cross_pod is not None:
            cross_pod = tuned.cross_pod
    plan = plan_for_mesh(
        tuple(mesh.axis_names), tuple(mesh.devices.shape), exchange=(
            "xla" if impl == "xla" else "round_robin"
        )
    )
    small_sizes = [
        size
        for ax, size in zip(mesh.axis_names, mesh.devices.shape)
        if ax not in plan.large_axes
    ]
    impl = resolve_schedule_impl(impl, small_sizes)
    if impl != "xla":
        kind = "shift" if impl == "round_robin" else impl
        for size in small_sizes:
            if size > 1:
                verify_schedule(make_schedule(size, kind))
    if cross_pod not in ("broadcast", "reshard"):
        raise ValueError(f"unknown cross_pod strategy {cross_pod!r}")
    return CommMultiplexer(
        plan=plan,
        impl=impl,
        pack_impl=pack_impl,
        pipeline_chunks=pipeline_chunks,
        transport_chunks=transport_chunks,
        cross_pod=cross_pod,
    )


# ----------------------------------------------------------------------------
# Ambient multiplexer: lets code that cannot take a mux argument (the MoE
# layer inside a model's decode step) still route its exchanges through the
# session's tuned policy object.
# ----------------------------------------------------------------------------

_ACTIVE_MUX: list[CommMultiplexer] = []


@contextlib.contextmanager
def use_multiplexer(mux: CommMultiplexer):
    """Make ``mux`` the ambient multiplexer inside the with-block.

    The serving engine wraps its decode loop in this so the expert-parallel
    dispatch (``models/moe.py``) traces against the engine's auto-tuned
    multiplexer — same schedules as the relational exchanges — without
    threading a mux through the uniform model API.  Consulted at TRACE time:
    jit caches compiled under one mux are only reused within the same knobs
    (the engine owns both the mux and its jitted callables, so this holds).
    """
    _ACTIVE_MUX.append(mux)
    try:
        yield mux
    finally:
        _ACTIVE_MUX.pop()


def current_multiplexer() -> CommMultiplexer | None:
    """The innermost :func:`use_multiplexer` mux, or None."""
    return _ACTIVE_MUX[-1] if _ACTIVE_MUX else None


def donate_buffers(fn: Callable, argnums: tuple[int, ...]) -> Callable:
    """Message-pool discipline: reuse communication buffers across calls.

    The paper registers RDMA memory regions once and recycles them through a
    pool because registration (pinning) is expensive.  XLA's analogue is
    buffer donation: the donated input's device memory is reused for outputs,
    so steady-state steps allocate nothing.
    """
    return jax.jit(fn, donate_argnums=argnums)


__all__ = [
    "CommMultiplexer",
    "make_multiplexer",
    "resolve_schedule_impl",
    "use_multiplexer",
    "current_multiplexer",
    "donate_buffers",
]
