"""Topology-driven autotuner for the communication multiplexer.

The paper's core claim is that the transport strategy must be *derived* from
the network's characteristics — message size vs link latency (Fig 10c),
schedule phase count vs switch contention (Fig 10b) — not left to the
operator.  This module closes that loop for the JAX rendition: it prices
every legal :class:`~repro.core.multiplexer.CommMultiplexer` configuration
with the :mod:`repro.core.topology` cost model and returns the knob setting
that minimizes the modeled shuffle makespan.

The knobs and the model
-----------------------

For one decoupled exchange of ``rows`` packed rows of ``row_bytes`` each,
over a shuffle axis of ``n`` units, a configuration is

* ``impl`` — scheduled shift phases (``"round_robin"``), bidirectional
  pairing (``"one_factorization"``, even ``n``), or the monolithic
  ``"xla"`` all-to-all (one launch, but contention-degraded wire time);
* ``pack_impl`` — ``"xla"`` one-hot/cumsum (O(rows x n) HBM traffic) vs the
  fused ``"pallas"`` partition+pack kernel (O(rows));
* ``pipeline_chunks`` (``C``) — split the shuffle into ``C`` row chunks and
  double-buffer: pack chunk ``k + 1`` while chunk ``k``'s phases ship;
* ``transport_chunks`` (``t``) — split each phase message into ``t``
  independent ppermutes (finer DMA granularity, one launch each).

Per pipeline chunk the model charges ``pack_c`` =
:func:`~repro.core.topology.pack_time` and ``ship_c`` =
:func:`~repro.core.topology.shuffle_time` (phase launch latencies + link-load
weighted wire time + the small counts exchange).  Chunks overlap pack with
shipping; how much of ``min(pack_c, ship_c)`` the async scheduler can
actually hide grows with the number of independently issued DMAs per chunk
(``(n - 1) * t`` for scheduled impls, 1 for the monolithic all-to-all):

    makespan(C) = C * (pack_c + ship_c)
                  - (C - 1) * (1 - 1 / n_dma) * min(pack_c, ship_c)

Launch latencies make both ``C`` and ``t`` costly for tiny messages (the
model collapses to ``C = t = 1``) while large messages amortize them and buy
overlap — the same size-driven regime change as the paper's Fig 10(c).

Two modes
---------

* **analytical** (default): pure cost-model argmin — deterministic, no
  device work, usable at trace/plan time.
* **empirical refinement** (``refine=True``): micro-benchmark the 2-3 best
  modeled candidates on the live mesh with a synthetic shuffle and keep the
  measured winner — the model prunes the space, the hardware settles it.

Entry points: :func:`tune_multiplexer` here, or
``make_multiplexer(mesh, auto=True, table_stats=...)`` which applies the
tuned knobs directly; the relational queries pass ``impl="auto"`` by default.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Sequence

from .topology import ChipSpec, V5E, pack_time, pod_broadcast_time, shuffle_time

PIPELINE_CANDIDATES = (1, 2, 4, 8)
TRANSPORT_CANDIDATES = (1, 2, 4)


@dataclasses.dataclass(frozen=True)
class TableStats:
    """Shape summary of one exchange, as seen by a single parallel unit.

    ``rows`` is the per-unit row count entering the shuffle, which under the
    zero-drop capacity bound is also the per-destination message capacity;
    ``row_bytes`` the packed row width (int32 columns x 4).
    """

    rows: int
    row_bytes: int

    def __post_init__(self):
        assert self.rows >= 0 and self.row_bytes > 0, (self.rows, self.row_bytes)


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """A multiplexer knob setting plus the model's (and measurement's) view.

    ``candidates`` holds every evaluated ``(impl, pack_impl, pipeline_chunks,
    transport_chunks, modeled_s)`` tuple, best first — the benchmark reports
    it, and it makes the tuner's decision auditable.
    """

    impl: str
    pack_impl: str
    pipeline_chunks: int
    transport_chunks: int
    modeled_s: float
    measured_s: float | None = None
    candidates: tuple = ()
    # Two-level meshes only: how the build side of a broadcast-style join
    # crosses the pod axis — "broadcast" (replicate over DCI, the paper's
    # broadcast join between coarse units) or "reshard" (hash-exchange it
    # like the probe side; wins once the build side outgrows the paper's
    # n - 1 broadcast threshold).  None on single-pod meshes.
    cross_pod: str | None = None
    cross_pod_modeled_s: dict | None = None

    def knobs(self) -> dict:
        return dict(
            impl=self.impl,
            pack_impl=self.pack_impl,
            pipeline_chunks=self.pipeline_chunks,
            transport_chunks=self.transport_chunks,
        )


def ep_capacity(
    tokens_per_shard: int, top_k: int, num_experts: int, capacity_factor: float
) -> int:
    """Per-expert message-buffer capacity (the paper's fixed-size reusable
    pool): ``ceil(capacity_factor * fair_share)`` with a floor of 4.

    THE shared definition — ``models.moe`` sizes its dispatch buffers with
    this and :func:`decode_table_stats` prices them with it, so the tuner
    always models the shapes the MoE layer actually ships.
    """
    fair = tokens_per_shard * top_k / num_experts
    return max(int(math.ceil(capacity_factor * fair)), 4)


def decode_table_stats(cfg, batch_size: int, num_shards: int) -> TableStats:
    """Shape of the EP token dispatch for ONE decode step, per parallel unit.

    At decode every slot contributes one token, so each unit packs
    ``batch_size / num_shards`` tokens x ``top_k`` choices into its
    ``E x C`` per-expert capacity buffers (``C`` from :func:`ep_capacity`,
    the same sizing the MoE layer uses) and ships those — the same
    fixed-size message pool as at train time, just tiny (tens of rows of
    ``d_model`` activations).  Feeding THIS to :func:`tune_multiplexer` is
    what makes the tuner price the per-step messages correctly: launch
    latency dominates at this size, so it collapses to the unchunked
    scheduled transport instead of inheriting chunking tuned for
    relational tables.

    ``cfg`` is duck-typed (``num_experts``/``top_k``/``capacity_factor``/
    ``d_model``/``dtype``) so core does not import the configs package.
    """
    import numpy as np

    E = int(getattr(cfg, "num_experts", 0) or 1)
    k = int(getattr(cfg, "top_k", 0) or 1)
    t_loc = max(1, batch_size // max(num_shards, 1))
    C = ep_capacity(t_loc, k, E, float(getattr(cfg, "capacity_factor", 1.0)))
    row_bytes = int(cfg.d_model) * np.dtype(getattr(cfg, "dtype", "float32")).itemsize
    return TableStats(rows=E * C, row_bytes=row_bytes)


def moe_expert_time(
    cfg, batch_size: int, num_shards: int, chip: ChipSpec = V5E
) -> float:
    """Modeled expert-FFN seconds for ONE decode step on one parallel unit.

    Each unit owns ``E / num_shards`` experts and receives ``num_shards``
    capacity buffers per local expert, so it batch-matmuls
    ``E_loc * num_shards * C`` slot rows through the SwiGLU (three
    ``d x f`` matmuls = ``6 * d * f`` FLOPs per row — the compute the
    async dispatch pipeline hides exchange DMA behind).  Same duck-typed
    ``cfg`` contract as :func:`decode_table_stats`.
    """
    E = int(getattr(cfg, "num_experts", 0) or 1)
    k = int(getattr(cfg, "top_k", 0) or 1)
    d = int(cfg.d_model)
    f = int(getattr(cfg, "moe_d_ff", 0) or getattr(cfg, "d_ff", d))
    n = max(num_shards, 1)
    t_loc = max(1, batch_size // n)
    C = ep_capacity(t_loc, k, E, float(getattr(cfg, "capacity_factor", 1.0)))
    E_loc = max(E // n, 1)
    slot_rows = E_loc * n * C
    return slot_rows * 6.0 * d * f / chip.peak_flops_bf16


def ep_dispatch_makespan(
    stats: TableStats,
    n: int,
    compute_s: float,
    impl: str = "round_robin",
    pack_impl: str = "xla",
    num_chunks: int = 1,
    transport_chunks: int = 1,
    chip: ChipSpec = V5E,
    topology: str = "ring",
    num_pods: int = 1,
    overlap: bool = True,
) -> float:
    """Modeled makespan of one EP layer: dispatch + expert FFN + combine.

    ``stats`` is the per-unit dispatch shape (:func:`decode_table_stats`),
    ``compute_s`` the expert compute it feeds (:func:`moe_expert_time`).
    ``num_chunks`` splits the capacity buffers into that many chunks
    pipelined exactly like the MoE layer's double-buffered path: chunk
    ``c + 1``'s dispatch DMA runs while chunk ``c``'s experts compute.

    ``overlap=False`` prices the fully serialized schedule —
    ``chunks * (dispatch + compute + combine)`` with no hiding; that is the
    baseline the bench lane compares against.  With overlap on, every chunk
    boundary (the ``chunks - 1`` internal ones plus the cross-layer one the
    transformer's unrolled layer scan exposes) hides
    ``min(compute, exchange)`` scaled by the DMA-independence factor
    ``1 - 1/n_dma`` — the same overlap model as the chunked relational
    shuffle (:func:`exchange_makespan`), extended with the coarse-hop DMAs
    on a pod mesh.
    """
    if stats.rows % num_chunks:
        num_chunks = 1
    chunk = TableStats(rows=stats.rows // num_chunks, row_bytes=stats.row_bytes)
    disp_c = exchange_makespan(
        chunk, n, impl, pack_impl, 1, transport_chunks, chip, topology,
        num_pods,
    )
    comb_c = disp_c  # the return trip runs the same schedule mirrored
    comp_c = compute_s / num_chunks
    serial = num_chunks * (disp_c + comp_c + comb_c)
    if not overlap:
        return serial
    n_dma = 1 if impl == "xla" else max(n - 1, 1) * transport_chunks
    if num_pods > 1 and impl != "xla":
        n_dma += num_pods - 1  # the coarse-hop phases are independent DMAs
    overlap_frac = 0.0 if n_dma <= 1 else 1.0 - 1.0 / n_dma
    boundaries = num_chunks  # chunks-1 internal + 1 cross-layer (unroll)
    hidden = boundaries * overlap_frac * min(comp_c, disp_c + comb_c)
    return max(serial - hidden, serial - num_chunks * (disp_c + comb_c))


def tune_ep_dispatch(
    cfg,
    batch_size: int,
    num_shards: int,
    num_pods: int = 1,
    impl: str = "round_robin",
    pack_impl: str = "xla",
    chip: ChipSpec = V5E,
    topology: str = "ring",
) -> dict:
    """Pick the async chunk count for the EP dispatch pipeline per topology.

    ``num_shards`` is the TOTAL unit count (pods x in-pod shards — the
    joint axis the two-level fabric spans).  Sweeps the pipeline chunk
    candidates that divide the per-expert capacity and returns::

        {"chunks", "serial_s", "async_s", "overlap_fraction", "candidates"}

    where ``serial_s`` is the unoverlapped schedule at the chosen chunking,
    ``async_s`` the overlapped one, and ``overlap_fraction`` the share of
    exchange time hidden behind expert compute — the modeled counterpart of
    the HLO-audited number :func:`repro.launch.roofline` reports.
    """
    E = int(getattr(cfg, "num_experts", 0) or 1)
    k = int(getattr(cfg, "top_k", 0) or 1)
    n_inner = max(num_shards // max(num_pods, 1), 1)
    t_loc = max(1, batch_size // max(num_shards, 1))
    C = ep_capacity(t_loc, k, E, float(getattr(cfg, "capacity_factor", 1.0)))
    stats = decode_table_stats(cfg, batch_size, num_shards)
    compute_s = moe_expert_time(cfg, batch_size, num_shards, chip)
    scored = []
    for ch in PIPELINE_CANDIDATES:
        if C % ch:
            continue
        async_s = ep_dispatch_makespan(
            stats, n_inner, compute_s, impl, pack_impl, ch, 1, chip,
            topology, num_pods, overlap=True,
        )
        serial_s = ep_dispatch_makespan(
            stats, n_inner, compute_s, impl, pack_impl, ch, 1, chip,
            topology, num_pods, overlap=False,
        )
        scored.append((async_s, ch, serial_s))
    scored.sort()
    async_s, chunks, serial_s = scored[0]
    exchange_s = serial_s - compute_s
    frac = (serial_s - async_s) / exchange_s if exchange_s > 0 else 0.0
    return {
        "chunks": chunks,
        "serial_s": serial_s,
        "async_s": async_s,
        "overlap_fraction": frac,
        "candidates": tuple((ch, a, s) for a, ch, s in scored),
    }


def exchange_makespan(
    stats: TableStats,
    n: int,
    impl: str = "round_robin",
    pack_impl: str = "xla",
    pipeline_chunks: int = 1,
    transport_chunks: int = 1,
    chip: ChipSpec = V5E,
    topology: str = "ring",
    num_pods: int = 1,
    skew: float = 1.0,
) -> float:
    """Modeled end-to-end time of one decoupled exchange (pack + shuffle).

    See the module docstring for the pipeline-overlap formula.  Requires
    ``pipeline_chunks`` to divide ``stats.rows`` and ``transport_chunks`` to
    divide the per-chunk capacity — the same divisibility contract
    ``hash_shuffle`` enforces (it falls back to unchunked otherwise).

    ``num_pods > 1`` prices the TWO-LEVEL exchange
    (:func:`repro.core.exchange.hash_shuffle_two_level`): a coarse cross-pod
    hop first — pack by destination pod, then ``num_pods - 1`` phases over
    DCI with ~``rows / num_pods`` rows per pod message — followed by the
    in-pod shuffle over the ``num_pods``-fold received buffer (the zero-drop
    bound inflates the static hop-2 shapes by ``num_pods``, and the model
    prices the shapes that actually move, not the expected occupancy).

    ``skew`` is the measured or estimated relative load of the max-loaded
    shard (``max_partition_load / fair_share``; 1.0 = balanced).  An exchange
    finishes when its SLOWEST receiver finishes, so wire time scales with the
    max-loaded shard, not the average — the planner prices plain vs salted
    repartitioning of a skewed key by calling this with each shape's overload
    factor (paper §3.1).  The default keeps every existing call bit-identical.
    """
    if skew < 1.0:
        raise ValueError(f"skew is max/fair-share and must be >= 1.0: {skew}")
    if n <= 1 and num_pods <= 1:
        return 0.0
    if stats.rows == 0:
        return 0.0
    hop1 = 0.0
    if num_pods > 1:
        hop1_impl = "xla" if impl == "xla" else "round_robin"
        pod_msg = -(-stats.rows // num_pods) * stats.row_bytes
        hop1 = pack_time(stats.rows, stats.row_bytes, num_pods, chip, pack_impl)
        hop1 += skew * shuffle_time(
            num_pods, pod_msg, chip, hop1_impl, 1, "switch", network="dci"
        )
        hop1 += shuffle_time(num_pods, 4, chip, hop1_impl, 1, "switch",
                             network="dci")
        stats = TableStats(rows=stats.rows * num_pods,
                           row_bytes=stats.row_bytes)
        if n <= 1:
            return hop1
    C = pipeline_chunks
    assert stats.rows % C == 0, (stats.rows, C)
    rows_c = stats.rows // C
    assert rows_c % transport_chunks == 0, (rows_c, transport_chunks)
    pack_c = pack_time(rows_c, stats.row_bytes, n, chip, pack_impl)
    ship_c = skew * shuffle_time(
        n, rows_c * stats.row_bytes, chip, impl, transport_chunks, topology
    )
    # Each chunk also ships the [n] per-destination counts (4 B messages).
    ship_c += shuffle_time(n, 4, chip, impl, 1, topology)
    n_dma = 1 if impl == "xla" else (n - 1) * transport_chunks
    overlap_frac = 0.0 if (C == 1 or n_dma <= 1) else 1.0 - 1.0 / n_dma
    inner = C * (pack_c + ship_c) - (C - 1) * overlap_frac * min(pack_c, ship_c)
    return hop1 + inner


def pod_strategy_times(
    build: TableStats,
    n: int,
    num_pods: int,
    chip: ChipSpec = V5E,
    topology: str = "ring",
) -> dict:
    """Modeled cost of each way to deliver a join's build side on a pod mesh.

    * ``"broadcast"`` — replicate: ring all-gather in-pod (ICI), then ship
      each pod's aggregated ``n x local`` bytes to every other pod over DCI.
      DCI bytes scale with ``num_pods * n`` — the classic-exchange blow-up —
      but there is no pack and no second shuffle, so tiny build sides win
      (the paper's ``n - 1`` broadcast-join threshold).
    * ``"reshard"`` — treat the build side like the probe side: a two-level
      hash exchange.  DCI carries each byte once; pays pack + in-pod shuffle.
    """
    local_bytes = build.rows * build.row_bytes
    in_pod_gather = (n - 1) * local_bytes / chip.ici_link_bandwidth + (
        max(n - 1, 0)
    ) * chip.ici_launch_latency
    broadcast = in_pod_gather + pod_broadcast_time(
        num_pods, n * local_bytes, chip
    )
    reshard = exchange_makespan(
        build, n, chip=chip, topology=topology, num_pods=num_pods
    )
    return {"broadcast": broadcast, "reshard": reshard}


def _shuffle_axis(mesh) -> tuple[str | None, int, int]:
    """The mesh's shuffle axis (largest small-network axis) and pod count."""
    from .hybrid import plan_for_mesh

    plan = plan_for_mesh(tuple(mesh.axis_names), tuple(mesh.devices.shape))
    best, size, pods = None, 1, 1
    for ax, s in zip(mesh.axis_names, mesh.devices.shape):
        if ax in plan.large_axes:
            pods *= int(s)
        elif s > size:
            best, size = ax, s
    return best, size, pods


def candidate_configs(
    n: int, stats: Sequence[TableStats]
) -> list[tuple[str, str, int, int]]:
    """Every legal knob setting for these exchanges on an ``n``-unit axis.

    ``pipeline_chunks`` must divide every exchange's row count (one
    multiplexer serves the whole query) and ``transport_chunks`` every
    per-chunk capacity; ``one_factorization`` needs even ``n``.
    """
    g = math.gcd(*[s.rows for s in stats]) if stats else 1
    impls = ["round_robin", "xla"]
    if n >= 2 and n % 2 == 0:
        impls.insert(1, "one_factorization")
    out = []
    for C in PIPELINE_CANDIDATES:
        if g % C:
            continue
        for t in TRANSPORT_CANDIDATES:
            if (g // C) % t:
                continue
            for impl in impls:
                if impl == "xla" and (C > 1 or t > 1):
                    # chunking buys nothing on the monolithic transport
                    # (no independent DMAs to overlap) — skip the redundant
                    # configs rather than model them all as equal-or-worse.
                    continue
                for pack_impl in ("xla", "pallas"):
                    out.append((impl, pack_impl, C, t))
    return out


def tune_config(
    n: int,
    table_stats: TableStats | Sequence[TableStats],
    num_pods: int = 1,
    chip: ChipSpec = V5E,
    topology: str = "ring",
    broadcast_stats: TableStats | None = None,
) -> TunedConfig:
    """Analytic argmin over multiplexer knobs for an ``n``-unit shuffle axis.

    The mesh-free core of :func:`tune_multiplexer`: everything the cost
    model needs is the shuffle-axis size, the pod count, and the exchange
    shapes — so plan-time consumers (the query planner's ``explain()``,
    which must run without any devices) can price a plan deterministically.
    ``tune_multiplexer`` derives ``(n, num_pods)`` from a live mesh and
    optionally adds empirical refinement on top of this.
    """
    stats = (
        (table_stats,)
        if isinstance(table_stats, TableStats)
        else tuple(table_stats)
    )
    cross_pod = cross_pod_times = None
    if num_pods > 1 and broadcast_stats is not None:
        cross_pod_times = pod_strategy_times(
            broadcast_stats, n, num_pods, chip, topology
        )
        cross_pod = min(cross_pod_times, key=cross_pod_times.get)
    if n <= 1 or not stats or all(s.rows == 0 for s in stats):
        return TunedConfig(
            "round_robin", "xla", 1, 1, 0.0,
            cross_pod=cross_pod, cross_pod_modeled_s=cross_pod_times,
        )

    scored = []
    for impl, pack_impl, C, t in candidate_configs(n, stats):
        total = sum(
            exchange_makespan(
                s, n, impl, pack_impl, C, t, chip, topology, num_pods
            )
            for s in stats
        )
        scored.append((total, C, t, impl, pack_impl))
    # tie-break toward the simpler config (fewer chunks, scheduled transport)
    scored.sort(key=lambda r: (r[0], r[1], r[2], r[3], r[4]))
    candidates = tuple(
        (impl, pack_impl, C, t, total) for total, C, t, impl, pack_impl in scored
    )
    total, C, t, impl, pack_impl = scored[0]
    return TunedConfig(
        impl=impl,
        pack_impl=pack_impl,
        pipeline_chunks=C,
        transport_chunks=t,
        modeled_s=total,
        candidates=candidates,
        cross_pod=cross_pod,
        cross_pod_modeled_s=cross_pod_times,
    )


def tune_shared_config(
    n: int,
    stats_groups: Sequence[TableStats | Sequence[TableStats]],
    num_pods: int = 1,
    chip: ChipSpec = V5E,
    topology: str = "ring",
    weights: Sequence[float] | None = None,
) -> TunedConfig:
    """One knob set for SEVERAL queries' exchanges sharing one multiplexer.

    The query-serving engine runs compatible plans concurrently on one
    mesh, and they all ride the same multiplexer — so the knobs must be
    tuned over the UNION of every query's exchange shapes, not per query:
    the legal candidate set is the intersection (``pipeline_chunks`` must
    divide every exchange's rows across all queries) and the objective is
    the traffic-weighted total makespan.  ``stats_groups`` holds one group
    of :class:`TableStats` per query (a plan's ``shuffle_stats``);
    ``weights`` optionally scales each query's contribution by its share
    of the request mix (default: uniform).  Degenerate inputs (single
    unit, no exchanges) collapse to :func:`tune_config`'s default exactly.
    """
    groups = tuple(
        (g,) if isinstance(g, TableStats) else tuple(g) for g in stats_groups
    )
    flat = tuple(s for g in groups for s in g)
    if n <= 1 or not flat or all(s.rows == 0 for s in flat):
        return tune_config(n, flat, num_pods, chip, topology)
    if weights is None:
        weights = (1.0,) * len(groups)
    assert len(weights) == len(groups), (len(weights), len(groups))
    scored = []
    for impl, pack_impl, C, t in candidate_configs(n, flat):
        total = sum(
            w * sum(
                exchange_makespan(
                    s, n, impl, pack_impl, C, t, chip, topology, num_pods
                )
                for s in g
            )
            for w, g in zip(weights, groups)
        )
        scored.append((total, C, t, impl, pack_impl))
    scored.sort(key=lambda r: (r[0], r[1], r[2], r[3], r[4]))
    candidates = tuple(
        (impl, pack_impl, C, t, total) for total, C, t, impl, pack_impl in scored
    )
    total, C, t, impl, pack_impl = scored[0]
    return TunedConfig(
        impl=impl,
        pack_impl=pack_impl,
        pipeline_chunks=C,
        transport_chunks=t,
        modeled_s=total,
        candidates=candidates,
    )


def tune_multiplexer(
    mesh,
    table_stats: TableStats | Sequence[TableStats],
    chip: ChipSpec = V5E,
    topology: str = "ring",
    axis: str | None = None,
    refine: bool = False,
    refine_top_k: int = 3,
    broadcast_stats: TableStats | None = None,
) -> TunedConfig:
    """Choose the multiplexer knobs that minimize the modeled shuffle makespan.

    ``table_stats`` describes the exchange(s) the multiplexer will carry (a
    query with several shuffles passes one :class:`TableStats` each; the
    model minimizes their summed makespan under the shared divisibility
    constraints).  ``axis`` defaults to the mesh's largest small-network
    axis.  With ``refine=True`` the ``refine_top_k`` best modeled candidates
    are micro-benchmarked on the live mesh and the measured winner is
    returned (``measured_s`` filled in).

    On a two-level mesh (a ``pod`` axis in the hybrid plan) every exchange
    is priced as the two-level shuffle — coarse DCI hop plus the
    ``num_pods``-fold in-pod hop — and, when ``broadcast_stats`` describes a
    broadcast-style join's build side, the cheaper of cross-pod
    ``"broadcast"`` and ``"reshard"`` is recorded in
    :attr:`TunedConfig.cross_pod` (see :func:`pod_strategy_times`).
    """
    stats = (
        (table_stats,)
        if isinstance(table_stats, TableStats)
        else tuple(table_stats)
    )
    if axis is None:
        axis, n, num_pods = _shuffle_axis(mesh)
    else:
        n = int(mesh.devices.shape[list(mesh.axis_names).index(axis)])
        num_pods = _shuffle_axis(mesh)[2]
    tuned = tune_config(
        n if axis is not None else 1, stats, num_pods=num_pods, chip=chip,
        topology=topology, broadcast_stats=broadcast_stats,
    )
    if refine and num_pods > 1:
        # measure_shuffle_config runs the single-level in-pod shuffle; on a
        # two-level mesh that measures neither the DCI hop nor the P-fold
        # hop-2 shapes the model prices, so a "measured winner" would be
        # ranked on the wrong experiment.  Stay analytical rather than
        # return a measured_s that is not comparable to modeled_s.
        import warnings

        warnings.warn(
            "tune_multiplexer(refine=True) is not supported on two-level "
            "meshes yet; returning the analytical winner",
            stacklevel=2,
        )
        refine = False
    scored = [
        (total, C, t, impl, pack_impl)
        for impl, pack_impl, C, t, total in tuned.candidates
    ]
    if not refine or len(scored) <= 1:
        return tuned
    probe = max(stats, key=lambda s: s.rows * s.row_bytes)
    timed = []
    for total, C, t, impl, pack_impl in scored[:refine_top_k]:
        wall = measure_shuffle_config(
            mesh, axis, probe, impl=impl, pack_impl=pack_impl,
            pipeline_chunks=C, transport_chunks=t,
        )
        timed.append((wall, (total, C, t, impl, pack_impl)))
    timed.sort(key=lambda r: r[0])
    measured, (total, C, t, impl, pack_impl) = timed[0]
    return dataclasses.replace(
        tuned,
        impl=impl,
        pack_impl=pack_impl,
        pipeline_chunks=C,
        transport_chunks=t,
        modeled_s=total,
        measured_s=measured,
    )


# ----------------------------------------------------------------------------
# Empirical refinement: micro-benchmark a config on the live mesh.
# ----------------------------------------------------------------------------

def _best_wall(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Min wall seconds over ``iters`` runs — the standard microbenchmark
    reducer: the minimum is the run least disturbed by scheduler noise."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    walls = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        walls.append(time.perf_counter() - t0)
    return min(walls)


def measure_shuffle_config(
    mesh,
    axis: str,
    stats: TableStats,
    impl: str = "round_robin",
    pack_impl: str = "xla",
    pipeline_chunks: int = 1,
    transport_chunks: int = 1,
    iters: int = 3,
    max_rows: int | None = None,
) -> float:
    """Min wall seconds (over ``iters`` runs) of one ``hash_shuffle``.

    Runs a synthetic exchange (uniform int32 keys, ``stats.row_bytes`` wide
    rows, zero-drop capacity) through a real multiplexer on the live mesh,
    at the *actual* ``stats.rows`` by default — measuring in a smaller-size
    regime would systematically undo the tuner's size-driven decisions
    (chunking only pays above a message-size threshold).  Pass ``max_rows``
    to cap the probe when a cheaper, regime-*approximate* measurement is
    acceptable; rows are then re-aligned to keep chunk divisibility.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from .multiplexer import make_multiplexer

    n = int(mesh.devices.shape[list(mesh.axis_names).index(axis)])
    rows = stats.rows if max_rows is None else min(stats.rows, max_rows)
    step = pipeline_chunks * transport_chunks  # C | rows and t | rows/C
    rows = max(step, rows - rows % step)
    width = max(1, stats.row_bytes // 4)
    mux = make_multiplexer(
        mesh, impl=impl, pack_impl=pack_impl,
        pipeline_chunks=pipeline_chunks, transport_chunks=transport_chunks,
    )

    key = jax.random.PRNGKey(0)
    keys = jax.random.randint(key, (rows * n,), 0, 1 << 30, dtype=jnp.int32)
    data = jax.random.randint(
        jax.random.fold_in(key, 1), (rows * n, width), 0, 1 << 20,
        dtype=jnp.int32,
    )

    def body(k, r):
        out_rows, out_valid, dropped = mux.hash_shuffle(
            k, r, axis, capacity=rows
        )
        return out_rows.sum() + out_valid.sum() + dropped

    fn = jax.jit(
        shard_map(
            body, mesh=mesh, in_specs=(P(axis), P(axis)), out_specs=P(),
            check_vma=False,
        )
    )
    return _best_wall(fn, keys, data, iters=iters)


def calibrate_chip(
    mesh,
    axis: str,
    chip: ChipSpec = V5E,
    message_rows: Sequence[int] = (1024, 65536),
    row_bytes: int = 16,
) -> ChipSpec:
    """Fit the cost model's constants to the machine actually running.

    The model is two affine laws — shuffle wall = launches + bytes/link_bw,
    pack wall = dispatch + touched/hbm_bw.  Measuring each at a small and a
    large size and solving the 2x2 system yields *effective* link bandwidth,
    launch latency, HBM bandwidth and dispatch cost for whatever backend is
    underneath (CPU fake devices in CI, real ICI on TPU).  The returned spec
    makes ``exchange_makespan`` directly comparable to wall-clock on this
    host — which is how ``benchmarks/bench_autotune.py`` validates the model.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from . import exchange
    from .schedule import make_schedule, schedule_ring_loads

    n = int(mesh.devices.shape[list(mesh.axis_names).index(axis)])
    if n <= 1:
        return chip
    load_sum = sum(schedule_ring_loads(make_schedule(n, "shift")))
    width = max(1, row_bytes // 4)

    # -- link law: scheduled all_to_all wall at two message sizes ----------
    walls, sizes = [], []
    for rows in message_rows:
        x = jax.random.randint(
            jax.random.PRNGKey(rows), (n * n, rows, width), 0, 1 << 20,
            dtype=jnp.int32,
        )
        fn = jax.jit(
            shard_map(
                lambda v: exchange.all_to_all(v, axis, impl="round_robin"),
                mesh=mesh, in_specs=P(axis), out_specs=P(axis),
            )
        )
        walls.append(_best_wall(fn, x))
        sizes.append(rows * width * 4)
    slope = (walls[-1] - walls[0]) / max(sizes[-1] - sizes[0], 1)
    slope = max(slope, 1e-15)
    intercept = max(walls[0] - slope * sizes[0], 1e-9)
    link_bw = load_sum / slope
    launch = intercept / (n - 1)

    # -- pack law: pack_by_destination wall at two row counts --------------
    pk_walls, pk_bytes = [], []
    for rows in message_rows:
        dest = jax.random.randint(
            jax.random.PRNGKey(rows + 1), (rows,), 0, n, dtype=jnp.int32
        )
        data = jax.random.randint(
            jax.random.PRNGKey(rows + 2), (rows, width), 0, 1 << 20,
            dtype=jnp.int32,
        )
        fn = jax.jit(
            lambda d, r: exchange.pack_by_destination(d, r, n, rows, impl="xla")
        )
        pk_walls.append(_best_wall(fn, dest, data))
        # same bytes-touched expression as pack_time(impl="xla")
        pk_bytes.append(rows * 12 * (n + 1) + 8 * rows + 2 * rows * row_bytes)
    pk_slope = (pk_walls[-1] - pk_walls[0]) / max(pk_bytes[-1] - pk_bytes[0], 1)
    pk_slope = max(pk_slope, 1e-15)
    pk_intercept = max(pk_walls[0] - pk_slope * pk_bytes[0], 1e-9)

    return dataclasses.replace(
        chip,
        name=chip.name + "-calibrated",
        ici_link_bandwidth=link_bw,
        ici_launch_latency=launch,
        hbm_bandwidth=1.0 / pk_slope,
        kernel_launch_latency=pk_intercept,
    )


__all__ = [
    "TableStats",
    "TunedConfig",
    "decode_table_stats",
    "ep_capacity",
    "moe_expert_time",
    "ep_dispatch_makespan",
    "tune_ep_dispatch",
    "exchange_makespan",
    "pod_strategy_times",
    "candidate_configs",
    "tune_config",
    "tune_shared_config",
    "tune_multiplexer",
    "measure_shuffle_config",
    "calibrate_chip",
]
