"""Core: the paper's contribution as composable JAX modules.

- ``schedule``    — round-robin conflict-free phase schedules (Fig 10a)
- ``exchange``    — decoupled exchange operators over shard_map collectives
- ``multiplexer`` — per-mesh communication policy (the RDMA multiplexer)
- ``autotune``    — topology-driven knob planner for the multiplexer
- ``hybrid``      — hybrid-parallelism planner + paper cost model (§3.1)
- ``topology``    — v5e roofline constants + switch-contention simulator
                    + the per-phase pack/shuffle cost model
- ``skew``        — Zipf partition-skew analysis + salting (§3.1)
"""

from . import autotune, exchange, hybrid, multiplexer, schedule, skew, topology
from .autotune import TableStats, TunedConfig, tune_multiplexer
from .exchange import (
    all_to_all,
    broadcast_exchange,
    hash_shuffle,
    hierarchical_psum_tree,
    scheduled_all_to_all,
    xla_all_to_all,
)
from .multiplexer import CommMultiplexer, make_multiplexer
from .schedule import Schedule, make_schedule, verify_schedule

__all__ = [
    "autotune",
    "exchange",
    "hybrid",
    "multiplexer",
    "schedule",
    "skew",
    "topology",
    "all_to_all",
    "broadcast_exchange",
    "hash_shuffle",
    "hierarchical_psum_tree",
    "scheduled_all_to_all",
    "xla_all_to_all",
    "TableStats",
    "TunedConfig",
    "tune_multiplexer",
    "CommMultiplexer",
    "make_multiplexer",
    "Schedule",
    "make_schedule",
    "verify_schedule",
]
