"""Decoupled exchange operators as JAX collectives (paper §3.2).

The paper replaces the classic Volcano exchange operator with *decoupled*
exchange operators that only talk to a per-server communication multiplexer,
which in turn performs an all-to-all shuffle over ``n - 1`` conflict-free
round-robin phases (§3.2.3).  This module is the JAX/TPU rendition:

* a *parallel unit* is a device along one mesh axis (inside ``shard_map``),
* a *message* is the per-destination chunk of a device-local array,
* a *phase* is a ``jax.lax.ppermute`` whose permutation is one phase of a
  :class:`repro.core.schedule.Schedule` — a cyclic shift routes along
  disjoint torus links, so no link is shared within a phase, which is
  exactly the property the paper's switch scheduling establishes,
* the *message pool / zero-copy* discipline becomes buffer donation and the
  ping-pong accumulation of :func:`scheduled_all_to_all_consume` (process
  each message as it arrives instead of materializing all of them — the
  paper's workers do the same with incoming tuples).

Everything here must be called inside ``shard_map`` (a named mesh axis in
scope).  The pjit/auto-sharded layers above call these through
:mod:`repro.core.multiplexer`.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Literal

import jax
import jax.numpy as jnp
from jax import lax

from .schedule import Schedule, make_schedule

AllToAllImpl = Literal["xla", "round_robin", "one_factorization"]


# ----------------------------------------------------------------------------
# All-to-all.
# ----------------------------------------------------------------------------

def xla_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """Baseline: XLA's monolithic all-to-all (the 'unscheduled' transport).

    ``x[j]`` (leading dim = axis size) is the chunk destined for device ``j``;
    the result's ``y[j]`` is the chunk received from device ``j``.
    """
    n = lax.axis_size(axis_name)
    assert x.shape[0] == n, f"leading dim {x.shape[0]} != axis size {n}"
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=True)


def _phase_tables(schedule: Schedule):
    """Static per-phase (targets_by_src, sources_by_dst) lookup arrays."""
    tgt, src = [], []
    for phase in schedule.phases:
        t = [0] * schedule.n
        s = [0] * schedule.n
        for a, b in phase:
            t[a] = b
            s[b] = a
        tgt.append(t)
        src.append(s)
    return jnp.asarray(tgt, jnp.int32), jnp.asarray(src, jnp.int32)


def scheduled_all_to_all(
    x: jax.Array,
    axis_name: str,
    schedule: str = "shift",
) -> jax.Array:
    """The paper's phased round-robin all-to-all (Fig 10a) via ppermute.

    Same contract as :func:`xla_all_to_all` but decomposed into ``n - 1``
    conflict-free permutation phases.  Each phase of the default ``shift``
    schedule is a cyclic shift ``i -> i + k``, which a torus routes over
    link-disjoint paths; the XLA async scheduler may overlap consecutive
    phases' DMAs with unrelated compute.
    """
    n = lax.axis_size(axis_name)
    assert x.shape[0] == n, f"leading dim {x.shape[0]} != axis size {n}"
    if n == 1:
        return x
    sched = make_schedule(n, schedule)
    me = lax.axis_index(axis_name)
    tgt_tab, src_tab = _phase_tables(sched)

    # Own chunk stays put: y[me] = x[me].
    own = lax.dynamic_slice_in_dim(x, me, 1, axis=0)
    y = lax.dynamic_update_slice_in_dim(jnp.zeros_like(x), own, me, axis=0)

    for k in range(sched.num_phases):
        send_to = tgt_tab[k, me]  # who I send to this phase
        recv_from = src_tab[k, me]  # who I receive from this phase
        chunk = lax.dynamic_slice_in_dim(x, send_to, 1, axis=0)
        got = lax.ppermute(chunk, axis_name, sched.phase_permutation(k))
        # The chunk I got came from `recv_from` and was destined for me.
        y = lax.dynamic_update_slice_in_dim(y, got, recv_from, axis=0)
    return y


def all_to_all(
    x: jax.Array, axis_name: str, impl: AllToAllImpl = "round_robin"
) -> jax.Array:
    """Dispatcher: the communication multiplexer's shuffle entry point."""
    if impl == "xla":
        return xla_all_to_all(x, axis_name)
    if impl == "round_robin":
        return scheduled_all_to_all(x, axis_name, schedule="shift")
    if impl == "one_factorization":
        return scheduled_all_to_all(x, axis_name, schedule="one_factorization")
    raise ValueError(f"unknown all_to_all impl {impl!r}")


def scheduled_all_to_all_consume(
    x: jax.Array,
    axis_name: str,
    consume: Callable[[Any, jax.Array, jax.Array], Any],
    init: Any,
    schedule: str = "shift",
) -> Any:
    """Streaming shuffle: fold each message as it arrives (paper §3.2 step 5-7).

    ``consume(acc, chunk, src_index) -> acc`` is applied to the device's own
    chunk first, then to each received chunk phase by phase.  Because the
    accumulator does not depend on later phases' sends, XLA can overlap the
    phase ``k+1`` ppermute with the phase ``k`` consume — the TPU analogue of
    the paper's multiplexer notifying workers to process messages right away
    instead of waiting for the full shuffle.  Avoids materializing the
    ``[n, ...]`` receive buffer (the message pool is one chunk deep).
    """
    n = lax.axis_size(axis_name)
    assert x.shape[0] == n
    me = lax.axis_index(axis_name)
    own = lax.dynamic_slice_in_dim(x, me, 1, axis=0)
    acc = consume(init, own[0], me)
    if n == 1:
        return acc
    sched = make_schedule(n, schedule)
    tgt_tab, src_tab = _phase_tables(sched)
    for k in range(sched.num_phases):
        send_to = tgt_tab[k, me]
        recv_from = src_tab[k, me]
        chunk = lax.dynamic_slice_in_dim(x, send_to, 1, axis=0)
        got = lax.ppermute(chunk, axis_name, sched.phase_permutation(k))
        acc = consume(acc, got[0], recv_from)
    return acc


# ----------------------------------------------------------------------------
# Broadcast exchange (paper §3.1: broadcast joins; §3.2.1 retain counter).
# ----------------------------------------------------------------------------

def ring_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """Broadcast exchange: every device ends with all ``n`` chunks.

    Ring algorithm = ``n - 1`` single-shift phases, each conflict-free; total
    volume per device is ``(n-1) * |x|`` — the hybrid model's "send once per
    remote server" (vs ``n*t - 1`` sends under classic exchange).  Result
    ``y[j]`` is device ``j``'s chunk.
    """
    n = lax.axis_size(axis_name)
    me = lax.axis_index(axis_name)
    y = jnp.zeros((n,) + x.shape, x.dtype)
    y = lax.dynamic_update_slice_in_dim(y, x[None], me, axis=0)
    if n == 1:
        return y
    perm = [(i, (i + 1) % n) for i in range(n)]
    cur = x
    for k in range(1, n):
        cur = lax.ppermute(cur, axis_name, perm)
        src = (me - k) % n  # after k hops I hold device (me-k)'s chunk
        y = lax.dynamic_update_slice_in_dim(y, cur[None], src, axis=0)
    return y


def broadcast_exchange(x: jax.Array, axis_name: str, impl: str = "ring") -> jax.Array:
    if impl == "ring":
        return ring_all_gather(x, axis_name)
    if impl == "xla":
        return lax.all_gather(x, axis_name, axis=0, tiled=False)
    raise ValueError(f"unknown broadcast impl {impl!r}")


# ----------------------------------------------------------------------------
# Hierarchical collectives (hybrid parallelism for gradient sync).
# ----------------------------------------------------------------------------

def hierarchical_psum(x: jax.Array, inner_axis: str, outer_axis: str) -> jax.Array:
    """Two-level all-reduce: RS(inner) -> AR(outer) -> AG(inner).

    The paper's "network in the small vs in the large": the bandwidth-hungry
    reduce-scatter/all-gather stay on the fast inner network (ICI); only the
    already-reduced ``1/inner_size`` shard crosses the slow outer network
    (DCI).  Cross-pod traffic drops by the inner axis size versus a flat
    all-reduce over both axes.

    ``x``'s leading dim must be divisible by the inner axis size (use
    :func:`hierarchical_psum_tree` for arbitrary pytrees).
    """
    shard = lax.psum_scatter(x, inner_axis, scatter_dimension=0, tiled=True)
    shard = lax.psum(shard, outer_axis)
    return lax.all_gather(shard, inner_axis, axis=0, tiled=True)


def _pad_to(x: jax.Array, multiple: int) -> jax.Array:
    pad = (-x.shape[0]) % multiple
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x


def hierarchical_psum_tree(tree: Any, inner_axis: str, outer_axis: str) -> Any:
    """Hierarchical all-reduce of a gradient pytree (flatten-pad-reshape)."""

    def one(leaf: jax.Array) -> jax.Array:
        flat = leaf.reshape(-1)
        n = flat.shape[0]
        inner = lax.axis_size(inner_axis)
        padded = _pad_to(flat, inner)
        red = hierarchical_psum(padded, inner_axis, outer_axis)
        return red[:n].reshape(leaf.shape)

    return jax.tree.map(one, tree)


def flat_psum_tree(tree: Any, axis_names: tuple[str, ...]) -> Any:
    """Baseline: single flat all-reduce over all data axes."""
    return jax.tree.map(lambda g: lax.psum(g, axis_names), tree)


# ----------------------------------------------------------------------------
# Hash shuffle: the decoupled exchange operator proper (paper §3.2 steps 1-7).
# ----------------------------------------------------------------------------

def fibonacci_hash(keys: jax.Array) -> jax.Array:
    """Schema-specialized hash of int keys (stands in for the paper's CRC32).

    The paper hashes join attributes with CRC32 (hardware instruction on
    x86).  TPUs have no CRC32 unit; a Fibonacci/murmur-style multiply-xor mix
    gives the same uniformity at pure-VPU cost.  uint32 avalanche mix.
    """
    x = keys.astype(jnp.uint32)
    x ^= x >> 16
    x = x * jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    x = x * jnp.uint32(0x846CA68B)
    x ^= x >> 16
    return x


def pack_by_destination(
    dest: jax.Array,
    rows: jax.Array,
    num_dest: int,
    capacity: int,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Partition ``rows`` into per-destination message buffers (paper step 2).

    Returns ``(buffers, counts, dropped)`` with ``buffers: [num_dest,
    capacity, row...]``, ``counts: [num_dest]`` valid rows per buffer and
    ``dropped``: rows lost to capacity overflow (0 when capacity is sized to
    the skew bound).  Static shapes throughout — the message pool analogue:
    fixed-size reusable buffers.
    """
    nrows = dest.shape[0]
    if valid is None:
        valid = jnp.ones((nrows,), jnp.bool_)
    dest = jnp.where(valid, dest, num_dest)  # invalid rows -> overflow bucket
    onehot = jax.nn.one_hot(dest, num_dest + 1, dtype=jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) - onehot  # rank within destination
    my_rank = jnp.take_along_axis(rank, dest[:, None], axis=1)[:, 0]
    counts = jnp.minimum(onehot.sum(axis=0)[:num_dest], capacity)
    keep = (my_rank < capacity) & valid & (dest < num_dest)
    slot = jnp.where(keep, dest * capacity + my_rank, num_dest * capacity)
    flat = jnp.zeros((num_dest * capacity + 1,) + rows.shape[1:], rows.dtype)
    flat = flat.at[slot].set(jnp.where(keep.reshape((-1,) + (1,) * (rows.ndim - 1)), rows, 0))
    buffers = flat[:-1].reshape((num_dest, capacity) + rows.shape[1:])
    dropped = (valid & (dest < num_dest)).sum() - keep.sum()
    return buffers, counts, dropped


def hash_shuffle(
    keys: jax.Array,
    rows: jax.Array,
    axis_name: str,
    capacity: int,
    impl: AllToAllImpl = "round_robin",
    valid: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full decoupled exchange: partition by key hash, shuffle, reassemble.

    Per device: rows whose ``hash(key) % n == j`` are packed into message
    ``j`` and shuffled so that afterwards every device holds exactly the rows
    hashing to its index.  Returns ``(rows_out, valid_out, dropped)`` where
    ``rows_out: [n * capacity, row...]`` and ``valid_out`` masks real rows.
    """
    n = lax.axis_size(axis_name)
    dest = (fibonacci_hash(keys) % jnp.uint32(n)).astype(jnp.int32)
    buffers, counts, dropped = pack_by_destination(dest, rows, n, capacity, valid)
    shuffled = all_to_all(buffers, axis_name, impl=impl)
    counts_in = all_to_all(counts.reshape(n, 1), axis_name, impl=impl).reshape(n)
    rows_out = shuffled.reshape((n * capacity,) + shuffled.shape[2:])
    valid_out = (
        jnp.arange(capacity)[None, :] < counts_in[:, None]
    ).reshape(n * capacity)
    return rows_out, valid_out, lax.psum(dropped, axis_name)


__all__ = [
    "AllToAllImpl",
    "xla_all_to_all",
    "scheduled_all_to_all",
    "scheduled_all_to_all_consume",
    "all_to_all",
    "ring_all_gather",
    "broadcast_exchange",
    "hierarchical_psum",
    "hierarchical_psum_tree",
    "flat_psum_tree",
    "fibonacci_hash",
    "pack_by_destination",
    "hash_shuffle",
]
