"""Decoupled exchange operators as JAX collectives (paper §3.2).

The paper replaces the classic Volcano exchange operator with *decoupled*
exchange operators that only talk to a per-server communication multiplexer,
which in turn performs an all-to-all shuffle over ``n - 1`` conflict-free
round-robin phases (§3.2.3).  This module is the JAX/TPU rendition:

* a *parallel unit* is a device along one mesh axis (inside ``shard_map``),
* a *message* is the per-destination chunk of a device-local array,
* a *phase* is a ``jax.lax.ppermute`` whose permutation is one phase of a
  :class:`repro.core.schedule.Schedule` — a cyclic shift routes along
  disjoint torus links, so no link is shared within a phase, which is
  exactly the property the paper's switch scheduling establishes,
* the *message pool / zero-copy* discipline becomes buffer donation and the
  ping-pong accumulation of :func:`scheduled_all_to_all_consume` (process
  each message as it arrives instead of materializing all of them — the
  paper's workers do the same with incoming tuples).

The partition hot path (paper §3.2.1's per-tuple CRC32 + message-buffer
fill) has two implementations, selected by ``pack_impl``:

* ``"xla"`` — reference: a ``[rows, num_dest + 1]`` one-hot + cumsum.
  O(rows x destinations) memory and FLOPs; fine for small meshes, dominates
  the shuffle itself as the mesh grows.
* ``"pallas"`` — the fused kernel of :mod:`repro.kernels.hash_partition`:
  hash + validity mask + block-local rank + block histogram in one pass,
  combined by an ``[nblocks, bins]`` exclusive scan and a flat gather.  The
  row-global one-hot never materializes; cost scales with
  ``rows + nblocks x destinations``.

:func:`hash_shuffle` additionally supports a *chunked double-buffered
pipeline* (``num_chunks > 1``): rows are split into chunks, and chunk
``k + 1`` is packed before chunk ``k``'s ppermute phases are issued.  The
pack has no data dependence on the in-flight shuffle, so XLA's async
scheduler can overlap partition compute with DMA — the TPU rendition of the
paper's multiplexer sending message ``k`` while the workers fill ``k + 1``.
``transport_chunks`` further splits each phase's message into independent
ppermutes (finer DMA granularity at one extra launch each).

The chunking contract (enforced by assertions here; the multiplexer layer
pre-checks and falls back with a warning instead): ``num_chunks`` divides
both the row count and ``capacity``, and ``transport_chunks`` divides the
per-chunk capacity ``capacity / num_chunks``.  Every (impl, pack_impl,
chunking) combination delivers the same rows to the same devices; only the
padding layout differs (chunked shuffles pad at chunk boundaries).

Overflow semantics: packing is capacity-bounded (fixed-size message
buffers, the paper's registered message pool), so rows beyond a
destination's capacity are *counted, not shipped* — :func:`hash_shuffle`
returns the psum'd ``dropped`` total and callers decide the policy.  The
relational layer (:mod:`repro.relational.distributed`) sizes capacity to
the static zero-drop bound and raises on any nonzero count: overflow is an
error, never silent row loss.

Everything here must be called inside ``shard_map`` (a named mesh axis in
scope).  The pjit/auto-sharded layers above call these through
:mod:`repro.core.multiplexer`, which owns the knob *values* — hand-set or
derived from the topology cost model by :mod:`repro.core.autotune`.
"""

from __future__ import annotations

from typing import Any, Callable, Literal

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size as _axis_size
from .schedule import Schedule, make_schedule

AllToAllImpl = Literal["xla", "round_robin", "one_factorization"]
PackImpl = Literal["xla", "pallas"]


# ----------------------------------------------------------------------------
# All-to-all.
# ----------------------------------------------------------------------------

def xla_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """Baseline: XLA's monolithic all-to-all (the 'unscheduled' transport).

    ``x[j]`` (leading dim = axis size) is the chunk destined for device ``j``;
    the result's ``y[j]`` is the chunk received from device ``j``.
    """
    n = _axis_size(axis_name)
    assert x.shape[0] == n, f"leading dim {x.shape[0]} != axis size {n}"
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=True)


def _phase_tables(schedule: Schedule):
    """Static per-phase (targets_by_src, sources_by_dst) lookup arrays."""
    tgt, src = [], []
    for phase in schedule.phases:
        t = [0] * schedule.n
        s = [0] * schedule.n
        for a, b in phase:
            t[a] = b
            s[b] = a
        tgt.append(t)
        src.append(s)
    return jnp.asarray(tgt, jnp.int32), jnp.asarray(src, jnp.int32)


def scheduled_all_to_all(
    x: jax.Array,
    axis_name: str,
    schedule: str = "shift",
    num_chunks: int = 1,
) -> jax.Array:
    """The paper's phased round-robin all-to-all (Fig 10a) via ppermute.

    Same contract as :func:`xla_all_to_all` but decomposed into ``n - 1``
    conflict-free permutation phases.  Each phase of the default ``shift``
    schedule is a cyclic shift ``i -> i + k``, which a torus routes over
    link-disjoint paths; the XLA async scheduler may overlap consecutive
    phases' DMAs with unrelated compute.

    ``num_chunks > 1`` splits each per-destination message along its second
    axis into sub-messages shipped by independent ppermutes — smaller
    in-flight transfers that the async scheduler can pipeline (double
    buffering at the transport level).  Requires ``x.ndim >= 2`` and
    ``x.shape[1] % num_chunks == 0``.
    """
    n = _axis_size(axis_name)
    assert x.shape[0] == n, f"leading dim {x.shape[0]} != axis size {n}"
    if n == 1:
        return x
    if num_chunks > 1:
        assert x.ndim >= 2 and x.shape[1] % num_chunks == 0, (
            f"num_chunks={num_chunks} must divide message dim "
            f"{x.shape[1] if x.ndim >= 2 else None}"
        )
    sched = make_schedule(n, schedule)
    me = lax.axis_index(axis_name)
    tgt_tab, src_tab = _phase_tables(sched)

    # Own chunk stays put: y[me] = x[me].
    own = lax.dynamic_slice_in_dim(x, me, 1, axis=0)
    y = lax.dynamic_update_slice_in_dim(jnp.zeros_like(x), own, me, axis=0)

    sub = x.shape[1] // num_chunks if num_chunks > 1 else 0
    for k in range(sched.num_phases):
        send_to = tgt_tab[k, me]  # who I send to this phase
        recv_from = src_tab[k, me]  # who I receive from this phase
        chunk = lax.dynamic_slice_in_dim(x, send_to, 1, axis=0)
        if num_chunks == 1:
            got = lax.ppermute(chunk, axis_name, sched.phase_permutation(k))
        else:
            parts = [
                lax.ppermute(
                    lax.slice_in_dim(chunk, c * sub, (c + 1) * sub, axis=1),
                    axis_name,
                    sched.phase_permutation(k),
                )
                for c in range(num_chunks)
            ]
            got = jnp.concatenate(parts, axis=1)
        # The chunk I got came from `recv_from` and was destined for me.
        y = lax.dynamic_update_slice_in_dim(y, got, recv_from, axis=0)
    return y


def all_to_all(
    x: jax.Array,
    axis_name: str,
    impl: AllToAllImpl = "round_robin",
    num_chunks: int = 1,
) -> jax.Array:
    """Dispatcher: the communication multiplexer's shuffle entry point.

    ``num_chunks`` only affects the scheduled transports (the monolithic XLA
    all-to-all has no phases to pipeline).
    """
    if impl == "xla":
        return xla_all_to_all(x, axis_name)
    if impl == "round_robin":
        return scheduled_all_to_all(x, axis_name, schedule="shift", num_chunks=num_chunks)
    if impl == "one_factorization":
        return scheduled_all_to_all(
            x, axis_name, schedule="one_factorization", num_chunks=num_chunks
        )
    raise ValueError(f"unknown all_to_all impl {impl!r}")


def scheduled_all_to_all_consume(
    x: jax.Array,
    axis_name: str,
    consume: Callable[[Any, jax.Array, jax.Array], Any],
    init: Any,
    schedule: str = "shift",
) -> Any:
    """Streaming shuffle: fold each message as it arrives (paper §3.2 step 5-7).

    ``consume(acc, chunk, src_index) -> acc`` is applied to the device's own
    chunk first, then to each received chunk phase by phase.  Because the
    accumulator does not depend on later phases' sends, XLA can overlap the
    phase ``k+1`` ppermute with the phase ``k`` consume — the TPU analogue of
    the paper's multiplexer notifying workers to process messages right away
    instead of waiting for the full shuffle.  Avoids materializing the
    ``[n, ...]`` receive buffer (the message pool is one chunk deep).
    """
    n = _axis_size(axis_name)
    assert x.shape[0] == n
    me = lax.axis_index(axis_name)
    own = lax.dynamic_slice_in_dim(x, me, 1, axis=0)
    acc = consume(init, own[0], me)
    if n == 1:
        return acc
    sched = make_schedule(n, schedule)
    tgt_tab, src_tab = _phase_tables(sched)
    for k in range(sched.num_phases):
        send_to = tgt_tab[k, me]
        recv_from = src_tab[k, me]
        chunk = lax.dynamic_slice_in_dim(x, send_to, 1, axis=0)
        got = lax.ppermute(chunk, axis_name, sched.phase_permutation(k))
        acc = consume(acc, got[0], recv_from)
    return acc


# ----------------------------------------------------------------------------
# Broadcast exchange (paper §3.1: broadcast joins; §3.2.1 retain counter).
# ----------------------------------------------------------------------------

def ring_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """Broadcast exchange: every device ends with all ``n`` chunks.

    Ring algorithm = ``n - 1`` single-shift phases, each conflict-free; total
    volume per device is ``(n-1) * |x|`` — the hybrid model's "send once per
    remote server" (vs ``n*t - 1`` sends under classic exchange).  Result
    ``y[j]`` is device ``j``'s chunk.
    """
    n = _axis_size(axis_name)
    me = lax.axis_index(axis_name)
    y = jnp.zeros((n,) + x.shape, x.dtype)
    y = lax.dynamic_update_slice_in_dim(y, x[None], me, axis=0)
    if n == 1:
        return y
    perm = [(i, (i + 1) % n) for i in range(n)]
    cur = x
    for k in range(1, n):
        cur = lax.ppermute(cur, axis_name, perm)
        src = (me - k) % n  # after k hops I hold device (me-k)'s chunk
        y = lax.dynamic_update_slice_in_dim(y, cur[None], src, axis=0)
    return y


def broadcast_exchange(x: jax.Array, axis_name: str, impl: str = "ring") -> jax.Array:
    if impl == "ring":
        return ring_all_gather(x, axis_name)
    if impl == "xla":
        return lax.all_gather(x, axis_name, axis=0, tiled=False)
    raise ValueError(f"unknown broadcast impl {impl!r}")


# ----------------------------------------------------------------------------
# Hierarchical collectives (hybrid parallelism for gradient sync).
# ----------------------------------------------------------------------------

def hierarchical_psum(x: jax.Array, inner_axis: str, outer_axis: str) -> jax.Array:
    """Two-level all-reduce: RS(inner) -> AR(outer) -> AG(inner).

    The paper's "network in the small vs in the large": the bandwidth-hungry
    reduce-scatter/all-gather stay on the fast inner network (ICI); only the
    already-reduced ``1/inner_size`` shard crosses the slow outer network
    (DCI).  Cross-pod traffic drops by the inner axis size versus a flat
    all-reduce over both axes.

    ``x``'s leading dim must be divisible by the inner axis size (use
    :func:`hierarchical_psum_tree` for arbitrary pytrees).
    """
    shard = lax.psum_scatter(x, inner_axis, scatter_dimension=0, tiled=True)
    shard = lax.psum(shard, outer_axis)
    return lax.all_gather(shard, inner_axis, axis=0, tiled=True)


def _pad_to(x: jax.Array, multiple: int) -> jax.Array:
    pad = (-x.shape[0]) % multiple
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x


def hierarchical_psum_tree(tree: Any, inner_axis: str, outer_axis: str) -> Any:
    """Hierarchical all-reduce of a gradient pytree (flatten-pad-reshape)."""

    def one(leaf: jax.Array) -> jax.Array:
        flat = leaf.reshape(-1)
        n = flat.shape[0]
        inner = _axis_size(inner_axis)
        padded = _pad_to(flat, inner)
        red = hierarchical_psum(padded, inner_axis, outer_axis)
        return red[:n].reshape(leaf.shape)

    return jax.tree.map(one, tree)


def flat_psum_tree(tree: Any, axis_names: tuple[str, ...]) -> Any:
    """Baseline: single flat all-reduce over all data axes."""
    return jax.tree.map(lambda g: lax.psum(g, axis_names), tree)


# ----------------------------------------------------------------------------
# Hash shuffle: the decoupled exchange operator proper (paper §3.2 steps 1-7).
# ----------------------------------------------------------------------------

def fibonacci_hash(keys: jax.Array) -> jax.Array:
    """Schema-specialized hash of int keys (stands in for the paper's CRC32).

    The paper hashes join attributes with CRC32 (hardware instruction on
    x86).  TPUs have no CRC32 unit; a Fibonacci/murmur-style multiply-xor mix
    gives the same uniformity at pure-VPU cost.  Delegates to the single
    shared definition in :mod:`repro.kernels.ref` — the Pallas pack kernel
    uses the same one, which is what makes the xla/pallas pack paths
    bit-exact.
    """
    from repro.kernels.ref import fibonacci_hash_ref

    return fibonacci_hash_ref(keys)


def _scatter_pack(
    dest: jax.Array,
    my_rank: jax.Array,
    counts_all: jax.Array,
    rows: jax.Array,
    num_dest: int,
    capacity: int,
    valid: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Shared scatter epilogue: within-destination ranks -> message buffers.

    ``dest`` is the masked destination (invalid rows -> bin ``num_dest``),
    ``my_rank`` the arrival-order rank within that bin, ``counts_all`` the
    per-bin totals (only ``[:num_dest]`` is used).  The scatter itself stays
    in XLA — dynamic scatter is not an MXU shape.
    """
    counts = jnp.minimum(counts_all[:num_dest], capacity)
    keep = (my_rank < capacity) & valid & (dest < num_dest)
    slot = jnp.where(keep, dest * capacity + my_rank, num_dest * capacity)
    flat = jnp.zeros((num_dest * capacity + 1,) + rows.shape[1:], rows.dtype)
    flat = flat.at[slot].set(jnp.where(keep.reshape((-1,) + (1,) * (rows.ndim - 1)), rows, 0))
    buffers = flat[:-1].reshape((num_dest, capacity) + rows.shape[1:])
    dropped = (valid & (dest < num_dest)).sum() - keep.sum()
    return buffers, counts, dropped


def _rank_by_destination(
    dest: jax.Array, num_dest: int, impl: PackImpl
) -> tuple[jax.Array, jax.Array]:
    """Arrival-order rank within each destination bin + per-bin totals.

    ``dest`` must already have invalid rows masked to the overflow bin
    ``num_dest``.  Shared by :func:`pack_by_destination` and the two-level
    shuffle (which packs several arrays with one rank computation).
    """
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops

        return kernel_ops.partition_ranks(dest, num_dest + 1)
    if impl == "xla":
        onehot = jax.nn.one_hot(dest, num_dest + 1, dtype=jnp.int32)
        rank = jnp.cumsum(onehot, axis=0) - onehot  # rank within destination
        my_rank = jnp.take_along_axis(rank, dest[:, None], axis=1)[:, 0]
        return my_rank, onehot.sum(axis=0)
    raise ValueError(f"unknown pack impl {impl!r}")


def pack_by_destination(
    dest: jax.Array,
    rows: jax.Array,
    num_dest: int,
    capacity: int,
    valid: jax.Array | None = None,
    impl: PackImpl = "xla",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Partition ``rows`` into per-destination message buffers (paper step 2).

    Returns ``(buffers, counts, dropped)`` with ``buffers: [num_dest,
    capacity, row...]``, ``counts: [num_dest]`` valid rows per buffer and
    ``dropped``: rows lost to capacity overflow (0 when capacity is sized to
    the skew bound).  Static shapes throughout — the message pool analogue:
    fixed-size reusable buffers.

    ``impl="xla"`` ranks rows with a ``[rows, num_dest + 1]`` one-hot/cumsum
    (the reference); ``impl="pallas"`` uses the fused block-parallel kernel
    (:func:`repro.kernels.ops.partition_ranks`) and never materializes the
    one-hot.  Both produce bit-identical buffers, counts and drop counts.
    """
    nrows = dest.shape[0]
    if valid is None:
        valid = jnp.ones((nrows,), jnp.bool_)
    dest = jnp.where(valid, dest, num_dest)  # invalid rows -> overflow bucket
    my_rank, counts_all = _rank_by_destination(dest, num_dest, impl)
    return _scatter_pack(dest, my_rank, counts_all, rows, num_dest, capacity, valid)


def hash_shuffle(
    keys: jax.Array,
    rows: jax.Array,
    axis_name: str,
    capacity: int,
    impl: AllToAllImpl = "round_robin",
    valid: jax.Array | None = None,
    pack_impl: PackImpl = "xla",
    num_chunks: int = 1,
    transport_chunks: int = 1,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full decoupled exchange: partition by key hash, shuffle, reassemble.

    Per device: rows whose ``hash(key) % n == j`` are packed into message
    ``j`` and shuffled so that afterwards every device holds exactly the rows
    hashing to its index.  Returns ``(rows_out, valid_out, dropped)`` where
    ``rows_out: [n * capacity, row...]`` and ``valid_out`` masks real rows.

    ``pack_impl="pallas"`` fuses hash + mask + rank into one kernel pass
    (:func:`repro.kernels.ops.hash_partition_ranks`).

    ``num_chunks > 1`` turns the shuffle into a chunked double-buffered
    pipeline: rows are split into ``num_chunks`` equal chunks (each with
    ``capacity / num_chunks`` per-destination slots), and chunk ``k + 1`` is
    packed *before* chunk ``k``'s phases are issued, so partition compute
    overlaps shuffle DMA.  Requires ``num_chunks`` to divide both the row
    count and ``capacity``.  The output layout is unchanged
    (``rows_out[j*capacity : (j+1)*capacity]`` holds device ``j``'s rows in
    arrival order), but padding slots sit at each chunk boundary rather than
    all at the tail, and capacity overflow is assessed per chunk.

    ``transport_chunks`` is forwarded to the scheduled transports: each
    phase's message buffer is split into this many independent ppermutes
    (must divide the per-chunk capacity; the tiny counts exchange is never
    split).
    """
    n = _axis_size(axis_name)
    T = keys.shape[0]
    if valid is None:
        valid = jnp.ones((T,), jnp.bool_)
    assert T % num_chunks == 0 and capacity % num_chunks == 0, (
        f"num_chunks={num_chunks} must divide rows={T} and capacity={capacity}"
    )
    cap_c = capacity // num_chunks
    assert cap_c % transport_chunks == 0, (
        f"transport_chunks={transport_chunks} must divide per-chunk capacity {cap_c}"
    )
    rows_c = T // num_chunks

    def pack(c: int):
        sl = slice(c * rows_c, (c + 1) * rows_c)
        keys_c, data_c, valid_c = keys[sl], rows[sl], valid[sl]
        if pack_impl == "pallas":
            from repro.kernels import ops as kernel_ops

            dest, my_rank, counts_all = kernel_ops.hash_partition_ranks(
                keys_c, valid_c.astype(jnp.int32), n
            )
            return _scatter_pack(dest, my_rank, counts_all, data_c, n, cap_c, valid_c)
        dest = (fibonacci_hash(keys_c) % jnp.uint32(n)).astype(jnp.int32)
        return pack_by_destination(dest, data_c, n, cap_c, valid=valid_c, impl=pack_impl)

    # Double-buffered pipeline: the pack of chunk c+1 is issued before the
    # ppermute phases of chunk c and has no data dependence on them, so the
    # async scheduler is free to overlap partition compute with shuffle DMA.
    packed = pack(0)
    shuffled_chunks, counts_chunks = [], []
    dropped = jnp.int32(0)
    for c in range(num_chunks):
        bufs, counts, dropped_c = packed
        if c + 1 < num_chunks:
            packed = pack(c + 1)
        shuffled_chunks.append(
            all_to_all(bufs, axis_name, impl=impl, num_chunks=transport_chunks)
        )
        counts_chunks.append(
            all_to_all(counts.reshape(n, 1), axis_name, impl=impl).reshape(n)
        )
        dropped = dropped + dropped_c

    if num_chunks == 1:
        shuffled, counts_in = shuffled_chunks[0], counts_chunks[0]
        rows_out = shuffled.reshape((n * capacity,) + shuffled.shape[2:])
        valid_out = (
            jnp.arange(cap_c)[None, :] < counts_in[:, None]
        ).reshape(n * capacity)
    else:
        stacked = jnp.stack(shuffled_chunks, axis=1)  # [n, C, cap_c, row...]
        rows_out = stacked.reshape((n * capacity,) + stacked.shape[3:])
        counts_in = jnp.stack(counts_chunks, axis=1)  # [n, C]
        valid_out = (
            jnp.arange(cap_c)[None, None, :] < counts_in[:, :, None]
        ).reshape(n * capacity)
    return rows_out, valid_out, lax.psum(dropped, axis_name)


def hash_shuffle_spill(
    keys: jax.Array,
    rows: jax.Array,
    axis_name: str,
    capacity: int,
    impl: AllToAllImpl = "round_robin",
    valid: jax.Array | None = None,
    pack_impl: PackImpl = "xla",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Exchange that reports overflow instead of dropping it.

    Same wire layout as single-chunk :func:`hash_shuffle`, but a row whose
    within-destination arrival rank exceeds ``capacity`` is *withheld on the
    sender* rather than silently lost: the third return value is a per-row
    boolean ``spilled`` mask (sender-local, shape ``[rows]``).  The caller
    moves the masked rows to a host-memory overflow partition and re-offers
    them in a later drain pass.  Delivered rows are structurally drop-free —
    every row is either in ``rows_out`` on its owner or flagged in
    ``spilled`` on its sender, never neither.

    Overflow is detectable before any data moves because the rank/count pass
    runs on the sender (paper §3.2 step 2): ``my_rank >= capacity`` is
    exactly the overflow condition the fixed-size message pool would hit.
    """
    n = _axis_size(axis_name)
    T = keys.shape[0]
    if valid is None:
        valid = jnp.ones((T,), jnp.bool_)
    if pack_impl == "pallas":
        from repro.kernels import ops as kernel_ops

        dest, my_rank, counts_all = kernel_ops.hash_partition_ranks(
            keys, valid.astype(jnp.int32), n
        )
    else:
        dest = (fibonacci_hash(keys) % jnp.uint32(n)).astype(jnp.int32)
        dest = jnp.where(valid, dest, n)
        my_rank, counts_all = _rank_by_destination(dest, n, pack_impl)
    spilled = valid & (my_rank >= capacity)
    deliver = valid & ~spilled
    bufs, counts, _ = _scatter_pack(dest, my_rank, counts_all, rows, n, capacity, deliver)
    shuffled = all_to_all(bufs, axis_name, impl=impl)
    counts_in = all_to_all(counts.reshape(n, 1), axis_name, impl=impl).reshape(n)
    rows_out = shuffled.reshape((n * capacity,) + shuffled.shape[2:])
    valid_out = (jnp.arange(capacity)[None, :] < counts_in[:, None]).reshape(n * capacity)
    return rows_out, valid_out, spilled


# ----------------------------------------------------------------------------
# Two-level exchange: coarse cross-pod hop + fine in-pod shuffle (paper §3.1).
# ----------------------------------------------------------------------------

def hash_shuffle_two_level(
    keys: jax.Array,
    rows: jax.Array,
    inner_axis: str,
    outer_axis: str,
    capacity: int,
    impl: AllToAllImpl = "round_robin",
    valid: jax.Array | None = None,
    pack_impl: PackImpl = "xla",
    num_chunks: int = 1,
    transport_chunks: int = 1,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Globally repartition by key hash over a two-level (pod x inner) mesh.

    The paper's hybrid-parallelism rule says fine-grained shuffles must never
    cross the network in the large — but a join still needs rows with equal
    keys co-located *globally*.  The resolution (§3.1/§3.2.2) is granularity:
    the slow network carries one COARSE message per remote server pair
    (multiplexer-to-multiplexer), while fine-grained partitioning stays on
    the fast network.  This is that exchange, as two hops:

    1. **cross-pod, coarse** — rows are packed by *destination pod*
       (``hash(key) % (P * n) // n``) and shipped over ``outer_axis`` with
       one message per peer pod.  Per device that is ``P - 1`` messages of
       up to the full local row count — pod granularity, so the cross-DCI
       connection count is ``N * (P - 1)`` instead of the classic
       ``N * (N - 1)`` (the paper's ``n^2`` vs ``n^2 t^2`` argument).
    2. **in-pod, fine** — a normal :func:`hash_shuffle` over ``inner_axis``
       delivers each row to the in-pod device owning ``hash(key) % n``
       (because ``n`` divides ``P * n``, the in-pod owner is independent of
       which pod computed it).

    The destination device for every row is exactly the one a flat
    ``hash % N`` shuffle over the joint axis would pick (mesh device order
    puts pod ``p``'s devices at indices ``p*n .. p*n + n - 1``), so results
    match the single-level exchange up to arrival order.

    ``capacity`` has flat-shuffle semantics: the per-(src, dst) message
    bound of the equivalent *global* exchange.  The output is
    ``[n * P * capacity]`` rows per device — the same total as a flat
    ``N``-unit shuffle with that capacity.  Hop 1 is structurally zero-drop
    (its per-pod message capacity is the full local row count); hop 2
    inherits the caller's bound scaled by ``P``.  ``num_chunks`` /
    ``transport_chunks`` pipeline the in-pod hop (the coarse hop is a single
    phase sequence and ships unchunked).  The returned ``dropped`` is
    psummed over BOTH axes — a global count.
    """
    P = _axis_size(outer_axis)
    if P == 1:
        out_rows, out_valid, dropped = hash_shuffle(
            keys, rows, inner_axis, capacity, impl=impl, valid=valid,
            pack_impl=pack_impl, num_chunks=num_chunks,
            transport_chunks=transport_chunks,
        )
        return out_rows, out_valid, lax.psum(dropped, outer_axis)
    n = _axis_size(inner_axis)
    N = P * n
    T = keys.shape[0]
    if valid is None:
        valid = jnp.ones((T,), jnp.bool_)

    # Hop 1: pack by destination pod, one rank computation for keys + rows.
    gdest = (fibonacci_hash(keys) % jnp.uint32(N)).astype(jnp.int32)
    dest_pod = jnp.where(valid, gdest // n, P)  # invalid -> overflow bucket
    my_rank, counts_all = _rank_by_destination(dest_pod, P, pack_impl)
    # Coarse shift phases over the pod axis (the multiplexer connections of
    # the paper): scheduled transports use the shift schedule — valid for
    # every P, unlike one_factorization — and "xla" keeps the monolithic
    # all-to-all for the baseline configuration.
    hop1 = "xla" if impl == "xla" else "round_robin"
    if rows.ndim == 2 and rows.dtype == keys.dtype:
        # Ship keys as an extra leading column of the row matrix: one phase
        # sequence over the slowest network instead of two.  (This is the
        # relational hot path — int32 keys, packed int32 rows.)
        aug = jnp.concatenate([keys[:, None], rows], axis=1)
        aug_bufs, counts, drop1 = _scatter_pack(
            dest_pod, my_rank, counts_all, aug, P, T, valid
        )
        aug_in = all_to_all(aug_bufs, outer_axis, impl=hop1)
        keys_in, rows_in = aug_in[:, :, 0], aug_in[:, :, 1:]
    else:
        key_bufs, counts, drop1 = _scatter_pack(
            dest_pod, my_rank, counts_all, keys, P, T, valid
        )
        row_bufs, _, _ = _scatter_pack(
            dest_pod, my_rank, counts_all, rows, P, T, valid
        )
        keys_in = all_to_all(key_bufs, outer_axis, impl=hop1)
        rows_in = all_to_all(row_bufs, outer_axis, impl=hop1)
    counts_in = all_to_all(counts.reshape(P, 1), outer_axis, impl=hop1)
    valid_in = (
        jnp.arange(T)[None, :] < counts_in.reshape(P)[:, None]
    ).reshape(P * T)

    # Hop 2: ordinary in-pod shuffle.  n | N makes hash % n the correct
    # in-pod owner for rows from any source pod.
    out_rows, out_valid, drop2 = hash_shuffle(
        keys_in.reshape(P * T),
        rows_in.reshape((P * T,) + rows_in.shape[2:]),
        inner_axis,
        capacity * P,
        impl=impl,
        valid=valid_in,
        pack_impl=pack_impl,
        num_chunks=num_chunks,
        transport_chunks=transport_chunks,
    )
    # drop2 is already psummed over the inner axis; lift both to global.
    dropped = lax.psum(lax.psum(drop1, inner_axis), outer_axis)
    dropped = dropped + lax.psum(drop2, outer_axis)
    return out_rows, out_valid, dropped


# ----------------------------------------------------------------------------
# Generic two-level dispatch/combine: the token-routing fabric (paper §3.1).
# ----------------------------------------------------------------------------

def _hop1_impl(impl: AllToAllImpl) -> AllToAllImpl:
    """Coarse-hop transport: shift phases are valid for every pod count
    (one_factorization needs even n), xla keeps the monolithic baseline."""
    return "xla" if impl == "xla" else "round_robin"


def dispatch_two_level(
    x: jax.Array,
    inner_axis: str,
    outer_axis: str,
    impl: AllToAllImpl = "round_robin",
    num_chunks: int = 1,
) -> jax.Array:
    """All-to-all over the JOINT ``(outer, inner)`` axis, as two hops.

    ``x[q * n + j]`` (leading dim ``N = P * n``, mesh device order
    ``(pod, inner) -> pod * n + inner``) is the chunk destined for pod ``q``'s
    device ``j``; the result's entry ``q * n + j`` is the chunk received from
    that device — exactly the contract of a flat :func:`all_to_all` over the
    joint axis, but routed hierarchically:

    1. **coarse, cross-pod** — ``x`` is regrouped by destination *pod* and
       shipped over ``outer_axis`` with ONE message per peer pod (the
       paper's multiplexer-to-multiplexer connection over the network in
       the large: ``P - 1`` coarse messages instead of ``N - 1`` fine ones).
    2. **fine, in-pod** — a scheduled all-to-all over ``inner_axis``
       delivers each sub-chunk to its in-pod owner (``num_chunks`` is the
       transport sub-chunking of this hop).

    Both hops are pure permutations of the same elements — zero arithmetic —
    so the result is BIT-IDENTICAL to the flat joint-axis all-to-all for
    every dtype.  This is what lets MoE expert dispatch (and any other
    token-routing workload) cross a pod mesh without a correctness caveat.

    Generalizes :func:`hash_shuffle_two_level` beyond hash keys: here the
    caller has already assigned every row a destination slot (the leading
    index); the two-level route only changes *how* the bytes move.
    """
    P = _axis_size(outer_axis)
    n = _axis_size(inner_axis)
    if P == 1:
        return all_to_all(x, inner_axis, impl=impl, num_chunks=num_chunks)
    N = P * n
    assert x.shape[0] == N, (
        f"leading dim {x.shape[0]} != joint axis size {P} * {n}"
    )
    rest = x.shape[1:]
    # Hop 1 (coarse): x3[q] = everything destined for pod q, contiguous.
    x3 = x.reshape((P, n) + rest)
    h = all_to_all(x3, outer_axis, impl=_hop1_impl(impl))
    # h[q, j] = chunk from pod q (same inner index) destined for (my_pod, j).
    h2 = jnp.swapaxes(h, 0, 1).reshape((n, -1))
    # Hop 2 (fine): deliver to the in-pod owner j.
    g = all_to_all(h2, inner_axis, impl=impl, num_chunks=num_chunks)
    # g[j, q] = chunk from (q, j) destined for me; restore flat (q, j) order.
    out = jnp.swapaxes(g.reshape((n, P) + rest), 0, 1)
    return out.reshape((N,) + rest)


def combine_two_level(
    x: jax.Array,
    inner_axis: str,
    outer_axis: str,
    impl: AllToAllImpl = "round_robin",
    num_chunks: int = 1,
) -> jax.Array:
    """The return trip of :func:`dispatch_two_level` (same flat-all-to-all
    contract), with the hop order mirrored: fine in-pod first, then ONE
    coarse message per peer pod over ``outer_axis``.  Also a pure
    permutation — bit-identical to the flat route."""
    P = _axis_size(outer_axis)
    n = _axis_size(inner_axis)
    if P == 1:
        return all_to_all(x, inner_axis, impl=impl, num_chunks=num_chunks)
    N = P * n
    assert x.shape[0] == N, (
        f"leading dim {x.shape[0]} != joint axis size {P} * {n}"
    )
    rest = x.shape[1:]
    # Hop 1 (fine): group by destination inner index, shuffle in-pod.
    x3 = jnp.swapaxes(x.reshape((P, n) + rest), 0, 1).reshape((n, -1))
    g = all_to_all(x3, inner_axis, impl=impl, num_chunks=num_chunks)
    # g[j, q] -> h[q, j]: everything destined for pod q, contiguous again.
    h = jnp.swapaxes(g.reshape((n, P) + rest), 0, 1)
    # Hop 2 (coarse): one message per peer pod over the slow network.
    out3 = all_to_all(h, outer_axis, impl=_hop1_impl(impl))
    return out3.reshape((N,) + rest)


__all__ = [
    "AllToAllImpl",
    "PackImpl",
    "xla_all_to_all",
    "scheduled_all_to_all",
    "scheduled_all_to_all_consume",
    "all_to_all",
    "ring_all_gather",
    "broadcast_exchange",
    "hierarchical_psum",
    "hierarchical_psum_tree",
    "flat_psum_tree",
    "fibonacci_hash",
    "pack_by_destination",
    "hash_shuffle",
    "hash_shuffle_spill",
    "hash_shuffle_two_level",
    "dispatch_two_level",
    "combine_two_level",
]
