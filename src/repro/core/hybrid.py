"""Hybrid-parallelism planner (paper §3.1-3.2).

Encodes the paper's quantitative case for distinguishing the *network in the
small* from the *network in the large*, and decides — per mesh — which
collective strategy each model/relational component uses.

Paper cost model (n servers, t threads each):

===============================  ====================  ==================
quantity                          classic exchange      hybrid (this work)
===============================  ====================  ==================
parallel units                    ``n * t``             ``n``
connections in the cluster        ``n^2 t^2 - t``       ``n (n - 1)``
buffers per exchange operator     ``n t - 1``           ``n - 1``
broadcast-join threshold          ``n t - 1`` (239x)    ``n - 1`` (5x)
===============================  ====================  ==================

On TPU: "server" -> pod (or, single-pod, the device row along the `data`
axis), "thread" -> per-chip lanes.  The planner's job is to keep fine-grained
parallelism (TP/morsels) strictly inside the fast network level and run the
shuffle between coarse units only.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

from .topology import ClusterSpec, V5E


# ----------------------------------------------------------------------------
# Paper §3.1 cost model — exact formulas, reproduced in bench_connections.
# ----------------------------------------------------------------------------

def classic_parallel_units(n: int, t: int) -> int:
    return n * t


def hybrid_parallel_units(n: int, t: int) -> int:
    del t
    return n


def classic_connections(n: int, t: int) -> int:
    """Every exchange operator connects to every other: n^2 t^2 - t.

    (The paper counts, for each of the ``n*t`` operators, ``n*t - 1`` peer
    connections but de-duplicates only the self-server loopback term,
    yielding exactly ``n^2 t^2 - t`` = 57,560 for n=6, t=40.)
    """
    return n * n * t * t - t


def hybrid_connections(n: int, t: int) -> int:
    """Only multiplexers are connected: n (n - 1) = 30 for n=6."""
    del t
    return n * (n - 1)


def classic_buffers_per_operator(n: int, t: int) -> int:
    return n * t - 1


def hybrid_buffers_per_operator(n: int, t: int) -> int:
    del t
    return n - 1


def broadcast_threshold(n: int, t: int, hybrid: bool) -> int:
    """Max size ratio (small:large input) at which broadcast still wins.

    A broadcast join sends the small side once to each peer *unit*; hybrid
    parallelism has n-1 peers instead of n*t-1, so broadcast applies to much
    less lopsided joins (5x vs 239x on the paper's cluster).
    """
    return (n - 1) if hybrid else (n * t - 1)


# ----------------------------------------------------------------------------
# Two-level mesh policy.
# ----------------------------------------------------------------------------

CollectiveStrategy = Literal["flat", "hierarchical"]
ExchangeStrategy = Literal["xla", "round_robin"]


@dataclasses.dataclass(frozen=True)
class HybridPlan:
    """Which network level carries which traffic class.

    - ``small_axes``: mesh axes inside the fast network (ICI) — TP, EP,
      sequence parallelism, relational shuffles live here.
    - ``large_axes``: mesh axes across the slow network (DCI) — only
      coarse-grained, bandwidth-frugal traffic (DP gradient sync) crosses it.
    """

    small_axes: tuple[str, ...]
    large_axes: tuple[str, ...]
    grad_sync: CollectiveStrategy
    exchange: ExchangeStrategy
    cluster: ClusterSpec

    @property
    def pod_axis(self) -> str | None:
        """The mesh axis crossing the slow network, if any."""
        return self.large_axes[0] if self.large_axes else None

    @property
    def num_pods(self) -> int:
        return self.cluster.num_pods

    def validate_axis_for_alltoall(self, axis: str) -> None:
        """Fine-grained shuffles must never cross the network in the large.

        Cross-pod traffic is only legal at coarse granularity — one message
        per pod pair (the two-level exchange's first hop, hierarchical
        gradient sync, broadcast of small build sides).  Routing a
        per-destination-device shuffle over a ``large_axes`` member would
        re-create the classic exchange's ``n^2 t^2`` connection blow-up on
        the slowest network, so it is rejected at plan level.
        """
        if axis in self.large_axes:
            raise ValueError(
                f"all-to-all over large-network axis {axis!r}: the hybrid plan "
                "forbids fine-grained shuffles across the slow network "
                "(paper §3.2: exchanges run between coarse units only; use "
                "the two-level hash_shuffle_global for a global repartition)"
            )


def plan_for_mesh(
    axis_names: tuple[str, ...],
    axis_sizes: tuple[int, ...],
    exchange: ExchangeStrategy = "round_robin",
) -> HybridPlan:
    """Derive the hybrid plan from mesh axis names.

    Convention (launch/mesh.py): a leading ``pod`` axis is the network in the
    large; everything else (``data``, ``model``) is in the small.  Single-pod
    meshes have no large axis and gradient sync stays flat (pure ICI).
    """
    names = tuple(axis_names)
    large = tuple(a for a in names if a == "pod")
    small = tuple(a for a in names if a != "pod")
    sizes = dict(zip(axis_names, axis_sizes))
    cluster = ClusterSpec(
        chip=V5E,
        chips_per_pod=int(
            __import__("math").prod(sizes[a] for a in small) if small else 1
        ),
        num_pods=int(sizes.get("pod", 1)),
    )
    return HybridPlan(
        small_axes=small,
        large_axes=large,
        grad_sync="hierarchical" if large else "flat",
        exchange=exchange,
        cluster=cluster,
    )


__all__ = [
    "classic_parallel_units",
    "hybrid_parallel_units",
    "classic_connections",
    "hybrid_connections",
    "classic_buffers_per_operator",
    "hybrid_buffers_per_operator",
    "broadcast_threshold",
    "HybridPlan",
    "plan_for_mesh",
]
