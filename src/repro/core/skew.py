"""Partition-skew analysis and mitigation (paper §3.1).

The paper's argument for hybrid parallelism: with classic exchange operators
every thread is a parallel unit, so an all-to-all shuffle hash-partitions its
input into ``n x t`` partitions (240 on their 6-server cluster).  Under a
moderately skewed Zipf distribution (z = 0.84) the largest of 240 partitions
receives *more than 2x* its fair share, while the largest of only 6
server-level partitions is overloaded by a mere *2.8 %*.  Fewer parallel
units => less skew impact, before any skew-specific technique.

This module reproduces that math (``zipf_partition_overload``) and implements
the two SPMD-compatible mitigations used by the relational engine:

* ``salt_keys`` — split pathologically heavy keys across ``s`` salted
  sub-keys (the standard skew-join trick; the paper cites this family of
  techniques as orthogonal).
* round-robin *morsel interleaving* happens in ``relational/table.py``.
"""

from __future__ import annotations

import numpy as np


def zipf_pmf(num_keys: int, z: float) -> np.ndarray:
    """Zipf probability mass over ``num_keys`` ranked keys, exponent ``z``."""
    ranks = np.arange(1, num_keys + 1, dtype=np.float64)
    w = ranks**-z
    return w / w.sum()


def _hash_keys(keys: np.ndarray, seed: int) -> np.ndarray:
    """Cheap deterministic integer mix (Fibonacci hashing) for partitioning."""
    x = keys.astype(np.uint64) + np.uint64(seed)
    x ^= x >> np.uint64(33)
    x *= np.uint64(0xFF51AFD7ED558CCD)
    x ^= x >> np.uint64(33)
    x *= np.uint64(0xC4CEB9FE1A85EC53)
    x ^= x >> np.uint64(33)
    return x


def zipf_partition_overload(
    num_partitions: int,
    z: float = 0.84,
    num_keys: int = 1_000_000,
    seed: int = 0,
) -> float:
    """Expected relative overload of the largest hash partition.

    Returns ``max_partition_load / fair_share`` where fair share is
    ``1 / num_partitions``.  Computed exactly from the Zipf pmf (no sampling):
    each distinct key's whole mass lands in ``hash(key) % num_partitions``.

    Paper's numbers (z = 0.84): ~2x for 240 partitions, ~1.028 for 6.
    """
    pmf = zipf_pmf(num_keys, z)
    part = (_hash_keys(np.arange(num_keys), seed) % np.uint64(num_partitions)).astype(
        np.int64
    )
    loads = np.bincount(part, weights=pmf, minlength=num_partitions)
    return float(loads.max() * num_partitions)


def generalized_harmonic(num_keys: int, z: float) -> float:
    """H(N, z) = sum_{k=1..N} k^-z, Euler-Maclaurin for huge N.

    Exact summation for the first 100k terms, integral + correction terms for
    the tail — accurate to ~1e-10 relative for the z of interest.
    """
    cut = min(num_keys, 100_000)
    head = float(np.sum(np.arange(1, cut + 1, dtype=np.float64) ** -z))
    if num_keys <= cut:
        return head
    a, b = float(cut), float(num_keys)
    if abs(z - 1.0) < 1e-12:
        integral = np.log(b) - np.log(a)
    else:
        integral = (b ** (1 - z) - a ** (1 - z)) / (1 - z)
    # Euler-Maclaurin: sum_{a+1..b} f ~ integral + (f(b) - f(a))/2 + ...
    corr = (b**-z - a**-z) / 2.0
    return head + integral + corr


def zipf_partition_overload_analytic(
    num_partitions: int,
    z: float = 0.84,
    num_keys: int = 5_600_000_000,
    top: int = 100_000,
    seed: int = 0,
) -> float:
    """Paper-scale skew claim without materializing the key domain.

    The top ``top`` keys are hashed to partitions exactly; the Zipf tail is
    near-uniform under hashing and is spread evenly.  With the paper's
    z = 0.84 and a ~5.6e9-key domain this reproduces BOTH claims of §3.1 at
    once: the largest of 240 partitions carries ~2x its fair share while the
    largest of 6 partitions is overloaded by only ~2.8 %.
    """
    h_all = generalized_harmonic(num_keys, z)
    ranks = np.arange(1, top + 1, dtype=np.float64)
    head_mass = ranks**-z / h_all
    tail_mass = 1.0 - head_mass.sum()
    part = (_hash_keys(np.arange(top), seed) % np.uint64(num_partitions)).astype(
        np.int64
    )
    loads = np.bincount(part, weights=head_mass, minlength=num_partitions)
    loads += tail_mass / num_partitions
    return float(loads.max() * num_partitions)


def zipf_partition_overload_expected(
    num_partitions: int,
    z: float = 0.84,
    num_keys: int = 1_000_000,
    trials: int = 16,
) -> float:
    """Mean over hash seeds — smooths the single-seed variance."""
    vals = [
        zipf_partition_overload(num_partitions, z, num_keys, seed=s)
        for s in range(trials)
    ]
    return float(np.mean(vals))


def salt_keys(
    keys: np.ndarray, heavy_keys: np.ndarray, num_salts: int, seed: int = 0
) -> np.ndarray:
    """Split heavy keys into ``num_salts`` sub-keys to spread their load.

    Non-heavy keys are returned untouched (shifted into the salted key space
    deterministically so no collisions with salted heavy keys are possible).
    The join build side must replicate heavy-key rows across all salts.

    All arithmetic happens in the uint64 key space: the historical int64
    version silently wrapped ``key * num_salts`` for keys above ``2**63 /
    num_salts`` and mapped negative keys and their uint64 twins to the same
    salted slot.  Keys whose shifted value would not fit uint64 — and any
    negative key, which would alias a large uint64 key after the cast — now
    raise instead of corrupting the partitioning.  ``unsalt_keys`` is the
    exact inverse: ``unsalt_keys(salt_keys(k, ...), num_salts) == k``.
    """
    keys = np.asarray(keys)
    num_salts = int(num_salts)
    if num_salts < 1:
        raise ValueError(f"salt_keys: num_salts must be >= 1, got {num_salts}")
    if np.issubdtype(keys.dtype, np.signedinteger) and keys.size and keys.min() < 0:
        raise ValueError(
            "salt_keys: negative keys would alias large uint64 keys after the "
            "unsigned cast; hash keys into [0, 2**64) first"
        )
    u = keys.astype(np.uint64)
    if num_salts > 1 and u.size and int(u.max()) >= 2**64 // num_salts:
        raise ValueError(
            f"salt_keys: key {int(u.max())} * num_salts={num_salts} overflows "
            "the uint64 salted key space"
        )
    out = u * np.uint64(num_salts)
    heavy = np.isin(u, np.asarray(heavy_keys).astype(np.uint64))
    salts = _hash_keys(np.arange(keys.size), seed) % np.uint64(num_salts)
    out[heavy] += salts[heavy]
    return out


def unsalt_keys(salted: np.ndarray, num_salts: int) -> np.ndarray:
    """Recover the original keys from ``salt_keys`` output (exact inverse)."""
    return np.asarray(salted).astype(np.uint64) // np.uint64(num_salts)


def straggler_excess(loads: np.ndarray) -> float:
    """max/mean - 1: the extra work the slowest parallel unit carries."""
    loads = np.asarray(loads, dtype=np.float64)
    return float(loads.max() / loads.mean() - 1.0)


__all__ = [
    "zipf_pmf",
    "generalized_harmonic",
    "zipf_partition_overload",
    "zipf_partition_overload_analytic",
    "zipf_partition_overload_expected",
    "salt_keys",
    "unsalt_keys",
    "straggler_excess",
]
