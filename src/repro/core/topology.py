"""Hardware model + switch-contention simulator.

Two roles:

1. Roofline constants for the TARGET hardware (TPU v5e), used by
   ``launch/roofline.py`` and the benchmarks:
   197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

2. A discrete-event model of the paper's switch-contention experiment
   (Fig 10b): uncoordinated all-to-all vs round-robin scheduled phases.
   The paper measures +40 % throughput from scheduling on an 8-port
   InfiniBand switch; the simulator reproduces that number analytically so
   the claim is checkable without network hardware.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip roofline constants (TPU v5e, the assignment's target).

    ``ici_launch_latency`` is the fixed cost of issuing one collective-permute
    (DMA descriptor setup + phase sync) — the TPU analogue of the paper's
    ~1 us inline synchronization message (Fig 10c).  ``kernel_launch_latency``
    is the fixed cost of one pack-kernel dispatch.  Both feed the autotuner's
    per-phase cost model (:func:`phase_time`, :func:`pack_time`).
    """

    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12  # FLOP/s
    hbm_bandwidth: float = 819e9  # B/s
    ici_link_bandwidth: float = 50e9  # B/s per link per direction
    ici_links_per_chip: int = 4  # 2D torus: +x, -x, +y, -y
    dci_bandwidth: float = 25e9  # B/s per chip cross-pod (optical, scarcer)
    hbm_bytes: int = 16 * 2**30
    vmem_bytes: int = 128 * 2**20
    ici_launch_latency: float = 2e-6  # s per issued ppermute phase
    dci_launch_latency: float = 10e-6  # s per cross-pod phase (DCN RTT-ish)
    kernel_launch_latency: float = 1e-6  # s per pack-kernel dispatch

    def link_bandwidth(self, network: str = "ici") -> float:
        """Per-unit link bandwidth of one network level ('ici' or 'dci')."""
        if network == "ici":
            return self.ici_link_bandwidth
        if network == "dci":
            return self.dci_bandwidth
        raise ValueError(f"unknown network level {network!r}")

    def launch_latency(self, network: str = "ici") -> float:
        """Per-phase collective launch latency of one network level."""
        if network == "ici":
            return self.ici_launch_latency
        if network == "dci":
            return self.dci_launch_latency
        raise ValueError(f"unknown network level {network!r}")


V5E = ChipSpec()


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Two-level cluster: the paper's 'network in the small / in the large'.

    Paper: NUMA/QPI inside a server, InfiniBand between servers.
    Here:  ICI inside a pod, DCI between pods.
    """

    chip: ChipSpec = V5E
    chips_per_pod: int = 256
    num_pods: int = 1

    @property
    def total_chips(self) -> int:
        return self.chips_per_pod * self.num_pods

    def bisection_bandwidth_small(self) -> float:
        """Aggregate ICI bisection bandwidth inside one pod (16x16 torus)."""
        # 16x16 2D torus bisection: 2 * 16 wraparound rings cut twice.
        side = int(round(self.chips_per_pod**0.5))
        return 2 * 2 * side * self.chip.ici_link_bandwidth

    def bisection_bandwidth_large(self) -> float:
        """Aggregate DCI bandwidth between pods."""
        return self.chips_per_pod * self.chip.dci_bandwidth


def _maxmin_rates(flows: list[tuple[int, int]], n: int) -> dict[int, float]:
    """Max-min fair rate per flow index, senders and receivers capped at 1.

    Progressive water-filling: repeatedly saturate the most-constrained port
    and freeze its flows' rates.
    """
    rates: dict[int, float] = {}
    active = set(range(len(flows)))
    send_cap = [1.0] * n
    recv_cap = [1.0] * n
    while active:
        # Per-port share if split evenly among its unfrozen flows.
        port_share: list[tuple[float, str, int]] = []
        snd: dict[int, list[int]] = {}
        rcv: dict[int, list[int]] = {}
        for f in active:
            s, d = flows[f]
            snd.setdefault(s, []).append(f)
            rcv.setdefault(d, []).append(f)
        for s, fs in snd.items():
            port_share.append((send_cap[s] / len(fs), "s", s))
        for d, fs in rcv.items():
            port_share.append((recv_cap[d] / len(fs), "r", d))
        share, kind, port = min(port_share)
        frozen = snd[port] if kind == "s" else rcv[port]
        for f in frozen:
            rates[f] = share
            s, d = flows[f]
            send_cap[s] -= share
            recv_cap[d] -= share
            active.discard(f)
    return rates


def simulate_contention_factor(
    n: int,
    messages_per_pair: int = 8,
    outstanding: int = 3,
    trials: int = 32,
    seed: int = 0,
) -> float:
    """Effective-throughput factor of an UNcoordinated all-to-all.

    Discrete-event model of an ``n``-port switch (paper §3.2.3): each server
    sends ``messages_per_pair`` equal messages to each of the other ``n - 1``
    servers in an independent random target order.  A sender may have up to
    ``outstanding`` head-of-queue messages in flight (InfiniBand credit /
    switch input-buffer depth); beyond that it blocks — the credit-starvation
    effect the paper describes.  Active flows get max-min fair rates with
    sender NICs and receiver ports both capped at link rate.

    Returns ``scheduled_time / unscheduled_time`` (<= 1).  At ``n = 8``,
    ``outstanding = 3`` (default) this yields ~0.73, i.e. scheduling wins
    ~1.4x — the paper's Fig 10(b) measurement (+40 %).  ``outstanding = 1``
    models a bufferless switch (worst case, ~2x win); large ``outstanding``
    approaches ideal output queuing (no win).  The win grows with n
    (1.39x @ 4, 1.47x @ 6, 1.58x @ 16), matching the paper's expectation
    that "the impact of network scheduling ... increase[s] further with the
    cluster size".
    """
    rng = np.random.default_rng(seed)
    factors = []
    ideal = (n - 1) * messages_per_pair  # time units at unit message time
    for _ in range(trials):
        queues = []
        for i in range(n):
            targets = rng.permutation(
                np.repeat([j for j in range(n) if j != i], messages_per_pair)
            )
            queues.append(list(targets))
        # In-flight window per sender: list of [dst, remaining].
        windows: list[list[list[float]]] = [[] for _ in range(n)]
        t = 0.0
        while any(queues) or any(windows):
            for i in range(n):
                while len(windows[i]) < outstanding and queues[i]:
                    windows[i].append([queues[i].pop(0), 1.0])
            flows = [
                (i, int(m[0])) for i in range(n) for m in windows[i]
            ]
            if not flows:
                break
            rates = _maxmin_rates(flows, n)
            # Map flow rates back per message in order.
            k = 0
            dt = float("inf")
            for i in range(n):
                for m in windows[i]:
                    r = rates[k]
                    dt = min(dt, m[1] / r if r > 0 else float("inf"))
                    k += 1
            t += dt
            k = 0
            for i in range(n):
                keep = []
                for m in windows[i]:
                    m[1] -= rates[k] * dt
                    k += 1
                    if m[1] > 1e-12:
                        keep.append(m)
                windows[i] = keep
        factors.append(ideal / t)
    return float(np.mean(factors))


@functools.lru_cache(maxsize=None)
def contention_factor(n: int) -> float:
    """Cached, budgeted contention factor for model/benchmark use.

    The discrete-event simulator is O(n^3)-ish per event; beyond 32 ports
    the factor has plateaued (the paper's effect saturates once every
    receiver is persistently over-subscribed), so we evaluate the simulator
    up to 32 ports with a trial budget that shrinks with n and hold the
    32-port value constant beyond — a *conservative* (smaller) win.
    """
    if n <= 2:
        return 1.0
    if n > 32:
        return contention_factor(32)
    trials = max(2, 64 // n)
    return simulate_contention_factor(n, trials=trials)


def scheduled_vs_unscheduled_speedup(n: int, **kw) -> float:
    """Paper Fig 10(b): throughput gain of round-robin scheduling."""
    if kw:
        return 1.0 / simulate_contention_factor(n, **kw)
    return 1.0 / contention_factor(n)


# ----------------------------------------------------------------------------
# Per-phase cost model (feeds repro.core.autotune.tune_multiplexer).
#
# The paper's argument (§3.2.3, Fig 10b/c) is that the right transport
# strategy follows from message size vs link latency and schedule phase count
# vs switch contention — so the model below prices exactly those terms:
# pack compute against HBM bandwidth, each ppermute phase as launch latency
# plus wire time, and the unscheduled baseline degraded by the simulated
# contention factor.
# ----------------------------------------------------------------------------

PACK_IMPLS = ("xla", "pallas")


def pack_time(
    rows: int,
    row_bytes: float,
    num_dest: int,
    chip: ChipSpec = V5E,
    impl: str = "xla",
) -> float:
    """Modeled partition+pack time for one pipeline chunk (HBM-bound).

    The pack is pure data movement — hash, rank, scatter — so it is priced as
    bytes touched over HBM bandwidth plus one kernel dispatch:

    * ``"xla"`` (one-hot/cumsum reference): materializes and re-reads a
      ``[rows, num_dest + 1]`` int32 one-hot (write + cumsum read/write =
      3 passes), then gathers ranks and scatters the rows — the
      O(rows x destinations) term that dominates as the mesh grows.
    * ``"pallas"`` (fused partition+pack kernel): one pass over keys and
      ranks plus the ``[nblocks, bins]`` histogram scan; the scatter
      epilogue reads and writes each row once.  Cost scales with
      ``rows + nblocks x destinations``.
    """
    if rows <= 0:
        return 0.0
    bins = num_dest + 1  # + overflow bucket for invalid rows
    scatter = 2 * rows * row_bytes  # read rows + write buffers (both impls)
    if impl == "xla":
        touched = rows * 12 * bins + 8 * rows + scatter
    elif impl == "pallas":
        nblocks = max(1, -(-rows // 256))
        touched = 8 * rows + 12 * nblocks * bins + scatter
    else:
        raise ValueError(f"unknown pack impl {impl!r}")
    return chip.kernel_launch_latency + touched / chip.hbm_bandwidth


def phase_time(
    message_bytes: float,
    chip: ChipSpec = V5E,
    transport_chunks: int = 1,
    link_load: int = 1,
    network: str = "ici",
) -> float:
    """One scheduled shuffle phase: launch latency per sub-message + wire time.

    ``transport_chunks`` splits the phase message into that many independent
    ppermutes — each pays the launch latency, the wire time is unchanged.
    ``link_load`` is the number of messages sharing the phase's busiest link
    (1 on a non-blocking switch; :func:`repro.core.schedule.ring_phase_load`
    on a torus ring), which stretches the wire time proportionally.
    ``network`` selects the level the phase crosses: ``"ici"`` (in-pod, the
    network in the small) or ``"dci"`` (cross-pod, the network in the large
    — lower bandwidth, higher per-phase latency).
    """
    wire = link_load * message_bytes / chip.link_bandwidth(network)
    return transport_chunks * chip.launch_latency(network) + wire


def shuffle_time(
    n: int,
    message_bytes: float,
    chip: ChipSpec = V5E,
    impl: str = "round_robin",
    transport_chunks: int = 1,
    topology: str = "switch",
    network: str = "ici",
) -> float:
    """Modeled all-to-all time: ``message_bytes`` from each unit to each peer.

    * scheduled impls (``"round_robin"`` = shift schedule,
      ``"one_factorization"``): a sum of :func:`phase_time` over the
      schedule's ``n - 1`` phases.  With ``topology="switch"`` every phase is
      contention-free (the paper's non-blocking switch; at zero launch
      latency this equals ``schedule_link_time(..., scheduled=True)``); with
      ``topology="ring"`` each phase's wire time is stretched by its peak
      ring-link load (multi-hop shifts share links).
    * ``"xla"`` (the monolithic all-to-all): one launch.  On a switch it is
      the paper's *unscheduled* baseline — total wire time degraded by the
      simulated contention factor (:func:`contention_factor`), matching
      ``schedule_link_time(..., scheduled=False)``.  On a ring there is no
      uncoordinated-switch to contend for: the compiler schedules the
      collective over the same links, so it pays the same link-load wire
      bound as the shift schedule with a single launch — its real cost
      relative to the scheduled impls is that one monolithic DMA cannot be
      pipelined against pack compute (see the autotuner's overlap term).

    ``network`` prices the same shuffle over the other network level: the
    cross-pod hop of a two-level exchange is a ``num_pods``-unit all-to-all
    over ``"dci"`` (a switched optical fabric — ``topology="switch"`` is the
    natural pairing; there is no DCI ring to share links on).
    """
    from .schedule import make_schedule, schedule_ring_loads

    if n <= 1 or message_bytes <= 0:
        return 0.0
    if impl == "xla":
        if topology == "ring":
            loads = schedule_ring_loads(make_schedule(n, "shift"))
            wire = sum(loads) * message_bytes / chip.link_bandwidth(network)
            return chip.launch_latency(network) + wire
        wire = (n - 1) * message_bytes / chip.link_bandwidth(network)
        return chip.launch_latency(network) + wire / contention_factor(n)
    kind = "shift" if impl == "round_robin" else impl
    sched = make_schedule(n, kind)
    if topology == "ring":
        loads = schedule_ring_loads(sched)
    elif topology == "switch":
        loads = [1] * sched.num_phases
    else:
        raise ValueError(f"unknown topology {topology!r}")
    return sum(
        phase_time(message_bytes, chip, transport_chunks, load, network)
        for load in loads
    )


def pod_broadcast_time(
    num_pods: int,
    pod_bytes: float,
    chip: ChipSpec = V5E,
) -> float:
    """Cross-pod broadcast: ship one pod's aggregate ``pod_bytes`` to every
    other pod over DCI (ring all-gather: ``num_pods - 1`` phases).  The
    paper's broadcast-join cost under hybrid parallelism — each byte is sent
    once per remote *server*, not once per remote thread.
    """
    if num_pods <= 1 or pod_bytes <= 0:
        return 0.0
    return (num_pods - 1) * phase_time(pod_bytes, chip, network="dci")


def sync_amortization(
    message_bytes: float,
    link_bandwidth: float = V5E.ici_link_bandwidth,
    sync_latency_s: float = 1e-6,
    messages_per_phase: int = 8,
) -> float:
    """Paper Fig 10(c): fraction of peak throughput with phase-sync overhead.

    The paper synchronizes phases with ~1 us inline messages and finds 512 KB
    messages fully hide the cost.  On TPU the phase boundary is the
    collective_permute itself; its launch latency plays the same role.
    """
    transfer = messages_per_phase * message_bytes / link_bandwidth
    return transfer / (transfer + sync_latency_s)


__all__ = [
    "ChipSpec",
    "ClusterSpec",
    "V5E",
    "simulate_contention_factor",
    "contention_factor",
    "scheduled_vs_unscheduled_speedup",
    "pack_time",
    "phase_time",
    "shuffle_time",
    "pod_broadcast_time",
    "sync_amortization",
]
