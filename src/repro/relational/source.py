"""DataSource: what a ``Scan`` reads from.

An in-memory :class:`~repro.relational.table.Table` is one implementation
(:class:`TableSource`, a single chunk).  Out-of-core inputs are chunked:
they yield fixed-capacity partitions ("morsels") one at a time, so a table
whose total capacity exceeds device memory streams through the executor
morsel by morsel (``planner/stream.py``) with double-buffered host→device
prefetch (``data/pipeline.py``).

Chunks are fixed-shape by construction — every chunk of a source has the
same row capacity (the last one padded with invalid rows) — so the jitted
per-morsel step compiles once and is reused for every chunk.
"""

from __future__ import annotations

import math
from typing import Callable, Iterator

import jax.numpy as jnp

from .table import Table, pad_to

__all__ = [
    "DataSource",
    "TableSource",
    "MorselView",
    "GeneratorSource",
    "as_source",
    "concat_tables",
]


def concat_tables(chunks: list[Table]) -> Table:
    """Row-wise concatenation (dictionaries taken from the first chunk)."""
    if not chunks:
        raise ValueError("concat_tables: empty chunk list")
    cols = {
        c: jnp.concatenate([t.columns[c] for t in chunks]) for c in chunks[0].columns
    }
    valid = jnp.concatenate([t.valid for t in chunks])
    return Table(cols, valid, dict(chunks[0].dictionaries))


class DataSource:
    """Base interface: a named relation delivered as fixed-capacity chunks."""

    #: Total row capacity across all chunks (what the planner catalogs).
    capacity: int
    #: Number of fixed-capacity chunks; 1 means fully in-memory.
    num_chunks: int
    #: Row capacity of every chunk (``capacity == num_chunks * chunk_rows``).
    chunk_rows: int

    @property
    def is_chunked(self) -> bool:
        return self.num_chunks > 1

    def chunks(self) -> Iterator[Table]:
        raise NotImplementedError

    def materialize(self) -> Table:
        """The whole relation as one in-memory Table (the streaming oracle)."""
        return concat_tables(list(self.chunks()))


class TableSource(DataSource):
    """An in-memory Table as a single-chunk source."""

    def __init__(self, table: Table):
        self.table = table
        self.capacity = table.capacity
        self.num_chunks = 1
        self.chunk_rows = table.capacity

    def chunks(self) -> Iterator[Table]:
        yield self.table

    def materialize(self) -> Table:
        return self.table


class MorselView(DataSource):
    """Chunked view over an in-memory Table.

    Slices ``table`` into ``ceil(capacity / morsel_rows)`` fixed-capacity
    morsels (last padded with invalid rows).  The padding rows make
    ``capacity`` grow to the next multiple of ``morsel_rows``; they carry
    ``valid=False`` so results are unaffected.  Useful for exercising the
    streamed execution path against data that does fit in memory.
    """

    def __init__(self, table: Table, morsel_rows: int):
        if morsel_rows < 1:
            raise ValueError("morsel_rows must be >= 1")
        self.table = table
        self.chunk_rows = min(morsel_rows, table.capacity)
        self.num_chunks = math.ceil(table.capacity / self.chunk_rows)
        self.capacity = self.num_chunks * self.chunk_rows

    def chunks(self) -> Iterator[Table]:
        t, m = self.table, self.chunk_rows
        for i in range(self.num_chunks):
            lo, hi = i * m, min((i + 1) * m, t.capacity)
            cols = {c: t.columns[c][lo:hi] for c in t.columns}
            chunk = Table(cols, t.valid[lo:hi], dict(t.dictionaries))
            yield pad_to(chunk, m) if hi - lo < m else chunk


class GeneratorSource(DataSource):
    """Chunks produced on demand by ``make_chunk(chunk_index) -> Table``.

    This is the true out-of-core source: chunks are generated (or loaded)
    lazily, so only ``chunk_rows`` rows are ever resident on the host per
    chunk — total capacity can exceed any memory budget.
    """

    def __init__(self, make_chunk: Callable[[int], Table], num_chunks: int, chunk_rows: int):
        if num_chunks < 1 or chunk_rows < 1:
            raise ValueError("num_chunks and chunk_rows must be >= 1")
        self.make_chunk = make_chunk
        self.num_chunks = num_chunks
        self.chunk_rows = chunk_rows
        self.capacity = num_chunks * chunk_rows

    def chunks(self) -> Iterator[Table]:
        for i in range(self.num_chunks):
            chunk = self.make_chunk(i)
            if chunk.capacity != self.chunk_rows:
                raise ValueError(
                    f"chunk {i} has capacity {chunk.capacity}, expected {self.chunk_rows}"
                )
            yield chunk


def as_source(obj: "Table | DataSource") -> DataSource:
    if isinstance(obj, DataSource):
        return obj
    if isinstance(obj, Table):
        return TableSource(obj)
    raise TypeError(f"expected Table or DataSource, got {type(obj)!r}")
