"""Relational operators with static shapes (the local execution engine).

Everything is mask-carrying and shape-static so it jits, shards, and lowers
for the dry-run.  The operators mirror HyPer's pipeline set used by the
paper's TPC-H plans: filter (selection vectors), project (column pruning),
group-by aggregation, PK-FK join, top-k.

HARDWARE ADAPTATION (DESIGN.md §2): HyPer's joins/aggregations are
hash-table-based — pointer chasing that x86 cores love and TPU vector units
hate.  The TPU-idiomatic equivalents used here are *sort-based*: bitonic
sort + ``searchsorted`` for PK-FK joins and sorted segment reduction for
group-by.  Same results, same asymptotics up to the log factor, but contiguous
vector memory traffic instead of random probes.  (The paper itself cites
MPSM [2] — sort-merge — as the NUMA-friendly choice; the same argument holds
one level down on the TPU.)  The *distributed* layer on top (queries.py) is
exactly the paper's: partition/broadcast decisions + the scheduled exchange.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from .table import Table

_KEY_SENTINEL = jnp.iinfo(jnp.int32).max


# ----------------------------------------------------------------------------
# Aggregation primitives.
# ----------------------------------------------------------------------------

def sum_where(col: jax.Array, mask: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Masked sum.  Money/quantity sums accumulate in f32: int32 would
    overflow on TPC-H money columns and int64/f64 need the global x64 flag.
    Two-stage (per-device then psum) summation keeps the f32 error ~1e-6."""
    return jnp.sum(jnp.where(mask, col.astype(dtype), 0))


def count_where(mask: jax.Array) -> jax.Array:
    return jnp.sum(mask.astype(jnp.int32))


# ----------------------------------------------------------------------------
# Group-by: dense (small key domain) and sort-based (large key domain).
# ----------------------------------------------------------------------------

def groupby_dense(
    group_ids: jax.Array,
    num_groups: int,
    aggregates: dict[str, tuple[jax.Array, str]],
    valid: jax.Array,
) -> dict[str, jax.Array]:
    """Aggregate into a small dense group table (e.g. Q1's 6 groups).

    ``aggregates``: name -> (column, 'sum'|'count').  This is the paper's
    *pre-aggregation* building block (Fig 6c): each device reduces its rows
    locally into num_groups cells; cross-device combination is a psum of the
    tiny group table instead of a shuffle of raw rows.
    """
    gid = jnp.where(valid, group_ids, num_groups)  # invalid -> overflow cell
    out = {}
    for name, (col, kind) in aggregates.items():
        if kind == "sum":
            vals = col.astype(jnp.float32)
        else:  # count
            vals = jnp.ones_like(gid, jnp.int32)
        out[name] = jax.ops.segment_sum(
            jnp.where(valid, vals, 0), gid, num_segments=num_groups + 1
        )[:num_groups]
    return out


def groupby_sorted(
    keys: jax.Array,
    valid: jax.Array,
    aggregates: dict[str, tuple[jax.Array, str]],
) -> tuple[jax.Array, jax.Array, dict[str, jax.Array]]:
    """Sort-based group-by for large key domains (e.g. Q3's orderkeys).

    Returns ``(group_keys, group_valid, aggs)`` all with the input's
    capacity (each row could be its own group — the static worst case).
    """
    n = keys.shape[0]
    skeys = jnp.where(valid, keys.astype(jnp.int32), _KEY_SENTINEL)
    order = jnp.argsort(skeys)
    sk = skeys[order]
    is_start = jnp.concatenate([jnp.ones((1,), jnp.bool_), sk[1:] != sk[:-1]])
    gid = jnp.cumsum(is_start) - 1  # dense group id per sorted row
    sval = valid[order]
    out = {}
    for name, (col, kind) in aggregates.items():
        vals = (
            col.astype(jnp.float32)[order]
            if kind == "sum"
            else jnp.ones((n,), jnp.int32)
        )
        out[name] = jax.ops.segment_sum(
            jnp.where(sval, vals, 0), gid, num_segments=n
        )
    gkeys = jax.ops.segment_max(
        jnp.where(sval, sk, -1), gid, num_segments=n
    )
    gvalid = (
        jax.ops.segment_max(sval.astype(jnp.int32), gid, num_segments=n) > 0
    )
    return gkeys, gvalid, out


# ----------------------------------------------------------------------------
# PK-FK join (build side has unique keys — every TPC-H join in our plans).
# ----------------------------------------------------------------------------

def join_pk(
    build_keys: jax.Array,
    build_valid: jax.Array,
    probe_keys: jax.Array,
    probe_valid: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Sorted PK-FK join: returns (build_row_index, match_mask) per probe row.

    Build side is sorted once (invalid keys to +inf), probes binary-search it.
    ``build_row_index`` addresses the ORIGINAL build table order, so callers
    gather payload columns directly.
    """
    skeys = jnp.where(build_valid, build_keys.astype(jnp.int32), _KEY_SENTINEL)
    order = jnp.argsort(skeys)
    sk = skeys[order]
    pos = jnp.searchsorted(sk, probe_keys.astype(jnp.int32))
    pos = jnp.clip(pos, 0, sk.shape[0] - 1)
    match = (sk[pos] == probe_keys.astype(jnp.int32)) & probe_valid
    return order[pos], match


def gather_payload(
    build: Table, build_idx: jax.Array, match: jax.Array, names: list[str]
) -> dict[str, jax.Array]:
    """Gather build-side columns for matched probe rows (zeros elsewhere)."""
    out = {}
    for n in names:
        col = build.columns[n]
        got = col[build_idx]
        out[n] = jnp.where(match, got, jnp.zeros_like(got))
    return out


# ----------------------------------------------------------------------------
# Top-k (Q3's ORDER BY revenue DESC LIMIT 10).
# ----------------------------------------------------------------------------

def topk_rows(
    sort_key: jax.Array, valid: jax.Array, k: int, payload: dict[str, jax.Array]
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Top-k rows by key (descending); invalid rows sort last."""
    neg = jnp.where(valid, sort_key.astype(jnp.float32), -jnp.inf)
    vals, idx = jax.lax.top_k(neg, k)
    out = {name: col[idx] for name, col in payload.items()}
    return vals, out


# ----------------------------------------------------------------------------
# Decimal helpers (money is int64 cents; percents are int 0..100).
# ----------------------------------------------------------------------------

def money_times_pct(money: jax.Array, pct: jax.Array) -> jax.Array:
    """money * (pct/100) in f32 (cents scale; see sum_where dtype note)."""
    return money.astype(jnp.float32) * (pct.astype(jnp.float32) / 100.0)


__all__ = [
    "sum_where",
    "count_where",
    "groupby_dense",
    "groupby_sorted",
    "join_pk",
    "gather_payload",
    "topk_rows",
    "money_times_pct",
]
