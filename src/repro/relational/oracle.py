"""Pure-numpy float64 reference implementations of the TPC-H queries.

The JAX engine (local and distributed) must agree with these to ~1e-4
relative (f32 accumulation vs f64).  Deliberately written in the dumbest
possible style — dictionaries and boolean masks — so bugs here are unlikely
to correlate with bugs in the engine.
"""

from __future__ import annotations

import numpy as np

from .datagen import LINESTATUS, RETURNFLAGS, date_to_days
from .table import Table


def _np(table: Table) -> dict[str, np.ndarray]:
    cols = {k: np.asarray(v) for k, v in table.columns.items()}
    cols["_valid"] = np.asarray(table.valid)
    return cols


def q1_oracle(lineitem: Table, delta_days: int = 90):
    t = _np(lineitem)
    cutoff = date_to_days(1998, 12, 1) - delta_days
    m = t["_valid"] & (t["l_shipdate"] <= cutoff)
    gid = t["l_returnflag"] * len(LINESTATUS) + t["l_linestatus"]
    price = t["l_extendedprice"].astype(np.float64)
    disc = t["l_discount"].astype(np.float64) / 100.0
    tax = t["l_tax"].astype(np.float64) / 100.0
    ngroups = len(RETURNFLAGS) * len(LINESTATUS)
    out = {
        k: np.zeros(ngroups)
        for k in (
            "sum_qty",
            "sum_base_price",
            "sum_disc_price",
            "sum_charge",
            "sum_disc",
            "count_order",
        )
    }
    for g in range(ngroups):
        mm = m & (gid == g)
        out["sum_qty"][g] = t["l_quantity"][mm].sum()
        out["sum_base_price"][g] = price[mm].sum()
        out["sum_disc_price"][g] = (price * (1 - disc))[mm].sum()
        out["sum_charge"][g] = (price * (1 - disc) * (1 + tax))[mm].sum()
        out["sum_disc"][g] = disc[mm].sum()
        out["count_order"][g] = mm.sum()
    return out


def q6_oracle(lineitem: Table, year: int = 1994) -> float:
    t = _np(lineitem)
    lo, hi = date_to_days(year, 1, 1), date_to_days(year + 1, 1, 1)
    m = (
        t["_valid"]
        & (t["l_shipdate"] >= lo)
        & (t["l_shipdate"] < hi)
        & (t["l_discount"] >= 5)
        & (t["l_discount"] <= 7)
        & (t["l_quantity"] < 24)
    )
    rev = t["l_extendedprice"].astype(np.float64) * t["l_discount"] / 100.0
    return float(rev[m].sum())


def q17_oracle(
    lineitem: Table, part: Table, brand: int = 12, container: int = 2
) -> float:
    lt, pt = _np(lineitem), _np(part)
    sel_parts = set(
        pt["p_partkey"][
            pt["_valid"] & (pt["p_brand"] == brand) & (pt["p_container"] == container)
        ].tolist()
    )
    by_part: dict[int, list[int]] = {}
    for i in range(lt["l_partkey"].shape[0]):
        if lt["_valid"][i] and int(lt["l_partkey"][i]) in sel_parts:
            by_part.setdefault(int(lt["l_partkey"][i]), []).append(i)
    total = 0.0
    for pk, idxs in by_part.items():
        avg = np.mean([lt["l_quantity"][i] for i in idxs])
        for i in idxs:
            if lt["l_quantity"][i] < 0.2 * avg:
                total += float(lt["l_extendedprice"][i])
    return total / 7.0


def q3_oracle(
    customer: Table,
    orders: Table,
    lineitem: Table,
    segment: int = 1,
    cutoff: int | None = None,
):
    ct, ot, lt = _np(customer), _np(orders), _np(lineitem)
    cutoff = date_to_days(1995, 3, 15) if cutoff is None else cutoff
    good_cust = set(
        ct["c_custkey"][ct["_valid"] & (ct["c_mktsegment"] == segment)].tolist()
    )
    good_orders = {}
    for i in range(ot["o_orderkey"].shape[0]):
        if (
            ot["_valid"][i]
            and ot["o_orderdate"][i] < cutoff
            and int(ot["o_custkey"][i]) in good_cust
        ):
            good_orders[int(ot["o_orderkey"][i])] = i
    revenue: dict[int, float] = {}
    for i in range(lt["l_orderkey"].shape[0]):
        ok = int(lt["l_orderkey"][i])
        if lt["_valid"][i] and lt["l_shipdate"][i] > cutoff and ok in good_orders:
            r = float(lt["l_extendedprice"][i]) * (100 - int(lt["l_discount"][i])) / 100.0
            revenue[ok] = revenue.get(ok, 0.0) + r
    top = sorted(revenue.items(), key=lambda kv: (-kv[1], kv[0]))[:10]
    return {
        "o_orderkey": np.array([k for k, _ in top], np.int32),
        "revenue": np.array([v for _, v in top]),
    }


def q14_oracle(lineitem: Table, part: Table, year: int = 1995, month: int = 9,
               promo_brands: int = 5) -> float:
    lt, pt = _np(lineitem), _np(part)
    lo = date_to_days(year, month, 1)
    hi = lo + 30
    brand_of = {int(k): int(b) for k, b in zip(pt["p_partkey"], pt["p_brand"])}
    promo = total = 0.0
    for i in range(lt["l_orderkey"].shape[0]):
        if not lt["_valid"][i]:
            continue
        if not (lo <= lt["l_shipdate"][i] < hi):
            continue
        pk = int(lt["l_partkey"][i])
        if pk not in brand_of:
            continue
        rev = float(lt["l_extendedprice"][i]) * (100 - int(lt["l_discount"][i])) / 100.0
        total += rev
        if brand_of[pk] < promo_brands:
            promo += rev
    return 100.0 * promo / max(total, 1e-9)


def q4_oracle(
    lineitem: Table, orders: Table, year: int = 1993, month: int = 7
) -> np.ndarray:
    """Order-priority counts over orders with >= 1 late lineitem (EXISTS)."""
    from .datagen import ORDERPRIORITIES

    lt, ot = _np(lineitem), _np(orders)
    lo = date_to_days(year, month, 1)
    m2, y2 = (month + 3, year) if month + 3 <= 12 else (month - 9, year + 1)
    hi = date_to_days(y2, m2, 1)
    late = set()
    for i in range(lt["l_orderkey"].shape[0]):
        if lt["_valid"][i] and lt["l_commitdate"][i] < lt["l_receiptdate"][i]:
            late.add(int(lt["l_orderkey"][i]))
    counts = np.zeros(len(ORDERPRIORITIES))
    for i in range(ot["o_orderkey"].shape[0]):
        if (
            ot["_valid"][i]
            and lo <= ot["o_orderdate"][i] < hi
            and int(ot["o_orderkey"][i]) in late
        ):
            counts[int(ot["o_orderpriority"][i])] += 1
    return counts


def q12_oracle(
    lineitem: Table, orders: Table, year: int = 1994,
    modes: tuple[int, ...] = (5, 3),
) -> dict:
    """Per-shipmode high/low priority line counts (all modes; only the
    selected ones can be nonzero)."""
    from .datagen import SHIPMODES

    lt, ot = _np(lineitem), _np(orders)
    lo, hi = date_to_days(year, 1, 1), date_to_days(year + 1, 1, 1)
    prio_of = {
        int(k): int(p)
        for k, p, v in zip(ot["o_orderkey"], ot["o_orderpriority"],
                           ot["_valid"])
        if v
    }
    high = np.zeros(len(SHIPMODES))
    low = np.zeros(len(SHIPMODES))
    for i in range(lt["l_orderkey"].shape[0]):
        if not lt["_valid"][i]:
            continue
        if int(lt["l_shipmode"][i]) not in modes:
            continue
        if not (lt["l_commitdate"][i] < lt["l_receiptdate"][i]):
            continue
        if not (lt["l_shipdate"][i] < lt["l_commitdate"][i]):
            continue
        if not (lo <= lt["l_receiptdate"][i] < hi):
            continue
        ok = int(lt["l_orderkey"][i])
        if ok not in prio_of:
            continue
        m = int(lt["l_shipmode"][i])
        if prio_of[ok] < 2:
            high[m] += 1
        else:
            low[m] += 1
    return {"high_line_count": high, "low_line_count": low}


def q18_oracle(
    lineitem: Table, orders: Table, customer: Table,
    threshold: int = 300, k: int = 100,
) -> dict:
    """Large-volume customers: orders whose lineitems sum past ``threshold``
    quantity, top-``k`` by o_totalprice descending."""
    lt, ot, ct = _np(lineitem), _np(orders), _np(customer)
    sums: dict[int, float] = {}
    for i in range(lt["l_orderkey"].shape[0]):
        if lt["_valid"][i]:
            ok = int(lt["l_orderkey"][i])
            sums[ok] = sums.get(ok, 0.0) + float(lt["l_quantity"][i])
    seg_of = {
        int(c): int(s)
        for c, s, v in zip(ct["c_custkey"], ct["c_mktsegment"], ct["_valid"])
        if v
    }
    rows = []
    for i in range(ot["o_orderkey"].shape[0]):
        if not ot["_valid"][i]:
            continue
        ok = int(ot["o_orderkey"][i])
        if sums.get(ok, 0.0) <= threshold:
            continue
        ck = int(ot["o_custkey"][i])
        if ck not in seg_of:
            continue
        rows.append(
            (
                ok,
                ck,
                seg_of[ck],
                int(ot["o_orderdate"][i]),
                int(ot["o_totalprice"][i]),
                sums[ok],
            )
        )
    rows.sort(key=lambda r: (-r[4], r[0]))
    rows = rows[:k]
    names = ("o_orderkey", "o_custkey", "c_mktsegment", "o_orderdate",
             "o_totalprice", "sum_qty")
    return {
        n: np.array([r[j] for r in rows]) for j, n in enumerate(names)
    }


def q19_oracle(lineitem: Table, part: Table, terms=None) -> float:
    from .queries import Q19_TERMS

    terms = terms or Q19_TERMS
    lt, pt = _np(lineitem), _np(part)
    pmap = {
        int(k): (int(b), int(c), int(s))
        for k, b, c, s in zip(
            pt["p_partkey"], pt["p_brand"], pt["p_container"], pt["p_size"]
        )
        if True
    }
    total = 0.0
    for i in range(lt["l_orderkey"].shape[0]):
        if not lt["_valid"][i]:
            continue
        pk = int(lt["l_partkey"][i])
        if pk not in pmap:
            continue
        b, c, s = pmap[pk]
        q = int(lt["l_quantity"][i])
        ok = any(
            b == tb and tc_lo <= c < tc_hi and tq_lo <= q <= tq_hi and 1 <= s <= ts_hi
            for (tb, tc_lo, tc_hi, tq_lo, tq_hi, ts_hi) in terms
        )
        if ok:
            total += float(lt["l_extendedprice"][i]) * (100 - int(lt["l_discount"][i])) / 100.0
    return total


__all__ = ["q1_oracle", "q6_oracle", "q17_oracle", "q3_oracle",
           "q14_oracle", "q19_oracle", "q4_oracle", "q12_oracle",
           "q18_oracle"]
