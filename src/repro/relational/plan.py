"""Distributed query planner (paper §3.1 / Fig 6).

Implements the two exchange-plan optimizations the paper highlights and the
hybrid-parallelism decision rule that widens the broadcast window:

* **broadcast vs partition** — broadcast the small join side when it is at
  most ``broadcast_threshold`` times smaller than the big side; under hybrid
  parallelism the threshold is ``n - 1`` (vs ``n*t - 1`` classic), so a 6-pod
  cluster already broadcasts at a 5x size difference (paper: 5x vs 239x).
* **pre-aggregation** — aggregations with small group domains reduce locally
  first and exchange only the group table (Q1/Q17's AVG subquery).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

from ..core import hybrid as H

JoinStrategy = Literal["broadcast", "partition"]


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    num_units: int  # parallel units on the exchange level (devices on axis)
    threads_per_unit: int = 1  # >1 only to *model* classic exchange
    hybrid: bool = True


def choose_join_strategy(
    small_rows: int, large_rows: int, cfg: PlannerConfig
) -> JoinStrategy:
    """Paper §3.1: broadcast iff  large/small >= units - 1.

    Broadcast cost per unit: (units-1) * small_rows sends.
    Partition cost per unit: ~ (units-1)/units * (small+large)/units sends.
    The crossover is large/small ~ units - 1 (paper's formula).
    """
    thr = H.broadcast_threshold(
        cfg.num_units, cfg.threads_per_unit, hybrid=cfg.hybrid
    )
    if small_rows == 0:
        return "broadcast"
    return "broadcast" if large_rows / small_rows >= thr else "partition"


def exchange_bytes(
    strategy: JoinStrategy,
    small_rows: int,
    large_rows: int,
    row_bytes: int,
    cfg: PlannerConfig,
) -> int:
    """Bytes crossing the network for the chosen strategy (cost model)."""
    n = cfg.num_units
    if strategy == "broadcast":
        return (n - 1) * small_rows * row_bytes
    # hash partition both sides: each row moves with prob (n-1)/n
    return int((small_rows + large_rows) * row_bytes * (n - 1) / n)


def use_preaggregation(num_groups: int, rows: int, threshold: float = 0.5) -> bool:
    """Pre-aggregate when the group table is much smaller than the input

    (paper Fig 6c: 'especially for aggregations with a small number of
    groups').
    """
    return num_groups <= rows * threshold


__all__ = [
    "PlannerConfig",
    "JoinStrategy",
    "choose_join_strategy",
    "exchange_bytes",
    "use_preaggregation",
]
