"""Sampling-based statistics for the adaptive planner (paper §3.1).

PR 5's planner priced every exchange from static ``table_capacity`` bounds,
so a Zipf-skewed ``l_partkey`` produced the same plan as uniform data and
overloaded one shard — exactly the load-imbalance failure mode the paper
attributes to the inflexible classic exchange.  This module is the
estimation layer that lets the planner react:

* :func:`collect_stats` draws a deterministic row sample from each
  :class:`~repro.relational.table.Table` and derives, per integer column,
  an NDV estimate and a heavy-hitter sketch (:class:`SpaceSaving`).
* :func:`partition_overload` turns a heavy-hitter profile into the
  ``max_partition_load / fair_share`` factor of a hash repartitioning —
  plain or salted — mirroring ``core.skew.zipf_partition_overload_analytic``
  (heavy keys hashed exactly, the near-uniform tail spread evenly).
* The retained sample feeds
  :func:`~repro.relational.planner.logical.predicate_selectivity`, so
  filter selectivities are estimated with the same ``Expr.eval`` the
  executor runs.

Estimates degrade gracefully to exact values when the sample covers the
whole table (the property tests pin this), and everything is seeded — the
same data always yields the same profile, keeping planner output
deterministic for golden snapshots.

Hash-path note: key mixing happens in unsigned space (:func:`fib_hash32`,
the exact runtime routing hash); results are cast to int64 ONLY
immediately before ``np.bincount``, which refuses uint64 input (the
modulus keeps values far below 2**63, so the cast is lossless —
regression-tested in tests/test_stats.py).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Iterable, Mapping, Sequence

import numpy as np

from .table import Table

# Sketch capacity: any key with true sample frequency above 1/k is
# guaranteed present (classic SpaceSaving bound); 32 counters comfortably
# covers every salting-relevant heavy hitter at the shard counts we plan.
SKETCH_CAPACITY = 32

DEFAULT_SAMPLE_SIZE = 2048


class SpaceSaving:
    """Metwally et al.'s SpaceSaving top-k sketch over an integer stream.

    Keeps ``capacity`` counters; when a new key arrives with all counters
    taken, it REPLACES the minimum counter, inherits its count, and records
    that inherited count as the entry's ERROR bound.  Guarantees used by
    the planner: any key whose true frequency exceeds ``n / capacity`` is
    in the sketch after ``n`` updates (a heavy hitter can be overestimated
    but never missed), and ``count - error`` never exceeds the true
    frequency — so filtering on the guaranteed count rejects the phantom
    heavy hitters count inheritance fabricates on uniform data.
    """

    def __init__(self, capacity: int = SKETCH_CAPACITY):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1: {capacity}")
        self.capacity = int(capacity)
        self._counts: dict[int, int] = {}
        self._errors: dict[int, int] = {}
        self.total = 0

    def update(self, key: int) -> None:
        key = int(key)
        self.total += 1
        counts = self._counts
        if key in counts:
            counts[key] += 1
        elif len(counts) < self.capacity:
            counts[key] = 1
            self._errors[key] = 0
        else:
            victim = min(counts, key=counts.__getitem__)
            inherited = counts.pop(victim)
            self._errors.pop(victim)
            counts[key] = inherited + 1
            self._errors[key] = inherited

    def update_many(self, keys: Iterable[int]) -> None:
        for k in keys:
            self.update(k)

    def entries(self) -> tuple[tuple[int, int, int], ...]:
        """(key, estimated count, error bound) sorted by count desc, then
        key — a total deterministic order (ties broken by key, never dict
        order).  ``count`` upper-bounds the true frequency, ``count -
        error`` lower-bounds it."""
        return tuple(
            (k, c, self._errors[k])
            for k, c in sorted(
                self._counts.items(), key=lambda kv: (-kv[1], kv[0])
            )
        )


_U32 = np.uint64(0xFFFFFFFF)


def fib_hash32(keys: np.ndarray) -> np.ndarray:
    """Numpy mirror of ``kernels.ref.fibonacci_hash_ref`` (uint32 avalanche)
    — the EXACT hash the runtime exchange routes with, so the planner's
    modeled shard placements match the executor's measured histogram.
    Computed in uint64 with explicit 32-bit masking: numpy's native uint32
    multiply wraps too, but the mask makes the overflow intent explicit and
    silences overflow warnings on scalar inputs."""
    x = np.asarray(keys).astype(np.uint64) & _U32
    x ^= x >> np.uint64(16)
    x = (x * np.uint64(0x7FEB352D)) & _U32
    x ^= x >> np.uint64(15)
    x = (x * np.uint64(0x846CA68B)) & _U32
    x ^= x >> np.uint64(16)
    return x


def estimate_ndv(sample: np.ndarray, total_rows: int) -> int:
    """Distinct-value estimate from a uniform row sample.

    GEE (Charikar et al.'s Guaranteed-Error Estimator): keys seen once in
    the sample are the evidence for unseen keys, scaled by ``sqrt(N / n)``
    — the scale factor with a PROVEN ratio-error bound of ``sqrt(N / n)``
    over all distributions (the naive ``N / n`` scale-up overshoots by the
    full sampling fraction on near-uniform data).  Exact when the sample
    covers the table (the scale factor degrades to 1, leaving ``d``),
    clamped to ``[distinct_in_sample, total_rows]`` always.
    """
    n = int(sample.size)
    total_rows = int(total_rows)
    if n == 0 or total_rows == 0:
        return 0
    _, counts = np.unique(sample, return_counts=True)
    d = int(counts.size)
    f1 = int((counts == 1).sum())
    scale = max(np.sqrt(total_rows / n), 1.0)
    est = d - f1 + round(scale * f1)
    return int(min(max(est, d), total_rows))


@dataclasses.dataclass(frozen=True)
class ColumnStats:
    """Per-column estimates derived from the sample."""

    name: str
    ndv: int
    # (key, estimated share of rows) sorted by share desc — sketch entries
    # whose share clears the noise floor (>= 2 sample hits).
    heavy_hitters: tuple[tuple[int, float], ...]
    max_share: float


@dataclasses.dataclass(frozen=True)
class TableProfile:
    """Everything the planner knows about one table's actual content."""

    table: str
    rows: int          # valid rows in the profiled table (exact, not capacity)
    sample_rows: int
    columns: Mapping[str, ColumnStats]
    # The raw sampled rows (integer columns only), kept so the planner can
    # run predicate_selectivity over real data instead of guessing.
    sample: Mapping[str, np.ndarray]


def _profile_column(name: str, vals: np.ndarray, total_rows: int) -> ColumnStats:
    sketch = SpaceSaving(SKETCH_CAPACITY)
    sketch.update_many(vals.tolist())
    n = max(int(vals.size), 1)
    # Guaranteed (lower-bound) counts reject inheritance phantoms; a key
    # must provably account for >= 4 sample rows to be called heavy.
    heavy = tuple(
        (k, c / n) for k, c, err in sketch.entries() if c - err >= 4
    )
    return ColumnStats(
        name=name,
        ndv=estimate_ndv(vals, total_rows),
        heavy_hitters=heavy,
        max_share=heavy[0][1] if heavy else (1.0 / n if vals.size else 0.0),
    )


def profile_table(
    name: str,
    table: Table,
    sample_size: int = DEFAULT_SAMPLE_SIZE,
    seed: int = 0,
) -> TableProfile:
    """Sample ``table`` and derive per-integer-column statistics.

    The sample is a seeded without-replacement draw over VALID rows only
    (padding rows carry sentinel values that would poison every estimate).
    If the table is smaller than ``sample_size`` the profile is exact.
    """
    valid = np.asarray(table.valid).astype(bool)
    idx = np.flatnonzero(valid)
    rows = int(idx.size)
    # Stable per-table stream: same (seed, name) -> same sample, and two
    # tables profiled under one seed still draw independent samples.
    rng = np.random.default_rng([int(seed), zlib.crc32(name.encode())])
    if rows > sample_size:
        idx = np.sort(rng.choice(idx, size=sample_size, replace=False))
    sample: dict[str, np.ndarray] = {}
    columns: dict[str, ColumnStats] = {}
    for cname in table.columns:
        col = np.asarray(table.columns[cname])[idx]
        if not np.issubdtype(col.dtype, np.integer):
            continue
        sample[cname] = col
        columns[cname] = _profile_column(cname, col, rows)
    return TableProfile(
        table=name, rows=rows, sample_rows=int(idx.size),
        columns=columns, sample=sample,
    )


def collect_stats(
    tables: Mapping[str, Table],
    sample_size: int = DEFAULT_SAMPLE_SIZE,
    seed: int = 0,
) -> dict[str, TableProfile]:
    """Profile every table; the dict plugs straight into ``plan_physical``
    / ``PlannedQuery.plan`` as the ``stats`` argument."""
    return {
        name: profile_table(name, t, sample_size=sample_size, seed=seed)
        for name, t in sorted(tables.items())
    }


def partition_overload(
    heavy: Sequence[tuple[int, float]],
    num_shards: int,
    num_salts: int = 1,
    salted: Sequence[int] | None = None,
) -> float:
    """Estimated ``max_partition_load / fair_share`` of hash-partitioning a
    column with this heavy-hitter profile over ``num_shards``.

    Same construction as ``skew.zipf_partition_overload_analytic`` but with
    the RUNTIME routing hash (:func:`fib_hash32`): each heavy key's whole
    share lands on ``fibonacci_hash(key) % num_shards`` — the same shard
    the executor will send it to — and the residual (non-heavy) mass is
    near-uniform under hashing and is spread evenly.  ``num_salts > 1``
    models the salted repartitioning: every key in ``salted`` (default:
    all heavy keys) splits its share evenly across ``num_salts`` salted
    sub-keys (``key * num_salts + salt``, the ``skew.salt_keys`` key
    space) which hash independently; heavy keys NOT in ``salted`` still
    land whole, exactly like the runtime routes them.
    """
    if num_shards <= 1:
        return 1.0
    heavy = list(heavy)
    residual = max(1.0 - sum(s for _, s in heavy), 0.0)
    loads = np.full(num_shards, residual / num_shards, dtype=np.float64)
    if heavy:
        split = (
            {int(k) for k, _ in heavy} if salted is None
            else {int(k) for k in salted}
        ) if num_salts > 1 else set()
        keys: list[int] = []
        shares: list[float] = []
        for k, s in heavy:
            if int(k) in split:
                keys.extend(int(k) * num_salts + j for j in range(num_salts))
                shares.extend([s / num_salts] * num_salts)
            else:
                keys.append(int(k))
                shares.append(s)
        # int64 cast ONLY for bincount (which refuses uint64); the modulus
        # bounds values to num_shards - 1, far below 2**63.
        part = (
            fib_hash32(np.asarray(keys, dtype=np.uint64))
            % np.uint64(num_shards)
        ).astype(np.int64)
        loads += np.bincount(
            part, weights=np.asarray(shares), minlength=num_shards
        )
    return float(loads.max() * num_shards)


def salting_keys(
    cs: ColumnStats, num_shards: int, share_threshold: float | None = None
) -> tuple[int, ...]:
    """Heavy keys worth salting for an ``num_shards``-way repartitioning.

    A key contributes meaningful imbalance well before it fills a whole
    fair share on its own: a key carrying an EIGHTH of a fair share can
    stack on top of the residual and other mid-weight keys to push one
    shard past the runtime threshold.  Default threshold: ``0.125 /
    num_shards`` of total mass (calibrated against the Zipf(1.2) TPC-H
    scenario: anything coarser leaves measured max/fair-share above 1.3
    at 8 shards).
    """
    if share_threshold is None:
        share_threshold = 0.125 / num_shards
    return tuple(k for k, s in cs.heavy_hitters if s >= share_threshold)


# Salts per shard: sub-keys route through the same hash as everything else,
# so with only ``num_shards`` salts the giant key's sub-keys collide and it
# still lumps (measured ~1.38x at 8 shards).  64 salts per shard makes the
# per-heavy-key placement multinomially smooth (~1.15x) and costs nothing:
# partial aggregation is by TRUE key and build sides are replicated, so no
# state scales with the salt count.
SALTS_PER_SHARD = 64


def choose_num_salts(heavy: Sequence[int], num_shards: int) -> int:
    """Salt count for these heavy keys, kept inside the int32 route space.

    Routing computes ``key * num_salts + salt`` in int32 (only for HEAVY
    keys — non-heavy rows route by their raw key), so the salt count is
    halved until the largest salted sub-key fits; 0 means the keys are too
    large to salt safely and the planner falls back to the plain exchange.
    """
    num_salts = SALTS_PER_SHARD * num_shards
    top = max((int(k) for k in heavy), default=0)
    while num_salts > 1 and (top + 1) * num_salts >= 2**31:
        num_salts //= 2
    return num_salts if num_salts > 1 else 0


__all__ = [
    "SKETCH_CAPACITY",
    "DEFAULT_SAMPLE_SIZE",
    "SpaceSaving",
    "fib_hash32",
    "estimate_ndv",
    "ColumnStats",
    "TableProfile",
    "profile_table",
    "collect_stats",
    "partition_overload",
    "salting_keys",
    "choose_num_salts",
    "SALTS_PER_SHARD",
]
