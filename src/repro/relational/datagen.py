"""TPC-H-like data generator (dbgen analogue, numpy-deterministic).

Generates the relations the paper's evaluation joins: lineitem, orders,
customer, part — with TPC-H cardinality ratios per scale factor
(SF 1 = 6M lineitem rows; we run fractional SFs on CPU).  Strings are
dictionary-encoded, money is int32 cents (sums accumulate in f32 — see
operators.sum_where), dates are int32 days since 1992-01-01.

``zipf_partkey`` switches l_partkey from uniform to Zipf(z) — the skew
experiment of paper §3.1.
"""

from __future__ import annotations

import numpy as np

from .table import Table, from_numpy

# TPC-H cardinalities per scale factor.
CARD = {
    "customer": 150_000,
    "orders": 1_500_000,
    "lineitem": 6_000_000,  # ~4 per order
    "part": 200_000,
    "partsupp": 800_000,
}

RETURNFLAGS = ["A", "N", "R"]
LINESTATUS = ["F", "O"]
MKTSEGMENTS = ["AUTOMOBILE", "BUILDING", "FURNITURE", "HOUSEHOLD", "MACHINERY"]
ORDERPRIORITIES = ["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"]
SHIPMODES = ["REG AIR", "AIR", "RAIL", "SHIP", "TRUCK", "MAIL", "FOB"]
BRANDS = [f"Brand#{i}{j}" for i in range(1, 6) for j in range(1, 6)]
CONTAINERS = [
    f"{s} {t}"
    for s in ["SM", "LG", "MED", "JUMBO", "WRAP"]
    for t in ["CASE", "BOX", "BAG", "JAR", "PKG", "PACK", "CAN", "DRUM"]
]

DATE_MIN_DAYS = 0  # 1992-01-01
DATE_MAX_DAYS = 2526  # ~1998-12-01

# Minimum row counts at tiny scale factors (keeps every table shardable).
FLOORS = {
    "part": 64,
    "customer": 64,
    "orders": 256,
    "lineitem": 1024,
}


def table_capacity(name: str, sf: float) -> int:
    """Row count of table ``name`` at scale factor ``sf`` — THE shared
    definition: the ``gen_*`` functions size their tables with this and the
    planner's ``tpch.tpch_catalog`` plans against it, so golden plan
    snapshots can never drift from generated-table capacities."""
    return max(int(CARD[name] * sf), FLOORS[name])


def date_to_days(y: int, m: int, d: int) -> int:
    """Days since 1992-01-01 (proleptic, numpy datetime arithmetic)."""
    return int(
        (np.datetime64(f"{y:04d}-{m:02d}-{d:02d}") - np.datetime64("1992-01-01"))
        / np.timedelta64(1, "D")
    )


def _zipf_ranks(rng, n: int, domain: int, z: float) -> np.ndarray:
    """n samples from a Zipf(z) over [0, domain) via inverse-CDF on the pmf."""
    ranks = np.arange(1, domain + 1, dtype=np.float64)
    pmf = ranks**-z
    pmf /= pmf.sum()
    return rng.choice(domain, size=n, p=pmf)


def gen_part(sf: float, seed: int = 1) -> Table:
    rng = np.random.default_rng(seed)
    n = table_capacity("part", sf)
    return from_numpy(
        {
            "p_partkey": np.arange(n, dtype=np.int32),
            "p_brand": rng.integers(0, len(BRANDS), n).astype(np.int32),
            "p_container": rng.integers(0, len(CONTAINERS), n).astype(np.int32),
            "p_retailprice": (
                90000 + (np.arange(n) % 20001) * 10  # cents, dbgen-like ramp
            ).astype(np.int32),
            "p_size": rng.integers(1, 51, n).astype(np.int32),
        },
        dictionaries={"p_brand": BRANDS, "p_container": CONTAINERS},
    )


def gen_customer(sf: float, seed: int = 2) -> Table:
    rng = np.random.default_rng(seed)
    n = table_capacity("customer", sf)
    return from_numpy(
        {
            "c_custkey": np.arange(n, dtype=np.int32),
            "c_mktsegment": rng.integers(0, len(MKTSEGMENTS), n).astype(np.int32),
        },
        dictionaries={"c_mktsegment": MKTSEGMENTS},
    )


def gen_orders(sf: float, seed: int = 3) -> Table:
    rng = np.random.default_rng(seed)
    n = table_capacity("orders", sf)
    ncust = table_capacity("customer", sf)
    # draw order matters: new columns draw AFTER the originals so existing
    # columns stay bit-identical across the schema extension
    custkey = rng.integers(0, ncust, n).astype(np.int32)
    orderdate = rng.integers(DATE_MIN_DAYS, DATE_MAX_DAYS - 151, n).astype(
        np.int32
    )
    priority = rng.integers(0, len(ORDERPRIORITIES), n).astype(np.int32)
    # cents; dbgen's o_totalprice is the sum of the order's lines — a wide
    # uniform stands in.  Deliberately capped at 5.5M cents ($55k), below
    # the f32 integer-exact range (2^23): Q18's top-k sorts this column
    # through an f32 key, and values beyond 2^23 would round and reorder
    # ties differently from the int-exact numpy oracle.
    totalprice = rng.integers(90_000, 55_000_00, n).astype(np.int32)
    return from_numpy(
        {
            "o_orderkey": np.arange(n, dtype=np.int32),
            "o_custkey": custkey,
            "o_orderdate": orderdate,
            "o_shippriority": np.zeros(n, np.int32),
            "o_orderpriority": priority,
            "o_totalprice": totalprice,
        },
        dictionaries={"o_orderpriority": ORDERPRIORITIES},
    )


def _lineitem_columns(
    rng,
    n: int,
    npart: int,
    norder: int,
    zipf_partkey: float | None,
    zipf_orderkey: float | None,
) -> dict[str, np.ndarray]:
    if zipf_partkey:
        partkey = _zipf_ranks(rng, n, npart, zipf_partkey).astype(np.int32)
    else:
        partkey = rng.integers(0, npart, n).astype(np.int32)
    qty = rng.integers(1, 51, n).astype(np.int32)
    # extendedprice = qty * part retail-ish price (cents)
    # extendedprice fits int32: max 50 * 290_000 = 14.5M cents
    price = (qty.astype(np.int32) * (90000 + (partkey.astype(np.int32) % 2000) * 100))
    orderdate = rng.integers(DATE_MIN_DAYS, DATE_MAX_DAYS - 151, n)
    shipdate = (orderdate + rng.integers(1, 122, n)).astype(np.int32)
    # draw order matters: keep the original columns' draws in their original
    # sequence (dict order below) and append the Q4/Q12 columns' draws after,
    # so pre-existing columns stay bit-identical across the schema extension
    # (zipf_orderkey replaces the orderkey draw IN PLACE, so it only
    # perturbs downstream draws when actually enabled — Q18 skew scenarios)
    if zipf_orderkey:
        orderkey = _zipf_ranks(rng, n, norder, zipf_orderkey).astype(np.int32)
    else:
        orderkey = rng.integers(0, norder, n).astype(np.int32)
    discount = rng.integers(0, 11, n).astype(np.int32)  # percent
    tax = rng.integers(0, 9, n).astype(np.int32)  # percent
    returnflag = rng.integers(0, len(RETURNFLAGS), n).astype(np.int32)
    linestatus = rng.integers(0, len(LINESTATUS), n).astype(np.int32)
    # dbgen-like: commit ~ order + [30, 90); receipt ~ ship + [1, 30) — so
    # l_shipdate < l_commitdate (Q12) and l_commitdate < l_receiptdate (Q4)
    # each hold for a nontrivial fraction of rows
    commitdate = (orderdate + rng.integers(30, 91, n)).astype(np.int32)
    receiptdate = (shipdate + rng.integers(1, 31, n)).astype(np.int32)
    shipmode = rng.integers(0, len(SHIPMODES), n).astype(np.int32)
    return {
        "l_orderkey": orderkey,
        "l_partkey": partkey,
        "l_quantity": qty,
        "l_extendedprice": price,
        "l_discount": discount,
        "l_tax": tax,
        "l_returnflag": returnflag,
        "l_linestatus": linestatus,
        "l_shipdate": shipdate,
        "l_commitdate": commitdate,
        "l_receiptdate": receiptdate,
        "l_shipmode": shipmode,
    }


LINEITEM_DICTIONARIES = {
    "l_returnflag": RETURNFLAGS,
    "l_linestatus": LINESTATUS,
    "l_shipmode": SHIPMODES,
}


def gen_lineitem(
    sf: float,
    seed: int = 4,
    zipf_partkey: float | None = None,
    zipf_orderkey: float | None = None,
) -> Table:
    rng = np.random.default_rng(seed)
    n = table_capacity("lineitem", sf)
    cols = _lineitem_columns(
        rng,
        n,
        table_capacity("part", sf),
        table_capacity("orders", sf),
        zipf_partkey,
        zipf_orderkey,
    )
    return from_numpy(cols, dictionaries=LINEITEM_DICTIONARIES)


def gen_lineitem_chunked(
    sf: float,
    num_chunks: int,
    seed: int = 4,
    zipf_partkey: float | None = None,
    zipf_orderkey: float | None = None,
):
    """Lineitem as a chunked :class:`~repro.relational.source.GeneratorSource`.

    Each chunk is generated lazily from its own seed ``(seed, chunk_index)``
    — only one chunk of rows is ever resident on the host, so total scale
    can exceed any memory budget.  Key domains (part/order capacities) are
    those of the FULL scale factor, so joins against ``gen_part``/
    ``gen_orders`` at the same ``sf`` behave like one big table.

    The chunked stream is its own deterministic dataset (per-chunk seeding),
    not a re-chunking of ``gen_lineitem(sf, seed)``; the streaming oracle is
    ``source.materialize()``.
    """
    from .source import GeneratorSource

    if num_chunks < 1:
        raise ValueError("num_chunks must be >= 1")
    total = table_capacity("lineitem", sf)
    chunk_rows = -(-total // num_chunks)  # ceil: capacity rounds up to fit
    npart = table_capacity("part", sf)
    norder = table_capacity("orders", sf)

    def make_chunk(i: int) -> Table:
        rng = np.random.default_rng((seed, i))
        cols = _lineitem_columns(rng, chunk_rows, npart, norder, zipf_partkey, zipf_orderkey)
        return from_numpy(cols, dictionaries=LINEITEM_DICTIONARIES)

    return GeneratorSource(make_chunk, num_chunks, chunk_rows)


def gen_all(
    sf: float,
    seed: int = 0,
    zipf_partkey: float | None = None,
    zipf_orderkey: float | None = None,
):
    return {
        "part": gen_part(sf, seed + 1),
        "customer": gen_customer(sf, seed + 2),
        "orders": gen_orders(sf, seed + 3),
        "lineitem": gen_lineitem(sf, seed + 4, zipf_partkey, zipf_orderkey),
    }


__all__ = [
    "CARD",
    "FLOORS",
    "table_capacity",
    "RETURNFLAGS",
    "LINESTATUS",
    "MKTSEGMENTS",
    "ORDERPRIORITIES",
    "SHIPMODES",
    "BRANDS",
    "CONTAINERS",
    "date_to_days",
    "gen_part",
    "gen_customer",
    "gen_orders",
    "gen_lineitem",
    "gen_lineitem_chunked",
    "gen_all",
]
