"""Persistent plan + compile cache: repeated query templates skip planning.

The serving argument (ROADMAP: multi-tenant query serving): a production
engine sees *streams* of queries, and most of them are re-runs of a small
set of templates.  Planning is pure and deterministic
(:func:`~repro.relational.planner.physical.plan_physical` touches no
devices), so a plan is a cacheable artifact — what varies is only the
inputs the planner actually reads.  The cache key captures exactly those:

* the **canonical render** of the logical DAG (:func:`canonical_render`) —
  a structural, id()-free serialization, so the key is identical across
  process restarts and across different DAG *construction* orders (a
  shared subtree and an equal duplicated subtree render the same, and the
  planner produces equivalent plans for both);
* the **catalog** (capacities size every exchange buffer);
* the **mesh shape** ``(num_shards, num_pods)`` plus the planner config /
  chip / topology / cross-pod pin / salt threshold (all priced into the
  plan);
* the **stats bucket** (:func:`stats_bucket`) — a coarse quantization of
  the optimizer statistics.  Raw profiles jitter run-to-run (they are
  sampled); bucketing rows/NDV to powers of two and heavy-hitter shares to
  coarse magnitude classes keeps the key stable under sampling noise while
  a *real* shift (skew appearing, a table growing past a capacity decade)
  changes the bucket and invalidates the entry, forcing a replan.

Two cache levels, mirroring ``jax``'s compilation cache split between
in-memory and persistent stores:

* **plans** persist across processes: pickled to ``<cache_dir>/`` (atomic
  tempfile + rename, version-stamped, key material stored alongside so a
  digest collision or format drift reads as a miss, never a wrong plan);
* **compiled executors** are memoized in-process only (a jitted closure
  over the live table buffers cannot outlive them), keyed by plan digest +
  the caller's data token + the multiplexer knobs.

``plan_physical.calls`` is the counter hook the regression tests watch: a
warm path must plan *zero* times.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
import os
import pickle
import tempfile
from typing import Callable, Mapping

from ...core.topology import ChipSpec, V5E
from .. import stats as S
from . import logical as L
from .executor import compile_plan
from .physical import DEFAULT_SALT_THRESHOLD, PhysicalPlan, PlannerConfig

# Bump whenever the key material, the pickle layout, or plan semantics
# change — stale artifacts from an older layout must read as misses.
CACHE_FORMAT_VERSION = 3

# Heavy-hitter shares below this floor are sampling noise, not skew: they
# can never push a shard past the salting threshold, so they must not
# perturb the cache key.
HEAVY_SHARE_FLOOR = 1.0 / 64.0


# ---------------------------------------------------------------------------
# Canonical logical-DAG render (the collision-tested identity of a query).
# ---------------------------------------------------------------------------


def canonical_render(root: L.Node) -> str:
    """Structural serialization of a logical DAG.

    Purely a function of node types and field VALUES — never of object
    identity, construction order, or dict iteration — so two plans built
    independently (or in different processes) render identically iff they
    are the same query.  Every semantic field is included with fixed
    delimiters; column names are identifiers, so fields cannot bleed into
    each other.  Shared subtrees are rendered structurally (memoized by id
    only to keep DAG walks linear): sharing is an executor optimization,
    not part of the query's identity.
    """
    memo: dict[int, str] = {}

    def aggs(specs) -> str:
        return ";".join(f"{n}:{k}({e.render()})" for n, e, k in specs)

    def r(n: L.Node) -> str:
        if id(n) in memo:
            return memo[id(n)]
        if isinstance(n, L.Scan):
            out = f"Scan({n.table};{','.join(n.columns)})"
        elif isinstance(n, L.Filter):
            out = f"Filter({r(n.child)};{n.pred.render()})"
        elif isinstance(n, L.Project):
            der = ";".join(f"{name}={e.render()}" for name, e in n.derived)
            out = f"Project({r(n.child)};keep={','.join(n.keep)};der={der})"
        elif isinstance(n, L.HashJoin):
            out = (
                f"HashJoin(build={r(n.build)};probe={r(n.probe)};"
                f"on={n.build_key}={n.probe_key};"
                f"payload={','.join(n.payload)})"
            )
        elif isinstance(n, L.GroupBy):
            ke = n.key_expr.render() if n.key_expr is not None else ""
            out = (
                f"GroupBy({r(n.child)};key={n.key};key_expr={ke};"
                f"G={n.num_groups};aggs={aggs(n.aggs)})"
            )
        elif isinstance(n, L.Aggregate):
            out = f"Aggregate({r(n.child)};aggs={aggs(n.aggs)})"
        elif isinstance(n, L.TopK):
            out = (
                f"TopK({r(n.child)};key={n.key};k={n.k};"
                f"payload={','.join(n.payload)})"
            )
        else:
            raise TypeError(f"unknown logical node {type(n).__name__}")
        memo[id(n)] = out
        return out

    return r(root)


def _share_class(share: float) -> int:
    """Coarse magnitude class of a heavy-hitter share: floor(-log2(share)),
    clamped — 1/2 and 1/3 are both class 1, 1/5 is class 2, ...  Sampling
    noise moves a share a few percent; it takes a ~2x change to move class."""
    return min(int(-math.floor(math.log2(max(min(share, 1.0), 1e-9)))), 30)


def stats_bucket(stats: Mapping[str, S.TableProfile] | None) -> str:
    """Quantize optimizer statistics into the cache key's stats bucket.

    ``None`` (static planning) is its own bucket.  Otherwise, per table in
    name order: valid rows bucketed to powers of two, and per integer
    column the NDV power-of-two bucket plus the heavy-hitter set with each
    share reduced to its magnitude class (shares under
    ``HEAVY_SHARE_FLOOR`` dropped — they cannot trigger salting).  The raw
    sample is deliberately NOT part of the bucket: selectivity refinements
    only re-price exchanges, and two samples of the same distribution
    should hit the same cached plan.
    """
    if stats is None:
        return "static"
    parts = []
    for tname in sorted(stats):
        p = stats[tname]
        cols = []
        for cname in sorted(p.columns):
            cs = p.columns[cname]
            heavy = sorted(
                (int(k), _share_class(share))
                for k, share in cs.heavy_hitters
                if share >= HEAVY_SHARE_FLOOR
            )
            hh = ",".join(f"{k}^{c}" for k, c in heavy)
            cols.append(f"{cname}:ndv2^{max(int(cs.ndv), 1).bit_length()}:{hh}")
        parts.append(
            f"{tname}(rows2^{max(int(p.rows), 1).bit_length()};"
            + ";".join(cols) + ")"
        )
    return "|".join(parts)


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """A resolved cache key: the sha256 digest plus the full key material
    (kept for collision auditing — a persisted entry stores the material
    and a lookup whose material mismatches is a miss, so even a digest
    collision can never return a wrong plan)."""

    digest: str
    material: str


def plan_key(
    root: L.Node,
    catalog: L.Catalog,
    num_shards: int,
    num_pods: int = 1,
    cfg: PlannerConfig | None = None,
    chip: ChipSpec = V5E,
    topology: str = "ring",
    cross_pod: str | None = None,
    stats: Mapping[str, S.TableProfile] | None = None,
    salt_threshold: float = DEFAULT_SALT_THRESHOLD,
    morsel_rows: int | None = None,
) -> PlanKey:
    """The cache key for ``plan_physical`` with these exact arguments.

    Mirrors the planner's signature on purpose: everything ``plan_physical``
    reads is in the material, and nothing else (the query *name* is display
    metadata, not identity).
    """
    cfg = cfg or PlannerConfig(num_units=num_shards, hybrid=True)
    material = "\n".join(
        (
            f"v={CACHE_FORMAT_VERSION}",
            f"plan={canonical_render(root)}",
            "catalog=" + ",".join(
                f"{t}:{int(catalog[t])}" for t in sorted(catalog)
            ),
            f"mesh=({int(num_shards)},{int(num_pods)})",
            f"cfg=({cfg.num_units},{cfg.threads_per_unit},{cfg.hybrid})",
            f"chip={chip.name}",
            f"topology={topology}",
            f"cross_pod={cross_pod}",
            f"salt_threshold={float(salt_threshold)!r}",
            f"stats={stats_bucket(stats)}",
            f"morsel_rows={morsel_rows}",
        )
    )
    digest = hashlib.sha256(material.encode("utf-8")).hexdigest()
    return PlanKey(digest=digest, material=material)


# ---------------------------------------------------------------------------
# The cache.
# ---------------------------------------------------------------------------


class PlanCache:
    """Two-level plan + compile cache (module docstring for the design).

    ``cache_dir=None`` (and no ``REPRO_PLAN_CACHE_DIR`` in the env) keeps
    the cache in-process only; with a directory, plans persist across
    processes.  ``max_entries`` (or ``REPRO_PLAN_CACHE_MAX``; 0 =
    unlimited) caps the on-disk entry count with LRU eviction, so a
    long-lived cache dir shared by many templates cannot grow without
    bound.  Counters (`hits`/`misses`/`disk_hits`/`evictions`/
    `executor_hits`/`executor_misses`) feed the serving engine's records
    and the bench's cache-hit-rate line.
    """

    def __init__(self, cache_dir: str | None = None,
                 max_entries: int | None = None):
        self.cache_dir = (
            cache_dir
            if cache_dir is not None
            else os.environ.get("REPRO_PLAN_CACHE_DIR")
        )
        if max_entries is None:
            max_entries = int(os.environ.get("REPRO_PLAN_CACHE_MAX", "0"))
        #: On-disk entry cap (0 = unlimited).  Enforced after every insert
        #: by mtime — effectively LRU, because lookup() touches the file.
        self.max_entries = max_entries
        self.evictions = 0
        self._plans: dict[str, PhysicalPlan] = {}
        self._runners: dict[tuple, Callable] = {}
        self.hits = 0
        self.misses = 0
        self.disk_hits = 0
        self.executor_hits = 0
        self.executor_misses = 0

    # -- plan level --------------------------------------------------------

    def _path(self, key: PlanKey) -> str | None:
        if not self.cache_dir:
            return None
        return os.path.join(self.cache_dir, f"plan-{key.digest}.pkl")

    def lookup(self, key: PlanKey) -> PhysicalPlan | None:
        """Memory, then disk.  Any persisted-entry problem — unreadable,
        version drift, key-material mismatch — is a miss, never an error."""
        plan = self._plans.get(key.digest)
        if plan is not None:
            return plan
        path = self._path(key)
        if path is None:
            return None
        try:
            with open(path, "rb") as f:
                entry = pickle.load(f)
            if (
                entry.get("version") != CACHE_FORMAT_VERSION
                or entry.get("material") != key.material
            ):
                return None
            plan = entry["plan"]
        except (OSError, pickle.PickleError, EOFError, KeyError,
                AttributeError, ImportError):
            return None
        try:
            os.utime(path)  # LRU touch: recency, not insertion order
        except OSError:
            pass
        self._plans[key.digest] = plan
        self.disk_hits += 1
        return plan

    def insert(self, key: PlanKey, plan: PhysicalPlan) -> None:
        self._plans[key.digest] = plan
        path = self._path(key)
        if path is None:
            return
        os.makedirs(self.cache_dir, exist_ok=True)
        entry = {
            "version": CACHE_FORMAT_VERSION,
            "material": key.material,
            "plan": plan,
        }
        # Atomic publish (tempfile + rename), so a concurrent reader sees
        # either no entry or a complete one — same discipline as jax's
        # persistent compilation cache.
        fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(entry, f)
            os.replace(tmp, path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
        self._enforce_cap(keep=os.path.basename(path))

    def _enforce_cap(self, keep: str | None = None) -> None:
        """Bound the on-disk cache at ``max_entries`` plan files, evicting
        least-recently-used first (mtime order — ``lookup`` touches on
        read).  Races with concurrent processes are benign: eviction is a
        best-effort unlink of a complete entry, a loser just re-plans, and
        every OSError (already gone, permissions, NFS lag) is swallowed.
        ``keep`` shields the just-inserted entry so the cap can never evict
        the plan the caller is about to rely on."""
        if not self.max_entries or not self.cache_dir:
            return
        try:
            names = [
                n for n in os.listdir(self.cache_dir)
                if n.startswith("plan-") and n.endswith(".pkl")
            ]
        except OSError:
            return
        if len(names) <= self.max_entries:
            return

        def mtime(n: str) -> float:
            try:
                return os.path.getmtime(os.path.join(self.cache_dir, n))
            except OSError:
                return float("inf")  # can't stat — treat as fresh, skip

        victims = sorted(names, key=mtime)
        excess = len(names) - self.max_entries
        for n in victims:
            if excess <= 0:
                break
            if n == keep:
                continue
            try:
                os.unlink(os.path.join(self.cache_dir, n))
                self.evictions += 1
                excess -= 1
            except OSError:
                excess -= 1  # someone else removed it — still gone

    def get_plan(
        self, key: PlanKey, planner: Callable[[], PhysicalPlan]
    ) -> tuple[PhysicalPlan, bool]:
        """Cached plan for ``key``, or plan-and-insert via ``planner()``.
        Returns ``(plan, hit)``."""
        plan = self.lookup(key)
        if plan is not None:
            self.hits += 1
            return plan, True
        self.misses += 1
        plan = planner()
        self.insert(key, plan)
        return plan, False

    # -- executor level ----------------------------------------------------

    def executor(
        self,
        key: PlanKey,
        plan: PhysicalPlan,
        tables,
        data_token: str = "",
        mux=None,
        **compile_kw,
    ) -> tuple[Callable, bool]:
        """In-process memo of :func:`compile_plan` runners.

        ``data_token`` names the table set the runner closed over — the
        caller (the serving engine: one token per engine) bumps it when the
        tables change, because a jitted closure over stale buffers would
        silently serve old data.  Returns ``(runner, hit)``.
        """
        knobs = tuple(sorted(compile_kw.items())) + (
            ("mux", id(mux)) if mux is not None else (),
        )
        memo_key = (key.digest, data_token, knobs)
        runner = self._runners.get(memo_key)
        if runner is not None:
            self.executor_hits += 1
            return runner, True
        self.executor_misses += 1
        runner = compile_plan(plan, tables, mux=mux, **compile_kw)
        self._runners[memo_key] = runner
        return runner, False

    # -- introspection -----------------------------------------------------

    def clear_memory(self) -> None:
        """Drop the in-process level only (tests use this to simulate a
        restart: persisted plans survive, compiled runners do not)."""
        self._plans.clear()
        self._runners.clear()

    def record(self) -> dict:
        total = self.hits + self.misses
        return dict(
            plan_hits=self.hits,
            plan_misses=self.misses,
            plan_disk_hits=self.disk_hits,
            plan_evictions=self.evictions,
            executor_hits=self.executor_hits,
            executor_misses=self.executor_misses,
            hit_fraction=(self.hits / total) if total else 0.0,
        )


__all__ = [
    "CACHE_FORMAT_VERSION",
    "PlanCache",
    "PlanKey",
    "canonical_render",
    "plan_key",
    "stats_bucket",
]
