"""Cost-based physical planner (paper §3.1 / Fig 6).

Turns a logical DAG into a physical plan by deciding, per join/group
boundary, where an exchange goes and what kind it is:

* **broadcast vs partition** (Fig 6a/6b) — broadcast the small build side
  when it is at most ``broadcast_threshold`` times smaller than the probe;
  under hybrid parallelism the threshold is ``n - 1`` (vs ``n*t - 1``
  classic), so an 8-unit mesh already broadcasts at a 7x size difference
  (paper: 5x vs 239x on their 6-server cluster).
* **pre-aggregation** (Fig 6c) — dense group-bys reduce locally first and
  combine the tiny group table with a psum instead of shuffling raw rows.
* **co-partitioning reuse** — partitioning properties (round-robin /
  hash(key) / replicated) propagate through the plan, so a pipeline that is
  already partitioned on the join key gets NO new exchange (Q17's single
  lineitem shuffle feeds the correlated-AVG group-by *and* the join back).

Every exchange edge carries its own :class:`~repro.core.autotune.TableStats`
(static per-shard rows x packed row bytes — the zero-drop shapes that
actually move), and the whole set is priced by the topology autotuner's
analytic core (:func:`repro.core.autotune.tune_config`) to pick the
multiplexer knobs — at *plan* time, with no devices, which is what makes
``explain()`` deterministic and golden-snapshotable.

On two-level meshes (``num_pods > 1``) the planner emits the same plan; the
executor routes shuffles through ``hash_shuffle_global`` (coarse cross-pod
hop + fine in-pod — the DCI never carries fine-grained traffic, per
``HybridPlan``) and broadcast edges obey the tuned ``cross_pod`` strategy,
falling back to a hash reshard by the build key when the build side
outgrows the broadcast window.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

from ...core import hybrid as H
from ...core.autotune import (
    TableStats,
    TunedConfig,
    exchange_makespan,
    pod_strategy_times,
    tune_config,
)
from ...core.topology import ChipSpec, V5E
from .. import stats as S
from . import logical as L

JoinStrategy = Literal["broadcast", "partition"]


# ----------------------------------------------------------------------------
# Paper §3.1 decision rules (absorbed from the old ``relational/plan.py`` —
# one formula, one home).
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    num_units: int  # parallel units on the exchange level (devices on axis)
    threads_per_unit: int = 1  # >1 only to *model* classic exchange
    hybrid: bool = True


def choose_join_strategy(
    small_rows: int, large_rows: int, cfg: PlannerConfig
) -> JoinStrategy:
    """Paper §3.1: broadcast iff  large/small >= units - 1.

    Broadcast cost per unit: (units-1) * small_rows sends.
    Partition cost per unit: ~ (units-1)/units * (small+large)/units sends.
    The crossover is large/small ~ units - 1 (paper's formula).
    """
    thr = H.broadcast_threshold(
        cfg.num_units, cfg.threads_per_unit, hybrid=cfg.hybrid
    )
    if small_rows == 0:
        return "broadcast"
    return "broadcast" if large_rows / small_rows >= thr else "partition"


def exchange_bytes(
    strategy: JoinStrategy,
    small_rows: int,
    large_rows: int,
    row_bytes: int,
    cfg: PlannerConfig,
) -> int:
    """Bytes crossing the network for the chosen strategy (cost model)."""
    n = cfg.num_units
    if strategy == "broadcast":
        return (n - 1) * small_rows * row_bytes
    # hash partition both sides: each row moves with prob (n-1)/n
    return int((small_rows + large_rows) * row_bytes * (n - 1) / n)


def use_preaggregation(num_groups: int, rows: int, threshold: float = 0.5) -> bool:
    """Pre-aggregate when the group table is much smaller than the input

    (paper Fig 6c: 'especially for aggregations with a small number of
    groups').
    """
    return num_groups <= rows * threshold


# ----------------------------------------------------------------------------
# Physical nodes.
# ----------------------------------------------------------------------------

# partitioning property: None (round-robin morsels), ("hash", key),
# ("salted", key) — hash on the salted sub-key space, rows of one heavy key
# span shards — or "replicated"
Partitioning = object

REPLICATED = "replicated"

# Estimated plain-hash overload (max/fair-share) above which the planner
# considers the salted repartitioning; also the runtime re-optimization
# threshold the executor compares its measured histogram against.
DEFAULT_SALT_THRESHOLD = 1.5


@dataclasses.dataclass
class PNode:
    """One physical operator. ``kind`` dispatches the executor; ``info``
    holds kind-specific fields (predicates, keys, strategies, stats)."""

    kind: str
    schema: tuple[str, ...]
    cap: int  # per-shard row capacity flowing OUT of this node
    part: Partitioning
    children: tuple["PNode", ...]
    info: dict
    # which schema columns are float (group-by sums, derived ratios): these
    # cannot go through the int32 row-image exchange
    float_cols: frozenset = frozenset()

    # display index, assigned at plan finalization (deterministic)
    idx: int = -1


@dataclasses.dataclass
class PhysicalPlan:
    """A planned query: the physical DAG + everything the executor needs."""

    name: str
    root: PNode
    scans: tuple[str, ...]  # distinct base tables, first-visit order
    shuffle_stats: tuple[TableStats, ...]
    broadcast_stats: tuple[TableStats, ...]
    tuned: TunedConfig
    num_shards: int
    num_pods: int
    cfg: PlannerConfig
    catalog: dict

    def exchange_summary(self) -> list[dict]:
        """One record per exchange edge (benchmarks report these)."""
        out = []

        def walk(n: PNode, seen: set[int]):
            if id(n) in seen:
                return
            seen.add(id(n))
            for c in n.children:
                walk(c, seen)
            if n.kind == "exchange":
                st: TableStats = n.info["stats"]
                out.append(
                    dict(
                        kind=n.info["exkind"],
                        key=n.info["key"],
                        columns=len(n.children[0].schema),
                        rows_per_shard=st.rows,
                        row_bytes=st.row_bytes,
                        wire_bytes=self._wire_bytes(n.info["exkind"], st),
                    )
                )

        walk(self.root, set())
        return out

    def _wire_bytes(self, exkind: str, st: TableStats) -> int:
        """Modeled bytes on the wire for one exchange edge:
        :func:`exchange_bytes` (the paper's §3.1 formulas — one home)
        applied to the edge's total capacity across all shards."""
        total_rows = st.rows * self.num_shards
        strategy = "broadcast" if exkind == "broadcast" else "partition"
        return exchange_bytes(
            strategy, total_rows, 0, st.row_bytes,
            PlannerConfig(num_units=self.num_shards),
        )

    def total_wire_bytes(self) -> int:
        return sum(e["wire_bytes"] for e in self.exchange_summary())

    def explain(self) -> str:
        return explain(self)


def _per_shard_cap(rows: int, num_shards: int) -> int:
    return math.ceil(rows / num_shards)


def plan_physical(
    root: L.Node,
    catalog: L.Catalog,
    num_shards: int,
    num_pods: int = 1,
    cfg: PlannerConfig | None = None,
    chip: ChipSpec = V5E,
    topology: str = "ring",
    name: str = "query",
    cross_pod: str | None = None,
    stats: dict[str, S.TableProfile] | None = None,
    salt_threshold: float = DEFAULT_SALT_THRESHOLD,
    morsel_rows: int | None = None,
) -> PhysicalPlan:
    """Place exchanges, infer partitionings/capacities, tune the multiplexer.

    ``morsel_rows`` (out-of-core streaming) caps the rows the tuner prices
    per shuffle at one morsel's per-shard slice — streamed exchanges move one
    morsel at a time, so tuning them for the full-capacity message would
    mis-size the pipeline knobs.  Plan shape and node capacities are
    unaffected.

    Pure function of the logical DAG + catalog + mesh shape — no devices
    touched, so it runs at test/CI time and its ``explain()`` rendering is
    deterministic.

    ``stats`` (from :func:`repro.relational.stats.collect_stats`) switches
    the planner from static pricing to adaptive: filter selectivities and
    NDVs refine the row estimates behind each exchange's pricing, and a
    shuffle key whose heavy-hitter profile predicts a plain-hash overload
    above ``salt_threshold`` is planned as a SALTED repartitioning (heavy
    keys split across salted sub-keys, ``core.skew.salt_keys``-style),
    priced against the plain hash and a broadcast of the same edge.  The
    capacity-based ``TableStats`` still size the zero-drop buffers and the
    tuner input, so with no skew in the stats the emitted plan is
    bit-identical to the stats-free one.

    On two-level meshes the cross-pod build-side strategy is itself a *plan*
    decision: a first pass places broadcast edges and prices them with
    :func:`~repro.core.autotune.pod_strategy_times`; if ``"reshard"`` wins
    (or ``cross_pod="reshard"`` is pinned), the plan is rebuilt with those
    joins co-partitioned instead — resharding ONLY the build side would
    strand it away from an un-partitioned probe, so the reshard strategy
    must pull the probe onto the same hash partitioning.
    """
    # Counter hook: the plan+compile cache's regression tests assert the
    # warm path plans ZERO times (tests/test_plan_cache.py).
    plan_physical.calls += 1
    cfg = cfg or PlannerConfig(num_units=num_shards, hybrid=True)

    def build(reshard: bool) -> dict:
        return _plan_once(
            root, catalog, num_shards, cfg, reshard=reshard,
            num_pods=num_pods, chip=chip, topology=topology,
            stats=stats, salt_threshold=salt_threshold,
            morsel_rows=morsel_rows,
        )

    built = build(reshard=False)
    resolved_cross_pod = None

    def tune(b):
        bstats = max(
            b["broadcast_stats"], key=lambda s: s.rows * s.row_bytes,
            default=None,
        )
        return tune_config(
            num_shards // max(num_pods, 1), tuple(b["shuffle_stats"]),
            num_pods=num_pods, chip=chip, topology=topology,
            broadcast_stats=bstats,
        )

    tuned = tune(built)
    if num_pods > 1:
        resolved_cross_pod = cross_pod or tuned.cross_pod or "broadcast"
        if resolved_cross_pod == "reshard" and built["broadcast_stats"]:
            rebuilt = build(reshard=True)
            # joins whose schemas carry float columns keep their broadcast
            # edge (can_reshard=False); only re-tune if anything changed
            if rebuilt["broadcast_stats"] != built["broadcast_stats"]:
                built = rebuilt
                tuned = tune(built)
        tuned = dataclasses.replace(tuned, cross_pod=resolved_cross_pod)
    return PhysicalPlan(
        name=name,
        root=built["root"],
        scans=tuple(built["scans"]),
        shuffle_stats=tuple(built["shuffle_stats"]),
        broadcast_stats=tuple(built["broadcast_stats"]),
        tuned=tuned,
        num_shards=num_shards,
        num_pods=num_pods,
        cfg=cfg,
        catalog=dict(catalog),
    )


# How many times the planner has run in this process — the cache layer's
# zero-replan-on-warm-path assertions read (and tests reset) this.
plan_physical.calls = 0


def _plan_once(
    root: L.Node,
    catalog: L.Catalog,
    num_shards: int,
    cfg: PlannerConfig,
    reshard: bool,
    num_pods: int = 1,
    chip: ChipSpec = V5E,
    topology: str = "ring",
    stats: dict[str, S.TableProfile] | None = None,
    salt_threshold: float = DEFAULT_SALT_THRESHOLD,
    morsel_rows: int | None = None,
) -> dict:
    """One planning pass; ``reshard=True`` turns broadcast-threshold joins
    into co-partitioned ones (the two-level reshard strategy)."""
    shuffle_stats: list[TableStats] = []
    broadcast_stats: list[TableStats] = []
    memo: dict[int, PNode] = {}
    exch_memo: dict[tuple[int, str, str | None], PNode] = {}
    scans: list[str] = []
    # column name -> ColumnStats; TPC-H column names are globally unique,
    # and the deterministic sorted-table iteration pins any tie.
    stats_by_col: dict[str, S.ColumnStats] = {}
    profiles: dict[str, S.TableProfile] = dict(stats) if stats else {}
    for _tname in sorted(profiles):
        for _cname, _cs in profiles[_tname].columns.items():
            stats_by_col.setdefault(_cname, _cs)
    # id(PNode) -> estimated total valid rows (refines capacity for the
    # per-edge pricing; capacities still size every buffer)
    est: dict[int, float] = {}

    def _est(p: PNode, default: float | None = None) -> float:
        if default is None:
            default = float(p.cap * num_shards)
        return est.get(id(p), default)

    def _selectivity(pred: L.Expr) -> float:
        cols = set(pred.columns())
        for tname in sorted(profiles):
            sample = profiles[tname].sample
            if cols <= set(sample):
                return L.predicate_selectivity(pred, sample)
        return 1.0

    def _salt_decision(child: PNode, key: str) -> dict | None:
        """Price plain vs salted vs broadcast for this shuffle edge; a dict
        of salted-exchange info when the salted repartitioning wins."""
        cs = stats_by_col.get(key)
        if cs is None or num_shards <= 1:
            return None
        heavy = S.salting_keys(cs, num_shards)
        num_salts = S.choose_num_salts(heavy, num_shards)
        if not heavy or not num_salts:
            return None
        over_plain = S.partition_overload(cs.heavy_hitters, num_shards)
        over_salted = S.partition_overload(
            cs.heavy_hitters, num_shards, num_salts=num_salts, salted=heavy
        )
        if over_plain < salt_threshold:
            return None
        # Price the three physical alternatives on the ESTIMATED rows (the
        # real TableStats), with the makespan charged to the max-loaded
        # shard via the skew factor.
        rows_ps = max(1, math.ceil(_est(child) / num_shards))
        pstats = TableStats(rows=rows_ps, row_bytes=4 * len(child.schema))
        n_inner = num_shards // max(num_pods, 1)
        priced = {
            "plain": exchange_makespan(
                pstats, n_inner, chip=chip, topology=topology,
                num_pods=num_pods, skew=over_plain,
            ),
            "salted": exchange_makespan(
                pstats, n_inner, chip=chip, topology=topology,
                num_pods=num_pods, skew=over_salted,
            ),
            "broadcast": pod_strategy_times(
                pstats, n_inner, num_pods, chip=chip, topology=topology
            )["broadcast"],
        }
        if priced["salted"] >= priced["plain"]:
            return None
        return {
            "salted": True,
            "num_salts": num_salts,
            "heavy_keys": tuple(int(k) for k in heavy),
            "overload_plain": over_plain,
            "overload_salted": over_salted,
            "priced_s": priced,
            "runtime_threshold": salt_threshold,
        }

    def exchange(child: PNode, exkind: str, key: str | None) -> PNode:
        mkey = (id(child), exkind, key)
        if mkey in exch_memo:
            return exch_memo[mkey]
        if exkind == "shuffle" and child.float_cols:
            raise ValueError(
                f"cannot hash-exchange a schema with float columns "
                f"{sorted(child.float_cols)}: the exchange ships an int32 "
                "row image — aggregate after the exchange, or project the "
                "float columns away first"
            )
        priced_rows = child.cap
        if morsel_rows is not None and exkind == "shuffle":
            # streamed exchanges move one morsel per step, not the full table
            priced_rows = min(priced_rows, math.ceil(morsel_rows / num_shards))
        stats_t = TableStats(rows=priced_rows, row_bytes=4 * len(child.schema))
        info = {"exkind": exkind, "key": key, "stats": stats_t}
        if exkind == "shuffle":
            shuffle_stats.append(stats_t)
            salt = _salt_decision(child, key)
            if salt:
                info.update(salt)
                part = ("salted", key)
            else:
                part = ("hash", key)
        else:
            broadcast_stats.append(stats_t)
            part = REPLICATED
        node = PNode(
            kind="exchange",
            schema=child.schema,
            # zero-drop bound: every sender may deliver its whole buffer
            cap=child.cap * num_shards,
            part=part,
            children=(child,),
            info=info,
            float_cols=child.float_cols,
        )
        est[id(node)] = _est(child)
        # expose the estimate for the model-vs-measured check: modeled wire
        # bytes price the rows the estimator expects to FLOW, while the
        # capacity-based ``stats`` above keep sizing every buffer
        info["est_rows"] = est[id(node)]
        exch_memo[mkey] = node
        return node

    def ensure_hash(p: PNode, key: str) -> PNode:
        # REPLICATED is acceptable for join sides: valid matches still land
        # exactly once globally (the other copies fail the key-owner test).
        # A salted partitioning on the same key is the adaptive equivalent
        # of hash(key); consumers that need co-location by the TRUE key
        # (sort-based GroupBy, join sides) handle it explicitly below.
        if p.part in (("hash", key), ("salted", key), REPLICATED):
            return p
        return exchange(p, "shuffle", key)

    def reject_replicated(p: PNode, op: str) -> PNode:
        # psum/top-k combines count every shard's contribution: a replicated
        # input would be counted num_shards times — reject at plan time
        # rather than silently multiply results
        if p.part == REPLICATED:
            raise ValueError(
                f"{op} over a replicated input would be combined "
                f"{num_shards}-fold by the cross-shard psum/top-k merge; "
                "restructure the plan so the aggregated side stays "
                "partitioned"
            )
        return p

    def plan(node: L.Node) -> PNode:
        if id(node) in memo:
            return memo[id(node)]
        if isinstance(node, L.Scan):
            if node.table not in scans:
                scans.append(node.table)
            p = PNode(
                kind="scan",
                schema=node.schema,
                cap=_per_shard_cap(node.est_rows(catalog), num_shards),
                part=None,
                children=(),
                info={"table": node.table},
            )
            prof = profiles.get(node.table)
            est[id(p)] = float(
                prof.rows if prof else node.est_rows(catalog)
            )
        elif isinstance(node, L.Filter):
            c = plan(node.child)
            p = PNode("filter", c.schema, c.cap, c.part, (c,),
                      {"pred": node.pred}, float_cols=c.float_cols)
            est[id(p)] = _est(c) * (_selectivity(node.pred) if profiles else 1.0)
        elif isinstance(node, L.Project):
            c = plan(node.child)
            fcols = frozenset(
                k for k in node.keep if k in c.float_cols
            ) | frozenset(
                name for name, e in node.derived if e.is_float(c.float_cols)
            )
            p = PNode("project", node.schema, c.cap, c.part, (c,),
                      {"keep": node.keep, "derived": node.derived},
                      float_cols=fcols)
            est[id(p)] = _est(c)
        elif isinstance(node, L.HashJoin):
            b, pr = plan(node.build), plan(node.probe)
            build_rows = node.build.est_rows(catalog)
            probe_rows = node.probe.est_rows(catalog)
            strategy = choose_join_strategy(build_rows, probe_rows, cfg)
            # Co-partitioning ships both sides through the int32 row-image
            # exchange, which cannot carry float columns (group-by sums,
            # derived ratios).  If a side that would need exchanging carries
            # floats, fall back to broadcasting the build (the replicate
            # route ships columns individually and handles any dtype) — a
            # always-valid plan, just not the cost winner.
            def needs_hash(side: PNode, key: str) -> bool:
                return side.part != ("hash", key) and side.part != REPLICATED

            forced = None
            if strategy == "partition" and (
                (needs_hash(b, node.build_key) and b.float_cols)
                or (needs_hash(pr, node.probe_key) and pr.float_cols)
            ):
                strategy = "broadcast"
                forced = "float columns cannot hash-exchange"
            # reshard = co-partition both sides; same float constraint —
            # keep the broadcast edge for such joins
            can_reshard = not b.float_cols and not pr.float_cols
            resharded = strategy == "broadcast" and reshard and can_reshard \
                and forced is None
            if strategy == "broadcast" and not resharded:
                if b.part != REPLICATED:
                    b = exchange(b, "broadcast", node.build_key)
                out_part = pr.part
            else:
                b = ensure_hash(b, node.build_key)
                pr = ensure_hash(pr, node.probe_key)
                out_part = ("hash", node.probe_key)
                # Under a salted repartitioning one heavy key's probe rows
                # span shards, so a co-partitioned build cannot meet them —
                # the build side must be replicated (the salted-join rule:
                # probe salts, build replicates across all salts).
                if pr.part == ("salted", node.probe_key):
                    out_part = pr.part
                    if b.part != REPLICATED:
                        b = exchange(b, "broadcast", node.build_key)
                        forced = "salted probe needs a replicated build"
                elif b.part == ("salted", node.build_key):
                    b = exchange(b, "broadcast", node.build_key)
                    forced = "salted build side must replicate"
            p = PNode(
                "join",
                node.schema,
                pr.cap,
                out_part,
                (b, pr),
                {
                    "build_key": node.build_key,
                    "probe_key": node.probe_key,
                    "payload": node.payload,
                    "strategy": strategy,
                    "forced": forced,
                    "resharded": resharded,
                    "build_rows": build_rows,
                    "probe_rows": probe_rows,
                    "threshold": H.broadcast_threshold(
                        cfg.num_units, cfg.threads_per_unit, cfg.hybrid
                    ),
                },
                float_cols=pr.float_cols | frozenset(
                    c for c in node.payload if c in b.float_cols
                ),
            )
            # Containment estimate: under referential integrity every probe
            # key is drawn from the build's key domain, so the probe rows
            # surviving the join are the fraction of build keys surviving
            # upstream filters — est(b) / ndv(build_key).  The build-key ndv
            # comes from the base-table profile (exact when the sample
            # covers the dimension table); the probe-key ndv is only a
            # fallback — its GEE estimate carries a sqrt(N/n) error that
            # would leak straight into the output cardinality.  Without
            # profiles, keep the pass-through estimate.
            est_out = _est(pr)
            if profiles:
                cs = stats_by_col.get(node.build_key) or stats_by_col.get(
                    node.probe_key
                )
                if cs is not None and cs.ndv > 0:
                    est_out = _est(pr) * min(1.0, _est(b) / float(cs.ndv))
            est[id(p)] = est_out
        elif isinstance(node, L.GroupBy) and node.num_groups is None:
            c = reject_replicated(plan(node.child), "sort-based GroupBy")
            c = ensure_hash(c, node.key)
            sum_cols = frozenset(
                name for name, _e, kind in node.aggs if kind == "sum"
            )
            if c.part == ("salted", node.key):
                # Salted shape (Fig 6c adapted to skew): aggregate per
                # salted sub-stream by the TRUE key, broadcast the small
                # partial-aggregate tables, and merge them everywhere by
                # summing partial sums AND partial counts — the replicated
                # result feeds join builds with no further exchange.
                partial = PNode(
                    "groupby_sorted", node.schema, c.cap, c.part, (c,),
                    {"key": node.key, "aggs": node.aggs, "partial": True},
                    float_cols=sum_cols,
                )
                est[id(partial)] = _est(c)
                bc = exchange(partial, "broadcast", node.key)
                p = PNode(
                    "groupby_combine", node.schema, bc.cap, REPLICATED, (bc,),
                    {"key": node.key, "aggs": node.aggs},
                    # every aggregate is re-summed in f32 by the combine
                    float_cols=frozenset(n for n, _e, _k in node.aggs),
                )
            else:
                p = PNode(
                    "groupby_sorted",
                    node.schema,
                    c.cap,
                    ("hash", node.key),
                    (c,),
                    {"key": node.key, "aggs": node.aggs},
                    float_cols=sum_cols,
                )
            cs = stats_by_col.get(node.key)
            est[id(p)] = min(_est(c), float(cs.ndv)) if cs else _est(c)
        elif isinstance(node, L.GroupBy):
            c = reject_replicated(plan(node.child), "dense GroupBy")
            assert use_preaggregation(node.num_groups, c.cap), (
                "dense GroupBy domain too large to pre-aggregate; use the "
                "sort-based GroupBy (key=...)"
            )
            p = PNode(
                "groupby_dense",
                node.schema,
                node.num_groups,
                REPLICATED,
                (c,),
                {"key_expr": node.key_expr, "num_groups": node.num_groups,
                 "aggs": node.aggs},
            )
        elif isinstance(node, L.Aggregate):
            c = reject_replicated(plan(node.child), "Aggregate")
            p = PNode("aggregate", node.schema, 1, REPLICATED, (c,),
                      {"aggs": node.aggs})
        elif isinstance(node, L.TopK):
            c = reject_replicated(plan(node.child), "TopK")
            p = PNode("topk", node.schema, node.k, REPLICATED, (c,),
                      {"key": node.key, "k": node.k, "payload": node.payload})
        else:
            raise TypeError(f"unknown logical node {type(node).__name__}")
        memo[id(node)] = p
        return p

    proot = plan(root)
    if proot.kind not in ("groupby_dense", "aggregate", "topk"):
        raise ValueError(
            f"plan root must be an aggregation/top-k (got {proot.kind}): "
            "distributed results are combined with psum/top-k, not gathered"
        )
    # deterministic display indices (first-visit preorder)
    counter = [0]
    seen: set[int] = set()

    def number(n: PNode):
        if id(n) in seen:
            return
        seen.add(id(n))
        n.idx = counter[0]
        counter[0] += 1
        for c in n.children:
            number(c)

    number(proot)
    return {
        "root": proot,
        "scans": scans,
        "shuffle_stats": shuffle_stats,
        "broadcast_stats": broadcast_stats,
    }


# ----------------------------------------------------------------------------
# explain(): the deterministic rendering golden snapshots assert on.
# ----------------------------------------------------------------------------

def _aggs_str(aggs) -> str:
    return ", ".join(f"{n}={k}({e.render()})" for n, e, k in aggs)


def _part_str(part) -> str:
    if part is None:
        return "round-robin"
    if part == REPLICATED:
        return "replicated"
    if part[0] == "salted":
        return f"salted-hash({part[1]})"
    return f"hash({part[1]})"


def _node_line(n: PNode) -> str:
    if n.kind == "scan":
        d = f"Scan[{n.info['table']}: {','.join(n.schema)}]"
    elif n.kind == "filter":
        d = f"Filter[{n.info['pred'].render()}]"
    elif n.kind == "project":
        derived = "".join(
            f" {name}:={e.render()}" for name, e in n.info["derived"]
        )
        d = f"Project[{','.join(n.info['keep'])}{derived}]"
    elif n.kind == "exchange":
        st: TableStats = n.info["stats"]
        if n.info.get("salted"):
            pr = n.info["priced_s"]
            d = (
                f"Exchange[shuffle by {n.info['key']}, "
                f"salted x{n.info['num_salts']} over "
                f"{len(n.info['heavy_keys'])} heavy] "
                f"rows/shard={st.rows} row_bytes={st.row_bytes} "
                f"overload {n.info['overload_plain']:.2f}->"
                f"{n.info['overload_salted']:.2f} "
                f"priced/s plain={pr['plain']:.2e} "
                f"salted={pr['salted']:.2e} "
                f"broadcast={pr['broadcast']:.2e}"
            )
        else:
            d = (
                f"Exchange[{n.info['exkind']} by {n.info['key']}] "
                f"rows/shard={st.rows} row_bytes={st.row_bytes}"
            )
    elif n.kind == "join":
        i = n.info
        ratio = (
            i["probe_rows"] / i["build_rows"] if i["build_rows"] else
            float("inf")
        )
        strategy = i["strategy"] + (
            "+cross_pod_reshard" if i.get("resharded") else ""
        ) + (f" (forced: {i['forced']})" if i.get("forced") else "")
        d = (
            f"HashJoin[{i['build_key']} = {i['probe_key']}] "
            f"strategy={strategy} "
            f"(probe/build = {i['probe_rows']}/{i['build_rows']} = "
            f"{ratio:.1f}, broadcast at >= {i['threshold']})"
        )
        if i["payload"]:
            d += f" payload={','.join(i['payload'])}"
    elif n.kind == "groupby_sorted":
        partial = " partial-per-salt" if n.info.get("partial") else ""
        d = (
            f"GroupBy[{n.info['key']}: {_aggs_str(n.info['aggs'])}] "
            f"sort-based{partial}"
        )
    elif n.kind == "groupby_combine":
        d = (
            f"GroupByCombine[{n.info['key']}: "
            f"{_aggs_str(n.info['aggs'])}] replicated merge of salted "
            "partials"
        )
    elif n.kind == "groupby_dense":
        d = (
            f"GroupBy[{n.info['key_expr'].render()} -> "
            f"{n.info['num_groups']} groups: {_aggs_str(n.info['aggs'])}] "
            "dense pre-aggregation + psum"
        )
    elif n.kind == "aggregate":
        d = f"Aggregate[{_aggs_str(n.info['aggs'])}] + psum"
    elif n.kind == "topk":
        d = (
            f"TopK[{n.info['key']} desc, k={n.info['k']}] "
            f"payload={','.join(n.info['payload'])} + broadcast combine"
        )
    else:  # pragma: no cover
        d = n.kind
    return f"#{n.idx} {d}  [cap/shard={n.cap}, {_part_str(n.part)}]"


def explain(plan: PhysicalPlan) -> str:
    """Render the physical plan: header, tuned multiplexer, operator tree.

    Shared subtrees (the DAG case) are printed once and referenced by
    ``#idx`` afterwards; everything here is a pure function of the plan, so
    a cost-model change that flips a broadcast/shuffle decision shows up as
    a reviewable golden-file diff.
    """
    t = plan.tuned
    lines = [
        f"plan {plan.name}: num_shards={plan.num_shards} "
        f"num_pods={plan.num_pods} units={plan.cfg.num_units} "
        f"broadcast_threshold={H.broadcast_threshold(plan.cfg.num_units, plan.cfg.threads_per_unit, plan.cfg.hybrid)}",
        f"multiplexer: impl={t.impl} pack={t.pack_impl} "
        f"pipeline_chunks={t.pipeline_chunks} "
        f"transport_chunks={t.transport_chunks} "
        f"modeled={t.modeled_s:.3e}s"
        + (f" cross_pod={t.cross_pod}" if t.cross_pod else ""),
        f"exchanges: {len(plan.shuffle_stats)} shuffle, "
        f"{len(plan.broadcast_stats)} broadcast, "
        f"wire_bytes~{plan.total_wire_bytes()}",
    ]
    printed: set[int] = set()

    def walk(n: PNode, depth: int):
        pad = "  " * depth
        if id(n) in printed:
            lines.append(f"{pad}#{n.idx} (shared, see above)")
            return
        printed.add(id(n))
        lines.append(pad + _node_line(n))
        for c in n.children:
            walk(c, depth + 1)

    walk(plan.root, 0)
    return "\n".join(lines) + "\n"


__all__ = [
    "JoinStrategy",
    "PlannerConfig",
    "choose_join_strategy",
    "exchange_bytes",
    "use_preaggregation",
    "PNode",
    "PhysicalPlan",
    "plan_physical",
    "explain",
    "REPLICATED",
    "DEFAULT_SALT_THRESHOLD",
]
