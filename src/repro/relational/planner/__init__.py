"""Declarative query-plan subsystem (paper §3.1 / Fig 6).

Three layers, replacing the hand-wired shard_map plumbing that used to live
per-query in ``relational/distributed.py``:

* :mod:`~repro.relational.planner.logical` — a small relational operator DAG
  (``Scan``/``Filter``/``Project``/``HashJoin``/``GroupBy``/``Aggregate``/
  ``TopK``) with schema and cardinality inference, plus the tiny expression
  language predicates and aggregates are written in.
* :mod:`~repro.relational.planner.physical` — the cost-based physical
  planner: places an ``Exchange(shuffle|broadcast)`` edge on every join /
  group boundary using the paper's hybrid broadcast threshold and the
  topology autotuner's makespan model, tracks partitioning properties so
  co-partitioned pipelines share one exchange, and renders a deterministic
  ``explain()`` string (the golden-snapshot surface).
* :mod:`~repro.relational.planner.executor` — compiles a physical plan into
  ONE ``shard_map``-ed function over the mask-carrying operators in
  ``relational/operators.py``, with every exchange routed through the
  query's auto-tuned :class:`~repro.core.multiplexer.CommMultiplexer` and
  capacity overflow surfaced as an error (never silent row loss).

``planner.tpch`` expresses all nine TPC-H queries (Q1/Q3/Q4/Q6/Q12/Q14/
Q17/Q18/Q19) as logical plans; ``relational/distributed.py``'s entry points
are thin wrappers over it.

:mod:`~repro.relational.planner.plan_cache` sits beside the three layers:
a persistent plan + compile cache (canonical-DAG-render + stats-bucket +
mesh-shape keys, pickled plan artifacts, in-process executor memo) so the
query-serving engine's hot path never replans or retraces a repeated
template.
"""

from .logical import (
    Aggregate,
    Expr,
    Filter,
    GroupBy,
    HashJoin,
    Project,
    Scan,
    TopK,
    col,
    lit,
    where,
)
from .physical import (
    PhysicalPlan,
    PlannerConfig,
    choose_join_strategy,
    exchange_bytes,
    plan_physical,
    use_preaggregation,
)
from .executor import compile_plan, execute_plan
from .plan_cache import (
    PlanCache,
    PlanKey,
    canonical_render,
    plan_key,
    stats_bucket,
)

__all__ = [
    "Aggregate",
    "Expr",
    "Filter",
    "GroupBy",
    "HashJoin",
    "Project",
    "Scan",
    "TopK",
    "col",
    "lit",
    "where",
    "PhysicalPlan",
    "PlannerConfig",
    "choose_join_strategy",
    "exchange_bytes",
    "plan_physical",
    "use_preaggregation",
    "execute_plan",
    "compile_plan",
    "PlanCache",
    "PlanKey",
    "canonical_render",
    "plan_key",
    "stats_bucket",
]
