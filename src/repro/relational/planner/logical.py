"""Logical operator DAG + the expression language plans are written in.

The IR is deliberately small — exactly the operators the paper's TPC-H
evaluation needs (HyPer's pipeline set): ``Scan``, ``Filter``, ``Project``,
``HashJoin`` (PK build side), ``GroupBy`` (dense pre-aggregating or
sort-based), scalar ``Aggregate``, and ``TopK``.  Nodes are frozen
dataclasses; a node used by two consumers (e.g. Q17's partitioned lineitem
feeding both the correlated-AVG group-by and the probe of the join back)
makes the plan a DAG, and both the physical planner and the executor
memoize on node identity so shared pipelines are planned and executed once.

Expressions (:class:`Expr`) are declarative — ``col("l_quantity") < lit(24)``
— so the physical planner can render them deterministically in ``explain()``
(the golden-snapshot surface) and the executor can evaluate them against a
mask-carrying :class:`~repro.relational.table.Table`.  Python operator
overloads build the tree; ``eval`` maps onto jax.numpy.

Schema inference is structural (every node exposes ``.schema``); cardinality
inference (``est_rows``) propagates the *static capacity* bound from a
catalog of base-table row counts — capacities, not expected selectivities,
because capacities are what size the zero-drop exchange buffers and what
the paper's broadcast-threshold rule compares (§3.1).
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from ..table import Table

# ----------------------------------------------------------------------------
# Expression language.
# ----------------------------------------------------------------------------

_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


class Expr:
    """Base class: a scalar-per-row expression over a Table's columns."""

    def eval(self, t: Table) -> jax.Array:
        raise NotImplementedError

    def render(self) -> str:
        raise NotImplementedError

    def columns(self) -> frozenset[str]:
        """Column names this expression reads (for pruning checks)."""
        raise NotImplementedError

    def is_float(self, float_cols: frozenset[str]) -> bool:
        """Whether this expression produces a float column, given which of
        the input columns are float.  The physical planner uses this to
        know which schemas can still go through the int32 row-image
        exchange (float aggregates must stay local)."""
        raise NotImplementedError

    def f32(self) -> "Expr":
        return Cast(self, "f32")

    # -- operator overloads (non-Expr operands become literals) -------------
    def __add__(self, o):
        return Bin("+", self, _wrap(o))

    def __radd__(self, o):
        return Bin("+", _wrap(o), self)

    def __sub__(self, o):
        return Bin("-", self, _wrap(o))

    def __rsub__(self, o):
        return Bin("-", _wrap(o), self)

    def __mul__(self, o):
        return Bin("*", self, _wrap(o))

    def __rmul__(self, o):
        return Bin("*", _wrap(o), self)

    def __truediv__(self, o):
        return Bin("/", self, _wrap(o))

    def __lt__(self, o):
        return Bin("<", self, _wrap(o))

    def __le__(self, o):
        return Bin("<=", self, _wrap(o))

    def __gt__(self, o):
        return Bin(">", self, _wrap(o))

    def __ge__(self, o):
        return Bin(">=", self, _wrap(o))

    def eq(self, o):  # __eq__ would break hashing/dataclass equality
        return Bin("==", self, _wrap(o))

    def ne(self, o):
        return Bin("!=", self, _wrap(o))

    def __and__(self, o):
        return Bin("&", self, _wrap(o))

    def __or__(self, o):
        return Bin("|", self, _wrap(o))


@dataclasses.dataclass(frozen=True)
class Col(Expr):
    name: str

    def eval(self, t: Table) -> jax.Array:
        return t[self.name]

    def render(self) -> str:
        return self.name

    def columns(self) -> frozenset[str]:
        return frozenset((self.name,))

    def is_float(self, float_cols: frozenset[str]) -> bool:
        return self.name in float_cols


@dataclasses.dataclass(frozen=True)
class Lit(Expr):
    value: float | int | bool

    def eval(self, t: Table):
        return self.value

    def render(self) -> str:
        return repr(self.value)

    def columns(self) -> frozenset[str]:
        return frozenset()

    def is_float(self, float_cols: frozenset[str]) -> bool:
        return isinstance(self.value, float)


_OPS = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "&": lambda a, b: a & b,
    "|": lambda a, b: a | b,
}


@dataclasses.dataclass(frozen=True)
class Bin(Expr):
    op: str
    lhs: Expr
    rhs: Expr

    def eval(self, t: Table):
        return _OPS[self.op](self.lhs.eval(t), self.rhs.eval(t))

    def render(self) -> str:
        return f"({self.lhs.render()} {self.op} {self.rhs.render()})"

    def columns(self) -> frozenset[str]:
        return self.lhs.columns() | self.rhs.columns()

    def is_float(self, float_cols: frozenset[str]) -> bool:
        if self.op == "/":
            return True  # true division promotes to float
        if self.op in ("<", "<=", ">", ">=", "==", "!=", "&", "|"):
            return False  # boolean result
        return self.lhs.is_float(float_cols) or self.rhs.is_float(float_cols)


@dataclasses.dataclass(frozen=True)
class Cast(Expr):
    child: Expr
    dtype: str  # "f32" | "i32"

    def eval(self, t: Table):
        return jnp.asarray(self.child.eval(t)).astype(_DTYPES[self.dtype])

    def render(self) -> str:
        return f"{self.dtype}({self.child.render()})"

    def columns(self) -> frozenset[str]:
        return self.child.columns()

    def is_float(self, float_cols: frozenset[str]) -> bool:
        return self.dtype == "f32"


@dataclasses.dataclass(frozen=True)
class Where(Expr):
    cond: Expr
    then: Expr
    other: Expr

    def eval(self, t: Table):
        return jnp.where(self.cond.eval(t), self.then.eval(t), self.other.eval(t))

    def render(self) -> str:
        return (
            f"where({self.cond.render()}, {self.then.render()}, "
            f"{self.other.render()})"
        )

    def columns(self) -> frozenset[str]:
        return self.cond.columns() | self.then.columns() | self.other.columns()

    def is_float(self, float_cols: frozenset[str]) -> bool:
        return (
            self.then.is_float(float_cols) or self.other.is_float(float_cols)
        )


def _wrap(v) -> Expr:
    return v if isinstance(v, Expr) else Lit(v)


def col(name: str) -> Col:
    return Col(name)


def lit(v) -> Lit:
    return Lit(v)


def where(cond: Expr, then, other) -> Where:
    return Where(cond, _wrap(then), _wrap(other))


def predicate_selectivity(pred: Expr, sample: Mapping[str, "np.ndarray"]) -> float:
    """Fraction of ``sample`` rows passing ``pred`` — the estimation side of
    the expression language.

    ``sample`` maps column names to equal-length numpy arrays (a statistics
    sample, see :mod:`repro.relational.stats`); the predicate is evaluated
    with the exact same ``Expr.eval`` the executor uses, so the estimate and
    the runtime filter can never disagree on semantics.  An empty sample
    returns 1.0 (no evidence to prune on — keep the conservative capacity).
    """
    cols = {k: jnp.asarray(v) for k, v in sample.items()}
    n = next(iter(cols.values())).shape[0] if cols else 0
    if n == 0:
        return 1.0
    t = Table(cols, jnp.ones((n,), jnp.bool_))
    mask = np.asarray(pred.eval(t)).astype(bool)
    return float(mask.mean())


# ----------------------------------------------------------------------------
# Logical operators.
# ----------------------------------------------------------------------------

AggKind = Literal["sum", "count"]
# (output name, input expression, kind); count ignores the expression
AggSpec = tuple[str, Expr, AggKind]

Catalog = Mapping[str, int]  # base table name -> row count (capacity)


class Node:
    """Base logical operator; subclasses are frozen dataclasses."""

    @property
    def schema(self) -> tuple[str, ...]:
        raise NotImplementedError

    def children(self) -> tuple["Node", ...]:
        raise NotImplementedError

    def est_rows(self, catalog: Catalog) -> int:
        """Static row-capacity bound flowing out of this operator."""
        raise NotImplementedError


def _assert_streaming(child: "Node", op: str) -> None:
    """Root-only combines (dense GroupBy / Aggregate / TopK) produce a
    cross-shard-combined result, not a row stream — consuming one from
    another operator is an illegal plan shape; reject it at construction
    instead of failing inside jit tracing."""
    root_only = isinstance(child, (Aggregate, TopK)) or (
        isinstance(child, GroupBy) and child.num_groups is not None
    )
    if root_only:
        raise TypeError(
            f"{op} cannot consume {type(child).__name__}: dense/scalar "
            "combines are root-only (their psum/top-k merge already "
            "crossed shards)"
        )


@dataclasses.dataclass(frozen=True)
class Scan(Node):
    """Read a base table, pruned to ``columns`` (paper §3.2.1: prune before
    anything ships)."""

    table: str
    columns: tuple[str, ...]

    @property
    def schema(self) -> tuple[str, ...]:
        return self.columns

    def children(self):
        return ()

    def est_rows(self, catalog: Catalog) -> int:
        return int(catalog[self.table])


@dataclasses.dataclass(frozen=True)
class Filter(Node):
    """Selection vector: AND ``pred`` into the validity mask (no movement)."""

    child: Node
    pred: Expr

    def __post_init__(self):
        _assert_streaming(self.child, "Filter")
        missing = self.pred.columns() - set(self.child.schema)
        assert not missing, f"Filter reads unknown columns {sorted(missing)}"

    @property
    def schema(self) -> tuple[str, ...]:
        return self.child.schema

    def children(self):
        return (self.child,)

    def est_rows(self, catalog: Catalog) -> int:
        return self.child.est_rows(catalog)


@dataclasses.dataclass(frozen=True)
class Project(Node):
    """Keep ``keep`` columns and append ``derived`` computed columns."""

    child: Node
    keep: tuple[str, ...]
    derived: tuple[tuple[str, Expr], ...] = ()

    def __post_init__(self):
        _assert_streaming(self.child, "Project")
        have = set(self.child.schema)
        missing = set(self.keep) - have
        for _, e in self.derived:
            missing |= e.columns() - have
        assert not missing, f"Project reads unknown columns {sorted(missing)}"

    @property
    def schema(self) -> tuple[str, ...]:
        return self.keep + tuple(n for n, _ in self.derived)

    def children(self):
        return (self.child,)

    def est_rows(self, catalog: Catalog) -> int:
        return self.child.est_rows(catalog)


@dataclasses.dataclass(frozen=True)
class HashJoin(Node):
    """PK-FK join: ``build`` has unique keys, ``probe`` rows survive with
    ``payload`` build columns attached (non-matches masked out).

    The physical planner decides broadcast-vs-partition for the build side
    with the paper's hybrid threshold (§3.1) — the join itself is strategy-
    agnostic, which is the whole point of the IR.
    """

    build: Node
    probe: Node
    build_key: str
    probe_key: str
    payload: tuple[str, ...] = ()

    def __post_init__(self):
        _assert_streaming(self.build, "HashJoin (build)")
        _assert_streaming(self.probe, "HashJoin (probe)")
        assert self.build_key in self.build.schema, self.build_key
        assert self.probe_key in self.probe.schema, self.probe_key
        missing = set(self.payload) - set(self.build.schema)
        assert not missing, f"payload not in build schema: {sorted(missing)}"

    @property
    def schema(self) -> tuple[str, ...]:
        return self.probe.schema + self.payload

    def children(self):
        return (self.build, self.probe)

    def est_rows(self, catalog: Catalog) -> int:
        return self.probe.est_rows(catalog)


@dataclasses.dataclass(frozen=True)
class GroupBy(Node):
    """Group-by aggregation, two physical flavors picked by ``num_groups``:

    * ``num_groups is None`` — sort-based over a large key domain
      (``key`` column); output is a group table (key + aggregates), hash-
      partitioned on the key.  Forces co-partitioning on ``key``.
    * ``num_groups = G`` — dense pre-aggregation over a small domain
      (``key_expr`` computes the group id): each shard reduces locally into
      ``G`` cells and the cross-shard combine is a psum of the tiny group
      table, not a shuffle of raw rows (paper Fig 6c).  Root-only.
    """

    child: Node
    aggs: tuple[AggSpec, ...]
    key: str | None = None
    key_expr: Expr | None = None
    num_groups: int | None = None

    def __post_init__(self):
        _assert_streaming(self.child, "GroupBy")
        have = set(self.child.schema)
        if self.num_groups is None:
            assert self.key in have, self.key
        else:
            assert self.key_expr is not None, "dense GroupBy needs key_expr"
            missing = self.key_expr.columns() - have
            assert not missing, (
                f"key_expr reads unknown columns {sorted(missing)}"
            )
        for _, e, _k in self.aggs:
            missing = e.columns() - have
            assert not missing, f"agg reads unknown columns {sorted(missing)}"

    @property
    def schema(self) -> tuple[str, ...]:
        names = tuple(n for n, _, _ in self.aggs)
        return ((self.key,) + names) if self.num_groups is None else names

    def children(self):
        return (self.child,)

    def est_rows(self, catalog: Catalog) -> int:
        if self.num_groups is not None:
            return self.num_groups
        return self.child.est_rows(catalog)  # worst case: all keys distinct


@dataclasses.dataclass(frozen=True)
class Aggregate(Node):
    """Scalar aggregates over the whole input; combine is a psum. Root-only."""

    child: Node
    aggs: tuple[AggSpec, ...]

    def __post_init__(self):
        _assert_streaming(self.child, "Aggregate")
        have = set(self.child.schema)
        for _, e, _k in self.aggs:
            missing = e.columns() - have
            assert not missing, f"agg reads unknown columns {sorted(missing)}"

    @property
    def schema(self) -> tuple[str, ...]:
        return tuple(n for n, _, _ in self.aggs)

    def children(self):
        return (self.child,)

    def est_rows(self, catalog: Catalog) -> int:
        return 1


@dataclasses.dataclass(frozen=True)
class TopK(Node):
    """Top-``k`` rows by ``key`` (descending): local top-k per shard, then a
    broadcast combine of the tiny candidate set. Root-only."""

    child: Node
    key: str
    k: int
    payload: tuple[str, ...]

    def __post_init__(self):
        _assert_streaming(self.child, "TopK")
        have = set(self.child.schema)
        assert self.key in have, self.key
        missing = set(self.payload) - have
        assert not missing, f"payload not in schema: {sorted(missing)}"

    @property
    def schema(self) -> tuple[str, ...]:
        return self.payload

    def children(self):
        return (self.child,)

    def est_rows(self, catalog: Catalog) -> int:
        return self.k


def scans_of(root: Node) -> tuple[Scan, ...]:
    """Every distinct Scan in the DAG, in deterministic first-visit order."""
    seen: dict[int, Scan] = {}
    out: list[Scan] = []

    def walk(n: Node):
        if id(n) in seen:
            return
        seen[id(n)] = n  # type: ignore[assignment]
        if isinstance(n, Scan):
            out.append(n)
        for c in n.children():
            walk(c)

    walk(root)
    return tuple(out)


__all__ = [
    "Expr",
    "Col",
    "Lit",
    "Bin",
    "Cast",
    "Where",
    "col",
    "lit",
    "where",
    "AggSpec",
    "Catalog",
    "Node",
    "Scan",
    "Filter",
    "Project",
    "HashJoin",
    "GroupBy",
    "Aggregate",
    "TopK",
    "scans_of",
]
