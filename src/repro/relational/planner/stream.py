"""Out-of-core morsel-streamed plan execution.

The in-memory executor (:mod:`.executor`) evaluates the whole plan in one
shard_map over full-capacity tables, so scale factor is bounded by device
memory.  This module executes the SAME physical plan chunk-at-a-time: one
base table is a chunked :class:`~repro.relational.source.DataSource` whose
fixed-capacity morsels stream through the pipeline with double-buffered
host→device prefetch (:class:`~repro.data.pipeline.Prefetcher`), while
every pipeline *breaker* (aggregates, group-bys, top-k) keeps a
fixed-shape per-shard partial state that each morsel merges into — the
``GroupByCombine`` semantics (re-group partials by the true key, re-sum
sums AND counts) applied incrementally.

Execution is decomposed into **passes**: breakers whose inputs contain no
other breaker run in pass 1, breakers over pass-1 outputs run in pass 2,
and so on (Q17 is the canonical two-pass query: pass 1 builds the per-part
average over the morsel stream, pass 2 re-scans the stream and aggregates
against it).  A pass whose breakers never touch the streamed scan runs as
a single step over resident inputs; the others loop over the morsels.
Non-breaker work upstream of a breaker (filters, projects, joins, the
build-side broadcast) re-evaluates per morsel — compute is traded for
memory, which is the out-of-core deal.

Exchanges inside the streamed pipeline move one morsel at a time, sized
for structural zero drop by default.  A tighter per-(src,dst) message
capacity (``ExecutionContext.exchange_rows``) can overflow; with
``spill=True`` overflow rows are withheld on the sender
(:func:`repro.core.exchange.hash_shuffle_spill`), parked in a host-memory
overflow partition, and re-offered in drain rounds after the morsel loop —
rows are never silently lost, and with spill disabled overflow raises
exactly like the in-memory executor's drop check.

Not supported streamed (raises ``NotImplementedError``): salted/adaptive
plans (``groupby_combine``), joins whose BUILD side streams, and non-
group-by breaker outputs consumed by later passes.  Plans built with
``StatsMode.STATIC`` never contain the former.
"""

from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ...compat import fetch, shard_map
from ...data.pipeline import Prefetcher
from ...obs.trace import deposit, maybe_span
from .. import operators as ops
from ..source import DataSource, as_source
from ..table import Table, from_numpy, pad_to
from .executor import (
    SHUFFLE_AXIS,
    RunnerBase,
    _axes,
    _make_mux,
    _mesh,
    _prep,
    _raise_on_dropped,
    _report_keys,
    _shuffle_histogram,
)
from .physical import PhysicalPlan, PNode

BREAKER_KINDS = frozenset(
    {"groupby_sorted", "groupby_combine", "groupby_dense", "aggregate", "topk"}
)

# Drain rounds make monotonic progress (every round delivers at least one
# row per backlogged destination), so this bound only trips on a logic bug.
MAX_DRAIN_ROUNDS = 1000


def _walk_unique(root: PNode):
    seen: set[int] = set()

    def go(n: PNode):
        if id(n) in seen:
            return
        seen.add(id(n))
        yield n
        for c in n.children:
            yield from go(c)

    yield from go(root)


class _StreamedPlan:
    """Static analysis of one physical plan against one streamed scan:
    which nodes vary morsel-to-morsel, and which pass each breaker runs in."""

    def __init__(self, plan: PhysicalPlan, streamed_table: str):
        self.plan = plan
        self.streamed_table = streamed_table
        self._streamed: dict[int, bool] = {}
        for n in _walk_unique(plan.root):
            if n.kind == "groupby_combine":
                raise NotImplementedError(
                    "salted/adaptive plans cannot stream; plan with "
                    "StatsMode.STATIC for out-of-core execution"
                )
            if (
                n.kind == "exchange"
                and isinstance(n.part, tuple)
                and n.part[0] == "salted"
            ):
                raise NotImplementedError("salted exchanges cannot stream")
        if plan.root.kind not in BREAKER_KINDS:
            raise ValueError("plan root must be an aggregation/top-k to stream")
        self.breakers = [
            n for n in _walk_unique(plan.root) if n.kind in BREAKER_KINDS
        ]
        self.pass_of: dict[int, int] = {}
        for b in self.breakers:
            self._assign_pass(b)
        self.num_passes = max(self.pass_of.values(), default=1)

    def streamed(self, n: PNode) -> bool:
        """Does this node's output change morsel to morsel?"""
        if id(n) in self._streamed:
            return self._streamed[id(n)]
        if n.kind == "scan":
            r = n.info["table"] == self.streamed_table
        elif n.kind in BREAKER_KINDS:
            r = False  # breaker output is resident state
        elif n.kind == "join":
            build, probe = n.children
            if self.streamed(build):
                raise NotImplementedError(
                    "join build side streams: streamed execution requires "
                    "the chunked table on the probe side"
                )
            r = self.streamed(probe)
        else:
            r = any(self.streamed(c) for c in n.children)
        self._streamed[id(n)] = r
        return r

    def _upstream_breakers(self, n: PNode) -> list[PNode]:
        out: list[PNode] = []
        seen: set[int] = set()

        def go(m: PNode):
            for c in m.children:
                if id(c) in seen:
                    continue
                seen.add(id(c))
                if c.kind in BREAKER_KINDS:
                    out.append(c)
                else:
                    go(c)

        go(n)
        return out

    def _assign_pass(self, b: PNode) -> int:
        if id(b) in self.pass_of:
            return self.pass_of[id(b)]
        ups = self._upstream_breakers(b)
        p = 1 + max((self._assign_pass(u) for u in ups), default=0)
        self.pass_of[id(b)] = p
        return p

    def pass_breakers(self, p: int) -> list[PNode]:
        return [b for b in self.breakers if self.pass_of[id(b)] == p]

    def shuffles_feeding(self, b: PNode, streamed_only: bool) -> list[PNode]:
        """Shuffle exchanges on ``b``'s input side, not crossing breakers."""
        out: list[PNode] = []
        seen: set[int] = set()

        def go(m: PNode):
            if id(m) in seen or m.kind in BREAKER_KINDS:
                return
            seen.add(id(m))
            if m.kind == "exchange" and m.info["exkind"] == "shuffle":
                if not streamed_only or self.streamed(m):
                    out.append(m)
            for c in m.children:
                go(c)

        go(b.children[0])
        return out


def _bname(n: PNode) -> str:
    return f"b{n.idx}"


def compile_plan_streamed(
    plan: PhysicalPlan,
    sources: dict[str, DataSource | Table],
    ctx,
    mux=None,
):
    """Build a zero-arg runner that streams the plan over morsels.

    ``sources`` maps every base table of the plan to a Table or DataSource;
    exactly one must be chunked (``num_chunks > 1``) — that relation
    streams, everything else stays resident.  ``ctx`` is an
    :class:`~repro.relational.context.ExecutionContext` (morsel/spill knobs
    plus the usual multiplexer knobs).  The runner returns the same result
    shape as the in-memory executor (integer outputs bit-identical; float
    aggregates differ only by f32 summation order) and exposes ``.stats``
    with morsel/pass/spill/prefetch-overlap counters.
    """
    num_shards, num_pods = plan.num_shards, plan.num_pods
    srcs = {name: as_source(sources[name]) for name in plan.scans}
    for name in plan.scans:
        if srcs[name].capacity != plan.catalog[name]:
            raise ValueError(
                f"source {name!r} has capacity {srcs[name].capacity} but the "
                f"plan was built for {plan.catalog[name]}; re-plan for the "
                "actual sources"
            )
    chunked = [n for n in plan.scans if srcs[n].is_chunked]
    if len(chunked) != 1:
        raise ValueError(
            f"streamed execution needs exactly one chunked source, got "
            f"{chunked or 'none'}; use execute_plan for fully in-memory runs"
        )
    streamed_name = chunked[0]
    sp = _StreamedPlan(plan, streamed_name)
    src = srcs[streamed_name]

    mesh = _mesh(num_shards, num_pods)
    axes = _axes(num_pods)
    report_keys = _report_keys(plan.root)
    tracer = ctx.trace
    if mux is None:
        mux = _make_mux(mesh, plan, ctx.impl, ctx.pack_impl, ctx.num_chunks)
    if ctx.spill and mux.plan.pod_axis is not None:
        raise NotImplementedError(
            "spill is single-level only; on pod meshes stream with "
            "zero-drop exchange capacity (exchange_rows=None)"
        )
    single = num_shards == 1 and num_pods == 1

    # Per-shard row capacity of one prepped morsel — every streamed
    # pipeline node keeps this capacity (filters/projects/joins preserve it).
    morsel_cap = math.ceil(src.chunk_rows / num_shards) * num_shards
    per_shard = morsel_cap // num_shards

    budget = ctx.device_row_budget
    if budget is not None:
        if per_shard > budget:
            raise ValueError(
                f"morsel slice of {per_shard} rows/device exceeds "
                f"device_row_budget={budget}; use smaller chunks"
            )
        for name in plan.scans:
            if name == streamed_name:
                continue
            resident_ps = math.ceil(srcs[name].capacity / num_shards)
            if resident_ps > budget:
                raise ValueError(
                    f"resident table {name!r} needs {resident_ps} rows/device,"
                    f" over device_row_budget={budget}; chunk it or raise the "
                    "budget"
                )

    resident_names = [n for n in plan.scans if n != streamed_name]
    resident_prepped = [
        _prep(srcs[name].materialize(), num_shards) for name in resident_names
    ]

    # The pass schedule: streamed breakers join the morsel loop, resident
    # ones run a single step (their input never touches the morsel — one
    # step per pass, or they would multiply-count).
    pass_plan = []
    for p in range(1, sp.num_passes + 1):
        bs = sp.pass_breakers(p)
        streamed_bs = [b for b in bs if sp.streamed(b.children[0])]
        resident_bs = [b for b in bs if not sp.streamed(b.children[0])]
        spill_nodes: list[PNode] = []
        if ctx.spill:
            seen: set[int] = set()
            for b in streamed_bs:
                for x in sp.shuffles_feeding(b, streamed_only=True):
                    if id(x) not in seen:
                        seen.add(id(x))
                        spill_nodes.append(x)
            if len(spill_nodes) > 1:
                raise NotImplementedError(
                    "spill supports one streamed shuffle per pass"
                )
        pass_plan.append((p, streamed_bs, resident_bs, spill_nodes))

    # ---- breaker state templates (global shapes, leading dim = num_shards)
    def _group_cap(n: PNode) -> int:
        if ctx.group_state_rows is not None:
            return int(ctx.group_state_rows)
        cap = n.cap
        if budget is not None:
            cap = min(cap, budget)
        return max(int(cap), 1)

    def _init_state(n: PNode):
        N = num_shards
        if n.kind == "aggregate":
            return {
                name: jnp.zeros((N,), jnp.float32 if kind == "sum" else jnp.int32)
                for name, _e, kind in n.info["aggs"]
            }
        if n.kind == "groupby_dense":
            G = n.info["num_groups"]
            return {
                name: jnp.zeros((N, G), jnp.float32 if kind == "sum" else jnp.int32)
                for name, _e, kind in n.info["aggs"]
            }
        if n.kind == "groupby_sorted":
            C = _group_cap(n)
            return {
                "keys": jnp.zeros((N, C), jnp.int32),
                "valid": jnp.zeros((N, C), jnp.bool_),
                "aggs": {
                    name: jnp.zeros((N, C), jnp.float32)
                    for name, _e, _k in n.info["aggs"]
                },
                "overflow": jnp.zeros((N,), jnp.int32),
            }
        if n.kind == "topk":
            child = n.children[0]
            k = n.info["k"]
            return {
                "vals": jnp.full((N, k), -jnp.inf, jnp.float32),
                "payload": {
                    c: jnp.zeros(
                        (N, k),
                        jnp.float32 if c in child.float_cols else jnp.int32,
                    )
                    for c in n.info["payload"]
                },
            }
        raise NotImplementedError(f"no streamed state for breaker {n.kind!r}")

    states = {_bname(b): _init_state(b) for b in sp.breakers}
    if budget is not None:
        for b in sp.breakers:
            if b.kind == "groupby_sorted" and _group_cap(b) > budget:
                raise ValueError(
                    f"group state of {_group_cap(b)} rows/device exceeds "
                    f"device_row_budget={budget}; set group_state_rows"
                )

    # ---- per-step evaluation ---------------------------------------------
    def _exchange_streamed(t: Table, n: PNode, spills, reports,
                           do_spill: bool, bounded: bool):
        """One morsel's worth of rows through the decoupled exchange.

        ``bounded``: apply ``ctx.exchange_rows`` as the per-(src,dst)
        message capacity (streamed shuffles and drain re-offers only;
        resident exchanges keep the zero-drop bound).  The per-destination
        arrival histogram is psum'd into ``reports`` ALWAYS (same
        always-on discipline as the in-memory executor) — tracing decides
        who reads it, never whether it exists, so the jitted program is
        identical traced and untraced."""
        columns = list(n.schema)
        cap = t.valid.shape[0]
        msg_cap = cap
        if bounded and ctx.exchange_rows is not None:
            msg_cap = min(cap, int(ctx.exchange_rows))
        rows = jnp.stack([t[c].astype(jnp.int32) for c in columns], axis=1)
        keys = t[n.info["key"]].astype(jnp.int32)
        hist, _over = _shuffle_histogram(keys, t.valid, num_shards, axes)
        reports[report_keys[id(n)]] = hist
        if do_spill:
            out_rows, out_valid, spilled = mux.hash_shuffle_spill(
                keys, rows, SHUFFLE_AXIS, capacity=msg_cap, valid=t.valid
            )
            spills[id(n)] = (rows, spilled)
            dropped = jnp.int32(0)
        else:
            out_rows, out_valid, dropped = mux.hash_shuffle_global(
                keys, rows, SHUFFLE_AXIS, capacity=msg_cap, valid=t.valid
            )
        cols = {c: out_rows[:, i] for i, c in enumerate(columns)}
        return Table(cols, out_valid), dropped

    def _make_ev(tabs, local_states, drops, spills, spill_ids, reports,
                 drain_for=None):
        """Node evaluator for one step.

        ``tabs``: base-table name -> per-shard Table (the streamed scan's
        entry is the current morsel, or None in drain/resident-only steps).
        ``spill_ids``: exchange node ids that run the spill-capable path.
        ``drain_for``: (exchange_node_id, drain_table) — overrides that
        exchange to re-offer spilled rows instead of evaluating its child.
        """
        memo: dict[int, object] = {}

        def ev(n: PNode):
            if id(n) in memo:
                return memo[id(n)]
            r = _eval(n)
            memo[id(n)] = r
            return r

        def _agg_dict(t: Table, aggs):
            return {name: (e.eval(t), kind) for name, e, kind in aggs}

        def _eval(n: PNode):
            if n.kind in BREAKER_KINDS:
                # consumed output of an earlier pass: rebuild from state
                if n.kind != "groupby_sorted":
                    raise NotImplementedError(
                        f"streamed consumption of {n.kind} output"
                    )
                st = local_states[_bname(n)]
                cols = {n.info["key"]: st["keys"][0]}
                for name, _e, _k in n.info["aggs"]:
                    cols[name] = st["aggs"][name][0]
                return Table(cols, st["valid"][0])
            if n.kind == "scan":
                src_t = tabs[n.info["table"]]
                if src_t is None:
                    raise NotImplementedError(
                        "drain pass reached the streamed scan off the "
                        "spilling exchange's path"
                    )
                return Table({c: src_t[c] for c in n.schema}, src_t.valid)
            if n.kind == "filter":
                t = ev(n.children[0])
                return t.with_mask(n.info["pred"].eval(t))
            if n.kind == "project":
                t = ev(n.children[0])
                cols = {c: t[c] for c in n.info["keep"]}
                for name, e in n.info["derived"]:
                    cols[name] = e.eval(t)
                return Table(cols, t.valid)
            if n.kind == "exchange":
                if drain_for is not None and id(n) == drain_for[0]:
                    t = drain_for[1]
                else:
                    t = ev(n.children[0])
                if single:
                    return t
                if n.info["exkind"] == "shuffle":
                    out, d = _exchange_streamed(
                        t, n, spills, reports,
                        do_spill=id(n) in spill_ids,
                        bounded=sp.streamed(n)
                        or (drain_for is not None and id(n) == drain_for[0]),
                    )
                else:
                    cols = {
                        c: mux.broadcast_global(t[c], SHUFFLE_AXIS).reshape(-1)
                        for c in n.schema
                    }
                    v = mux.broadcast_global(t.valid, SHUFFLE_AXIS).reshape(-1)
                    out, d = Table(cols, v), jnp.int32(0)
                drops.append(d)
                return out
            if n.kind == "join":
                b, p = ev(n.children[0]), ev(n.children[1])
                bidx, match = ops.join_pk(
                    b[n.info["build_key"]], b.valid,
                    p[n.info["probe_key"]], p.valid,
                )
                cols = dict(p.columns)
                cols.update(
                    ops.gather_payload(b, bidx, match, list(n.info["payload"]))
                )
                return Table(cols, match)
            raise TypeError(f"unstreamable physical node kind {n.kind!r}")

        ev.agg_dict = _agg_dict
        return ev

    def _merge(b: PNode, st, ev):
        """Fold one step's local partial of breaker ``b`` into its state."""
        t = ev(b.children[0])
        if b.kind == "aggregate":
            out = {}
            for name, e, kind in b.info["aggs"]:
                local = (
                    ops.sum_where(e.eval(t), t.valid)
                    if kind == "sum"
                    else ops.count_where(t.valid)
                )
                out[name] = st[name] + local[None].astype(st[name].dtype)
            return out
        if b.kind == "groupby_dense":
            res = ops.groupby_dense(
                b.info["key_expr"].eval(t),
                b.info["num_groups"],
                ev.agg_dict(t, b.info["aggs"]),
                t.valid,
            )
            return {
                name: st[name] + res[name][None].astype(st[name].dtype)
                for name in st
            }
        if b.kind == "groupby_sorted":
            key = b.info["key"]
            gkeys, gvalid, out = ops.groupby_sorted(
                t[key], t.valid, ev.agg_dict(t, b.info["aggs"])
            )
            C = st["keys"].shape[1]
            # the GroupByCombine path, incrementally: concat state with the
            # morsel partial, re-group by true key, re-SUM every agg (counts
            # are small exact integers in f32)
            ck = jnp.concatenate([st["keys"][0], gkeys])
            cv = jnp.concatenate([st["valid"][0], gvalid])
            caggs = {
                name: (
                    jnp.concatenate(
                        [st["aggs"][name][0], out[name].astype(jnp.float32)]
                    ),
                    "sum",
                )
                for name, _e, _k in b.info["aggs"]
            }
            mkeys, mvalid, mout = ops.groupby_sorted(ck, cv, caggs)
            # compact surviving groups into the fixed-capacity state (merged
            # arrays are at concat length, valid groups sit at group starts)
            rank = jnp.cumsum(mvalid.astype(jnp.int32)) - 1
            keep = mvalid & (rank < C)
            slot = jnp.where(keep, rank, C)
            new_keys = (
                jnp.zeros((C + 1,), jnp.int32)
                .at[slot]
                .set(jnp.where(keep, mkeys, 0))[:C]
            )
            new_valid = jnp.zeros((C + 1,), jnp.bool_).at[slot].set(keep)[:C]
            new_aggs = {
                name: jnp.zeros((C + 1,), jnp.float32)
                .at[slot]
                .set(jnp.where(keep, mout[name], 0.0))[:C][None]
                for name, _e, _k in b.info["aggs"]
            }
            over = st["overflow"][0] + (mvalid & ~keep).sum().astype(jnp.int32)
            return {
                "keys": new_keys[None],
                "valid": new_valid[None],
                "aggs": new_aggs,
                "overflow": over[None],
            }
        if b.kind == "topk":
            k = b.info["k"]
            vals, payload = ops.topk_rows(
                t[b.info["key"]], t.valid, k,
                {c: t[c] for c in b.info["payload"]},
            )
            cvals = jnp.concatenate([st["vals"][0], vals])
            top_vals, idx = jax.lax.top_k(cvals, k)
            new_payload = {
                c: jnp.concatenate(
                    [st["payload"][c][0],
                     payload[c].astype(st["payload"][c].dtype)]
                )[idx][None]
                for c in st["payload"]
            }
            return {"vals": top_vals[None], "payload": new_payload}
        raise NotImplementedError(b.kind)

    # ---- jitted steps ------------------------------------------------------
    check_vma = mux.pack_impl != "pallas" and num_pods == 1
    state_specs = jax.tree.map(lambda _: P(axes), states)
    res_specs = (P(axes),) * (2 * len(resident_prepped))

    def _resident_flats():
        flat = []
        for t in resident_prepped:
            flat.extend((t.columns, t.valid))
        return flat

    def _build_step(breakers: list[PNode], *, with_rows: bool,
                    spill_nodes: list[PNode], drain_node: PNode | None):
        """jit(shard_map) over (states, resident tables[, morsel/drain])."""
        spill_ids = {id(n) for n in spill_nodes}
        if drain_node is not None:
            spill_ids = {id(drain_node)}
        nspill = len(spill_ids)

        def body(st, *flat):
            drops: list[jax.Array] = []
            spills: dict[int, tuple] = {}
            reports: dict[str, jax.Array] = {}
            nres = 2 * len(resident_prepped)
            morsel = None
            drain_for = None
            if drain_node is not None:
                drain_for = (
                    id(drain_node), Table(dict(flat[nres]), flat[nres + 1])
                )
            elif with_rows:
                morsel = Table(dict(flat[nres]), flat[nres + 1])
            tabs = {
                name: Table(dict(flat[2 * i]), flat[2 * i + 1])
                for i, name in enumerate(resident_names)
            }
            tabs[streamed_name] = morsel
            ev = _make_ev(tabs, st, drops, spills, spill_ids, reports,
                          drain_for=drain_for)
            new = dict(st)
            for b in breakers:
                new[_bname(b)] = _merge(b, st[_bname(b)], ev)
            dropped = sum(drops) if drops else jnp.int32(0)
            spill_out = [spills[k] for k in sorted(spills)]
            return new, spill_out, dropped, reports

        extra_specs = ()
        if with_rows or drain_node is not None:
            extra_specs = (P(axes), P(axes))
        fn = shard_map(
            body,
            mesh=mesh,
            in_specs=(state_specs,) + res_specs + extra_specs,
            out_specs=(state_specs, [(P(axes), P(axes))] * nspill, P(), P()),
            check_vma=check_vma,
        )
        return jax.jit(fn)

    def _collect_spill(spill_out, width: int) -> np.ndarray:
        rows_list = []
        for rows, mask in spill_out:
            r = np.asarray(fetch(rows))
            m = np.asarray(fetch(mask))
            rows_list.append(r[m])
        if not rows_list:
            return np.zeros((0, width), np.int32)
        return np.concatenate(rows_list)

    drain_steps: dict = {}
    steps: dict = {}

    def _drain(p: int, node: PNode, breakers, pending: np.ndarray, st,
               drops_h, stats):
        """Re-offer spilled rows until the overflow partition drains dry."""
        schema = list(node.schema)
        key = (p, id(node))
        if key not in drain_steps:
            downstream = [
                b for b in breakers
                if any(id(x) == id(node)
                       for x in sp.shuffles_feeding(b, streamed_only=True))
            ]
            drain_steps[key] = _build_step(
                downstream, with_rows=False, spill_nodes=[], drain_node=node
            )
        step = drain_steps[key]
        rounds = 0
        while len(pending):
            if rounds >= MAX_DRAIN_ROUNDS:
                raise RuntimeError(
                    f"{plan.name}: spill drain did not converge after "
                    f"{rounds} rounds ({len(pending)} rows pending)"
                )
            rounds += 1
            take, pending = pending[:morsel_cap], pending[morsel_cap:]
            dt = from_numpy(
                {c: take[:, i].astype(np.int32) for i, c in enumerate(schema)}
            )
            dt = _prep(pad_to(dt, morsel_cap), num_shards)
            # drain-step reports are re-offers of already-counted rows, so
            # they stay out of the per-edge arrival histograms
            with maybe_span(tracer, f"drain-round:{rounds}", "stream",
                            pending_rows=int(len(take))):
                st, spill_out, dropped, _reports = step(
                    st, *_resident_flats(), dt.columns, dt.valid
                )
                jax.block_until_ready(st)
            drops_h.append(dropped)
            fresh = _collect_spill(spill_out, len(schema))
            if len(fresh):
                pending = (
                    np.concatenate([pending, fresh]) if len(pending) else fresh
                )
        stats["drain_rounds"] += rounds
        return st

    # ---- finalize ----------------------------------------------------------
    def _finalize_root(st):
        root = plan.root
        s = jax.tree.map(lambda x: np.asarray(fetch(x)), st[_bname(root)])
        if root.kind in ("aggregate", "groupby_dense"):
            return {
                name: s[name].sum(axis=0) for name, _e, _k in root.info["aggs"]
            }
        if root.kind == "topk":
            k = root.info["k"]
            vals = s["vals"].reshape(-1)
            order = np.argsort(-vals, kind="stable")[:k]
            out = {c: s["payload"][c].reshape(-1)[order] for c in s["payload"]}
            out["_valid"] = ~np.isneginf(vals[order])
            return out
        raise NotImplementedError(f"streamed root {root.kind}")

    def _check_group_overflow(st):
        for b in sp.breakers:
            if b.kind != "groupby_sorted":
                continue
            over = int(np.asarray(fetch(st[_bname(b)]["overflow"])).sum())
            if over:
                raise RuntimeError(
                    f"{plan.name}: group state overflowed by {over} groups on "
                    f"{_bname(b)}; raise group_state_rows (or the device "
                    "budget)"
                )

    # ---- per-edge arrival accumulation -------------------------------------
    # Shuffle edges whose input varies morsel-to-morsel: their per-step
    # histograms accumulate to ONE traversal of the stream per pass.  A
    # resident-side edge inside a streamed pass instead re-ships its whole
    # (unchanging) table every step — its traversal count is the step
    # count, and the byte model prices one shipment, so the report carries
    # the multiplier explicitly.
    streaming_edge_keys = set()

    def _mark_streaming(n: PNode, seen: set) -> None:
        if id(n) in seen:
            return
        seen.add(id(n))
        for c in n.children:
            _mark_streaming(c, seen)
        if (
            n.kind == "exchange"
            and n.info["exkind"] == "shuffle"
            and sp.streamed(n)
        ):
            streaming_edge_keys.add(report_keys[id(n)])

    _mark_streaming(plan.root, set())

    def _accumulate_reports(edge_hists, reports, p: int) -> None:
        """Fold one step's psum'd histograms into the per-(edge, pass)
        accumulators.  Keyed by pass: a shuffle shared across passes (Q17's
        lineitem shuffle feeds both) re-ships the stream per pass, so each
        traversal is measured against the model separately — summing them
        would read as 2x the modeled single-traversal bytes."""
        for k, h in reports.items():
            arr = np.asarray(fetch(h)).astype(np.int64)
            ek = (k, p)
            hist, n_steps = edge_hists.get(ek, (0, 0))
            edge_hists[ek] = (hist + arr, n_steps + 1)

    def _final_reports(edge_hists) -> dict:
        """Executor-shaped report dict from the accumulators.  Edges seen
        in one pass keep their base key; multi-pass edges split into
        ``<key>@p<pass>`` traversals.  Streamed plans never salt (salted
        plans refuse to stream), so overload is the plain-route arrival
        skew of the whole stream."""
        passes_of: dict[str, list[int]] = {}
        for k, p in edge_hists:
            passes_of.setdefault(k, []).append(p)
        out: dict = {}
        for (k, p), (h, n_steps) in sorted(edge_hists.items()):
            key = f"{k}@p{p}" if len(passes_of[k]) > 1 else k
            total = max(int(h.sum()), 1)
            over = float(h.max()) * num_shards / total
            out[key] = {
                "hist": h,
                "traversals": 1 if k in streaming_edge_keys else n_steps,
                "overload": over,
                "plain_overload": over,
                "salted": False,
            }
        return out

    # ---- the runner --------------------------------------------------------
    def run():
        st = states
        drops_h: list = []
        edge_hists: dict = {}
        stats = {
            "passes": sp.num_passes,
            "morsels": 0,
            "spilled_rows": 0,
            "drain_rounds": 0,
            "prefetch_wait_s": 0.0,
            "prefetch_total_s": 0.0,
        }
        for p, streamed_bs, resident_bs, spill_nodes in pass_plan:
            with maybe_span(tracer, f"pass:{p}", "stream",
                            streamed_breakers=len(streamed_bs),
                            resident_breakers=len(resident_bs)):
                if resident_bs:
                    key = (p, "resident")
                    if key not in steps:
                        steps[key] = _build_step(
                            resident_bs, with_rows=False, spill_nodes=[],
                            drain_node=None,
                        )
                    st, _, dropped, reports = steps[key](
                        st, *_resident_flats()
                    )
                    _accumulate_reports(edge_hists, reports, p)
                    drops_h.append(dropped)
                if not streamed_bs:
                    continue
                key = (p, "streamed")
                if key not in steps:
                    steps[key] = _build_step(
                        streamed_bs, with_rows=True, spill_nodes=spill_nodes,
                        drain_node=None,
                    )
                step = steps[key]
                pending = np.zeros((0, 0), np.int32)
                it = Prefetcher(
                    (_prep(chunk, num_shards) for chunk in src.chunks()),
                    depth=ctx.prefetch_depth,
                )
                t0 = time.perf_counter()
                wait = 0.0
                while True:
                    w0 = time.perf_counter()
                    try:
                        m = next(it)
                    except StopIteration:
                        wait += time.perf_counter() - w0
                        break
                    wait += time.perf_counter() - w0
                    stats["morsels"] += 1
                    with maybe_span(tracer, f"morsel:{stats['morsels']}",
                                    "stream", pass_idx=p):
                        st, spill_out, dropped, reports = step(
                            st, *_resident_flats(), m.columns, m.valid
                        )
                        # block on the fold: otherwise async dispatch returns
                        # instantly and the device compute queued here gets
                        # billed to the *next* ``next(it)`` wait, inverting
                        # the overlap measurement
                        jax.block_until_ready(st)
                    _accumulate_reports(edge_hists, reports, p)
                    drops_h.append(dropped)
                    if spill_nodes:
                        fresh = _collect_spill(
                            spill_out, len(spill_nodes[0].schema)
                        )
                        stats["spilled_rows"] += int(len(fresh))
                        pending = (
                            np.concatenate([pending, fresh])
                            if pending.size
                            else fresh
                        )
                stats["prefetch_wait_s"] += wait
                stats["prefetch_total_s"] += time.perf_counter() - t0
                if spill_nodes and len(pending):
                    st = _drain(
                        p, spill_nodes[0], streamed_bs, pending, st, drops_h,
                        stats,
                    )
        dropped_total = sum(int(fetch(d)) for d in drops_h)
        if dropped_total:
            _raise_on_dropped(plan.name, jnp.int32(dropped_total))
        _check_group_overflow(st)
        total = stats["prefetch_total_s"]
        stats["prefetch_overlap_fraction"] = (
            1.0 - stats["prefetch_wait_s"] / total if total > 0 else 0.0
        )
        return _finalize_root(st), stats, _final_reports(edge_hists)

    from ...obs.model_check import edge_models

    return _StreamedRunner(plan, run, edge_models(plan), tracer)


class _StreamedRunner(RunnerBase):
    """Zero-arg streamed runner.

    Unlike the in-memory :class:`~.executor.CompiledRunner`, streamed
    runners are built per call chain (never memoized), so they may hold the
    compile-time tracer and deposit into it directly.  ``.stats`` keeps the
    historical morsel/pass/spill/prefetch counters of the LAST run; the
    same numbers ride each run's :class:`QueryTrace` as ``counters``.
    """

    def __init__(self, plan, run_fn, models: dict, tracer):
        self._plan = plan
        self._run_fn = run_fn
        self._models = models
        self._tracer = tracer
        self.stats: dict = {}

    def __call__(self):
        from ...obs.model_check import build_query_trace

        t0 = time.perf_counter()
        result, stats, reports = self._run_fn()
        measured = time.perf_counter() - t0
        self.stats = stats
        qt = build_query_trace(
            self._plan, reports, self._models,
            counters={k: float(v) for k, v in stats.items()},
            measured_s=measured,
        )
        self._last_trace = qt
        deposit(self._tracer, qt)
        return result


__all__ = ["compile_plan_streamed", "BREAKER_KINDS"]
