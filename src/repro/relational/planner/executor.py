"""Plan executor: one shard_map over the local operators + the multiplexer.

Compiles a :class:`~repro.relational.planner.physical.PhysicalPlan` into a
single ``shard_map``-ed function: base tables enter as (columns, valid)
pytrees sharded over the query mesh, every ``Exchange`` edge is routed
through ONE per-query :class:`~repro.core.multiplexer.CommMultiplexer`
(knobs from the plan-time tuner, unless the caller pins them — the A/B
benchmarks and equivalence tests do), local operators come from
``relational/operators.py``, and the final combine is a psum (dense
group-bys, scalar aggregates) or a broadcast top-k merge.

The exchange contract is the repo-wide one: capacities are the static
zero-drop bound, the psum'd drop count of every exchange is summed and
checked after execution, and any overflow raises instead of silently
losing rows.

Two-level meshes (``num_pods > 1``): shuffles take
``hash_shuffle_global`` (coarse cross-pod hop + fine in-pod — DCI never
carries fine-grained traffic), broadcast edges obey the tuned
``cross_pod`` strategy (replicate, or hash-reshard by the build key), and
psum/top-k combines cross both axes.  Plans are mesh-shape-agnostic; only
this module touches devices.
"""

from __future__ import annotations

import math
import time
import warnings

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ...compat import fetch, make_mesh, shard_map
from ...core import exchange as core_exchange
from ...core.multiplexer import CommMultiplexer, make_multiplexer
from ...obs.trace import QueryTrace
from .. import operators as ops
from ..table import Table, pad_to, shard_rows
from .physical import PhysicalPlan, PNode

SHUFFLE_AXIS = "q"  # the in-pod (fast network) exchange axis


def _mesh(num_shards: int, num_pods: int = 1):
    """Query mesh: 1-D single-pod, or two-level ``(pod, q)`` with the fine
    shuffle axis strictly in-pod."""
    if num_pods <= 1:
        return make_mesh((num_shards,), (SHUFFLE_AXIS,))
    if num_shards % num_pods:
        raise ValueError(
            f"num_shards={num_shards} does not split across "
            f"num_pods={num_pods}; pick a pod count dividing the shard count"
        )
    return make_mesh((num_pods, num_shards // num_pods), ("pod", SHUFFLE_AXIS))


def _axes(num_pods: int):
    """The mesh axes a table's rows are sharded over (shard_map specs and
    the final cross-unit psum both use this)."""
    return ("pod", SHUFFLE_AXIS) if num_pods > 1 else (SHUFFLE_AXIS,)


def _prep(table: Table, num_shards: int) -> Table:
    cap = math.ceil(table.capacity / num_shards) * num_shards
    return shard_rows(pad_to(table, cap), num_shards)


def _make_mux(
    mesh,
    plan: PhysicalPlan,
    impl: str,
    pack_impl: str | None,
    num_chunks: int | None,
) -> CommMultiplexer:
    """One multiplexer per query.

    ``impl="auto"`` applies the PLAN-TIME tuned knobs (so ``explain()``
    describes exactly what runs), with any explicitly passed knob pinned
    over the tuner's choice.  An explicit ``impl`` uses the caller's knobs
    verbatim with the pre-tuner defaults for anything unset.  The
    ``cross_pod`` strategy is a plan shape (see ``plan_physical``), so the
    mux just records the plan's resolved choice for introspection.
    """
    resolved = plan.tuned.cross_pod or "broadcast"
    if impl == "auto":
        t = plan.tuned
        return make_multiplexer(
            mesh,
            impl=t.impl,
            pack_impl=pack_impl or t.pack_impl,
            pipeline_chunks=num_chunks or t.pipeline_chunks,
            transport_chunks=t.transport_chunks,
            cross_pod=resolved,
        )
    return make_multiplexer(
        mesh, impl=impl, pack_impl=pack_impl or "xla",
        pipeline_chunks=num_chunks or 1, cross_pod=resolved,
    )


def _exchange_by_key(
    mux: CommMultiplexer, tbl: Table, key_name: str, columns: list[str],
    route_keys: jax.Array | None = None,
) -> tuple[Table, jax.Array]:
    """Decoupled exchange: repartition rows by hash(key) over the mesh.

    Routed through :meth:`CommMultiplexer.hash_shuffle_global`: the plain
    in-axis shuffle on single-level meshes, the coarse-cross-pod +
    fine-in-pod exchange on two-level ones.  Capacity per (src, dst)
    message equals the local capacity — the static zero-drop bound.
    ``route_keys`` overrides the ROUTING key only (the salted
    repartitioning: heavy rows route by ``key * num_salts + salt`` while
    the true key column ships unchanged in the row image).
    Returns ``(table, dropped)`` with ``dropped`` psum'd.
    """
    for c in columns:
        if not jnp.issubdtype(tbl[c].dtype, jnp.integer):
            raise TypeError(
                f"exchange of non-integer column {c!r} ({tbl[c].dtype}): "
                "the packed row image is int32 — keep float aggregates "
                "local (group after the exchange, not before)"
            )
    cap = tbl.valid.shape[0]
    rows = jnp.stack([tbl[c].astype(jnp.int32) for c in columns], axis=1)
    keys = tbl[key_name] if route_keys is None else route_keys
    out_rows, out_valid, dropped = mux.hash_shuffle_global(
        keys.astype(jnp.int32), rows, SHUFFLE_AXIS,
        capacity=cap, valid=tbl.valid,
    )
    cols = {c: out_rows[:, i] for i, c in enumerate(columns)}
    return Table(cols, out_valid), dropped


def _shuffle_histogram(
    keys: jax.Array, valid: jax.Array, num_shards: int, axes
) -> tuple[jax.Array, jax.Array]:
    """Global per-destination row histogram of a (routing-key, valid) pair.

    Uses the exact routing rule of the exchange (``fibonacci_hash % N``
    over the GLOBAL shard count — ``hash_shuffle`` single-level,
    ``hash_shuffle_two_level`` two-level), psum'd over the mesh, so the
    result is the true arrival histogram.  Returns ``(hist, overload)``
    with ``overload = max_load / fair_share`` (1.0 = balanced).
    """
    dest = (
        core_exchange.fibonacci_hash(keys.astype(jnp.int32))
        % jnp.uint32(num_shards)
    ).astype(jnp.int32)
    local = jnp.zeros((num_shards,), jnp.int32).at[dest].add(
        valid.astype(jnp.int32)
    )
    hist = lax.psum(local, axes)
    total = jnp.maximum(hist.sum(), 1).astype(jnp.float32)
    overload = hist.max().astype(jnp.float32) * num_shards / total
    return hist, overload


def _global_shard_index(num_shards: int, num_pods: int) -> jax.Array:
    if num_pods > 1:
        return lax.axis_index("pod") * (num_shards // num_pods) + \
            lax.axis_index(SHUFFLE_AXIS)
    return lax.axis_index(SHUFFLE_AXIS)


def _route_and_report(
    tbl: Table, node: PNode, num_shards: int, num_pods: int, axes
) -> tuple[jax.Array | None, dict]:
    """Runtime re-optimization of one shuffle edge (paper §3.1).

    Every shuffle psums its per-shard destination histogram.  On an edge
    the planner marked salted, the MEASURED plain overload is compared to
    the plan's runtime threshold inside the jit: above it, heavy-key rows
    switch to the salted route (``key * num_salts + salt``, salt drawn
    per-row from the row index so one key spreads evenly); below it —
    stats were wrong, data is balanced — the exchange stays a plain hash
    and downstream partial+combine still reduces correctly.  Returns the
    routing-key override (None = plain) and the report entry exposed as
    ``run.exchange_report``.
    """
    info = node.info
    keys = tbl[info["key"]].astype(jnp.int32)
    hist_plain, over_plain = _shuffle_histogram(
        keys, tbl.valid, num_shards, axes
    )
    if not info.get("salted"):
        return None, {
            "hist": hist_plain,
            "overload": over_plain,
            "plain_overload": over_plain,
            "salted": jnp.bool_(False),
        }
    s = int(info["num_salts"])
    heavy = jnp.asarray(info["heavy_keys"], jnp.int32)
    do_salt = over_plain > jnp.float32(info["runtime_threshold"])
    # Per-row salt: hash the global row position (decorrelated across
    # shards by the shard index) so each heavy key's rows spread evenly
    # over all its sub-keys regardless of their layout.
    gidx = _global_shard_index(num_shards, num_pods).astype(jnp.uint32)
    iota = jnp.arange(keys.shape[0], dtype=jnp.uint32)
    rsalt = (
        core_exchange.fibonacci_hash(
            iota + gidx * jnp.uint32(0x9E3779B9)
        ) % jnp.uint32(s)
    ).astype(jnp.int32)
    salted_keys = keys * jnp.int32(s) + rsalt
    route = jnp.where(
        do_salt & jnp.isin(keys, heavy) & tbl.valid, salted_keys, keys
    )
    hist, overload = _shuffle_histogram(route, tbl.valid, num_shards, axes)
    return route, {
        "hist": hist,
        "overload": overload,
        "plain_overload": over_plain,
        "salted": do_salt,
    }


def _broadcast_table(
    mux: CommMultiplexer, tbl: Table, columns: list[str]
) -> tuple[Table, jax.Array]:
    """Deliver a join's (small) build side to where the probe rows are.

    Single-level mesh: ring all-gather.  Two-level mesh: in-pod all-gather,
    then one coarse cross-pod all-gather — the build side crosses DCI once
    per remote pod.  (The alternative ``cross_pod="reshard"`` strategy is a
    *plan shape*, not a transport swap: the planner rebuilds the join as
    co-partitioned, because resharding only the build side would strand it
    away from an un-partitioned probe.)
    """
    cols = {}
    for c in columns:
        cols[c] = mux.broadcast_global(tbl[c], SHUFFLE_AXIS).reshape(-1)
    v = mux.broadcast_global(tbl.valid, SHUFFLE_AXIS).reshape(-1)
    return Table(cols, v), jnp.int32(0)


def _report_keys(root: PNode) -> dict[int, str]:
    """Stable per-edge keys for ``run.exchange_report``.

    The display index (``PNode.idx``) renumbers whenever an unrelated part
    of the plan changes shape — salting an edge inserts combine nodes,
    reshard rebuilds a join — so a report keyed on it is NOT comparable
    across plan variants, cached reloads, or replans of the same query.
    Reports instead key on the shuffle's first-visit ordinal plus its key
    column (``shuffle[l_partkey]#0``): a pure function of the shuffle edges
    themselves, identical for cold, warm, and unpickled plans.
    """
    seen: set[int] = set()
    order: list[PNode] = []

    def walk(n: PNode):
        if id(n) in seen:
            return
        seen.add(id(n))
        if n.kind == "exchange" and n.info["exkind"] == "shuffle":
            order.append(n)
        for c in n.children:
            walk(c)

    walk(root)
    return {
        id(n): f"shuffle[{n.info['key']}]#{j}" for j, n in enumerate(order)
    }


def _raise_on_dropped(query: str, dropped) -> None:
    """Capacity overflow is an error, not silent row loss (paper: the message
    pool is sized so overflow cannot happen; if it does, results are wrong)."""
    d = int(fetch(dropped))
    if d:
        raise RuntimeError(
            f"{query}: exchange dropped {d} rows to capacity overflow — "
            "results would silently lose rows; raise the capacity bound"
        )


def _check_vma(plan: PhysicalPlan, mux: CommMultiplexer) -> bool:
    """Keep the replication checker on only where it has rules: the top-k
    broadcast combine, pallas_call packs, and two-level ppermute hierarchies
    all lack VMA rules (same conditions the hand-written plans used)."""
    return (
        plan.root.kind != "topk"
        and mux.pack_impl != "pallas"
        and plan.num_pods == 1
    )


def _resolve_exec_ctx(plan: PhysicalPlan, ctx, where: str):
    """Resolve the context for this plan.

    The bare two-argument call (``compile_plan(plan, tables)``) is
    first-class API — it resolves to the plan's own mesh shape with default
    knobs.  Anything else must be an :class:`ExecutionContext` whose mesh
    shape matches the plan's (the PR-9 per-knob kwarg shim is gone; old
    spellings raise ``TypeError``).
    """
    from ..context import ExecutionContext, require_context

    if ctx is None:
        ctx = ExecutionContext(plan.num_shards, num_pods=plan.num_pods)
    ctx = require_context(ctx, where=where)
    if (ctx.num_shards, ctx.num_pods) != (plan.num_shards, plan.num_pods):
        raise ValueError(
            f"{where}: context mesh {ctx.num_shards}x{ctx.num_pods} does not "
            f"match the plan's {plan.num_shards}x{plan.num_pods}; re-plan or "
            "fix the context"
        )
    return ctx


def _resident_table(name: str, obj) -> Table:
    """Coerce a Table-or-DataSource to an in-memory Table (the executor's
    unit of work); chunked sources belong to the streamed path."""
    if isinstance(obj, Table):
        return obj
    from ..source import DataSource

    if isinstance(obj, DataSource):
        if obj.is_chunked:
            raise ValueError(
                f"table {name!r} is a chunked DataSource; in-memory "
                "execution cannot hold it — run through run_query (or "
                "stream.compile_plan_streamed) for out-of-core execution"
            )
        return obj.materialize()
    raise TypeError(f"table {name!r}: expected Table or DataSource, got {type(obj)!r}")


def _check_row_budget(plan: PhysicalPlan, tables: dict[str, Table], ctx) -> None:
    """``device_row_budget`` is a hard promise: in-memory execution refuses
    base tables whose per-shard slice exceeds it (chunk them instead)."""
    if ctx.device_row_budget is None:
        return
    for name in plan.scans:
        per_shard = math.ceil(tables[name].capacity / plan.num_shards)
        if per_shard > ctx.device_row_budget:
            raise ValueError(
                f"table {name!r} needs {per_shard} rows/device, over "
                f"device_row_budget={ctx.device_row_budget}; stream it as a "
                "chunked DataSource (run_query with morsel_rows) instead"
            )


def execute_plan(plan: PhysicalPlan, tables: dict, ctx=None):
    """Run a physical plan over real data; returns the fetched result dict.

    ``tables`` maps base-table names to :class:`Table`\\ s (or
    :class:`~repro.relational.source.DataSource`\\ s) whose capacities match
    the catalog the plan was built from.  A chunked source switches to
    morsel-streamed out-of-core execution
    (:func:`~repro.relational.planner.stream.compile_plan_streamed`);
    everything resident runs the one-shard_map in-memory path.  ``ctx`` is
    an :class:`~repro.relational.context.ExecutionContext` (or None for the
    plan's own mesh with default knobs).
    """
    ctx = _resolve_exec_ctx(plan, ctx, where="execute_plan")
    from ..source import DataSource

    if any(
        isinstance(t, DataSource) and t.is_chunked for t in tables.values()
    ):
        from .stream import compile_plan_streamed

        return compile_plan_streamed(plan, tables, ctx)()
    return compile_plan(plan, tables, ctx)()


def compile_plan(
    plan: PhysicalPlan,
    tables: dict,
    ctx=None,
    mux: CommMultiplexer | None = None,
):
    """Build a zero-arg runner for the plan (jit object created once, so
    repeated calls hit the compile cache — what the benchmarks time).

    ``ctx`` is an :class:`~repro.relational.context.ExecutionContext`
    carrying the multiplexer knobs (its mesh shape must match the plan's);
    omitted, the plan's own mesh with default knobs applies.

    ``mux`` injects a SHARED multiplexer instead of building the per-query
    one: the query-serving engine tunes one knob set over every concurrent
    plan's exchanges (:func:`repro.core.autotune.tune_shared_config`) and
    passes it here, so compatible plans running together ride the same
    tuned schedules.  The mux must have been built for this plan's mesh
    shape; its knobs override the plan-time tuner's.

    The returned :class:`CompiledRunner` is callable (run to completion) or
    split-phase: ``run.dispatch()`` launches without a host sync and
    ``run.finalize(out)`` / ``run.collect(out)`` fetch+check — the serving
    engine dispatches a whole admission round before finalizing any of it,
    so concurrent queries overlap on the XLA async runtime.  ``collect``
    additionally returns the run's :class:`~repro.obs.trace.QueryTrace`
    (per-edge measured bytes, destination histograms, salting decisions,
    model predictions) without mutating the runner — the runner is shared
    across concurrent callers, so per-run telemetry never lives on it.
    """
    ctx = _resolve_exec_ctx(plan, ctx, where="compile_plan")
    impl, pack_impl, num_chunks = ctx.impl, ctx.pack_impl, ctx.num_chunks
    num_shards, num_pods = plan.num_shards, plan.num_pods
    tables = {name: _resident_table(name, tables[name]) for name in plan.scans}
    _check_row_budget(plan, tables, ctx)
    for name in plan.scans:
        if tables[name].capacity != plan.catalog[name]:
            raise ValueError(
                f"table {name!r} has capacity {tables[name].capacity} but the "
                f"plan was built for {plan.catalog[name]}; re-plan for the "
                "actual tables"
            )
    mesh = _mesh(num_shards, num_pods)
    axes = _axes(num_pods)
    if mux is None:
        mux = _make_mux(mesh, plan, impl, pack_impl, num_chunks)
    if ctx.trace is not None:
        # compile-time metadata only (the runner itself stays tracer-free:
        # it may be memoized and shared with untraced contexts)
        ctx.trace.add_span(
            f"mux:{plan.name}", cat="compile", **mux.describe()
        )
    prepped = [_prep(tables[name], num_shards) for name in plan.scans]
    single = num_shards == 1 and num_pods == 1
    report_keys = _report_keys(plan.root)

    def body(*flat):
        tabs = {
            name: Table(dict(flat[2 * i]), flat[2 * i + 1])
            for i, name in enumerate(plan.scans)
        }
        drops: list[jax.Array] = []
        reports: dict[str, dict] = {}
        memo: dict[int, object] = {}

        def ev(n: PNode):
            if id(n) in memo:
                return memo[id(n)]
            r = _eval(n)
            memo[id(n)] = r
            return r

        def _agg_dict(t: Table, aggs):
            return {
                name: (e.eval(t), kind) for name, e, kind in aggs
            }

        def _eval(n: PNode):
            if n.kind == "scan":
                src = tabs[n.info["table"]]
                return Table({c: src[c] for c in n.schema}, src.valid)
            if n.kind == "filter":
                t = ev(n.children[0])
                return t.with_mask(n.info["pred"].eval(t))
            if n.kind == "project":
                t = ev(n.children[0])
                cols = {c: t[c] for c in n.info["keep"]}
                for name, e in n.info["derived"]:
                    cols[name] = e.eval(t)
                return Table(cols, t.valid)
            if n.kind == "exchange":
                t = ev(n.children[0])
                if single:  # hash % 1 == 0: the exchange is the identity
                    return t
                if n.info["exkind"] == "shuffle":
                    route, rep = _route_and_report(
                        t, n, num_shards, num_pods, axes
                    )
                    out, d = _exchange_by_key(
                        mux, t, n.info["key"], list(n.schema),
                        route_keys=route,
                    )
                    reports[report_keys[id(n)]] = rep
                else:
                    out, d = _broadcast_table(mux, t, list(n.schema))
                drops.append(d)
                return out
            if n.kind == "join":
                b, p = ev(n.children[0]), ev(n.children[1])
                bidx, match = ops.join_pk(
                    b[n.info["build_key"]], b.valid,
                    p[n.info["probe_key"]], p.valid,
                )
                cols = dict(p.columns)
                cols.update(
                    ops.gather_payload(b, bidx, match, list(n.info["payload"]))
                )
                return Table(cols, match)
            if n.kind == "groupby_sorted":
                t = ev(n.children[0])
                gkeys, gvalid, out = ops.groupby_sorted(
                    t[n.info["key"]], t.valid, _agg_dict(t, n.info["aggs"])
                )
                return Table({n.info["key"]: gkeys, **out}, gvalid)
            if n.kind == "groupby_combine":
                # merge salted partials: every shard holds ALL partial
                # groups (they arrive by broadcast), so re-grouping by the
                # true key and re-summing the partial sums/counts — counts
                # are small exact integers in f32 — yields the exact global
                # aggregate, replicated.
                t = ev(n.children[0])
                aggs = {
                    name: (t[name], "sum") for name, _e, _k in n.info["aggs"]
                }
                gkeys, gvalid, out = ops.groupby_sorted(
                    t[n.info["key"]], t.valid, aggs
                )
                return Table({n.info["key"]: gkeys, **out}, gvalid)
            if n.kind == "groupby_dense":
                t = ev(n.children[0])
                res = ops.groupby_dense(
                    n.info["key_expr"].eval(t),
                    n.info["num_groups"],
                    _agg_dict(t, n.info["aggs"]),
                    t.valid,
                )
                return jax.tree.map(lambda x: lax.psum(x, axes), res)
            if n.kind == "aggregate":
                t = ev(n.children[0])
                out = {}
                for name, e, kind in n.info["aggs"]:
                    local = (
                        ops.sum_where(e.eval(t), t.valid)
                        if kind == "sum"
                        else ops.count_where(t.valid)
                    )
                    out[name] = lax.psum(local, axes)
                return out
            if n.kind == "topk":
                t = ev(n.children[0])
                k = n.info["k"]
                vals, payload = ops.topk_rows(
                    t[n.info["key"]], t.valid, k,
                    {c: t[c] for c in n.info["payload"]},
                )
                # topk_rows pads to k with -inf sort keys; surface validity
                # so fewer-than-k matches don't leak garbage rows
                if single:
                    return {**payload, "_valid": ~jnp.isneginf(vals)}
                all_vals = mux.broadcast_global(vals, SHUFFLE_AXIS).reshape(-1)
                gathered = {
                    c: mux.broadcast_global(col, SHUFFLE_AXIS).reshape(-1)
                    for c, col in payload.items()
                }
                top_vals, idx = lax.top_k(all_vals, k)
                out = {c: col[idx] for c, col in gathered.items()}
                out["_valid"] = ~jnp.isneginf(top_vals)
                return out
            raise TypeError(f"unknown physical node kind {n.kind!r}")

        result = ev(plan.root)
        dropped = sum(drops) if drops else jnp.int32(0)
        return result, dropped, reports

    flat = []
    for t in prepped:
        flat.extend((t.columns, t.valid))
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axes),) * len(flat),
        out_specs=(P(), P(), P()),
        check_vma=_check_vma(plan, mux),
    )
    jfn = jax.jit(fn)
    from ...obs import model_check as _mc

    models = _mc.edge_models(plan)
    return CompiledRunner(plan, jfn, flat, models)


class RunnerBase:
    """Shared surface of the in-memory and streamed runners.

    Per-run telemetry travels through :meth:`collect`'s return value, not
    the runner: compiled runners are memoized and shared across concurrent
    callers, so a mutable report attribute is a data race (two overlapped
    ``finalize`` calls clobber each other's reports).  The deprecated
    ``exchange_report`` property remains as a warned view of the LAST
    finalized run for single-caller code; concurrent callers must use
    ``collect``.
    """

    _last_trace: QueryTrace | None = None

    @property
    def last_trace(self) -> QueryTrace | None:
        """The :class:`QueryTrace` of the most recent finalized run (None
        before the first)."""
        return self._last_trace

    @property
    def exchange_report(self) -> dict:
        """Deprecated last-run report view; racy under concurrency."""
        warnings.warn(
            "run.exchange_report is deprecated: it reflects only the LAST "
            "finalized run, which races under concurrent serving. Use "
            "result, trace = run.collect(run.dispatch()) and "
            "trace.exchange_report() (or trace.edges) instead.",
            DeprecationWarning,
            stacklevel=2,
        )
        qt = self._last_trace
        return qt.exchange_report() if qt is not None else {}


class CompiledRunner(RunnerBase):
    """Zero-arg in-memory runner with split-phase dispatch/collect."""

    def __init__(self, plan: PhysicalPlan, jfn, flat, models: dict):
        self._plan = plan
        self._jfn = jfn
        self._flat = flat
        self._models = models

    def dispatch(self):
        """Launch the jitted program without waiting on the host — results
        are live device values (XLA async dispatch)."""
        return self._jfn(*self._flat)

    def collect(self, out, t_dispatch: float | None = None):
        """Fetch + check a ``dispatch()`` result; returns ``(result,
        QueryTrace)`` without touching runner state (safe under
        concurrency).  ``t_dispatch`` (a ``time.perf_counter()`` reading
        taken just before ``dispatch``) prices the trace's measured wall.
        """
        from ...obs.model_check import build_query_trace

        result, dropped, reports = out
        _raise_on_dropped(self._plan.name, dropped)
        fetched = fetch(result)
        measured = (
            time.perf_counter() - t_dispatch if t_dispatch is not None else None
        )
        qt = build_query_trace(
            self._plan, fetch(reports), self._models, measured_s=measured
        )
        return fetched, qt

    def finalize(self, out, t_dispatch: float | None = None):
        """``collect`` plus last-trace bookkeeping; returns the result."""
        result, qt = self.collect(out, t_dispatch)
        self._last_trace = qt
        return result

    def __call__(self):
        t0 = time.perf_counter()
        return self.finalize(self.dispatch(), t_dispatch=t0)


__all__ = [
    "execute_plan",
    "compile_plan",
    "RunnerBase",
    "CompiledRunner",
    "_exchange_by_key",
    "_broadcast_table",
    "_raise_on_dropped",
    "_report_keys",
    "_mesh",
    "_axes",
    "_prep",
    "_make_mux",
]
