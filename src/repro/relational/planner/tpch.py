"""The TPC-H workload as logical plans (paper Table 2 / Fig 6).

Every query the repo runs — Q1, Q3, Q4, Q6, Q12, Q14, Q17, Q18, Q19 — is
expressed here as a logical operator DAG and nothing else: no shard_map
plumbing, no hand-picked exchanges.  The physical planner decides where
exchanges go (broadcast vs partition per the paper's hybrid threshold,
pre-aggregation for dense group-bys, co-partitioning reuse for chained
joins/group-bys) and the executor runs the result over the multiplexer.

Q17 is the paper's own worked example (their Fig 6): the planner broadcasts
the (30x smaller) part side, places ONE lineitem shuffle that is shared by
the correlated-AVG group-by and the join back, and pre-aggregates nothing —
exactly the paper's hand-derived plan, now derived by cost.  Q1/Q6 plan to
zero exchanges (Fig 11: they ship almost nothing).  Q3's customer side is
*broadcast* under the hybrid threshold (10x ratio on the 8-unit mesh, vs
the two hand-written partition exchanges the old code used) — the planner
finding a better plan than the port it replaced.

Q4, Q12 and Q18 exist ONLY as plans — there is no hand-written distributed
version to fall back to.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

from ...obs.trace import deposit, maybe_span
from ..datagen import (
    LINESTATUS,
    ORDERPRIORITIES,
    RETURNFLAGS,
    SHIPMODES,
    date_to_days,
)
from ..context import ExecutionContext, StatsMode, require_context
from ..source import MorselView, as_source
from . import logical as L
from .executor import compile_plan
from .logical import Aggregate, Filter, GroupBy, HashJoin, Project, Scan, TopK
from .logical import col, lit, where
from .physical import PhysicalPlan, PlannerConfig, plan_physical


@dataclasses.dataclass(frozen=True)
class PlannedQuery:
    """A query as the planner sees it: name, base tables, logical root, and
    a host-side finalize applied to the fetched result."""

    name: str
    tables: tuple[str, ...]
    logical: L.Node
    finalize: Callable | None = None

    def plan(
        self,
        catalog: L.Catalog,
        num_shards: int,
        num_pods: int = 1,
        cfg: PlannerConfig | None = None,
        cross_pod: str | None = None,
        stats: dict | None = None,
        morsel_rows: int | None = None,
    ) -> PhysicalPlan:
        return plan_physical(
            self.logical, catalog, num_shards, num_pods=num_pods, cfg=cfg,
            name=self.name, cross_pod=cross_pod, stats=stats,
            morsel_rows=morsel_rows,
        )


def run_query(pq: PlannedQuery, tables: dict, ctx=None):
    """Plan against the actual source capacities, execute, finalize.

    ``tables`` maps base-table names to :class:`Table`\\ s or
    :class:`~repro.relational.source.DataSource`\\ s.  Execution is
    parameterized by one :class:`~repro.relational.context.ExecutionContext`
    (``ctx``, or None for single-shard defaults).  With ``ctx.trace`` set,
    the run records plan/compile/execute spans and deposits the run's
    :class:`~repro.obs.trace.QueryTrace` (per-edge measured vs modeled
    exchange bytes) into the tracer.

    Out-of-core: a chunked DataSource streams morsel-by-morsel through
    :func:`~repro.relational.planner.stream.compile_plan_streamed`.  With
    ``ctx.morsel_rows`` set and plain in-memory tables, the one table
    larger than ``morsel_rows`` is wrapped in a chunked
    :class:`~repro.relational.source.MorselView` automatically.  The
    planner prices streamed shuffles at one morsel (``morsel_rows``
    reaches :func:`plan_physical`), and the plan-cache key covers it.
    """
    if ctx is None:
        ctx = ExecutionContext()
    ctx = require_context(ctx, where="run_query")
    tracer = ctx.trace
    srcs = {t: as_source(tables[t]) for t in pq.tables}
    if ctx.morsel_rows is not None and not any(
        s.is_chunked for s in srcs.values()
    ):
        big = [t for t in pq.tables if srcs[t].capacity > ctx.morsel_rows]
        if len(big) > 1:
            raise ValueError(
                f"morsel_rows={ctx.morsel_rows} would stream {big}, but "
                "streamed execution supports one chunked relation; chunk "
                "exactly one source (or raise morsel_rows)"
            )
        if big:
            srcs[big[0]] = MorselView(
                srcs[big[0]].materialize(), ctx.morsel_rows
            )
    chunked = [t for t in pq.tables if srcs[t].is_chunked]
    if ctx.stats_mode is StatsMode.COLLECT:
        if chunked:
            raise ValueError(
                "StatsMode.COLLECT samples in-memory tables; streamed "
                "sources plan with STATIC stats or a pre-collected PROFILE"
            )
        stats = ctx.planner_stats(
            {t: srcs[t].materialize() for t in pq.tables}
        )
    else:
        stats = ctx.planner_stats()
    catalog = {t: srcs[t].capacity for t in pq.tables}
    morsel = srcs[chunked[0]].chunk_rows if chunked else None
    with maybe_span(tracer, f"plan:{pq.name}", "plan",
                    num_shards=ctx.num_shards, num_pods=ctx.num_pods,
                    streamed=bool(chunked)):
        phys = pq.plan(
            catalog, ctx.num_shards, num_pods=ctx.num_pods, cfg=ctx.cfg,
            cross_pod=ctx.cross_pod, stats=stats, morsel_rows=morsel,
        )
    if chunked:
        from .stream import compile_plan_streamed

        with maybe_span(tracer, f"compile:{pq.name}", "compile",
                        streamed=True):
            runner = compile_plan_streamed(phys, srcs, ctx)
        with maybe_span(tracer, f"execute:{pq.name}", "execute"):
            raw = runner()  # deposits its own QueryTrace + pass/morsel spans
    else:
        with maybe_span(tracer, f"compile:{pq.name}", "compile",
                        streamed=False):
            runner = compile_plan(phys, srcs, ctx)
        t0 = time.perf_counter()
        with maybe_span(tracer, f"execute:{pq.name}", "execute"):
            raw, qt = runner.collect(runner.dispatch(), t_dispatch=t0)
        deposit(tracer, qt)
    return pq.finalize(raw) if pq.finalize else raw


def explain_query(pq: PlannedQuery, catalog: L.Catalog, ctx=None) -> str:
    """Render the physical plan the context would execute.

    ``StatsMode.COLLECT`` is not explainable without the tables — collect a
    profile first and pass it via ``StatsMode.PROFILE``.
    """
    if ctx is None:
        ctx = ExecutionContext()
    ctx = require_context(ctx, where="explain_query")
    return pq.plan(
        catalog, ctx.num_shards, num_pods=ctx.num_pods, cfg=ctx.cfg,
        cross_pod=ctx.cross_pod, stats=ctx.planner_stats(),
    ).explain()


def tpch_catalog(sf: float) -> dict[str, int]:
    """Base-table capacities at scale factor ``sf`` — straight from
    ``datagen.table_capacity`` (the shared definition the ``gen_*``
    functions size with), so plans built from this catalog are identical to
    plans built from generated tables (golden snapshots use this to plan
    without generating any data)."""
    from ..datagen import table_capacity

    return {
        t: table_capacity(t, sf)
        for t in ("part", "customer", "orders", "lineitem")
    }


# ----------------------------------------------------------------------------
# The money expression both revenue queries share: price * (100 - disc) / 100
# in f32 cents (identical op order to operators.money_times_pct).
# ----------------------------------------------------------------------------

def _disc_price() -> L.Expr:
    return col("l_extendedprice").f32() * (
        (lit(100) - col("l_discount")).f32() / lit(100.0)
    )


def _trim_topk(r: dict) -> dict:
    """Drop the top-k slots that never matched (the executor pads to k and
    marks real rows in ``_valid``)."""
    import numpy as np

    m = np.asarray(r["_valid"]).astype(bool)
    return {k: np.asarray(v)[m] for k, v in r.items() if k != "_valid"}


# ----------------------------------------------------------------------------
# Q1: pricing summary report — pure pre-aggregation, zero exchanges.
# ----------------------------------------------------------------------------

def q1(delta_days: int = 90) -> PlannedQuery:
    cutoff = date_to_days(1998, 12, 1) - delta_days
    li = Scan(
        "lineitem",
        ("l_quantity", "l_extendedprice", "l_discount", "l_tax",
         "l_returnflag", "l_linestatus", "l_shipdate"),
    )
    f = Filter(li, col("l_shipdate") <= lit(cutoff))
    price = col("l_extendedprice").f32()
    disc = col("l_discount").f32() / lit(100.0)
    tax = col("l_tax").f32() / lit(100.0)
    disc_price = price * (lit(1.0) - disc)
    charge = disc_price * (lit(1.0) + tax)
    gid = col("l_returnflag") * lit(len(LINESTATUS)) + col("l_linestatus")
    g = GroupBy(
        f,
        aggs=(
            ("sum_qty", col("l_quantity"), "sum"),
            ("sum_base_price", price, "sum"),
            ("sum_disc_price", disc_price, "sum"),
            ("sum_charge", charge, "sum"),
            ("sum_disc", disc, "sum"),
            ("count_order", lit(1), "count"),
        ),
        key_expr=gid,
        num_groups=len(RETURNFLAGS) * len(LINESTATUS),
    )
    from .. import queries as Q

    return PlannedQuery("q1", ("lineitem",), g, finalize=Q.q1_finalize)


# ----------------------------------------------------------------------------
# Q6: forecasting revenue change — filter + scalar aggregate, zero exchanges.
# ----------------------------------------------------------------------------

def q6(year: int = 1994) -> PlannedQuery:
    lo, hi = date_to_days(year, 1, 1), date_to_days(year + 1, 1, 1)
    li = Scan(
        "lineitem",
        ("l_quantity", "l_extendedprice", "l_discount", "l_shipdate"),
    )
    d = col("l_discount")
    f = Filter(
        li,
        (col("l_shipdate") >= lit(lo)) & (col("l_shipdate") < lit(hi))
        & (d >= lit(5)) & (d <= lit(7)) & (col("l_quantity") < lit(24)),
    )
    revenue = col("l_extendedprice").f32() * (d.f32() / lit(100.0))
    agg = Aggregate(f, (("revenue", revenue, "sum"),))
    return PlannedQuery(
        "q6", ("lineitem",), agg, finalize=lambda r: r["revenue"]
    )


# ----------------------------------------------------------------------------
# Q17: small-quantity-order revenue — the paper's Fig 6 worked example.
# One broadcast (filtered part), ONE lineitem shuffle shared by the
# correlated-AVG group-by and the join back.
# ----------------------------------------------------------------------------

def q17(brand: int = 12, container: int = 2) -> PlannedQuery:
    li = Scan("lineitem", ("l_partkey", "l_quantity", "l_extendedprice"))
    pt = Scan("part", ("p_partkey", "p_brand", "p_container"))
    fpt = Filter(
        pt,
        col("p_brand").eq(lit(brand)) & col("p_container").eq(lit(container)),
    )
    semi = HashJoin(
        build=fpt, probe=li, build_key="p_partkey", probe_key="l_partkey"
    )
    g = GroupBy(
        semi,
        key="l_partkey",
        aggs=(
            ("sum_qty", col("l_quantity"), "sum"),
            ("cnt", lit(1), "count"),
        ),
    )
    avg = Project(
        g,
        keep=("l_partkey",),
        derived=(
            (
                "avg_qty",
                col("sum_qty")
                / where(col("cnt") < lit(1), lit(1.0), col("cnt").f32()),
            ),
        ),
    )
    back = HashJoin(
        build=avg, probe=semi, build_key="l_partkey", probe_key="l_partkey",
        payload=("avg_qty",),
    )
    small = Filter(back, col("l_quantity").f32() < lit(0.2) * col("avg_qty"))
    agg = Aggregate(small, (("revenue", col("l_extendedprice").f32(), "sum"),))
    return PlannedQuery(
        "q17", ("lineitem", "part"), agg,
        finalize=lambda r: r["revenue"] / 7.0,
    )


# ----------------------------------------------------------------------------
# Q3: shipping priority — 3-table join + distributed top-10.  The hybrid
# threshold broadcasts the customer side (10x smaller than orders).
# ----------------------------------------------------------------------------

def q3(segment: int = 1, cutoff: int | None = None) -> PlannedQuery:
    cutoff = date_to_days(1995, 3, 15) if cutoff is None else cutoff
    cu = Scan("customer", ("c_custkey", "c_mktsegment"))
    od = Scan("orders", ("o_orderkey", "o_custkey", "o_orderdate"))
    li = Scan(
        "lineitem",
        ("l_orderkey", "l_shipdate", "l_extendedprice", "l_discount"),
    )
    fcu = Filter(cu, col("c_mktsegment").eq(lit(segment)))
    fod = Filter(od, col("o_orderdate") < lit(cutoff))
    j1 = HashJoin(
        build=fcu, probe=fod, build_key="c_custkey", probe_key="o_custkey"
    )
    keys = Project(j1, keep=("o_orderkey",))
    fli = Filter(li, col("l_shipdate") > lit(cutoff))
    j2 = HashJoin(
        build=keys, probe=fli, build_key="o_orderkey", probe_key="l_orderkey"
    )
    g = GroupBy(j2, key="l_orderkey", aggs=(("revenue", _disc_price(), "sum"),))
    named = Project(
        g, keep=("revenue",), derived=(("o_orderkey", col("l_orderkey")),)
    )
    top = TopK(named, key="revenue", k=10, payload=("o_orderkey", "revenue"))
    return PlannedQuery(
        "q3", ("customer", "orders", "lineitem"), top, finalize=_trim_topk
    )


# ----------------------------------------------------------------------------
# Q14: promotion effect — broadcast part, conditional revenue split.
# ----------------------------------------------------------------------------

def q14(year: int = 1995, month: int = 9, promo_brands: int = 5) -> PlannedQuery:
    lo = date_to_days(year, month, 1)
    hi = lo + 30
    li = Scan(
        "lineitem",
        ("l_partkey", "l_extendedprice", "l_discount", "l_shipdate"),
    )
    pt = Scan("part", ("p_partkey", "p_brand"))
    fli = Filter(
        li, (col("l_shipdate") >= lit(lo)) & (col("l_shipdate") < lit(hi))
    )
    j = HashJoin(
        build=pt, probe=fli, build_key="p_partkey", probe_key="l_partkey",
        payload=("p_brand",),
    )
    dp = _disc_price()
    agg = Aggregate(
        j,
        (
            ("promo", where(col("p_brand") < lit(promo_brands), dp, lit(0.0)),
             "sum"),
            ("total", dp, "sum"),
        ),
    )
    from .. import queries as Q

    return PlannedQuery(
        "q14", ("lineitem", "part"), agg,
        finalize=lambda r: Q.q14_finalize(r["promo"], r["total"]),
    )


# ----------------------------------------------------------------------------
# Q19: discounted revenue — broadcast part, disjunction of range predicates.
# ----------------------------------------------------------------------------

def q19(terms=None) -> PlannedQuery:
    from .. import queries as Q

    terms = terms or Q.Q19_TERMS
    li = Scan(
        "lineitem",
        ("l_partkey", "l_quantity", "l_extendedprice", "l_discount"),
    )
    pt = Scan("part", ("p_partkey", "p_brand", "p_container", "p_size"))
    j = HashJoin(
        build=pt, probe=li, build_key="p_partkey", probe_key="l_partkey",
        payload=("p_brand", "p_container", "p_size"),
    )
    keep = None
    for (b, c_lo, c_hi, q_lo, q_hi, s_hi) in terms:
        term = (
            col("p_brand").eq(lit(b))
            & (col("p_container") >= lit(c_lo))
            & (col("p_container") < lit(c_hi))
            & (col("l_quantity") >= lit(q_lo))
            & (col("l_quantity") <= lit(q_hi))
            & (col("p_size") >= lit(1))
            & (col("p_size") <= lit(s_hi))
        )
        keep = term if keep is None else keep | term
    f = Filter(j, keep)
    agg = Aggregate(f, (("revenue", _disc_price(), "sum"),))
    return PlannedQuery(
        "q19", ("lineitem", "part"), agg, finalize=lambda r: r["revenue"]
    )


# ----------------------------------------------------------------------------
# Q4: order priority checking — EXISTS as distinct-keys build side, dense
# priority group-by.  Plan-only (no hand-written counterpart ever existed).
# ----------------------------------------------------------------------------

def q4(year: int = 1993, month: int = 7) -> PlannedQuery:
    lo = date_to_days(year, month, 1)
    m2, y2 = (month + 3, year) if month + 3 <= 12 else (month - 9, year + 1)
    hi = date_to_days(y2, m2, 1)
    li = Scan("lineitem", ("l_orderkey", "l_commitdate", "l_receiptdate"))
    fli = Filter(li, col("l_commitdate") < col("l_receiptdate"))
    pli = Project(fli, keep=("l_orderkey",))
    distinct = GroupBy(
        pli, key="l_orderkey", aggs=(("n_late", lit(1), "count"),)
    )
    od = Scan("orders", ("o_orderkey", "o_orderdate", "o_orderpriority"))
    fod = Filter(
        od, (col("o_orderdate") >= lit(lo)) & (col("o_orderdate") < lit(hi))
    )
    pod = Project(fod, keep=("o_orderkey", "o_orderpriority"))
    j = HashJoin(
        build=distinct, probe=pod, build_key="l_orderkey",
        probe_key="o_orderkey",
    )
    g = GroupBy(
        j,
        key_expr=col("o_orderpriority"),
        num_groups=len(ORDERPRIORITIES),
        aggs=(("order_count", lit(1), "count"),),
    )
    return PlannedQuery("q4", ("lineitem", "orders"), g)


# ----------------------------------------------------------------------------
# Q12: shipmode priority split — co-partition orders x lineitem, dense
# shipmode group-by with conditional counts.  Plan-only.
# ----------------------------------------------------------------------------

def q12(year: int = 1994, modes: tuple[int, int] = (5, 3)) -> PlannedQuery:
    # default modes: MAIL (5) and SHIP (3) in datagen.SHIPMODES order
    lo, hi = date_to_days(year, 1, 1), date_to_days(year + 1, 1, 1)
    li = Scan(
        "lineitem",
        ("l_orderkey", "l_shipmode", "l_shipdate", "l_commitdate",
         "l_receiptdate"),
    )
    in_modes = None
    for m in modes:
        e = col("l_shipmode").eq(lit(m))
        in_modes = e if in_modes is None else in_modes | e
    fli = Filter(
        li,
        in_modes
        & (col("l_commitdate") < col("l_receiptdate"))
        & (col("l_shipdate") < col("l_commitdate"))
        & (col("l_receiptdate") >= lit(lo))
        & (col("l_receiptdate") < lit(hi)),
    )
    pli = Project(fli, keep=("l_orderkey", "l_shipmode"))
    od = Scan("orders", ("o_orderkey", "o_orderpriority"))
    j = HashJoin(
        build=od, probe=pli, build_key="o_orderkey", probe_key="l_orderkey",
        payload=("o_orderpriority",),
    )
    g = GroupBy(
        j,
        key_expr=col("l_shipmode"),
        num_groups=len(SHIPMODES),
        aggs=(
            ("high_line_count",
             where(col("o_orderpriority") < lit(2), lit(1), lit(0)), "sum"),
            ("low_line_count",
             where(col("o_orderpriority") >= lit(2), lit(1), lit(0)), "sum"),
        ),
    )
    return PlannedQuery("q12", ("lineitem", "orders"), g)


# ----------------------------------------------------------------------------
# Q18: large-volume customers — HAVING over a sorted group-by, two joins
# (partitioned orders, broadcast customer), top-100.  Plan-only.
# ----------------------------------------------------------------------------

def q18(threshold: int = 300, k: int = 100) -> PlannedQuery:
    # threshold 300 keeps the qualifying set well under k at the SFs the
    # tests/benchmarks run (28/38/92 orders at SF 0.005/0.01/0.02), so the
    # top-k boundary never has to tie-break between equal sums
    li = Scan("lineitem", ("l_orderkey", "l_quantity"))
    g = GroupBy(li, key="l_orderkey", aggs=(("sum_qty", col("l_quantity"), "sum"),))
    big = Filter(g, col("sum_qty") > lit(float(threshold)))
    od = Scan(
        "orders", ("o_orderkey", "o_custkey", "o_orderdate", "o_totalprice")
    )
    j1 = HashJoin(
        build=big, probe=od, build_key="l_orderkey", probe_key="o_orderkey",
        payload=("sum_qty",),
    )
    cu = Scan("customer", ("c_custkey", "c_mktsegment"))
    j2 = HashJoin(
        build=cu, probe=j1, build_key="c_custkey", probe_key="o_custkey",
        payload=("c_mktsegment",),
    )
    top = TopK(
        j2, key="o_totalprice", k=k,
        payload=("o_orderkey", "o_custkey", "c_mktsegment", "o_orderdate",
                 "o_totalprice", "sum_qty"),
    )
    return PlannedQuery(
        "q18", ("lineitem", "orders", "customer"), top, finalize=_trim_topk
    )


ALL_QUERIES: dict[str, Callable[..., PlannedQuery]] = {
    "q1": q1,
    "q3": q3,
    "q4": q4,
    "q6": q6,
    "q12": q12,
    "q14": q14,
    "q17": q17,
    "q18": q18,
    "q19": q19,
}


__all__ = [
    "PlannedQuery",
    "run_query",
    "explain_query",
    "tpch_catalog",
    "ALL_QUERIES",
    "q1",
    "q3",
    "q4",
    "q6",
    "q12",
    "q14",
    "q17",
    "q18",
    "q19",
]
