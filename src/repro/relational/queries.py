"""TPC-H query plans: local (single device) versions.

These are the per-device pipelines; ``distributed.py`` wraps them with the
exchange layer into multi-device plans (paper Fig 6b/6c).  Q17 is the paper's
own worked example (their Figure 6); Q1/Q6 are the no-network queries the
paper calls out in Fig 11; Q3 exercises the multi-join shuffle path.

All money is int32 cents, aggregated in f32 (see operators.sum_where).
"""

from __future__ import annotations

import jax.numpy as jnp

from . import operators as ops
from .datagen import LINESTATUS, RETURNFLAGS, date_to_days
from .table import Table


# ----------------------------------------------------------------------------
# Q1: pricing summary report (pure aggregation, 6 groups).
# ----------------------------------------------------------------------------

def q1_local(lineitem: Table, delta_days: int = 90) -> dict[str, jnp.ndarray]:
    """Per-device partial aggregates; combine with psum then finalize."""
    cutoff = date_to_days(1998, 12, 1) - delta_days
    mask = lineitem.valid & (lineitem["l_shipdate"] <= cutoff)
    gid = lineitem["l_returnflag"] * len(LINESTATUS) + lineitem["l_linestatus"]
    price = lineitem["l_extendedprice"].astype(jnp.float32)
    disc = lineitem["l_discount"].astype(jnp.float32) / 100.0
    tax = lineitem["l_tax"].astype(jnp.float32) / 100.0
    disc_price = price * (1.0 - disc)
    charge = disc_price * (1.0 + tax)
    num_groups = len(RETURNFLAGS) * len(LINESTATUS)
    return ops.groupby_dense(
        gid,
        num_groups,
        {
            "sum_qty": (lineitem["l_quantity"], "sum"),
            "sum_base_price": (price, "sum"),
            "sum_disc_price": (disc_price, "sum"),
            "sum_charge": (charge, "sum"),
            "sum_disc": (disc, "sum"),
            "count_order": (gid, "count"),
        },
        mask,
    )


def q1_finalize(partials: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
    cnt = jnp.maximum(partials["count_order"].astype(jnp.float32), 1.0)
    return {
        **partials,
        "avg_qty": partials["sum_qty"] / cnt,
        "avg_price": partials["sum_base_price"] / cnt,
        "avg_disc": partials["sum_disc"] / cnt,
    }


# ----------------------------------------------------------------------------
# Q6: forecasting revenue change (filter + scalar aggregate).
# ----------------------------------------------------------------------------

def q6_local(lineitem: Table, year: int = 1994) -> jnp.ndarray:
    lo = date_to_days(year, 1, 1)
    hi = date_to_days(year + 1, 1, 1)
    d = lineitem["l_discount"]
    mask = (
        lineitem.valid
        & (lineitem["l_shipdate"] >= lo)
        & (lineitem["l_shipdate"] < hi)
        & (d >= 5)
        & (d <= 7)
        & (lineitem["l_quantity"] < 24)
    )
    revenue = ops.money_times_pct(lineitem["l_extendedprice"], d)
    return ops.sum_where(revenue, mask)


# ----------------------------------------------------------------------------
# Q17: small-quantity-order revenue — the paper's Figure 6 example.
#   avg yearly revenue lost if small orders of specific parts aren't filled:
#   SELECT sum(l_extendedprice)/7 FROM lineitem, part
#   WHERE p_partkey = l_partkey AND p_brand = X AND p_container = Y
#     AND l_quantity < 0.2 * (SELECT avg(l_quantity) FROM lineitem
#                             WHERE l_partkey = p_partkey)
# ----------------------------------------------------------------------------

def q17_part_filter(part: Table, brand: int, container: int) -> Table:
    return part.with_mask(
        (part["p_brand"] == brand) & (part["p_container"] == container)
    )


def q17_local(lineitem: Table, part: Table, brand: int = 12, container: int = 2):
    """Single-device Q17: semi-join + correlated AVG + anti-filter + sum."""
    fpart = q17_part_filter(part, brand, container)
    bidx, match = ops.join_pk(
        fpart["p_partkey"], fpart.valid, lineitem["l_partkey"], lineitem.valid
    )
    # Correlated subquery: avg(l_quantity) per partkey over ALL lineitems
    # (matching parts only — others can't pass the join anyway).
    gkeys, gvalid, aggs = ops.groupby_sorted(
        lineitem["l_partkey"],
        lineitem.valid & match,
        {"sum_qty": (lineitem["l_quantity"], "sum"), "cnt": (lineitem["l_quantity"], "count")},
    )
    avg_qty = aggs["sum_qty"] / jnp.maximum(aggs["cnt"].astype(jnp.float32), 1.0)
    # Join the per-partkey avg back to each lineitem row.
    aidx, amatch = ops.join_pk(gkeys, gvalid, lineitem["l_partkey"], match)
    row_avg = avg_qty[aidx]
    keep = amatch & (lineitem["l_quantity"].astype(jnp.float32) < 0.2 * row_avg)
    total = ops.sum_where(lineitem["l_extendedprice"], keep)
    return total / 7.0


# ----------------------------------------------------------------------------
# Q3: shipping priority (customer x orders x lineitem, top-10 by revenue).
# ----------------------------------------------------------------------------

def q3_local(
    customer: Table,
    orders: Table,
    lineitem: Table,
    segment: int = 1,  # BUILDING
    cutoff: int | None = None,
):
    cutoff = date_to_days(1995, 3, 15) if cutoff is None else cutoff
    fcust = customer.with_mask(customer["c_mktsegment"] == segment)
    ford = orders.with_mask(orders["o_orderdate"] < cutoff)
    # orders ⋈ customer on custkey (customer is PK side)
    cidx, cmatch = ops.join_pk(
        fcust["c_custkey"], fcust.valid, ford["o_custkey"], ford.valid
    )
    ord_keep = cmatch
    # lineitem ⋈ orders on orderkey (orders is PK side)
    flin = lineitem.with_mask(lineitem.valid & (lineitem["l_shipdate"] > cutoff))
    oidx, omatch = ops.join_pk(
        ford["o_orderkey"], ord_keep, flin["l_orderkey"], flin.valid
    )
    revenue = ops.money_times_pct(
        flin["l_extendedprice"], 100 - flin["l_discount"]
    )
    # Group by orderkey; carry orderdate/shippriority through segment_max.
    gkeys, gvalid, aggs = ops.groupby_sorted(
        flin["l_orderkey"], omatch, {"revenue": (revenue, "sum")}
    )
    vals, payload = ops.topk_rows(
        aggs["revenue"], gvalid, 10, {"o_orderkey": gkeys, "revenue": aggs["revenue"]}
    )
    return payload


# ----------------------------------------------------------------------------
# Q14: promotion effect (lineitem x part, one month, conditional revenue).
# "PROMO" parts are brand-ids < promo_brands (datagen has no p_type column).
# ----------------------------------------------------------------------------

def q14_local(lineitem: Table, part: Table, year: int = 1995, month: int = 9,
              promo_brands: int = 5):
    lo = date_to_days(year, month, 1)
    hi = lo + 30
    mask = lineitem.valid & (lineitem["l_shipdate"] >= lo) & (lineitem["l_shipdate"] < hi)
    pidx, match = ops.join_pk(
        part["p_partkey"], part.valid, lineitem["l_partkey"], mask
    )
    disc_price = ops.money_times_pct(
        lineitem["l_extendedprice"], 100 - lineitem["l_discount"]
    )
    promo = match & (part["p_brand"][pidx] < promo_brands)
    promo_rev = ops.sum_where(disc_price, promo)
    total_rev = ops.sum_where(disc_price, match)
    return promo_rev, total_rev


def q14_finalize(promo_rev, total_rev):
    return 100.0 * promo_rev / jnp.maximum(total_rev, 1e-9)


# ----------------------------------------------------------------------------
# Q19: discounted revenue, disjunction of (brand, container-range, qty, size).
# ----------------------------------------------------------------------------

Q19_TERMS = (
    # (brand, container_lo, container_hi, qty_lo, qty_hi, size_hi)
    (12, 0, 10, 1, 11, 5),
    (14, 10, 25, 10, 20, 10),
    (15, 25, 40, 20, 30, 15),
)


def q19_local(lineitem: Table, part: Table, terms=Q19_TERMS):
    pidx, match = ops.join_pk(
        part["p_partkey"], part.valid, lineitem["l_partkey"], lineitem.valid
    )
    brand = part["p_brand"][pidx]
    container = part["p_container"][pidx]
    size = part["p_size"][pidx]
    qty = lineitem["l_quantity"]
    keep = jnp.zeros_like(match)
    for (b, c_lo, c_hi, q_lo, q_hi, s_hi) in terms:
        keep = keep | (
            (brand == b)
            & (container >= c_lo) & (container < c_hi)
            & (qty >= q_lo) & (qty <= q_hi)
            & (size >= 1) & (size <= s_hi)
        )
    keep = keep & match
    disc_price = ops.money_times_pct(
        lineitem["l_extendedprice"], 100 - lineitem["l_discount"]
    )
    return ops.sum_where(disc_price, keep)


__all__ = [
    "q1_local",
    "q1_finalize",
    "q6_local",
    "q17_part_filter",
    "q17_local",
    "q3_local",
    "q14_local",
    "q14_finalize",
    "q19_local",
    "Q19_TERMS",
]
