"""One execution-configuration object for the whole query surface.

Historically every entry point (``run_query``, ``explain_query``,
``compile_plan``, the ``q*_distributed`` wrappers, ``QueryServeEngine``)
hand-threaded the same tuple of knobs — ``(num_shards, num_pods, impl,
pack_impl, num_chunks, cross_pod, cfg, stats)`` — through its signature.
``ExecutionContext`` replaces that sprawl: mesh shape, multiplexer knobs,
planner config, stats mode, the out-of-core morsel/spill knobs, and the
observability hook live in one frozen, hashable dataclass that every entry
point accepts.

The PR-9 deprecated per-knob kwarg shim (``resolve_context`` /
``reset_deprecation_warning``) is gone after its one-release grace: the
old spellings now raise ``TypeError`` at the entry points instead of
warning.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import TYPE_CHECKING, Any, Mapping

if TYPE_CHECKING:  # annotation-only: keeps this module import-cycle-free
    from repro.obs.trace import Tracer
    from repro.relational.planner.physical import PlannerConfig

__all__ = [
    "StatsMode",
    "ExecutionContext",
    "require_context",
]


class StatsMode(enum.Enum):
    """How the planner obtains table statistics.

    Replaces the old ``stats="collect"`` magic string (which punned a str
    sentinel and a profile dict through one parameter).
    """

    #: Plan from catalog capacities only (no sampling).
    STATIC = "static"
    #: Sample the input tables at plan time (``relational.stats.collect_stats``).
    COLLECT = "collect"
    #: Use the pre-collected profile in ``ExecutionContext.stats_profile``.
    PROFILE = "profile"


@dataclasses.dataclass(frozen=True)
class ExecutionContext:
    """Frozen bundle of everything that parameterizes query execution.

    Hashable (usable as a cache key); ``stats_profile`` and ``trace`` are
    excluded from equality/hash — profile dicts and tracers are payload,
    not configuration.  In particular a traced and an untraced context
    compare (and hash) EQUAL, so attaching a tracer can never invalidate a
    plan-cache entry or an executor memo: tracing changes what gets
    written down, never what runs.
    """

    # --- mesh shape -------------------------------------------------------
    num_shards: int = 1
    num_pods: int = 1
    # --- multiplexer knobs (see core.multiplexer.make_multiplexer) --------
    impl: str = "auto"
    pack_impl: str | None = None
    num_chunks: int | None = None
    cross_pod: str | None = None
    # --- planner ----------------------------------------------------------
    cfg: PlannerConfig | None = None
    stats_mode: StatsMode = StatsMode.STATIC
    stats_profile: Mapping[str, Any] | None = dataclasses.field(
        default=None, compare=False
    )
    # --- out-of-core morsel streaming ------------------------------------
    #: Global rows per morsel.  On plain in-memory tables this wraps any
    #: table larger than ``morsel_rows`` in a chunked MorselView; chunked
    #: DataSources stream regardless.  None = fully in-memory execution.
    morsel_rows: int | None = None
    #: Hard per-device row budget.  In-memory execution refuses tables whose
    #: per-shard slice exceeds it; streamed execution bounds morsels and
    #: resident state by it.  None = unbounded.
    device_row_budget: int | None = None
    #: Per-(src,dst) message capacity for streamed exchanges.  None sizes
    #: messages for structural zero drop; smaller values force overflow
    #: (spill when ``spill=True``, error otherwise).
    exchange_rows: int | None = None
    #: Route exchange overflow to a host-memory overflow partition and
    #: re-shuffle it in drain passes instead of raising.
    spill: bool = False
    #: Per-shard capacity of streamed group-by state (distinct groups per
    #: shard).  None = min(plan capacity, device_row_budget).
    group_state_rows: int | None = None
    #: Depth of the host→device prefetch queue for morsel streaming.
    prefetch_depth: int = 2
    # --- observability ----------------------------------------------------
    #: A :class:`repro.obs.trace.Tracer` to record spans, counters and
    #: per-run :class:`~repro.obs.trace.QueryTrace`\ s into.  Excluded from
    #: equality/hash (see class docstring): traced and untraced contexts
    #: share plan-cache entries and memoized executors, and device-side
    #: counters are always on — None just means nobody writes them down.
    trace: "Tracer | None" = dataclasses.field(
        default=None, compare=False, repr=False
    )

    def __post_init__(self) -> None:
        if self.num_shards < 1 or self.num_pods < 1:
            raise ValueError("num_shards and num_pods must be >= 1")
        if self.num_shards % self.num_pods:
            raise ValueError(
                f"num_shards={self.num_shards} not divisible by num_pods={self.num_pods}"
            )
        if not isinstance(self.stats_mode, StatsMode):
            raise TypeError(
                f"stats_mode must be a StatsMode, got {self.stats_mode!r}; "
                'the old stats="collect" magic string was removed with the '
                "per-knob kwargs"
            )
        if self.stats_mode is StatsMode.PROFILE and self.stats_profile is None:
            raise ValueError("StatsMode.PROFILE requires stats_profile")
        if self.stats_profile is not None and self.stats_mode is not StatsMode.PROFILE:
            raise ValueError("stats_profile is only meaningful with StatsMode.PROFILE")

    # -- derived helpers ---------------------------------------------------

    def planner_stats(self, tables: Mapping[str, Any] | None = None):
        """Resolve the ``stats`` argument for ``plan_physical``.

        ``tables`` (name → Table) is required for COLLECT mode; pass the
        query's input tables.
        """
        if self.stats_mode is StatsMode.STATIC:
            return None
        if self.stats_mode is StatsMode.PROFILE:
            return dict(self.stats_profile)
        if tables is None:
            raise ValueError("StatsMode.COLLECT needs the input tables to sample")
        from repro.relational import stats as rstats

        return rstats.collect_stats(dict(tables))

    def with_(self, **changes) -> "ExecutionContext":
        """`dataclasses.replace` spelled as a method."""
        return dataclasses.replace(self, **changes)


def require_context(ctx: Any, *, where: str) -> ExecutionContext:
    """Entry-point guard now that the kwarg shim is gone: anything that is
    not an :class:`ExecutionContext` gets a pointed TypeError naming the
    migration, instead of a confusing attribute error downstream."""
    if isinstance(ctx, ExecutionContext):
        return ctx
    raise TypeError(
        f"{where}: expected an ExecutionContext, got {type(ctx).__name__!r}. "
        "The deprecated per-knob kwargs (num_shards/impl/pack_impl/"
        "num_chunks/num_pods/cross_pod/cfg/stats) were removed; build an "
        "ExecutionContext (repro.relational.context) instead."
    )
