"""One execution-configuration object for the whole query surface.

Historically every entry point (``run_query``, ``explain_query``,
``compile_plan``, the ``q*_distributed`` wrappers, ``QueryServeEngine``)
hand-threaded the same tuple of knobs — ``(num_shards, num_pods, impl,
pack_impl, num_chunks, cross_pod, cfg, stats)`` — through its signature.
``ExecutionContext`` replaces that sprawl: mesh shape, multiplexer knobs,
planner config, stats mode, and the out-of-core morsel/spill knobs live in
one frozen, hashable dataclass that every entry point accepts.

The old kwarg spellings keep working for one release through a single
``DeprecationWarning`` shim (:func:`resolve_context`); in-repo code is fully
migrated and the test suite runs with ``error::DeprecationWarning`` so only
the shim itself may emit.
"""

from __future__ import annotations

import dataclasses
import enum
import warnings
from typing import TYPE_CHECKING, Any, Mapping

if TYPE_CHECKING:  # annotation-only: keeps this module import-cycle-free
    from repro.relational.planner.physical import PlannerConfig

__all__ = [
    "StatsMode",
    "ExecutionContext",
    "resolve_context",
    "reset_deprecation_warning",
    "LEGACY_KWARGS",
]


class StatsMode(enum.Enum):
    """How the planner obtains table statistics.

    Replaces the old ``stats="collect"`` magic string (which punned a str
    sentinel and a profile dict through one parameter).
    """

    #: Plan from catalog capacities only (no sampling).
    STATIC = "static"
    #: Sample the input tables at plan time (``relational.stats.collect_stats``).
    COLLECT = "collect"
    #: Use the pre-collected profile in ``ExecutionContext.stats_profile``.
    PROFILE = "profile"


@dataclasses.dataclass(frozen=True)
class ExecutionContext:
    """Frozen bundle of everything that parameterizes query execution.

    Hashable (usable as a cache key); ``stats_profile`` is excluded from
    equality/hash because profile dicts are unhashable payload, not
    configuration — two contexts in PROFILE mode compare equal iff their
    other knobs match.
    """

    # --- mesh shape -------------------------------------------------------
    num_shards: int = 1
    num_pods: int = 1
    # --- multiplexer knobs (see core.multiplexer.make_multiplexer) --------
    impl: str = "auto"
    pack_impl: str | None = None
    num_chunks: int | None = None
    cross_pod: str | None = None
    # --- planner ----------------------------------------------------------
    cfg: PlannerConfig | None = None
    stats_mode: StatsMode = StatsMode.STATIC
    stats_profile: Mapping[str, Any] | None = dataclasses.field(
        default=None, compare=False
    )
    # --- out-of-core morsel streaming ------------------------------------
    #: Global rows per morsel.  On plain in-memory tables this wraps any
    #: table larger than ``morsel_rows`` in a chunked MorselView; chunked
    #: DataSources stream regardless.  None = fully in-memory execution.
    morsel_rows: int | None = None
    #: Hard per-device row budget.  In-memory execution refuses tables whose
    #: per-shard slice exceeds it; streamed execution bounds morsels and
    #: resident state by it.  None = unbounded.
    device_row_budget: int | None = None
    #: Per-(src,dst) message capacity for streamed exchanges.  None sizes
    #: messages for structural zero drop; smaller values force overflow
    #: (spill when ``spill=True``, error otherwise).
    exchange_rows: int | None = None
    #: Route exchange overflow to a host-memory overflow partition and
    #: re-shuffle it in drain passes instead of raising.
    spill: bool = False
    #: Per-shard capacity of streamed group-by state (distinct groups per
    #: shard).  None = min(plan capacity, device_row_budget).
    group_state_rows: int | None = None
    #: Depth of the host→device prefetch queue for morsel streaming.
    prefetch_depth: int = 2

    def __post_init__(self) -> None:
        if self.num_shards < 1 or self.num_pods < 1:
            raise ValueError("num_shards and num_pods must be >= 1")
        if self.num_shards % self.num_pods:
            raise ValueError(
                f"num_shards={self.num_shards} not divisible by num_pods={self.num_pods}"
            )
        if not isinstance(self.stats_mode, StatsMode):
            raise TypeError(
                f"stats_mode must be a StatsMode, got {self.stats_mode!r}; "
                'the stats="collect" magic string is only accepted through the '
                "deprecated-kwarg shim"
            )
        if self.stats_mode is StatsMode.PROFILE and self.stats_profile is None:
            raise ValueError("StatsMode.PROFILE requires stats_profile")
        if self.stats_profile is not None and self.stats_mode is not StatsMode.PROFILE:
            raise ValueError("stats_profile is only meaningful with StatsMode.PROFILE")

    # -- derived helpers ---------------------------------------------------

    def planner_stats(self, tables: Mapping[str, Any] | None = None):
        """Resolve the ``stats`` argument for ``plan_physical``.

        ``tables`` (name → Table) is required for COLLECT mode; pass the
        query's input tables.
        """
        if self.stats_mode is StatsMode.STATIC:
            return None
        if self.stats_mode is StatsMode.PROFILE:
            return dict(self.stats_profile)
        if tables is None:
            raise ValueError("StatsMode.COLLECT needs the input tables to sample")
        from repro.relational import stats as rstats

        return rstats.collect_stats(dict(tables))

    def with_(self, **changes) -> "ExecutionContext":
        """`dataclasses.replace` spelled as a method."""
        return dataclasses.replace(self, **changes)


# Legacy kwarg names accepted (for one release) by every migrated entry
# point.  ``stats`` carries the old str-or-dict pun and is unpunned below.
LEGACY_KWARGS = (
    "num_shards",
    "num_pods",
    "impl",
    "pack_impl",
    "num_chunks",
    "cross_pod",
    "cfg",
    "stats",
)

_warned = False


def reset_deprecation_warning() -> None:
    """Re-arm the warn-once latch (test helper)."""
    global _warned
    _warned = False


def _warn_once(where: str) -> None:
    global _warned
    if _warned:
        return
    _warned = True
    warnings.warn(
        f"{where}: passing num_shards/impl/pack_impl/num_chunks/num_pods/"
        "cross_pod/cfg/stats individually is deprecated; pass an "
        "ExecutionContext instead (repro.relational.context). The old "
        "kwargs will be removed next release.",
        DeprecationWarning,
        stacklevel=4,
    )


def _from_legacy(where: str, legacy: dict) -> ExecutionContext:
    unknown = set(legacy) - set(LEGACY_KWARGS)
    if unknown:
        raise TypeError(f"{where}: unexpected keyword arguments {sorted(unknown)}")
    _warn_once(where)
    stats = legacy.pop("stats", None)
    if stats == "collect":
        legacy["stats_mode"] = StatsMode.COLLECT
    elif isinstance(stats, Mapping):
        legacy["stats_mode"] = StatsMode.PROFILE
        legacy["stats_profile"] = stats
    elif stats is not None:
        raise TypeError(f"{where}: stats must be None, 'collect', or a profile dict")
    if legacy.get("impl") is None:
        legacy.pop("impl", None)
    return ExecutionContext(**legacy)


def resolve_context(
    ctx: "ExecutionContext | int | None",
    legacy: dict | None = None,
    *,
    where: str,
    default: "ExecutionContext | None" = None,
) -> ExecutionContext:
    """Accept the new ExecutionContext or the deprecated kwarg spelling.

    ``ctx`` is either an :class:`ExecutionContext` (the supported API), a
    bare int (the old positional ``num_shards``), or ``None``; ``legacy``
    holds whatever old-style keyword arguments the caller captured via
    ``**legacy``.  Any non-ExecutionContext spelling emits one
    ``DeprecationWarning`` per process (re-arm with
    :func:`reset_deprecation_warning`).
    """
    legacy = dict(legacy or {})
    if isinstance(ctx, ExecutionContext):
        if legacy:
            raise TypeError(
                f"{where}: legacy kwargs {sorted(legacy)} cannot be combined "
                "with an ExecutionContext; set them on the context"
            )
        return ctx
    if isinstance(ctx, bool):
        raise TypeError(f"{where}: expected ExecutionContext or int, got {ctx!r}")
    if isinstance(ctx, int):
        if "num_shards" in legacy:
            raise TypeError(f"{where}: num_shards given positionally and by keyword")
        legacy["num_shards"] = ctx
    elif ctx is not None:
        raise TypeError(f"{where}: expected ExecutionContext or int, got {type(ctx)!r}")
    if not legacy:
        if default is not None:
            return default
        raise TypeError(f"{where}: missing ExecutionContext (or legacy num_shards)")
    return _from_legacy(where, legacy)
