"""The distributed query engine: decoupled exchange plans over shard_map.

This is the paper's §3.2 pipeline end-to-end: local morsel pipelines
(queries.py) composed with the decoupled exchange operators
(core.exchange) under ``shard_map`` — partition shuffles for joins on the
shuffle key, broadcast exchanges for small build sides (planner rule
``plan.choose_join_strategy``), pre-aggregation before the exchange where
the group domain is small (Q1), and a final psum/top-k combine.

Tables cross the shard_map boundary as (columns-dict, valid) pytrees; the
exchange ships a densely packed int32 row matrix (paper Fig 8's fixed-width
serialization — column pruning happens before the pack).

All exchanges are routed through a :class:`repro.core.multiplexer
.CommMultiplexer` built once per query ("decoupled": the query plans never
pick transports themselves).  By default (``impl="auto"``) every
multiplexer knob — transport, ``pack_impl``, ``pipeline_chunks``,
``transport_chunks``, and on pod meshes the ``cross_pod`` build-side
strategy — is derived from the topology cost model by
:func:`repro.core.autotune.tune_multiplexer`, fed the per-shard row counts
and packed row widths of the query's own exchanges.  Passing an explicit
``impl`` (plus optional ``pack_impl`` / ``num_chunks`` / ``cross_pod``)
bypasses the tuner — that is what the A/B benchmarks and equivalence tests
do — and passing only ``pack_impl`` / ``num_chunks`` / ``cross_pod`` under
``impl="auto"`` pins just those knobs while the tuner picks the rest.
Every partition exchange's capacity is the static zero-drop bound, and the
psum'd drop count of each exchange is checked after execution — capacity
overflow raises instead of silently losing rows.

Two-level meshes (``num_pods > 1``, the paper's network in the large): rows
are sharded over ``("pod", "q")``; every partition exchange becomes the
two-level shuffle (coarse cross-pod hop, then fine in-pod — fine-grained
traffic never crosses DCI), build sides either replicate across pods or
reshard by key per the tuned ``cross_pod`` strategy, and the final
psum/top-k combine crosses the pod axis coarsely.  Results are identical
to the single-pod plan (the multi-device and multi-process suites assert
it).  Works both single-process (fake pods) and under
``repro.launch.cluster`` with one pod per real process.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import fetch, make_mesh, shard_map
from repro.core.autotune import TableStats
from repro.core.multiplexer import CommMultiplexer, make_multiplexer
from . import operators as ops
from . import queries as Q
from .plan import PlannerConfig, choose_join_strategy
from .table import Table, pad_to, shard_rows


def _mesh(num_shards: int, num_pods: int = 1):
    """Query mesh: 1-D single-pod, or two-level ``(pod, q)`` with the fine
    shuffle axis strictly in-pod (``num_pods`` defaults to 1 even in a
    multi-process run — pass it explicitly to engage the two-level plan)."""
    if num_pods <= 1:
        return make_mesh((num_shards,), ("q",))
    if num_shards % num_pods:
        raise ValueError(
            f"num_shards={num_shards} does not split across "
            f"num_pods={num_pods}; pick a pod count dividing the shard count"
        )
    return make_mesh((num_pods, num_shards // num_pods), ("pod", "q"))


def _axes(num_pods: int):
    """The mesh axes a table's rows are sharded over (shard_map specs and
    the final cross-unit psum both use this)."""
    return ("pod", "q") if num_pods > 1 else ("q",)


def _make_mux(
    mesh, impl: str, pack_impl: str | None = None, num_chunks: int | None = None,
    stats: list[TableStats] | None = None,
    broadcast_stats: TableStats | None = None,
    cross_pod: str | None = None,
) -> CommMultiplexer:
    """One multiplexer per query.

    ``impl="auto"`` hands the knobs to the topology autotuner, fed ``stats``
    (one entry per exchange in the plan) and ``broadcast_stats`` (the build
    side of a broadcast-style join, so the tuner can pick the cross-pod
    strategy on two-level meshes); an explicitly passed ``pack_impl`` /
    ``num_chunks`` / ``cross_pod`` (non-``None``) pins that knob even under
    auto.  An explicit ``impl`` uses the caller's knobs verbatim, with the
    pre-tuner defaults (``"xla"`` pack, unchunked, cross-pod broadcast) for
    anything left unset."""
    if impl == "auto":
        mux = make_multiplexer(
            mesh, auto=True, table_stats=stats or (),
            broadcast_stats=broadcast_stats,
        )
        pins = {}
        if pack_impl is not None:
            pins["pack_impl"] = pack_impl
        if num_chunks is not None:
            pins["pipeline_chunks"] = num_chunks
        if cross_pod is not None:
            pins["cross_pod"] = cross_pod
        return dataclasses.replace(mux, **pins) if pins else mux
    return make_multiplexer(
        mesh, impl=impl, pack_impl=pack_impl or "xla",
        pipeline_chunks=num_chunks or 1, cross_pod=cross_pod or "broadcast",
    )


def _exchange_stats(prepped: Table, num_shards: int, num_cols: int) -> TableStats:
    """Cost-model view of one exchange: per-shard rows x packed row bytes."""
    return TableStats(
        rows=prepped.capacity // num_shards, row_bytes=4 * num_cols
    )


def _prep(table: Table, num_shards: int) -> Table:
    cap = math.ceil(table.capacity / num_shards) * num_shards
    return shard_rows(pad_to(table, cap), num_shards)


def _local(table: Table):
    """Split a Table into shard_map-compatible pytrees."""
    return table.columns, table.valid


def _exchange_by_key(
    mux: CommMultiplexer, tbl_cols: dict, tbl_valid, key_name: str,
    columns: list[str], axis: str,
) -> tuple[Table, jax.Array]:
    """Decoupled exchange: repartition rows by hash(key) over the mesh.

    Routed through :meth:`CommMultiplexer.hash_shuffle_global`: on a
    single-level mesh that is the plain in-axis shuffle; on a two-level mesh
    it is the coarse-cross-pod + fine-in-pod exchange (``axis`` is the
    in-pod axis — the pod hop is the multiplexer's, never the caller's).
    Capacity per (src, dst) message equals the local capacity — the static
    zero-drop bound (a destination can at most receive every row of every
    sender).  Column pruning (paper §3.2.1) happens via ``columns``.

    Returns ``(table, dropped)`` where ``dropped`` is the psum'd number of
    rows lost to capacity overflow (0 under the zero-drop bound; surfaced so
    callers can turn overflow into an error instead of silent row loss).
    """
    cap = tbl_valid.shape[0]
    rows = jnp.stack([tbl_cols[c].astype(jnp.int32) for c in columns], axis=1)
    out_rows, out_valid, dropped = mux.hash_shuffle_global(
        tbl_cols[key_name].astype(jnp.int32), rows, axis,
        capacity=cap, valid=tbl_valid,
    )
    cols = {c: out_rows[:, i] for i, c in enumerate(columns)}
    return Table(cols, out_valid), dropped


def _broadcast_table(
    mux: CommMultiplexer, tbl_cols: dict, tbl_valid, columns: list[str],
    axis: str, key_name: str | None = None,
) -> tuple[Table, jax.Array]:
    """Deliver a join's (small) build side to where the probe rows are.

    Single-level mesh: ring all-gather — every device gets every row.  On a
    two-level mesh the multiplexer's tuned ``cross_pod`` strategy decides:

    * ``"broadcast"`` — replicate everywhere (in-pod all-gather, then one
      coarse cross-pod all-gather).  The paper's broadcast join: the build
      side crosses DCI once per remote pod.
    * ``"reshard"`` — hash-exchange the build side by ``key_name`` exactly
      like the probe side; equal keys land on the same device, so the local
      join sees only its partition.  Wins once the build side outgrows the
      broadcast threshold.

    Returns ``(table, dropped)`` (broadcast never drops; reshard is under
    the zero-drop bound, surfaced for the caller's overflow check).
    """
    if mux.plan.pod_axis is not None and mux.cross_pod == "reshard":
        assert key_name is not None, "reshard needs the build-side join key"
        return _exchange_by_key(mux, tbl_cols, tbl_valid, key_name, columns, axis)
    cols = {}
    for c in columns:
        g = mux.broadcast_global(tbl_cols[c], axis)
        cols[c] = g.reshape(-1)
    v = mux.broadcast_global(tbl_valid, axis).reshape(-1)
    return Table(cols, v), jnp.int32(0)


def _raise_on_dropped(query: str, dropped) -> None:
    """Capacity overflow is an error, not silent row loss (paper: the message
    pool is sized so overflow cannot happen; if it does, results are wrong)."""
    d = int(fetch(dropped))
    if d:
        raise RuntimeError(
            f"{query}: exchange dropped {d} rows to capacity overflow — "
            "results would silently lose rows; raise the capacity bound"
        )


# ----------------------------------------------------------------------------
# Q1 — pure pre-aggregation plan: no row exchange at all (paper Fig 11: Q1
# transfers almost nothing).  Local dense group-by, psum of the group table.
# ----------------------------------------------------------------------------

def q1_distributed(
    lineitem: Table, num_shards: int, delta_days: int = 90, num_pods: int = 1
):
    li = _prep(lineitem, num_shards)
    axes = _axes(num_pods)

    def body(cols, valid):
        partial_ = Q.q1_local(Table(cols, valid), delta_days)
        return jax.tree.map(lambda x: lax.psum(x, axes), partial_)

    fn = shard_map(
        body, mesh=_mesh(num_shards, num_pods),
        in_specs=(P(axes), P(axes)), out_specs=P(),
    )
    return Q.q1_finalize(fetch(jax.jit(fn)(*_local(li))))


def q6_distributed(
    lineitem: Table, num_shards: int, year: int = 1994, num_pods: int = 1
):
    li = _prep(lineitem, num_shards)
    axes = _axes(num_pods)

    def body(cols, valid):
        return lax.psum(Q.q6_local(Table(cols, valid), year), axes)

    fn = shard_map(
        body, mesh=_mesh(num_shards, num_pods),
        in_specs=(P(axes), P(axes)), out_specs=P(),
    )
    return fetch(jax.jit(fn)(*_local(li)))


# ----------------------------------------------------------------------------
# Q17 — the paper's worked example (Fig 6): partition lineitem by l_partkey,
# broadcast the (filtered, tiny) part side, local correlated-AVG plan, psum.
# ----------------------------------------------------------------------------

def q17_distributed(
    lineitem: Table,
    part: Table,
    num_shards: int,
    brand: int = 12,
    container: int = 2,
    impl: str = "auto",
    pack_impl: str | None = None,
    num_chunks: int | None = None,
    num_pods: int = 1,
    cross_pod: str | None = None,
):
    li = _prep(lineitem, num_shards)
    pt = _prep(part, num_shards)
    mesh = _mesh(num_shards, num_pods)
    axes = _axes(num_pods)
    mux = _make_mux(mesh, impl, pack_impl, num_chunks,
                    stats=[_exchange_stats(li, num_shards, 3)],
                    broadcast_stats=_exchange_stats(pt, num_shards, 3),
                    cross_pod=cross_pod)
    planner = PlannerConfig(num_units=num_shards, hybrid=True)
    strategy = choose_join_strategy(
        small_rows=part.capacity, large_rows=lineitem.capacity, cfg=planner
    )

    def body(li_cols, li_valid, pt_cols, pt_valid):
        li_t, dropped = _exchange_by_key(
            mux, li_cols, li_valid, "l_partkey",
            ["l_partkey", "l_quantity", "l_extendedprice"], "q",
        )
        assert strategy == "broadcast", strategy  # part is ~30x smaller
        pt_t, drop_pt = _broadcast_table(
            mux, pt_cols, pt_valid, ["p_partkey", "p_brand", "p_container"],
            "q", key_name="p_partkey",
        )
        partial_ = Q.q17_local(li_t, pt_t, brand, container)
        return lax.psum(partial_, axes), dropped + drop_pt

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axes),) * 4, out_specs=(P(), P()),
        # the replication checker has no rule for pallas_call (the fused
        # pack kernel) nor for the two-level ppermute hierarchy; keep it on
        # for the single-pod xla pack path only
        check_vma=mux.pack_impl != "pallas" and num_pods == 1,
    )
    result, dropped = jax.jit(fn)(*_local(li), *_local(pt))
    _raise_on_dropped("q17", dropped)
    return fetch(result)


# ----------------------------------------------------------------------------
# Q3 — two partition exchanges (custkey, then orderkey) + distributed top-k.
# ----------------------------------------------------------------------------

def q3_distributed(
    customer: Table,
    orders: Table,
    lineitem: Table,
    num_shards: int,
    segment: int = 1,
    impl: str = "auto",
    pack_impl: str | None = None,
    num_chunks: int | None = None,
    num_pods: int = 1,
):
    cu = _prep(customer, num_shards)
    od = _prep(orders, num_shards)
    li = _prep(lineitem, num_shards)
    mesh = _mesh(num_shards, num_pods)
    axes = _axes(num_pods)
    mux = _make_mux(mesh, impl, pack_impl, num_chunks, stats=[
        _exchange_stats(cu, num_shards, 2),   # customer by c_custkey
        _exchange_stats(od, num_shards, 3),   # orders by o_custkey
        _exchange_stats(od, num_shards, 2),   # joined orders by o_orderkey
        _exchange_stats(li, num_shards, 4),   # lineitem by l_orderkey
    ])
    from .datagen import date_to_days

    cutoff = date_to_days(1995, 3, 15)

    def body(cu_cols, cu_valid, od_cols, od_valid, li_cols, li_valid):
        # stage 1: co-partition customer and orders on custkey
        cu_t, drop0 = _exchange_by_key(
            mux, cu_cols, cu_valid, "c_custkey", ["c_custkey", "c_mktsegment"], "q"
        )
        od_t, drop1 = _exchange_by_key(
            mux, od_cols, od_valid, "o_custkey",
            ["o_custkey", "o_orderkey", "o_orderdate"], "q",
        )
        fcust = cu_t.with_mask(cu_t["c_mktsegment"] == segment)
        ford = od_t.with_mask(od_t["o_orderdate"] < cutoff)
        cidx, cmatch = ops.join_pk(
            fcust["c_custkey"], fcust.valid, ford["o_custkey"], ford.valid
        )
        od_j = ford.with_mask(cmatch)

        # stage 2: co-partition joined orders and lineitem on orderkey
        od_t2, drop2 = _exchange_by_key(
            mux, od_j.columns, od_j.valid, "o_orderkey",
            ["o_orderkey", "o_orderdate"], "q",
        )
        li_t, drop3 = _exchange_by_key(
            mux, li_cols, li_valid, "l_orderkey",
            ["l_orderkey", "l_shipdate", "l_extendedprice", "l_discount"], "q",
        )
        flin = li_t.with_mask(li_t["l_shipdate"] > cutoff)
        oidx, omatch = ops.join_pk(
            od_t2["o_orderkey"], od_t2.valid, flin["l_orderkey"], flin.valid
        )
        revenue = ops.money_times_pct(
            flin["l_extendedprice"], 100 - flin["l_discount"]
        )
        gkeys, gvalid, aggs = ops.groupby_sorted(
            flin["l_orderkey"], omatch, {"revenue": (revenue, "sum")}
        )
        # local top-10, then broadcast-combine for the global top-10
        vals, payload = ops.topk_rows(
            aggs["revenue"], gvalid, 10,
            {"o_orderkey": gkeys, "revenue": aggs["revenue"]},
        )
        all_vals = mux.broadcast_global(vals, "q").reshape(-1)
        all_keys = mux.broadcast_global(payload["o_orderkey"], "q").reshape(-1)
        all_rev = mux.broadcast_global(payload["revenue"], "q").reshape(-1)
        top_vals, idx = lax.top_k(all_vals, 10)
        result = {"o_orderkey": all_keys[idx], "revenue": all_rev[idx]}
        return result, drop0 + drop1 + drop2 + drop3

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axes),) * 6, out_specs=(P(), P()),
        # the top-k combine is replicated by construction (same ring
        # all-gather on every shard) but VMA can't infer that through
        # ppermute — disable the check rather than force an extra psum
        check_vma=False,
    )
    result, dropped = jax.jit(fn)(*_local(cu), *_local(od), *_local(li))
    _raise_on_dropped("q3", dropped)
    return fetch(result)


def _partkey_join_plan(query_fn, part_cols_needed):
    """Shared plan for Q14/Q19: partition lineitem by l_partkey, broadcast
    the (much smaller) part side — the hybrid planner's broadcast rule."""

    def run(lineitem: Table, part: Table, num_shards: int, impl: str = "auto",
            pack_impl: str | None = None, num_chunks: int | None = None,
            num_pods: int = 1, cross_pod: str | None = None, **kw):
        li = _prep(lineitem, num_shards)
        pt = _prep(part, num_shards)
        mesh = _mesh(num_shards, num_pods)
        axes = _axes(num_pods)
        mux = _make_mux(mesh, impl, pack_impl, num_chunks,
                        stats=[_exchange_stats(li, num_shards, 5)],
                        broadcast_stats=_exchange_stats(
                            pt, num_shards, len(part_cols_needed)
                        ),
                        cross_pod=cross_pod)

        def body(li_cols, li_valid, pt_cols, pt_valid):
            li_t, dropped = _exchange_by_key(
                mux, li_cols, li_valid, "l_partkey",
                ["l_partkey", "l_quantity", "l_extendedprice", "l_discount",
                 "l_shipdate"], "q",
            )
            pt_t, drop_pt = _broadcast_table(
                mux, pt_cols, pt_valid, part_cols_needed, "q",
                key_name="p_partkey",
            )
            return jax.tree.map(
                lambda v: lax.psum(v, axes), query_fn(li_t, pt_t, **kw)
            ), dropped + drop_pt

        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P(axes),) * 4, out_specs=(P(), P()),
            # see q17: no replication rule for pallas_call / two-level hops
            check_vma=mux.pack_impl != "pallas" and num_pods == 1,
        )
        result, dropped = jax.jit(fn)(*_local(li), *_local(pt))
        _raise_on_dropped(getattr(query_fn, "__name__", "partkey_join"), dropped)
        return fetch(result)

    return run


def q14_distributed(lineitem, part, num_shards, impl="auto", **kw):
    run = _partkey_join_plan(
        lambda li, pt, **k: Q.q14_local(li, pt, **k),
        ["p_partkey", "p_brand"],
    )
    promo, total = run(lineitem, part, num_shards, impl, **kw)
    return Q.q14_finalize(promo, total)


def q19_distributed(lineitem, part, num_shards, impl="auto", **kw):
    run = _partkey_join_plan(
        lambda li, pt, **k: Q.q19_local(li, pt, **k),
        ["p_partkey", "p_brand", "p_container", "p_size"],
    )
    return run(lineitem, part, num_shards, impl, **kw)


__all__ = [
    "q1_distributed",
    "q6_distributed",
    "q17_distributed",
    "q3_distributed",
    "q14_distributed",
    "q19_distributed",
]
