"""Distributed TPC-H entry points — thin wrappers over the query planner.

Every query here is a *logical plan* (``planner/tpch.py``); the cost-based
physical planner (``planner/physical.py``) places the exchanges — broadcast
vs partition per the paper's hybrid threshold (§3.1, Fig 6), pre-aggregation
for dense group-bys, co-partitioning reuse across chained joins — and the
executor (``planner/executor.py``) compiles the result into one shard_map
over the communication multiplexer.  The hand-wired per-query shard_map
plumbing that used to live here is gone; adding a query is now ~20 lines of
IR, and the planner's decisions are inspectable via
``planner.tpch.explain_query`` (golden-snapshotted under
``tests/golden_plans/``).

The execution contract is unchanged from the hand-written era and the
equivalence suites still hold these entry points to it:

* every exchange runs through ONE per-query auto-tuned
  :class:`~repro.core.multiplexer.CommMultiplexer` (``impl="auto"``;
  explicit ``impl``/``pack_impl``/``num_chunks``/``cross_pod`` pin knobs
  for A/B tests);
* capacities are the static zero-drop bound and any exchange overflow
  raises instead of silently losing rows;
* ``num_pods > 1`` runs the two-level ``(pod, q)`` mesh: shuffles take the
  coarse-cross-pod + fine-in-pod route, build sides follow the tuned
  ``cross_pod`` strategy, and results equal the single-pod plan exactly.
"""

from __future__ import annotations

from .planner import tpch
from .planner.tpch import run_query as _run
from .table import Table


# ----------------------------------------------------------------------------
# Q1/Q6 — pure pre-aggregation plans: no row exchange at all (paper Fig 11).
# ----------------------------------------------------------------------------

def q1_distributed(
    lineitem: Table, num_shards: int, delta_days: int = 90, num_pods: int = 1
):
    return _run(
        tpch.q1(delta_days), {"lineitem": lineitem}, num_shards,
        num_pods=num_pods,
    )


def q6_distributed(
    lineitem: Table, num_shards: int, year: int = 1994, num_pods: int = 1
):
    return _run(
        tpch.q6(year), {"lineitem": lineitem}, num_shards, num_pods=num_pods
    )


# ----------------------------------------------------------------------------
# Q17 — the paper's worked example (Fig 6): the planner broadcasts the
# (filtered, tiny) part side and shares one lineitem shuffle between the
# correlated-AVG group-by and the join back.
# ----------------------------------------------------------------------------

def q17_distributed(
    lineitem: Table,
    part: Table,
    num_shards: int,
    brand: int = 12,
    container: int = 2,
    impl: str = "auto",
    pack_impl: str | None = None,
    num_chunks: int | None = None,
    num_pods: int = 1,
    cross_pod: str | None = None,
):
    return _run(
        tpch.q17(brand, container), {"lineitem": lineitem, "part": part},
        num_shards, num_pods=num_pods, impl=impl, pack_impl=pack_impl,
        num_chunks=num_chunks, cross_pod=cross_pod,
    )


# ----------------------------------------------------------------------------
# Q3 — 3-table join + distributed top-10.  The hybrid threshold broadcasts
# the customer side (10x smaller than orders); lineitem and the surviving
# order keys co-partition on orderkey.
# ----------------------------------------------------------------------------

def q3_distributed(
    customer: Table,
    orders: Table,
    lineitem: Table,
    num_shards: int,
    segment: int = 1,
    impl: str = "auto",
    pack_impl: str | None = None,
    num_chunks: int | None = None,
    num_pods: int = 1,
    cross_pod: str | None = None,
):
    return _run(
        tpch.q3(segment),
        {"customer": customer, "orders": orders, "lineitem": lineitem},
        num_shards, num_pods=num_pods, impl=impl, pack_impl=pack_impl,
        num_chunks=num_chunks, cross_pod=cross_pod,
    )


# ----------------------------------------------------------------------------
# Q14/Q19 — broadcast-part joins; the planner drops the lineitem shuffle the
# old hand-written plan paid for nothing (no group-by needs co-partitioning).
# ----------------------------------------------------------------------------

def q14_distributed(
    lineitem: Table,
    part: Table,
    num_shards: int,
    impl: str = "auto",
    year: int = 1995,
    month: int = 9,
    promo_brands: int = 5,
    pack_impl: str | None = None,
    num_chunks: int | None = None,
    num_pods: int = 1,
    cross_pod: str | None = None,
):
    return _run(
        tpch.q14(year, month, promo_brands),
        {"lineitem": lineitem, "part": part},
        num_shards, num_pods=num_pods, impl=impl, pack_impl=pack_impl,
        num_chunks=num_chunks, cross_pod=cross_pod,
    )


def q19_distributed(
    lineitem: Table,
    part: Table,
    num_shards: int,
    impl: str = "auto",
    terms=None,
    pack_impl: str | None = None,
    num_chunks: int | None = None,
    num_pods: int = 1,
    cross_pod: str | None = None,
):
    return _run(
        tpch.q19(terms), {"lineitem": lineitem, "part": part},
        num_shards, num_pods=num_pods, impl=impl, pack_impl=pack_impl,
        num_chunks=num_chunks, cross_pod=cross_pod,
    )


# ----------------------------------------------------------------------------
# Q4/Q12/Q18 — plan-only queries: these never had a hand-written distributed
# version; the logical plan in planner/tpch.py IS the implementation.
# ----------------------------------------------------------------------------

def q4_distributed(
    lineitem: Table,
    orders: Table,
    num_shards: int,
    year: int = 1993,
    month: int = 7,
    impl: str = "auto",
    pack_impl: str | None = None,
    num_chunks: int | None = None,
    num_pods: int = 1,
    cross_pod: str | None = None,
):
    return _run(
        tpch.q4(year, month), {"lineitem": lineitem, "orders": orders},
        num_shards, num_pods=num_pods, impl=impl, pack_impl=pack_impl,
        num_chunks=num_chunks, cross_pod=cross_pod,
    )


def q12_distributed(
    lineitem: Table,
    orders: Table,
    num_shards: int,
    year: int = 1994,
    modes: tuple[int, int] = (5, 3),
    impl: str = "auto",
    pack_impl: str | None = None,
    num_chunks: int | None = None,
    num_pods: int = 1,
    cross_pod: str | None = None,
):
    return _run(
        tpch.q12(year, modes), {"lineitem": lineitem, "orders": orders},
        num_shards, num_pods=num_pods, impl=impl, pack_impl=pack_impl,
        num_chunks=num_chunks, cross_pod=cross_pod,
    )


def q18_distributed(
    lineitem: Table,
    orders: Table,
    customer: Table,
    num_shards: int,
    threshold: int = 300,
    k: int = 100,
    impl: str = "auto",
    pack_impl: str | None = None,
    num_chunks: int | None = None,
    num_pods: int = 1,
    cross_pod: str | None = None,
):
    return _run(
        tpch.q18(threshold, k),
        {"lineitem": lineitem, "orders": orders, "customer": customer},
        num_shards, num_pods=num_pods, impl=impl, pack_impl=pack_impl,
        num_chunks=num_chunks, cross_pod=cross_pod,
    )


__all__ = [
    "q1_distributed",
    "q3_distributed",
    "q4_distributed",
    "q6_distributed",
    "q12_distributed",
    "q14_distributed",
    "q17_distributed",
    "q18_distributed",
    "q19_distributed",
]
