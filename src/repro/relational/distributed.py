"""Distributed TPC-H entry points — thin wrappers over the query planner.

Every query here is a *logical plan* (``planner/tpch.py``); the cost-based
physical planner (``planner/physical.py``) places the exchanges — broadcast
vs partition per the paper's hybrid threshold (§3.1, Fig 6), pre-aggregation
for dense group-bys, co-partitioning reuse across chained joins — and the
executor (``planner/executor.py``) compiles the result into one shard_map
over the communication multiplexer.  The hand-wired per-query shard_map
plumbing that used to live here is gone; adding a query is now ~20 lines of
IR, and the planner's decisions are inspectable via
``planner.tpch.explain_query`` (golden-snapshotted under
``tests/golden_plans/``).

Execution is parameterized by ONE object: pass an
:class:`~repro.relational.context.ExecutionContext` (mesh shape,
multiplexer knobs, planner config, stats mode, out-of-core morsel/spill
knobs, observability tracer) as ``ctx``.  The PR-9 per-knob kwarg shim
(``num_shards`` positionally plus ``impl=``/``pack_impl=``/... keywords)
is gone: old spellings raise ``TypeError``.  Inputs may be in-memory
``Table``\\ s or chunked ``DataSource``\\ s (the latter stream
morsel-by-morsel, out of core).

The execution contract is unchanged from the hand-written era and the
equivalence suites still hold these entry points to it:

* every exchange runs through ONE per-query auto-tuned
  :class:`~repro.core.multiplexer.CommMultiplexer` (``impl="auto"``;
  explicit knobs on the context pin them for A/B tests);
* capacities are the static zero-drop bound and any exchange overflow
  raises instead of silently losing rows (unless the context opts into
  spill-to-host with ``spill=True``);
* ``num_pods > 1`` runs the two-level ``(pod, q)`` mesh: shuffles take the
  coarse-cross-pod + fine-in-pod route, build sides follow the tuned
  ``cross_pod`` strategy, and results equal the single-pod plan exactly.
"""

from __future__ import annotations

from .planner import tpch
from .planner.tpch import run_query as _run


def q1_distributed(lineitem, ctx=None, delta_days: int = 90):
    return _run(tpch.q1(delta_days), {"lineitem": lineitem}, ctx)


def q6_distributed(lineitem, ctx=None, year: int = 1994):
    return _run(tpch.q6(year), {"lineitem": lineitem}, ctx)


def q17_distributed(lineitem, part, ctx=None, brand: int = 12,
                    container: int = 2):
    return _run(
        tpch.q17(brand, container), {"lineitem": lineitem, "part": part}, ctx
    )


def q3_distributed(customer, orders, lineitem, ctx=None, segment: int = 1):
    return _run(
        tpch.q3(segment),
        {"customer": customer, "orders": orders, "lineitem": lineitem},
        ctx,
    )


def q14_distributed(
    lineitem, part, ctx=None, year: int = 1995, month: int = 9,
    promo_brands: int = 5,
):
    return _run(
        tpch.q14(year, month, promo_brands),
        {"lineitem": lineitem, "part": part}, ctx,
    )


def q19_distributed(lineitem, part, ctx=None, terms=None):
    return _run(tpch.q19(terms), {"lineitem": lineitem, "part": part}, ctx)


def q4_distributed(lineitem, orders, ctx=None, year: int = 1993,
                   month: int = 7):
    return _run(
        tpch.q4(year, month), {"lineitem": lineitem, "orders": orders}, ctx
    )


def q12_distributed(
    lineitem, orders, ctx=None, year: int = 1994,
    modes: tuple[int, int] = (5, 3),
):
    return _run(
        tpch.q12(year, modes), {"lineitem": lineitem, "orders": orders}, ctx
    )


def q18_distributed(
    lineitem, orders, customer, ctx=None, threshold: int = 300, k: int = 100,
):
    return _run(
        tpch.q18(threshold, k),
        {"lineitem": lineitem, "orders": orders, "customer": customer},
        ctx,
    )


__all__ = [
    "q1_distributed",
    "q3_distributed",
    "q4_distributed",
    "q6_distributed",
    "q12_distributed",
    "q14_distributed",
    "q17_distributed",
    "q18_distributed",
    "q19_distributed",
]
