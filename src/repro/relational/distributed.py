"""The distributed query engine: decoupled exchange plans over shard_map.

This is the paper's §3.2 pipeline end-to-end: local morsel pipelines
(queries.py) composed with the decoupled exchange operators
(core.exchange) under ``shard_map`` — partition shuffles for joins on the
shuffle key, broadcast exchanges for small build sides (planner rule
``plan.choose_join_strategy``), pre-aggregation before the exchange where
the group domain is small (Q1), and a final psum/top-k combine.

Tables cross the shard_map boundary as (columns-dict, valid) pytrees; the
exchange ships a densely packed int32 row matrix (paper Fig 8's fixed-width
serialization — column pruning happens before the pack).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import exchange
from . import operators as ops
from . import queries as Q
from .plan import PlannerConfig, choose_join_strategy
from .table import Table, pad_to, shard_rows


def _mesh(num_shards: int):
    return jax.make_mesh(
        (num_shards,), ("q",),
        axis_types=(jax.sharding.AxisType.Auto,),
    )


def _prep(table: Table, num_shards: int) -> Table:
    cap = math.ceil(table.capacity / num_shards) * num_shards
    return shard_rows(pad_to(table, cap), num_shards)


def _local(table: Table):
    """Split a Table into shard_map-compatible pytrees."""
    return table.columns, table.valid


def _exchange_by_key(
    tbl_cols: dict, tbl_valid, key_name: str, columns: list[str],
    axis: str, impl: str,
) -> Table:
    """Decoupled exchange: repartition rows by hash(key) over ``axis``.

    Capacity per (src, dst) message equals the local capacity — the static
    zero-drop bound (a destination can at most receive every row of every
    sender).  Column pruning (paper §3.2.1) happens via ``columns``.
    """
    n = lax.axis_size(axis)
    cap = tbl_valid.shape[0]
    rows = jnp.stack([tbl_cols[c].astype(jnp.int32) for c in columns], axis=1)
    out_rows, out_valid, _ = exchange.hash_shuffle(
        tbl_cols[key_name].astype(jnp.int32), rows, axis,
        capacity=cap, impl=impl, valid=tbl_valid,
    )
    cols = {c: out_rows[:, i] for i, c in enumerate(columns)}
    return Table(cols, out_valid)


def _broadcast_table(tbl_cols: dict, tbl_valid, columns: list[str], axis: str) -> Table:
    """Broadcast exchange (ring all-gather) of a small table."""
    cols = {}
    for c in columns:
        g = exchange.broadcast_exchange(tbl_cols[c], axis, impl="ring")
        cols[c] = g.reshape(-1)
    v = exchange.broadcast_exchange(tbl_valid, axis, impl="ring").reshape(-1)
    return Table(cols, v)


# ----------------------------------------------------------------------------
# Q1 — pure pre-aggregation plan: no row exchange at all (paper Fig 11: Q1
# transfers almost nothing).  Local dense group-by, psum of the group table.
# ----------------------------------------------------------------------------

def q1_distributed(lineitem: Table, num_shards: int, delta_days: int = 90):
    li = _prep(lineitem, num_shards)

    def body(cols, valid):
        partial_ = Q.q1_local(Table(cols, valid), delta_days)
        return jax.tree.map(lambda x: lax.psum(x, "q"), partial_)

    fn = jax.shard_map(
        body, mesh=_mesh(num_shards),
        in_specs=(P("q"), P("q")), out_specs=P(),
    )
    return Q.q1_finalize(jax.jit(fn)(*_local(li)))


def q6_distributed(lineitem: Table, num_shards: int, year: int = 1994):
    li = _prep(lineitem, num_shards)

    def body(cols, valid):
        return lax.psum(Q.q6_local(Table(cols, valid), year), "q")

    fn = jax.shard_map(
        body, mesh=_mesh(num_shards), in_specs=(P("q"), P("q")), out_specs=P()
    )
    return jax.jit(fn)(*_local(li))


# ----------------------------------------------------------------------------
# Q17 — the paper's worked example (Fig 6): partition lineitem by l_partkey,
# broadcast the (filtered, tiny) part side, local correlated-AVG plan, psum.
# ----------------------------------------------------------------------------

def q17_distributed(
    lineitem: Table,
    part: Table,
    num_shards: int,
    brand: int = 12,
    container: int = 2,
    impl: str = "round_robin",
):
    li = _prep(lineitem, num_shards)
    pt = _prep(part, num_shards)
    planner = PlannerConfig(num_units=num_shards, hybrid=True)
    strategy = choose_join_strategy(
        small_rows=part.capacity, large_rows=lineitem.capacity, cfg=planner
    )

    def body(li_cols, li_valid, pt_cols, pt_valid):
        li_t = _exchange_by_key(
            li_cols, li_valid, "l_partkey",
            ["l_partkey", "l_quantity", "l_extendedprice"], "q", impl,
        )
        assert strategy == "broadcast", strategy  # part is ~30x smaller
        pt_t = _broadcast_table(
            pt_cols, pt_valid, ["p_partkey", "p_brand", "p_container"], "q"
        )
        partial_ = Q.q17_local(li_t, pt_t, brand, container)
        return lax.psum(partial_, "q")

    fn = jax.shard_map(
        body, mesh=_mesh(num_shards),
        in_specs=(P("q"), P("q"), P("q"), P("q")), out_specs=P(),
    )
    return jax.jit(fn)(*_local(li), *_local(pt))


# ----------------------------------------------------------------------------
# Q3 — two partition exchanges (custkey, then orderkey) + distributed top-k.
# ----------------------------------------------------------------------------

def q3_distributed(
    customer: Table,
    orders: Table,
    lineitem: Table,
    num_shards: int,
    segment: int = 1,
    impl: str = "round_robin",
):
    cu = _prep(customer, num_shards)
    od = _prep(orders, num_shards)
    li = _prep(lineitem, num_shards)
    from .datagen import date_to_days

    cutoff = date_to_days(1995, 3, 15)

    def body(cu_cols, cu_valid, od_cols, od_valid, li_cols, li_valid):
        # stage 1: co-partition customer and orders on custkey
        cu_t = _exchange_by_key(
            cu_cols, cu_valid, "c_custkey", ["c_custkey", "c_mktsegment"], "q", impl
        )
        od_t = _exchange_by_key(
            od_cols, od_valid, "o_custkey",
            ["o_custkey", "o_orderkey", "o_orderdate"], "q", impl,
        )
        fcust = cu_t.with_mask(cu_t["c_mktsegment"] == segment)
        ford = od_t.with_mask(od_t["o_orderdate"] < cutoff)
        cidx, cmatch = ops.join_pk(
            fcust["c_custkey"], fcust.valid, ford["o_custkey"], ford.valid
        )
        od_j = ford.with_mask(cmatch)

        # stage 2: co-partition joined orders and lineitem on orderkey
        od_t2 = _exchange_by_key(
            od_j.columns, od_j.valid, "o_orderkey",
            ["o_orderkey", "o_orderdate"], "q", impl,
        )
        li_t = _exchange_by_key(
            li_cols, li_valid, "l_orderkey",
            ["l_orderkey", "l_shipdate", "l_extendedprice", "l_discount"], "q", impl,
        )
        flin = li_t.with_mask(li_t["l_shipdate"] > cutoff)
        oidx, omatch = ops.join_pk(
            od_t2["o_orderkey"], od_t2.valid, flin["l_orderkey"], flin.valid
        )
        revenue = ops.money_times_pct(
            flin["l_extendedprice"], 100 - flin["l_discount"]
        )
        gkeys, gvalid, aggs = ops.groupby_sorted(
            flin["l_orderkey"], omatch, {"revenue": (revenue, "sum")}
        )
        # local top-10, then broadcast-combine for the global top-10
        vals, payload = ops.topk_rows(
            aggs["revenue"], gvalid, 10,
            {"o_orderkey": gkeys, "revenue": aggs["revenue"]},
        )
        all_vals = exchange.broadcast_exchange(vals, "q", impl="ring").reshape(-1)
        all_keys = exchange.broadcast_exchange(
            payload["o_orderkey"], "q", impl="ring"
        ).reshape(-1)
        all_rev = exchange.broadcast_exchange(
            payload["revenue"], "q", impl="ring"
        ).reshape(-1)
        top_vals, idx = lax.top_k(all_vals, 10)
        return {"o_orderkey": all_keys[idx], "revenue": all_rev[idx]}

    fn = jax.shard_map(
        body, mesh=_mesh(num_shards),
        in_specs=(P("q"),) * 6, out_specs=P(),
        # the top-k combine is replicated by construction (same ring
        # all-gather on every shard) but VMA can't infer that through
        # ppermute — disable the check rather than force an extra psum
        check_vma=False,
    )
    return jax.jit(fn)(*_local(cu), *_local(od), *_local(li))


def _partkey_join_plan(query_fn, part_cols_needed):
    """Shared plan for Q14/Q19: partition lineitem by l_partkey, broadcast
    the (much smaller) part side — the hybrid planner's broadcast rule."""

    def run(lineitem: Table, part: Table, num_shards: int, impl: str = "round_robin",
            **kw):
        li = _prep(lineitem, num_shards)
        pt = _prep(part, num_shards)

        def body(li_cols, li_valid, pt_cols, pt_valid):
            li_t = _exchange_by_key(
                li_cols, li_valid, "l_partkey",
                ["l_partkey", "l_quantity", "l_extendedprice", "l_discount",
                 "l_shipdate"], "q", impl,
            )
            pt_t = _broadcast_table(pt_cols, pt_valid, part_cols_needed, "q")
            return jax.tree.map(
                lambda v: lax.psum(v, "q"), query_fn(li_t, pt_t, **kw)
            )

        fn = jax.shard_map(
            body, mesh=_mesh(num_shards),
            in_specs=(P("q"), P("q"), P("q"), P("q")), out_specs=P(),
        )
        return jax.jit(fn)(*_local(li), *_local(pt))

    return run


def q14_distributed(lineitem, part, num_shards, impl="round_robin", **kw):
    run = _partkey_join_plan(
        lambda li, pt, **k: Q.q14_local(li, pt, **k),
        ["p_partkey", "p_brand"],
    )
    promo, total = run(lineitem, part, num_shards, impl, **kw)
    return Q.q14_finalize(promo, total)


def q19_distributed(lineitem, part, num_shards, impl="round_robin", **kw):
    run = _partkey_join_plan(
        lambda li, pt, **k: Q.q19_local(li, pt, **k),
        ["p_partkey", "p_brand", "p_container", "p_size"],
    )
    return run(lineitem, part, num_shards, impl, **kw)


__all__ = [
    "q1_distributed",
    "q6_distributed",
    "q17_distributed",
    "q3_distributed",
    "q14_distributed",
    "q19_distributed",
]
