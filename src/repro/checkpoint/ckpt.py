"""Sharded, crash-consistent checkpoints with elastic restore.

Fault-tolerance contract (DESIGN.md §5):

* **crash consistency** — a checkpoint is written to ``step_<n>.tmp`` and
  atomically renamed to ``step_<n>``; readers only ever see complete
  checkpoints, a crash mid-write leaves the previous checkpoint intact.
* **sharded save** — every leaf is written as one ``.npy`` per *addressable
  shard* (per device on this host); the JSON manifest records the global
  shape and each shard's index slices.  On a real multi-host pod each host
  writes only its shards (no gather), so save bandwidth scales with hosts.
* **elastic restore** — the manifest is mesh-agnostic: restore reassembles
  the global array from shard files and re-shards it onto whatever mesh the
  *new* job runs (different device count after a node failure), so training
  resumes after losing/gaining hardware.
* **retention** — keep the newest ``keep`` checkpoints; corrupt/partial
  directories (missing MANIFEST) are skipped by ``latest_step``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
from typing import Any

import jax
import numpy as np

MANIFEST = "MANIFEST.json"


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Write ``tree`` as a sharded checkpoint; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest: dict[str, Any] = {"step": step, "leaves": {}}
    for path, leaf in leaves:
        name = _path_str(path)
        safe = re.sub(r"[^A-Za-z0-9_.-]", "_", name)
        arr = leaf if isinstance(leaf, jax.Array) else np.asarray(leaf)
        entry: dict[str, Any] = {
            "file_prefix": safe,
            "shape": list(np.shape(arr)),
            "dtype": str(np.asarray(jax.eval_shape(lambda: arr).dtype)
                         if isinstance(arr, jax.Array) else arr.dtype),
        }
        shards = []
        if isinstance(arr, jax.Array) and hasattr(arr, "addressable_shards"):
            for i, sh in enumerate(arr.addressable_shards):
                fn = f"{safe}.shard{i}.npy"
                np.save(os.path.join(tmp, fn), np.asarray(sh.data))
                shards.append({
                    "file": fn,
                    "index": [[s.start, s.stop] if s.start is not None else None
                              for s in sh.index],
                })
        else:
            fn = f"{safe}.shard0.npy"
            np.save(os.path.join(tmp, fn), np.asarray(arr))
            shards.append({"file": fn, "index": None})
        entry["shards"] = shards
        manifest["leaves"][name] = entry

    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def _assemble(entry: dict, ckpt_dir: str) -> np.ndarray:
    shape = tuple(entry["shape"])
    first = np.load(os.path.join(ckpt_dir, entry["shards"][0]["file"]))
    if entry["shards"][0]["index"] is None and len(entry["shards"]) == 1:
        return first.reshape(shape) if shape else first
    out = np.zeros(shape, dtype=first.dtype)
    for sh in entry["shards"]:
        data = np.load(os.path.join(ckpt_dir, sh["file"]))
        idx = tuple(
            slice(None) if s is None else slice(s[0], s[1]) for s in sh["index"]
        )
        out[idx] = data
    return out


def restore_checkpoint(
    directory: str,
    step: int | None,
    target: Any,
    shardings: Any = None,
) -> Any:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (same structure, NamedSharding or
    None leaves) re-shards onto the *current* mesh — elastic restart."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    ckpt_dir = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(ckpt_dir, MANIFEST)) as f:
        manifest = json.load(f)

    leaves, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves)
    )
    out = []
    for (path, leaf), shd in zip(leaves, shard_leaves):
        name = _path_str(path)
        if name not in manifest["leaves"]:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = _assemble(manifest["leaves"][name], ckpt_dir)
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    best = None
    for d in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(directory, d, MANIFEST)):
            best = max(best or -1, int(m.group(1)))
    return best


@dataclasses.dataclass
class CheckpointManager:
    """Periodic save + retention + resume for the training driver."""

    directory: str
    every: int = 100
    keep: int = 3

    def maybe_save(self, step: int, tree: Any) -> str | None:
        if self.every <= 0 or step % self.every != 0:
            return None
        path = save_checkpoint(self.directory, step, tree)
        self._gc()
        return path

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1))
            for d in os.listdir(self.directory)
            if (m := re.fullmatch(r"step_(\d+)", d))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    def restore_latest(self, target: Any, shardings: Any = None) -> tuple[int, Any] | None:
        step = latest_step(self.directory)
        if step is None:
            return None
        return step, restore_checkpoint(self.directory, step, target, shardings)


__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "CheckpointManager"]
