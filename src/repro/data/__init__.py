"""Data pipeline: deterministic synthetic streams + binary token files."""

from .pipeline import (
    SyntheticLM,
    TokenFileDataset,
    Prefetcher,
    make_batch_iterator,
    write_token_file,
)

__all__ = [
    "SyntheticLM",
    "TokenFileDataset",
    "Prefetcher",
    "make_batch_iterator",
    "write_token_file",
]
