"""Deterministic sharded data pipeline with background prefetch.

Two sources:

* :class:`SyntheticLM` — seeded synthetic token stream (a learnable
  order-k Markov chain, so training loss actually falls); deterministic in
  ``(seed, step, shard)``, which makes restarts reproducible: after a crash
  the restored step index regenerates exactly the batches that would have
  followed — data-pipeline state needs NO checkpointing.
* :class:`TokenFileDataset` — memory-mapped binary token files (the
  production path), sampled in deterministic windows per (step, shard).

Sharding follows the paper's morsel discipline: the global batch is cut
into per-datashard *morsels* assigned round-robin, so a skewed/hot region
of the corpus decorrelates across shards (table.shard_rows uses the same
trick for relations).

:class:`Prefetcher` overlaps host-side batch assembly with device compute
on a background thread (the data-pipeline analogue of the paper's
dedicated network thread).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator

import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.registry import VLM_PATCHES


@dataclasses.dataclass
class SyntheticLM:
    """Order-1 Markov token stream; next-token structure is learnable."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1
    shard: int = 0
    markov_states: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        s = min(self.markov_states, self.vocab_size)
        # sparse-ish transition matrix over a reduced state space
        self.trans = rng.dirichlet(np.full(s, 0.3), size=s).astype(np.float64)
        self.proj = rng.integers(0, self.vocab_size, size=s)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        assert self.global_batch % self.num_shards == 0
        b_local = self.global_batch // self.num_shards
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4_096 + self.shard
        )
        s = self.trans.shape[0]
        states = rng.integers(0, s, size=b_local)
        seq = np.empty((b_local, self.seq_len + 1), np.int64)
        cum = np.cumsum(self.trans, axis=1)
        for t in range(self.seq_len + 1):
            seq[:, t] = self.proj[states]
            u = rng.random(b_local)
            states = (cum[states] < u[:, None]).sum(axis=1).clip(max=s - 1)
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }


@dataclasses.dataclass
class TokenFileDataset:
    """Deterministic random windows over a memory-mapped token file."""

    path: str
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1
    shard: int = 0

    def __post_init__(self):
        self.tokens = np.memmap(self.path, dtype=np.int32, mode="r")
        assert len(self.tokens) > self.seq_len + 1, "token file too small"

    def batch(self, step: int) -> dict[str, np.ndarray]:
        b_local = self.global_batch // self.num_shards
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4_096 + self.shard
        )
        starts = rng.integers(0, len(self.tokens) - self.seq_len - 1, size=b_local)
        rows = np.stack([self.tokens[s : s + self.seq_len + 1] for s in starts])
        return {
            "tokens": rows[:, :-1].astype(np.int32),
            "labels": rows[:, 1:].astype(np.int32),
        }


def write_token_file(path: str, tokens: np.ndarray) -> None:
    np.asarray(tokens, np.int32).tofile(path)


def _augment_for_family(cfg: ModelConfig, batch: dict, rng: np.random.Generator) -> dict:
    """Add the stub modality inputs (whisper frames / vlm patches)."""
    if cfg.family == "encdec":
        B, S = batch["tokens"].shape
        batch["frames"] = rng.standard_normal((B, S, cfg.d_model)).astype(np.float32)
    elif cfg.family == "vlm":
        B, S = batch["tokens"].shape
        P = min(VLM_PATCHES, S // 2)
        batch["tokens"] = batch["tokens"][:, : S - P]
        batch["labels"] = batch["labels"][:, : S - P]
        batch["patches"] = rng.standard_normal((B, P, cfg.d_model)).astype(np.float32)
    return batch


def make_batch_iterator(
    cfg: ModelConfig,
    shape: ShapeSpec,
    seed: int = 0,
    start_step: int = 0,
    source: Any = None,
) -> Iterator[dict[str, np.ndarray]]:
    """Infinite deterministic iterator of training batches for (cfg, shape)."""
    src = source or SyntheticLM(
        vocab_size=cfg.vocab_size,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        seed=seed,
    )
    step = start_step
    while True:
        rng = np.random.default_rng(seed * 7_919 + step)
        yield _augment_for_family(cfg, src.batch(step), rng)
        step += 1


class Prefetcher:
    """Background-thread prefetch of an iterator (depth-bounded queue)."""

    _DONE = object()

    def __init__(self, it: Iterator[Any], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: BaseException | None = None

        def run():
            try:
                for item in it:
                    self._q.put(item)
            except BaseException as e:  # surfaced on next()
                self._err = e
            finally:
                self._q.put(self._DONE)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._DONE:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


__all__ = [
    "SyntheticLM",
    "TokenFileDataset",
    "write_token_file",
    "make_batch_iterator",
    "Prefetcher",
]
