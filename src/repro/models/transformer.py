"""Decoder-only transformer stack (dense + MoE families).

One implementation serves minicpm / qwen2.5 / deepseek-67b / qwen1.5 (dense),
deepseek-v2-lite (MLA + MoE, first layer dense) and olmoe (all-MoE), plus the
qwen2-vl backbone (M-RoPE + patch-embedding prefix).

The layer stack is a list of *segments* — runs of identical layers scanned
with ``lax.scan`` over stacked params, so the lowered HLO is O(1) in depth
(95-layer deepseek compiles as fast as 16-layer olmoe).  Heterogeneous depth
patterns (deepseek-v2's dense first layer) become multiple segments.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from . import layers as L
from . import moe as M


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str  # "dense" | "moe"
    count: int


def segments_for(cfg: ModelConfig) -> list[Segment]:
    if cfg.num_experts == 0:
        return [Segment("dense", cfg.num_layers)]
    segs = []
    if cfg.first_dense_layers:
        segs.append(Segment("dense", cfg.first_dense_layers))
    segs.append(Segment("moe", cfg.num_layers - cfg.first_dense_layers))
    return segs


# ----------------------------------------------------------------------------
# Per-layer init/specs.
# ----------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, kind: str) -> Any:
    ks = jax.random.split(key, 4)
    attn = (L.init_mla if cfg.attn_kind == "mla" else L.init_attention)(ks[0], cfg)
    p = {
        "ln1": L.init_rmsnorm(cfg.d_model, L.pdtype(cfg)),
        "attn": attn,
        "ln2": L.init_rmsnorm(cfg.d_model, L.pdtype(cfg)),
    }
    if kind == "moe":
        p["ffn"] = M.init_moe_layer(ks[1], cfg)
    else:
        p["ffn"] = L.init_mlp(ks[1], cfg)
    return p


def _specs_layer(cfg: ModelConfig, kind: str) -> Any:
    attn = (L.specs_mla if cfg.attn_kind == "mla" else L.specs_attention)(cfg)
    s = {
        "ln1": L.specs_rmsnorm(),
        "attn": attn,
        "ln2": L.specs_rmsnorm(),
    }
    s["ffn"] = M.specs_moe_layer(cfg) if kind == "moe" else L.specs_mlp(cfg)
    return s


def _stack_specs(spec_tree: Any) -> Any:
    """Prepend the (replicated) layer-stacking dim to every leaf spec."""
    return jax.tree.map(
        lambda axes: (None,) + tuple(axes),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def init(key, cfg: ModelConfig) -> Any:
    ks = jax.random.split(key, 2 + len(segments_for(cfg)))
    params: dict[str, Any] = {"embedding": L.init_embedding(ks[0], cfg)}
    params["final_norm"] = L.init_rmsnorm(cfg.d_model, L.pdtype(cfg))
    for i, seg in enumerate(segments_for(cfg)):
        seg_keys = jax.random.split(ks[2 + i], seg.count)
        params[f"seg{i}"] = jax.vmap(lambda k: _init_layer(k, cfg, seg.kind))(seg_keys)
    return params


def specs(cfg: ModelConfig) -> Any:
    s: dict[str, Any] = {
        "embedding": L.specs_embedding(cfg),
        "final_norm": L.specs_rmsnorm(),
    }
    for i, seg in enumerate(segments_for(cfg)):
        s[f"seg{i}"] = _stack_specs(_specs_layer(cfg, seg.kind))
    return s


# ----------------------------------------------------------------------------
# Layer body (shared by train/prefill/decode paths).
# ----------------------------------------------------------------------------

def _ffn(p, cfg: ModelConfig, kind: str, x):
    if kind == "moe":
        return M.moe_ffn(p, cfg, x)
    return L.mlp_block(p, cfg, x)


def _layer_fwd(p, cfg: ModelConfig, kind: str, x, cos, sin):
    r = jnp.asarray(cfg.residual_scale, x.dtype)
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.attn_kind == "mla":
        a = L.mla_block(p["attn"], cfg, h, cos, sin, causal=True)
    else:
        a = L.attention_block(p["attn"], cfg, h, cos, sin, causal=True)
    x = x + r * a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + r * _ffn(p["ffn"], cfg, kind, h)
    return shard(x, "batch", "seq_sp", "d_model")


def _layer_decode(p, cfg: ModelConfig, kind: str, x, cache, pos, cos, sin):
    r = jnp.asarray(cfg.residual_scale, x.dtype)
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.attn_kind == "mla":
        a, c, kr = L.mla_decode(p["attn"], cfg, h, cache["c"], cache["kr"], pos, cos, sin)
        new_cache = {"c": c, "kr": kr}
    else:
        a, ck, cv = L.attention_decode(
            p["attn"], cfg, h, cache["k"], cache["v"], pos, cos, sin
        )
        new_cache = {"k": ck, "v": cv}
    x = x + r * a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + r * _ffn(p["ffn"], cfg, kind, h)
    return x, new_cache


def _layer_decode_slots(p, cfg: ModelConfig, kind: str, x, cache, positions, cos, sin):
    r = jnp.asarray(cfg.residual_scale, x.dtype)
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.attn_kind == "mla":
        a, c, kr = L.mla_decode_slots(
            p["attn"], cfg, h, cache["c"], cache["kr"], positions, cos, sin
        )
        new_cache = {"c": c, "kr": kr}
    else:
        a, ck, cv = L.attention_decode_slots(
            p["attn"], cfg, h, cache["k"], cache["v"], positions, cos, sin
        )
        new_cache = {"k": ck, "v": cv}
    x = x + r * a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + r * _ffn(p["ffn"], cfg, kind, h)
    return x, new_cache


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    policy = (
        jax.checkpoint_policies.nothing_saveable
        if cfg.remat == "full"
        else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    )
    return jax.checkpoint(fn, policy=policy)


def _scan_unroll(cfg: ModelConfig) -> int:
    """Unroll factor for the layer scans (cfg.overlap_unroll).

    > 1 interleaves consecutive layers' HLO inside one scan iteration, which
    is what lets XLA's latency-hiding scheduler start layer k+1's MoE
    dispatch DMA while layer k's expert FFN still runs — the cross-layer
    half of the async overlap path (the in-layer half is the chunked
    dispatch pipeline in models/moe.py).  Numerics-neutral: unrolling
    changes instruction scheduling, not values.
    """
    return max(int(getattr(cfg, "overlap_unroll", 1) or 1), 1)


def _run_segments(params, cfg: ModelConfig, x, cos, sin):
    for i, seg in enumerate(segments_for(cfg)):
        body = _maybe_remat(
            lambda h, p, kind=seg.kind: (_layer_fwd(p, cfg, kind, h, cos, sin), None),
            cfg,
        )
        if cfg.scan_layers:
            x, _ = lax.scan(body, x, params[f"seg{i}"], unroll=_scan_unroll(cfg))
        else:
            for l in range(seg.count):
                p_l = jax.tree.map(lambda a: a[l], params[f"seg{i}"])
                x, _ = body(x, p_l)
    return x


# ----------------------------------------------------------------------------
# Public API: forward / train_loss / cache / prefill / decode.
# ----------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, batch) -> tuple[jax.Array, jax.Array]:
    """Token embedding (+ optional VLM patch prefix) and positions."""
    x = L.embed(params["embedding"], cfg, batch["tokens"])
    if "patches" in batch:  # qwen2-vl stub frontend: precomputed embeddings
        patches = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
    x = shard(x, "batch", "seq_sp", "d_model")
    B, S = x.shape[0], x.shape[1]
    if "positions" in batch:
        pos = batch["positions"]
    else:
        pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
        if cfg.rope_kind == "mrope":
            pos = jnp.broadcast_to(pos[None], (3, B, S))
    return x, pos


def forward(params, cfg: ModelConfig, batch) -> jax.Array:
    """Full-sequence causal forward -> hidden states [B, S, d]."""
    x, pos = _embed_inputs(params, cfg, batch)
    cos, sin = L.rope_tables(cfg, pos, _rope_dim(cfg))
    x = _run_segments(params, cfg, x, cos, sin)
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def _rope_dim(cfg: ModelConfig) -> int:
    if cfg.attn_kind == "mla":
        return cfg.qk_rope_head_dim
    return cfg.resolved_head_dim


def train_loss(params, cfg: ModelConfig, batch) -> jax.Array:
    h = forward(params, cfg, batch)
    n_text = batch["tokens"].shape[1]
    h = h[:, -n_text:]  # VLM: loss over text positions only
    logits = L.unembed(params["embedding"], cfg, h)
    return L.xent_loss(logits, batch["labels"], batch.get("loss_mask"))


def init_cache(cfg: ModelConfig, batch_size: int, capacity: int, dtype=None) -> Any:
    dtype = dtype or L.cdtype(cfg)
    cache: dict[str, Any] = {}
    for i, seg in enumerate(segments_for(cfg)):
        if cfg.attn_kind == "mla":
            cache[f"seg{i}"] = {
                "c": jnp.zeros((seg.count, batch_size, capacity, cfg.kv_lora_rank), dtype),
                "kr": jnp.zeros((seg.count, batch_size, capacity, cfg.qk_rope_head_dim), dtype),
            }
        else:
            kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            cache[f"seg{i}"] = {
                "k": jnp.zeros((seg.count, batch_size, capacity, kh, hd), dtype),
                "v": jnp.zeros((seg.count, batch_size, capacity, kh, hd), dtype),
            }
    return cache


def cache_specs(cfg: ModelConfig) -> Any:
    """Logical axes for each cache leaf (leading layer dim replicated)."""
    out: dict[str, Any] = {}
    for i, seg in enumerate(segments_for(cfg)):
        if cfg.attn_kind == "mla":
            out[f"seg{i}"] = {
                "c": (None, "batch", "kv_seq", None),
                "kr": (None, "batch", "kv_seq", None),
            }
        else:
            out[f"seg{i}"] = {
                "k": (None, "batch", "kv_seq", None, None),
                "v": (None, "batch", "kv_seq", None, None),
            }
    return out


def decode_step(params, cfg: ModelConfig, tokens, cache, pos):
    """One token for every stream: tokens [B, 1] -> (logits [B, vocab], cache)."""
    x = L.embed(params["embedding"], cfg, tokens)
    B = x.shape[0]
    p = jnp.full((B, 1), pos, jnp.int32)
    if cfg.rope_kind == "mrope":
        p = jnp.broadcast_to(p[None], (3, B, 1))
    cos, sin = L.rope_tables(cfg, p, _rope_dim(cfg))

    new_cache = {}
    for i, seg in enumerate(segments_for(cfg)):
        def body(x, xs, kind=seg.kind):
            p_l, cache_l = xs
            x, new_cache_l = _layer_decode(p_l, cfg, kind, x, cache_l, pos, cos, sin)
            return x, new_cache_l

        body = _maybe_remat(body, cfg) if False else body  # no remat at decode
        x, new_cache[f"seg{i}"] = lax.scan(
            body, x, (params[f"seg{i}"], cache[f"seg{i}"]),
            unroll=_scan_unroll(cfg),
        )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embedding"], cfg, x)
    return logits[:, 0], new_cache


def decode_step_slots(params, cfg: ModelConfig, tokens, cache, positions):
    """One token per SLOT at per-slot positions: the continuous-batching step.

    ``tokens [B, 1]``, ``positions [B] int32`` -> (logits [B, vocab], cache).
    Every slot decodes every step (fixed batch shape — no retrace as slots
    come and go); dead slots compute garbage that the engine masks out
    host-side.  With all positions equal this is bit-identical to
    :func:`decode_step` — same embed/rope/scatter/mask/unembed numerics —
    which the serve tests rely on.
    """
    x = L.embed(params["embedding"], cfg, tokens)
    B = x.shape[0]
    p = positions[:, None]  # [B, 1]
    if cfg.rope_kind == "mrope":
        p = jnp.broadcast_to(p[None], (3, B, 1))
    cos, sin = L.rope_tables(cfg, p, _rope_dim(cfg))

    new_cache = {}
    for i, seg in enumerate(segments_for(cfg)):
        def body(x, xs, kind=seg.kind):
            p_l, cache_l = xs
            x, new_cache_l = _layer_decode_slots(
                p_l, cfg, kind, x, cache_l, positions, cos, sin
            )
            return x, new_cache_l

        x, new_cache[f"seg{i}"] = lax.scan(
            body, x, (params[f"seg{i}"], cache[f"seg{i}"]),
            unroll=_scan_unroll(cfg),
        )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embedding"], cfg, x)
    return logits[:, 0], new_cache


def prefill(params, cfg: ModelConfig, batch):
    """Process the whole prompt; return last-token logits + filled cache.

    The cache is produced by re-projecting k/v per layer inside the same
    scan (ys outputs), so prefill costs one forward pass.
    """
    x, pos = _embed_inputs(params, cfg, batch)
    cos, sin = L.rope_tables(cfg, pos, _rope_dim(cfg))

    cache = {}
    for i, seg in enumerate(segments_for(cfg)):
        def body(h, p, kind=seg.kind):
            hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
            if cfg.attn_kind == "mla":
                q_nope, q_rope, c, kr = L._mla_qk(p["attn"], cfg, hn, cos, sin)
                a = L._mla_attend(p["attn"], cfg, q_nope, q_rope, c, kr, causal=True)
                out_cache = {"c": c, "kr": kr}
            else:
                q, k, v = L.attention_qkv(p["attn"], cfg, hn)
                if cfg.rope_kind in ("rope", "mrope"):
                    q = L.apply_rope(q, cos, sin)
                    k = L.apply_rope(k, cos, sin)
                k = shard(k, "batch", "kv_seq", None, None)
                v = shard(v, "batch", "kv_seq", None, None)
                a = L.attention_out(p["attn"], L.sdpa(q, k, v, causal=True))
                out_cache = {"k": k, "v": v}
            r = jnp.asarray(cfg.residual_scale, h.dtype)
            h = h + r * a
            hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
            h = h + r * _ffn(p["ffn"], cfg, kind, hn)
            return shard(h, "batch", "seq_sp", "d_model"), out_cache

        body = _maybe_remat(body, cfg)
        x, cache[f"seg{i}"] = lax.scan(
            body, x, params[f"seg{i}"], unroll=_scan_unroll(cfg)
        )

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embedding"], cfg, x[:, -1:])
    return logits[:, 0], cache


__all__ = [
    "Segment",
    "segments_for",
    "init",
    "specs",
    "forward",
    "train_loss",
    "init_cache",
    "cache_specs",
    "decode_step",
    "decode_step_slots",
    "prefill",
]
