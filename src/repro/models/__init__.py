"""The assigned-architecture model zoo (pure JAX, pytree params).

Every model exposes the same functional API through ``registry.build``:

* ``init(rng) -> params``                       (with matching sharding specs)
* ``train_loss(params, batch) -> scalar``       (teacher-forced xent)
* ``prefill(params, batch) -> (logits, cache)``
* ``decode_step(params, tokens, cache, pos) -> (logits, cache)``
* ``input_specs(shape) -> dict[str, ShapeDtypeStruct]``

Models tag activations with logical axis names (``repro.distributed.shard``)
and never reference mesh axes; the MoE layers route their expert dispatch
through :mod:`repro.core.exchange` — the paper's scheduled all-to-all as a
first-class model feature.
"""

__all__ = ["registry"]  # import repro.models.registry lazily (avoids cycles)
