"""Mamba2 (SSD — state-space duality) blocks and the pure-SSM LM.

The SSD chunked algorithm ("Transformers are SSMs", arXiv:2405.21060):
sequence split into chunks of ``Q``; within a chunk the recurrence is
evaluated as a masked attention-like quadratic form (MXU-friendly), across
chunks a linear recurrence carries the ``[H, P, N]`` state — O(L) total,
O(1)-state decode.  ``kernels/ssd_scan.py`` provides the Pallas version of
the intra-chunk quadratic; this module is the reference/fallback and the
decode path.

Tensor names follow the paper: x ``[B,L,H,P]`` values, dt ``[B,L,H]`` step
sizes, A ``[H]`` (negative) decay rates, B/C ``[B,L,G,N]`` input/output
projections (G groups broadcast over H heads).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from . import layers as L


def dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    conv_ch = d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return d_inner, nheads, conv_ch


# ----------------------------------------------------------------------------
# Params.
# ----------------------------------------------------------------------------

def init_mamba_block(key, cfg: ModelConfig) -> Any:
    d = cfg.d_model
    d_inner, H, conv_ch = dims(cfg)
    N, G, K = cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_conv
    dt = L.pdtype(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * G * N + H  # z, x, B, C, dt
    # dt bias: inverse softplus of uniform [1e-3, 1e-1]
    u = jax.random.uniform(ks[2], (H,), minval=math.log(1e-3), maxval=math.log(1e-1))
    dt_bias = jnp.log(jnp.expm1(jnp.exp(u)))
    return {
        "in_proj": L.he_init(ks[0], (d, proj_out), d, dt),
        "conv_w": L._normal(ks[1], (K, conv_ch), 1.0 / math.sqrt(K), dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(
            jax.random.uniform(ks[3], (H,), minval=1.0, maxval=16.0)
        ).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "gate_norm": L.init_rmsnorm(d_inner, dt),
        "out_proj": L.he_init(jax.random.fold_in(key, 9), (d_inner, d), d_inner, dt),
    }


def specs_mamba_block(cfg: ModelConfig) -> Any:
    return {
        "in_proj": ("fsdp", "conv_dim"),
        "conv_w": (None, "conv_dim"),
        "conv_b": ("conv_dim",),
        "dt_bias": ("ssm_heads",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "gate_norm": L.specs_rmsnorm(),
        "out_proj": ("conv_dim", "fsdp"),
    }


# ----------------------------------------------------------------------------
# SSD chunked scan (training/prefill).
# ----------------------------------------------------------------------------

def ssd_chunked(
    x: jax.Array,    # [B, Lq, H, P]
    dt: jax.Array,   # [B, Lq, H]  (already softplus'd, f32)
    A: jax.Array,    # [H] negative, f32
    Bm: jax.Array,   # [B, Lq, G, N]
    Cm: jax.Array,   # [B, Lq, G, N]
    chunk: int,
    initial_state: jax.Array | None = None,  # [B, H, P, N]
    use_kernel: bool = False,
):
    """Returns (y [B,Lq,H,P], final_state [B,H,P,N]).  f32 recurrence."""
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, initial_state=initial_state)
    B_, Lq, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    R = H // G
    assert Lq % chunk == 0, (Lq, chunk)
    nc = Lq // chunk
    f32 = jnp.float32

    # chunk-major layout for the scan: [nc, B, Q, ...]
    xc = jnp.moveaxis(x.reshape(B_, nc, chunk, G, R, P), 1, 0).astype(f32)
    dtc = jnp.moveaxis(dt.reshape(B_, nc, chunk, G, R), 1, 0).astype(f32)
    Bc = jnp.moveaxis(Bm.reshape(B_, nc, chunk, G, N), 1, 0).astype(f32)
    Cc = jnp.moveaxis(Cm.reshape(B_, nc, chunk, G, N), 1, 0).astype(f32)

    if initial_state is None:
        s0 = jnp.zeros((B_, G, R, P, N), f32)
    else:
        s0 = initial_state.reshape(B_, G, R, P, N).astype(f32)

    ii = jnp.arange(chunk)
    causal = ii[:, None] >= ii[None, :]  # [Q, Q]
    A_gr = A.reshape(G, R)

    def chunk_body(s, inp):
        """One chunk: intra-chunk quadratic + inter-chunk read + state update.

        Processing chunk-by-chunk keeps the [B, Q, Q, G, R] decay tensor
        transient per step instead of materialized for all chunks at once —
        the memory profile the Pallas kernel has by construction.
        """
        xq, dtq, Bq, Cq = inp  # [B,Q,G,R,P], [B,Q,G,R], [B,Q,G,N] ×2
        a = dtq * A_gr  # [B,Q,G,R]
        a_cs = jnp.cumsum(a, axis=1)

        scores = jnp.einsum("bign,bjgn->bgij", Cq, Bq)  # [B,G,Q,Q]
        seg_log = a_cs[:, :, None] - a_cs[:, None]      # [B,Q,Q,G,R]
        decay = jnp.exp(
            jnp.where(causal[None, :, :, None, None], seg_log, -jnp.inf)
        )
        m = jnp.einsum("bgij,bijgr,bjgr->bijgr", scores, decay, dtq)
        y = jnp.einsum("bijgr,bjgrp->bigrp", m, xq)

        # inter-chunk read of the entering state
        y = y + jnp.einsum("bign,bigr,bgrpn->bigrp", Cq, jnp.exp(a_cs), s)

        # state update
        a_last = a_cs[:, -1]  # [B,G,R]
        w = jnp.exp(a_last[:, None] - a_cs) * dtq  # [B,Q,G,R]
        upd = jnp.einsum("bjgr,bjgn,bjgrp->bgrpn", w, Bq, xq)
        s = s * jnp.exp(a_last)[..., None, None] + upd
        return s, y

    final, ys = lax.scan(chunk_body, s0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, Lq, H, P)
    return y.astype(x.dtype), final.reshape(B_, H, P, N)


def ssd_step(
    x: jax.Array,   # [B, H, P]
    dt: jax.Array,  # [B, H]
    A: jax.Array,   # [H]
    Bm: jax.Array,  # [B, G, N]
    Cm: jax.Array,  # [B, G, N]
    state: jax.Array,  # [B, H, P, N] f32
):
    """Single-token recurrence (decode): O(1) in context length."""
    B_, H, P = x.shape
    G = Bm.shape[1]
    R = H // G
    f32 = jnp.float32
    xg = x.reshape(B_, G, R, P).astype(f32)
    dtg = dt.reshape(B_, G, R).astype(f32)
    dec = jnp.exp(dtg * A.reshape(G, R))
    sg = state.reshape(B_, G, R, P, N := state.shape[-1])
    upd = jnp.einsum("bgr,bgn,bgrp->bgrpn", dtg, Bm.astype(f32), xg)
    sg = sg * dec[..., None, None] + upd
    y = jnp.einsum("bgn,bgrpn->bgrp", Cm.astype(f32), sg)
    return y.reshape(B_, H, P).astype(x.dtype), sg.reshape(B_, H, P, -1)


# ----------------------------------------------------------------------------
# Conv + block plumbing.
# ----------------------------------------------------------------------------

def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    d_inner, H, _ = dims(cfg)
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * G * N], axis=-1)
    return z, xBC, dt


def _causal_conv(w: jax.Array, b: jax.Array, xBC: jax.Array) -> jax.Array:
    """Depthwise causal conv over [B, Lq, ch] with kernel [K, ch]."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        pad[:, k : k + xBC.shape[1], :] * w[k][None, None, :] for k in range(K)
    )
    return jax.nn.silu(out + b[None, None, :])


def mamba_block(
    params: Any,
    cfg: ModelConfig,
    x: jax.Array,  # [B, Lq, d_model]
    initial_state: jax.Array | None = None,
    return_state: bool = False,
    use_kernel: bool = False,
):
    """Full-sequence mamba2 block (train/prefill)."""
    d_inner, H, conv_ch = dims(cfg)
    G, N, P = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_head_dim
    dtype = x.dtype
    B_, Lq, _ = x.shape

    zxbcdt = jnp.einsum("bld,dk->blk", x, params["in_proj"].astype(dtype))
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(params["conv_w"].astype(dtype), params["conv_b"].astype(dtype), xBC)
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + G * N], axis=-1)
    xs = shard(xs.reshape(B_, Lq, H, P), "batch", "seq", "ssm_heads", None)
    Bm = Bm.reshape(B_, Lq, G, N)
    Cm = Cm.reshape(B_, Lq, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    y, final = ssd_chunked(
        xs, dt, A, Bm, Cm, cfg.ssm_chunk, initial_state, use_kernel=use_kernel
    )
    y = y + (params["D"].astype(dtype)[None, None, :, None] * xs)
    y = y.reshape(B_, Lq, d_inner)
    y = L.rmsnorm(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("blk,kd->bld", y, params["out_proj"].astype(dtype))
    if return_state:
        conv_state = None
        if cfg.ssm_conv > 1:
            # last K-1 *pre-conv* inputs (pad left if Lq < K-1)
            zxbcdt_tail = zxbcdt[:, -(cfg.ssm_conv - 1) :, :]
            _, xBC_tail, _ = _split_proj(cfg, zxbcdt_tail)
            conv_state = xBC_tail
        return out, {"ssm": final, "conv": conv_state}
    return out


def mamba_block_step(params: Any, cfg: ModelConfig, x: jax.Array, state: Any):
    """Single-token step: x [B, 1, d_model], state {"ssm", "conv"}."""
    d_inner, H, conv_ch = dims(cfg)
    G, N, P = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_head_dim
    dtype = x.dtype
    B_ = x.shape[0]
    K = cfg.ssm_conv

    zxbcdt = jnp.einsum("bld,dk->blk", x, params["in_proj"].astype(dtype))[:, 0]
    d_zx = d_inner
    z, xBC_new, dt_raw = (
        zxbcdt[:, :d_zx],
        zxbcdt[:, d_zx : 2 * d_inner + 2 * G * N],
        zxbcdt[:, 2 * d_inner + 2 * G * N :],
    )
    # conv over the rolling window [B, K-1, ch] + the new input
    window = jnp.concatenate([state["conv"], xBC_new[:, None, :]], axis=1)  # [B,K,ch]
    w = params["conv_w"].astype(dtype)
    xBC = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"].astype(dtype)
    )
    new_conv = window[:, 1:, :]

    xs, Bm, Cm = (
        xBC[:, :d_inner],
        xBC[:, d_inner : d_inner + G * N],
        xBC[:, d_inner + G * N :],
    )
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, new_ssm = ssd_step(
        xs.reshape(B_, H, P), dt, A, Bm.reshape(B_, G, N), Cm.reshape(B_, G, N),
        state["ssm"],
    )
    y = y + params["D"].astype(dtype)[None, :, None] * xs.reshape(B_, H, P)
    y = y.reshape(B_, d_inner)
    y = L.rmsnorm(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y, params["out_proj"].astype(dtype))[:, None, :]
    return out, {"ssm": new_ssm, "conv": new_conv}


# ----------------------------------------------------------------------------
# The pure-SSM LM (mamba2-1.3b): embed -> [norm -> mamba]*L -> norm -> logits.
# ----------------------------------------------------------------------------

def init(key, cfg: ModelConfig) -> Any:
    ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embedding": L.init_embedding(ks[0], cfg),
        "layers": jax.vmap(lambda k: {
            "norm": L.init_rmsnorm(cfg.d_model, L.pdtype(cfg)),
            "mamba": init_mamba_block(k, cfg),
        })(layer_keys),
        "final_norm": L.init_rmsnorm(cfg.d_model, L.pdtype(cfg)),
    }


def specs(cfg: ModelConfig) -> Any:
    from .transformer import _stack_specs

    return {
        "embedding": L.specs_embedding(cfg),
        "layers": _stack_specs({
            "norm": L.specs_rmsnorm(),
            "mamba": specs_mamba_block(cfg),
        }),
        "final_norm": L.specs_rmsnorm(),
    }


def _body(cfg: ModelConfig, use_kernel=False):
    def fwd(x, p):
        h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
        x = x + mamba_block(p["mamba"], cfg, h, use_kernel=use_kernel)
        return shard(x, "batch", "seq_sp", "d_model"), None

    return fwd


def forward(params, cfg: ModelConfig, batch) -> jax.Array:
    from .transformer import _maybe_remat

    x = L.embed(params["embedding"], cfg, batch["tokens"])
    x = shard(x, "batch", "seq_sp", "d_model")
    x, _ = lax.scan(_maybe_remat(_body(cfg), cfg), x, params["layers"])
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def train_loss(params, cfg: ModelConfig, batch) -> jax.Array:
    h = forward(params, cfg, batch)
    logits = L.unembed(params["embedding"], cfg, h)
    return L.xent_loss(logits, batch["labels"], batch.get("loss_mask"))


def init_cache(cfg: ModelConfig, batch_size: int, capacity: int, dtype=None) -> Any:
    """SSM cache is O(1) in context length (the long_500k story)."""
    del capacity
    dtype = dtype or L.cdtype(cfg)
    d_inner, H, conv_ch = dims(cfg)
    return {
        "ssm": jnp.zeros(
            (cfg.num_layers, batch_size, H, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        ),
        "conv": jnp.zeros(
            (cfg.num_layers, batch_size, cfg.ssm_conv - 1, conv_ch), dtype
        ),
    }


def cache_specs(cfg: ModelConfig) -> Any:
    return {
        "ssm": (None, "batch", "ssm_heads", None, None),
        "conv": (None, "batch", None, "conv_dim"),
    }


def decode_step(params, cfg: ModelConfig, tokens, cache, pos):
    del pos  # SSM state carries the context; position is implicit
    x = L.embed(params["embedding"], cfg, tokens)

    def body(x, xs):
        p, st = xs
        h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
        o, new_st = mamba_block_step(p["mamba"], cfg, h, st)
        return x + o, new_st

    x, new_cache = lax.scan(
        body, x, (params["layers"], cache)
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embedding"], cfg, x)
    return logits[:, 0], new_cache


def prefill(params, cfg: ModelConfig, batch):
    """Run the prompt through the chunked scan, keep per-layer final states."""
    x = L.embed(params["embedding"], cfg, batch["tokens"])

    def body(x, p):
        h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
        o, st = mamba_block(p["mamba"], cfg, h, return_state=True)
        return x + o, st

    from .transformer import _maybe_remat

    x, states = lax.scan(_maybe_remat(body, cfg), x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embedding"], cfg, x[:, -1:])
    return logits[:, 0], states


__all__ = [
    "dims",
    "init_mamba_block",
    "specs_mamba_block",
    "ssd_chunked",
    "ssd_step",
    "mamba_block",
    "mamba_block_step",
    "init",
    "specs",
    "forward",
    "train_loss",
    "init_cache",
    "cache_specs",
    "decode_step",
    "prefill",
]
