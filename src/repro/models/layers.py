"""Shared neural layers: norms, RoPE/M-RoPE, GQA + MLA attention, MLPs.

Conventions
-----------
* params are plain dicts of ``jnp`` arrays; every ``init_*`` has a matching
  ``specs_*`` returning the same pytree of logical-axis tuples (consumed by
  ``registry.param_shardings``).
* activations: ``[batch, seq, d_model]``; attention heads ``[B, S, H, Dh]``.
* ``positions`` are int32 ``[B, S]`` (RoPE) or ``[3, B, S]`` (M-RoPE).
* caches are dicts of arrays with a leading layer dim (stacked for scan).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard

Params = Any  # nested dict[str, jax.Array]
Specs = Any   # same structure, leaves are tuples of logical-axis names


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def he_init(key, shape, fan_in, dtype):
    return _normal(key, shape, 1.0 / math.sqrt(max(fan_in, 1)), dtype)


# ----------------------------------------------------------------------------
# Norms.
# ----------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def specs_rmsnorm() -> Specs:
    return {"scale": (None,)}


def rmsnorm(params: Params, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def specs_layernorm() -> Specs:
    return {"scale": (None,), "bias": (None,)}


def layernorm(params: Params, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dt)


# ----------------------------------------------------------------------------
# RoPE / M-RoPE.
# ----------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables ``[..., head_dim/2]`` for int positions ``[...]``."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., half]
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(
    positions: jax.Array, head_dim: int, theta: float, sections: tuple[int, int, int]
) -> tuple[jax.Array, jax.Array]:
    """Qwen2-VL multimodal RoPE: 3 position streams (t, h, w) feed disjoint
    frequency sections.  ``positions: [3, B, S]`` -> cos/sin ``[B, S, half]``.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    cos_t, sin_t = rope_angles(positions, head_dim, theta)  # [3, B, S, half]
    # section id of each frequency index: [half] in {0,1,2}
    sec = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=half)
    cos = jnp.take_along_axis(
        jnp.moveaxis(cos_t, 0, -1), sec[None, None, :, None], axis=-1
    )[..., 0]
    sin = jnp.take_along_axis(
        jnp.moveaxis(sin_t, 0, -1), sec[None, None, :, None], axis=-1
    )[..., 0]
    return cos, sin


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate ``x: [B, S, H, Dh]`` with cos/sin ``[B, S, Dh/2]`` (half-split
    layout, as used by llama/qwen/deepseek)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def positions_for(cfg: ModelConfig, batch: dict) -> jax.Array:
    """Default position ids from the token grid (overridable via batch)."""
    if "positions" in batch:
        return batch["positions"]
    tokens = batch["tokens"]
    B, S = tokens.shape
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    if cfg.rope_kind == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def rope_tables(cfg: ModelConfig, positions: jax.Array, head_dim: int):
    if cfg.rope_kind == "mrope":
        return mrope_angles(positions, head_dim, cfg.rope_theta, cfg.mrope_sections)
    return rope_angles(positions, head_dim, cfg.rope_theta)


# ----------------------------------------------------------------------------
# Scaled-dot-product attention core (masked, GQA-aware).
# ----------------------------------------------------------------------------

def sdpa(
    q: jax.Array,       # [B, Sq, H, Dh]
    k: jax.Array,       # [B, Sk, KH, Dh]
    v: jax.Array,       # [B, Sk, KH, Dv]
    *,
    causal: bool,
    q_offset: jax.Array | int = 0,  # absolute position of q[:, 0]
    kv_valid_len: jax.Array | None = None,  # mask k/v positions >= this
    scale: float | None = None,
) -> jax.Array:
    """Reference attention used on every non-kernel path.

    GQA: ``H`` must be a multiple of ``KH``; query heads are grouped.  The
    softmax runs in f32.  Sk is the (static) cache capacity at decode; the
    dynamic fill level arrives via ``kv_valid_len`` — a scalar (one fill
    level for the whole batch, the static-batch decode) or a ``[B]`` vector
    (per-slot fill levels, the continuous-batching decode).
    """
    B, Sq, H, Dh = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, Sq, KH, G, Dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    mask = jnp.ones((Sq, Sk), jnp.bool_)
    if causal:
        qpos = jnp.arange(Sq)[:, None] + q_offset
        kpos = jnp.arange(Sk)[None, :]
        mask = kpos <= qpos
    bmask = mask[None, None, None]  # broadcast over [B, KH, G, ...]
    if kv_valid_len is not None:
        kvl = jnp.asarray(kv_valid_len)
        if kvl.ndim == 0:
            bmask = bmask & (jnp.arange(Sk)[None, :] < kvl)[None, None, None]
        else:  # per-slot valid lengths [B]
            valid = jnp.arange(Sk)[None, :] < kvl[:, None]          # [B, Sk]
            bmask = bmask & valid[:, None, None, None, :]
    logits = jnp.where(bmask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, Sq, H, v.shape[-1])


def chunked_sdpa(
    q: jax.Array,       # [B, S, H, Dh]
    k: jax.Array,       # [B, S, KH, Dh]
    v: jax.Array,
    *,
    causal: bool,
    q_block: int = 512,
    scale: float | None = None,
) -> jax.Array:
    """Query-block-chunked attention: O(S·q_block) live logits.

    The scan body is checkpointed, so the backward pass recomputes each
    block's [bq, S] logits instead of storing all S² — a flash-style memory
    profile in pure jnp (differentiable; the Pallas kernel handles the
    non-autodiff inference path).
    """
    B, S, H, Dh = q.shape
    bq = min(q_block, S)
    if S % bq != 0:
        return sdpa(q, k, v, causal=causal, scale=scale)
    nq = S // bq
    qb = jnp.moveaxis(q.reshape(B, nq, bq, H, Dh), 1, 0)  # [nq, B, bq, H, Dh]

    @jax.checkpoint
    def body(_, inp):
        qi, i = inp
        out = sdpa(qi, k, v, causal=causal, q_offset=i * bq, scale=scale)
        return None, out

    _, outs = lax.scan(body, None, (qb, jnp.arange(nq)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, Dh)


def attention_core(
    cfg: ModelConfig,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
) -> jax.Array:
    """Select the attention implementation (cfg.attn_impl).

    auto: plain sdpa for short sequences, query-chunked beyond — keeps the
    logits working set bounded at 32k prefill.  flash: the Pallas kernel
    (custom_vjp; backward recomputes via the chunked path).
    """
    S = q.shape[1]
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "sdpa" if S <= 1024 else "chunked"
    if impl == "flash":
        from repro.kernels import ops as kops

        return kops.flash_attention_vjp(q, k, v, causal=causal)
    if impl == "chunked":
        return chunked_sdpa(q, k, v, causal=causal, q_block=cfg.attn_q_block)
    return sdpa(q, k, v, causal=causal)


# ----------------------------------------------------------------------------
# GQA attention block (llama/qwen family).
# ----------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig) -> Params:
    d, H, KH, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = pdtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": he_init(ks[0], (d, H, Dh), d, dt),
        "wk": he_init(ks[1], (d, KH, Dh), d, dt),
        "wv": he_init(ks[2], (d, KH, Dh), d, dt),
        "wo": he_init(ks[3], (H, Dh, d), H * Dh, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, Dh), dt)
        p["bk"] = jnp.zeros((KH, Dh), dt)
        p["bv"] = jnp.zeros((KH, Dh), dt)
    return p


def specs_attention(cfg: ModelConfig) -> Specs:
    s = {
        "wq": ("fsdp", "heads", None),
        "wk": ("fsdp", "kv_heads", None),
        "wv": ("fsdp", "kv_heads", None),
        "wo": ("heads", None, "fsdp"),
    }
    if cfg.qkv_bias:
        s["bq"] = ("heads", None)
        s["bk"] = ("kv_heads", None)
        s["bv"] = ("kv_heads", None)
    return s


def attention_qkv(params: Params, cfg: ModelConfig, x: jax.Array):
    """Project to q/k/v (+bias) in compute dtype."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    return q, k, v


def attention_out(params: Params, x: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", x, params["wo"].astype(x.dtype))


def attention_block(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,
    cos: jax.Array,
    sin: jax.Array,
    *,
    causal: bool = True,
    use_flash: bool = False,
) -> jax.Array:
    """Full-sequence (train/prefill) GQA attention."""
    q, k, v = attention_qkv(params, cfg, x)
    if cfg.rope_kind in ("rope", "mrope"):
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    del use_flash  # impl selection lives in cfg.attn_impl (attention_core)
    o = attention_core(cfg, q, k, v, causal=causal)
    o = shard(o, "batch", "seq", "heads", None)
    return attention_out(params, o)


def attention_decode(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,            # [B, 1, d]
    cache_k: jax.Array,      # [B, S, KH, Dh]
    cache_v: jax.Array,
    pos: jax.Array,          # scalar int32: write position / context length
    cos: jax.Array,
    sin: jax.Array,
):
    """One decode step; returns (out, new_cache_k, new_cache_v).

    The KV cache is sharded on its sequence dim (``kv_seq -> model``):
    flash-decode style — each model shard scores its cache slice and GSPMD
    combines the sharded softmax (the TPU analogue of the paper's rule that
    big scans stay on the fast network level).
    """
    q, k, v = attention_qkv(params, cfg, x)
    if cfg.rope_kind in ("rope", "mrope"):
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    cache_k = lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, 1)
    cache_v = lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, 1)
    cache_k = shard(cache_k, "batch", "kv_seq", None, None)
    cache_v = shard(cache_v, "batch", "kv_seq", None, None)
    o = sdpa(q, cache_k, cache_v, causal=False, kv_valid_len=pos + 1)
    return attention_out(params, o), cache_k, cache_v


def attention_decode_slots(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,            # [B, 1, d]
    cache_k: jax.Array,      # [B, S, KH, Dh]
    cache_v: jax.Array,
    positions: jax.Array,    # [B] int32: per-slot write position / context len
    cos: jax.Array,
    sin: jax.Array,
):
    """One decode step with a per-slot position vector (continuous batching).

    Identical numerics to :func:`attention_decode` when every slot sits at
    the same position — the scatter writes the same bytes the
    ``dynamic_update_slice`` would, and the per-slot ``kv_valid_len`` builds
    the same mask — which is what keeps the continuous engine bit-identical
    to the static one on uniform batches (tests/test_serve.py).
    """
    q, k, v = attention_qkv(params, cfg, x)
    if cfg.rope_kind in ("rope", "mrope"):
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    b = jnp.arange(x.shape[0])
    cache_k = cache_k.at[b, positions].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[b, positions].set(v[:, 0].astype(cache_v.dtype))
    cache_k = shard(cache_k, "batch", "kv_seq", None, None)
    cache_v = shard(cache_v, "batch", "kv_seq", None, None)
    o = sdpa(q, cache_k, cache_v, causal=False, kv_valid_len=positions + 1)
    return attention_out(params, o), cache_k, cache_v


# ----------------------------------------------------------------------------
# MLA attention (deepseek-v2): low-rank compressed KV cache.
# ----------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig) -> Params:
    d, H = cfg.d_model, cfg.num_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = pdtype(cfg)
    ks = jax.random.split(key, 5)
    return {
        # queries: full-rank (v2-lite has no q compression)
        "wq": he_init(ks[0], (d, H, dn + dr), d, dt),
        # kv: compress to r (+ shared rope dims), then per-head expand
        "wkv_a": he_init(ks[1], (d, r + dr), d, dt),
        "kv_norm": init_rmsnorm(r, dt),
        "wk_b": he_init(ks[2], (r, H, dn), r, dt),
        "wv_b": he_init(ks[3], (r, H, dv), r, dt),
        "wo": he_init(ks[4], (H, dv, d), H * dv, dt),
    }


def specs_mla(cfg: ModelConfig) -> Specs:
    return {
        "wq": ("fsdp", "heads", None),
        "wkv_a": ("fsdp", None),
        "kv_norm": specs_rmsnorm(),
        "wk_b": ("fsdp", "heads", None),
        "wv_b": ("fsdp", "heads", None),
        "wo": ("heads", None, "fsdp"),
    }


def _mla_qk(params, cfg: ModelConfig, x, cos, sin):
    """Shared query path + compressed kv path (train and decode)."""
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, cos, sin)
    ckv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"].astype(dt))
    c, k_rope = ckv[..., : cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank :]
    c = rmsnorm(params["kv_norm"], c, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # single shared rope head
    return q_nope, q_rope, c, k_rope[:, :, 0, :]


def _mla_attend_block(params, cfg: ModelConfig, q_nope, q_rope, c, k_rope, *, causal, q_offset=0, kv_valid_len=None):
    """Attention in the compressed space: absorb wk_b into the query.

    scores = q_nope . (c @ wk_b) + q_rope . k_rope; computing
    ``q_absorbed = q_nope @ wk_b^T`` instead keeps the cache compressed
    (this is MLA's trick; same FLOPs order, r-dim contraction).
    """
    dt = q_nope.dtype
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, params["wk_b"].astype(dt))
    logits = jnp.einsum("bshr,btr->bhst", q_abs, c)
    logits = logits + jnp.einsum("bshk,btk->bhst", q_rope, k_rope)
    logits = logits.astype(jnp.float32) * scale
    Sq, Sk = logits.shape[2], logits.shape[3]
    mask = jnp.ones((Sq, Sk), jnp.bool_)
    if causal:
        mask = jnp.arange(Sk)[None, :] <= (jnp.arange(Sq)[:, None] + q_offset)
    bmask = mask[None, None]  # broadcast over [B, H, ...]
    if kv_valid_len is not None:
        kvl = jnp.asarray(kv_valid_len)
        if kvl.ndim == 0:
            bmask = bmask & (jnp.arange(Sk)[None, :] < kvl)[None, None]
        else:  # per-slot valid lengths [B]
            bmask = bmask & (jnp.arange(Sk)[None, :] < kvl[:, None])[:, None, None, :]
    logits = jnp.where(bmask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(dt)
    o_c = jnp.einsum("bhst,btr->bshr", w, c)  # attend over compressed values
    o = jnp.einsum("bshr,rhv->bshv", o_c, params["wv_b"].astype(dt))
    return jnp.einsum("bshv,hvd->bsd", o, params["wo"].astype(dt))


def _mla_attend(params, cfg: ModelConfig, q_nope, q_rope, c, k_rope, *, causal, q_offset=0, kv_valid_len=None):
    """Q-block-chunked MLA attention (same memory story as chunked_sdpa)."""
    Sq = q_nope.shape[1]
    bq = cfg.attn_q_block
    if (
        cfg.attn_impl == "sdpa"
        or Sq % bq != 0
        or Sq == bq
        or (cfg.attn_impl == "auto" and Sq <= max(bq, 1024))
    ):
        return _mla_attend_block(
            params, cfg, q_nope, q_rope, c, k_rope,
            causal=causal, q_offset=q_offset, kv_valid_len=kv_valid_len,
        )
    nq = Sq // bq
    B = q_nope.shape[0]
    qn = jnp.moveaxis(q_nope.reshape(B, nq, bq, *q_nope.shape[2:]), 1, 0)
    qr = jnp.moveaxis(q_rope.reshape(B, nq, bq, *q_rope.shape[2:]), 1, 0)

    @jax.checkpoint
    def body(_, inp):
        qni, qri, i = inp
        out = _mla_attend_block(
            params, cfg, qni, qri, c, k_rope,
            causal=causal, q_offset=i * bq + q_offset, kv_valid_len=kv_valid_len,
        )
        return None, out

    _, outs = lax.scan(body, None, (qn, qr, jnp.arange(nq)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, -1)


def mla_block(params, cfg: ModelConfig, x, cos, sin, *, causal=True):
    q_nope, q_rope, c, k_rope = _mla_qk(params, cfg, x, cos, sin)
    return _mla_attend(params, cfg, q_nope, q_rope, c, k_rope, causal=causal)


def mla_decode(params, cfg: ModelConfig, x, cache_c, cache_kr, pos, cos, sin):
    """Decode with the compressed cache: c ``[B,S,r]``, k_rope ``[B,S,dr]``."""
    q_nope, q_rope, c_new, kr_new = _mla_qk(params, cfg, x, cos, sin)
    cache_c = lax.dynamic_update_slice_in_dim(cache_c, c_new.astype(cache_c.dtype), pos, 1)
    cache_kr = lax.dynamic_update_slice_in_dim(cache_kr, kr_new.astype(cache_kr.dtype), pos, 1)
    cache_c = shard(cache_c, "batch", "kv_seq", None)
    cache_kr = shard(cache_kr, "batch", "kv_seq", None)
    out = _mla_attend(
        params, cfg, q_nope, q_rope, cache_c, cache_kr,
        causal=False, kv_valid_len=pos + 1,
    )
    return out, cache_c, cache_kr


def mla_decode_slots(params, cfg: ModelConfig, x, cache_c, cache_kr, positions, cos, sin):
    """MLA decode with per-slot positions ``[B]`` (continuous batching)."""
    q_nope, q_rope, c_new, kr_new = _mla_qk(params, cfg, x, cos, sin)
    b = jnp.arange(x.shape[0])
    cache_c = cache_c.at[b, positions].set(c_new[:, 0].astype(cache_c.dtype))
    cache_kr = cache_kr.at[b, positions].set(kr_new[:, 0].astype(cache_kr.dtype))
    cache_c = shard(cache_c, "batch", "kv_seq", None)
    cache_kr = shard(cache_kr, "batch", "kv_seq", None)
    out = _mla_attend(
        params, cfg, q_nope, q_rope, cache_c, cache_kr,
        causal=False, kv_valid_len=positions + 1,
    )
    return out, cache_c, cache_kr


# ----------------------------------------------------------------------------
# MLPs.
# ----------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = pdtype(cfg)
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu":
        return {
            "w_in": he_init(ks[0], (d, f), d, dt),
            "b_in": jnp.zeros((f,), dt),
            "w_out": he_init(ks[1], (f, d), f, dt),
            "b_out": jnp.zeros((d,), dt),
        }
    return {
        "w_gate": he_init(ks[0], (d, f), d, dt),
        "w_up": he_init(ks[1], (d, f), d, dt),
        "w_down": he_init(ks[2], (f, d), f, dt),
    }


def specs_mlp(cfg: ModelConfig) -> Specs:
    if cfg.act == "gelu":
        return {
            "w_in": ("fsdp", "d_ff"),
            "b_in": ("d_ff",),
            "w_out": ("d_ff", "fsdp"),
            "b_out": (None,),
        }
    return {
        "w_gate": ("fsdp", "d_ff"),
        "w_up": ("fsdp", "d_ff"),
        "w_down": ("d_ff", "fsdp"),
    }


def mlp_block(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dt = x.dtype
    if cfg.act == "gelu":
        h = jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(dt)) + params["b_in"].astype(dt)
        h = jax.nn.gelu(h)
        h = shard(h, "batch", "seq", "d_ff")
        return jnp.einsum("bsf,fd->bsd", h, params["w_out"].astype(dt)) + params["b_out"].astype(dt)
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    h = shard(h, "batch", "seq", "d_ff")
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(dt))


# ----------------------------------------------------------------------------
# Embedding / unembedding / loss.
# ----------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig) -> Params:
    dt = pdtype(cfg)
    p = {"table": _normal(key, (cfg.vocab_size, cfg.d_model), 0.02, dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = he_init(
            jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab_size), cfg.d_model, dt
        )
    return p


def specs_embedding(cfg: ModelConfig) -> Specs:
    s = {"table": ("vocab", "fsdp")}
    if not cfg.tie_embeddings:
        s["unembed"] = ("fsdp", "vocab")
    return s


def embed(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    x = params["table"].astype(cdtype(cfg))[tokens]
    return x * jnp.asarray(cfg.emb_scale, x.dtype)


def unembed(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dt = x.dtype
    x = x * jnp.asarray(cfg.logits_scale, dt)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["table"].astype(dt))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(dt))
    return shard(logits, "batch", "seq", "vocab")


def xent_loss(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token cross entropy in f32 (numerically stable)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


__all__ = [k for k in dir() if not k.startswith("_")]
