"""Uniform functional API over the model zoo + input specs for the dry-run.

``build(cfg)`` returns a :class:`ModelApi` whose members close over ``cfg``.
``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of that (arch × shape) cell — weak-type-correct, shardable, no
device allocation — plus the matching logical-axis trees used by the
launcher to build in_shardings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec

# Number of image-patch positions the VLM stub prepends (qwen2-vl dynamic
# resolution -> fixed budget here; the frontend itself is out of scope).
VLM_PATCHES = 1024


def _module(cfg: ModelConfig):
    if cfg.family == "ssm":
        from . import mamba2 as m
    elif cfg.family == "hybrid":
        from . import zamba2 as m
    elif cfg.family == "encdec":
        from . import whisper as m
    else:  # dense / moe / vlm share the transformer stack
        from . import transformer as m
    return m


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    train_loss: Callable[[Any, dict], jax.Array]
    prefill: Callable[[Any, dict], tuple[jax.Array, Any]]
    decode_step: Callable[[Any, jax.Array, Any, jax.Array], tuple[jax.Array, Any]]
    init_cache: Callable[[int, int], Any]
    param_specs: Any           # pytree of logical-axis tuples (matches init)
    cache_spec_fn: Callable[[], Any]
    # Per-slot decode (continuous batching): (params, tokens [B,1], cache,
    # positions [B]) -> (logits, cache).  None for families whose cache is
    # not a per-position KV map (ssm/hybrid/encdec) — the continuous engine
    # rejects those with an actionable error.
    decode_step_slots: Callable[[Any, jax.Array, Any, jax.Array], tuple[jax.Array, Any]] | None = None


def build(cfg: ModelConfig) -> ModelApi:
    m = _module(cfg)
    slots = getattr(m, "decode_step_slots", None)
    return ModelApi(
        cfg=cfg,
        init=lambda key: m.init(key, cfg),
        train_loss=lambda params, batch: m.train_loss(params, cfg, batch),
        prefill=lambda params, batch: m.prefill(params, cfg, batch),
        decode_step=lambda params, tokens, cache, pos: m.decode_step(
            params, cfg, tokens, cache, pos
        ),
        init_cache=lambda bs, cap: m.init_cache(cfg, bs, cap),
        param_specs=m.specs(cfg),
        cache_spec_fn=lambda: m.cache_specs(cfg),
        decode_step_slots=(
            None if slots is None
            else lambda params, tokens, cache, positions: slots(
                params, cfg, tokens, cache, positions
            )
        ),
    )


# ----------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins) per (arch × shape).
# ----------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> tuple[dict, dict]:
    """(specs, logical_axes) for the batch argument of the step function."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)

    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            specs = {
                "frames": _sds((B, S, cfg.d_model), act),
                "tokens": _sds((B, S), i32),
            }
            axes = {
                "frames": ("batch", "seq", "d_model"),
                "tokens": ("batch", "seq"),
            }
        elif cfg.family == "vlm":
            P = min(VLM_PATCHES, S // 2)
            specs = {
                "tokens": _sds((B, S - P), i32),
                "patches": _sds((B, P, cfg.d_model), act),
            }
            axes = {
                "tokens": ("batch", "seq"),
                "patches": ("batch", "seq", "d_model"),
            }
        else:
            specs = {"tokens": _sds((B, S), i32)}
            axes = {"tokens": ("batch", "seq")}
        if shape.kind == "train":
            n_text = specs["tokens"].shape[1]
            specs["labels"] = _sds((B, n_text), i32)
            axes["labels"] = ("batch", "seq")
        return specs, axes

    # decode: one new token per stream against a cache of length S
    specs = {"tokens": _sds((B, 1), i32)}
    axes = {"tokens": ("batch", None)}
    return specs, axes


def cache_shape_specs(cfg: ModelConfig, shape: ShapeSpec) -> tuple[Any, Any]:
    """(ShapeDtypeStruct tree, logical axes tree) for the decode cache."""
    m = _module(cfg)
    tree = jax.eval_shape(lambda: m.init_cache(cfg, shape.global_batch, shape.seq_len))
    return tree, m.cache_specs(cfg)


def param_shape_specs(cfg: ModelConfig) -> tuple[Any, Any]:
    """(ShapeDtypeStruct tree, logical axes tree) for the params."""
    m = _module(cfg)
    tree = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0), cfg))
    return tree, m.specs(cfg)


# ----------------------------------------------------------------------------
# Parameter counting (roofline MODEL_FLOPS = 6 * N * D).
# ----------------------------------------------------------------------------

def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    tree, specs = param_shape_specs(cfg)
    # jax.tree.leaves_with_path needs jax>=0.4.38; the tree_util spelling
    # works on every supported version
    flat = jax.tree_util.tree_leaves_with_path(tree)
    total = 0
    for path, leaf in flat:
        n = leaf.size
        if active_only and cfg.num_experts:
            keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
            if any(k in ("w_gate", "w_up", "w_down") for k in keys) and "ffn" in keys:
                n = n * cfg.top_k // cfg.num_experts
        total += n
    return int(total)


__all__ = [
    "ModelApi",
    "build",
    "input_specs",
    "cache_shape_specs",
    "param_shape_specs",
    "param_count",
    "VLM_PATCHES",
]
