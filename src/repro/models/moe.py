"""Mixture-of-Experts layer routed through the paper's exchange machinery.

The mapping (DESIGN.md §4): a token is a *tuple*, the router's expert id is
the *join key*, per-expert capacity buffers are the *message pool*, and the
expert-parallel dispatch/combine is the decoupled exchange operator's
all-to-all — executed by :func:`repro.core.exchange.all_to_all` with either
the paper's round-robin phase schedule or XLA's monolithic collective
(``cfg.exchange_impl``).

Three execution paths (``cfg.moe_impl``):

* ``"dense"``  — every device evaluates every expert, weighted combine.
  Exact (no capacity drops); used for CPU smoke tests and as the oracle in
  property tests.  With ``experts -> model`` sharding constraints this is
  also the efficient *decode* path (few tokens, replicate-and-reduce), so
  ``"gspmd"`` is an alias.
* ``"ep_shardmap"`` — true expert parallelism: tokens are sharded over the
  exchange axis, packed into per-expert capacity buffers, shuffled to the
  expert owners (scheduled all-to-all), batch-matmul'd, shuffled back, and
  combined.  This is the paper's §3.2 pipeline, steps 1-7.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import exchange
from repro.distributed.sharding import current_mesh_context
from . import layers as L


# ----------------------------------------------------------------------------
# Params.
# ----------------------------------------------------------------------------

def init_moe_layer(key, cfg: ModelConfig) -> Any:
    d, E, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff or cfg.d_ff
    dt = L.pdtype(cfg)
    ks = jax.random.split(key, 5)
    p = {
        "router": L._normal(ks[0], (d, E), 0.02, jnp.float32),  # router in f32
        "w_gate": L.he_init(ks[1], (E, d, f), d, dt),
        "w_up": L.he_init(ks[2], (E, d, f), d, dt),
        "w_down": L.he_init(ks[3], (E, f, d), f, dt),
    }
    if cfg.num_shared_experts:
        p["shared"] = L.init_mlp(ks[4], cfg, d_ff=f * cfg.num_shared_experts)
    return p


def specs_moe_layer(cfg: ModelConfig) -> Any:
    s = {
        "router": (None, None),
        "w_gate": ("experts", "expert_fsdp", None),
        "w_up": ("experts", "expert_fsdp", None),
        "w_down": ("experts", None, "expert_fsdp"),
    }
    if cfg.num_shared_experts:
        s["shared"] = L.specs_mlp(cfg)
    return s


# ----------------------------------------------------------------------------
# Router.
# ----------------------------------------------------------------------------

def route(params, cfg: ModelConfig, x: jax.Array):
    """Top-k routing -> (weights [T, k] f32, expert ids [T, k] int32)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, cfg.top_k)
    if cfg.router_norm_topk:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx.astype(jnp.int32)


def _expert_ffn(w_gate, w_up, w_down, x):
    """Batched per-expert SwiGLU: x [E, C, d] -> [E, C, d]."""
    g = jnp.einsum("ecd,edf->ecf", x, w_gate)
    u = jnp.einsum("ecd,edf->ecf", x, w_up)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down)


# ----------------------------------------------------------------------------
# Dense / GSPMD path (exact; smoke oracle; decode).
# ----------------------------------------------------------------------------

def moe_dense(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Evaluate all experts for all tokens, combine by router weight.

    With ``experts -> model`` sharding the per-expert compute is model-
    parallel and the weighted sum contracts the expert dim (XLA inserts the
    reduce) — the standard replicate-tokens EP used at decode.
    """
    T, d = x.shape
    dt = x.dtype
    w, idx = route(params, cfg, x)
    # full [T, E] combine weights (zero where not selected)
    full_w = jnp.zeros((T, cfg.num_experts), jnp.float32)
    full_w = jax.vmap(lambda fw, ww, ii: fw.at[ii].add(ww))(full_w, w, idx)
    g = jnp.einsum("td,edf->tef", x, params["w_gate"].astype(dt))
    u = jnp.einsum("td,edf->tef", x, params["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("tef,efd->ted", h, params["w_down"].astype(dt))
    return jnp.einsum("ted,te->td", y, full_w.astype(dt))


# ----------------------------------------------------------------------------
# Expert-parallel shard_map path (the paper's exchange pipeline).
# ----------------------------------------------------------------------------

def _ep_capacity(cfg: ModelConfig, tokens_per_shard: int, num_shards: int) -> int:
    """Per-expert message-buffer capacity (paper: fixed-size reusable pool).

    Delegates to :func:`repro.core.autotune.ep_capacity` — the ONE place the
    formula lives, so the tuner's decode-shaped pricing can never drift from
    the buffers this layer actually ships.
    """
    from repro.core.autotune import ep_capacity

    return ep_capacity(tokens_per_shard, cfg.top_k, cfg.num_experts,
                       cfg.capacity_factor)


def _resolve_exchange(cfg: ModelConfig, mux) -> tuple[str, str]:
    """ONE source of truth for the EP exchange policy: ``(impl, pack_impl)``.

    The ambient multiplexer (the serving engine's tuned policy object) wins
    when present — BOTH knobs come from it, so the pack layout and the
    transport can never disagree about whose policy is in force.  Without a
    mux, the legacy config knob drives the transport and the pack falls back
    to the one-hot reference.
    """
    if mux is not None:
        return mux.impl, mux.pack_impl
    return cfg.exchange_impl, "xla"


def _dispatch_slots(flat_dest: jax.Array, E: int, C: int, pack_impl: str):
    """slot(t, k) = expert * C + arrival rank; overflow -> the E*C drop bin.

    Two implementations of the same capacity-bounded packing (the paper's
    fixed-size reusable message pool), selected by the multiplexer's
    ``pack_impl`` knob exactly like the relational pack paths:

    * ``"xla"`` — one-hot/cumsum reference: materializes a ``[T, E]``
      running histogram in HBM;
    * ``"pallas"`` — :func:`repro.kernels.ops.moe_dispatch`: the arrival
      ranks come from per-block VMEM counters, nothing of shape ``[T, E]``
      exists (interpret mode off-TPU).

    Both produce bit-identical slots; returns ``(slot [T], kept [T])``.
    """
    if pack_impl == "pallas":
        from repro.kernels import ops

        slot, _ = ops.moe_dispatch(flat_dest, E, C)
        return slot, slot < E * C
    onehot = jax.nn.one_hot(flat_dest, E, dtype=jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) - onehot
    my_rank = jnp.take_along_axis(rank, flat_dest[:, None], axis=1)[:, 0]
    kept = my_rank < C
    return jnp.where(kept, flat_dest * C + my_rank, E * C), kept


def _ep_moe_local(params, cfg: ModelConfig, x, axis_name: str,
                  pod_axis: str | None = None):
    """Per-shard body (inside shard_map, manual over the exchange axes).

    x: [T_loc, d] — this shard's slice of the token stream.  When an
    ambient :func:`repro.core.multiplexer.use_multiplexer` is active (the
    continuous serving engine's decode loop), the dispatch/return shuffles
    and the pack impl follow ITS tuned policy (:func:`_resolve_exchange`);
    otherwise the legacy ``cfg.exchange_impl`` transport with the XLA pack.

    On a pod mesh (``pod_axis`` set) a parallel unit is one device of the
    JOINT ``(pod, axis_name)`` axis and the dispatch/return trips take the
    two-level fabric — one coarse message per peer pod over the slow
    network, then the fine in-pod scheduled all-to-all — which is a pure
    permutation and therefore bit-identical to the flat route.

    ``cfg.moe_async_chunks > 1`` (or the ambient mux's ``pipeline_chunks``)
    splits the capacity dim into chunks and double-buffers: chunk ``c+1``'s
    dispatch is issued before chunk ``c``'s expert FFN, so XLA's async
    scheduler can overlap exchange DMA with expert compute (the same
    pipeline as the chunked relational shuffle).  Pure chunk-wise
    permutations on disjoint capacity slices — output is bit-identical for
    every chunk count dividing ``C``.
    """
    from repro.compat import axis_size
    from repro.core.multiplexer import current_multiplexer

    mux = current_multiplexer()
    m = axis_size(axis_name)
    P_pods = axis_size(pod_axis) if pod_axis is not None else 1
    N = P_pods * m  # parallel units across BOTH network levels
    T_loc, d = x.shape
    E = cfg.num_experts
    E_loc = E // N
    assert params["w_gate"].shape[0] == E_loc, "expert weights must be pre-sharded"
    C = _ep_capacity(cfg, T_loc, N)
    dt = x.dtype
    impl, pack_impl = _resolve_exchange(cfg, mux)

    w, idx = route(params, cfg, x)  # [T_loc, k]

    # -- step 2: partition tuples into per-expert messages (the message pool).
    flat_dest = idx.reshape(-1)                       # [T_loc * k] expert ids
    flat_rows = jnp.repeat(x, cfg.top_k, axis=0)      # token copy per choice
    slot, kept = _dispatch_slots(flat_dest, E, C, pack_impl)
    buffers = jnp.zeros((E * C + 1, d), dt).at[slot].set(
        jnp.where(kept[:, None], flat_rows, 0)
    )[:-1]
    dropped = (~kept).sum()

    # -- step 3: the multiplexer shuffle to the experts' owner shards.
    # buffers [E, C, d] -> [N, E_loc * C_sub, d] by owner unit.
    if (pod_axis is None and mux is not None
            and mux.plan.pod_axis is not None and mux.plan.num_pods > 1):
        raise ValueError(
            "flat EP dispatch with a two-level multiplexer: the mesh has a "
            f"pod axis ({mux.plan.pod_axis!r}) but the MoE layer was not "
            "given it — a flat all-to-all here would silently route fine-"
            "grained traffic over the slow network.  Pass the pod axis "
            "through moe_ep (MeshContext.pod_axis) so dispatch/combine take "
            "the two-level fabric."
        )

    def ship_out(v):
        if pod_axis is not None:
            if mux is not None:
                return mux.dispatch(v, axis_name)
            return exchange.dispatch_two_level(v, axis_name, pod_axis, impl=impl)
        if mux is not None:
            return mux.all_to_all(v, axis_name)
        return exchange.all_to_all(v, axis_name, impl=impl)

    def ship_back(v):
        if pod_axis is not None:
            if mux is not None:
                return mux.combine(v, axis_name)
            return exchange.combine_two_level(v, axis_name, pod_axis, impl=impl)
        if mux is not None:
            return mux.all_to_all(v, axis_name)
        return exchange.all_to_all(v, axis_name, impl=impl)

    wg, wu, wd = (params[k].astype(dt) for k in ("w_gate", "w_up", "w_down"))

    chunks = mux.pipeline_chunks if mux is not None else cfg.moe_async_chunks
    if chunks < 1 or C % chunks:
        chunks = 1
    cc = C // chunks
    send = buffers.reshape(N, E_loc, C, d)

    # Double-buffered pipeline: chunk c+1's dispatch has no data dependence
    # on chunk c's expert FFN or return trip, so the async scheduler is free
    # to overlap exchange DMA with expert compute (paper §3.2: the
    # multiplexer ships message k while the workers fill k + 1).
    def dispatch_chunk(c: int):
        return ship_out(send[:, :, c * cc:(c + 1) * cc].reshape(N, E_loc * cc, d))

    inflight = dispatch_chunk(0)
    rets = []
    for c in range(chunks):
        got = inflight
        if c + 1 < chunks:
            inflight = dispatch_chunk(c + 1)
        # got[j] = slice from unit j destined to my local experts.
        recv = got.reshape(N, E_loc, cc, d).transpose(1, 0, 2, 3)
        recv = recv.reshape(E_loc, N * cc, d)
        # -- steps 5-6: process NUMA-local messages (batched expert FFN).
        # Expert weights arrive pre-sharded over the exchange axes
        # (in_specs) — the owner's slice is already local, zero weight
        # traffic.
        out = _expert_ffn(wg, wu, wd, recv)  # [E_loc, N*cc, d]
        # -- step 7: return trip through the same schedule.
        back = out.reshape(E_loc, N, cc, d).transpose(1, 0, 2, 3)
        rets.append(ship_back(back.reshape(N, E_loc * cc, d))
                    .reshape(N, E_loc, cc, d))

    ret = rets[0] if chunks == 1 else jnp.concatenate(rets, axis=2)
    ret = ret.reshape(E * C, d)
    ret = jnp.concatenate([ret, jnp.zeros((1, d), dt)])  # dropped bin reads 0

    # combine: out[t] = sum_k w[t,k] * ret[slot(t,k)]
    gathered = ret[slot].reshape(T_loc, cfg.top_k, d)
    y = jnp.einsum("tkd,tk->td", gathered, w.astype(dt))
    return y, dropped


def moe_ep(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Expert-parallel dispatch via shard_map over the exchange axes.

    On a single-level mesh the tokens/experts shard over ``exchange_axis``
    alone and dispatch is the flat scheduled all-to-all.  On a pod mesh
    (``ctx.pod_axis`` with size > 1) a parallel unit is one device of the
    joint ``(pod, exchange_axis)`` axis and dispatch/combine route through
    the two-level fabric — the flat route over a pod mesh is an explicit
    error (raised here and inside :func:`_ep_moe_local`), never a silent
    fine-grained shuffle over the slow network.
    """
    from repro.core.multiplexer import current_multiplexer

    ctx = current_mesh_context()
    assert ctx is not None, "ep_shardmap requires an active mesh context"
    axis = ctx.exchange_axis
    m = ctx.exchange_size
    pod = ctx.pod_axis
    pods = ctx.mesh.shape[pod] if pod is not None else 1
    if pods <= 1:
        pod = None
    N = (pods if pod is not None else 1) * m

    mux = current_multiplexer()
    if mux is not None and pod is not None and mux.plan.pod_axis is None:
        raise ValueError(
            "EP dispatch on a pod mesh with a single-level multiplexer: "
            f"the mesh context has pod axis {ctx.pod_axis!r} (size "
            f"{pods}) but the ambient mux's plan has none — its flat "
            "all-to-all would silently cross the slow network.  Build "
            "the multiplexer for the SAME two-level mesh "
            "(make_multiplexer(ctx.mesh, ...))."
        )

    T = x.shape[0]
    if N == 1 or T % N != 0 or T // N == 0 or cfg.num_experts % N != 0:
        return moe_dense(params, cfg, x)

    def body(params, x):
        y, _ = _ep_moe_local(params, cfg, x, axis, pod_axis=pod)
        return y

    # NOTE(§Perf C5/C6, refuted): pre-gathering bf16 expert weights to
    # axis-local replicas (with_sharding_constraint before the shard_map)
    # was tried to kill the ~288 GB/chip activation all-reduce that the
    # data-sharded weight contraction causes — GSPMD responded with
    # "involuntary full rematerialization" replicate-and-repartition around
    # the manual region, inflating compute 2.3-6x.  Keeping the storage
    # sharding; the structural fix is a fully-manual MoE block (all mesh
    # axes manual) or the Shardy partitioner — see EXPERIMENTS.md §Perf.
    ep_params = {k: params[k] for k in ("router", "w_gate", "w_up", "w_down")}
    # On a pod mesh the unit axis is the JOINT (pod, exchange) axis: tokens
    # and experts shard over both levels, and the manual region sees both
    # axis names so dispatch can run its two hops.
    unit = (pod, axis) if pod is not None else axis
    param_specs = {
        "router": P(None, None),          # small; replicated over the axis
        "w_gate": P(unit, None, None),    # experts stay sharded in place
        "w_up": P(unit, None, None),
        "w_down": P(unit, None, None),
    }
    from repro.compat import shard_map

    fn = shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(param_specs, P(unit, None)),
        out_specs=P(unit, None),
        axis_names={pod, axis} if pod is not None else {axis},
        check_vma=False,
    )
    return fn(ep_params, x)


# ----------------------------------------------------------------------------
# Layer entry point.
# ----------------------------------------------------------------------------

def moe_ffn(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """The FFN slot of a MoE transformer layer: routed + shared experts."""
    B, S, d = x.shape
    tokens = x.reshape(B * S, d)
    if cfg.moe_impl == "ep_shardmap":
        y = moe_ep(params, cfg, tokens)
    else:  # "dense" and "gspmd"
        y = moe_dense(params, cfg, tokens)
    y = y.reshape(B, S, d)
    if cfg.num_shared_experts:
        y = y + L.mlp_block(params["shared"], cfg, x)
    return y


__all__ = [
    "init_moe_layer",
    "specs_moe_layer",
    "route",
    "moe_dense",
    "moe_ep",
    "moe_ffn",
]
