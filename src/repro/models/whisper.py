"""Whisper-medium backbone: transformer encoder-decoder with cross-attention.

Per the assignment the conv/mel frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings ``[B, S, d_model]`` directly (the output the
two conv layers would produce).  Sinusoidal positions are added to both
streams (real whisper uses learned decoder positions capped at 448; our
shape grid decodes at 32k, so we use the unbounded sinusoidal form — noted
in DESIGN.md).  LayerNorm + GELU as in the original.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from . import layers as L
from .transformer import _maybe_remat, _stack_specs


def sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    """Classic transformer sinusoids: [B, S] -> [B, S, d]."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------------
# Layer init/specs.
# ----------------------------------------------------------------------------

def _enc_layer_init(key, cfg):
    ks = jax.random.split(key, 2)
    dt = L.pdtype(cfg)
    return {
        "ln1": L.init_layernorm(cfg.d_model, dt),
        "attn": L.init_attention(ks[0], cfg),
        "ln2": L.init_layernorm(cfg.d_model, dt),
        "mlp": L.init_mlp(ks[1], cfg),
    }


def _enc_layer_specs(cfg):
    return {
        "ln1": L.specs_layernorm(),
        "attn": L.specs_attention(cfg),
        "ln2": L.specs_layernorm(),
        "mlp": L.specs_mlp(cfg),
    }


def _dec_layer_init(key, cfg):
    ks = jax.random.split(key, 3)
    dt = L.pdtype(cfg)
    return {
        "ln1": L.init_layernorm(cfg.d_model, dt),
        "self_attn": L.init_attention(ks[0], cfg),
        "ln2": L.init_layernorm(cfg.d_model, dt),
        "cross_attn": L.init_attention(ks[1], cfg),
        "ln3": L.init_layernorm(cfg.d_model, dt),
        "mlp": L.init_mlp(ks[2], cfg),
    }


def _dec_layer_specs(cfg):
    return {
        "ln1": L.specs_layernorm(),
        "self_attn": L.specs_attention(cfg),
        "ln2": L.specs_layernorm(),
        "cross_attn": L.specs_attention(cfg),
        "ln3": L.specs_layernorm(),
        "mlp": L.specs_mlp(cfg),
    }


def init(key, cfg: ModelConfig) -> Any:
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[1], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[2], cfg.num_layers)
    dt = L.pdtype(cfg)
    return {
        "embedding": L.init_embedding(ks[0], cfg),
        "encoder": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "enc_norm": L.init_layernorm(cfg.d_model, dt),
        "decoder": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "dec_norm": L.init_layernorm(cfg.d_model, dt),
    }


def specs(cfg: ModelConfig) -> Any:
    return {
        "embedding": L.specs_embedding(cfg),
        "encoder": _stack_specs(_enc_layer_specs(cfg)),
        "enc_norm": L.specs_layernorm(),
        "decoder": _stack_specs(_dec_layer_specs(cfg)),
        "dec_norm": L.specs_layernorm(),
    }


# ----------------------------------------------------------------------------
# Encoder.
# ----------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: [B, S, d_model] stub conv-frontend output -> memory."""
    B, S, _ = frames.shape
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    x = frames.astype(L.cdtype(cfg)) + sinusoidal(pos, cfg.d_model).astype(L.cdtype(cfg))
    x = shard(x, "batch", "seq_sp", "d_model")

    def body(x, p):
        h = L.layernorm(p["ln1"], x, cfg.norm_eps)
        x = x + L.attention_block(p["attn"], cfg, h, None, None, causal=False)
        h = L.layernorm(p["ln2"], x, cfg.norm_eps)
        x = x + L.mlp_block(p["mlp"], cfg, h)
        return shard(x, "batch", "seq_sp", "d_model"), None

    x, _ = lax.scan(_maybe_remat(body, cfg), x, params["encoder"])
    return L.layernorm(params["enc_norm"], x, cfg.norm_eps)


# ----------------------------------------------------------------------------
# Decoder.
# ----------------------------------------------------------------------------

def _cross_attend(p, cfg, h, mem_k, mem_v):
    dt = h.dtype
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
    o = L.sdpa(q, mem_k, mem_v, causal=False)
    return L.attention_out(p, o)


def _memory_kv(p, cfg, memory):
    dt = memory.dtype
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"].astype(dt))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return k, v


def decode_train(params, cfg: ModelConfig, tokens: jax.Array, memory: jax.Array):
    B, S = tokens.shape
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    x = L.embed(params["embedding"], cfg, tokens)
    x = x + sinusoidal(pos, cfg.d_model).astype(x.dtype)
    x = shard(x, "batch", "seq_sp", "d_model")

    def body(x, p):
        h = L.layernorm(p["ln1"], x, cfg.norm_eps)
        x = x + L.attention_block(p["self_attn"], cfg, h, None, None, causal=True)
        h = L.layernorm(p["ln2"], x, cfg.norm_eps)
        mk, mv = _memory_kv(p["cross_attn"], cfg, memory)
        x = x + _cross_attend(p["cross_attn"], cfg, h, mk, mv)
        h = L.layernorm(p["ln3"], x, cfg.norm_eps)
        x = x + L.mlp_block(p["mlp"], cfg, h)
        return shard(x, "batch", "seq_sp", "d_model"), None

    x, _ = lax.scan(_maybe_remat(body, cfg), x, params["decoder"])
    return L.layernorm(params["dec_norm"], x, cfg.norm_eps)


def train_loss(params, cfg: ModelConfig, batch) -> jax.Array:
    memory = encode(params, cfg, batch["frames"])
    h = decode_train(params, cfg, batch["tokens"], memory)
    logits = L.unembed(params["embedding"], cfg, h)
    return L.xent_loss(logits, batch["labels"], batch.get("loss_mask"))


def init_cache(cfg: ModelConfig, batch_size: int, capacity: int, dtype=None) -> Any:
    """Self-attn KV per decoder layer + precomputed cross KV (filled at prefill)."""
    dtype = dtype or L.cdtype(cfg)
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    Ld = cfg.num_layers
    return {
        "self_k": jnp.zeros((Ld, batch_size, capacity, kh, hd), dtype),
        "self_v": jnp.zeros((Ld, batch_size, capacity, kh, hd), dtype),
        "cross_k": jnp.zeros((Ld, batch_size, capacity, kh, hd), dtype),
        "cross_v": jnp.zeros((Ld, batch_size, capacity, kh, hd), dtype),
    }


def cache_specs(cfg: ModelConfig) -> Any:
    kv = (None, "batch", "kv_seq", None, None)
    return {"self_k": kv, "self_v": kv, "cross_k": kv, "cross_v": kv}


def prefill(params, cfg: ModelConfig, batch):
    """Encode frames + fill cross KV; decoder cache starts empty (BOS next).

    Returns logits for the first decoder position fed with batch["tokens"]
    (prompt of length S), plus the filled cache.
    """
    memory = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    x = L.embed(params["embedding"], cfg, tokens)
    x = x + sinusoidal(pos, cfg.d_model).astype(x.dtype)

    def body(x, p):
        h = L.layernorm(p["ln1"], x, cfg.norm_eps)
        q, k, v = L.attention_qkv(p["self_attn"], cfg, h)
        x = x + L.attention_out(p["self_attn"], L.sdpa(q, k, v, causal=True))
        h = L.layernorm(p["ln2"], x, cfg.norm_eps)
        mk, mv = _memory_kv(p["cross_attn"], cfg, memory)
        x = x + _cross_attend(p["cross_attn"], cfg, h, mk, mv)
        h = L.layernorm(p["ln3"], x, cfg.norm_eps)
        x = x + L.mlp_block(p["mlp"], cfg, h)
        return shard(x, "batch", "seq_sp", "d_model"), (k, v, mk, mv)

    x, (ks, vs, mks, mvs) = lax.scan(_maybe_remat(body, cfg), x, params["decoder"])
    x = L.layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embedding"], cfg, x[:, -1:])
    cache = {"self_k": ks, "self_v": vs, "cross_k": mks, "cross_v": mvs}
    return logits[:, 0], cache


def decode_step(params, cfg: ModelConfig, tokens, cache, pos):
    x = L.embed(params["embedding"], cfg, tokens)
    B = x.shape[0]
    p_ids = jnp.full((B, 1), pos, jnp.int32)
    x = x + sinusoidal(p_ids, cfg.d_model).astype(x.dtype)

    def body(x, xs):
        p, sk, sv, ck, cv = xs
        h = L.layernorm(p["ln1"], x, cfg.norm_eps)
        a, nk, nv = L.attention_decode(p["self_attn"], cfg, h, sk, sv, pos, None, None)
        x = x + a
        h = L.layernorm(p["ln2"], x, cfg.norm_eps)
        x = x + _cross_attend(p["cross_attn"], cfg, h, ck, cv)
        h = L.layernorm(p["ln3"], x, cfg.norm_eps)
        x = x + L.mlp_block(p["mlp"], cfg, h)
        return x, (nk, nv)

    x, (nks, nvs) = lax.scan(
        body, x,
        (params["decoder"], cache["self_k"], cache["self_v"],
         cache["cross_k"], cache["cross_v"]),
    )
    x = L.layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embedding"], cfg, x)
    new_cache = dict(cache, self_k=nks, self_v=nvs)
    return logits[:, 0], new_cache


__all__ = [
    "sinusoidal", "init", "specs", "encode", "decode_train", "train_loss",
    "init_cache", "cache_specs", "prefill", "decode_step",
]
