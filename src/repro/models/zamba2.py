"""Zamba2-style hybrid: a Mamba2 backbone with a *shared* attention block.

The assignment config (81 layers, d_model 3584, 32 heads, d_ff 14336,
ssm_state 64) is realized as 13 groups of ``attn_every=6`` mamba2 layers,
each group followed by ONE shared transformer block (weights reused across
all 13 invocations — Zamba2's parameter-sharing trick), plus a 3-layer
mamba tail (13*6 + 3 = 81).

ADAPTATION NOTE (DESIGN.md): real Zamba2 concatenates the original
embedding with the hidden state at each shared-block invocation and applies
per-invocation LoRA deltas; we apply the shared block on the residual
stream directly — same compute/communication signature, simpler state.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from . import layers as L
from . import mamba2 as MB
from .transformer import _maybe_remat, _stack_specs


def layout(cfg: ModelConfig) -> tuple[int, int, int]:
    """(num_groups, group_size, tail) with groups*size + tail = num_layers."""
    g = cfg.attn_every
    return cfg.num_layers // g, g, cfg.num_layers % g


def _mamba_layer_init(key, cfg):
    return {
        "norm": L.init_rmsnorm(cfg.d_model, L.pdtype(cfg)),
        "mamba": MB.init_mamba_block(key, cfg),
    }


def _mamba_layer_specs(cfg):
    return {"norm": L.specs_rmsnorm(), "mamba": MB.specs_mamba_block(cfg)}


def init(key, cfg: ModelConfig) -> Any:
    ng, gs, tail = layout(cfg)
    ks = jax.random.split(key, 5)
    group_keys = jax.random.split(ks[1], ng * gs).reshape(ng, gs, -1)
    p = {
        "embedding": L.init_embedding(ks[0], cfg),
        "groups": jax.vmap(jax.vmap(lambda k: _mamba_layer_init(k, cfg)))(group_keys),
        "shared": {
            "ln1": L.init_rmsnorm(cfg.d_model, L.pdtype(cfg)),
            "attn": L.init_attention(ks[2], cfg),
            "ln2": L.init_rmsnorm(cfg.d_model, L.pdtype(cfg)),
            "mlp": L.init_mlp(ks[3], cfg),
        },
        "final_norm": L.init_rmsnorm(cfg.d_model, L.pdtype(cfg)),
    }
    if tail:
        tail_keys = jax.random.split(ks[4], tail)
        p["tail"] = jax.vmap(lambda k: _mamba_layer_init(k, cfg))(tail_keys)
    return p


def specs(cfg: ModelConfig) -> Any:
    ng, gs, tail = layout(cfg)
    s = {
        "embedding": L.specs_embedding(cfg),
        "groups": _stack_specs(_stack_specs(_mamba_layer_specs(cfg))),
        "shared": {
            "ln1": L.specs_rmsnorm(),
            "attn": L.specs_attention(cfg),
            "ln2": L.specs_rmsnorm(),
            "mlp": L.specs_mlp(cfg),
        },
        "final_norm": L.specs_rmsnorm(),
    }
    if tail:
        s["tail"] = _stack_specs(_mamba_layer_specs(cfg))
    return s


def _shared_block(p, cfg: ModelConfig, x, cos, sin):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    x = x + L.attention_block(p["attn"], cfg, h, cos, sin, causal=True)
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + L.mlp_block(p["mlp"], cfg, h)


def _mamba_fwd(p, cfg, x):
    h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    x = x + MB.mamba_block(p["mamba"], cfg, h)
    return shard(x, "batch", "seq_sp", "d_model")


def forward(params, cfg: ModelConfig, batch) -> jax.Array:
    x = L.embed(params["embedding"], cfg, batch["tokens"])
    x = shard(x, "batch", "seq_sp", "d_model")
    B, S = x.shape[0], x.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    cos, sin = L.rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta)

    def group_body(x, group_params):
        def inner(x, p):
            return _mamba_fwd(p, cfg, x), None

        x, _ = lax.scan(inner, x, group_params)
        return _shared_block(params["shared"], cfg, x, cos, sin), None

    x, _ = lax.scan(_maybe_remat(group_body, cfg), x, params["groups"])
    if "tail" in params:
        def inner(x, p):
            return _mamba_fwd(p, cfg, x), None

        x, _ = lax.scan(_maybe_remat(inner, cfg), x, params["tail"])
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def train_loss(params, cfg: ModelConfig, batch) -> jax.Array:
    h = forward(params, cfg, batch)
    logits = L.unembed(params["embedding"], cfg, h)
    return L.xent_loss(logits, batch["labels"], batch.get("loss_mask"))


def init_cache(cfg: ModelConfig, batch_size: int, capacity: int, dtype=None) -> Any:
    """Hybrid cache: O(1) mamba states + a KV cache per shared-attn call.

    At 500k context the 13 KV slots are the only O(L) state — that (and the
    SSD scan) is why this arch runs the long_500k cell.
    """
    dtype = dtype or L.cdtype(cfg)
    ng, gs, tail = layout(cfg)
    d_inner, H, conv_ch = MB.dims(cfg)
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def mamba_state(n):
        return {
            "ssm": jnp.zeros((n, batch_size, H, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((n, batch_size, cfg.ssm_conv - 1, conv_ch), dtype),
        }

    cache = {
        "groups": jax.tree.map(
            lambda a: a.reshape((ng, gs) + a.shape[1:]), mamba_state(ng * gs)
        ),
        "attn": {
            "k": jnp.zeros((ng, batch_size, capacity, kh, hd), dtype),
            "v": jnp.zeros((ng, batch_size, capacity, kh, hd), dtype),
        },
    }
    if tail:
        cache["tail"] = mamba_state(tail)
    return cache


def cache_specs(cfg: ModelConfig) -> Any:
    ng, gs, tail = layout(cfg)
    s = {
        "groups": {
            "ssm": (None, None, "batch", "ssm_heads", None, None),
            "conv": (None, None, "batch", None, "conv_dim"),
        },
        "attn": {
            "k": (None, "batch", "kv_seq", None, None),
            "v": (None, "batch", "kv_seq", None, None),
        },
    }
    if tail:
        s["tail"] = {
            "ssm": (None, "batch", "ssm_heads", None, None),
            "conv": (None, "batch", None, "conv_dim"),
        }
    return s


def decode_step(params, cfg: ModelConfig, tokens, cache, pos):
    x = L.embed(params["embedding"], cfg, tokens)
    B = x.shape[0]
    p_ids = jnp.full((B, 1), pos, jnp.int32)
    cos, sin = L.rope_angles(p_ids, cfg.resolved_head_dim, cfg.rope_theta)
    shared = params["shared"]

    def mamba_step(x, p, st):
        h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
        o, st = MB.mamba_block_step(p["mamba"], cfg, h, st)
        return x + o, st

    def group_body(x, xs):
        gp, gst, kc, vc = xs

        def inner(x, xs2):
            p, st = xs2
            x, st = mamba_step(x, p, st)
            return x, st

        x, new_gst = lax.scan(inner, x, (gp, gst))
        h = L.rmsnorm(shared["ln1"], x, cfg.norm_eps)
        a, nk, nv = L.attention_decode(shared["attn"], cfg, h, kc, vc, pos, cos, sin)
        x = x + a
        h = L.rmsnorm(shared["ln2"], x, cfg.norm_eps)
        x = x + L.mlp_block(shared["mlp"], cfg, h)
        return x, (new_gst, nk, nv)

    x, (new_groups, nk, nv) = lax.scan(
        group_body, x,
        (params["groups"], cache["groups"], cache["attn"]["k"], cache["attn"]["v"]),
    )
    new_cache = {"groups": new_groups, "attn": {"k": nk, "v": nv}}
    if "tail" in params:
        def inner(x, xs2):
            p, st = xs2
            return mamba_step(x, p, st)

        x, new_tail = lax.scan(inner, x, (params["tail"], cache["tail"]))
        new_cache["tail"] = new_tail
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embedding"], cfg, x)
    return logits[:, 0], new_cache


def prefill(params, cfg: ModelConfig, batch):
    x = L.embed(params["embedding"], cfg, batch["tokens"])
    B, S = x.shape[0], x.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    cos, sin = L.rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta)
    shared = params["shared"]

    def group_body(x, gp):
        def inner(x, p):
            h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
            o, st = MB.mamba_block(p["mamba"], cfg, h, return_state=True)
            return x + o, st

        x, states = lax.scan(inner, x, gp)
        h = L.rmsnorm(shared["ln1"], x, cfg.norm_eps)
        q, k, v = L.attention_qkv(shared["attn"], cfg, h)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        a = L.attention_out(shared["attn"], L.sdpa(q, k, v, causal=True))
        x = x + a
        h = L.rmsnorm(shared["ln2"], x, cfg.norm_eps)
        x = x + L.mlp_block(shared["mlp"], cfg, h)
        return x, (states, k, v)

    x, (group_states, ks, vs) = lax.scan(_maybe_remat(group_body, cfg), x, params["groups"])
    cache = {"groups": group_states, "attn": {"k": ks, "v": vs}}
    if "tail" in params:
        def inner(x, p):
            h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
            o, st = MB.mamba_block(p["mamba"], cfg, h, return_state=True)
            return x + o, st

        x, tail_states = lax.scan(_maybe_remat(inner, cfg), x, params["tail"])
        cache["tail"] = tail_states
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embedding"], cfg, x[:, -1:])
    return logits[:, 0], cache


__all__ = [
    "layout", "init", "specs", "forward", "train_loss",
    "init_cache", "cache_specs", "decode_step", "prefill",
]
