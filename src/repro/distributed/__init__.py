"""Distributed runtime: mesh context, logical-axis sharding rules, fault model.

The paper's hybrid parallelism distinguishes the *network in the small*
(intra-pod ICI) from the *network in the large* (inter-pod DCI).  This
package carries that distinction as data: a :class:`MeshContext` names the
mesh axes per network level and the sharding rules that keep fine-grained
parallelism (TP/morsels) on the fast level, shuffles on the coarse level.
"""

from .sharding import (
    AxisRules,
    MeshContext,
    current_mesh_context,
    mesh_context,
    logical_sharding,
    shard,
)

__all__ = [
    "AxisRules",
    "MeshContext",
    "current_mesh_context",
    "mesh_context",
    "logical_sharding",
    "shard",
]
