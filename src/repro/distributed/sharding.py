"""Logical-axis sharding rules + mesh context (MaxText-style, paper-aware).

Model code never names mesh axes directly.  It tags tensor dimensions with
*logical* names (``"batch"``, ``"heads"``, ``"d_ff"``, ``"experts"``, ...)
through :func:`shard`; an :class:`AxisRules` table maps logical names to mesh
axes.  On a CPU smoke test (no mesh context) everything is a no-op, so the
same model code runs single-device and on the 512-chip dry-run mesh.

The default rules encode the paper's hybrid-parallelism policy:

* coarse data parallelism crosses the slow network: ``batch -> (pod, data)``,
* fine model parallelism stays on the fast network: ``heads/d_ff/experts ->
  model`` (never ``pod``),
* the expert shuffle (the paper's all-to-all exchange) runs over ``model``
  only — parallel units for the exchange are the ``model``-axis devices,
  not every (pod, data, model) lane, which is exactly the paper's
  "n servers, not n x t threads" argument.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Iterator, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical dimension names used across the model zoo.
LOGICAL_AXES = (
    "batch",      # global batch                      -> (pod, data)
    "seq",        # sequence (attention q/k/v)        -> None
    "seq_sp",     # residual-stream seq (Megatron SP)  -> None | model
    "kv_seq",     # KV-cache sequence at decode       -> model (flash-decode)
    "d_model",    # residual stream                   -> None
    "heads",      # attention query heads             -> model
    "kv_heads",   # attention kv heads                -> model (if divisible)
    "d_ff",       # MLP hidden                        -> model
    "experts",    # MoE expert dim                    -> model (EP)
    "vocab",      # embedding/logits vocab            -> model
    "fsdp",       # parameter FSDP dim                -> data
    "expert_fsdp",# expert-weight inner dims           -> data (or model)
    "conv_dim",   # mamba conv channels               -> model
    "ssm_heads",  # mamba value heads                 -> model
)


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping logical axis name -> mesh axis (or tuple of axes, or None).

    ``allow_uneven``: keep a sharding constraint even when the dimension is
    not divisible by the mesh factor (GSPMD pads).  Off by default — the
    §Perf hillclimb enables it for the 36/40/12-head archs, where dropping
    the constraint makes XLA replicate the whole attention block.
    """

    table: Mapping[str, tuple[str, ...] | str | None]
    allow_uneven: bool = False

    def spec_for(self, *names: str | None) -> P:
        return P(*[self.table.get(n) if n else None for n in names])

    def replace(self, **kw) -> "AxisRules":
        uneven = kw.pop("allow_uneven", self.allow_uneven)
        t = dict(self.table)
        t.update(kw)
        return AxisRules(t, allow_uneven=uneven)


def default_rules(multi_pod: bool) -> AxisRules:
    batch = ("pod", "data") if multi_pod else ("data",)
    return AxisRules(
        {
            "batch": batch,
            "seq": None,
            "seq_sp": None,
            "kv_seq": "model",
            "d_model": None,
            "heads": "model",
            "kv_heads": "model",
            "d_ff": "model",
            "experts": "model",
            "vocab": "model",
            "fsdp": "data",
            "expert_fsdp": "data",
            "conv_dim": "model",
            "ssm_heads": "model",
        }
    )


@dataclasses.dataclass(frozen=True)
class MeshContext:
    """Everything the model zoo needs to know about the machine.

    ``exchange_impl`` selects the all-to-all transport for the MoE/relational
    exchange (the paper's knob): ``"round_robin"`` (scheduled phases),
    ``"one_factorization"``, or ``"xla"`` (unscheduled baseline).
    """

    mesh: Mesh
    rules: AxisRules
    exchange_axis: str = "model"  # mesh axis the decoupled exchange runs over
    data_axes: tuple[str, ...] = ("data",)
    pod_axis: str | None = None  # set on multi-pod meshes
    exchange_impl: str = "round_robin"

    @property
    def exchange_size(self) -> int:
        return self.mesh.shape[self.exchange_axis]


_CTX: contextvars.ContextVar[MeshContext | None] = contextvars.ContextVar(
    "repro_mesh_context", default=None
)


def current_mesh_context() -> MeshContext | None:
    return _CTX.get()


@contextlib.contextmanager
def mesh_context(ctx: MeshContext | None) -> Iterator[MeshContext | None]:
    token = _CTX.set(ctx)
    try:
        if ctx is not None:
            from repro.compat import set_mesh

            with set_mesh(ctx.mesh):
                yield ctx
        else:
            yield None
    finally:
        _CTX.reset(token)


def _divisible(
    dim: int, mesh: Mesh, axes: tuple[str, ...] | str | None, allow_uneven: bool
) -> bool:
    if axes is None:
        return True
    if isinstance(axes, str):
        axes = (axes,)
    k = 1
    for a in axes:
        k *= mesh.shape[a]
    if dim % k == 0:
        return True
    # uneven mode: keep the constraint as long as every shard gets >= 1 row
    # (GSPMD pads) — dropping it makes XLA replicate the whole operand chain
    return allow_uneven and dim >= k


def logical_sharding(
    shape: Sequence[int],
    *names: str | None,
    ctx: MeshContext | None = None,
    strict: bool = False,
) -> NamedSharding | None:
    """NamedSharding for a logical-tagged shape; None when no mesh context.

    Drops any logical axis whose mesh factor does not divide the dimension
    (e.g. 36 heads on a 16-way ``model`` axis) unless
    ``ctx.rules.allow_uneven`` — GSPMD pads uneven *internal* constraints.
    ``strict=True`` (argument shardings for jit ``in_shardings``) always
    requires exact divisibility: pjit rejects uneven argument shardings.
    """
    ctx = ctx or current_mesh_context()
    if ctx is None:
        return None
    assert len(shape) == len(names), (shape, names)
    uneven = ctx.rules.allow_uneven and not strict
    resolved = []
    used: set[str] = set()
    for dim, name in zip(shape, names):
        axes = ctx.rules.table.get(name) if name else None
        if isinstance(axes, str):
            axes = (axes,)
        if axes:
            # a mesh axis can shard at most one dim: leftmost logical name
            # wins -- under ZeRO-3 rules batch takes both axes and the
            # heads/d_ff constraints on the same tensor drop automatically,
            # while parameter specs (no batch dim) keep their mapping.
            axes = tuple(a for a in axes if a not in used)
        if not axes:
            resolved.append(None)
            continue
        if _divisible(dim, ctx.mesh, axes, uneven):
            used.update(axes)
            resolved.append(axes if len(axes) > 1 else axes[0])
        else:
            resolved.append(None)
    return NamedSharding(ctx.mesh, P(*resolved))


def is_spec_leaf(x) -> bool:
    """Spec trees use tuples of logical-axis names as leaves."""
    return isinstance(x, tuple) and (
        len(x) == 0 or all(n is None or isinstance(n, str) for n in x)
    )


def build_shardings(spec_tree, shape_tree, ctx: MeshContext | None = None):
    """NamedSharding tree from (logical spec tree, ShapeDtypeStruct tree).

    Used for jit argument shardings -> strict divisibility (pjit rejects
    padded argument shardings; uneven placement happens via internal
    constraints instead).
    """
    ctx = ctx or current_mesh_context()
    if ctx is None:
        return None
    return jax.tree.map(
        lambda spec, shp: logical_sharding(shp.shape, *spec, ctx=ctx, strict=True),
        spec_tree,
        shape_tree,
        is_leaf=is_spec_leaf,
    )


def shard(x: jax.Array, *names: str | None) -> jax.Array:
    """Tag an activation with logical axes (with_sharding_constraint)."""
    s = logical_sharding(x.shape, *names)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


__all__ = [
    "LOGICAL_AXES",
    "AxisRules",
    "default_rules",
    "MeshContext",
    "current_mesh_context",
    "mesh_context",
    "logical_sharding",
    "is_spec_leaf",
    "build_shardings",
    "shard",
]
