"""Exchange-operator partition hot loop — Pallas TPU kernels (paper §3.2.1).

HyPer's decoupled exchange operator hashes each tuple's join key (CRC32 on
x86) and partitions tuples into per-destination message buffers.  On TPU the
hash is a multiply-xor avalanche (pure VPU, no CRC unit — DESIGN.md §2) and
the hot loop is fused into a single block-parallel kernel.  Three entry
points, in increasing order of fusion:

* :func:`hash_partition` — (pid, per-block histogram).  The original kernel,
  kept for the MoE-style callers that only need destination ids.
* :func:`partition_pack` — given destination ids, emits per-block histograms
  AND each row's *block-local* within-destination rank.  The global rank a
  message-buffer pack needs is then ``exclusive_scan(block_hists)[block, d]
  + local_rank`` — an ``[nblocks, bins]`` scan plus a flat gather, so the
  pack never materializes the ``[rows, bins]`` one-hot/cumsum the pure-XLA
  path needs (O(rows x bins) memory and FLOPs).
* :func:`hash_partition_pack` — the full fused hot loop: hash + validity
  masking (invalid rows routed to the overflow bin) + block-local rank +
  block histogram in one pass over the keys.  This is the kernel analogue of
  the per-tuple loop the paper code-generates with LLVM; schema
  specialization happens at trace time (Pallas kernels are shape-specialized),
  mirroring the paper's generated serialization code.

The histogram tree-combine and the actual scatter stay in XLA (dynamic
scatter is not an MXU shape) — see :func:`repro.kernels.ops.partition_ranks`
for the combine and :func:`repro.core.exchange.pack_by_destination` for the
scatter.

Rows whose destination id is outside ``[0, num_bins)`` (the padding value
used by the ``ops`` wrappers) match no bin: they get rank 0 and contribute
to no histogram bucket.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# The uint32 multiply-xor mix shared with exchange.fibonacci_hash — one
# definition so the kernel/XLA bit-exactness contract can't drift.
from .ref import fibonacci_hash_ref as _avalanche  # noqa: E402


def _rank_and_hist(d: jax.Array, num_bins: int, block: int):
    """Block-local within-bin rank + bin histogram for one block of dests.

    ``[block, num_bins]`` lives only in VMEM for the duration of one grid
    step — this is the whole point of the kernel: the row-global one-hot
    never exists.
    """
    onehot = (
        d[:, None] == jax.lax.broadcasted_iota(jnp.int32, (block, num_bins), 1)
    ).astype(jnp.int32)
    csum = jnp.cumsum(onehot, axis=0)
    rank = ((csum - onehot) * onehot).sum(axis=1)
    return rank, csum[block - 1]


def _hash_kernel(keys_ref, pid_ref, hist_ref, *, num_partitions: int, block: int):
    x = _avalanche(keys_ref[...])
    pid = (x % jnp.uint32(num_partitions)).astype(jnp.int32)
    pid_ref[...] = pid
    onehot = (
        pid[:, None] == jax.lax.broadcasted_iota(jnp.int32, (block, num_partitions), 1)
    ).astype(jnp.int32)
    hist_ref[0] = onehot.sum(axis=0)


def hash_partition(
    keys: jax.Array, num_partitions: int, block: int = 256, interpret: bool = True
) -> tuple[jax.Array, jax.Array]:
    """(partition ids [T], per-block histograms [T/block, P])."""
    T = keys.shape[0]
    assert T % block == 0, (T, block)
    nb = T // block
    kernel = functools.partial(_hash_kernel, num_partitions=num_partitions, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1, num_partitions), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((nb, num_partitions), jnp.int32),
        ],
        interpret=interpret,
    )(keys)


def _partition_pack_kernel(dest_ref, hist_ref, rank_ref, *, num_bins: int, block: int):
    rank, hist = _rank_and_hist(dest_ref[...], num_bins, block)
    rank_ref[...] = rank
    hist_ref[0] = hist


def partition_pack(
    dest: jax.Array, num_bins: int, block: int = 256, interpret: bool = True
) -> tuple[jax.Array, jax.Array]:
    """(per-block histograms [T/block, num_bins], block-local ranks [T])."""
    T = dest.shape[0]
    assert T % block == 0, (T, block)
    nb = T // block
    kernel = functools.partial(_partition_pack_kernel, num_bins=num_bins, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((1, num_bins), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, num_bins), jnp.int32),
            jax.ShapeDtypeStruct((T,), jnp.int32),
        ],
        interpret=interpret,
    )(dest)


def _hash_partition_pack_kernel(
    keys_ref, valid_ref, dest_ref, hist_ref, rank_ref, *, num_partitions: int, block: int
):
    x = _avalanche(keys_ref[...])
    pid = (x % jnp.uint32(num_partitions)).astype(jnp.int32)
    # Invalid rows go to the overflow bin (bin index == num_partitions).
    d = jnp.where(valid_ref[...] != 0, pid, num_partitions)
    dest_ref[...] = d
    rank, hist = _rank_and_hist(d, num_partitions + 1, block)
    rank_ref[...] = rank
    hist_ref[0] = hist


def hash_partition_pack(
    keys: jax.Array,
    valid: jax.Array,
    num_partitions: int,
    block: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused hash + mask + pack metadata in one pass over the keys.

    Returns ``(dest [T], per-block histograms [T/block, P+1], block-local
    ranks [T])`` where ``dest`` is the masked destination (``P`` = overflow
    bin for invalid rows) and histograms/ranks cover all ``P + 1`` bins.
    ``valid`` is int32 (nonzero == valid).
    """
    T = keys.shape[0]
    assert T % block == 0, (T, block)
    assert valid.shape == (T,), (valid.shape, T)
    nb = T // block
    kernel = functools.partial(
        _hash_partition_pack_kernel, num_partitions=num_partitions, block=block
    )
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1, num_partitions + 1), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((nb, num_partitions + 1), jnp.int32),
            jax.ShapeDtypeStruct((T,), jnp.int32),
        ],
        interpret=interpret,
    )(keys, valid)


__all__ = ["hash_partition", "partition_pack", "hash_partition_pack"]
