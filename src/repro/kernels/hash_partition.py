"""Exchange-operator partition hot loop — Pallas TPU kernel (paper §3.2.1).

HyPer's decoupled exchange operator hashes each tuple's join key (CRC32 on
x86) and partitions tuples into per-destination message buffers.  On TPU the
hash is a multiply-xor avalanche (pure VPU, no CRC unit — DESIGN.md §2) and
the kernel emits, per block of keys, (a) the destination partition ids and
(b) a per-block destination histogram.  The histogram tree-combine and the
actual scatter stay in XLA (dynamic scatter is not an MXU shape), but the
per-row hashing+binning — the loop the paper code-generates with LLVM — is
this kernel.  Schema specialization happens at trace time (Pallas kernels
are shape-specialized), mirroring the paper's generated serialization code.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hash_kernel(keys_ref, pid_ref, hist_ref, *, num_partitions: int, block: int):
    x = keys_ref[...].astype(jnp.uint32)  # [block]
    x ^= x >> 16
    x = x * jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    x = x * jnp.uint32(0x846CA68B)
    x ^= x >> 16
    pid = (x % jnp.uint32(num_partitions)).astype(jnp.int32)
    pid_ref[...] = pid
    onehot = (
        pid[:, None] == jax.lax.broadcasted_iota(jnp.int32, (block, num_partitions), 1)
    ).astype(jnp.int32)
    hist_ref[0] = onehot.sum(axis=0)


def hash_partition(
    keys: jax.Array, num_partitions: int, block: int = 256, interpret: bool = True
) -> tuple[jax.Array, jax.Array]:
    """(partition ids [T], per-block histograms [T/block, P])."""
    T = keys.shape[0]
    assert T % block == 0, (T, block)
    nb = T // block
    kernel = functools.partial(_hash_kernel, num_partitions=num_partitions, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1, num_partitions), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((nb, num_partitions), jnp.int32),
        ],
        interpret=interpret,
    )(keys)


__all__ = ["hash_partition"]
