"""Mamba2 SSD chunk scan — Pallas TPU kernel.

Fuses, per (batch, head-block, chunk): the intra-chunk quadratic (the
"attention form" of SSD), the inter-chunk state read, and the state update —
all in VMEM, with the running ``[hb, P, N]`` state carried in scratch across
the sequential chunk axis (TPU grids execute the last axis in order, so the
scratch *is* the recurrence carry; the HBM round-trip of the per-chunk
states that the jnp reference makes via ``lax.scan`` disappears).

Layout notes: heads are processed in blocks of ``hb`` so the [Q, Q, hb]
decay tensor fits VMEM; Q (chunk) and P/N are MXU-aligned.  Single-group
(G=1) only — every assigned SSM arch uses ngroups=1; the wrapper falls back
to the reference otherwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(
    x_ref,    # [1, 1, Q, hb, P]
    dt_ref,   # [1, 1, Q, hb]
    A_ref,    # [1, hb]
    B_ref,    # [1, 1, Q, N]
    C_ref,    # [1, 1, Q, N]
    s0_ref,   # [1, hb, P, N]
    y_ref,    # out [1, 1, Q, hb, P]
    fin_ref,  # out [1, hb, P, N]
    state_ref,  # scratch [hb, P, N] f32
    *,
    num_chunks: int,
    chunk: int,
):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)    # [Q, hb, P]
    dt = dt_ref[0, 0].astype(jnp.float32)  # [Q, hb]
    A = A_ref[0].astype(jnp.float32)       # [hb]
    Bm = B_ref[0, 0].astype(jnp.float32)   # [Q, N]
    Cm = C_ref[0, 0].astype(jnp.float32)   # [Q, N]

    a = dt * A[None, :]                    # [Q, hb] log-decay
    a_cs = jnp.cumsum(a, axis=0)           # inclusive

    # intra-chunk quadratic
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, Q] (i, j)
    seg = a_cs[:, None, :] - a_cs[None, :, :]  # [Q, Q, hb]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where((ii >= jj)[:, :, None], seg, NEG_INF))
    m = scores[:, :, None] * decay * dt[None, :, :]          # [Q, Q, hb]
    # y_intra[i,h,p] = sum_j m[i,j,h] x[j,h,p]  (batch over h)
    mh = m.transpose(2, 0, 1)                                # [hb, Q, Q]
    xh = x.transpose(1, 0, 2)                                # [hb, Q, P]
    y_intra = jax.lax.dot_general(
        mh, xh, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )  # [hb, Q, P]

    # inter-chunk read of the entering state
    st = state_ref[...]                                      # [hb, P, N]
    y_in = jax.lax.dot_general(
        Cm, st, (((1,), (2,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, hb, P]
    y_inter = y_in * jnp.exp(a_cs)[:, :, None]
    y_ref[0, 0] = (y_intra.transpose(1, 0, 2) + y_inter).astype(y_ref.dtype)

    # state update: S <- exp(a_last) S + sum_j exp(a_last - a_cs[j]) dt_j x_j B_j^T
    a_last = a_cs[-1]                                        # [hb]
    w = jnp.exp(a_last[None, :] - a_cs) * dt                 # [Q, hb]
    xw = (x * w[:, :, None]).transpose(1, 2, 0)              # [hb, P, Q]
    upd = jax.lax.dot_general(
        xw, Bm, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [hb, P, N]
    state_ref[...] = st * jnp.exp(a_last)[:, None, None] + upd

    @pl.when(c == num_chunks - 1)
    def _finish():
        fin_ref[0] = state_ref[...]


def ssd_scan(
    x: jax.Array,    # [B, L, H, P]
    dt: jax.Array,   # [B, L, H] f32
    A: jax.Array,    # [H] f32
    Bm: jax.Array,   # [B, L, 1, N]  (G=1)
    Cm: jax.Array,   # [B, L, 1, N]
    chunk: int,
    initial_state: jax.Array | None = None,  # [B, H, P, N]
    head_block: int = 8,
    interpret: bool = True,
):
    """Returns (y [B,L,H,P], final_state [B,H,P,N])."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    assert Bm.shape[2] == 1, "kernel supports ngroups=1; use ref for G>1"
    assert L % chunk == 0
    nc = L // chunk
    hb = min(head_block, H)
    assert H % hb == 0
    nh = H // hb
    if initial_state is None:
        initial_state = jnp.zeros((B, H, P, N), jnp.float32)

    xc = x.reshape(B, nc, chunk, H, P)
    dtc = dt.astype(jnp.float32).reshape(B, nc, chunk, H)
    Bc = Bm.reshape(B, nc, chunk, N)
    Cc = Cm.reshape(B, nc, chunk, N)
    s0 = initial_state.reshape(B, nh, hb, P, N).reshape(B * nh, hb, P, N)
    # regroup head-block dim for clean BlockSpecs
    xc = xc.reshape(B, nc, chunk, nh, hb, P).transpose(0, 3, 1, 2, 4, 5).reshape(
        B * nh, nc, chunk, hb, P
    )
    dtc = dtc.reshape(B, nc, chunk, nh, hb).transpose(0, 3, 1, 2, 4).reshape(
        B * nh, nc, chunk, hb
    )
    A_blk = A.astype(jnp.float32).reshape(nh, hb)
    Bc = jnp.broadcast_to(Bc[:, None], (B, nh, nc, chunk, N)).reshape(B * nh, nc, chunk, N)
    Cc = jnp.broadcast_to(Cc[:, None], (B, nh, nc, chunk, N)).reshape(B * nh, nc, chunk, N)

    kernel = functools.partial(_ssd_kernel, num_chunks=nc, chunk=chunk)
    y, fin = pl.pallas_call(
        kernel,
        grid=(B * nh, 1, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hb, P), lambda g, z, c: (g, c, 0, 0, 0)),
            pl.BlockSpec((1, 1, chunk, hb), lambda g, z, c: (g, c, 0, 0)),
            pl.BlockSpec((1, hb), lambda g, z, c, nh=nh: (g % nh, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda g, z, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda g, z, c: (g, c, 0, 0)),
            pl.BlockSpec((1, hb, P, N), lambda g, z, c: (g, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hb, P), lambda g, z, c: (g, c, 0, 0, 0)),
            pl.BlockSpec((1, hb, P, N), lambda g, z, c: (g, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * nh, nc, chunk, hb, P), x.dtype),
            jax.ShapeDtypeStruct((B * nh, hb, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hb, P, N), jnp.float32)],
        interpret=interpret,
    )(xc, dtc, A_blk, Bc, Cc, s0)

    y = y.reshape(B, nh, nc, chunk, hb, P).transpose(0, 2, 3, 1, 4, 5).reshape(B, L, H, P)
    fin = fin.reshape(B, nh, hb, P, N).reshape(B, H, P, N)
    return y, fin


__all__ = ["ssd_scan"]
