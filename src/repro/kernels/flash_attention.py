"""Blocked (flash) causal GQA attention — Pallas TPU kernel.

Online-softmax attention tiled for VMEM: the grid walks (batch, q-head,
q-block, kv-block) with the kv-block axis innermost (TPU grids execute the
last axis sequentially), carrying running max/denominator/accumulator in
VMEM scratch.  Block shapes are MXU-aligned (128×head_dim); GQA maps each
query head to its kv head in the BlockSpec index map, so kv blocks are
fetched once per group from HBM.

Causal blocks entirely above the diagonal are skipped with ``pl.when`` —
the kernel does ~half the HBM reads and MXU work of the dense version
(the §Perf hillclimb quantifies this against the unmasked oracle).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # [1, 1, bq, D], [1, 1, bk, D]
    o_ref,                # [1, 1, bq, D]
    m_ref, l_ref, acc_ref,  # scratch: [bq, 1], [bq, 1], [bq, D]
    *,
    scale: float,
    causal: bool,
    bq: int,
    bk: int,
    num_kv_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Skip kv blocks strictly above the causal diagonal.
    run = (not causal) or (ik * bk <= iq * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]  # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # [bq, bk]
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, KH, Sk, D]
    v: jax.Array,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    nq, nk = Sq // bq, Sk // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, bq=bq, bk=bk, num_kv_blocks=nk
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


__all__ = ["flash_attention"]
