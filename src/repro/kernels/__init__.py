"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ``<name>.py`` pairs a ``pl.pallas_call`` (explicit BlockSpec
VMEM tiling, MXU-aligned block shapes) with a pure-jnp oracle in ``ref.py``;
``ops.py`` exposes jit'd wrappers that select kernel vs reference (kernels
run in ``interpret=True`` on CPU — the TPU path is the compile target).

Inventory (DESIGN.md §3):

* ``hash_partition`` — the decoupled exchange operator's partition hot loop
  (paper §3.2.1): multiply-xor hash + per-destination histogram, plus the
  fused partition+pack variants (``partition_pack`` /
  ``hash_partition_pack``) that also emit block-local within-destination
  ranks so the message-buffer pack never materializes a
  ``[rows, num_dest]`` one-hot (see ``ops.partition_ranks``).
* ``flash_attention``— blocked causal/GQA attention (prefill path).
* ``ssd_scan``      — mamba2 SSD chunk kernel (intra-chunk quadratic +
  chunk-state emission fused in VMEM).
* ``moe_dispatch``  — capacity-bounded token->expert packing (the message-
  buffer fill of the MoE exchange).
"""

__all__ = ["ops", "ref"]  # import submodules explicitly (avoids import cycles)
