"""Jit'd public wrappers: kernel when eligible, reference otherwise.

``use_kernels(False)`` (or the REPRO_NO_KERNELS env var) forces the jnp
reference everywhere — the A/B switch the tests and benchmarks flip.
On CPU the kernels execute via ``interpret=True``; on TPU the same code
compiles natively (interpret flag keys off the backend).
"""

from __future__ import annotations

import contextlib
import os
from functools import partial

import jax
import jax.numpy as jnp

from . import ref as R

_FORCE_REF = os.environ.get("REPRO_NO_KERNELS", "") not in ("", "0")
_STATE = {"enabled": not _FORCE_REF}


def kernels_enabled() -> bool:
    return _STATE["enabled"]


@contextlib.contextmanager
def use_kernels(enabled: bool):
    prev = _STATE["enabled"]
    _STATE["enabled"] = enabled
    try:
        yield
    finally:
        _STATE["enabled"] = prev


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ----------------------------------------------------------------------------

def flash_attention(q, k, v, causal=True, scale=None):
    """q [B,S,H,D] model layout -> kernel layout [B,H,S,D] and back."""
    B, Sq, H, D = q.shape
    ok = (
        kernels_enabled()
        and Sq % 128 == 0
        and k.shape[1] % 128 == 0
        and D in (32, 64, 128, 256)
        and H % k.shape[2] == 0
    )
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if ok:
        from .flash_attention import flash_attention as kern

        out = kern(qt, kt, vt, causal=causal, scale=scale, interpret=_interpret())
    else:
        out = R.flash_attention_ref(qt, kt, vt, causal=causal, scale=scale)
    return out.transpose(0, 2, 1, 3)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention_vjp(q, k, v, causal=True):
    """Differentiable flash attention: Pallas forward, chunked-jnp backward.

    The backward recomputes attention with the query-chunked reference and
    differentiates that — flash-style memory without a handwritten backward
    kernel (the recompute is what a remat'd sdpa would do anyway).
    """
    return flash_attention(q, k, v, causal=causal)


def _fa_fwd(q, k, v, causal):
    return flash_attention(q, k, v, causal=causal), (q, k, v)


def _fa_bwd(causal, res, g):
    q, k, v = res
    from repro.models.layers import chunked_sdpa

    def f(q, k, v):
        return chunked_sdpa(q, k, v, causal=causal, q_block=min(512, q.shape[1]))

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention_vjp.defvjp(_fa_fwd, _fa_bwd)


def ssd_scan(x, dt, A, Bm, Cm, chunk, initial_state=None):
    ok = (
        kernels_enabled()
        and Bm.shape[2] == 1
        and x.shape[1] % chunk == 0
        and x.shape[2] % min(8, x.shape[2]) == 0
    )
    if ok:
        from .ssd_scan import ssd_scan as kern

        return kern(x, dt, A, Bm, Cm, chunk, initial_state, interpret=_interpret())
    return R.ssd_scan_ref(x, dt, A, Bm, Cm, chunk, initial_state)


def hash_partition(keys, num_partitions, block=256):
    T = keys.shape[0]
    blk = min(block, T)
    if kernels_enabled() and T % blk == 0:
        from .hash_partition import hash_partition as kern

        return kern(keys, num_partitions, block=blk, interpret=_interpret())
    return R.hash_partition_ref(keys, num_partitions, block=blk)


def _combine_block_ranks(hist, local_rank, dest, blk):
    """Global within-bin ranks from per-block histograms + block-local ranks.

    ``rank[t] = sum(hist[b, dest[t]] for b < block_of(t)) + local_rank[t]``;
    the exclusive scan is over ``[nblocks, num_bins]`` and the per-row lookup
    is a flat 1-D gather — nothing of shape ``[rows, num_bins]`` exists.
    """
    num_bins = hist.shape[1]
    base = jnp.cumsum(hist, axis=0) - hist  # exclusive over blocks
    blocks = jnp.arange(dest.shape[0]) // blk
    flat_idx = blocks * num_bins + jnp.clip(dest, 0, num_bins - 1)
    return base.reshape(-1)[flat_idx] + local_rank


def partition_ranks(dest, num_bins, block=256):
    """(within-bin ranks [T], bin counts [num_bins]) for destination ids.

    The fused-pack entry point: Pallas kernel per block (histogram +
    block-local rank), cheap XLA combine across blocks.  ``dest`` values
    outside ``[0, num_bins)`` get an arbitrary rank and count nowhere.
    Handles arbitrary ``T`` by padding with an inert out-of-range id.
    """
    T = dest.shape[0]
    blk = min(block, T)
    pad = (-T) % blk
    d = dest.astype(jnp.int32)
    if pad:
        d = jnp.concatenate([d, jnp.full((pad,), num_bins, jnp.int32)])
    if kernels_enabled():
        from .hash_partition import partition_pack as kern

        hist, local = kern(d, num_bins, block=blk, interpret=_interpret())
    else:
        hist, local = R.partition_pack_ref(d, num_bins, block=blk)
    rank = _combine_block_ranks(hist, local, d, blk)
    return rank[:T], hist.sum(axis=0)


def hash_partition_ranks(keys, valid, num_partitions, block=256):
    """Fused hash+mask+rank: (dest [T], ranks [T], counts [P+1]).

    ``dest`` is the masked destination (invalid rows -> overflow bin ``P``).
    Padding rows (arbitrary ``T``) land in the overflow bin, so
    ``counts[num_partitions]`` includes them — only ``counts[:P]`` is
    meaningful to callers.
    """
    T = keys.shape[0]
    blk = min(block, T)
    pad = (-T) % blk
    k = keys.astype(jnp.int32)
    v = valid.astype(jnp.int32)
    if pad:
        k = jnp.concatenate([k, jnp.zeros((pad,), jnp.int32)])
        v = jnp.concatenate([v, jnp.zeros((pad,), jnp.int32)])
    if kernels_enabled():
        from .hash_partition import hash_partition_pack as kern

        dest, hist, local = kern(
            k, v, num_partitions, block=blk, interpret=_interpret()
        )
    else:
        dest, hist, local = R.hash_partition_pack_ref(k, v, num_partitions, block=blk)
    rank = _combine_block_ranks(hist, local, dest, blk)
    return dest[:T], rank[:T], hist.sum(axis=0)


def moe_dispatch(dest, num_dest, capacity, block=256):
    """(slot [T], counts [num_dest]); overflow/padding -> num_dest*capacity.

    Arbitrary ``T``: rows are padded with the inert id ``num_dest`` (matches
    no expert, ranks nowhere, slots to the drop bin) so the kernel's
    block-grid contract holds — the decode-step dispatch ships a handful of
    tokens per slot, far from any block multiple.
    """
    T = dest.shape[0]
    blk = min(block, T)
    if kernels_enabled():
        from .moe_dispatch import moe_dispatch as kern

        pad = (-T) % blk
        d = dest
        if pad:
            d = jnp.concatenate(
                [d, jnp.full((pad,), num_dest, dest.dtype)]
            )
        slot, counts = kern(d, num_dest, capacity, block=blk, interpret=_interpret())
        return slot[:T], counts
    return R.moe_dispatch_ref(dest, num_dest, capacity)


__all__ = [
    "kernels_enabled",
    "use_kernels",
    "flash_attention",
    "ssd_scan",
    "hash_partition",
    "partition_ranks",
    "hash_partition_ranks",
    "moe_dispatch",
]
