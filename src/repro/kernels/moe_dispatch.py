"""Capacity-bounded token->expert slot assignment — Pallas TPU kernel.

The MoE exchange (models/moe.py) packs each (token, choice) into a fixed
per-expert message buffer: ``slot = expert * C + arrival_rank``, dropping
overflow — the paper's fixed-size reusable message pool.  The arrival-rank
computation is an inherently *sequential* running histogram over the token
stream; this kernel carries the per-expert counters in VMEM scratch across a
sequential grid (one pass over token blocks, no [T, E] cumsum materialized
in HBM like the jnp reference does — that intermediate is T×E×4 bytes,
~1 GB for olmoe's train cell).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dispatch_kernel(
    dest_ref,   # [blk] int32
    slot_ref,   # out [blk] int32
    count_ref,  # out [1, E] int32 (final counts, clamped to capacity)
    run_ref,    # scratch [1, E] int32 running histogram
    *,
    num_dest: int,
    capacity: int,
    block: int,
    num_blocks: int,
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        run_ref[...] = jnp.zeros_like(run_ref)

    dest = dest_ref[...]  # [blk]
    onehot = (
        dest[:, None] == jax.lax.broadcasted_iota(jnp.int32, (block, num_dest), 1)
    ).astype(jnp.int32)
    within = jnp.cumsum(onehot, axis=0) - onehot      # rank within this block
    base = run_ref[0]                                  # [E] counts before block
    rank = jnp.sum(onehot * (within + base[None, :]), axis=1)
    kept = rank < capacity
    slot_ref[...] = jnp.where(kept, dest * capacity + rank, num_dest * capacity)
    run_ref[0] = base + onehot.sum(axis=0)

    @pl.when(i == num_blocks - 1)
    def _finish():
        count_ref[0] = jnp.minimum(run_ref[0], capacity)


def moe_dispatch(
    dest: jax.Array, num_dest: int, capacity: int, block: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(slot [T] int32, counts [num_dest] int32); overflow -> num_dest*capacity."""
    T = dest.shape[0]
    blk = min(block, T)
    assert T % blk == 0, (T, blk)
    nb = T // blk
    kernel = functools.partial(
        _dispatch_kernel, num_dest=num_dest, capacity=capacity, block=blk, num_blocks=nb
    )
    slot, counts = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1, num_dest), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((1, num_dest), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, num_dest), jnp.int32)],
        interpret=interpret,
    )(dest)
    return slot, counts[0]


__all__ = ["moe_dispatch"]
