"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------------
# flash_attention oracle.
# ----------------------------------------------------------------------------

def flash_attention_ref(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, KH, Sk, D]
    v: jax.Array,  # [B, KH, Sk, D]
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, KH, G, Sq, D)
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qg, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bksd->bkgqd", w, v)
    return out.reshape(B, H, Sq, D)


# ----------------------------------------------------------------------------
# ssd_scan oracle (delegates to the validated pure-jnp chunked scan).
# ----------------------------------------------------------------------------

def ssd_scan_ref(x, dt, A, Bm, Cm, chunk, initial_state=None):
    from repro.models.mamba2 import ssd_chunked

    return ssd_chunked(x, dt, A, Bm, Cm, chunk, initial_state, use_kernel=False)


# ----------------------------------------------------------------------------
# hash_partition oracle.
# ----------------------------------------------------------------------------

def fibonacci_hash_ref(keys: jax.Array) -> jax.Array:
    x = keys.astype(jnp.uint32)
    x ^= x >> 16
    x = x * jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    x = x * jnp.uint32(0x846CA68B)
    x ^= x >> 16
    return x


def hash_partition_ref(
    keys: jax.Array, num_partitions: int, block: int = 256
) -> tuple[jax.Array, jax.Array]:
    """(partition ids [T], per-block histogram [T/block, P])."""
    T = keys.shape[0]
    assert T % block == 0
    pid = (fibonacci_hash_ref(keys) % jnp.uint32(num_partitions)).astype(jnp.int32)
    onehot = jax.nn.one_hot(pid.reshape(T // block, block), num_partitions, dtype=jnp.int32)
    return pid, onehot.sum(axis=1)


def partition_pack_ref(
    dest: jax.Array, num_bins: int, block: int = 256
) -> tuple[jax.Array, jax.Array]:
    """(per-block histograms [T/block, num_bins], block-local ranks [T]).

    Oracle for :func:`repro.kernels.hash_partition.partition_pack`.  Out-of-
    range destinations (the wrappers' padding value) match no bin: rank 0,
    no histogram contribution.
    """
    T = dest.shape[0]
    assert T % block == 0, (T, block)
    d = dest.reshape(T // block, block)
    onehot = (d[:, :, None] == jnp.arange(num_bins)[None, None, :]).astype(jnp.int32)
    csum = jnp.cumsum(onehot, axis=1)
    local = ((csum - onehot) * onehot).sum(axis=-1).reshape(T)
    hist = onehot.sum(axis=1)
    return hist, local


def hash_partition_pack_ref(
    keys: jax.Array, valid: jax.Array, num_partitions: int, block: int = 256
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(dest [T], per-block histograms [T/block, P+1], block-local ranks [T])."""
    pid = (fibonacci_hash_ref(keys) % jnp.uint32(num_partitions)).astype(jnp.int32)
    dest = jnp.where(valid != 0, pid, num_partitions)
    hist, local = partition_pack_ref(dest, num_partitions + 1, block)
    return dest, hist, local


# ----------------------------------------------------------------------------
# moe_dispatch oracle: rank-within-expert + capacity slots.
# ----------------------------------------------------------------------------

def moe_dispatch_ref(
    dest: jax.Array, num_dest: int, capacity: int
) -> tuple[jax.Array, jax.Array]:
    """(slot [T], counts [num_dest]).

    ``slot[t] = dest[t] * capacity + rank`` if the row fits its destination
    buffer, else the overflow bin ``num_dest * capacity``.
    """
    onehot = jax.nn.one_hot(dest, num_dest, dtype=jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) - onehot
    my_rank = jnp.take_along_axis(rank, dest[:, None], axis=1)[:, 0]
    kept = my_rank < capacity
    slot = jnp.where(kept, dest * capacity + my_rank, num_dest * capacity)
    counts = jnp.minimum(onehot.sum(axis=0), capacity)
    return slot.astype(jnp.int32), counts


__all__ = [
    "flash_attention_ref",
    "ssd_scan_ref",
    "fibonacci_hash_ref",
    "hash_partition_ref",
    "partition_pack_ref",
    "hash_partition_pack_ref",
    "moe_dispatch_ref",
]
