"""JAX version-compat shims.

The codebase targets the current public API (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``) but must
also run on jax 0.4.x, where ``shard_map`` still lives in
``jax.experimental.shard_map`` (with ``check_rep`` instead of ``check_vma``)
and ``make_mesh`` takes no ``axis_types``.  Every module that builds meshes
or shard_maps goes through these two functions instead of touching ``jax``
directly.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax


def make_mesh(
    axis_shapes: Sequence[int], axis_names: Sequence[str]
) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            axis_shapes,
            axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(
    f: Any,
    *,
    mesh: jax.sharding.Mesh,
    in_specs: Any,
    out_specs: Any,
    check_vma: bool = True,
    axis_names: Any = None,
):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` on old.

    ``check_vma`` maps onto the old API's ``check_rep`` (both gate the
    replication/varying-manual-axes consistency check).  ``axis_names`` (the
    set of mesh axes the body is manual over) is honored on new jax; on
    0.4.x the equivalent partial-manual mode (``auto=`` complement) hits an
    XLA SPMD-partitioner check failure, so we run fully manual instead —
    axes not mentioned in a spec are then treated as replicated, which is
    semantically equivalent for bodies that only communicate over their
    manual axes (all in-repo call sites).
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if axis_names is None else {"axis_names": axis_names}
        return jax.shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=check_vma,
            **kwargs,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=check_vma,
    )


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager making ``mesh`` the ambient mesh.

    ``jax.set_mesh`` on new jax; on 0.4.x the ``Mesh`` object itself is the
    context manager that sets the global mesh for sharding resolution.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def axis_size(axis_name: Any) -> int:
    """Static size of a named mesh axis from inside shard_map.

    ``jax.lax.axis_size`` on new jax; on 0.4.x the classic idiom —
    ``psum`` of a unit constant, which the axis environment folds to a
    concrete int at trace time.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return int(jax.lax.psum(1, axis_name))


__all__ = ["make_mesh", "shard_map", "set_mesh", "axis_size"]
