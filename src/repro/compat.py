"""JAX version-compat shims.

The codebase targets the current public API (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``) but must
also run on jax 0.4.x, where ``shard_map`` still lives in
``jax.experimental.shard_map`` (with ``check_rep`` instead of ``check_vma``)
and ``make_mesh`` takes no ``axis_types``.  Every module that builds meshes
or shard_maps goes through these two functions instead of touching ``jax``
directly.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax


def make_mesh(
    axis_shapes: Sequence[int], axis_names: Sequence[str]
) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            axis_shapes,
            axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(
    f: Any,
    *,
    mesh: jax.sharding.Mesh,
    in_specs: Any,
    out_specs: Any,
    check_vma: bool = True,
    axis_names: Any = None,
):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` on old.

    ``check_vma`` maps onto the old API's ``check_rep`` (both gate the
    replication/varying-manual-axes consistency check).  ``axis_names`` (the
    set of mesh axes the body is manual over) is honored on new jax; on
    0.4.x the equivalent partial-manual mode (``auto=`` complement) hits an
    XLA SPMD-partitioner check failure, so we run fully manual instead —
    axes not mentioned in a spec are then treated as replicated, which is
    semantically equivalent for bodies that only communicate over their
    manual axes (all in-repo call sites).
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if axis_names is None else {"axis_names": axis_names}
        return jax.shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=check_vma,
            **kwargs,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=check_vma,
    )


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager making ``mesh`` the ambient mesh.

    ``jax.set_mesh`` on new jax; on 0.4.x the ``Mesh`` object itself is the
    context manager that sets the global mesh for sharding resolution.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def axis_size(axis_name: Any) -> int:
    """Static size of a named mesh axis from inside shard_map.

    ``jax.lax.axis_size`` on new jax; on 0.4.x the classic idiom —
    ``psum`` of a unit constant, which the axis environment folds to a
    concrete int at trace time.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return int(jax.lax.psum(1, axis_name))


def enable_cpu_collectives() -> bool:
    """Turn on cross-process CPU collectives (Gloo) where the jax supports it.

    Multi-process CPU runs (``launch/cluster.py``) need a CPU collectives
    backend — without one every cross-process psum/ppermute fails with
    "Multiprocess computations aren't implemented on the CPU backend".  The
    config knob is ``jax_cpu_collectives_implementation`` on 0.4.35+; older
    jaxlibs only honor the environment variable, and some builds ship
    without Gloo at all — so failure here is reported, not raised (the
    caller decides whether multi-process was mandatory).  Must run before
    the CPU backend is initialized (i.e. before any device query).
    """
    import os

    os.environ.setdefault("JAX_CPU_COLLECTIVES_IMPLEMENTATION", "gloo")
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, KeyError, ValueError):
        return False
    return True


def fetch(x: Any):
    """Concrete numpy value of an array that may span multiple processes.

    Single-process (every device addressable): plain ``np.asarray``.  In a
    multi-process run a jit output can span devices this process cannot
    address, and 0.4.x raises on plain value fetch even for replicated
    outputs — read the local shard when the array is fully replicated, and
    all-gather across processes otherwise.  Pytrees are mapped leaf-wise.
    """
    import numpy as np

    def one(leaf):
        if not hasattr(leaf, "sharding"):  # numpy / python scalar
            return np.asarray(leaf)
        if leaf.is_fully_addressable:
            return np.asarray(leaf)
        if leaf.is_fully_replicated:
            return np.asarray(leaf.addressable_shards[0].data)
        from jax.experimental import multihost_utils

        return np.asarray(multihost_utils.process_allgather(leaf, tiled=True))

    return jax.tree.map(one, x)


__all__ = [
    "make_mesh",
    "shard_map",
    "set_mesh",
    "axis_size",
    "enable_cpu_collectives",
    "fetch",
]
