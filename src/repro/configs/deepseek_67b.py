"""DeepSeek-67B [arXiv:2401.02954]: llama-arch dense, GQA kv=8, 95 layers."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22_016,
    vocab_size=102_400,
    rope_theta=10_000.0,
)

SMOKE = CONFIG.scaled(
    num_layers=4, d_model=96, num_heads=8, num_kv_heads=2, d_ff=192,
    vocab_size=499, dtype="float32", remat="none",
)
