"""Qwen1.5-32B [hf:Qwen/Qwen1.5-32B, sheet]: MHA (kv=40), QKV bias."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27_392,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.scaled(
    num_layers=3, d_model=80, num_heads=5, num_kv_heads=5, d_ff=208,
    vocab_size=487, dtype="float32", remat="none",
)
