"""Assigned architecture configs (one module per arch) + the paper's own.

``get_config(name)`` returns the full-size :class:`~repro.configs.base.ModelConfig`;
``get_smoke_config(name)`` returns the reduced same-family config used by the
CPU smoke tests (small layers/width/experts/vocab, identical code paths).
"""

from __future__ import annotations

import importlib

from .base import ModelConfig, ShapeSpec, SHAPES, shapes_for

ARCH_IDS = (
    "minicpm-2b",
    "qwen2.5-3b",
    "deepseek-67b",
    "qwen1.5-32b",
    "mamba2-1.3b",
    "deepseek-v2-lite-16b",
    "olmoe-1b-7b",
    "zamba2-7b",
    "whisper-medium",
    "qwen2-vl-2b",
)

_MODULES = {
    "minicpm-2b": "minicpm_2b",
    "qwen2.5-3b": "qwen2_5_3b",
    "deepseek-67b": "deepseek_67b",
    "qwen1.5-32b": "qwen1_5_32b",
    "mamba2-1.3b": "mamba2_1_3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "zamba2-7b": "zamba2_7b",
    "whisper-medium": "whisper_medium",
    "qwen2-vl-2b": "qwen2_vl_2b",
    # extra (not in the assigned list): the 100M example arch
    "train100m": "train100m",
}


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


__all__ = [
    "ARCH_IDS",
    "ModelConfig",
    "ShapeSpec",
    "SHAPES",
    "shapes_for",
    "get_config",
    "get_smoke_config",
]
