"""Model/shape config dataclasses shared by all assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A complete architecture description (decoder LM unless noted).

    Only a subset of fields applies per family; unused fields stay at their
    zero defaults.  All assigned configs instantiate this exactly as printed
    on the assignment sheet; reduced smoke variants use ``scaled(...)``.
    """

    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # --- attention ---------------------------------------------------------
    head_dim: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    attn_kind: Literal["gqa", "mla"] = "gqa"
    rope_kind: Literal["rope", "mrope", "sinusoidal", "none"] = "rope"
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # t/h/w rope splits

    # --- MLA (deepseek-v2) -------------------------------------------------
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0  # deepseek: layer 0 is a dense FFN
    router_norm_topk: bool = False  # normalize top-k probs to sum 1
    capacity_factor: float = 1.25

    # --- SSM (mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_ngroups: int = 1

    # --- hybrid (zamba2) -----------------------------------------------------
    attn_every: int = 0  # apply the shared attention block every k-th layer

    # --- embeddings / output --------------------------------------------------
    tie_embeddings: bool = False
    emb_scale: float = 1.0        # minicpm scale_emb
    residual_scale: float = 1.0   # minicpm scale_depth / sqrt(num_layers)
    logits_scale: float = 1.0     # minicpm: d_model / dim_model_base
    norm_eps: float = 1e-5
    act: Literal["silu", "gelu"] = "silu"

    # --- enc-dec (whisper) ----------------------------------------------------
    encoder_layers: int = 0
    is_encoder_decoder: bool = False

    # --- training schedule (assignment sheet: minicpm uses WSD) ----------------
    lr_schedule: Literal["cosine", "wsd"] = "cosine"

    # --- execution policy (not architecture) -----------------------------------
    dtype: str = "bfloat16"        # activation/param compute dtype
    param_dtype: str = "float32"   # master params
    scan_layers: bool = True
    remat: Literal["none", "block", "full"] = "block"
    attn_impl: Literal["auto", "sdpa", "chunked", "flash"] = "auto"
    attn_q_block: int = 512
    num_microbatches: int = 1
    moe_impl: Literal["dense", "gspmd", "ep_shardmap"] = "dense"
    exchange_impl: str = "round_robin"
    # Async overlap of exchange with expert compute: split the EP capacity
    # buffers into this many chunks and double-buffer dispatch against the
    # expert FFN (bit-identical for any divisor of the capacity; an ambient
    # multiplexer's tuned pipeline_chunks takes precedence).
    moe_async_chunks: int = 1
    # Unroll factor for the layer scan (transformer decode/prefill) and the
    # microbatch accumulation scan: > 1 interleaves consecutive iterations'
    # HLO so the latency-hiding scheduler can start layer k+1's dispatch
    # while layer k's expert compute runs.  Numerics-neutral.
    overlap_unroll: int = 1
    grad_sync: Literal["auto", "hierarchical"] = "auto"
    # §Perf levers (off in the paper-faithful baseline)
    grad_shard_constraint: bool = False  # pin grads to param sharding (AR->RS)
    uneven_shards: bool = False          # keep constraints on non-divisible dims
    sequence_parallel: bool = False      # residual seq dim -> model (RS/AG not AR)
    dp_only: bool = False                # ZeRO-3: batch over BOTH axes, no TP (dense parts)
    exchange_over_data: bool = False     # EP exchange over the data axis (paper topology)

    # -----------------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """long_500k runs only for sub-quadratic (SSM/hybrid) archs."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS=6*N*D)."""
        from repro.models import registry  # local import to avoid cycle

        return registry.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import registry

        return registry.param_count(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shapes_for(cfg: ModelConfig) -> list[ShapeSpec]:
    """The assigned shape set for one arch (long_500k only if sub-quadratic)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.supports_long_context:
        out.append(SHAPES["long_500k"])
    return out


__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "shapes_for", "Family"]
