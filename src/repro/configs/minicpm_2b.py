"""MiniCPM-2B [arXiv:2404.06395]: llama-like dense, mu-p scaling, WSD schedule.

scale_emb=12, scale_depth=1.4 (residual scale 1.4/sqrt(L)), logits divided by
d_model/dim_model_base = 2304/256 = 9.
"""

import math

from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    tie_embeddings=True,
    emb_scale=12.0,
    residual_scale=1.4 / math.sqrt(40),
    logits_scale=256.0 / 2304.0,
    rope_theta=10_000.0,
    lr_schedule="wsd",
)

SMOKE = CONFIG.scaled(
    num_layers=3, d_model=72, num_heads=6, num_kv_heads=6, d_ff=144,
    vocab_size=503, residual_scale=1.4 / math.sqrt(3),
    logits_scale=256.0 / 72.0, dtype="float32", remat="none",
)
