"""Mamba2-1.3B [arXiv:2405.21060]: pure SSD (state-space duality), attn-free."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=1,      # attention-free; placeholder
    num_kv_heads=1,
    d_ff=0,           # mamba blocks subsume the FFN
    vocab_size=50_280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_ngroups=1,
    rope_kind="none",
)

SMOKE = CONFIG.scaled(
    num_layers=3, d_model=64, ssm_state=16, ssm_head_dim=8, ssm_chunk=8,
    vocab_size=491, dtype="float32", remat="none",
)
