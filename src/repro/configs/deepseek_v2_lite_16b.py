"""DeepSeek-V2-Lite (16B) [arXiv:2405.04434]: MLA + fine-grained MoE.

Sheet says "MoE 64e top-6 ... 2 shared+160 routed"; 160 routed belongs to
full V2 — V2-Lite is 64 routed + 2 shared, top-6 (DESIGN.md note).  Layer 0
is a dense FFN (published intermediate 10944); MoE expert width 1408.
MLA: kv_lora_rank 512, qk_nope 128, qk_rope 64, v_head 128.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10_944,          # the dense first layer's FFN
    vocab_size=102_400,
    attn_kind="mla",
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    router_norm_topk=True,
    rope_theta=10_000.0,
    moe_impl="ep_shardmap",
)

SMOKE = CONFIG.scaled(
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, d_ff=176,
    vocab_size=497, kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
    v_head_dim=16, num_experts=8, top_k=2, moe_d_ff=48, num_shared_experts=1,
    dtype="float32", remat="none", moe_impl="dense",
)
