"""Whisper-medium [arXiv:2212.04356]: enc-dec backbone, conv frontend STUB.

24 encoder + 24 decoder layers; input_specs provides precomputed frame
embeddings (the conv frontend's output) per the assignment.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,
    encoder_layers=24,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51_865,
    qkv_bias=True,
    act="gelu",
    rope_kind="sinusoidal",
)

SMOKE = CONFIG.scaled(
    num_layers=2, encoder_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=463, dtype="float32", remat="none",
)
