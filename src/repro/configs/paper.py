"""The paper's own workload configuration (TPC-H over the exchange engine).

Mirrors the evaluation setup of §4: a 6-unit cluster (we run the nearest
power of two on the test mesh), SF-scaled TPC-H, hash-partition vs
broadcast per the hybrid planner, round-robin scheduled transport.
``examples/distributed_query.py`` and ``benchmarks/bench_tpch.py`` consume
this.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperConfig:
    scale_factor: float = 0.02      # CPU-scale stand-in for the paper's SF 100
    num_units: int = 8              # paper: 6 servers; we use the 8-dev test mesh
    threads_per_unit: int = 40      # paper's 20 cores x 2 HT (cost model only)
    exchange_impl: str = "round_robin"   # the paper's scheduled transport
    message_bytes: int = 512 * 1024      # paper §3.2.3: 512 KB messages
    zipf_z: float = 0.84            # §3.1 skew experiment
    queries: tuple = ("q1", "q6", "q17", "q3")


CONFIG = PaperConfig()
SMOKE = dataclasses.replace(CONFIG, scale_factor=0.001, num_units=4)
