"""Zamba2-7B [arXiv:2411.15242]: mamba2 backbone + shared attention block.

81 layers = 13 groups of 6 mamba2 blocks (attn_every=6), each followed by
the ONE weight-shared transformer block, + a 3-layer mamba tail.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14_336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_ngroups=1,
    attn_every=6,
    rope_theta=10_000.0,
)

SMOKE = CONFIG.scaled(
    num_layers=5, attn_every=2, d_model=64, num_heads=4, num_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=467, ssm_state=16, ssm_head_dim=8,
    ssm_chunk=8, dtype="float32", remat="none",
)
