"""Qwen2-VL-2B [arXiv:2409.12191]: M-RoPE backbone; vision frontend STUB.

input_specs provides precomputed patch embeddings prepended to the token
stream; M-RoPE splits each rotary half into (temporal, height, width)
sections (16, 24, 24) over head_dim 128.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151_936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.scaled(
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    mrope_sections=(2, 3, 3), d_ff=128, vocab_size=457,
    dtype="float32", remat="none",
)
