"""Qwen2.5-3B [hf:Qwen/Qwen2.5-3B]: GQA kv=2, QKV bias, tied embeddings."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11_008,
    vocab_size=151_936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.scaled(
    num_layers=3, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=160, vocab_size=509, dtype="float32", remat="none",
)
