"""~100M-parameter llama-family config for the end-to-end training example."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="train100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    d_ff=2048,
    vocab_size=32_000,
    tie_embeddings=True,
    dtype="float32",
    remat="block",
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=503, remat="none")
