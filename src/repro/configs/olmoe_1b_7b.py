"""OLMoE-1B-7B [arXiv:2409.02060]: 64 experts, top-8, all layers MoE."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50_304,
    num_experts=64,
    top_k=8,
    moe_d_ff=1024,
    router_norm_topk=False,
    rope_theta=10_000.0,
    moe_impl="ep_shardmap",
)

SMOKE = CONFIG.scaled(
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, d_ff=48,
    vocab_size=479, num_experts=8, top_k=2, moe_d_ff=48,
    dtype="float32", remat="none", moe_impl="dense",
)
