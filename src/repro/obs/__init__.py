"""Observability: the telemetry spine (spans, counters, query traces),
Perfetto export, and the model-vs-measured gate.

Import surface is deliberately lazy-friendly: :mod:`repro.obs.trace` has no
repro dependencies (executors import it freely), :mod:`repro.obs.export`
depends only on trace, and :mod:`repro.obs.model_check` imports the planner
lazily so ``python -m repro.obs.model_check`` can set fake-device flags
before jax initializes.
"""

from .trace import (  # noqa: F401
    ExchangeEdge,
    QueryTrace,
    Span,
    Tracer,
    deposit,
    maybe_span,
    model_error,
)

__all__ = [
    "ExchangeEdge",
    "QueryTrace",
    "Span",
    "Tracer",
    "deposit",
    "maybe_span",
    "model_error",
]
