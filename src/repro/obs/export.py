"""Trace export: JSON round-trip + Chrome trace-event (Perfetto) timelines.

Two serializations of one :class:`~repro.obs.trace.Tracer`:

* **JSON** — the full record (spans, counters, gauges, histograms, query
  traces) in a schema that round-trips: ``query_trace_from_dict(
  query_trace_to_dict(qt)) == qt``, so a trace written by a benchmark run
  can be re-loaded and re-gated later.

* **Chrome trace-event** — the ``traceEvents`` array Perfetto and
  ``chrome://tracing`` load directly: matched ``B``/``E`` duration events
  (microsecond timestamps, sorted), one *process* track per cluster
  process (``pid = jax.process_index()``) and one thread track per host
  thread.  :func:`write_trace_dir` writes ``trace-p<pid>.json`` per
  process; :func:`merge_trace_dir` concatenates every per-process file
  into one timeline — span timestamps are wall-clock epoch, so two Gloo
  processes on one host line up without clock translation.
"""

from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Any

from .trace import ExchangeEdge, QueryTrace, Span, Tracer

__all__ = [
    "query_trace_to_dict",
    "query_trace_from_dict",
    "query_trace_to_json",
    "query_trace_from_json",
    "chrome_trace_events",
    "tracer_to_dict",
    "write_trace",
    "write_trace_dir",
    "merge_trace_dir",
]


# ---------------------------------------------------------------------------
# QueryTrace JSON round-trip.
# ---------------------------------------------------------------------------


def query_trace_to_dict(qt: QueryTrace) -> dict:
    d = dataclasses.asdict(qt)
    d["counters"] = dict(qt.counters)
    d["edges"] = [dataclasses.asdict(e) for e in qt.edges]
    for e in d["edges"]:
        e["hist"] = list(e["hist"])
    return d


def query_trace_from_dict(d: dict) -> QueryTrace:
    edges = tuple(
        ExchangeEdge(**{**e, "hist": tuple(int(x) for x in e["hist"])})
        for e in d.get("edges", ())
    )
    return QueryTrace(
        query=d["query"],
        num_shards=int(d["num_shards"]),
        num_pods=int(d["num_pods"]),
        edges=edges,
        counters=dict(d.get("counters", {})),
        measured_s=d.get("measured_s"),
    )


def query_trace_to_json(qt: QueryTrace) -> str:
    return json.dumps(query_trace_to_dict(qt), sort_keys=True)


def query_trace_from_json(s: str) -> QueryTrace:
    return query_trace_from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Chrome trace-event export.
# ---------------------------------------------------------------------------


def _span_events(s: Span, out: list[dict]) -> None:
    ts = s.t0 * 1e6                       # trace-event timestamps are µs
    dur = (s.dur or 0.0) * 1e6
    args = {k: v for k, v in s.args.items() if _jsonable(v)}
    out.append(
        dict(name=s.name, cat=s.cat, ph="B", ts=ts, pid=s.pid, tid=s.tid,
             args=args)
    )
    for c in s.children:
        _span_events(c, out)
    out.append(
        dict(name=s.name, cat=s.cat, ph="E", ts=ts + dur, pid=s.pid,
             tid=s.tid)
    )


def _jsonable(v: Any) -> bool:
    return isinstance(v, (str, int, float, bool, type(None)))


def chrome_trace_events(tracer: Tracer, process_name: str | None = None) -> list[dict]:
    """The ``traceEvents`` array: metadata + sorted, matched B/E pairs.

    Events are emitted in (ts, B-before-E-at-equal-ts) order — Perfetto
    tolerates unsorted input but the validity tests (and humans diffing
    two traces) should not have to."""
    events: list[dict] = []
    for root in tracer.spans:
        _span_events(root, events)
    # Stable sort: ts ascending; at equal ts, B (opens) before E (closes)
    # of a *different* span, but an E already emitted before a B at the
    # same ts stays put — sorting on (ts, ph!="B") keeps pairs matched
    # because a child's B/E always nests strictly inside its parent's.
    events.sort(key=lambda e: (e["ts"], e["ph"] != "E"))
    meta: list[dict] = [
        dict(
            name="process_name", ph="M", pid=tracer.pid, tid=0,
            args={"name": process_name or f"process {tracer.pid}"},
        )
    ]
    return meta + events


def tracer_to_dict(tracer: Tracer, process_name: str | None = None) -> dict:
    """Everything: Perfetto loads ``traceEvents`` and ignores the rest;
    the JSON consumers read ``counters``/``queryTraces``."""
    return dict(
        traceEvents=chrome_trace_events(tracer, process_name),
        displayTimeUnit="ms",
        counters=dict(tracer.counters),
        gauges=dict(tracer.gauges),
        histograms={k: list(v) for k, v in tracer.histograms.items()},
        queryTraces=[query_trace_to_dict(qt) for qt in tracer.query_traces],
    )


def write_trace(tracer: Tracer, path: str, process_name: str | None = None) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(tracer_to_dict(tracer, process_name), f)
    os.replace(tmp, path)
    return path


def write_trace_dir(tracer: Tracer, trace_dir: str, basename: str = "trace") -> str:
    """Per-process trace file: ``<dir>/<basename>-p<pid>.json``.  Every
    process of a cluster writes its own file (atomic rename), then any one
    process merges with :func:`merge_trace_dir`."""
    return write_trace(
        tracer, os.path.join(trace_dir, f"{basename}-p{tracer.pid}.json")
    )


def merge_trace_dir(
    trace_dir: str, basename: str = "trace", out: str | None = None
) -> dict:
    """Merge every ``<basename>-p*.json`` in ``trace_dir`` into ONE
    Perfetto-loadable timeline (events re-sorted across processes; each
    process keeps its own pid track).  Writes ``out`` when given; returns
    the merged dict."""
    merged = dict(
        traceEvents=[], displayTimeUnit="ms", counters={}, gauges={},
        histograms={}, queryTraces=[],
    )
    paths = sorted(glob.glob(os.path.join(trace_dir, f"{basename}-p*.json")))
    if not paths:
        raise FileNotFoundError(
            f"no {basename}-p*.json trace files under {trace_dir!r}"
        )
    for p in paths:
        with open(p) as f:
            d = json.load(f)
        merged["traceEvents"].extend(d.get("traceEvents", ()))
        for k, v in d.get("counters", {}).items():
            merged["counters"][k] = merged["counters"].get(k, 0.0) + v
        merged["gauges"].update(d.get("gauges", {}))
        for k, v in d.get("histograms", {}).items():
            merged["histograms"].setdefault(k, []).extend(v)
        merged["queryTraces"].extend(d.get("queryTraces", ()))
    merged["traceEvents"].sort(
        key=lambda e: (0 if e.get("ph") == "M" else 1, e.get("ts", 0.0))
    )
    if out is not None:
        tmp = f"{out}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(merged, f)
        os.replace(tmp, out)
    return merged
