"""Model-vs-measured: per-edge error ratios between the planner/autotuner
and what the devices actually did.

The paper's cost model is only worth its exchange placements if its inputs
survive contact with data (Rödiger §3.1 prices every exchange; §6 checks
the prices).  For every traced shuffle edge this module compares

* **bytes** — the planner's modeled wire bytes
  (:meth:`PhysicalPlan.exchange_summary`, the §3.1 ``exchange_bytes``
  formula over catalog capacities) against the MEASURED arrivals (the
  psum'd destination histogram priced with the same (n-1)/n wire rule),
  as ``byte_model_err = max(modeled/measured, measured/modeled)``.  This
  ratio is deterministic for a given dataset and hardware-independent, so
  CI gates it at the same 2x bound ``bench_autotune`` applies to its
  makespan model.

* **time** — the autotuner's predicted makespan for the edge
  (:func:`repro.core.autotune.exchange_makespan` under the plan's tuned
  knobs) against the run's measured wall time, apportioned over edges by
  predicted share.  On CPU fake devices this ratio is surfaced but NOT
  gated: the model prices TPU ICI links, so only a
  :func:`~repro.core.autotune.calibrate_chip`-calibrated chip makes the
  2x bar meaningful (the ROADMAP's real-hardware item records into
  exactly this field).

``python -m repro.obs.model_check --query q17 --shards 8 --streamed``
runs one traced query on fake devices and prints the JSON report —
``bench_tpch`` shells out to it for the measured column, and the
OBSERVABILITY doc's executable block is a variant of it.
"""

from __future__ import annotations

from typing import Mapping

from .trace import ExchangeEdge, QueryTrace

__all__ = [
    "edge_models",
    "build_query_trace",
    "model_report",
    "assert_bytes_within",
    "BYTE_MODEL_BOUND",
]

# The CI bound on byte_model_err — same 2x bar bench_autotune asserts for
# its makespan model.
BYTE_MODEL_BOUND = 2.0


def edge_models(plan) -> dict[str, dict]:
    """Per-shuffle-edge model predictions, keyed like the runtime reports.

    Walks the plan's shuffle edges in :func:`_report_keys` order (the same
    stable ``shuffle[<col>]#<ordinal>`` keys the executor reports under)
    and prices each one: modeled wire bytes via the planner's own
    ``_wire_bytes`` and predicted makespan via ``exchange_makespan`` with
    the plan's tuned knobs.  Tuned chunk counts that do not divide an
    edge's row count fall back to unchunked — the same fallback
    ``hash_shuffle`` itself applies.
    """
    from ..core.autotune import exchange_makespan
    from ..relational.planner.executor import _report_keys
    from ..relational.planner.physical import PlannerConfig, exchange_bytes

    keys = _report_keys(plan.root)
    n_inner = plan.num_shards // max(plan.num_pods, 1)
    tuned = plan.tuned
    out: dict[str, dict] = {}

    def walk(n, seen):
        if id(n) in seen:
            return
        seen.add(id(n))
        for c in n.children:
            walk(c, seen)
        if n.kind != "exchange" or n.info["exkind"] != "shuffle":
            return
        st = n.info["stats"]
        # Bytes are priced on the rows the estimator expects to FLOW
        # (``est_rows`` — selectivity/containment-aware when the plan saw a
        # profile), not the buffer capacity: streamed plans cap ``stats`` at
        # one morsel-step, and a selective filter/join upstream means far
        # fewer valid rows than capacity.  The makespan prediction below
        # keeps the capacity stats — that is what the autotuner priced.
        est_rows = n.info.get("est_rows") or st.rows * plan.num_shards
        modeled = exchange_bytes(
            "partition", int(round(est_rows)), 0, st.row_bytes,
            PlannerConfig(num_units=plan.num_shards),
        )
        try:
            predicted = exchange_makespan(
                st, n_inner, impl=tuned.impl, pack_impl=tuned.pack_impl,
                pipeline_chunks=tuned.pipeline_chunks,
                transport_chunks=tuned.transport_chunks,
                num_pods=plan.num_pods,
            )
        except AssertionError:  # chunk knobs don't divide this edge's rows
            predicted = exchange_makespan(
                st, n_inner, impl=tuned.impl, pack_impl=tuned.pack_impl,
                pipeline_chunks=1, transport_chunks=1,
                num_pods=plan.num_pods,
            )
        out[keys[id(n)]] = dict(
            rows=int(round(est_rows)),
            row_bytes=int(st.row_bytes),
            modeled_wire_bytes=int(modeled),
            predicted_s=float(predicted),
        )

    walk(plan.root, set())
    return out


def _wire_fraction(num_shards: int) -> float:
    """A hash-routed row crosses the wire iff it leaves its shard:
    probability (n-1)/n — the planner's own partition-bytes rule."""
    return (num_shards - 1) / num_shards if num_shards > 1 else 0.0


def build_query_trace(
    plan,
    reports: Mapping[str, Mapping],
    models: Mapping[str, Mapping] | None = None,
    counters: Mapping[str, float] | None = None,
    measured_s: float | None = None,
) -> QueryTrace:
    """Assemble one run's :class:`QueryTrace` from the fetched device
    reports plus the plan's edge models.

    ``reports`` maps edge keys to the executor's per-shuffle report
    (``hist``/``overload``/``plain_overload``/``salted``).  Streamed runs
    key multi-pass traversals as ``<edge>@p<pass>`` — the base edge's
    model applies to each traversal (every pass re-ships the rows).
    ``measured_s`` (dispatch-to-fetched wall) is apportioned over edges by
    predicted share.
    """
    import numpy as np

    models = edge_models(plan) if models is None else models
    frac = _wire_fraction(plan.num_shards)
    edges = []
    preds = []
    for key in reports:
        base = key.split("@p")[0]
        preds.append((models.get(base) or {}).get("predicted_s") or 0.0)
    total_pred = sum(preds) or float(len(reports) or 1)
    for (key, rep), pred in zip(reports.items(), preds):
        base = key.split("@p")[0]
        m = models.get(base) or {}
        hist = np.asarray(rep["hist"]).astype(np.int64)
        rows_arrived = int(hist.sum())
        row_bytes = int(m.get("row_bytes") or 0)
        traversals = int(rep.get("traversals", 1) or 1)
        share = (
            pred / total_pred if total_pred else 1.0 / max(len(reports), 1)
        )
        edges.append(
            ExchangeEdge(
                key=key,
                rows=int(m.get("rows") or 0),
                row_bytes=row_bytes,
                hist=tuple(int(x) for x in hist),
                measured_bytes=int(rows_arrived * row_bytes * frac),
                modeled_wire_bytes=(
                    int(m.get("modeled_wire_bytes") or 0) * traversals
                ),
                traversals=traversals,
                overload=float(rep["overload"]),
                plain_overload=float(rep["plain_overload"]),
                salted=bool(rep["salted"]),
                predicted_s=m.get("predicted_s"),
                measured_s=(
                    measured_s * share if measured_s is not None else None
                ),
            )
        )
    return QueryTrace(
        query=plan.name,
        num_shards=plan.num_shards,
        num_pods=plan.num_pods,
        edges=tuple(edges),
        counters=dict(counters or {}),
        measured_s=measured_s,
    )


def model_report(qt: QueryTrace) -> dict:
    """Flat model-error summary for one run (benchmarks emit this):
    per-edge byte/time error ratios plus the worst byte ratio — the
    number CI's ``--compare`` gate watches (lower is better, >= 1)."""
    per_edge = {
        e.key: dict(
            measured_bytes=e.measured_bytes,
            modeled_wire_bytes=e.modeled_wire_bytes,
            byte_model_err=e.byte_model_err,
            predicted_s=e.predicted_s,
            measured_s=e.measured_s,
            time_model_err=e.time_model_err,
        )
        for e in qt.edges
    }
    byte_errs = [e.byte_model_err for e in qt.edges if e.byte_model_err]
    return dict(
        query=qt.query,
        edges=per_edge,
        worst_byte_model_err=max(byte_errs) if byte_errs else None,
    )


def assert_bytes_within(qt: QueryTrace, bound: float = BYTE_MODEL_BOUND) -> None:
    """Raise if any edge's measured wire bytes disagree with the planner's
    model by more than ``bound``x (edges that shipped zero rows are
    vacuous)."""
    for e in qt.edges:
        err = e.byte_model_err
        if err is not None and err > bound:
            raise AssertionError(
                f"{qt.query} {e.key}: measured {e.measured_bytes}B vs "
                f"modeled {e.modeled_wire_bytes}B wire bytes — "
                f"{err:.2f}x exceeds the {bound}x model bound"
            )


# ---------------------------------------------------------------------------
# CLI: one traced query on fake devices, report as JSON.
# ---------------------------------------------------------------------------


def _cli_run(args) -> dict:
    # Import order matters: the fake-device flag must precede jax init.
    import os

    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.shards}",
    )
    from repro.obs import export as obs_export
    from repro.obs.trace import Tracer
    from repro.relational import datagen
    from repro.relational import stats as rstats
    from repro.relational.context import ExecutionContext, StatsMode
    from repro.relational.planner import tpch as T

    tabs = datagen.gen_all(args.sf)
    pq = T.ALL_QUERIES[args.query]()
    tables = {t: tabs[t] for t in pq.tables}
    morsel_rows = args.morsel_rows
    if args.streamed and not morsel_rows:
        morsel_rows = max(tabs["lineitem"].capacity // 4, 1)
    tracer = Tracer()
    # Plan from a data profile: the byte model prices the rows the
    # estimator expects to flow, which is only meaningful when the
    # estimator has seen the data (selectivities, key ndv).
    ctx = ExecutionContext(
        num_shards=args.shards, num_pods=args.pods,
        morsel_rows=morsel_rows or None, trace=tracer,
        stats_mode=StatsMode.PROFILE,
        stats_profile=rstats.collect_stats(tables),
    )
    result = T.run_query(pq, tables, ctx)
    qt = tracer.query_traces[-1] if tracer.query_traces else None
    rep = model_report(qt) if qt is not None else {"query": args.query}
    try:
        rep["result"] = float(result)
    except (TypeError, ValueError):
        rep["result"] = None
    rep["span_names"] = sorted(
        {s.name.split(":")[0] for root in tracer.spans for s in root.walk()}
    )
    if args.trace_dir:
        rep["trace_path"] = obs_export.write_trace_dir(
            tracer, args.trace_dir, basename=f"model_check-{args.query}"
        )
    if qt is not None and args.bound > 0:
        assert_bytes_within(qt, args.bound)
    return rep


def main(argv=None) -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(
        description="run one traced TPC-H query and report model-vs-measured"
    )
    ap.add_argument("--query", default="q17")
    ap.add_argument("--sf", type=float, default=0.01)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--streamed", action="store_true",
                    help="stream lineitem morsel-by-morsel (out of core)")
    ap.add_argument("--morsel-rows", type=int, default=0)
    ap.add_argument("--trace-dir", default=None)
    ap.add_argument("--bound", type=float, default=BYTE_MODEL_BOUND,
                    help="fail if byte_model_err exceeds this (0 disables)")
    args = ap.parse_args(argv)
    print(json.dumps(_cli_run(args), indent=2, default=str))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
