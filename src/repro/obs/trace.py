"""One telemetry spine: host-side spans + per-query device counters.

The repo's measurement story used to be a pile of one-off side channels
(``run.exchange_report`` mutated on a function attribute, ``run.stats`` on
the streamed runner, ``ttfr_s`` fields on serve requests).  This module is
the one home for all of it, mirroring the paper's own discipline — every
design claim in Rödiger §4–§6 is justified by a per-phase timing or a
bandwidth-utilization number, so the repro records both, per query:

* :class:`Tracer` — nested host-side spans (plan → compile → pass → morsel
  → exchange → drain-round on the query side; admission round / prefill /
  decode step on the serve side) plus a thread-safe registry of counters,
  gauges and histograms.  Attach one via the frozen
  ``ExecutionContext.trace`` knob: the field is ``compare=False`` so a
  traced and an untraced context hash equal — tracing never invalidates a
  plan-cache or executor-memo entry, and never changes what runs inside
  the jit (device counters are ALWAYS on; the tracer only decides whether
  anyone writes them down).

* :class:`QueryTrace` — the per-run record of what the devices measured:
  one :class:`ExchangeEdge` per shuffle (destination histogram psum'd
  inside the jit, measured vs modeled wire bytes, the autotuner's
  predicted makespan next to measured wall time, salted/plain decision)
  plus the streamed path's spill/drain/prefetch counters.  Returned
  per-run from ``runner.collect(out)`` — the fix for the old
  ``run.exchange_report`` attribute, which concurrent serve rounds
  clobbered — and still readable through that attribute as a
  deprecation-warned view.

Span timestamps are wall-clock epoch seconds (``time.time``) so traces
from different processes of one Gloo cluster merge onto a single timeline;
durations come from the same clock, which is plenty for the >100µs spans
recorded here.  Export to JSON / Chrome trace-event lives in
:mod:`repro.obs.export`.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Any, Iterator, Mapping

__all__ = [
    "Span",
    "Tracer",
    "ExchangeEdge",
    "QueryTrace",
    "maybe_span",
    "model_error",
    "deposit",
]


def _process_index() -> int:
    """This process's track id — ``jax.process_index()`` when jax is up
    (multi-process Gloo runs), else 0.  Resolved lazily so a Tracer can be
    built before ``jax.distributed`` initializes."""
    try:
        import jax

        return int(jax.process_index())
    except Exception:
        return 0


def model_error(predicted: float | None, measured: float | None) -> float | None:
    """Symmetric model-error ratio: ``max(pred/meas, meas/pred)`` — always
    >= 1, lower is better, 1.0 = the model was exact.  The same score
    ``bench_autotune`` gates at 2x.  ``None`` (or a non-positive side) means
    no comparison is possible."""
    if predicted is None or measured is None:
        return None
    if predicted <= 0.0 or measured <= 0.0:
        return None
    return max(predicted / measured, measured / predicted)


# ---------------------------------------------------------------------------
# Spans.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Span:
    """One timed region.  ``t0`` is epoch seconds; ``dur`` is None while
    the span is open.  ``pid``/``tid`` are the Chrome trace-event track
    ids (process index / thread ident)."""

    name: str
    cat: str
    t0: float
    dur: float | None
    pid: int
    tid: int
    args: dict
    children: list["Span"] = dataclasses.field(default_factory=list)

    def walk(self) -> Iterator["Span"]:
        yield self
        for c in self.children:
            yield from c.walk()


class Tracer:
    """Thread-safe span + metric registry.

    Spans nest per-thread (a ``threading.local`` stack); finished root
    spans land in ``self.spans``.  Counters/gauges/histograms are plain
    dicts under one lock — cheap enough to leave on in benchmarks.
    ``query_traces`` accumulates every :class:`QueryTrace` deposited by a
    traced run, in completion order.
    """

    def __init__(self, pid: int | None = None):
        self._pid = pid
        self._lock = threading.Lock()
        self._tls = threading.local()
        self.spans: list[Span] = []
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, list[float]] = {}
        self.query_traces: list["QueryTrace"] = []

    @property
    def pid(self) -> int:
        if self._pid is None:
            self._pid = _process_index()
        return self._pid

    def _stack(self) -> list[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _attach(self, span: Span) -> None:
        stack = self._stack()
        if stack:
            stack[-1].children.append(span)
        else:
            with self._lock:
                self.spans.append(span)

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "host", **args: Any):
        """Open a nested span around a ``with`` block."""
        s = Span(
            name=name, cat=cat, t0=time.time(), dur=None,
            pid=self.pid, tid=threading.get_ident(), args=dict(args),
        )
        self._attach(s)
        self._stack().append(s)
        t0 = time.perf_counter()
        try:
            yield s
        finally:
            s.dur = time.perf_counter() - t0
            self._stack().pop()

    def add_span(
        self,
        name: str,
        cat: str = "host",
        t0: float | None = None,
        dur: float = 0.0,
        **args: Any,
    ) -> Span:
        """Record a span post-hoc (e.g. per-edge exchange spans laid out
        inside an already-measured execute window).  Nests under the
        current thread's open span, if any."""
        s = Span(
            name=name, cat=cat, t0=time.time() if t0 is None else t0,
            dur=dur, pid=self.pid, tid=threading.get_ident(),
            args=dict(args),
        )
        self._attach(s)
        return s

    # -- metrics ------------------------------------------------------------

    def counter(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + value

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            self.histograms.setdefault(name, []).append(float(value))

    # -- query traces ---------------------------------------------------------

    def add_query_trace(self, qt: "QueryTrace") -> None:
        with self._lock:
            self.query_traces.append(qt)


@contextlib.contextmanager
def maybe_span(tracer: Tracer | None, name: str, cat: str = "host", **args):
    """``tracer.span(...)`` when a tracer is attached, else a no-op — the
    one-liner every traced call site uses so untraced runs pay nothing."""
    if tracer is None:
        yield None
        return
    with tracer.span(name, cat=cat, **args) as s:
        yield s


# ---------------------------------------------------------------------------
# The per-run device-counter record.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ExchangeEdge:
    """What one shuffle edge measured, next to what the model predicted.

    ``hist`` is the psum'd per-destination arrival histogram (valid rows,
    the exact routing rule of the exchange).  ``measured_bytes`` prices the
    arrivals with the planner's own wire formula (rows x row_bytes x
    (n-1)/n — a row crosses the wire iff it leaves its shard), so the
    ratio against ``modeled_wire_bytes`` isolates the planner's ROW
    estimate.  ``predicted_s`` is the autotuner's makespan for this edge's
    stats under the plan's tuned knobs; ``measured_s`` the edge's share of
    the run's measured wall time (apportioned by predicted share — per-edge
    device timestamps need a profiler, not a counter).
    """

    key: str
    rows: int                    # estimated rows flowing per traversal
    row_bytes: int
    hist: tuple[int, ...]
    measured_bytes: int
    modeled_wire_bytes: int
    overload: float              # measured max/fair-share of the chosen route
    plain_overload: float        # measured overload of the plain-hash route
    salted: bool                 # did the runtime gate pick the salted route
    predicted_s: float | None = None
    measured_s: float | None = None
    # How many times this edge shipped its input during the traversal the
    # report covers: 1 for in-memory edges and streamed-side edges (the
    # morsel steps sum to one pass over the stream), the morsel-step count
    # for a resident-side edge inside a streamed pass (the evaluator
    # re-ships the unchanged table every step).  ``modeled_wire_bytes``
    # already includes the multiplier — the byte model prices one shipment.
    traversals: int = 1

    @property
    def byte_model_err(self) -> float | None:
        """max(modeled/measured, measured/modeled) wire bytes, >= 1."""
        return model_error(
            float(self.modeled_wire_bytes), float(self.measured_bytes)
        )

    @property
    def time_model_err(self) -> float | None:
        return model_error(self.predicted_s, self.measured_s)

    def legacy_report(self) -> dict:
        """The old ``run.exchange_report`` entry shape for this edge."""
        import numpy as np

        return {
            "hist": np.asarray(self.hist, dtype=np.int64),
            "overload": float(self.overload),
            "plain_overload": float(self.plain_overload),
            "salted": bool(self.salted),
        }


@dataclasses.dataclass(frozen=True)
class QueryTrace:
    """One run's worth of device-side measurement, under one record.

    ``counters`` carries whatever the execution path counted host-side:
    the streamed runner's ``passes``/``morsels``/``spilled_rows``/
    ``drain_rounds``/``prefetch_*`` stats land here verbatim; the
    in-memory executor contributes nothing beyond the edges.
    """

    query: str
    num_shards: int
    num_pods: int
    edges: tuple[ExchangeEdge, ...] = ()
    counters: Mapping[str, float] = dataclasses.field(default_factory=dict)
    measured_s: float | None = None   # dispatch-to-fetched wall time

    def exchange_report(self) -> dict:
        """The legacy ``run.exchange_report`` dict view."""
        return {e.key: e.legacy_report() for e in self.edges}

    def model_errors(self) -> dict[str, dict]:
        """Per-edge model-error ratios (``obs.model_check`` gates these)."""
        return {
            e.key: {
                "byte_model_err": e.byte_model_err,
                "time_model_err": e.time_model_err,
            }
            for e in self.edges
        }


def deposit(tracer: Tracer | None, qt: QueryTrace) -> None:
    """Write one run's QueryTrace into a tracer: the record itself, one
    ``exchange:`` span per edge (laid out inside the measured window when
    one is known), and byte counters.  No-op without a tracer."""
    if tracer is None:
        return
    tracer.add_query_trace(qt)
    now = time.time()
    window = qt.measured_s
    t0 = now - window if window is not None else now
    shares = [e.predicted_s or 0.0 for e in qt.edges]
    total_share = sum(shares) or float(len(qt.edges) or 1)
    at = t0
    for e, share in zip(qt.edges, shares):
        dur = (
            (window or 0.0) * (share / total_share)
            if window is not None
            else (e.measured_s or 0.0)
        )
        tracer.add_span(
            f"exchange:{e.key}", cat="exchange", t0=at, dur=dur,
            query=qt.query, measured_bytes=e.measured_bytes,
            modeled_wire_bytes=e.modeled_wire_bytes,
            byte_model_err=e.byte_model_err,
            predicted_s=e.predicted_s, measured_s=e.measured_s,
            time_model_err=e.time_model_err,
            overload=e.overload, salted=e.salted,
        )
        at += dur
        tracer.counter("exchange.measured_bytes", e.measured_bytes)
        tracer.counter("exchange.modeled_wire_bytes", e.modeled_wire_bytes)
    tracer.counter(f"query.{qt.query}.runs", 1.0)
    for k, v in qt.counters.items():
        if isinstance(v, (int, float)):
            tracer.counter(f"query.{qt.query}.{k}", float(v))
