"""Serving engines: static batching and continuous batching over KV caches.

Two engines share the uniform model API:

* :class:`ServeEngine` — the classic static batch: requests are grouped into
  fixed-size, same-prompt-length batches and decoded in lock step; the batch
  retires when every stream finishes.  This is the serving analogue of the
  *classic exchange operator* the paper critiques: a fixed assignment of
  work to workers, so one long sequence holds every slot hostage.
* :class:`ContinuousEngine` — the paper's fix, applied to decode slots
  instead of relational partitions: parallelism (the fixed decode batch
  shape) is decoupled from the assignment of requests to slots.  A
  :class:`SlotAllocator` keeps a slot map over ONE shared KV cache;
  finished sequences are evicted between decode steps and freed slots are
  refilled from a pending queue (prefill-on-admit scatters the new cache
  rows in place — no retrace, no flush of the running batch).

The continuous decode keeps a fixed ``[batch_size, 1]`` shape with per-slot
positions (``ModelApi.decode_step_slots``), so XLA compiles exactly two
programs (prefill per prompt-length bucket, one decode step) no matter how
requests arrive and finish.  With every slot at the same position the slot
decode is bit-identical to the static step — ``tests/test_serve.py`` holds
the two engines to the same greedy outputs.

Expert-parallel models route the decode step's token dispatch through the
communication multiplexer: when a mesh context is active and
``cfg.moe_impl == "ep_shardmap"``, the engine builds an auto-tuned
:class:`~repro.core.multiplexer.CommMultiplexer` for the *decode-shaped*
message sizes (:func:`repro.core.autotune.decode_table_stats` — tiny
per-step buffers, so the tuner collapses to unchunked transport) and the
MoE layer ships its per-expert capacity buffers through it, under the same
tuned schedules as the relational exchanges.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.obs.trace import maybe_span


def sample_token(key, logits: jax.Array, temperature: float = 0.0) -> jax.Array:
    """Greedy (t=0) or temperature sampling; logits [B, vocab] -> [B]."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # int32 [prompt_len]
    max_new_tokens: int
    eos_id: int = -1  # -1: never stops early
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    # --- continuous batching: arrival + per-request stats -------------------
    arrival_step: int = 0          # decode-step tick at which it may be admitted
    admitted_step: int | None = None
    finished_step: int | None = None
    ttft_s: float | None = None    # wall from ARRIVAL to first token
    decode_tok_s: float | None = None  # tokens/s over the decode phase
    _t_arrive: float | None = dataclasses.field(default=None, repr=False)
    _t_first: float | None = dataclasses.field(default=None, repr=False)

    @property
    def num_new_tokens(self) -> int:
        return len(self.out_tokens)


class SlotAllocator:
    """Slot map over the shared KV cache: admission + eviction-on-finish.

    The paper's flexible exchange in miniature — the fixed resource (decode
    slots = cache rows) is decoupled from the work assigned to it.  Holds
    the invariant ``free + live == num_slots`` at every step boundary
    (``check()``); a leaked slot is a leaked cache row.
    """

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self._free: list[int] = list(range(num_slots - 1, -1, -1))  # pop() -> slot 0 first
        self.live: dict[int, Request] = {}

    @property
    def num_free(self) -> int:
        return len(self._free)

    def admit(self, request: Request) -> int:
        """Assign a free slot to ``request``; caller prefills the cache row."""
        if not self._free:
            raise RuntimeError("no free slot (caller must check num_free)")
        slot = self._free.pop()
        self.live[slot] = request
        return slot

    def release(self, slot: int) -> Request:
        """Eviction-on-finish: the slot returns to the free list immediately."""
        request = self.live.pop(slot)
        self._free.append(slot)
        return request

    def check(self) -> None:
        assert len(self._free) + len(self.live) == self.num_slots, (
            f"slot leak: free={len(self._free)} live={len(self.live)} "
            f"!= {self.num_slots}"
        )
        assert set(self._free).isdisjoint(self.live), (self._free, self.live)


class ServeEngine:
    """Greedy/temperature STATIC batched generation over the uniform model API."""

    def __init__(self, api: registry.ModelApi, batch_size: int, capacity: int,
                 temperature: float = 0.0, seed: int = 0):
        self.api = api
        self.cfg = api.cfg
        self.batch_size = batch_size
        self.capacity = capacity
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(api.prefill)
        self._decode = jax.jit(api.decode_step)
        self.stats = {"prefill_tokens": 0, "decode_steps": 0, "slot_steps": 0,
                      "wall": 0.0}

    def _prefill_batch(self, params, prompts: np.ndarray, extra: dict | None = None):
        batch = {"tokens": jnp.asarray(prompts)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        logits, cache = self._prefill(params, batch)
        self.stats["prefill_tokens"] += int(prompts.size)
        return logits, cache

    def generate(
        self,
        params,
        requests: list[Request],
        extra_inputs: dict | None = None,
    ) -> list[Request]:
        """Run one static batch of same-length prompts to completion."""
        t0 = time.perf_counter()
        assert len(requests) <= self.batch_size
        plen = requests[0].prompt.shape[0]
        assert all(r.prompt.shape[0] == plen for r in requests), "bucket by length"
        B = self.batch_size
        prompts = np.zeros((B, plen), np.int32)
        for i, r in enumerate(requests):
            prompts[i] = r.prompt

        logits, cache = self._prefill_batch(params, prompts, extra_inputs)
        # Decode continues after the WHOLE prefill context — for VLM that is
        # patches + prompt rows, not just the prompt — into a capacity-length
        # cache (pad if needed).
        ctx_len = int(jax.tree.leaves(cache)[0].shape[2])
        cache = self._grow_cache(cache, ctx_len)

        max_new = max(r.max_new_tokens for r in requests)
        # Split BEFORE the first sample: reusing self.key both directly and
        # as the parent of later splits correlated the first token of every
        # batch (and max_new==1 batches never advanced the key at all).
        self.key, sub = jax.random.split(self.key)
        tokens = sample_token(sub, logits, self.temperature)
        live = np.array([not r.done for r in requests] + [False] * (B - len(requests)))
        for i, r in enumerate(requests):
            r.out_tokens.append(int(tokens[i]))
            if r.max_new_tokens <= 1 or int(tokens[i]) == r.eos_id:
                r.done = True
                live[i] = False

        pos = ctx_len
        for step in range(1, max_new):
            if pos >= self.capacity or not live.any():
                break
            self.key, sub = jax.random.split(self.key)
            logits, cache = self._decode(params, tokens[:, None], cache, jnp.int32(pos))
            tokens = sample_token(sub, logits, self.temperature)
            self.stats["decode_steps"] += 1
            self.stats["slot_steps"] += B
            pos += 1
            arr = np.asarray(tokens)
            for i, r in enumerate(requests):
                if live[i]:
                    r.out_tokens.append(int(arr[i]))
                    if len(r.out_tokens) >= r.max_new_tokens or arr[i] == r.eos_id:
                        r.done = True
                        live[i] = False
        for r in requests:
            r.done = True
        self.stats["wall"] += time.perf_counter() - t0
        return requests

    def _grow_cache(self, cache: Any, filled: int) -> Any:
        """Pad prefill-length cache arrays out to ``self.capacity`` slots.

        Identifies the cache-sequence dim as the one equal to ``filled``
        in the reference (capacity-sized) cache template.
        """
        template = jax.eval_shape(lambda: self.api.init_cache(self.batch_size, self.capacity))

        def grow(leaf, ref):
            if leaf.shape == ref.shape:
                return leaf
            pads = []
            for have, want in zip(leaf.shape, ref.shape):
                assert want >= have, (leaf.shape, ref.shape)
                pads.append((0, want - have))
            return jnp.pad(leaf, pads)

        return jax.tree.map(grow, cache, template)


def generate_bucketed(
    engine: ServeEngine, params, requests: list[Request],
    extra_inputs: dict | None = None,
) -> list[Request]:
    """Static-batch a MIXED-length workload: bucket by prompt length, then
    run fixed batches per bucket — the baseline the continuous engine beats.
    Requests are served in arrival order within each bucket."""
    buckets: dict[int, list[Request]] = {}
    for r in requests:
        buckets.setdefault(r.prompt.shape[0], []).append(r)
    for plen in sorted(buckets):
        group = buckets[plen]
        for i in range(0, len(group), engine.batch_size):
            engine.generate(params, group[i : i + engine.batch_size], extra_inputs)
    return requests


def make_mixed_workload(
    vocab_size: int,
    num_requests: int,
    prompt_lens: Sequence[int],
    max_new: int,
    rng: np.random.Generator,
    arrival_rate: float = 0.0,
) -> list[Request]:
    """The standard mixed workload the CLI and the bench both run.

    Prompt lengths cycle through ``prompt_lens`` (one prefill bucket each),
    output budgets are uniform in ``[1, max_new]``, and with
    ``arrival_rate`` r > 0 request ``i`` arrives at decode step ``i / r``
    (0 = everything queued up front).
    """
    reqs = []
    for i in range(num_requests):
        plen = prompt_lens[i % len(prompt_lens)]
        reqs.append(Request(
            prompt=rng.integers(0, vocab_size, plen, dtype=np.int32),
            max_new_tokens=int(rng.integers(1, max_new + 1)),
            arrival_step=int(i / arrival_rate) if arrival_rate > 0 else 0,
        ))
    return reqs


def engine_record(reqs: list[Request], stats: dict, wall: float) -> dict:
    """One engine run -> the comparable summary record (bench JSON / CLI)."""
    total_new = sum(len(r.out_tokens) for r in reqs)
    rec = {
        "requests": len(reqs),
        "new_tokens": total_new,
        "decode_steps": stats["decode_steps"],
        "slot_steps": stats["slot_steps"],
        "wall_s": round(wall, 4),
        "tok_s": round(total_new / wall, 2) if wall > 0 else None,
    }
    ttfts = [r.ttft_s for r in reqs if r.ttft_s is not None]
    if ttfts:
        rec["ttft_mean_s"] = round(float(np.mean(ttfts)), 4)
        rec["ttft_p99_s"] = round(float(np.quantile(ttfts, 0.99)), 4)
    if "live_slot_steps" in stats:
        rec["live_slot_steps"] = stats["live_slot_steps"]
    return rec


# ----------------------------------------------------------------------------
# Continuous batching.
# ----------------------------------------------------------------------------

class ContinuousEngine:
    """Continuous-batching generation: slot map + admission between steps.

    One persistent ``[batch_size, capacity]`` KV cache; requests stream
    through it.  Per iteration:

    1. **admit** — free slots are refilled from the pending queue (grouped
       by prompt length, one batched prefill per group, scattered into the
       slots' cache regions in place);
    2. **decode** — one fixed-shape ``decode_step_slots`` over ALL slots at
       their own positions (dead slots compute masked garbage);
    3. **evict** — streams that hit ``max_new_tokens``/EOS/capacity release
       their slot immediately, so the next iteration can admit into it.

    Stats are per-request (``ttft_s``, ``decode_tok_s``) plus engine
    aggregates; ``slot_steps`` (= decode_steps x batch_size) is the
    slot-occupancy currency the static-vs-continuous comparison uses.
    """

    def __init__(self, api: registry.ModelApi, batch_size: int, capacity: int,
                 temperature: float = 0.0, seed: int = 0, tracer=None):
        #: Optional :class:`repro.obs.trace.Tracer` — admission rounds,
        #: prefill groups and decode steps become spans on it.
        self.tracer = tracer
        if api.decode_step_slots is None:
            raise NotImplementedError(
                f"continuous batching needs a per-position KV cache; "
                f"family {api.cfg.family!r} does not provide decode_step_slots"
            )
        self.api = api
        self.cfg = api.cfg
        self.batch_size = batch_size
        self.capacity = capacity
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(api.prefill)
        self._decode = jax.jit(api.decode_step_slots)
        self._scatter = jax.jit(self._scatter_prefill)
        self.alloc = SlotAllocator(batch_size)
        self.stats = {
            "prefill_tokens": 0, "decode_steps": 0, "slot_steps": 0,
            "live_slot_steps": 0, "idle_steps": 0, "admitted": 0,
            "finished": 0, "wall": 0.0,
        }
        self.mux = self._make_decode_multiplexer()

    # -- EP dispatch over the communication multiplexer ---------------------

    def _make_decode_multiplexer(self):
        """Auto-tune a multiplexer for the decode step's expert traffic.

        Only when the model is expert-parallel (``ep_shardmap``) and a mesh
        context is active; the tuner prices the per-step ``E x C`` capacity
        buffers (tiny), so it lands on the unchunked scheduled transport.
        """
        if self.cfg.moe_impl != "ep_shardmap":
            return None
        from repro.distributed.sharding import current_mesh_context

        ctx = current_mesh_context()
        if ctx is None:
            return None
        # A parallel unit is one device of the JOINT (pod, exchange) axis:
        # on a pod mesh the dispatch runs the two-level fabric across
        # pods * exchange_size units, and the tuner must price the capacity
        # buffers the MoE layer actually sizes for that unit count.
        pods = ctx.mesh.shape[ctx.pod_axis] if ctx.pod_axis is not None else 1
        units = ctx.exchange_size * pods
        if units <= 1:
            return None
        from repro.core.autotune import decode_table_stats
        from repro.core.multiplexer import make_multiplexer

        stats = decode_table_stats(self.cfg, self.batch_size, units)
        return make_multiplexer(ctx.mesh, auto=True, table_stats=[stats])

    def _mux_scope(self):
        if self.mux is None:
            return contextlib.nullcontext()
        from repro.core.multiplexer import use_multiplexer

        return use_multiplexer(self.mux)

    # -- cache scatter (prefill-on-admit) -----------------------------------

    @staticmethod
    def _scatter_prefill(cache, pref, slots, active):
        """Write prefilled cache rows into their slots' regions, in place.

        ``slots [B]`` is a PERMUTATION of the slot ids: row ``j`` of the
        prefill batch lands in slot ``slots[j]`` when ``active[j]``;
        inactive rows re-write their target slot's current bytes (a no-op)
        so every slot is written exactly once — deterministic scatter, and
        the jitted program is reused for any number of admits (the admit
        count only changes ``active``'s values, not any shape).
        """
        def upd(leaf, p):
            # leaf [L, B, capacity, ...]; p [L, B, plen, ...]
            plen = p.shape[2]
            cur = jnp.take(leaf, slots, axis=1)[:, :, :plen]
            mask = active.reshape((1, -1) + (1,) * (leaf.ndim - 2))
            val = jnp.where(mask, p.astype(leaf.dtype), cur)
            return leaf.at[:, slots, :plen].set(val)

        return jax.tree.map(upd, cache, pref)

    def _admit_group(self, params, cache, requests: list[Request], step: int,
                     t0: float, extra: dict | None):
        """Prefill one same-prompt-length group and scatter it into slots."""
        B, plen = self.batch_size, requests[0].prompt.shape[0]
        prompts = np.zeros((B, plen), np.int32)
        for j, r in enumerate(requests):
            prompts[j] = r.prompt
        batch = {"tokens": jnp.asarray(prompts)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        with maybe_span(self.tracer, f"prefill:len{plen}", "serve",
                        requests=len(requests), step=step):
            logits, pref_cache = self._prefill(params, batch)
            jax.block_until_ready(logits)
        self.stats["prefill_tokens"] += len(requests) * plen
        # The context a slot starts with is the PREFILL CACHE length, not the
        # prompt length — the VLM frontend prepends patch rows, so its cache
        # is patches + prompt wide.  Decode continues after the whole prefix.
        ctx_len = int(jax.tree.leaves(pref_cache)[0].shape[2])
        if ctx_len >= self.capacity:
            raise ValueError(
                f"admission rejected: prefill context of {ctx_len} rows "
                f"(prompt {plen} + side inputs) cannot fit a capacity-"
                f"{self.capacity} cache slot"
            )

        slot_of = [self.alloc.admit(r) for r in requests]
        # complete the slot vector to a permutation of range(B): inactive
        # rows target the remaining slots and rewrite their current bytes
        rest = [s for s in range(B) if s not in set(slot_of)]
        slots = np.array(slot_of + rest[: B - len(slot_of)], np.int32)
        active = np.zeros((B,), bool)
        active[: len(requests)] = True
        cache = self._scatter(cache, pref_cache, jnp.asarray(slots),
                              jnp.asarray(active))

        self.key, sub = jax.random.split(self.key)
        first = np.asarray(sample_token(sub, logits, self.temperature))
        now = time.perf_counter() - t0
        for j, r in enumerate(requests):
            r.admitted_step = step
            r.out_tokens.append(int(first[j]))
            r.ttft_s = now - (r._t_arrive or 0.0)
            r._t_first = now
            self.stats["admitted"] += 1
            self._positions[slot_of[j]] = ctx_len
            self._tokens[slot_of[j]] = int(first[j])
            if r.max_new_tokens <= 1 or int(first[j]) == r.eos_id:
                self._finish(slot_of[j], r, step, t0)
        return cache

    def _finish(self, slot: int, r: Request, step: int, t0: float):
        r.done = True
        r.finished_step = step
        dt = (time.perf_counter() - t0) - (r._t_first or 0.0)
        if r.num_new_tokens > 1 and dt > 0:
            r.decode_tok_s = (r.num_new_tokens - 1) / dt
        self.stats["finished"] += 1
        self.alloc.release(slot)
        # park the dead slot at position 0 with token 0: it keeps decoding
        # (fixed batch shape) but its writes land in a region the next
        # admission's prefill scatter overwrites
        self._positions[slot] = 0
        self._tokens[slot] = 0

    # -- the serve loop -----------------------------------------------------

    def serve(
        self,
        params,
        requests: list[Request],
        extra_inputs: dict | None = None,
    ) -> list[Request]:
        """Run a mixed-length workload to completion with slot refill.

        Requests become admittable at ``arrival_step`` (a decode-step tick —
        virtual time, so tests and benches are deterministic).  Among the
        arrived, freed slots go to the LONGEST remaining budget first (LPT
        scheduling: starting a long sequence late is what stretches the
        makespan tail; ties keep arrival order, so uniform workloads admit
        FIFO).  Raises UP FRONT (before any state mutates) on requests that
        can never be admitted — prompt plus any side-input context rows
        (VLM patches) must fit a cache slot.
        """
        side = 0
        if extra_inputs and "patches" in extra_inputs:
            # the VLM frontend prepends this many rows to every slot's cache
            side = int(np.asarray(extra_inputs["patches"]).shape[1])
        for r in requests:
            if r.prompt.shape[0] + side >= self.capacity:
                raise ValueError(
                    f"admission rejected: prompt of {r.prompt.shape[0]} tokens"
                    + (f" + {side} side-input rows" if side else "")
                    + f" cannot fit a capacity-{self.capacity} cache slot"
                )
        t0 = time.perf_counter()
        B = self.batch_size
        pending = sorted(requests, key=lambda r: r.arrival_step)
        cache = self.api.init_cache(B, self.capacity)
        self._positions = np.zeros((B,), np.int32)
        self._tokens = np.zeros((B,), np.int32)
        step = 0

        with self._mux_scope():
            while pending or self.alloc.live:
                # -- admission: refill freed slots from the arrived queue --
                n_arrived = 0
                while (n_arrived < len(pending)
                       and pending[n_arrived].arrival_step <= step):
                    n_arrived += 1
                for i in range(n_arrived):  # TTFT clock starts at arrival
                    if pending[i]._t_arrive is None:
                        pending[i]._t_arrive = time.perf_counter() - t0
                admittable: list[Request] = []
                if n_arrived and self.alloc.num_free:
                    # LPT pick among the arrived; admit in arrival order
                    pick = sorted(
                        range(n_arrived),
                        key=lambda i: -pending[i].max_new_tokens,
                    )[: self.alloc.num_free]
                    chosen = set(pick)
                    admittable = [pending[i] for i in sorted(chosen)]
                    pending = [r for i, r in enumerate(pending)
                               if i not in chosen]
                by_len: dict[int, list[Request]] = {}
                for r in admittable:
                    by_len.setdefault(r.prompt.shape[0], []).append(r)
                if by_len:
                    with maybe_span(self.tracer, f"admission-round:{step}",
                                    "serve", admitted=len(admittable),
                                    groups=len(by_len)):
                        for plen in sorted(by_len):
                            cache = self._admit_group(
                                params, cache, by_len[plen], step, t0,
                                extra_inputs,
                            )
                self.alloc.check()

                if not self.alloc.live:
                    # nothing to decode: idle tick toward the next arrival
                    step += 1
                    self.stats["idle_steps"] += 1
                    continue

                # -- one fixed-shape decode step over every slot -----------
                self.key, sub = jax.random.split(self.key)
                with maybe_span(self.tracer, f"decode-step:{step}", "serve",
                                live=len(self.alloc.live)):
                    logits, cache = self._decode(
                        params, jnp.asarray(self._tokens[:, None]), cache,
                        jnp.asarray(self._positions),
                    )
                    sampled = np.asarray(
                        sample_token(sub, logits, self.temperature)
                    )
                self.stats["decode_steps"] += 1
                self.stats["slot_steps"] += B
                self.stats["live_slot_steps"] += len(self.alloc.live)

                # -- bookkeeping + eviction-on-finish ----------------------
                for slot, r in list(self.alloc.live.items()):
                    tok = int(sampled[slot])
                    r.out_tokens.append(tok)
                    self._tokens[slot] = tok
                    self._positions[slot] += 1
                    if (r.num_new_tokens >= r.max_new_tokens
                            or tok == r.eos_id
                            or self._positions[slot] >= self.capacity):
                        self._finish(slot, r, step, t0)
                step += 1
                self.alloc.check()

        self.stats["wall"] += time.perf_counter() - t0
        return requests


__all__ = [
    "ServeEngine",
    "ContinuousEngine",
    "SlotAllocator",
    "Request",
    "sample_token",
    "generate_bucketed",
    "make_mixed_workload",
    "engine_record",
]
