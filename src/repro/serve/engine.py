"""Batched serving engine: prefill + lock-step decode over KV caches.

Batching model: requests are grouped into fixed-size batches (padded to the
engine's batch size) and decoded in lock step — every stream appends one
token per ``decode_step`` against a shared-capacity cache, matching the
assignment's ``decode_*`` cells ("one new token with a KV cache of
seq_len").  Finished streams are masked; the batch retires when all finish
(static batching; the slot map for continuous batching is noted in
DESIGN.md as the multi-host extension).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry


def sample_token(key, logits: jax.Array, temperature: float = 0.0) -> jax.Array:
    """Greedy (t=0) or temperature sampling; logits [B, vocab] -> [B]."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # int32 [prompt_len]
    max_new_tokens: int
    eos_id: int = -1  # -1: never stops early
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Greedy/temperature batched generation over the uniform model API."""

    def __init__(self, api: registry.ModelApi, batch_size: int, capacity: int,
                 temperature: float = 0.0, seed: int = 0):
        self.api = api
        self.cfg = api.cfg
        self.batch_size = batch_size
        self.capacity = capacity
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(api.prefill)
        self._decode = jax.jit(api.decode_step)
        self.stats = {"prefill_tokens": 0, "decode_steps": 0, "wall": 0.0}

    def _prefill_batch(self, params, prompts: np.ndarray, extra: dict | None = None):
        batch = {"tokens": jnp.asarray(prompts)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        logits, cache = self._prefill(params, batch)
        self.stats["prefill_tokens"] += int(prompts.size)
        return logits, cache

    def generate(
        self,
        params,
        requests: list[Request],
        extra_inputs: dict | None = None,
    ) -> list[Request]:
        """Run one static batch of same-length prompts to completion."""
        t0 = time.perf_counter()
        assert len(requests) <= self.batch_size
        plen = requests[0].prompt.shape[0]
        assert all(r.prompt.shape[0] == plen for r in requests), "bucket by length"
        B = self.batch_size
        prompts = np.zeros((B, plen), np.int32)
        for i, r in enumerate(requests):
            prompts[i] = r.prompt

        logits, cache = self._prefill_batch(params, prompts, extra_inputs)
        # prefill produced a prompt-length cache; decode continues into a
        # capacity-length cache (pad if needed)
        cache = self._grow_cache(cache, plen)

        max_new = max(r.max_new_tokens for r in requests)
        tokens = sample_token(self.key, logits, self.temperature)
        live = np.array([not r.done for r in requests] + [False] * (B - len(requests)))
        for i, r in enumerate(requests):
            r.out_tokens.append(int(tokens[i]))
            if r.max_new_tokens <= 1 or int(tokens[i]) == r.eos_id:
                r.done = True
                live[i] = False

        pos = plen
        for step in range(1, max_new):
            if pos >= self.capacity or not live.any():
                break
            self.key, sub = jax.random.split(self.key)
            logits, cache = self._decode(params, tokens[:, None], cache, jnp.int32(pos))
            tokens = sample_token(sub, logits, self.temperature)
            self.stats["decode_steps"] += 1
            pos += 1
            arr = np.asarray(tokens)
            for i, r in enumerate(requests):
                if live[i]:
                    r.out_tokens.append(int(arr[i]))
                    if len(r.out_tokens) >= r.max_new_tokens or arr[i] == r.eos_id:
                        r.done = True
                        live[i] = False
        for r in requests:
            r.done = True
        self.stats["wall"] += time.perf_counter() - t0
        return requests

    def _grow_cache(self, cache: Any, filled: int) -> Any:
        """Pad prefill-length cache arrays out to ``self.capacity`` slots.

        Identifies the cache-sequence dim as the one equal to ``filled``
        in the reference (capacity-sized) cache template.
        """
        template = jax.eval_shape(lambda: self.api.init_cache(self.batch_size, self.capacity))

        def grow(leaf, ref):
            if leaf.shape == ref.shape:
                return leaf
            pads = []
            for have, want in zip(leaf.shape, ref.shape):
                assert want >= have, (leaf.shape, ref.shape)
                pads.append((0, want - have))
            return jnp.pad(leaf, pads)

        return jax.tree.map(grow, cache, template)


__all__ = ["ServeEngine", "Request", "sample_token"]
