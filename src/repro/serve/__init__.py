"""Serving substrate: static + continuous batching engines over KV caches,
plus the multi-tenant query-serving engine over the relational planner."""

from .engine import (
    ContinuousEngine,
    Request,
    ServeEngine,
    SlotAllocator,
    engine_record,
    generate_bucketed,
    make_mixed_workload,
    sample_token,
)
from .query_engine import (
    QueryRequest,
    QueryServeEngine,
    make_query_mix,
)

__all__ = [
    "ServeEngine",
    "ContinuousEngine",
    "SlotAllocator",
    "Request",
    "sample_token",
    "generate_bucketed",
    "make_mixed_workload",
    "engine_record",
    "QueryRequest",
    "QueryServeEngine",
    "make_query_mix",
]
