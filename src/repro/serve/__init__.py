"""Serving substrate: batched prefill/decode engine with KV caches."""

from .engine import ServeEngine, Request, sample_token

__all__ = ["ServeEngine", "Request", "sample_token"]
