"""Serving substrate: static + continuous batching engines over KV caches."""

from .engine import (
    ContinuousEngine,
    Request,
    ServeEngine,
    SlotAllocator,
    engine_record,
    generate_bucketed,
    make_mixed_workload,
    sample_token,
)

__all__ = [
    "ServeEngine",
    "ContinuousEngine",
    "SlotAllocator",
    "Request",
    "sample_token",
    "generate_bucketed",
    "make_mixed_workload",
    "engine_record",
]
