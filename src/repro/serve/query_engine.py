"""Multi-tenant query serving: continuous batching, applied to queries.

The paper's core claim is that the ENGINE must be redesigned for the
network, not the other way around — and a production engine faces a
*stream* of concurrent queries from many tenants, not one query at a time.
:class:`~repro.serve.engine.ContinuousEngine` proved the slot-map design
for token decode; this module is the same design one level up, with
queries as the unit of work and the shared mesh as the fixed resource:

* **admission queue + slot map** — a :class:`~repro.serve.engine.SlotAllocator`
  over ``num_slots`` mesh compute slots (same invariant: ``free + live ==
  num_slots`` at every round boundary).  Between rounds, arrived requests
  are admitted under **fair-share/LPT**: the least-served tenant goes
  first (round-robin in service units, so a flooding tenant cannot starve
  a light one), and within a tenant the largest job (LPT over the scanned
  capacity — the serving analogue of ``max_new_tokens``) fills the slot.
* **plan + compile cache** — every request resolves its plan through a
  :class:`~repro.relational.planner.plan_cache.PlanCache`
  (canonical-DAG-render + stats-bucket + mesh-shape key), so a repeated
  template skips ``plan_physical`` entirely and re-uses the memoized
  jitted executor: the hot path pays neither planning nor trace/compile.
* **one shared multiplexer** — concurrent plans' exchanges ride ONE
  multiplexer whose knobs are tuned over the union of every template's
  exchange shapes (:func:`repro.core.autotune.tune_shared_config`).  The
  knobs freeze at first use: retuning would invalidate every memoized
  executor, which is exactly the latency the cache exists to avoid — so
  pass ``templates=`` at construction to tune over the full expected mix.
* **per-request TTFR + per-tenant SLOs** — each request records wall time
  from arrival to fetched result (TTFR: queries return one result, so
  first-result latency IS the query latency) and how many scheduling
  rounds it queued; tenants accumulate SLO-violation counts against their
  declared ``slo_s``.

Execution inside one round is dispatch-then-finalize: every admitted
query's jitted program is launched before any result is fetched, so
compatible plans overlap on the XLA async runtime instead of serializing
on the host.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Mapping, Sequence

import numpy as np

from repro.core.autotune import tune_shared_config
from repro.core.multiplexer import make_multiplexer
from repro.core.topology import ChipSpec, V5E
from repro.obs.trace import QueryTrace, deposit, maybe_span
from repro.relational import stats as rstats
from repro.relational.context import ExecutionContext, StatsMode, require_context
from repro.relational.planner.executor import _mesh
from repro.relational.planner.physical import PhysicalPlan, plan_physical
from repro.relational.planner.plan_cache import PlanCache, PlanKey, plan_key
from repro.relational.planner.tpch import PlannedQuery
from repro.relational.table import Table

from .engine import SlotAllocator


@dataclasses.dataclass
class QueryRequest:
    """One query in the stream: who wants it, what template, when it lands."""

    tenant: str
    query: PlannedQuery
    arrival_round: int = 0         # scheduling-round tick of arrival
    slo_s: float | None = None     # per-request latency SLO (None: no SLO)
    # --- filled in by the engine -------------------------------------------
    admitted_round: int | None = None
    finished_round: int | None = None
    queue_rounds: int = 0          # rounds spent arrived-but-unadmitted
    ttfr_s: float | None = None    # wall from arrival to fetched result
    plan_cache_hit: bool | None = None
    executor_cache_hit: bool | None = None
    result: Any = None
    #: This run's device-side measurement (per-edge exchange bytes,
    #: histograms, model predictions).  Collected per-request from the
    #: runner — the runner itself is shared across concurrent requests, so
    #: the trace lives here, not on it.
    trace: QueryTrace | None = None
    _t_arrive: float | None = dataclasses.field(default=None, repr=False)


class QueryServeEngine:
    """Admit a stream of :class:`QueryRequest`\\ s onto one shared mesh.

    ``tables`` is the engine's resident data (the jitted executors close
    over it — one engine, one table set).  ``ctx`` is the engine-wide
    :class:`~repro.relational.context.ExecutionContext`: mesh shape,
    multiplexer knobs, and stats mode (``StatsMode.COLLECT`` profiles the
    tables once at construction so plans are skew-aware;
    ``StatsMode.PROFILE`` uses ``ctx.stats_profile``; STATIC keeps static
    plans).  ``ctx.trace`` attaches a tracer: every admission round and
    request becomes a span, and each request's :class:`QueryTrace` is
    deposited.  ``cache`` defaults to a fresh in-process
    :class:`PlanCache`; hand one a ``cache_dir`` (or set
    ``REPRO_PLAN_CACHE_DIR``) and plans persist across engine processes.
    """

    def __init__(
        self,
        tables: Mapping[str, Table],
        ctx: ExecutionContext | None = None,
        *,
        num_slots: int = 2,
        cache: PlanCache | None = None,
        chip: ChipSpec = V5E,
        topology: str = "ring",
        templates: Sequence[PlannedQuery] | None = None,
    ):
        if ctx is None:
            ctx = ExecutionContext()
        ctx = require_context(ctx, where="QueryServeEngine")
        self.ctx = ctx
        self.tables = dict(tables)
        self.num_shards = ctx.num_shards
        self.num_pods = ctx.num_pods
        self.alloc = SlotAllocator(num_slots)
        self.cache = cache if cache is not None else PlanCache()
        if ctx.stats_mode is StatsMode.COLLECT:
            self.stats = rstats.collect_stats(self.tables)
        elif ctx.stats_mode is StatsMode.PROFILE:
            self.stats = dict(ctx.stats_profile)
        else:
            self.stats = None
        self.chip = chip
        self.topology = topology
        self.rounds = 0
        self.served: list[QueryRequest] = []
        self.tenants: dict[str, dict] = {}
        self._service: dict[str, int] = {}  # fair-share counters
        self._plan_stats: dict[str, tuple] = {}  # digest -> shuffle_stats
        self._mux = None
        self._data_token = f"tables@{id(self):x}"
        for pq in templates or ():
            self._plan_for(pq)  # warm the plan cache + register exchange shapes

    # -- planning through the cache ----------------------------------------

    def _plan_for(self, pq: PlannedQuery) -> tuple[PhysicalPlan, PlanKey, bool]:
        catalog = {t: self.tables[t].capacity for t in pq.tables}
        stats = (
            {t: self.stats[t] for t in pq.tables if t in self.stats}
            if self.stats
            else None
        )
        key = plan_key(
            pq.logical, catalog, self.num_shards, num_pods=self.num_pods,
            chip=self.chip, topology=self.topology, stats=stats,
        )
        plan, hit = self.cache.get_plan(
            key,
            lambda: plan_physical(
                pq.logical, catalog, self.num_shards,
                num_pods=self.num_pods, chip=self.chip,
                topology=self.topology, name=pq.name, stats=stats,
            ),
        )
        self._plan_stats.setdefault(key.digest, tuple(plan.shuffle_stats))
        return plan, key, hit

    def _ensure_mux(self):
        """The one shared multiplexer, tuned over every registered plan's
        exchange shapes the first time an executor needs it."""
        if self._mux is None:
            tuned = tune_shared_config(
                self.num_shards,
                list(self._plan_stats.values()),
                num_pods=self.num_pods,
                chip=self.chip,
                topology=self.topology,
            )
            self.shared_tuned = tuned
            self._mux = make_multiplexer(
                _mesh(self.num_shards, self.num_pods),
                impl=tuned.impl,
                pack_impl=tuned.pack_impl,
                pipeline_chunks=tuned.pipeline_chunks,
                transport_chunks=tuned.transport_chunks,
            )
            if self.ctx.trace is not None:
                self.ctx.trace.add_span(
                    "mux:shared", cat="serve", **self._mux.describe()
                )
        return self._mux

    def _runner(self, req: QueryRequest):
        plan, key, plan_hit = self._plan_for(req.query)
        runner, exec_hit = self.cache.executor(
            key, plan, self.tables,
            data_token=self._data_token, mux=self._ensure_mux(),
            ctx=self.ctx,
        )
        req.plan_cache_hit = plan_hit
        req.executor_cache_hit = exec_hit
        return runner

    # -- scheduling ---------------------------------------------------------

    def _job_size(self, pq: PlannedQuery) -> int:
        """LPT job-size estimate: total capacity the query scans (known
        before planning, deterministic — the queries analogue of sorting
        decode admissions by ``max_new_tokens``)."""
        return sum(self.tables[t].capacity for t in pq.tables)

    def _pick(self, arrived: list[QueryRequest]) -> QueryRequest:
        """Fair-share across tenants, LPT within the chosen tenant.

        The least-served tenant (ties: name order) supplies the next job;
        among that tenant's arrived requests the largest scan wins (ties:
        arrival order, since ``max`` keeps the first maximum).
        """
        tenant = min(
            {r.tenant for r in arrived},
            key=lambda t: (self._service.get(t, 0), t),
        )
        mine = [r for r in arrived if r.tenant == tenant]
        return max(mine, key=lambda r: self._job_size(r.query))

    def serve(
        self, requests: Sequence[QueryRequest], max_rounds: int = 100_000
    ) -> list[QueryRequest]:
        """Run the stream to completion; returns requests in finish order.

        Queries complete within their round (the mesh is synchronous), so
        every round frees its slots: the scheduler can never deadlock, and
        the slot invariant is re-checked at each round boundary.
        """
        waiting = sorted(
            requests, key=lambda r: r.arrival_round
        )  # stable: preserves submission order within a tick
        done: list[QueryRequest] = []
        rnd = self.rounds
        while waiting:
            arrived = [r for r in waiting if r.arrival_round <= rnd]
            now = time.perf_counter()
            for r in arrived:
                if r._t_arrive is None:
                    r._t_arrive = now
            batch: list[tuple[int, QueryRequest]] = []
            while self.alloc.num_free and arrived:
                r = self._pick(arrived)
                arrived.remove(r)
                waiting.remove(r)
                slot = self.alloc.admit(r)
                r.admitted_round = rnd
                self._service[r.tenant] = self._service.get(r.tenant, 0) + 1
                batch.append((slot, r))
            for r in arrived:
                r.queue_rounds += 1
            # Concurrent execution: dispatch every admitted query before
            # collecting any — the jitted programs overlap on the async
            # runtime while the host is still launching the rest.  Results
            # and traces come back per-request from collect(): the runner
            # is shared (memoized) across the batch, so nothing per-run is
            # ever written onto it — that was the exchange_report race.
            tracer = self.ctx.trace
            with maybe_span(tracer, f"admission-round:{rnd}", "serve",
                            admitted=len(batch), queued=len(arrived)):
                launched = []
                for slot, r in batch:
                    runner = self._runner(r)
                    t0 = time.perf_counter()
                    launched.append((slot, r, runner, runner.dispatch(), t0))
                for slot, r, runner, out, t0 in launched:
                    with maybe_span(tracer, f"request:{r.query.name}",
                                    "serve", tenant=r.tenant):
                        raw, qt = runner.collect(out, t_dispatch=t0)
                    r.trace = qt
                    deposit(tracer, qt)
                    r.result = (
                        r.query.finalize(raw) if r.query.finalize else raw
                    )
                    r.ttfr_s = time.perf_counter() - r._t_arrive
                    r.finished_round = rnd
                    self.alloc.release(slot)
                    self._account(r)
                    done.append(r)
            self.alloc.check()
            rnd += 1
            if rnd - self.rounds > max_rounds:
                raise RuntimeError(
                    f"serve exceeded {max_rounds} rounds with "
                    f"{len(waiting)} requests still queued"
                )
        self.rounds = rnd
        self.served.extend(done)
        return done

    # -- accounting ---------------------------------------------------------

    def _account(self, r: QueryRequest) -> None:
        rec = self.tenants.setdefault(
            r.tenant,
            {"ttfr_s": [], "slo_violations": 0, "max_queue_rounds": 0},
        )
        rec["ttfr_s"].append(r.ttfr_s)
        rec["max_queue_rounds"] = max(rec["max_queue_rounds"], r.queue_rounds)
        if r.slo_s is not None and r.ttfr_s > r.slo_s:
            rec["slo_violations"] += 1

    def tenant_report(self) -> dict[str, dict]:
        """Per-tenant SLO accounting: served count, TTFR mean/p50/p99,
        violations, worst queueing."""
        out = {}
        for tenant in sorted(self.tenants):
            rec = self.tenants[tenant]
            tt = np.asarray(rec["ttfr_s"], dtype=np.float64)
            out[tenant] = dict(
                served=int(tt.size),
                ttfr_mean_s=float(tt.mean()),
                ttfr_p50_s=float(np.percentile(tt, 50)),
                ttfr_p99_s=float(np.percentile(tt, 99)),
                slo_violations=int(rec["slo_violations"]),
                max_queue_rounds=int(rec["max_queue_rounds"]),
            )
        return out

    def record(self) -> dict:
        """Engine-level record (benchmarks serialize this)."""
        tt = np.asarray(
            [r.ttfr_s for r in self.served if r.ttfr_s is not None],
            dtype=np.float64,
        )
        out = dict(
            served=len(self.served),
            rounds=self.rounds,
            num_slots=self.alloc.num_slots,
            cache=self.cache.record(),
            tenants=self.tenant_report(),
        )
        if tt.size:
            out.update(
                ttfr_p50_s=float(np.percentile(tt, 50)),
                ttfr_p99_s=float(np.percentile(tt, 99)),
            )
        return out


def make_query_mix(
    templates: Sequence[PlannedQuery],
    tenants: Sequence[str],
    num_requests: int,
    seed: int = 0,
    max_arrival_round: int = 4,
    slo_s: float | None = None,
) -> list[QueryRequest]:
    """Seeded multi-tenant TPC-H-mix workload (tests and benches share it):
    uniform draws over templates/tenants, arrivals over the first
    ``max_arrival_round + 1`` rounds."""
    rng = np.random.default_rng(seed)
    return [
        QueryRequest(
            tenant=str(rng.choice(list(tenants))),
            query=templates[int(rng.integers(len(templates)))],
            arrival_round=int(rng.integers(max_arrival_round + 1)),
            slo_s=slo_s,
        )
        for _ in range(num_requests)
    ]


__all__ = [
    "QueryRequest",
    "QueryServeEngine",
    "make_query_mix",
]
