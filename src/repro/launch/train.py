"""End-to-end training driver (example application + fault-tolerance demo).

Runs a real training loop on the current host (CPU smoke scale or the full
mesh): deterministic data pipeline with background prefetch, microbatched
AdamW train step, periodic crash-consistent checkpoints, and automatic
resume from the newest checkpoint — kill it at any step and rerun the same
command to continue (the deterministic pipeline regenerates exactly the
batches that would have followed; see checkpoint/ckpt.py).

  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --smoke \
      --steps 100 --ckpt-dir /tmp/ckpt --ckpt-every 20
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.data import Prefetcher, make_batch_iterator
from repro.models import registry as R
from repro.train import AdamWConfig, make_train_step
from repro.train.step import TrainState


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--warmup", type=int, default=20)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = cfg.scaled(num_microbatches=args.microbatches)
    api = R.build(cfg)
    shape = ShapeSpec("cli", args.seq_len, args.batch, "train")

    opt = AdamWConfig(
        lr=args.lr, warmup_steps=args.warmup, total_steps=args.steps,
        schedule=cfg.lr_schedule,
    )
    step_fn = jax.jit(make_train_step(api, opt))

    state = TrainState.create(api, jax.random.PRNGKey(args.seed))
    start = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every or 0)
        restored = mgr.restore_latest(jax.eval_shape(lambda: state))
        if restored is not None:
            start, state = restored
            print(f"resumed from checkpoint at step {start}")

    it = Prefetcher(
        make_batch_iterator(cfg, shape, seed=args.seed, start_step=start), depth=2
    )
    t0 = time.perf_counter()
    tokens_done = 0
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, batch)
        tokens_done += args.batch * args.seq_len
        if mgr and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            mgr.maybe_save(step + 1, state)
        if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
            dt = time.perf_counter() - t0
            print(
                f"step {step + 1:5d}  loss {float(metrics['loss']):.4f}  "
                f"lr {float(metrics['lr']):.2e}  gnorm {float(metrics['grad_norm']):.3f}  "
                f"tok/s {tokens_done / dt:,.0f}"
            )
    print("done")
    return state


if __name__ == "__main__":
    main()
