"""Roofline term extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), in seconds (EXPERIMENTS.md §Roofline):

* compute    = HLO_FLOPs / peak_FLOP/s          (per-chip program)
* memory     = HLO_bytes / HBM_bw
* collective = Σ per-op bytes / link_bw, split by network level:
  in-pod collectives ride ICI (~50 GB/s/link), cross-pod ride DCI.

``cost_analysis()`` supplies FLOPs/bytes of the per-device partitioned
program.  Collective bytes are NOT in cost_analysis: we parse the optimized
post-SPMD HLO (``compiled.as_text()``) and sum the result-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction, tagging each with its replica-group axis to
decide which network it crosses.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

from repro.core.topology import V5E

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|((?:[a-z0-9]+)\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_split(hlo_text: str) -> tuple[dict[str, int], dict[str, int]]:
    """(total, async) per-op-kind result bytes of the per-device HLO.

    ``-start``/``-done`` async pairs are counted once (on the start) and
    additionally tallied in the *async* dict: those are the collectives the
    latency-hiding scheduler may overlap with compute, which is what the
    overlap-fraction audit measures.  Plain (synchronous) collectives only
    appear in the total.
    """
    total: dict[str, int] = {}
    async_: dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async completion: counted at -start
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shape_txt = m.group(1) or m.group(2)
        kind = m.group(3)
        b = _shape_bytes(shape_txt)
        total[kind] = total.get(kind, 0) + b
        if m.group(4) == "-start":
            async_[kind] = async_.get(kind, 0) + b
    return total, async_


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-op-kind result bytes of every collective in the per-device HLO.

    ``-start``/``-done`` async pairs are counted once (on the start).
    """
    return collective_bytes_split(hlo_text)[0]


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: dict[str, int]
    model_flops_global: float  # 6*N*D (or 6*N_active*D)
    chips: int
    ideal_bytes_global: float = 0.0  # mandatory HBM traffic of a perfect impl
    # Subset of coll_bytes_per_chip issued as async -start/-done pairs (the
    # collectives the latency-hiding scheduler is free to overlap).
    async_coll_bytes_per_chip: dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / V5E.peak_flops_bf16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_chip / V5E.hbm_bandwidth

    @property
    def collective_s(self) -> float:
        total = sum(self.coll_bytes_per_chip.values())
        return total / V5E.ici_link_bandwidth

    @property
    def async_collective_s(self) -> float:
        total = sum(self.async_coll_bytes_per_chip.values())
        return total / V5E.ici_link_bandwidth

    @property
    def overlap_fraction(self) -> float:
        """Fraction of collective time hideable behind compute.

        Only async (-start/-done) collectives can overlap; of those, at
        most ``compute_s`` worth can actually hide.  0 when the program
        has no collectives at all.
        """
        if self.collective_s <= 0.0:
            return 0.0
        hidden = min(self.compute_s, self.async_collective_s)
        return hidden / self.collective_s

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        hlo_global = self.flops_per_chip * self.chips
        return self.model_flops_global / hlo_global if hlo_global else float("nan")

    @property
    def ideal_s(self) -> float:
        """Time a perfect implementation needs on this hardware.

        max(useful-FLOPs / peak, mandatory-HBM-bytes / bw): training at 4k
        is compute-ideal; decode is bandwidth-ideal (must read the weights
        and the KV cache once per token no matter what).
        """
        ideal_c = self.model_flops_global / self.chips / V5E.peak_flops_bf16
        ideal_m = self.ideal_bytes_global / self.chips / V5E.hbm_bandwidth
        return max(ideal_c, ideal_m)

    @property
    def roofline_fraction(self) -> float:
        """ideal time / modeled bound time (the score axis)."""
        return self.ideal_s / self.bound_s if self.bound_s else float("nan")

    def row(self) -> dict[str, Any]:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops_global,
            "ideal_s": self.ideal_s,
            "hlo_flops_per_chip": self.flops_per_chip,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
            "collective_breakdown": self.coll_bytes_per_chip,
            "async_collective_s": self.async_collective_s,
            "overlap_fraction": self.overlap_fraction,
        }


def model_flops(cfg, shape, n_params_active: int) -> float:
    """MODEL_FLOPS: 6*N*D for train, 2*N*D for prefill, 2*N*B for decode
    (D = tokens processed by the step; MoE uses N_active)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_params_active * B * S
    if shape.kind == "prefill":
        return 2.0 * n_params_active * B * S
    # decode: one token per stream
    return 2.0 * n_params_active * B


def _cache_bytes(cfg, shape) -> float:
    """KV/state cache footprint (bf16 kv, f32 ssm states) for decode cells."""
    B, S = shape.global_batch, shape.seq_len
    bytes_ = 0.0
    if cfg.family in ("ssm", "hybrid"):
        d_inner = cfg.ssm_expand * cfg.d_model
        H = d_inner // cfg.ssm_head_dim
        n_mamba = cfg.num_layers
        bytes_ += n_mamba * B * H * cfg.ssm_head_dim * cfg.ssm_state * 4
        if cfg.family == "hybrid" and cfg.attn_every:
            n_attn = cfg.num_layers // cfg.attn_every
            bytes_ += n_attn * B * S * 2 * cfg.num_kv_heads * cfg.resolved_head_dim * 2
        return bytes_
    if cfg.attn_kind == "mla":
        per_tok = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        return cfg.num_layers * B * S * per_tok * 2
    layers = cfg.num_layers * (2 if cfg.is_encoder_decoder else 1)
    return layers * B * S * 2 * cfg.num_kv_heads * cfg.resolved_head_dim * 2


def ideal_memory_bytes(cfg, shape, n_active: int, n_total: int, microbatches: int = 1) -> float:
    """Mandatory HBM traffic of a perfect implementation (global, bytes).

    train:   each microbatch makes fwd + bwd passes -> ~3 reads of the bf16
             params per microbatch (all experts are touched by a big batch),
             + one optimizer pass over f32 master/moments/grads (~20 B/param).
    prefill: one bf16 read of all params + one write of the cache.
    decode:  bf16 read of the params actually activated by the B streams
             (capped at all params) + one read of the cache.
    """
    if shape.kind == "train":
        return microbatches * 3.0 * 2.0 * n_total + 20.0 * n_total
    if shape.kind == "prefill":
        return 2.0 * n_total + _cache_bytes(cfg, shape)
    B = shape.global_batch
    return 2.0 * min(n_total, B * n_active) + _cache_bytes(cfg, shape)


def from_artifact(art: dict) -> RooflineTerms:
    return RooflineTerms(
        arch=art["arch"],
        shape=art["shape"],
        mesh=art["mesh"],
        flops_per_chip=art["cost_analysis"].get("flops", 0.0),
        bytes_per_chip=art["cost_analysis"].get("bytes accessed", 0.0),
        coll_bytes_per_chip=art["collective_bytes"],
        model_flops_global=art["model_flops"],
        chips=art["chips"],
        ideal_bytes_global=art.get("ideal_bytes", 0.0),
        async_coll_bytes_per_chip=art.get("async_collective_bytes", {}),
    )


def format_table(rows: list[RooflineTerms]) -> str:
    hdr = (
        f"{'arch':22s} {'shape':12s} {'mesh':6s} "
        f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
        f"{'bound':>10s} {'useful%':>8s} {'roofline%':>9s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:22s} {r.shape:12s} {r.mesh:6s} "
            f"{r.compute_s:10.4g} {r.memory_s:10.4g} {r.collective_s:10.4g} "
            f"{r.dominant:>10s} {100*r.useful_flops_fraction:8.1f} "
            f"{100*r.roofline_fraction:9.1f}"
        )
    return "\n".join(lines)


__all__ = [
    "collective_bytes",
    "collective_bytes_split",
    "RooflineTerms",
    "model_flops",
    "from_artifact",
    "format_table",
]
