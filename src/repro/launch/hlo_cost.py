"""While-loop-aware cost accounting over compiled (post-SPMD) HLO text.

``Compiled.cost_analysis()`` counts a ``while`` body ONCE, so any scanned
program (scan-over-layers, microbatch accumulation, chunked attention, SSD
chunk scan) is under-reported by its trip count.  This module re-derives the
per-device roofline inputs directly from ``compiled.as_text()``:

* **flops** — 2 · |result| · |contracted dims| for every ``dot``; recursed
  through ``fusion``/``call``/``while`` (multiplied by the
  ``known_trip_count`` XLA annotates on each while's backend_config) and
  ``conditional`` (max over branches).
* **bytes** — HBM-traffic proxy: Σ over *materialized* instructions of
  operand + result bytes (parameters/constants/GTE/tuple/bitcast excluded;
  fusion internals excluded — post-fusion HLO edges ≈ buffers).
* **collective_bytes** — result bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (async -start counted,
  -done skipped), trip-multiplied like everything else.

Validated against exact unrolled programs in tests/test_hlo_cost.py.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(r"([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^{]*)?\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[\\\"\':{ ]+n[\\\"\': ]+(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")

_SKIP_MEM_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    # elementwise/shape ops a TPU compiler fuses into neighbours
    "broadcast", "reshape", "convert", "add", "subtract", "multiply",
    "divide", "maximum", "minimum", "exponential", "tanh", "negate",
    "select", "compare", "and", "or", "not", "rsqrt", "sqrt", "abs",
}
_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}


def _shape_dims(shape_txt: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_txt):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shape_txt: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(shape_txt):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class _Instr:
    name: str
    shape_txt: str
    op: str
    operands_txt: str  # text inside the opcode's parens
    rest: str          # attribute tail after the closing paren


def _matching_paren(s: str, start: int = 0) -> int:
    """Index of the ')' matching the '(' at ``start``; -1 if unbalanced."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return -1


def _split_instr(line: str) -> _Instr | None:
    line = line.strip()
    if line.startswith("ROOT "):
        line = line[5:]
    if not line.startswith("%"):
        return None
    name, sep, rhs = line.partition(" = ")
    if not sep:
        return None
    rhs = rhs.strip()
    if rhs.startswith("("):  # tuple-shaped result
        end = _matching_paren(rhs)
        if end < 0:
            return None
        shape, rest = rhs[: end + 1], rhs[end + 1 :].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        shape, rest = rhs[:sp], rhs[sp + 1 :].strip()
    m = _OP_RE.match(rest)
    if not m:
        return None
    op = m.group(1)
    open_idx = m.end() - 1
    close_idx = _matching_paren(rest, open_idx)
    if close_idx < 0:
        operands, tail = rest[m.end():], ""
    else:
        operands, tail = rest[m.end(): close_idx], rest[close_idx + 1 :]
    return _Instr(name.strip().lstrip("%"), shape, op, operands, tail)


def _parse_computations(text: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    cur: list[_Instr] | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and ("->" in line or line.startswith("ENTRY")):
                comps[m.group(1)] = cur = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        instr = _split_instr(line)
        if instr is not None:
            cur.append(instr)
    return comps


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict[str, float] = dataclasses.field(default_factory=dict)
    # subset of ``coll`` issued async (-start/-done pairs or async-start
    # wrappers): the collectives the scheduler may overlap with compute
    coll_async: dict[str, float] = dataclasses.field(default_factory=dict)
    unknown_trip_whiles: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult
        for k, v in other.coll_async.items():
            self.coll_async[k] = self.coll_async.get(k, 0.0) + v * mult
        self.unknown_trip_whiles += other.unknown_trip_whiles


def _dot_flops(instr: _Instr, shapes: dict[str, str]) -> float:
    res = _shape_dims(instr.shape_txt)
    if not res:
        return 0.0
    _, rdims = res[0]
    out_elems = 1
    for d in rdims:
        out_elems *= d
    mc = _CONTRACT_RE.search(instr.rest)
    contract = 1
    if mc:
        ops = _OPERANDS_RE.findall(instr.operands_txt)
        if ops:
            lhs_shape = shapes.get(ops[0], "")
            dims = _shape_dims(lhs_shape)
            if dims:
                _, ldims = dims[0]
                for idx in (int(i) for i in mc.group(1).split(",") if i):
                    if idx < len(ldims):
                        contract *= ldims[idx]
    return 2.0 * out_elems * contract


def _analyze_comp(
    name: str,
    comps: dict[str, list[_Instr]],
    memo: dict[str, Cost],
    in_fusion: bool = False,
) -> Cost:
    if name in memo:
        return memo[name]
    memo[name] = Cost()  # cycle guard
    total = Cost()
    shapes = {i.name: i.shape_txt for i in comps.get(name, [])}
    for instr in comps.get(name, []):
        op = instr.op
        if op == "dot":
            total.flops += _dot_flops(instr, shapes)
        if op in _COLLECTIVES or any(
            op == c + "-start" for c in _COLLECTIVES
        ):
            kind = op.removesuffix("-start")
            b = _shape_bytes(instr.shape_txt)
            total.coll[kind] = total.coll.get(kind, 0.0) + b
            if op.endswith("-start"):
                total.coll_async[kind] = total.coll_async.get(kind, 0.0) + b
        if op == "while":
            m = _WHILE_RE.search(instr.rest)
            trip = None
            mt = _TRIP_RE.search(instr.rest)
            if mt:
                trip = int(mt.group(1))
            if m:
                body = _analyze_comp(m.group(2), comps, memo)
                if trip is None:
                    total.unknown_trip_whiles += 1
                    trip = 1
                total.add(body, trip)
            continue
        if op in ("fusion", "call", "async-start"):
            mc = _CALLS_RE.search(instr.rest)
            if mc:
                inner = _analyze_comp(mc.group(1), comps, memo, in_fusion=(op == "fusion"))
                # fusion internals: count flops/collectives, not bytes
                total.flops += inner.flops
                for k, v in inner.coll.items():
                    total.coll[k] = total.coll.get(k, 0.0) + v
                    if op == "async-start":
                        # async wrapper: everything inside runs off-thread
                        total.coll_async[k] = total.coll_async.get(k, 0.0) + v
                for k, v in inner.coll_async.items():
                    if op != "async-start":  # already counted above
                        total.coll_async[k] = total.coll_async.get(k, 0.0) + v
                total.unknown_trip_whiles += inner.unknown_trip_whiles
        if op == "conditional":
            mb = _BRANCHES_RE.search(instr.rest)
            if mb:
                branches = _OPERANDS_RE.findall(mb.group(1))
                if branches:
                    best = max(
                        (_analyze_comp(b, comps, memo) for b in branches),
                        key=lambda c: c.flops,
                    )
                    total.add(best)
        # memory proxy: fusions count their *result* only (a TPU compiler
        # reads fused-producer inputs from the ops that made them — those are
        # charged where produced); dots/reduces/etc. count operands + result.
        # In-place-able ops are charged at their *touched* size, not the full
        # buffer (XLA aliases DUS/copy inside while bodies):
        #   dynamic-update-slice: write the update slice only;
        #   dynamic-slice/gather:  read+write the slice only;
        #   copy:                  one write (read charged at the producer).
        if not in_fusion and op not in _SKIP_MEM_OPS and "-done" not in op:
            if op == "dynamic-update-slice":
                ops_ = _OPERANDS_RE.findall(instr.operands_txt)
                if len(ops_) >= 2 and ops_[1] in shapes:
                    total.bytes += 2 * _shape_bytes(shapes[ops_[1]])
            elif op in ("dynamic-slice", "gather", "copy"):
                mult = 1 if op == "copy" else 2
                total.bytes += mult * _shape_bytes(instr.shape_txt)
            elif op == "fusion" and "dynamic-update-slice" in instr.name:
                # fused in-place update: the big buffer operand is aliased;
                # charge everything but the largest operand (the buffer)
                sizes = [
                    _shape_bytes(shapes[o])
                    for o in _OPERANDS_RE.findall(instr.operands_txt)
                    if o in shapes
                ]
                if sizes:
                    total.bytes += 2 * (sum(sizes) - max(sizes))
            else:
                total.bytes += _shape_bytes(instr.shape_txt)
                if op != "fusion":
                    for operand in _OPERANDS_RE.findall(instr.operands_txt):
                        if operand in shapes:
                            total.bytes += _shape_bytes(shapes[operand])
    memo[name] = total
    return total


def analyze(hlo_text: str) -> dict:
    """Trip-count-corrected per-device cost of an optimized HLO module."""
    comps = _parse_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        raise ValueError("no ENTRY computation found")
    # computations reachable only via fusion/call/while from entry are
    # handled by recursion; memo shared across the walk
    memo: dict[str, Cost] = {}
    c = _analyze_comp(entry, comps, memo)
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes": {k: int(v) for k, v in c.coll.items()},
        "async_collective_bytes": {k: int(v) for k, v in c.coll_async.items()},
        "unknown_trip_whiles": c.unknown_trip_whiles,
    }


__all__ = ["analyze", "Cost"]
