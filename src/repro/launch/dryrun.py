"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set the fake-device count before any other import touches jax — the
device count is locked at first backend init.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, shapes_for
from repro.configs.base import ModelConfig, ShapeSpec, SHAPES
from repro.distributed.sharding import MeshContext, build_shardings, mesh_context
from repro.launch import roofline as RL
from repro.launch.mesh import make_context
from repro.models import registry as R
from repro.train import AdamWConfig
from repro.train.step import TrainState, make_train_step, state_shardings

# Per-arch microbatch counts for train_4k: chosen so one microbatch of
# activations (seq 4096, remat=block) fits 16 GB HBM next to params+opt.
MICROBATCHES = {
    "deepseek-67b": 16,
    "qwen1.5-32b": 16,
    "zamba2-7b": 8,
    "minicpm-2b": 4,
    "qwen2.5-3b": 4,
    "deepseek-v2-lite-16b": 4,
    "olmoe-1b-7b": 4,
    "mamba2-1.3b": 4,
    "whisper-medium": 4,
    "qwen2-vl-2b": 4,
}


def dryrun_config(
    arch: str, shape: ShapeSpec, overrides: dict | None = None, multi_pod: bool = False
) -> ModelConfig:
    """The execution policy used on the production mesh (not the smoke one)."""
    cfg = get_config(arch)
    over: dict = dict(dtype="bfloat16", remat="block", scan_layers=True)
    if shape.kind == "train":
        # each microbatch must still cover every data-parallel lane
        lanes = 32 if multi_pod else 16
        over["num_microbatches"] = min(
            MICROBATCHES.get(arch, 4), shape.global_batch // lanes
        )
    if cfg.num_experts:
        # EP exchange for bulk shapes; replicate-and-reduce at decode
        over["moe_impl"] = "ep_shardmap" if shape.kind != "decode" else "gspmd"
    if overrides:
        over.update(overrides)
    return cfg.scaled(**over)


def build_cell(api: R.ModelApi, shape: ShapeSpec, ctx):
    """(fn, example_args, in_shardings) for one (arch × shape) cell."""
    cfg = api.cfg
    batch_sds, batch_axes = R.input_specs(cfg, shape)
    batch_sh = build_shardings(batch_axes, batch_sds, ctx)

    if shape.kind == "train":
        step = make_train_step(api, AdamWConfig(schedule=cfg.lr_schedule))
        state_sds = jax.eval_shape(lambda k: TrainState.create(api, k), jax.random.PRNGKey(0))
        state_sh = state_shardings(api, ctx)
        return step, (state_sds, batch_sds), (state_sh, batch_sh)

    param_sds, param_axes = R.param_shape_specs(cfg)
    param_sh = build_shardings(param_axes, param_sds, ctx)

    if shape.kind == "prefill":
        return api.prefill, (param_sds, batch_sds), (param_sh, batch_sh)

    # decode
    cache_sds, cache_axes = R.cache_shape_specs(cfg, shape)
    cache_sh = build_shardings(cache_axes, cache_sds, ctx)
    tok_sds = batch_sds["tokens"]
    tok_sh = batch_sh["tokens"]
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(ctx.mesh, P())
    fn = lambda params, tokens, cache, pos: api.decode_step(params, tokens, cache, pos)
    return (
        fn,
        (param_sds, tok_sds, cache_sds, pos_sds),
        (param_sh, tok_sh, cache_sh, pos_sh),
    )


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    out_dir: str | None = None,
    overrides: dict | None = None,
    verbose: bool = True,
) -> dict:
    shape = SHAPES[shape_name]
    overrides = dict(overrides or {})
    tag = overrides.pop("tag", "")
    cfg = dryrun_config(arch, shape, overrides, multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256

    if shape.name == "long_500k" and not cfg.supports_long_context:
        art = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "skipped",
            "reason": "pure full-attention arch; sub-quadratic required (DESIGN.md)",
        }
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            with open(os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json"), "w") as f:
                json.dump(art, f, indent=1)
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] SKIPPED: {art['reason']}")
        return art

    exchange_axis = "data" if cfg.exchange_over_data else "model"
    # dryrun models the paper's fixed 256-chip pod on 512 fake devices in
    # one process, so the mesh is pinned here — (2,16,16) / (16,16), the
    # shapes the artifact labels above promise — rather than derived from
    # the host topology (a real multi-host launch uses jax.process_count()
    # via make_production_mesh instead).
    from repro.compat import make_mesh as _compat_make_mesh

    mesh = _compat_make_mesh(
        (2, 16, 16) if multi_pod else (16, 16),
        ("pod", "data", "model") if multi_pod else ("data", "model"),
    )
    ctx = make_context(mesh=mesh, exchange_impl=cfg.exchange_impl)
    rules = ctx.rules
    if cfg.exchange_over_data:
        # the paper's topology: shuffle between coarse (data) units, keep
        # fine-grained TP on the fast model axis inside each unit
        rules = rules.replace(experts="data", expert_fsdp="model")
    if cfg.uneven_shards:
        rules = rules.replace(allow_uneven=True)
    if cfg.sequence_parallel:
        rules = rules.replace(seq_sp="model")
    if cfg.dp_only:
        # ZeRO-3: every chip is a data lane.  Only the batch mapping changes;
        # per-spec mesh-axis de-duplication (sharding.logical_sharding) drops
        # the heads/d_ff constraints from activations automatically while
        # parameter specs keep their 256-way (fsdp x model) storage sharding.
        batch = ("pod", "data", "model") if multi_pod else ("data", "model")
        rules = rules.replace(batch=batch)
    if rules is not ctx.rules or exchange_axis != ctx.exchange_axis:
        ctx = MeshContext(
            mesh=ctx.mesh, rules=rules,
            exchange_axis=exchange_axis, data_axes=ctx.data_axes,
            pod_axis=ctx.pod_axis, exchange_impl=ctx.exchange_impl,
        )
    api = R.build(cfg)
    art: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
                 "overrides": overrides, "tag": tag}
    with mesh_context(ctx):
        fn, args, in_sh = build_cell(api, shape, ctx)
        t0 = time.perf_counter()
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()

        try:
            mem = compiled.memory_analysis()
            art["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(mem, k)
            }
        except Exception as e:  # CPU backend may not implement it
            art["memory_analysis"] = {"error": str(e)}

        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        art["xla_cost_analysis"] = {
            k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and k in ("flops", "bytes accessed", "transcendentals")
        }
        hlo = compiled.as_text()
        # trip-count-corrected per-device cost (XLA's counts while bodies once)
        from repro.launch import hlo_cost

        corrected = hlo_cost.analyze(hlo)
        art["cost_analysis"] = {
            "flops": corrected["flops"],
            "bytes accessed": corrected["bytes"],
        }
        art["unknown_trip_whiles"] = corrected["unknown_trip_whiles"]
        art["collective_bytes"] = corrected["collective_bytes"]
        art["async_collective_bytes"] = corrected["async_collective_bytes"]
        art["hlo_bytes"] = len(hlo)
        art["lower_s"] = t1 - t0
        art["compile_s"] = t2 - t1

    n_active = R.param_count(cfg, active_only=True)
    n_total = R.param_count(cfg)
    art["params"] = n_total
    art["active_params"] = n_active
    art["model_flops"] = RL.model_flops(cfg, shape, n_active)
    art["ideal_bytes"] = RL.ideal_memory_bytes(
        cfg, shape, n_active, n_total, cfg.num_microbatches
    )
    art["status"] = "ok"

    terms = RL.from_artifact(art)
    art["roofline"] = terms.row()
    if verbose:
        print(
            f"[{arch} × {shape_name} × {mesh_name}] compile={art['compile_s']:.1f}s "
            f"flops/chip={art['cost_analysis'].get('flops', 0):.3g} "
            f"dominant={terms.dominant} roofline={100*terms.roofline_fraction:.1f}%"
        )
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn_out = f"{arch}__{shape_name}__{mesh_name}{('__' + tag) if tag else ''}.json"
        with open(os.path.join(out_dir, fn_out), "w") as f:
            json.dump(art, f, indent=1, default=str)
    return art


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="all", help="arch id or 'all'")
    p.add_argument("--shape", default="all", help="shape name or 'all'")
    p.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    p.add_argument("--out", default="artifacts/dryrun")
    p.add_argument("--set", action="append", default=[],
                   help="cfg override key=value (e.g. exchange_impl=xla)")
    args = p.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [s.name for s in shapes_for(cfg)] + (
            ["long_500k"] if not cfg.supports_long_context else []
        )
        if args.shape != "all":
            shapes = [args.shape]
        for shape_name in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape_name, mp, args.out, overrides or None)
                except Exception:
                    failures.append((arch, shape_name, mp))
                    print(f"FAILED: {arch} × {shape_name} × multi_pod={mp}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {failures}")
    print("all requested dry-run cells passed")


if __name__ == "__main__":
    main()
