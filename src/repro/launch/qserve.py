"""Multi-tenant query-serving driver over the TPC-H mix.

  PYTHONPATH=src python -m repro.launch.qserve --sf 0.01 --slots 4 \
      --requests 16 --tenants 3

Builds the tables, prewarms the plan cache from the template mix, serves a
seeded multi-tenant stream, and prints per-tenant TTFR/SLO accounting plus
the cache counters.  With ``--cache-dir`` the plan artifacts persist: run
the same command twice and the second process reports ``plan_disk_hits``
and zero ``plan_physical`` calls for the prewarmed templates — the
cross-process half of the plan cache, demonstrated end to end.
"""

from __future__ import annotations

import argparse
import json
import time

from repro.obs.trace import Tracer
from repro.relational import datagen
from repro.relational.context import ExecutionContext, StatsMode
from repro.relational.planner import tpch
from repro.relational.planner.physical import plan_physical
from repro.relational.planner.plan_cache import PlanCache
from repro.serve import QueryServeEngine, make_query_mix

DEFAULT_MIX = ("q1", "q3", "q6", "q14", "q17")


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--sf", type=float, default=0.01)
    p.add_argument("--num-shards", type=int, default=1)
    p.add_argument("--num-pods", type=int, default=1)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--tenants", type=int, default=3)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--mix", default=",".join(DEFAULT_MIX),
                   help="comma-separated TPC-H template names")
    p.add_argument("--slo-ms", type=float, default=None,
                   help="per-request TTFR SLO (milliseconds)")
    p.add_argument("--cache-dir", default=None,
                   help="persist plan artifacts here (cross-process cache)")
    p.add_argument("--stats", action="store_true",
                   help="profile tables so plans are skew-aware")
    p.add_argument("--trace-dir", default=None,
                   help="write a Perfetto-loadable trace JSON per process")
    args = p.parse_args()

    tabs = datagen.gen_all(args.sf)
    templates = [tpch.ALL_QUERIES[name]() for name in args.mix.split(",")]
    names = sorted({t for pq in templates for t in pq.tables})
    tables = {name: tabs[name] for name in names}

    tracer = Tracer() if args.trace_dir else None
    calls_before = plan_physical.calls
    engine = QueryServeEngine(
        tables,
        ExecutionContext(
            num_shards=args.num_shards,
            num_pods=args.num_pods,
            stats_mode=StatsMode.COLLECT if args.stats else StatsMode.STATIC,
            trace=tracer,
        ),
        num_slots=args.slots,
        cache=PlanCache(cache_dir=args.cache_dir),
        templates=templates,
    )
    reqs = make_query_mix(
        templates,
        [f"tenant{i}" for i in range(args.tenants)],
        args.requests,
        seed=args.seed,
        slo_s=args.slo_ms / 1e3 if args.slo_ms is not None else None,
    )
    t0 = time.perf_counter()
    engine.serve(reqs)
    elapsed = time.perf_counter() - t0

    rec = engine.record()
    rec["qps"] = args.requests / elapsed
    rec["plan_physical_calls"] = plan_physical.calls - calls_before
    if tracer is not None:
        from repro.obs.export import write_trace_dir

        rec["trace_path"] = write_trace_dir(
            tracer, args.trace_dir, basename="qserve"
        )
    print(json.dumps(rec, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
