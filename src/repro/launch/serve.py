"""End-to-end serving driver: batched prefill + lock-step decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --requests 8 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import registry as R
from repro.models.registry import VLM_PATCHES
from repro.serve import Request, ServeEngine


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    api = R.build(cfg)
    params = api.init(jax.random.PRNGKey(args.seed))
    capacity = args.prompt_len + args.max_new + 1
    engine = ServeEngine(api, batch_size=args.batch, capacity=capacity,
                         temperature=args.temperature, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len, dtype=np.int32),
            max_new_tokens=args.max_new,
        )
        for _ in range(args.requests)
    ]
    extra = None
    if cfg.family == "encdec":
        extra = {"frames": rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32)}
    elif cfg.family == "vlm":
        P = min(VLM_PATCHES, args.prompt_len // 2)
        extra = {"patches": rng.standard_normal(
            (args.batch, P, cfg.d_model)).astype(np.float32)}

    t0 = time.perf_counter()
    done = 0
    for i in range(0, len(reqs), args.batch):
        batch = reqs[i : i + args.batch]
        engine.generate(params, batch, extra_inputs=extra)
        done += len(batch)
        print(f"batch {i // args.batch}: "
              + "; ".join(str(r.out_tokens[:8]) for r in batch))
    wall = time.perf_counter() - t0
    total_new = sum(len(r.out_tokens) for r in reqs)
    print(
        f"{done} requests, {total_new} tokens in {wall:.2f}s "
        f"({total_new / wall:.1f} tok/s); engine stats: {engine.stats}"
    )


if __name__ == "__main__":
    main()
