"""End-to-end serving driver: static batching or continuous batching.

Static (the classic fixed-batch baseline):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --requests 8 --prompt-len 32 --max-new 16

Continuous (slot map + admission between decode steps) on a MIXED-length
workload, with the static engine run on the same workload for comparison —
the ``slot_steps`` line is the paper's load-imbalance argument in serving
currency (decode steps x batch slots):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --continuous --requests 16 --arrival-rate 2
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import registry as R
from repro.models.registry import VLM_PATCHES
from repro.serve import (
    ContinuousEngine,
    Request,
    ServeEngine,
    engine_record,
    generate_bucketed,
    make_mixed_workload,
)


def _extra_inputs(cfg, args, rng):
    if cfg.family == "encdec":
        return {"frames": rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32)}
    if cfg.family == "vlm":
        P = min(VLM_PATCHES, args.prompt_len // 2)
        return {"patches": rng.standard_normal(
            (args.batch, P, cfg.d_model)).astype(np.float32)}
    return None


def _prompt_lens(cfg, args) -> list[int]:
    """Two prefill buckets, except families with fixed-shape side inputs
    (enc-dec frames, VLM patches) which keep one prompt length — their
    imbalance then comes from the output lengths alone."""
    if cfg.family in ("encdec", "vlm"):
        return [args.prompt_len]
    return [max(args.prompt_len // 2, 4), args.prompt_len]


def _summarize(tag: str, reqs: list[Request], stats: dict, wall: float) -> dict:
    rec = engine_record(reqs, stats, wall)
    line = (f"{tag}: {rec['requests']} requests, {rec['new_tokens']} tokens "
            f"in {rec['wall_s']:.2f}s ({rec['tok_s']} tok/s), "
            f"decode_steps={rec['decode_steps']} slot_steps={rec['slot_steps']}")
    if "ttft_mean_s" in rec:
        line += (f", ttft mean={rec['ttft_mean_s']*1e3:.0f}ms "
                 f"p99={rec['ttft_p99_s']*1e3:.0f}ms")
    print(line)
    return rec


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--continuous", action="store_true",
                   help="continuous batching on a mixed-length workload, "
                        "with a static-batching comparison run")
    p.add_argument("--arrival-rate", type=float, default=0.0,
                   help="requests per decode step (0 = all queued up front); "
                        "continuous mode only")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--trace-dir", default=None,
                   help="write a Perfetto-loadable trace JSON per process "
                        "(admission/prefill/decode-step spans; continuous "
                        "mode)")
    args = p.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    api = R.build(cfg)
    params = api.init(jax.random.PRNGKey(args.seed))
    capacity = args.prompt_len + args.max_new + 1
    if cfg.family == "vlm":
        # the VLM frontend prepends patch rows to the decode context
        capacity += min(VLM_PATCHES, args.prompt_len // 2)
    rng = np.random.default_rng(args.seed)
    extra = _extra_inputs(cfg, args, rng)

    if args.continuous:
        reqs = make_mixed_workload(
            cfg.vocab_size, args.requests, _prompt_lens(cfg, args),
            args.max_new, rng, arrival_rate=args.arrival_rate,
        )
        clone = [Request(prompt=r.prompt.copy(), max_new_tokens=r.max_new_tokens,
                         eos_id=r.eos_id) for r in reqs]

        tracer = None
        if args.trace_dir:
            from repro.obs.trace import Tracer

            tracer = Tracer()
        cont = ContinuousEngine(api, batch_size=args.batch, capacity=capacity,
                                temperature=args.temperature, seed=args.seed,
                                tracer=tracer)
        t0 = time.perf_counter()
        cont.serve(params, reqs, extra_inputs=extra)
        _summarize("continuous", reqs, cont.stats, time.perf_counter() - t0)
        if tracer is not None:
            from repro.obs.export import write_trace_dir

            print("trace:", write_trace_dir(tracer, args.trace_dir,
                                            basename="serve"))

        static = ServeEngine(api, batch_size=args.batch, capacity=capacity,
                             temperature=args.temperature, seed=args.seed)
        t0 = time.perf_counter()
        generate_bucketed(static, params, clone, extra_inputs=extra)
        _summarize("static    ", clone, static.stats, time.perf_counter() - t0)

        c, s = cont.stats["slot_steps"], static.stats["slot_steps"]
        print(f"slot_steps: continuous={c} static={s} "
              f"({s / max(c, 1):.2f}x fewer slot-seconds)")
        if c >= s:
            # a degenerate workload (e.g. a single request) cannot be
            # refilled, so slot refill has nothing to win — report it
            # cleanly instead of tracebacking
            raise SystemExit(
                f"continuous batching did not beat static on this workload "
                f"({c} vs {s} slot-steps); mixed-length workloads with more "
                f"requests than --batch are where refill pays"
            )
        return

    reqs = [
        Request(
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len, dtype=np.int32),
            max_new_tokens=args.max_new,
        )
        for _ in range(args.requests)
    ]
    engine = ServeEngine(api, batch_size=args.batch, capacity=capacity,
                         temperature=args.temperature, seed=args.seed)
    t0 = time.perf_counter()
    for i in range(0, len(reqs), args.batch):
        batch = reqs[i : i + args.batch]
        engine.generate(params, batch, extra_inputs=extra)
        print(f"batch {i // args.batch}: "
              + "; ".join(str(r.out_tokens[:8]) for r in batch))
    _summarize("static", reqs, engine.stats, time.perf_counter() - t0)


if __name__ == "__main__":
    main()
