"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state).

Axis semantics (paper mapping, DESIGN.md §2):

* ``pod``   — the network in the LARGE (inter-pod DCI); only coarse
  data-parallel gradient sync crosses it.
* ``data``  — intra-pod data parallelism / FSDP shard axis.
* ``model`` — the network in the SMALL for fine-grained parallelism:
  TP (heads/d_ff), EP (experts — the paper's exchange runs here), and
  sequence sharding of decode KV caches.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh as _make_mesh
from repro.distributed.sharding import AxisRules, MeshContext, default_rules


def _squarest_factors(n: int) -> tuple[int, int]:
    """``(d, m)`` with ``d * m == n`` and ``d <= m``, as square as possible."""
    d = int(n**0.5)
    while d > 1 and n % d:
        d -= 1
    return d, n // d


def make_production_mesh(*, multi_pod: bool = False, num_pods: int | None = None):
    """Mesh shaped from the LIVE topology, not hardcoded constants.

    Single-level: ``(data, model)`` is the squarest factorization of the
    device count (256 devices -> the classic ``(16, 16)``).  Multi-pod: one
    pod per process — ``num_pods`` defaults to ``jax.process_count()``, the
    only topology fact that tells us where the slow network actually is
    (launch via ``repro.launch.cluster`` or ``jax.distributed.initialize``
    first).  Every non-factoring combination fails with what to fix, not a
    reshape error five layers down.
    """
    total = jax.device_count()
    if not multi_pod:
        d, m = _squarest_factors(total)
        return _make_mesh((d, m), ("data", "model"))
    pods = num_pods if num_pods is not None else jax.process_count()
    if pods <= 1:
        raise ValueError(
            "make_production_mesh(multi_pod=True) needs a real process "
            f"topology, but jax.process_count() == {jax.process_count()} and "
            "no num_pods override was given.  Launch under "
            "`python -m repro.launch.cluster --processes N ...` (or call "
            "jax.distributed.initialize), or pass num_pods= explicitly to "
            "fake pods on a single process."
        )
    if total % pods:
        raise ValueError(
            f"{total} devices do not split across {pods} pods "
            f"({total} % {pods} != 0).  Use a pod count that divides the "
            "device count, or adjust --local-devices so every process "
            "contributes the same number of devices."
        )
    per_pod = total // pods
    if per_pod < 2:
        raise ValueError(
            f"{per_pod} device(s) per pod cannot form a (data, model) "
            "in-pod mesh — each pod needs at least 2 devices. Raise "
            "--local-devices (or lower the pod count)."
        )
    d, m = _squarest_factors(per_pod)
    return _make_mesh((pods, d, m), ("pod", "data", "model"))


def make_test_mesh(shape=None, axes=None):
    """Small mesh for the unit tests.

    Defaults derive from the live process topology: single-process, the
    classic ``(2, 4)`` over ``("data", "model")`` (8 fake devices);
    multi-process, one pod per process — ``(process_count,
    local_device_count)`` over ``("pod", "model")`` — so the same scenario
    code sees a genuine two-level mesh when launched under
    ``repro.launch.cluster``.
    """
    if shape is None and axes is None and jax.process_count() > 1:
        return _make_mesh(
            (jax.process_count(), jax.local_device_count()), ("pod", "model")
        )
    return _make_mesh(shape or (2, 4), axes or ("data", "model"))


def make_pod_mesh(num_pods: int | None = None, axes=("pod", "q")):
    """Two-level mesh for the relational engine / pod-axis scenarios.

    ``num_pods`` defaults to ``jax.process_count()`` (one pod per process —
    the in-pod axis is then pure fast-network); pass it explicitly to carve
    fake pods out of a single process's devices.  Fails with an actionable
    error when the device count does not factor.
    """
    total = jax.device_count()
    pods = num_pods if num_pods is not None else jax.process_count()
    if pods < 1 or total % pods:
        raise ValueError(
            f"cannot split {total} devices into {pods} pods; pick a pod "
            "count dividing the device count (launch via repro.launch."
            "cluster to control both)"
        )
    return _make_mesh((pods, total // pods), axes)


def make_context(
    *,
    multi_pod: bool = False,
    num_pods: int | None = None,
    exchange_impl: str = "round_robin",
    rules: AxisRules | None = None,
    mesh=None,
) -> MeshContext:
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod, num_pods=num_pods)
    axis_names = mesh.axis_names
    return MeshContext(
        mesh=mesh,
        rules=rules or default_rules("pod" in axis_names),
        exchange_axis="model",
        data_axes=tuple(a for a in axis_names if a in ("pod", "data")),
        pod_axis="pod" if "pod" in axis_names else None,
        exchange_impl=exchange_impl,
    )


__all__ = [
    "make_production_mesh",
    "make_test_mesh",
    "make_pod_mesh",
    "make_context",
]
