"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state).

Axis semantics (paper mapping, DESIGN.md §2):

* ``pod``   — the network in the LARGE (inter-pod DCI); only coarse
  data-parallel gradient sync crosses it.
* ``data``  — intra-pod data parallelism / FSDP shard axis.
* ``model`` — the network in the SMALL for fine-grained parallelism:
  TP (heads/d_ff), EP (experts — the paper's exchange runs here), and
  sequence sharding of decode KV caches.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh as _make_mesh
from repro.distributed.sharding import AxisRules, MeshContext, default_rules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for the multi-device unit tests (8 fake devices)."""
    return _make_mesh(shape, axes)


def make_context(
    *,
    multi_pod: bool = False,
    exchange_impl: str = "round_robin",
    rules: AxisRules | None = None,
    mesh=None,
) -> MeshContext:
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    axis_names = mesh.axis_names
    return MeshContext(
        mesh=mesh,
        rules=rules or default_rules("pod" in axis_names),
        exchange_axis="model",
        data_axes=tuple(a for a in axis_names if a in ("pod", "data")),
        pod_axis="pod" if "pod" in axis_names else None,
        exchange_impl=exchange_impl,
    )


__all__ = ["make_production_mesh", "make_test_mesh", "make_context"]
