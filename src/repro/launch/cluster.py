"""Multi-process cluster bring-up: a REAL network-in-the-large for CI.

Every mesh this repo ran before this module existed was single-host fake
devices — the ``pod`` axis, ``hierarchical_psum`` and the
exchange-forbidden-on-DCI rule had never crossed an actual process
boundary.  This module closes that gap two ways:

* :func:`init_cluster` — the worker half.  Call it at the top of a script
  (before anything touches jax devices); it reads the ``REPRO_CLUSTER_*``
  environment (or explicit arguments), forces the requested number of fake
  CPU devices *before* the backend initializes, enables the Gloo CPU
  collectives backend, and runs ``jax.distributed.initialize``.  After it
  returns, ``jax.process_count() == N`` and every collective over a mesh
  that spans processes really crosses a socket — the CI stand-in for DCI.

* :func:`run_local_cluster` — the launcher half.  Spawns N copies of a
  worker script as OS processes on this host (coordinator on a free
  localhost port), streams each worker's output to a spool file, enforces a
  deadline, and raises with the offending worker's output on any failure.

Command line (the recipe ``docs/MULTIHOST.md`` walks through)::

    python -m repro.launch.cluster --processes 2 --local-devices 4 \
        tests/_multiproc_driver.py hierarchical_psum

On real hardware none of the fakery is needed: ``jax.distributed
.initialize()`` with no arguments picks up the TPU/GPU cluster environment,
and ``init_cluster()`` degrades to exactly that call.
"""

from __future__ import annotations

import dataclasses
import os
import socket
import subprocess
import sys
import tempfile
import time

ENV_COORDINATOR = "REPRO_CLUSTER_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_CLUSTER_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_CLUSTER_PROCESS_ID"
ENV_LOCAL_DEVICES = "REPRO_CLUSTER_LOCAL_DEVICES"


@dataclasses.dataclass(frozen=True)
class ClusterInfo:
    """What :func:`init_cluster` established."""

    process_id: int
    num_processes: int
    coordinator: str | None
    local_devices: int


def _fake_device_flag(count: int) -> None:
    flag = f"--xla_force_host_platform_device_count={count}"
    existing = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in existing:
        os.environ["XLA_FLAGS"] = (existing + " " + flag).strip()


def init_cluster(
    *,
    coordinator: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
    local_devices: int | None = None,
    timeout_s: int = 120,
) -> ClusterInfo:
    """Join (or degenerate to) a jax.distributed cluster.  Call FIRST.

    Arguments default to the ``REPRO_CLUSTER_*`` environment set by
    :func:`run_local_cluster`; outside a launched cluster (all unset) this
    is a no-op returning a single-process :class:`ClusterInfo`, so worker
    scripts also run standalone.  Must run before jax initializes its
    backends — the fake-device flag and the Gloo collectives selection are
    both latched at backend init.
    """
    coordinator = coordinator or os.environ.get(ENV_COORDINATOR)
    if num_processes is None:
        num_processes = int(os.environ.get(ENV_NUM_PROCESSES, "1"))
    if process_id is None:
        process_id = int(os.environ.get(ENV_PROCESS_ID, "0"))
    if local_devices is None:
        local_devices = int(os.environ.get(ENV_LOCAL_DEVICES, "0"))

    if local_devices:
        _fake_device_flag(local_devices)
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from repro.compat import enable_cpu_collectives

    if num_processes > 1:
        if os.environ.get("JAX_PLATFORMS", "") in ("", "cpu"):
            enable_cpu_collectives()
        import jax

        jax.distributed.initialize(
            coordinator_address=coordinator,
            num_processes=num_processes,
            process_id=process_id,
            initialization_timeout=timeout_s,
        )
    return ClusterInfo(
        process_id=process_id,
        num_processes=num_processes,
        coordinator=coordinator,
        local_devices=local_devices,
    )


def _free_port() -> int:
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def run_local_cluster(
    argv: list[str],
    num_processes: int = 2,
    local_devices: int = 4,
    timeout_s: int = 600,
    env: dict | None = None,
    echo: bool = True,
) -> list[str]:
    """Spawn ``argv`` as ``num_processes`` coordinated worker processes.

    Each worker gets the ``REPRO_CLUSTER_*`` environment (:func:`init_cluster`
    reads it), ``JAX_PLATFORMS=cpu``, and a scrubbed ``XLA_FLAGS`` so the
    fake-device count is exactly ``local_devices``.  Output is spooled to
    files (not pipes — a full pipe would deadlock workers that are blocked
    in a collective with a chatty peer).  Returns each worker's combined
    stdout+stderr, process id order; raises ``RuntimeError`` with the full
    logs if any worker exits nonzero or the deadline passes.
    """
    port = _free_port()
    procs, logs = [], []
    for pid in range(num_processes):
        e = dict(os.environ)
        e.pop("XLA_FLAGS", None)
        e.update(env or {})
        e.update({
            ENV_COORDINATOR: f"127.0.0.1:{port}",
            ENV_NUM_PROCESSES: str(num_processes),
            ENV_PROCESS_ID: str(pid),
            ENV_LOCAL_DEVICES: str(local_devices),
            "JAX_PLATFORMS": "cpu",
        })
        log = tempfile.NamedTemporaryFile(
            mode="w+", suffix=f".proc{pid}.log", delete=False
        )
        logs.append(log)
        procs.append(subprocess.Popen(
            [sys.executable, *argv],
            env=e, stdout=log, stderr=subprocess.STDOUT, text=True,
        ))
    deadline = time.monotonic() + timeout_s
    try:
        for p in procs:
            p.wait(timeout=max(1.0, deadline - time.monotonic()))
    except subprocess.TimeoutExpired:
        for p in procs:
            p.kill()
        for p in procs:
            p.wait()
        raise RuntimeError(
            f"cluster run timed out after {timeout_s}s\n"
            + _format_logs(argv, procs, logs)
        ) from None
    outputs = []
    for log in logs:
        log.flush()
        log.seek(0)
        outputs.append(log.read())
        log.close()
        os.unlink(log.name)
    if echo:
        for pid, out in enumerate(outputs):
            for line in out.splitlines():
                print(f"[proc {pid}] {line}")
    bad = [p.returncode for p in procs if p.returncode]
    if bad:
        raise RuntimeError(
            f"cluster run failed (exit codes "
            f"{[p.returncode for p in procs]})\n"
            + "\n".join(
                f"--- proc {pid} ---\n{out}" for pid, out in enumerate(outputs)
            )
        )
    return outputs


def _format_logs(argv, procs, logs) -> str:
    parts = [f"argv: {argv}"]
    for pid, log in enumerate(logs):
        try:
            log.flush()
            log.seek(0)
            parts.append(f"--- proc {pid} (exit {procs[pid].returncode}) ---")
            parts.append(log.read())
            log.close()
            os.unlink(log.name)
        except OSError:
            pass
    return "\n".join(parts)


def main(argv: list[str]) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.cluster",
        description="Run a worker script as a local multi-process jax cluster "
        "(N CPU processes x M fake devices each).",
    )
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--local-devices", type=int, default=4)
    ap.add_argument("--timeout", type=int, default=600)
    ap.add_argument("worker", nargs=argparse.REMAINDER,
                    help="worker script and its arguments")
    args = ap.parse_args(argv)
    worker = [a for a in args.worker if a != "--"]
    if not worker:
        ap.error("missing worker script")
    try:
        run_local_cluster(
            worker, num_processes=args.processes,
            local_devices=args.local_devices, timeout_s=args.timeout,
        )
    except RuntimeError as e:
        print(str(e), file=sys.stderr)
        return 1
    return 0


__all__ = ["ClusterInfo", "init_cluster", "run_local_cluster"]

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
