"""The while-aware HLO cost analyzer vs exact unrolled ground truth."""

import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze


def _cost(fn, *args):
    return analyze(jax.jit(fn).lower(*args).compile().as_text())


X = jnp.ones((128, 128))
W = jnp.ones((128, 128))
MM_FLOPS = 2 * 128**3


def test_plain_matmul():
    r = _cost(lambda x, w: x @ w, X, W)
    assert r["flops"] == MM_FLOPS


def test_scan_trip_count_multiplied():
    def f(x, w):
        c, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=10)
        return c

    r = _cost(f, X, W)
    assert r["flops"] == 10 * MM_FLOPS
    assert r["unknown_trip_whiles"] == 0


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            c, _ = jax.lax.scan(lambda c2, _: (c2 @ w, None), c, None, length=5)
            return c, None

        c, _ = jax.lax.scan(outer, x, None, length=4)
        return c

    assert _cost(f, X, W)["flops"] == 20 * MM_FLOPS


def test_grad_of_scan():
    def f(w, x):
        c, _ = jax.lax.scan(lambda c, _: (jnp.tanh(c @ w), None), x, None, length=6)
        return (c**2).sum()

    # fwd: 6 dots; bwd: 2 dots per step (dx and dw)
    assert _cost(jax.grad(f), W, X)["flops"] == 18 * MM_FLOPS


def test_remat_recompute_counted():
    def f(w, x):
        body = jax.checkpoint(lambda c, _: (jnp.tanh(c @ w), None))
        c, _ = jax.lax.scan(body, x, None, length=6)
        return (c**2).sum()

    # fwd 6 + recompute 6 + bwd 12
    assert _cost(jax.grad(f), W, X)["flops"] == 24 * MM_FLOPS


def test_scan_matches_unrolled():
    def scanned(x, w):
        c, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=7)
        return c

    def unrolled(x, w):
        for _ in range(7):
            x = x @ w
        return x

    assert _cost(scanned, X, W)["flops"] == _cost(unrolled, X, W)["flops"]


def test_gqa_einsum_flops():
    q = jnp.ones((2, 8, 64, 32))
    k = jnp.ones((2, 8, 128, 32))

    def f(q, k):
        return jnp.einsum("bhqd,bhkd->bhqk", q, k)

    want = 2 * 2 * 8 * 64 * 128 * 32
    assert _cost(f, q, k)["flops"] == want


def test_memory_counts_dot_traffic():
    r = _cost(lambda x, w: x @ w, X, W)
    assert r["bytes"] >= 3 * 128 * 128 * 4  # two reads + one write


def test_collective_free_program_has_none():
    r = _cost(lambda x: x * 2 + 1, X)
    assert r["collective_bytes"] == {}
