"""``run.exchange_report`` keys: stable across plan variants and reloads.

The report used to key edges on the PNode display index (``#5 l_partkey``),
which renumbers whenever the planner changes the plan's SHAPE around an
unchanged shuffle — salting inserts combine/merge nodes, so the very same
``l_partkey`` shuffle is ``#5`` in the static Q17 plan but ``#7`` in the
salted one, and a warm (cached) run's report could never be diffed against
a cold one.  Keys are now the shuffle's key column plus its first-visit
ordinal (``shuffle[l_partkey]#0``) — a pure function of the shuffle edges,
identical for cold, warm, replanned, and unpickled plans.

Runtime coverage at 8 shards (values, not just keys) lives in the
``exchange_report`` scenario of ``tests/_multidev_driver.py``; at ``n=1``
the single-device executor elides exchanges entirely, so the report must
be EMPTY, not populated with degenerate entries.
"""

import pickle
import re

import numpy as np

from repro.relational import datagen
from repro.relational import stats as S
from repro.relational.planner import tpch
from repro.relational.planner.executor import _report_keys, compile_plan

KEY_RE = re.compile(r"^shuffle\[\w+\]#\d+$")

CATALOG_Q17 = {"lineitem": 480_000, "part": 2_000}


def _skewed_stats():
    """A synthetic l_partkey profile hot enough to flip Q17 to salted."""
    cs = S.ColumnStats(
        name="l_partkey", ndv=2_000,
        heavy_hitters=((0, 0.25), (1, 0.05)), max_share=0.25,
    )
    prof = S.TableProfile(
        table="lineitem", rows=480_000, sample_rows=1_024,
        columns={"l_partkey": cs},
        sample={"l_partkey": np.zeros(4, np.int64)},
    )
    return {"lineitem": prof}


def test_keys_are_key_column_plus_ordinal():
    pq = tpch.q3()
    cat = tpch.tpch_catalog(0.08)
    plan = pq.plan({t: cat[t] for t in pq.tables}, 8)
    keys = list(_report_keys(plan.root).values())
    assert keys, "q3 at 8 shards must have shuffle edges"
    assert all(KEY_RE.match(k) for k in keys), keys
    assert len(set(keys)) == len(keys)
    # ordinals are contiguous first-visit positions, not display indices
    assert sorted(int(k.rsplit("#", 1)[1]) for k in keys) == list(
        range(len(keys))
    )
    # both q3 shuffles, in preorder: orders side then lineitem side
    assert keys == ["shuffle[o_orderkey]#0", "shuffle[l_orderkey]#1"]


def test_keys_stable_across_replans():
    pq = tpch.q17()
    k1 = list(_report_keys(pq.plan(CATALOG_Q17, 8).root).values())
    k2 = list(_report_keys(pq.plan(CATALOG_Q17, 8).root).values())
    assert k1 == k2 == ["shuffle[l_partkey]#0"]


def test_keys_stable_when_salting_renumbers_the_plan():
    """The regression this fixes: salting inserts nodes, so the SAME
    shuffle edge gets a different display index — but the report key
    must not move."""
    pq = tpch.q17()
    static = pq.plan(CATALOG_Q17, 8)
    salted = pq.plan(CATALOG_Q17, 8, stats=_skewed_stats())
    assert "salted x" in salted.explain() and "salted x" not in static.explain()

    def idx_of_shuffle(plan):
        (line,) = [ln for ln in plan.explain().splitlines()
                   if "Exchange[shuffle" in ln]
        return int(line.split("#")[1].split(" ")[0])

    # the display index DID renumber (this is why it can't be the key) ...
    assert idx_of_shuffle(static) != idx_of_shuffle(salted)
    # ... but the report key did not
    assert (
        list(_report_keys(static.root).values())
        == list(_report_keys(salted.root).values())
        == ["shuffle[l_partkey]#0"]
    )


def test_keys_survive_pickle_roundtrip():
    """Cached plans are persisted with pickle: the reloaded plan (all-new
    object identities) must report under the same keys."""
    pq = tpch.q17()
    plan = pq.plan(CATALOG_Q17, 8)
    clone = pickle.loads(pickle.dumps(plan))
    assert (
        list(_report_keys(plan.root).values())
        == list(_report_keys(clone.root).values())
    )


def test_single_device_report_is_empty():
    """n=1 elides exchanges: the report is {} before AND after a run —
    never stale, never populated with degenerate entries."""
    import pytest

    tabs = datagen.gen_all(0.004)
    pq = tpch.q6()
    tables = {t: tabs[t] for t in pq.tables}
    plan = pq.plan({t: tables[t].capacity for t in pq.tables}, 1)
    run = compile_plan(plan, tables)
    with pytest.warns(DeprecationWarning, match="collect"):
        assert run.exchange_report == {}
    result, qt = run.collect(run.dispatch())
    assert qt.exchange_report() == {}
    assert qt.edges == ()


def test_collect_is_pure_and_per_run():
    """The old function-attribute report raced under the serve engine:
    two in-flight runs of one memoized executor stomped a single
    ``run.exchange_report``.  ``collect`` returns the QueryTrace with the
    result instead of mutating the runner — two dispatches of the SAME
    runner yield independent traces."""
    tabs = datagen.gen_all(0.004)
    pq = tpch.q6()
    tables = {t: tabs[t] for t in tabs if t in pq.tables}
    plan = pq.plan({t: tables[t].capacity for t in pq.tables}, 1)
    run = compile_plan(plan, tables)
    out_a, out_b = run.dispatch(), run.dispatch()
    res_a, qt_a = run.collect(out_a)
    res_b, qt_b = run.collect(out_b)
    assert qt_a is not qt_b
    assert qt_a.query == qt_b.query == plan.name
    # collect never wrote runner state
    assert run.last_trace is None
