"""Adaptive (stats-driven) planning: flip tests, goldens, runtime gate.

The contract under test, end to end:

* UNIFORM data: profiling finds no heavy hitters, so plans built WITH
  stats are bit-identical to the static plans (the existing goldens) —
  the adaptive layer is provably inert when data is balanced;
* ZIPF data: the profile flips Q17 (zipf ``l_partkey``) and Q18 (zipf
  ``l_orderkey``) to the salted-repartition shape, snapshotted under
  ``tests/golden_plans/q17_salted.txt`` / ``q18_salted.txt`` (regenerate
  with ``REPRO_UPDATE_GOLDEN=1``, same mechanism as test_planner.py);
* the salted plan computes the same answer as the numpy oracle on a
  single device (8-device runs: ``tests/_multidev_driver.py``
  ``skewed_q17``);
* the skew-aware makespan extension prices the max-loaded shard and is
  bit-identical to the old model at ``skew=1``.
"""

import os

import numpy as np
import pytest

from repro.core.autotune import TableStats, exchange_makespan
from repro.relational import datagen, oracle
from repro.relational import stats as rstats
from repro.relational.context import ExecutionContext, StatsMode
from repro.relational.planner import tpch

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden_plans")


@pytest.fixture(scope="module")
def uniform_tables():
    return datagen.gen_all(0.01)


@pytest.fixture(scope="module")
def zipf_tables():
    # zipf_partkey=1.2: the acceptance scenario (22% of lineitem on one
    # part); zipf_orderkey=1.5 pushes l_orderkey's top key past a fair
    # share at 8 shards so Q18's group-by exchange flips too.
    return datagen.gen_all(0.01, zipf_partkey=1.2, zipf_orderkey=1.5)


def _stats_for(pq, tables):
    return rstats.collect_stats({t: tables[t] for t in pq.tables})


def _ctx8(stats):
    return ExecutionContext(
        num_shards=8, stats_mode=StatsMode.PROFILE, stats_profile=stats,
    )


def _catalog(pq, tables):
    return {t: tables[t].capacity for t in pq.tables}


# ---------------------------------------------------------------------------
# Uniform stats leave every plan bit-identical to the static goldens.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("query", ["q3", "q4", "q12", "q17", "q18"])
def test_uniform_stats_keep_static_plans(query, uniform_tables):
    pq = tpch.ALL_QUERIES[query]()
    text = tpch.explain_query(
        pq, tpch.tpch_catalog(0.01),
        _ctx8(_stats_for(pq, uniform_tables)),
    )
    with open(os.path.join(GOLDEN_DIR, f"{query}.txt")) as f:
        assert text == f.read(), (
            f"uniform-data stats changed the {query} plan — the adaptive "
            "layer must be inert without heavy hitters"
        )


def test_uniform_profile_has_no_heavy_hitters(uniform_tables):
    prof = rstats.profile_table("lineitem", uniform_tables["lineitem"])
    assert prof.columns["l_partkey"].heavy_hitters == ()
    assert prof.columns["l_orderkey"].heavy_hitters == ()


# ---------------------------------------------------------------------------
# Zipf stats flip Q17/Q18 to the salted shape (golden snapshots).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fname,query", [
    ("q17_salted", "q17"),
    ("q18_salted", "q18"),
])
def test_zipf_stats_flip_to_salted_golden(fname, query, zipf_tables):
    pq = tpch.ALL_QUERIES[query]()
    text = tpch.explain_query(
        pq, _catalog(pq, zipf_tables), _ctx8(_stats_for(pq, zipf_tables))
    )
    assert "salted x" in text and "GroupByCombine" in text
    path = os.path.join(GOLDEN_DIR, f"{fname}.txt")
    if os.environ.get("REPRO_UPDATE_GOLDEN"):
        with open(path, "w") as f:
            f.write(text)
    with open(path) as f:
        want = f.read()
    assert text == want, (
        f"salted explain({query}) drifted from tests/golden_plans/{fname}.txt"
        " — if intended, regenerate with REPRO_UPDATE_GOLDEN=1"
    )


def test_zipf_profile_finds_the_planted_skew(zipf_tables):
    prof = rstats.profile_table("lineitem", zipf_tables["lineitem"])
    cs = prof.columns["l_partkey"]
    # key 0 carries ~22% of rows at z=1.2 over the 2000-part domain
    assert cs.heavy_hitters[0][0] == 0
    assert 0.15 < cs.max_share < 0.30
    over = rstats.partition_overload(cs.heavy_hitters, 8)
    assert over > 2.0  # the imbalance the plain exchange would eat
    heavy = rstats.salting_keys(cs, 8)
    salts = rstats.choose_num_salts(heavy, 8)
    assert rstats.partition_overload(
        cs.heavy_hitters, 8, num_salts=salts, salted=heavy
    ) < 1.3


def test_orders_side_stays_plain_under_zipf(zipf_tables):
    """o_orderkey is a key column (arange, never heavy): Q18's orders
    shuffle must stay a plain hash even when lineitem flips."""
    pq = tpch.q18()
    text = tpch.explain_query(
        pq, _catalog(pq, zipf_tables), _ctx8(_stats_for(pq, zipf_tables))
    )
    assert "shuffle by o_orderkey]" in text  # no salted suffix on that edge


# ---------------------------------------------------------------------------
# Salted plans compute the oracle answer (single device; 8-dev: multidev).
# ---------------------------------------------------------------------------

def test_salted_q17_matches_oracle_single_device(zipf_tables):
    pq = tpch.q17(brand=11, container=25)  # selects the heaviest part
    got = float(tpch.run_query(pq, zipf_tables, ExecutionContext(
        num_shards=1, stats_mode=StatsMode.COLLECT)))
    want = oracle.q17_oracle(
        zipf_tables["lineitem"], zipf_tables["part"], 11, 25
    )
    assert want > 0  # scenario must exercise real revenue
    np.testing.assert_allclose(got, want, rtol=1e-3)


def test_salted_q18_matches_oracle_single_device(zipf_tables):
    pq = tpch.q18()
    got = tpch.run_query(pq, zipf_tables, ExecutionContext(
        num_shards=1, stats_mode=StatsMode.COLLECT))
    want = oracle.q18_oracle(
        zipf_tables["lineitem"], zipf_tables["orders"], zipf_tables["customer"]
    )
    assert len(want["o_orderkey"])  # threshold still hit under zipf
    for k in want:
        np.testing.assert_allclose(
            np.asarray(got[k]), np.asarray(want[k]), rtol=1e-3
        )


# ---------------------------------------------------------------------------
# Skew-aware makespan: prices the max-loaded shard.
# ---------------------------------------------------------------------------

def test_makespan_skew_one_is_identity():
    st = TableStats(rows=10_000, row_bytes=16)
    assert exchange_makespan(st, 8) == exchange_makespan(st, 8, skew=1.0)


def test_makespan_monotone_in_skew():
    st = TableStats(rows=10_000, row_bytes=16)
    times = [exchange_makespan(st, 8, skew=s) for s in (1.0, 1.5, 2.0, 4.0)]
    assert times == sorted(times) and times[0] < times[-1]
    # two-level: the skewed shard also stalls the cross-pod hop
    t2 = [exchange_makespan(st, 4, num_pods=2, skew=s) for s in (1.0, 3.0)]
    assert t2[0] < t2[1]


def test_makespan_rejects_sub_unit_skew():
    with pytest.raises(ValueError, match="skew"):
        exchange_makespan(TableStats(rows=100, row_bytes=8), 8, skew=0.5)
