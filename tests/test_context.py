"""ExecutionContext API: legacy-kwarg equivalence and the one-shot shim.

Contract from the PR spec: every legacy kwarg spelling maps onto the exact
same ``ExecutionContext`` (dataclass equality), plans to the same
plan-cache digest, and returns bit-identical results — and the deprecated
spellings warn exactly once per process (``reset_deprecation_warning``
re-arms the latch for testing).
"""

import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.relational import datagen
from repro.relational.context import (
    ExecutionContext,
    StatsMode,
    reset_deprecation_warning,
    resolve_context,
)
from repro.relational.distributed import q1_distributed, q6_distributed
from repro.relational.planner import tpch
from repro.relational.planner.plan_cache import plan_key

SF = 0.004


@pytest.fixture(autouse=True)
def _rearm_shim():
    reset_deprecation_warning()
    yield
    reset_deprecation_warning()


@pytest.fixture(scope="module")
def lineitem():
    return datagen.gen_lineitem(SF)


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


# ---------------------------------------------------------------------------
# Every legacy spelling resolves to the identical ExecutionContext.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spelling", [
    dict(ctx=1),                                   # old positional num_shards
    dict(legacy=dict(num_shards=1)),               # old keyword
    dict(legacy=dict(num_shards=1, impl=None)),    # impl=None was the default
    dict(legacy=dict(num_shards=1, num_pods=1)),
])
def test_legacy_spellings_map_to_identical_context(spelling):
    with pytest.warns(DeprecationWarning):
        got = resolve_context(
            spelling.get("ctx"), spelling.get("legacy"), where="test"
        )
        reset_deprecation_warning()
    assert got == ExecutionContext(num_shards=1)
    assert hash(got) == hash(ExecutionContext(num_shards=1))


def test_legacy_stats_pun_is_unpunned():
    with pytest.warns(DeprecationWarning):
        collected = resolve_context(
            None, dict(num_shards=1, stats="collect"), where="test"
        )
    assert collected.stats_mode is StatsMode.COLLECT
    reset_deprecation_warning()

    profile = {"lineitem": object()}
    with pytest.warns(DeprecationWarning):
        profiled = resolve_context(
            None, dict(num_shards=1, stats=profile), where="test"
        )
    assert profiled.stats_mode is StatsMode.PROFILE
    assert profiled.stats_profile == profile
    # stats_profile is payload, not identity: contexts compare on knobs
    assert profiled == ExecutionContext(
        num_shards=1, stats_mode=StatsMode.PROFILE, stats_profile={"x": 1}
    )


def test_legacy_and_ctx_plan_to_same_digest(lineitem):
    pq = tpch.q1()
    catalog = {"lineitem": lineitem.capacity}
    with pytest.warns(DeprecationWarning):
        legacy = resolve_context(None, dict(num_shards=2), where="test")
    ctx = ExecutionContext(num_shards=2)
    assert legacy == ctx
    k_legacy = plan_key(pq.logical, catalog, legacy.num_shards,
                        num_pods=legacy.num_pods, cfg=legacy.cfg,
                        cross_pod=legacy.cross_pod)
    k_ctx = plan_key(pq.logical, catalog, ctx.num_shards,
                     num_pods=ctx.num_pods, cfg=ctx.cfg,
                     cross_pod=ctx.cross_pod)
    assert k_legacy.digest == k_ctx.digest


def test_legacy_and_ctx_results_bit_identical(lineitem):
    oracle = q1_distributed(lineitem, ExecutionContext(num_shards=1))
    with pytest.warns(DeprecationWarning):
        via_int = q1_distributed(lineitem, 1)
        reset_deprecation_warning()
    with pytest.warns(DeprecationWarning):
        via_kw = q1_distributed(lineitem, num_shards=1)
    assert _trees_equal(oracle, via_int)
    assert _trees_equal(oracle, via_kw)


def test_run_query_legacy_matches_ctx(lineitem):
    pq = tpch.q6()
    tables = {"lineitem": lineitem}
    oracle = tpch.run_query(pq, tables, ExecutionContext(num_shards=1))
    with pytest.warns(DeprecationWarning):
        legacy = tpch.run_query(pq, tables, 1)
    assert _trees_equal(oracle, legacy)


# ---------------------------------------------------------------------------
# The shim warns exactly once per process.
# ---------------------------------------------------------------------------

def test_deprecated_kwargs_warn_exactly_once(lineitem):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        q6_distributed(lineitem, 1)
        q1_distributed(lineitem, num_shards=1)
        resolve_context(None, dict(num_shards=1), where="test")
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1, [str(w.message) for w in deps]
    assert "ExecutionContext" in str(deps[0].message)


def test_ctx_api_never_warns(lineitem):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("error", DeprecationWarning)
        q6_distributed(lineitem, ExecutionContext(num_shards=1))
    assert not rec


# ---------------------------------------------------------------------------
# Shim error surface.
# ---------------------------------------------------------------------------

def test_ctx_plus_legacy_kwargs_rejected(lineitem):
    with pytest.raises(TypeError, match="cannot be combined"):
        q6_distributed(lineitem, ExecutionContext(num_shards=1), num_shards=1)


def test_unknown_kwarg_rejected(lineitem):
    with pytest.raises(TypeError, match="unexpected keyword"):
        q6_distributed(lineitem, 1, morsels=4)


def test_positional_and_keyword_num_shards_conflict():
    with pytest.raises(TypeError, match="positionally and by keyword"):
        resolve_context(1, dict(num_shards=1), where="test")


def test_context_validation():
    with pytest.raises(ValueError, match="not divisible"):
        ExecutionContext(num_shards=3, num_pods=2)
    with pytest.raises(TypeError, match="StatsMode"):
        ExecutionContext(num_shards=1, stats_mode="collect")
    with pytest.raises(ValueError, match="requires stats_profile"):
        ExecutionContext(num_shards=1, stats_mode=StatsMode.PROFILE)


def test_with_returns_updated_frozen_copy():
    ctx = ExecutionContext(num_shards=2)
    streamed = ctx.with_(morsel_rows=4096, spill=True)
    assert streamed.morsel_rows == 4096 and streamed.spill
    assert ctx.morsel_rows is None  # original untouched
    with pytest.raises(dataclasses.FrozenInstanceError):
        ctx.num_shards = 4
