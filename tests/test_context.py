"""ExecutionContext API: the one way to parameterize execution.

The PR-9 per-knob kwarg shim (``num_shards`` positionally, ``impl=``/
``stats=``/... keywords, the one-shot DeprecationWarning latch) is gone.
Old spellings now raise a pointed ``TypeError`` at the entry point
(``require_context``) instead of warning, the context validates its knobs
at construction, and the observability ``trace`` knob is excluded from
equality/hash so traced and untraced runs share plan-cache entries and
executor memos.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.obs.trace import Tracer
from repro.relational import datagen
from repro.relational.context import (
    ExecutionContext,
    StatsMode,
    require_context,
)
from repro.relational.distributed import q1_distributed, q6_distributed
from repro.relational.planner import tpch

SF = 0.004


@pytest.fixture(scope="module")
def lineitem():
    return datagen.gen_lineitem(SF)


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


# ---------------------------------------------------------------------------
# Old spellings raise TypeError, pointing at the migration.
# ---------------------------------------------------------------------------

def test_positional_int_rejected(lineitem):
    with pytest.raises(TypeError, match="ExecutionContext"):
        q6_distributed(lineitem, 1)


def test_legacy_keyword_rejected(lineitem):
    # the wrappers take (tables..., ctx=None, query-params...): the old
    # per-knob keywords are plain unexpected-keyword TypeErrors now
    with pytest.raises(TypeError):
        q1_distributed(lineitem, num_shards=1)


def test_legacy_stats_pun_rejected(lineitem):
    pq = tpch.q6()
    with pytest.raises(TypeError):
        tpch.run_query(pq, {"lineitem": lineitem}, stats="collect")


def test_require_context_names_the_migration():
    with pytest.raises(TypeError, match="per-knob kwargs.*removed"):
        require_context(4, where="test")
    with pytest.raises(TypeError, match="test:"):
        require_context({"num_shards": 4}, where="test")
    ctx = ExecutionContext(num_shards=1)
    assert require_context(ctx, where="test") is ctx


def test_ctx_api_never_warns(lineitem):
    import warnings

    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("error", DeprecationWarning)
        q6_distributed(lineitem, ExecutionContext(num_shards=1))
    assert not rec


def test_none_defaults_to_single_shard(lineitem):
    oracle = q6_distributed(lineitem, ExecutionContext(num_shards=1))
    assert _trees_equal(oracle, q6_distributed(lineitem))


# ---------------------------------------------------------------------------
# Construction-time validation.
# ---------------------------------------------------------------------------

def test_context_validation():
    with pytest.raises(ValueError, match="not divisible"):
        ExecutionContext(num_shards=3, num_pods=2)
    with pytest.raises(TypeError, match="StatsMode"):
        ExecutionContext(num_shards=1, stats_mode="collect")
    with pytest.raises(ValueError, match="requires stats_profile"):
        ExecutionContext(num_shards=1, stats_mode=StatsMode.PROFILE)
    with pytest.raises(ValueError, match="only meaningful"):
        ExecutionContext(num_shards=1, stats_profile={"lineitem": object()})


def test_with_returns_updated_frozen_copy():
    ctx = ExecutionContext(num_shards=2)
    streamed = ctx.with_(morsel_rows=4096, spill=True)
    assert streamed.morsel_rows == 4096 and streamed.spill
    assert ctx.morsel_rows is None  # original untouched
    with pytest.raises(dataclasses.FrozenInstanceError):
        ctx.num_shards = 4


# ---------------------------------------------------------------------------
# The trace knob is payload, not identity: attaching a tracer can never
# invalidate a plan-cache entry or an executor memo.
# ---------------------------------------------------------------------------

def test_trace_excluded_from_equality_and_hash():
    plain = ExecutionContext(num_shards=2)
    traced = ExecutionContext(num_shards=2, trace=Tracer())
    assert plain == traced
    assert hash(plain) == hash(traced)
    # ... and repr doesn't leak the tracer object (stable cache-key text)
    assert "Tracer" not in repr(traced)


def test_stats_profile_excluded_from_equality():
    profiled = ExecutionContext(
        num_shards=1, stats_mode=StatsMode.PROFILE,
        stats_profile={"lineitem": object()},
    )
    assert profiled == ExecutionContext(
        num_shards=1, stats_mode=StatsMode.PROFILE, stats_profile={"x": 1}
    )
