"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional test dependency (the ``test`` extra in
pyproject.toml); without it this module skips cleanly at collection instead
of erroring the whole suite.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis is an optional test extra")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import exchange
from repro.kernels import ref as KR


# ---------------------------------------------------------------------------
# pack_by_destination (the message-pool fill): conservation + placement.
# ---------------------------------------------------------------------------

@given(
    st.integers(2, 6),          # num_dest
    st.integers(1, 64),         # rows
    st.integers(1, 32),         # capacity
    st.integers(0, 2**31 - 1),  # seed
)
@settings(max_examples=40, deadline=None)
def test_pack_by_destination_invariants(n_dest, n_rows, cap, seed):
    rng = np.random.default_rng(seed)
    dest = jnp.asarray(rng.integers(0, n_dest, n_rows), jnp.int32)
    rows = jnp.asarray(rng.integers(0, 1000, (n_rows, 2)), jnp.int32)
    bufs, counts, dropped = exchange.pack_by_destination(dest, rows, n_dest, cap)
    # conservation: kept + dropped == total
    assert int(counts.sum()) + int(dropped) == n_rows
    # counts bounded by capacity
    assert int(counts.max()) <= cap
    # every buffered row was destined for that buffer
    d_np, bufs_np, counts_np = np.asarray(dest), np.asarray(bufs), np.asarray(counts)
    rows_np = np.asarray(rows)
    for j in range(n_dest):
        got = bufs_np[j, : counts_np[j]]
        want = rows_np[d_np == j][:cap]
        np.testing.assert_array_equal(got, want)  # arrival order preserved
    # the fused-kernel pack is bit-identical to the one-hot reference
    bufs_p, counts_p, dropped_p = exchange.pack_by_destination(
        dest, rows, n_dest, cap, impl="pallas"
    )
    np.testing.assert_array_equal(np.asarray(bufs_p), bufs_np)
    np.testing.assert_array_equal(np.asarray(counts_p), counts_np)
    assert int(dropped_p) == int(dropped)


@given(st.integers(2, 8), st.integers(1, 128), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_moe_dispatch_slots_are_unique_and_bounded(n_dest, n_rows, seed):
    rng = np.random.default_rng(seed)
    cap = max(1, n_rows // n_dest)
    dest = jnp.asarray(rng.integers(0, n_dest, n_rows), jnp.int32)
    slot, counts = KR.moe_dispatch_ref(dest, n_dest, cap)
    slot_np = np.asarray(slot)
    real = slot_np[slot_np < n_dest * cap]
    assert len(np.unique(real)) == len(real)  # no slot collisions
    assert int(np.asarray(counts).sum()) == len(real)
    # slot // cap equals the destination
    d_np = np.asarray(dest)
    np.testing.assert_array_equal((slot_np // cap)[slot_np < n_dest * cap],
                                  d_np[slot_np < n_dest * cap])


@given(st.integers(1, 64), st.integers(2, 64))
@settings(max_examples=30, deadline=None)
def test_hash_partition_histogram_sums(nblocks, parts):
    keys = (jnp.arange(nblocks * 256, dtype=jnp.uint32) * jnp.uint32(2654435761)).astype(jnp.int32)
    pid, hist = KR.hash_partition_ref(keys, parts)
    assert int(np.asarray(hist).sum()) == nblocks * 256
    assert np.asarray(pid).max() < parts
    # histogram matches a direct bincount
    np.testing.assert_array_equal(
        np.asarray(hist).sum(0), np.bincount(np.asarray(pid), minlength=parts)
    )


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_fibonacci_hash_is_permutation_free_of_fixed_patterns(seed):
    """Uniformity proxy: low-bit buckets of sequential keys are balanced."""
    base = np.random.default_rng(seed).integers(0, 1 << 20)
    keys = jnp.arange(base, base + 4096, dtype=jnp.int32)
    h = np.asarray(KR.fibonacci_hash_ref(keys))
    counts = np.bincount(h % 16, minlength=16)
    assert counts.max() / counts.mean() < 1.5


# ---------------------------------------------------------------------------
# Loss function sanity.
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_xent_matches_numpy(seed):
    from repro.models.layers import xent_loss

    rng = np.random.default_rng(seed)
    logits = rng.standard_normal((3, 5, 11)).astype(np.float32)
    labels = rng.integers(0, 11, (3, 5))
    got = float(xent_loss(jnp.asarray(logits), jnp.asarray(labels)))
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    want = -np.log(np.take_along_axis(p, labels[..., None], -1)).mean()
    np.testing.assert_allclose(got, want, rtol=1e-5)


@given(st.sampled_from(["cosine", "wsd", "constant"]))
@settings(max_examples=6, deadline=None)
def test_lr_schedule_shape(schedule):
    from repro.train.optim import AdamWConfig, lr_at

    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule=schedule)
    lrs = [float(lr_at(cfg, jnp.int32(s))) for s in range(0, 101, 5)]
    assert all(0 <= v <= 1.0 for v in lrs)
    assert lrs[0] < lrs[2]  # warmup rises
    if schedule != "constant":
        assert lrs[-1] < max(lrs)  # decays from the peak
        assert lrs[-1] >= 0.099  # floor at ~10 % of peak
