"""Continuous-batching serve engine: equivalence, invariants, admission.

The continuous engine must be a pure scheduling change: same tokens as the
static engine on uniform workloads (bit-identical greedy), strictly better
slot occupancy on mixed ones, and no resource leaks (the allocator's
``free + live == batch_size`` invariant).  Also covers the static engine's
first-token key-split bugfix and the decode-shaped autotuner stats.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import registry as R
from repro.serve import (
    ContinuousEngine,
    Request,
    ServeEngine,
    SlotAllocator,
    generate_bucketed,
    sample_token,
)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def minicpm():
    cfg = get_smoke_config("minicpm-2b")
    api = R.build(cfg)
    params = api.init(KEY)
    return cfg, api, params


def _requests(cfg, rng, plens, max_news, **kw):
    return [
        Request(
            prompt=rng.integers(0, cfg.vocab_size, pl, dtype=np.int32),
            max_new_tokens=int(mn), **kw,
        )
        for pl, mn in zip(plens, max_news)
    ]


def _clone(reqs):
    return [
        Request(prompt=r.prompt.copy(), max_new_tokens=r.max_new_tokens,
                eos_id=r.eos_id, arrival_step=r.arrival_step)
        for r in reqs
    ]


# ---------------------------------------------------------------------------
# SlotAllocator.
# ---------------------------------------------------------------------------

def test_slot_allocator_invariant_and_reuse():
    alloc = SlotAllocator(3)
    rs = [Request(prompt=np.zeros(1, np.int32), max_new_tokens=1) for _ in range(4)]
    s0, s1, s2 = (alloc.admit(r) for r in rs[:3])
    assert {s0, s1, s2} == {0, 1, 2} and alloc.num_free == 0
    alloc.check()
    with pytest.raises(RuntimeError):
        alloc.admit(rs[3])
    assert alloc.release(s1) is rs[1]
    alloc.check()
    s3 = alloc.admit(rs[3])
    assert s3 == s1  # eviction-on-finish: the freed slot is reused
    alloc.check()
    assert len(alloc.live) + alloc.num_free == 3


# ---------------------------------------------------------------------------
# Continuous vs static equivalence.
# ---------------------------------------------------------------------------

def test_continuous_matches_static_greedy(minicpm):
    """Same-length prompts, same budgets: bit-identical greedy outputs."""
    cfg, api, params = minicpm
    rng = np.random.default_rng(0)
    reqs_s = _requests(cfg, rng, [8] * 4, [6] * 4)
    reqs_c = _clone(reqs_s)

    ServeEngine(api, batch_size=4, capacity=32).generate(params, reqs_s)
    ContinuousEngine(api, batch_size=4, capacity=32).serve(params, reqs_c)
    for a, b in zip(reqs_s, reqs_c):
        assert a.out_tokens == b.out_tokens
        assert b.done and b.ttft_s is not None and b.admitted_step == 0


def test_mixed_lengths_finish_all_no_slot_leak(minicpm):
    """Mixed prompt/output lengths: everything finishes, nothing leaks,
    strictly fewer slot-steps than the bucketed static baseline."""
    cfg, api, params = minicpm
    rng = np.random.default_rng(1)
    reqs = _requests(cfg, rng, [8, 16] * 5, rng.integers(2, 12, 10))
    clone = _clone(reqs)

    cont = ContinuousEngine(api, batch_size=4, capacity=32)
    cont.serve(params, reqs)
    cont.alloc.check()  # free + live == batch_size
    assert cont.alloc.num_free == cont.batch_size  # all slots returned
    assert all(r.done for r in reqs)
    assert all(1 <= len(r.out_tokens) <= r.max_new_tokens for r in reqs)
    assert cont.stats["finished"] == len(reqs)

    static = ServeEngine(api, batch_size=4, capacity=32)
    generate_bucketed(static, params, clone)
    assert cont.stats["slot_steps"] < static.stats["slot_steps"]
    # both engines generate the same token budget per request
    for a, b in zip(reqs, clone):
        assert len(a.out_tokens) == len(b.out_tokens)


def test_arrival_steps_delay_admission(minicpm):
    cfg, api, params = minicpm
    rng = np.random.default_rng(2)
    reqs = _requests(cfg, rng, [8] * 4, [3] * 4)
    for i, r in enumerate(reqs):
        r.arrival_step = 4 * i
    eng = ContinuousEngine(api, batch_size=2, capacity=32)
    eng.serve(params, reqs)
    assert all(r.done for r in reqs)
    for r in reqs:
        assert r.admitted_step >= r.arrival_step
        # TTFT is anchored at ARRIVAL, not serve() start: a late arrival
        # must not be charged for the wall time before it existed
        assert 0 <= r.ttft_s <= eng.stats["wall"]
    assert reqs[-1]._t_arrive > 0


# ---------------------------------------------------------------------------
# EOS / early stop + slot refill.
# ---------------------------------------------------------------------------

def test_eos_frees_slot_for_pending_request(minicpm):
    cfg, api, params = minicpm
    rng = np.random.default_rng(3)
    # learn what token greedy produces third, then use it as EOS
    probe = _requests(cfg, rng, [8], [12])
    ContinuousEngine(api, batch_size=2, capacity=64).serve(params, probe)
    eos = probe[0].out_tokens[2]

    # same prompt with that EOS stops early ...
    short = Request(prompt=probe[0].prompt.copy(), max_new_tokens=12, eos_id=eos)
    # ... and a queued request gets the freed slot while a long one runs
    longer = Request(prompt=probe[0].prompt.copy(), max_new_tokens=12)
    queued = Request(prompt=probe[0].prompt.copy(), max_new_tokens=2)
    eng = ContinuousEngine(api, batch_size=2, capacity=64)
    eng.serve(params, [longer, short, queued])
    assert short.out_tokens[-1] == eos
    assert short.out_tokens == probe[0].out_tokens[: probe[0].out_tokens.index(eos) + 1]
    assert queued.done and queued.admitted_step == short.finished_step + 1
    eng.alloc.check()


# ---------------------------------------------------------------------------
# Admission rejection.
# ---------------------------------------------------------------------------

def test_capacity_overflow_admission_rejected(minicpm):
    cfg, api, params = minicpm
    eng = ContinuousEngine(api, batch_size=2, capacity=16)
    bad = Request(prompt=np.zeros(16, np.int32), max_new_tokens=1)
    with pytest.raises(ValueError, match="admission rejected"):
        eng.serve(params, [bad])
    # the rejection happens before any state mutates: a valid workload
    # still runs on the same engine
    ok = _requests(cfg, np.random.default_rng(4), [8, 8], [2, 2])
    eng.serve(params, ok)
    assert all(r.done for r in ok)


def test_non_kv_family_rejected():
    cfg = get_smoke_config("mamba2-1.3b")
    api = R.build(cfg)
    assert api.decode_step_slots is None
    with pytest.raises(NotImplementedError, match="decode_step_slots"):
        ContinuousEngine(api, batch_size=2, capacity=16)


# ---------------------------------------------------------------------------
# Static-engine key-split bugfix (satellite): temperature > 0.
# ---------------------------------------------------------------------------

def test_static_first_token_key_is_split(minicpm):
    """The first sampled token must use a key SPLIT from the engine key, and
    the key must advance even for max_new == 1 batches (the old code reused
    the constructor key for every batch's first token)."""
    cfg, api, params = minicpm
    prompt = np.arange(8, dtype=np.int32)

    eng = ServeEngine(api, batch_size=1, capacity=32, temperature=1.0, seed=7)
    k0 = eng.key
    (r1,) = eng.generate(params, [Request(prompt=prompt.copy(), max_new_tokens=1)])
    assert not np.array_equal(np.asarray(eng.key), np.asarray(k0))

    # manual replication of the key discipline
    logits, _ = api.prefill(params, {"tokens": jnp.asarray(prompt[None])})
    _, sub = jax.random.split(jax.random.PRNGKey(7))
    want = int(sample_token(sub, logits, 1.0)[0])
    assert r1.out_tokens == [want]

    # two consecutive max_new==1 batches draw DIFFERENT first-token keys
    (r2,) = eng.generate(params, [Request(prompt=prompt.copy(), max_new_tokens=1)])
    key2, sub2 = jax.random.split(jax.random.split(jax.random.PRNGKey(7))[0])
    want2 = int(sample_token(sub2, logits, 1.0)[0])
    assert r2.out_tokens == [want2]


def test_temperature_seeded_determinism(minicpm):
    cfg, api, params = minicpm
    rng = np.random.default_rng(5)
    reqs = _requests(cfg, rng, [8] * 3, [6, 3, 5])
    a, b = _clone(reqs), _clone(reqs)
    ServeEngine(api, batch_size=4, capacity=32, temperature=0.8, seed=11).generate(params, a)
    ServeEngine(api, batch_size=4, capacity=32, temperature=0.8, seed=11).generate(params, b)
    assert [r.out_tokens for r in a] == [r.out_tokens for r in b]

    c, d = _clone(reqs), _clone(reqs)
    ContinuousEngine(api, batch_size=4, capacity=32, temperature=0.8, seed=11).serve(params, c)
    ContinuousEngine(api, batch_size=4, capacity=32, temperature=0.8, seed=11).serve(params, d)
    assert [r.out_tokens for r in c] == [r.out_tokens for r in d]


# ---------------------------------------------------------------------------
# Decode-shaped autotuner stats (EP dispatch pricing).
# ---------------------------------------------------------------------------

def test_decode_table_stats_shape_and_tuning():
    from types import SimpleNamespace

    from repro.core.autotune import decode_table_stats, tune_multiplexer

    from repro.models.moe import _ep_capacity

    cfg = get_smoke_config("olmoe-1b-7b")
    stats = decode_table_stats(cfg, batch_size=8, num_shards=4)
    # the tuner must price EXACTLY the capacity buffers the MoE layer ships:
    # rows == E * C with C from the layer's own sizing (shared ep_capacity)
    assert stats.rows == cfg.num_experts * _ep_capacity(cfg, 8 // 4, 4)
    assert stats.row_bytes == cfg.d_model * np.dtype(cfg.dtype).itemsize

    # tiny per-step messages: the tuner must NOT inherit chunking — launch
    # latency dominates, so it collapses to the unchunked transport
    mesh = SimpleNamespace(axis_names=("data", "model"), devices=np.empty((2, 4)))
    tuned = tune_multiplexer(mesh, [stats])
    assert tuned.pipeline_chunks == 1 and tuned.transport_chunks == 1


def test_moe_dispatch_slots_pallas_matches_xla():
    """The kernel-backed dispatch (mux pack_impl='pallas') is bit-identical
    to the one-hot reference, including non-block-multiple token counts
    (decode ships a handful of tokens per step)."""
    from repro.models.moe import _dispatch_slots

    for T, E, C in [(8, 8, 4), (300, 8, 7), (512, 16, 9)]:
        dest = jax.random.randint(jax.random.PRNGKey(T), (T,), 0, E, dtype=jnp.int32)
        sx, kx = _dispatch_slots(dest, E, C, "xla")
        sp, kp = _dispatch_slots(dest, E, C, "pallas")
        np.testing.assert_array_equal(np.asarray(sx), np.asarray(sp))
        np.testing.assert_array_equal(np.asarray(kx), np.asarray(kp))


def test_request_stats_populated(minicpm):
    cfg, api, params = minicpm
    rng = np.random.default_rng(6)
    reqs = _requests(cfg, rng, [8, 8, 16], [5, 8, 3])
    eng = ContinuousEngine(api, batch_size=2, capacity=32)
    eng.serve(params, reqs)
    for r in reqs:
        assert r.ttft_s is not None and r.ttft_s >= 0
        assert r.admitted_step is not None and r.finished_step is not None
        if len(r.out_tokens) > 1:
            assert r.decode_tok_s is not None and r.decode_tok_s > 0
    # engine aggregates are consistent
    assert eng.stats["slot_steps"] == eng.stats["decode_steps"] * eng.batch_size
    assert eng.stats["live_slot_steps"] <= eng.stats["slot_steps"]
    assert eng.stats["admitted"] == eng.stats["finished"] == len(reqs)


def test_serve_continuous_ep_pods_two_level():
    """num_pods=2 fake-device case: continuous == static greedy bit-identity
    with the EP dispatch routed through the two-level fabric.  The scenario
    needs 8 fake devices, so it runs in a fresh subprocess (pytest has
    already initialized jax; the fake-device flag must precede that)."""
    import os
    import subprocess
    import sys

    driver = os.path.join(os.path.dirname(__file__), "_multidev_driver.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, driver, "serve_continuous_ep_pods"],
        capture_output=True, text=True, timeout=420, env=env,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "PASS serve_continuous_ep_pods" in proc.stdout
