"""Multi-device integration tests (8 fake devices via subprocess).

The fake-device XLA flag must be set before jax initializes; pytest has
already imported jax by test time, so each scenario runs in a fresh
subprocess (tests/_multidev_driver.py).
"""

import os
import subprocess
import sys

import pytest

DRIVER = os.path.join(os.path.dirname(__file__), "_multidev_driver.py")

SCENARIOS = [
    "a2a_equiv",
    "streaming_consume",
    "hierarchical_psum",
    "hash_shuffle",
    "two_level_shuffle",
    "moe_ep",
    "sharded_train_equiv",
    "ckpt_elastic",
    "distributed_q17",
    "distributed_q14_q19",
    "distributed_q1_q6",
    "planner_new_queries",
    "tpch_pod_mesh_1proc",
    "decode_sharded_equiv",
    "serve_continuous_ep",
    "skewed_q17",
    "qserve_cached",
    "exchange_report",
    "oocore_streamed",
    "oocore_spill",
    "traced_query",
    "qserve_traced_mix",
]


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_multidevice(scenario):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, DRIVER, scenario],
        capture_output=True, text=True, timeout=420, env=env,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert f"PASS {scenario}" in proc.stdout
