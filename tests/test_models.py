"""Per-arch smoke tests + decode/prefill/forward equivalences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import layers as L
from repro.models import registry as R

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg, key=KEY):
    specs, _ = R.input_specs(cfg, C.ShapeSpec("t", S, B, "train"))
    batch = {}
    for k, s in specs.items():
        if s.dtype == jnp.int32:
            batch[k] = jax.random.randint(jax.random.fold_in(key, 1), s.shape, 0, cfg.vocab_size)
        else:
            batch[k] = jax.random.normal(jax.random.fold_in(key, 2), s.shape, s.dtype)
    return batch


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """Reduced same-family config: one forward/train step, shapes + no NaNs."""
    cfg = C.get_smoke_config(arch)
    api = R.build(cfg)
    params = api.init(KEY)
    loss = jax.jit(api.train_loss)(params, _batch(cfg))
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    grads = jax.grad(api.train_loss)(params, _batch(cfg))
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_arch_smoke_decode_step(arch):
    cfg = C.get_smoke_config(arch)
    api = R.build(cfg)
    params = api.init(KEY)
    cache = api.init_cache(B, S)
    toks = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = jax.jit(api.decode_step)(params, toks, cache, jnp.int32(3))
    assert logits.shape == (B, cfg.vocab_size), arch
    assert np.isfinite(np.asarray(logits)).all(), arch
    jax.tree.map(lambda a, b: (a.shape, b.shape), cache, new_cache)  # same structure


@pytest.mark.parametrize(
    "arch", ["qwen2.5-3b", "deepseek-v2-lite-16b", "mamba2-1.3b", "zamba2-7b", "whisper-medium"]
)
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the teacher-forced forward pass."""
    cfg = C.get_smoke_config(arch)
    api = R.build(cfg)
    params = api.init(KEY)
    batch = _batch(cfg)
    toks = batch["tokens"]

    if cfg.family == "encdec":
        from repro.models import whisper as W

        memory = W.encode(params, cfg, batch["frames"])
        h = W.decode_train(params, cfg, toks, memory)
        full = L.unembed(params["embedding"], cfg, h)
        _, cache = api.prefill(params, {"frames": batch["frames"], "tokens": toks[:, :1]})
        cache = jax.tree.map(
            lambda a, b: jnp.pad(a, [(0, w - h2) for h2, w in zip(a.shape, b.shape)]),
            cache, jax.eval_shape(lambda: api.init_cache(B, toks.shape[1])),
        )
        logits = None
        for t in range(toks.shape[1]):
            if t == 0:
                # cache already holds position 0 from the 1-token prefill
                logits = full[:, 0]
                continue
            logits, cache = api.decode_step(params, toks[:, t : t + 1], cache, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, -1]), rtol=3e-3, atol=3e-3
        )
        return

    mod = R._module(cfg)
    h = mod.forward(params, cfg, {"tokens": toks})
    full = L.unembed(params["embedding"], cfg, h)
    cache = api.init_cache(B, S + 2)
    logits = None
    for t in range(toks.shape[1]):
        logits, cache = api.decode_step(params, toks[:, t : t + 1], cache, jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full[:, -1]), rtol=3e-3, atol=3e-3
    )


@pytest.mark.parametrize("arch", ["minicpm-2b", "olmoe-1b-7b", "mamba2-1.3b"])
def test_prefill_matches_forward(arch):
    cfg = C.get_smoke_config(arch)
    api = R.build(cfg)
    params = api.init(KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    mod = R._module(cfg)
    full = L.unembed(params["embedding"], cfg, mod.forward(params, cfg, {"tokens": toks}))
    logits, _ = jax.jit(api.prefill)(params, {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3
    )


def test_chunked_attention_equals_sdpa():
    cfg = C.get_smoke_config("qwen2.5-3b").scaled(attn_impl="chunked", attn_q_block=4)
    cfg_ref = cfg.scaled(attn_impl="sdpa")
    api, api_ref = R.build(cfg), R.build(cfg_ref)
    params = api.init(KEY)
    b = _batch(cfg)
    np.testing.assert_allclose(
        float(api.train_loss(params, b)), float(api_ref.train_loss(params, b)), rtol=1e-5
    )


def test_mrope_sections_differ_from_rope():
    """M-RoPE with distinct t/h/w positions must change the result."""
    cfg = C.get_smoke_config("qwen2-vl-2b")
    api = R.build(cfg)
    params = api.init(KEY)
    b = _batch(cfg)
    S_tot = b["tokens"].shape[1] + b["patches"].shape[1]
    lin = jnp.arange(S_tot, dtype=jnp.int32)[None, :].repeat(B, 0)
    pos_same = jnp.broadcast_to(lin[None], (3, B, S_tot))
    pos_diff = jnp.stack([lin, lin // 2, lin % 7])
    mod = R._module(cfg)
    h1 = mod.forward(params, cfg, dict(b, positions=pos_same))
    h2 = mod.forward(params, cfg, dict(b, positions=pos_diff))
    assert not np.allclose(np.asarray(h1), np.asarray(h2))


def test_param_counts_match_published_sizes():
    expect = {
        "minicpm-2b": 2.7e9, "qwen2.5-3b": 3.1e9, "deepseek-67b": 67.4e9,
        "mamba2-1.3b": 1.4e9, "deepseek-v2-lite-16b": 15.7e9,
        "olmoe-1b-7b": 6.9e9, "zamba2-7b": 6.8e9, "whisper-medium": 0.8e9,
        "qwen2-vl-2b": 1.5e9,
    }
    for arch, want in expect.items():
        n = R.param_count(C.get_config(arch))
        assert abs(n - want) / want < 0.12, (arch, n, want)


def test_moe_active_params_smaller():
    for arch in ("olmoe-1b-7b", "deepseek-v2-lite-16b"):
        cfg = C.get_config(arch)
        assert R.param_count(cfg, active_only=True) < 0.45 * R.param_count(cfg)
