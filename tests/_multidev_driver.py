"""Multi-device test scenarios, run as a subprocess with 8 fake devices.

Invoked as:  python tests/_multidev_driver.py <scenario> [...]
(the XLA fake-device flag must be set before jax initializes, which pytest
cannot do in-process — the assignment forbids setting it globally).
Each scenario prints "PASS <name>" on success; any exception fails the run.
"""

import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.compat import shard_map  # noqa: E402
from repro.core import exchange  # noqa: E402
from repro.distributed.sharding import MeshContext, default_rules, mesh_context  # noqa: E402
from repro.launch.mesh import make_test_mesh  # noqa: E402
from repro.relational.context import ExecutionContext as Ctx  # noqa: E402


def _mesh1d():
    return make_test_mesh((8,), ("x",))


def scenario_a2a_equiv():
    """scheduled/one_factorization all-to-all == XLA all-to-all."""
    mesh = _mesh1d()
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 4))
    outs = {}
    for impl in ("xla", "round_robin", "one_factorization"):
        fn = shard_map(
            lambda x, impl=impl: exchange.all_to_all(x, "x", impl=impl),
            mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        )
        outs[impl] = np.asarray(jax.jit(fn)(x))
    np.testing.assert_allclose(outs["round_robin"], outs["xla"])
    np.testing.assert_allclose(outs["one_factorization"], outs["xla"])
    print("PASS a2a_equiv")


def scenario_streaming_consume():
    """scheduled_all_to_all_consume folds the same chunks as the full shuffle."""
    mesh = _mesh1d()
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 4))

    def full(x):
        return exchange.all_to_all(x, "x", impl="xla").sum(axis=0)

    def stream(x):
        # each folded chunk is one device's row [4]; accumulate elementwise
        return exchange.scheduled_all_to_all_consume(
            x, "x", lambda acc, chunk, src: acc + chunk,
            jnp.zeros((4,), x.dtype),
        )

    a = jax.jit(shard_map(full, mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
    b = jax.jit(shard_map(stream, mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    print("PASS streaming_consume")


def scenario_hierarchical_psum():
    mesh = make_test_mesh((2, 4), ("pod", "data"))
    g = jax.random.normal(jax.random.PRNGKey(2), (16, 3))

    def hier(g):
        return exchange.hierarchical_psum_tree({"g": g}, "data", "pod")["g"]

    def flat(g):
        return exchange.flat_psum_tree({"g": g}, ("pod", "data"))["g"]

    a = jax.jit(shard_map(hier, mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(("pod", "data"))))(g)
    b = jax.jit(shard_map(flat, mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(("pod", "data"))))(g)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)
    print("PASS hierarchical_psum")


def scenario_hash_shuffle():
    """Every valid row lands on the shard owning its hash; none lost."""
    mesh = _mesh1d()
    keys = jax.random.randint(jax.random.PRNGKey(3), (256,), 0, 10_000)
    rows = jnp.stack([keys, keys * 2], axis=1)

    def shuffle(keys, rows):
        out_rows, out_valid, dropped = exchange.hash_shuffle(
            keys, rows, "x", capacity=64
        )
        me = jax.lax.axis_index("x")
        h = exchange.fibonacci_hash(out_rows[:, 0].astype(jnp.uint32)) % jnp.uint32(8)
        ok = jnp.where(out_valid, h == me.astype(jnp.uint32), True).all()
        return out_valid.sum()[None], dropped, ok[None]

    fn = shard_map(shuffle, mesh=mesh, in_specs=(P("x"), P("x")),
                       out_specs=(P("x"), P(), P("x")))
    kept, dropped, ok = jax.jit(fn)(keys, rows)
    assert int(dropped) == 0, int(dropped)
    assert int(jnp.asarray(kept).sum()) == 256
    assert bool(jnp.asarray(ok).all())
    print("PASS hash_shuffle")


def scenario_moe_ep():
    """EP shard_map MoE == dense oracle, both transports."""
    from repro.configs.base import ModelConfig
    from repro.models import moe as M

    cfg = ModelConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=64, num_experts=16, top_k=4,
        moe_d_ff=48, capacity_factor=8.0, dtype="float32",
        moe_impl="ep_shardmap",
    )
    params = M.init_moe_layer(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
    dense = M.moe_dense(params, cfg, x)
    mesh = make_test_mesh((2, 4), ("data", "model"))
    for impl in ("round_robin", "xla"):
        ctx = MeshContext(mesh=mesh, rules=default_rules(False),
                          exchange_axis="model", exchange_impl=impl)
        with mesh_context(ctx):
            ep = jax.jit(lambda p, x: M.moe_ep(p, cfg.scaled(exchange_impl=impl), x))(params, x)
        np.testing.assert_allclose(np.asarray(ep), np.asarray(dense), rtol=2e-4, atol=2e-5)
    print("PASS moe_ep")


def scenario_serve_continuous_ep():
    """Continuous-batching decode with EP dispatch over the multiplexer.

    An expert-parallel MoE model served by the continuous engine on a
    (2, 4) mesh: the engine auto-tunes a CommMultiplexer for the
    decode-shaped expert messages (tiny -> unchunked scheduled transport)
    and the MoE layer ships its capacity buffers through it.  Greedy
    outputs must be bit-identical to the STATIC engine on the same mesh
    (same numerics family, same batch shapes), and a mixed-length workload
    must finish with no slot leak and fewer slot-steps.
    """
    from repro.configs import get_smoke_config
    from repro.models import registry as R
    from repro.serve import (
        ContinuousEngine, Request, ServeEngine, generate_bucketed,
    )

    cfg = get_smoke_config("olmoe-1b-7b").scaled(
        moe_impl="ep_shardmap", capacity_factor=8.0
    )
    api = R.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    mesh = make_test_mesh((2, 4), ("data", "model"))
    ctx = MeshContext(mesh=mesh, rules=default_rules(False),
                      exchange_axis="model", exchange_impl="round_robin")
    rng = np.random.default_rng(0)
    B, cap = 4, 48

    with mesh_context(ctx):
        same = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
                for _ in range(B)]
        reqs_s = [Request(prompt=p.copy(), max_new_tokens=5) for p in same]
        reqs_c = [Request(prompt=p.copy(), max_new_tokens=5) for p in same]
        se = ServeEngine(api, batch_size=B, capacity=cap)
        se.generate(params, reqs_s)
        ce = ContinuousEngine(api, batch_size=B, capacity=cap)
        assert ce.mux is not None, "EP engine must build a decode multiplexer"
        # decode-shaped stats: tiny messages -> no chunking
        assert ce.mux.pipeline_chunks == 1 and ce.mux.transport_chunks == 1, ce.mux
        ce.serve(params, reqs_c)
        for a, b in zip(reqs_s, reqs_c):
            assert a.out_tokens == b.out_tokens, (a.out_tokens, b.out_tokens)

        mixed = [
            Request(prompt=rng.integers(0, cfg.vocab_size, pl, dtype=np.int32),
                    max_new_tokens=int(mn))
            for pl, mn in zip([8, 16] * 4, rng.integers(2, 10, 8))
        ]
        mixed_c = [Request(prompt=r.prompt.copy(), max_new_tokens=r.max_new_tokens)
                   for r in mixed]
        se2 = ServeEngine(api, batch_size=B, capacity=cap)
        generate_bucketed(se2, params, mixed)
        ce2 = ContinuousEngine(api, batch_size=B, capacity=cap)
        ce2.serve(params, mixed_c)
        ce2.alloc.check()
        assert all(r.done for r in mixed_c)
        assert ce2.stats["slot_steps"] < se2.stats["slot_steps"], (
            ce2.stats, se2.stats
        )
    print("PASS serve_continuous_ep")


def scenario_serve_continuous_ep_pods():
    """Continuous vs static greedy decode on a num_pods=2 mesh: the EP
    dispatch crosses the pod boundary through the two-level fabric (the
    engine's auto-tuned multiplexer carries a two-level plan), and the
    continuous engine's greedy tokens are bit-identical to the static
    engine's — the same guarantee as the flat-mesh case, now with the
    exchange routed coarse-then-fine.
    """
    from repro.configs import get_smoke_config
    from repro.models import registry as R
    from repro.serve import ContinuousEngine, Request, ServeEngine

    cfg = get_smoke_config("olmoe-1b-7b").scaled(
        moe_impl="ep_shardmap", capacity_factor=8.0
    )
    api = R.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    # (pod, data, model): 2 pods x 4-way exchange = 8 joint EP units.
    # batch_size=8 keeps decode T divisible by the unit count — smaller
    # batches would silently fall back to the dense path and test nothing.
    mesh = make_test_mesh((2, 1, 4), ("pod", "data", "model"))
    ctx = MeshContext(mesh=mesh, rules=default_rules(True),
                      exchange_axis="model", pod_axis="pod",
                      exchange_impl="round_robin")
    rng = np.random.default_rng(0)
    B, cap = 8, 48

    with mesh_context(ctx):
        same = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
                for _ in range(B)]
        reqs_s = [Request(prompt=p.copy(), max_new_tokens=5) for p in same]
        reqs_c = [Request(prompt=p.copy(), max_new_tokens=5) for p in same]
        se = ServeEngine(api, batch_size=B, capacity=cap)
        se.generate(params, reqs_s)
        ce = ContinuousEngine(api, batch_size=B, capacity=cap)
        assert ce.mux is not None, "EP engine must build a decode multiplexer"
        assert ce.mux.plan.pod_axis == "pod" and ce.mux.plan.num_pods == 2, (
            "the decode multiplexer must carry the two-level plan", ce.mux.plan
        )
        ce.serve(params, reqs_c)
        ce.alloc.check()
        for a, b in zip(reqs_s, reqs_c):
            assert a.out_tokens == b.out_tokens, (a.out_tokens, b.out_tokens)
    print("PASS serve_continuous_ep_pods")


def scenario_sharded_train_equiv():
    """Sharded train step == single-device train step (same numbers)."""
    from repro.configs import get_smoke_config
    from repro.models import registry as R
    from repro.train import AdamWConfig, make_train_step
    from repro.train.step import TrainState, state_shardings

    cfg = get_smoke_config("qwen2.5-3b")
    api = R.build(cfg)
    key = jax.random.PRNGKey(0)
    state = TrainState.create(api, key)
    batch = {
        "tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
    }
    step = make_train_step(api, AdamWConfig(lr=1e-3))
    _, m_ref = jax.jit(step)(state, batch)

    mesh = make_test_mesh((4, 2), ("data", "model"))
    ctx = MeshContext(mesh=mesh, rules=default_rules(False),
                      exchange_axis="model", exchange_impl="round_robin")
    with mesh_context(ctx):
        sh = state_shardings(api, ctx)
        state_s = jax.device_put(state, sh)
        _, m_shard = jax.jit(step)(state_s, batch)
    np.testing.assert_allclose(
        float(m_ref["loss"]), float(m_shard["loss"]), rtol=1e-5
    )
    np.testing.assert_allclose(
        float(m_ref["grad_norm"]), float(m_shard["grad_norm"]), rtol=1e-4
    )
    print("PASS sharded_train_equiv")


def scenario_ckpt_elastic():
    """Save sharded on a (4,2) mesh, restore onto (2,4): elastic restart."""
    import tempfile
    from repro.checkpoint import save_checkpoint, restore_checkpoint
    from repro.distributed.sharding import logical_sharding

    mesh_a = make_test_mesh((4, 2), ("data", "model"))
    mesh_b = make_test_mesh((2, 4), ("data", "model"))
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    ctx_a = MeshContext(mesh=mesh_a, rules=default_rules(False))
    ctx_b = MeshContext(mesh=mesh_b, rules=default_rules(False))
    xa = jax.device_put(x, logical_sharding(x.shape, "batch", "d_ff", ctx=ctx_a))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, {"w": xa})
        shard_b = {"w": logical_sharding(x.shape, "batch", "d_ff", ctx=ctx_b)}
        restored = restore_checkpoint(d, None, {"w": jax.eval_shape(lambda: x)}, shard_b)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(x))
    assert restored["w"].sharding.spec == shard_b["w"].spec
    print("PASS ckpt_elastic")


def scenario_distributed_q17():
    """Paper's Fig 6 query distributed over 8 shards == numpy oracle."""
    from repro.relational import datagen, oracle
    from repro.relational.distributed import q17_distributed

    tabs = datagen.gen_all(0.01)
    got = q17_distributed(tabs["lineitem"], tabs["part"], Ctx(num_shards=8))
    want = oracle.q17_oracle(tabs["lineitem"], tabs["part"])
    np.testing.assert_allclose(float(got), want, rtol=1e-3)
    print("PASS distributed_q17")


def scenario_distributed_q14_q19():
    """Q14/Q19 over the partition+broadcast plan == numpy oracle."""
    from repro.relational import datagen, oracle
    from repro.relational.distributed import q14_distributed, q19_distributed

    tabs = datagen.gen_all(0.01)
    li, part = tabs["lineitem"], tabs["part"]
    got14 = float(q14_distributed(li, part, Ctx(num_shards=8)))
    np.testing.assert_allclose(got14, oracle.q14_oracle(li, part), rtol=1e-3)
    got19 = float(q19_distributed(li, part, Ctx(num_shards=8)))
    np.testing.assert_allclose(got19, oracle.q19_oracle(li, part), rtol=1e-3)
    print("PASS distributed_q14_q19")


def scenario_decode_sharded_equiv():
    """Sharded decode step == single-device decode step."""
    from repro.configs import get_smoke_config
    from repro.models import registry as R

    cfg = get_smoke_config("deepseek-67b")
    api = R.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache = api.init_cache(8, 32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 1), 0, cfg.vocab_size)
    logits_ref, _ = jax.jit(api.decode_step)(params, toks, cache, jnp.int32(5))

    mesh = make_test_mesh((2, 4), ("data", "model"))
    ctx = MeshContext(mesh=mesh, rules=default_rules(False))
    with mesh_context(ctx):
        logits_s, _ = jax.jit(api.decode_step)(params, toks, cache, jnp.int32(5))
    np.testing.assert_allclose(
        np.asarray(logits_ref), np.asarray(logits_s), rtol=2e-4, atol=2e-4
    )
    print("PASS decode_sharded_equiv")


def scenario_hash_shuffle_equiv():
    """hash_shuffle delivers the same rows per device across every transport
    (xla / round_robin / one_factorization), pack impl (xla / pallas) and
    pipeline chunking (1 / 4), on uniform and heavily skewed keys."""
    mesh = _mesh1d()
    rng = np.random.default_rng(0)
    uniform = rng.integers(0, 10_000, 256)
    skewed = np.where(rng.random(256) < 0.8, 7, rng.integers(0, 10_000, 256))
    for name, keys_np in (("uniform", uniform), ("skewed", skewed)):
        keys = jnp.asarray(keys_np, jnp.int32)
        rows = jnp.stack([keys, keys * 2 + 1], axis=1)
        baseline = None
        configs = [
            (impl, pack_impl, chunks, 1)
            for impl in ("xla", "round_robin", "one_factorization")
            for pack_impl in ("xla", "pallas")
            for chunks in (1, 4)
        ] + [("round_robin", "pallas", 4, 2)]  # + split-phase transport
        for impl, pack_impl, chunks, transport in configs:
            def shuffle(keys, rows, impl=impl, pack=pack_impl, ch=chunks,
                        tc=transport):
                return exchange.hash_shuffle(
                    keys, rows, "x", capacity=32, impl=impl,
                    pack_impl=pack, num_chunks=ch, transport_chunks=tc,
                )
            fn = shard_map(
                shuffle, mesh=mesh, in_specs=(P("x"), P("x")),
                out_specs=(P("x"), P("x"), P()),
                check_vma=False,  # no replication rule for pallas_call
            )
            r, v, d = jax.jit(fn)(keys, rows)
            assert int(d) == 0, (name, impl, pack_impl, chunks, int(d))
            r, v = np.asarray(r), np.asarray(v)
            per_dev = []
            for j in range(8):
                rows_j = r[j * 256:(j + 1) * 256][v[j * 256:(j + 1) * 256]]
                order = np.lexsort(rows_j.T)
                per_dev.append(rows_j[order])
            if baseline is None:
                baseline = per_dev
                assert sum(len(b) for b in baseline) == 256
            else:
                for j in range(8):
                    np.testing.assert_array_equal(
                        per_dev[j], baseline[j],
                        err_msg=f"{name}/{impl}/{pack_impl}/c{chunks}/dev{j}",
                    )
    print("PASS hash_shuffle_equiv")


def scenario_consume_equiv():
    """Streaming consume folds the same (chunk, src) pairs under every
    schedule as the materialize-then-fold xla baseline."""
    mesh = _mesh1d()
    x = jax.random.normal(jax.random.PRNGKey(4), (64, 4))

    def fold(acc, chunk, src):
        return acc + chunk * (jnp.float32(src) + 1.0)  # src-weighted: order-free

    def baseline(x):
        y = exchange.all_to_all(x, "x", impl="xla")
        acc = jnp.zeros((4,), x.dtype)
        for j in range(8):
            acc = fold(acc, y[j], j)
        return acc

    want = np.asarray(jax.jit(
        shard_map(baseline, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    )(x))
    for schedule in ("shift", "one_factorization"):
        def stream(x, schedule=schedule):
            return exchange.scheduled_all_to_all_consume(
                x, "x", fold, jnp.zeros((4,), x.dtype), schedule=schedule
            )
        got = np.asarray(jax.jit(
            shard_map(stream, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
        )(x))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6, err_msg=schedule)
    print("PASS consume_equiv")


def scenario_mux_schedule_fallback():
    """make_multiplexer downgrades one_factorization on odd-sized axes to the
    shift schedule instead of letting an invalid config reach trace time."""
    import warnings
    from repro.core.multiplexer import make_multiplexer

    mesh3 = jax.sharding.Mesh(np.asarray(jax.devices()[:3]), ("x",))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        mux = make_multiplexer(mesh3, impl="one_factorization")
    assert mux.impl == "round_robin", mux.impl
    assert any("one_factorization" in str(x.message) for x in w), [str(x.message) for x in w]

    x = jax.random.normal(jax.random.PRNGKey(5), (9, 4))
    got = np.asarray(jax.jit(shard_map(
        lambda x: mux.all_to_all(x, "x"), mesh=mesh3, in_specs=P("x"), out_specs=P("x")
    ))(x))
    want = np.asarray(jax.jit(shard_map(
        lambda x: exchange.all_to_all(x, "x", impl="xla"),
        mesh=mesh3, in_specs=P("x"), out_specs=P("x"),
    ))(x))
    np.testing.assert_allclose(got, want)

    mesh4 = jax.sharding.Mesh(np.asarray(jax.devices()[:4]), ("x",))
    mux4 = make_multiplexer(mesh4, impl="one_factorization")
    assert mux4.impl == "one_factorization", mux4.impl
    print("PASS mux_schedule_fallback")


def scenario_autotune_mux():
    """An auto-tuned multiplexer (knobs from the topology cost model, no
    hand-set values) shuffles identically to the monolithic-XLA baseline,
    and empirical refinement picks a measured winner on the live mesh."""
    from repro.core.autotune import TableStats, tune_multiplexer
    from repro.core.multiplexer import make_multiplexer

    mesh = _mesh1d()
    rows_per_dev = 64
    stats = TableStats(rows=rows_per_dev, row_bytes=8)
    mux = make_multiplexer(mesh, auto=True, table_stats=stats)
    assert mux.pipeline_chunks >= 1 and mux.transport_chunks >= 1
    assert mux.impl in ("xla", "round_robin", "one_factorization")

    keys = jax.random.randint(jax.random.PRNGKey(7), (8 * rows_per_dev,), 0, 10_000)
    rows = jnp.stack([keys, keys * 3 + 1], axis=1).astype(jnp.int32)

    def shuffle(mux):
        def body(k, r):
            return mux.hash_shuffle(k, r, "x", capacity=rows_per_dev)
        return jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P("x"), P("x")),
            out_specs=(P("x"), P("x"), P()), check_vma=False,
        ))

    r_auto, v_auto, d_auto = shuffle(mux)(keys.astype(jnp.int32), rows)
    base = make_multiplexer(mesh, impl="xla", pack_impl="xla")
    r_ref, v_ref, d_ref = shuffle(base)(keys.astype(jnp.int32), rows)
    assert int(d_auto) == 0 and int(d_ref) == 0
    for j in range(8):
        sl = slice(j * 8 * rows_per_dev, (j + 1) * 8 * rows_per_dev)
        got = np.asarray(r_auto)[sl][np.asarray(v_auto)[sl]]
        want = np.asarray(r_ref)[sl][np.asarray(v_ref)[sl]]
        np.testing.assert_array_equal(
            got[np.lexsort(got.T)], want[np.lexsort(want.T)], err_msg=f"dev{j}"
        )

    refined = tune_multiplexer(mesh, stats, refine=True, refine_top_k=2)
    assert refined.measured_s is not None and refined.measured_s > 0
    print("PASS autotune_mux")


def scenario_two_level_shuffle():
    """hash_shuffle_two_level on a (2, 4) pod mesh delivers each row to the
    same device as a flat hash % 8 shuffle over a joint 8-way axis — for
    every transport/pack combination, including skewed keys."""
    pod_mesh = make_test_mesh((2, 4), ("pod", "q"))
    flat_mesh = _mesh1d()
    rng = np.random.default_rng(42)
    for name, keys_np in (
        ("uniform", rng.integers(0, 10_000, 256)),
        ("skewed", np.where(rng.random(256) < 0.8, 7,
                            rng.integers(0, 10_000, 256))),
    ):
        keys = jnp.asarray(keys_np, jnp.int32)
        rows = jnp.stack([keys, keys * 5 + 3], axis=1)

        def flat(k, r):
            return exchange.hash_shuffle(k, r, "x", capacity=32)

        fr, fv, fd = jax.jit(shard_map(
            flat, mesh=flat_mesh, in_specs=(P("x"), P("x")),
            out_specs=(P("x"), P("x"), P()),
        ))(keys, rows)
        assert int(fd) == 0

        def want_rows(j):
            r, v = np.asarray(fr), np.asarray(fv)
            rows_j = r[j * 256:(j + 1) * 256][v[j * 256:(j + 1) * 256]]
            return rows_j[np.lexsort(rows_j.T)]

        for impl, pack_impl, chunks in (
            ("xla", "xla", 1), ("round_robin", "xla", 1),
            ("round_robin", "pallas", 4), ("one_factorization", "xla", 2),
        ):
            def two(k, r, impl=impl, pack=pack_impl, ch=chunks):
                return exchange.hash_shuffle_two_level(
                    k, r, "q", "pod", capacity=32, impl=impl,
                    pack_impl=pack, num_chunks=ch,
                )
            tr, tv, td = jax.jit(shard_map(
                two, mesh=pod_mesh, in_specs=(P(("pod", "q")), P(("pod", "q"))),
                out_specs=(P(("pod", "q")), P(("pod", "q")), P()),
                check_vma=False,
            ))(keys, rows)
            assert int(td) == 0, (name, impl, pack_impl, chunks, int(td))
            tr, tv = np.asarray(tr), np.asarray(tv)
            # device (pod p, inner i) = flat device p*4 + i; each holds
            # [4 * 2 * 32] = 256 output slots
            for j in range(8):
                rows_j = tr[j * 256:(j + 1) * 256][tv[j * 256:(j + 1) * 256]]
                got = rows_j[np.lexsort(rows_j.T)]
                np.testing.assert_array_equal(
                    got, want_rows(j),
                    err_msg=f"{name}/{impl}/{pack_impl}/c{chunks}/dev{j}",
                )

    # float32 rows with int32 keys: hop 1 cannot fold the keys into the row
    # matrix (dtype mismatch) and takes the separate-buffers path
    keys = jnp.asarray(rng.integers(0, 10_000, 256), jnp.int32)
    frows = jnp.stack([keys * 1.5, keys * 0.25], axis=1).astype(jnp.float32)
    fr, fv, fd = jax.jit(shard_map(
        lambda k, r: exchange.hash_shuffle(k, r, "x", capacity=32),
        mesh=flat_mesh, in_specs=(P("x"), P("x")),
        out_specs=(P("x"), P("x"), P()),
    ))(keys, frows)
    tr, tv, td = jax.jit(shard_map(
        lambda k, r: exchange.hash_shuffle_two_level(
            k, r, "q", "pod", capacity=32
        ),
        mesh=pod_mesh, in_specs=(P(("pod", "q")), P(("pod", "q"))),
        out_specs=(P(("pod", "q")), P(("pod", "q")), P()), check_vma=False,
    ))(keys, frows)
    assert int(fd) == 0 and int(td) == 0
    fr, fv, tr, tv = map(np.asarray, (fr, fv, tr, tv))
    for j in range(8):
        a = fr[j * 256:(j + 1) * 256][fv[j * 256:(j + 1) * 256]]
        b = tr[j * 256:(j + 1) * 256][tv[j * 256:(j + 1) * 256]]
        np.testing.assert_array_equal(
            a[np.lexsort(a.T)], b[np.lexsort(b.T)], err_msg=f"float/dev{j}"
        )
    print("PASS two_level_shuffle")


def scenario_tpch_pod_mesh_1proc():
    """TPC-H on a two-level (2 pods x 4) mesh — single process, fake DCI:
    Q17 matches the oracle under BOTH cross-pod build-side strategies, and
    Q3's two chained two-level exchanges + cross-pod top-k combine match the
    single-pod run exactly."""
    from repro.relational import datagen, oracle
    from repro.relational.distributed import q3_distributed, q17_distributed

    tabs = datagen.gen_all(0.01)
    li, pt = tabs["lineitem"], tabs["part"]
    want17 = oracle.q17_oracle(li, pt)
    for cross_pod in ("broadcast", "reshard"):
        got = q17_distributed(
            li, pt, Ctx(num_shards=8, num_pods=2, impl="round_robin",
                        pack_impl="pallas", cross_pod=cross_pod),
        )
        np.testing.assert_allclose(float(got), want17, rtol=1e-3,
                                   err_msg=cross_pod)

    flat = q3_distributed(tabs["customer"], tabs["orders"], li, Ctx(num_shards=8))
    pod = q3_distributed(tabs["customer"], tabs["orders"], li,
                         Ctx(num_shards=8, num_pods=2))
    for k in flat:
        np.testing.assert_array_equal(np.asarray(flat[k]), np.asarray(pod[k]),
                                      err_msg=k)
    print("PASS tpch_pod_mesh_1proc")


def scenario_distributed_q1_q6():
    """Q1/Q6 (the no-network queries, paper Fig 11) over 8 shards match the
    numpy oracle, on both the flat mesh and a (2 pods x 4) two-level mesh —
    and the pod run equals the flat run exactly."""
    from repro.relational import datagen, oracle
    from repro.relational.distributed import q1_distributed, q6_distributed

    tabs = datagen.gen_all(0.01)
    li = tabs["lineitem"]
    want1 = oracle.q1_oracle(li)
    want6 = oracle.q6_oracle(li)
    flat1 = q1_distributed(li, Ctx(num_shards=8))
    for k in want1:
        np.testing.assert_allclose(np.asarray(flat1[k]), want1[k], rtol=1e-4,
                                   err_msg=k)
    pod1 = q1_distributed(li, Ctx(num_shards=8, num_pods=2))
    for k in flat1:
        np.testing.assert_allclose(np.asarray(flat1[k]), np.asarray(pod1[k]),
                                   rtol=1e-6, err_msg=f"pod/{k}")
    flat6 = float(q6_distributed(li, Ctx(num_shards=8)))
    np.testing.assert_allclose(flat6, want6, rtol=1e-4)
    pod6 = float(q6_distributed(li, Ctx(num_shards=8, num_pods=2)))
    np.testing.assert_allclose(pod6, flat6, rtol=1e-6)
    print("PASS distributed_q1_q6")


def scenario_planner_new_queries():
    """The plan-only queries (Q4/Q12/Q18 — no hand-written distributed
    version exists) over 8 shards match the numpy oracle, and Q18 on a
    (2 pods x 4) two-level mesh equals the flat run exactly."""
    from repro.relational import datagen, oracle
    from repro.relational.distributed import (
        q4_distributed, q12_distributed, q18_distributed,
    )

    tabs = datagen.gen_all(0.01)
    li, od, cu = tabs["lineitem"], tabs["orders"], tabs["customer"]

    got4 = q4_distributed(li, od, Ctx(num_shards=8))
    want4 = oracle.q4_oracle(li, od)
    assert want4.sum() > 0
    np.testing.assert_allclose(np.asarray(got4["order_count"]), want4)

    got12 = q12_distributed(li, od, Ctx(num_shards=8))
    want12 = oracle.q12_oracle(li, od)
    np.testing.assert_allclose(got12["high_line_count"],
                               want12["high_line_count"])
    np.testing.assert_allclose(got12["low_line_count"],
                               want12["low_line_count"])

    got18 = q18_distributed(li, od, cu, Ctx(num_shards=8))
    want18 = oracle.q18_oracle(li, od, cu)
    assert len(want18["o_orderkey"]) > 0
    got_map = {int(k): (int(tp), float(sq)) for k, tp, sq in zip(
        got18["o_orderkey"], got18["o_totalprice"], got18["sum_qty"])}
    want_map = {int(k): (int(tp), float(sq)) for k, tp, sq in zip(
        want18["o_orderkey"], want18["o_totalprice"], want18["sum_qty"])}
    assert got_map == want_map, (got_map, want_map)

    pod18 = q18_distributed(li, od, cu, Ctx(num_shards=8, num_pods=2))
    for k in got18:
        np.testing.assert_array_equal(
            np.asarray(got18[k]), np.asarray(pod18[k]), err_msg=f"pod/{k}"
        )
    print("PASS planner_new_queries")


def scenario_tpch_pack_equiv():
    """Scheduled transport + Pallas fused pack matches the monolithic-XLA
    baseline bit-exactly on the TPC-H join queries (Q17 and Q3)."""
    from repro.relational import datagen
    from repro.relational.distributed import q17_distributed, q3_distributed

    tabs = datagen.gen_all(0.01)
    a17 = q17_distributed(tabs["lineitem"], tabs["part"],
                          Ctx(num_shards=8, impl="xla", pack_impl="xla"))
    b17 = q17_distributed(tabs["lineitem"], tabs["part"],
                          Ctx(num_shards=8, impl="round_robin",
                              pack_impl="pallas"))
    np.testing.assert_array_equal(np.asarray(a17), np.asarray(b17))

    a3 = q3_distributed(tabs["customer"], tabs["orders"], tabs["lineitem"],
                        Ctx(num_shards=8, impl="xla", pack_impl="xla"))
    b3 = q3_distributed(tabs["customer"], tabs["orders"], tabs["lineitem"],
                        Ctx(num_shards=8, impl="round_robin",
                            pack_impl="pallas"))
    for k in a3:
        np.testing.assert_array_equal(np.asarray(a3[k]), np.asarray(b3[k]))
    print("PASS tpch_pack_equiv")


def scenario_skewed_q17():
    """The adaptive-optimizer acceptance scenario (paper §3.1): Zipf(1.2)
    ``l_partkey`` over 8 shards.  Stats flip Q17's shared lineitem shuffle
    to the salted repartitioning; the executor measures per-shard load at
    the exchange and reports it.  Asserts: salted matches the oracle with
    zero drops, the measured max/fair-share of the salted route stays
    strictly below the unsalted one (< 1.3 vs > 2), and uniform data
    through the SAME salted plan keeps the plain route (runtime gate)."""
    from repro.relational import datagen, oracle
    from repro.relational import stats as rstats
    from repro.relational.planner import executor, tpch

    tabs = datagen.gen_all(0.01, zipf_partkey=1.2)
    # brand/container of partkey 0, the heaviest Zipf key (~22% of rows):
    # the semi-join keeps it, so the shuffle actually sees the skew
    pq = tpch.q17(brand=11, container=25)
    want = oracle.q17_oracle(tabs["lineitem"], tabs["part"], 11, 25)
    assert want > 0
    catalog = {t: tabs[t].capacity for t in pq.tables}
    stats = rstats.collect_stats({t: tabs[t] for t in pq.tables})

    salted_plan = pq.plan(catalog, 8, stats=stats)
    assert "salted x" in salted_plan.explain()
    run = executor.compile_plan(salted_plan, tabs)
    raw, qt = run.collect(run.dispatch())  # collect raises on dropped rows
    got = pq.finalize(raw)
    np.testing.assert_allclose(float(got), want, rtol=1e-3)
    (edge,) = qt.edges
    assert edge.salted
    plain_over = float(edge.plain_overload)
    salted_over = float(edge.overload)
    assert plain_over > 2.0, plain_over
    assert salted_over < 1.3, salted_over
    assert salted_over < plain_over

    # the static plan routes plain and eats the full overload
    run0 = executor.compile_plan(pq.plan(catalog, 8), tabs)
    raw0, qt0 = run0.collect(run0.dispatch())
    got0 = pq.finalize(raw0)
    np.testing.assert_allclose(float(got0), want, rtol=1e-3)
    (edge0,) = qt0.edges
    assert float(edge0.overload) == plain_over

    # runtime gate: a salted PLAN on balanced data keeps the plain route.
    # Q17's shuffle sits behind the semi-join (2 surviving keys are
    # legitimately imbalanced even uniform), so the gate is shown on
    # Q18's scan-fed group-by exchange instead: plan from Zipf orderkeys,
    # execute on uniform ones.
    pq18 = tpch.q18()
    z18 = datagen.gen_all(0.01, zipf_orderkey=1.5)
    cat18 = {t: z18[t].capacity for t in pq18.tables}
    plan18 = pq18.plan(
        cat18, 8, stats=rstats.collect_stats({t: z18[t] for t in pq18.tables})
    )
    assert "salted x" in plan18.explain()
    uni = datagen.gen_all(0.01)
    run_u = executor.compile_plan(plan18, uni)
    raw_u, qt_u = run_u.collect(run_u.dispatch())
    got_u = pq18.finalize(raw_u)
    want_u = oracle.q18_oracle(uni["lineitem"], uni["orders"], uni["customer"])
    for k in want_u:
        np.testing.assert_allclose(
            np.asarray(got_u[k]), np.asarray(want_u[k]), rtol=1e-3
        )
    edge_u = next(e for e in qt_u.edges if "l_orderkey" in e.key)
    assert not edge_u.salted
    assert float(edge_u.plain_overload) < 1.5
    print("PASS skewed_q17")


def scenario_qserve_cached():
    """The query-serving engine on the real 8-device mesh: all nine TPC-H
    templates served cold then warm through one QueryServeEngine.  The
    warm pass makes ZERO ``plan_physical`` calls (plan cache) and zero
    retraces (executor memo), returns results bit-identical to the cold
    pass, and spot-checked queries are bit-identical to a solo
    ``compile_plan`` run sharing the engine's multiplexer.  The slot
    invariant holds after every drain."""
    from repro.relational import datagen
    from repro.relational.planner import executor, tpch
    from repro.relational.planner.physical import plan_physical
    from repro.relational.planner.plan_cache import PlanCache
    from repro.serve import QueryRequest, QueryServeEngine

    tabs = datagen.gen_all(0.01)
    templates = [make() for make in tpch.ALL_QUERIES.values()]
    names = sorted({t for pq in templates for t in pq.tables})
    tables = {name: tabs[name] for name in names}
    engine = QueryServeEngine(
        tables, Ctx(num_shards=8), num_slots=3, cache=PlanCache(),
        templates=templates,
    )
    cold = engine.serve([QueryRequest("t", pq) for pq in templates])
    engine.alloc.check()
    assert engine.alloc.num_free == 3 and not engine.alloc.live

    before = plan_physical.calls
    warm = engine.serve([QueryRequest("t", pq) for pq in templates])
    assert plan_physical.calls == before, "warm path replanned"
    assert all(r.plan_cache_hit and r.executor_cache_hit for r in warm)
    engine.alloc.check()

    def eq(a, b):
        la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
        return len(la) == len(lb) and all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(la, lb)
        )

    by_name = {r.query.name: r.result for r in cold}
    for r in warm:
        assert eq(r.result, by_name[r.query.name]), r.query.name
    # solo run, same mux: the engine changes scheduling, never bytes
    for qname in ("q3", "q17"):
        pq = next(p for p in templates if p.name == qname)
        plan = pq.plan({t: tables[t].capacity for t in pq.tables}, 8)
        run = executor.compile_plan(plan, tables, mux=engine._mux)
        assert eq(pq.finalize(run()), by_name[qname]), qname
    print("PASS qserve_cached")


def scenario_exchange_report():
    """Exchange reports are comparable across plan lifecycles: a cold Q3
    run, a replanned run, and a run from an UNPICKLED cached plan emit
    identical report keys (``shuffle[col]#ordinal``) AND identical values
    on the 8-device mesh — the regression that display-index keys broke."""
    import pickle

    from repro.relational import datagen
    from repro.relational.planner import executor, tpch

    tabs = datagen.gen_all(0.01)
    pq = tpch.q3()
    tables = {t: tabs[t] for t in pq.tables}
    catalog = {t: tables[t].capacity for t in pq.tables}

    plan_cold = pq.plan(catalog, 8)
    plan_re = pq.plan(catalog, 8)          # fresh replan, new identities
    plan_disk = pickle.loads(pickle.dumps(plan_cold))  # cached reload

    reports = []
    results = []
    for plan in (plan_cold, plan_re, plan_disk):
        run = executor.compile_plan(plan, tables)
        raw, qt = run.collect(run.dispatch())
        results.append(pq.finalize(raw))
        reports.append(qt.exchange_report())

    base = reports[0]
    assert set(base) == {"shuffle[o_orderkey]#0", "shuffle[l_orderkey]#1"}
    for rep in reports[1:]:
        assert list(rep) == list(base), (list(rep), list(base))
        for k in base:
            for field in base[k]:
                np.testing.assert_array_equal(
                    np.asarray(base[k][field]), np.asarray(rep[k][field]),
                    err_msg=f"{k}.{field} differs across plan lifecycles",
                )
    for got in results[1:]:
        for k in results[0]:
            np.testing.assert_array_equal(
                np.asarray(results[0][k]), np.asarray(got[k])
            )
    print("PASS exchange_report")


def _streamed_vs_resident(pq, sources, ctx):
    from repro.relational.planner.executor import execute_plan
    from repro.relational.planner.stream import compile_plan_streamed

    mat = {t: sources[t].materialize() for t in pq.tables}
    catalog = {t: sources[t].capacity for t in pq.tables}
    plan = pq.plan(catalog, ctx.num_shards)
    oracle = pq.finalize(execute_plan(plan, mat))
    run = compile_plan_streamed(plan, sources, ctx)
    return oracle, pq.finalize(run()), run.stats, plan


def _assert_close(oracle, got):
    if not isinstance(oracle, dict):
        oracle, got = {"r": oracle}, {"r": got}
    for k in oracle:
        o, g = np.asarray(oracle[k]), np.asarray(got[k])
        if o.dtype.kind == "f":
            np.testing.assert_allclose(g, o, rtol=1e-3, err_msg=k)
        else:
            np.testing.assert_array_equal(g, o, err_msg=k)


def scenario_oocore_streamed():
    """Q17/Q18 morsel-streamed over 8 shards == in-memory run, same mesh.

    The streamed table is chunked so only one morsel's shard slice is
    device-resident at a time; a device_row_budget below the full table
    capacity proves the in-memory path could not have run."""
    from repro.relational import datagen
    from repro.relational.planner.tpch import q17, q18
    from repro.relational.source import MorselView, as_source

    tabs = datagen.gen_all(0.01)
    li = tabs["lineitem"]
    budget = li.capacity // 2
    ctx = Ctx(num_shards=8, device_row_budget=budget)
    assert li.capacity > budget

    src17 = {"lineitem": MorselView(li, morsel_rows=4096),
             "part": as_source(tabs["part"])}
    oracle, got, stats, _ = _streamed_vs_resident(q17(), src17, ctx)
    _assert_close(oracle, got)
    assert stats["passes"] == 2 and stats["spilled_rows"] == 0

    src18 = {"lineitem": MorselView(li, morsel_rows=4096),
             "orders": as_source(tabs["orders"]),
             "customer": as_source(tabs["customer"])}
    oracle, got, stats, _ = _streamed_vs_resident(q18(), src18, ctx)
    _assert_close(oracle, got)
    assert len(np.asarray(got["o_orderkey"]))  # non-vacuous top-k
    print("PASS oocore_streamed")


def scenario_oocore_spill():
    """Forced exchange overflow: without spill the run raises; with
    ``spill=True`` the overflow lands in the host overflow partition, drains
    back through the same exchange, and the result matches the no-pressure
    run bit-for-bit."""
    from repro.relational import datagen
    from repro.relational.planner.stream import compile_plan_streamed
    from repro.relational.planner.tpch import q18
    from repro.relational.source import MorselView, as_source

    tabs = datagen.gen_all(0.01)
    pq = q18()
    sources = {"lineitem": MorselView(tabs["lineitem"], morsel_rows=4096),
               "orders": as_source(tabs["orders"]),
               "customer": as_source(tabs["customer"])}
    oracle, got, stats, plan = _streamed_vs_resident(
        pq, sources, Ctx(num_shards=8))
    _assert_close(oracle, got)
    assert stats["spilled_rows"] == 0

    # Q18 shuffles the unfiltered lineitem stream by l_orderkey: a 16-row
    # message capacity guarantees overflow on every morsel.
    try:
        compile_plan_streamed(
            plan, sources, Ctx(num_shards=8, exchange_rows=16))()
    except RuntimeError as e:
        assert "dropped" in str(e), e
    else:
        raise AssertionError("overflow without spill must raise")

    run = compile_plan_streamed(
        plan, sources, Ctx(num_shards=8, exchange_rows=16, spill=True))
    spilled = pq.finalize(run())
    assert run.stats["spilled_rows"] > 0, run.stats
    assert run.stats["drain_rounds"] > 0, run.stats
    for k in oracle:
        np.testing.assert_array_equal(
            np.asarray(spilled[k]), np.asarray(oracle[k]), err_msg=k)
    print("PASS oocore_spill")


def scenario_traced_query():
    """The telemetry-spine acceptance run: ONE traced streamed Q17 over 8
    shards yields a Perfetto-loadable trace whose spans cover
    plan/compile/pass/morsel/exchange, whose per-edge measured wire bytes
    sit inside the 2x byte-model bound with a model-error ratio reported
    per edge — and tracing observes without perturbing: the result is
    bit-identical to the untraced run and planning happened exactly as
    often (the trace knob is payload, not identity)."""
    import json

    from repro.obs.export import chrome_trace_events, tracer_to_dict
    from repro.obs.model_check import assert_bytes_within, model_report
    from repro.obs.trace import Tracer
    from repro.relational import datagen
    from repro.relational import stats as rstats
    from repro.relational.context import StatsMode
    from repro.relational.planner import tpch
    from repro.relational.planner.physical import plan_physical

    tabs = datagen.gen_all(0.01)
    pq = tpch.q17()
    tables = {t: tabs[t] for t in pq.tables}
    base = Ctx(
        num_shards=8, morsel_rows=4096,
        stats_mode=StatsMode.PROFILE,
        stats_profile=rstats.collect_stats(tables),
    )
    before = plan_physical.calls
    want = tpch.run_query(pq, tables, base)            # tracing OFF
    per_run = plan_physical.calls - before

    tracer = Tracer()
    traced = base.with_(trace=tracer)
    assert traced == base and hash(traced) == hash(base)  # same cache keys
    got = tpch.run_query(pq, tables, traced)           # tracing ON
    assert plan_physical.calls - before == 2 * per_run, "tracing replanned"
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # the span hierarchy is complete: plan -> compile -> execute, with the
    # streamed runner's pass/morsel spans and per-edge exchange spans inside
    fams = {s.name.split(":")[0]
            for root in tracer.spans for s in root.walk()}
    assert {"plan", "compile", "execute", "pass", "morsel",
            "exchange"} <= fams, fams

    # one QueryTrace, a model-error ratio per edge, bytes inside the gate
    (qt,) = tracer.query_traces
    assert qt.query == "q17" and qt.edges
    rep = model_report(qt)
    assert set(rep["edges"]) == {e.key for e in qt.edges}
    assert all(v["byte_model_err"] is not None for v in rep["edges"].values())
    assert_bytes_within(qt)  # the same 2x bound CI gates

    # Perfetto-loadable: jsonable, B/E matched per track, sorted timestamps
    json.dumps(tracer_to_dict(tracer, process_name="driver"))
    dur = [e for e in chrome_trace_events(tracer) if e["ph"] in ("B", "E")]
    assert [e["ts"] for e in dur] == sorted(e["ts"] for e in dur)
    depth = 0
    for e in dur:
        depth += 1 if e["ph"] == "B" else -1
        assert depth >= 0
    assert depth == 0 and len(dur) >= 2 * 6
    print("PASS traced_query")


def scenario_qserve_traced_mix():
    """The exchange-report race, fixed at the source: one serve round
    running Q3 and Q17 through MEMOIZED executors returns a per-request
    QueryTrace that carries its OWN query's edges.  The old
    ``run.exchange_report`` function attribute was clobbered by whichever
    overlapped run finalized last — under the engine's async dispatch a Q3
    request could read Q17's report."""
    from repro.obs.trace import Tracer
    from repro.relational import datagen
    from repro.relational.planner import tpch
    from repro.relational.planner.plan_cache import PlanCache
    from repro.serve import QueryRequest, QueryServeEngine

    tabs = datagen.gen_all(0.01)
    templates = [tpch.q3(), tpch.q17()]
    names = sorted({t for pq in templates for t in pq.tables})
    tracer = Tracer()
    engine = QueryServeEngine(
        {n: tabs[n] for n in names}, Ctx(num_shards=8, trace=tracer),
        num_slots=2, cache=PlanCache(), templates=templates,
    )
    # two interleaved copies of each template: every round overlaps a Q3
    # and a Q17 through the same memoized runners
    done = engine.serve(
        [QueryRequest("t", pq) for _ in range(2) for pq in templates]
    )
    expect = {
        "q3": {"shuffle[o_orderkey]#0", "shuffle[l_orderkey]#1"},
        "q17": {"shuffle[l_partkey]#0"},
    }
    for r in done:
        assert r.trace is not None and r.trace.query == r.query.name
        assert {e.key for e in r.trace.edges} == expect[r.query.name], (
            r.query.name, [e.key for e in r.trace.edges],
        )
    assert len(tracer.query_traces) == len(done) == 4
    cats = {s.cat for root in tracer.spans for s in root.walk()}
    assert "serve" in cats, cats
    print("PASS qserve_traced_mix")


SCENARIOS = {
    name.removeprefix("scenario_"): fn
    for name, fn in list(globals().items())
    if name.startswith("scenario_")
}

if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    names = SCENARIOS if which == "all" else [which]
    for n in names:
        SCENARIOS[n]()
