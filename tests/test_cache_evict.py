"""LRU eviction of the on-disk plan cache (``REPRO_PLAN_CACHE_MAX``).

A long-lived cache dir shared by many query templates must not grow
without bound: ``max_entries`` caps the ``plan-*.pkl`` count, evicting by
mtime — effectively least-recently-USED, because ``lookup`` touches the
file on every disk hit.  The just-inserted entry is shielded (``keep``)
so the cap can never evict the plan the caller is about to rely on, and
eviction races with concurrent processes are benign: a loser just
replans.
"""

import os
import subprocess
import sys

from repro.relational.planner.physical import plan_physical
from repro.relational.planner.plan_cache import PlanCache, plan_key
from repro.relational.planner.tpch import ALL_QUERIES

NODE = ALL_QUERIES["q6"]().logical


def _key(rows: int):
    """Distinct catalogs -> distinct cache keys for the same template."""
    return plan_key(NODE, {"lineitem": rows}, 8)


def _plan():
    return plan_physical(NODE, {"lineitem": 8192}, 8, name="q6")


def _entries(cache_dir) -> list:
    return sorted(
        n for n in os.listdir(cache_dir)
        if n.startswith("plan-") and n.endswith(".pkl")
    )


def _set_mtime(cache_dir, digest: str, t: float) -> None:
    """Pin an entry's recency (the filesystem's own stamps are too coarse
    to order back-to-back inserts deterministically)."""
    os.utime(os.path.join(cache_dir, f"plan-{digest}.pkl"), (t, t))


T0 = 1_000_000_000.0  # any fixed epoch; only the ORDER matters


def test_cap_bounds_entry_count_and_counts_evictions(tmp_path):
    cache = PlanCache(cache_dir=str(tmp_path), max_entries=3)
    plan = _plan()
    keys = [_key(1024 * (i + 1)) for i in range(6)]
    for i, k in enumerate(keys):
        cache.insert(k, plan)
        _set_mtime(tmp_path, k.digest, T0 + i)
    assert len(_entries(tmp_path)) == 3
    assert cache.evictions == 3
    assert cache.record()["plan_evictions"] == 3
    # survivors are the three MOST RECENT inserts
    assert _entries(tmp_path) == sorted(
        f"plan-{k.digest}.pkl" for k in keys[3:]
    )


def test_unlimited_by_default(tmp_path):
    cache = PlanCache(cache_dir=str(tmp_path))  # no env, no arg -> 0
    assert cache.max_entries == 0
    plan = _plan()
    for i in range(8):
        cache.insert(_key(512 * (i + 1)), plan)
    assert len(_entries(tmp_path)) == 8 and cache.evictions == 0


def test_env_var_sets_cap(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE_MAX", "2")
    cache = PlanCache(cache_dir=str(tmp_path))
    assert cache.max_entries == 2
    plan = _plan()
    for i in range(4):
        cache.insert(_key(512 * (i + 1)), plan)
    assert len(_entries(tmp_path)) == 2


def test_disk_hit_refreshes_recency(tmp_path):
    """LRU, not FIFO: a lookup touches the file, so the oldest INSERT
    survives if it was the most recently USED."""
    cache = PlanCache(cache_dir=str(tmp_path), max_entries=2)
    plan = _plan()
    ka, kb = _key(1024), _key(2048)
    cache.insert(ka, plan)
    cache.insert(kb, plan)
    _set_mtime(tmp_path, ka.digest, T0)
    _set_mtime(tmp_path, kb.digest, T0 + 1)

    # a fresh cache (memory level empty) reads A from disk -> utime touch
    reader = PlanCache(cache_dir=str(tmp_path), max_entries=2)
    assert reader.lookup(ka) is not None and reader.disk_hits == 1
    assert os.path.getmtime(tmp_path / f"plan-{ka.digest}.pkl") > T0 + 1

    cache.insert(_key(4096), plan)  # cap exceeded: victim is B, not A
    names = _entries(tmp_path)
    assert f"plan-{ka.digest}.pkl" in names
    assert f"plan-{kb.digest}.pkl" not in names


def test_keep_shields_the_just_inserted_entry(tmp_path):
    """Even when the new entry lands with the OLDEST mtime (clock skew,
    NFS), the cap evicts around it — never the plan being published."""
    cache = PlanCache(cache_dir=str(tmp_path), max_entries=1)
    plan = _plan()
    ka, kb = _key(1024), _key(2048)
    cache.insert(ka, plan)
    _set_mtime(tmp_path, ka.digest, T0 + 100)  # A looks newer than B will

    cache.insert(kb, plan)
    # _enforce_cap ran inside insert with keep=B: B has the older mtime
    # but survives; A is the victim
    post = PlanCache(cache_dir=str(tmp_path), max_entries=1)
    post.insert(kb, plan)  # re-publish is idempotent, still 1 entry
    assert _entries(tmp_path) == [f"plan-{kb.digest}.pkl"]


_EVICTOR_SCRIPT = """
from repro.relational.planner import tpch
from repro.relational.planner.plan_cache import PlanCache, plan_key

node = tpch.ALL_QUERIES["q6"]().logical
cache = PlanCache(cache_dir={cache_dir!r}, max_entries=2)
key = plan_key(node, {{"lineitem": 9999}}, 8)
plan, hit = cache.get_plan(key, lambda: tpch.ALL_QUERIES["q6"]().plan(
    {{"lineitem": 8192}}, 8))
assert not hit
print("EVICTIONS", cache.evictions)
"""


def test_eviction_across_processes(tmp_path):
    """A second process sharing the dir enforces the same cap; the parent
    sees its oldest entries gone and a lookup of an evicted key is a
    plain miss (the loser replans — never an error)."""
    cache = PlanCache(cache_dir=str(tmp_path), max_entries=2)
    plan = _plan()
    keys = [_key(1024 * (i + 1)) for i in range(2)]
    for i, k in enumerate(keys):
        cache.insert(k, plan)
        _set_mtime(tmp_path, k.digest, T0 + i)
    assert len(_entries(tmp_path)) == 2

    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c",
         _EVICTOR_SCRIPT.format(cache_dir=str(tmp_path))],
        capture_output=True, text=True, timeout=180, env=env,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "EVICTIONS 1" in proc.stdout

    assert len(_entries(tmp_path)) == 2
    # the parent's oldest entry was the victim; a FRESH cache (no memory
    # level) misses it and would simply replan
    fresh = PlanCache(cache_dir=str(tmp_path), max_entries=2)
    assert fresh.lookup(keys[0]) is None
    assert fresh.lookup(keys[1]) is not None
