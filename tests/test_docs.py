"""Docs can't rot: every ```python block in README/ARCHITECTURE must run.

Delegates to tools/check_docs.py (the same entry point the CI docs job
uses); each block executes in its own subprocess so the Q3 quickstart can
set up its 8 fake devices before jax initializes.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CHECKER = os.path.join(REPO, "tools", "check_docs.py")
DOCS = [
    "README.md",
    os.path.join("docs", "ARCHITECTURE.md"),
    os.path.join("docs", "SERVING.md"),
]


@pytest.mark.parametrize("doc", DOCS)
def test_doc_snippets_execute(doc):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # snippets set their own fake-device flags
    proc = subprocess.run(
        [sys.executable, CHECKER, os.path.join(REPO, doc)],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
