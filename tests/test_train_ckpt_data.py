"""Training loop, checkpointing, data pipeline, serving engine."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data import Prefetcher, SyntheticLM, TokenFileDataset, make_batch_iterator, write_token_file
from repro.models import registry as R
from repro.serve import Request, ServeEngine
from repro.train import AdamWConfig, make_train_step
from repro.train.step import TrainState

KEY = jax.random.PRNGKey(0)


def _setup(arch="qwen2.5-3b", **over):
    cfg = C.get_smoke_config(arch).scaled(**over)
    api = R.build(cfg)
    state = TrainState.create(api, KEY)
    batch = {
        "tokens": jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size),
    }
    return cfg, api, state, batch


def test_train_overfits_single_batch():
    cfg, api, state, batch = _setup()
    step = jax.jit(make_train_step(api, AdamWConfig(lr=1e-3, warmup_steps=1)))
    losses = []
    for _ in range(10):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_microbatched_grads_match_full_batch():
    cfg, api, state, batch = _setup()
    cfg2 = cfg.scaled(num_microbatches=4)
    api2 = R.build(cfg2)
    s1, m1 = jax.jit(make_train_step(api, AdamWConfig()))(state, batch)
    s2, m2 = jax.jit(make_train_step(api2, AdamWConfig()))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    np.testing.assert_allclose(
        float(m1["grad_norm"]), float(m2["grad_norm"]), rtol=1e-4
    )
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-6
        ),
        s1.params, s2.params,
    )


def test_grad_clipping_engages():
    cfg, api, state, batch = _setup()
    step = jax.jit(make_train_step(api, AdamWConfig(grad_clip=0.01)))
    _, m = step(state, batch)
    assert float(m["grad_norm"]) > 0.01  # raw norm reported, clip applied inside


# ---------------------------------------------------------------------------
# Checkpointing.
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_retention():
    cfg, api, state, _ = _setup()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, every=1, keep=2)
        for s in (1, 2, 3):
            mgr.maybe_save(s, state)
        assert latest_step(d) == 3
        assert not os.path.exists(os.path.join(d, "step_00000001"))  # GC'd
        got = restore_checkpoint(d, None, jax.eval_shape(lambda: state))
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            state, got,
        )


def test_checkpoint_crash_consistency():
    """A stale .tmp directory must not shadow the last good checkpoint."""
    cfg, api, state, _ = _setup()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 5, state)
        os.makedirs(os.path.join(d, "step_00000009.tmp"))  # simulated crash
        assert latest_step(d) == 5
        restore_checkpoint(d, None, jax.eval_shape(lambda: state))


def test_checkpoint_missing_leaf_raises():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"a": jnp.ones(3)})
        with pytest.raises(KeyError):
            restore_checkpoint(d, 1, {"b": jax.ShapeDtypeStruct((3,), jnp.float32)})


# ---------------------------------------------------------------------------
# Data pipeline.
# ---------------------------------------------------------------------------

def test_synthetic_stream_deterministic_and_restartable():
    cfg = C.get_smoke_config("minicpm-2b")
    shape = C.ShapeSpec("t", 32, 8, "train")
    a = make_batch_iterator(cfg, shape, seed=1, start_step=0)
    batches = [next(a) for _ in range(5)]
    b = make_batch_iterator(cfg, shape, seed=1, start_step=3)  # resume at 3
    np.testing.assert_array_equal(next(b)["tokens"], batches[3]["tokens"])


def test_synthetic_stream_is_learnable():
    """Markov stream: consecutive-token mutual structure above chance."""
    src = SyntheticLM(vocab_size=64, seq_len=256, global_batch=4, seed=0)
    b = src.batch(0)
    toks = b["tokens"].reshape(-1)
    # repeated bigrams should appear far more often than uniform chance
    bigrams = toks[:-1].astype(np.int64) * 64 + toks[1:]
    _, counts = np.unique(bigrams, return_counts=True)
    assert counts.max() > 3 * (len(bigrams) / 64**2 + 1)


def test_sharded_batches_partition_the_global_batch():
    parts = [
        SyntheticLM(vocab_size=97, seq_len=16, global_batch=8, seed=5,
                    num_shards=4, shard=i).batch(2)["tokens"]
        for i in range(4)
    ]
    assert all(p.shape == (2, 16) for p in parts)
    full = SyntheticLM(vocab_size=97, seq_len=16, global_batch=8, seed=5).batch(2)
    assert full["tokens"].shape == (8, 16)


def test_token_file_dataset(tmp_path):
    path = str(tmp_path / "toks.bin")
    write_token_file(path, np.arange(10_000) % 251)
    ds = TokenFileDataset(path, seq_len=32, global_batch=4, seed=0)
    b1, b2 = ds.batch(0), ds.batch(0)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_prefetcher_propagates_errors():
    def bad():
        yield {"x": 1}
        raise RuntimeError("boom")

    pf = Prefetcher(bad(), depth=1)
    next(pf)
    with pytest.raises(RuntimeError):
        next(pf)
        next(pf)


# ---------------------------------------------------------------------------
# Serving.
# ---------------------------------------------------------------------------

def test_serve_greedy_matches_manual_loop():
    cfg = C.get_smoke_config("minicpm-2b")
    api = R.build(cfg)
    params = api.init(KEY)
    prompt = np.arange(8, dtype=np.int32)
    eng = ServeEngine(api, batch_size=1, capacity=32)
    (req,) = eng.generate(params, [Request(prompt=prompt, max_new_tokens=4)])

    # manual: prefill + argmax decode
    logits, cache = api.prefill(params, {"tokens": jnp.asarray(prompt[None])})
    cache = eng._grow_cache(cache, 8)
    toks = [int(jnp.argmax(logits[0]))]
    cur = jnp.asarray([[toks[-1]]], jnp.int32)
    for t in range(3):
        logits, cache = api.decode_step(params, cur, cache, jnp.int32(8 + t))
        toks.append(int(jnp.argmax(logits[0])))
        cur = jnp.asarray([[toks[-1]]], jnp.int32)
    assert req.out_tokens == toks


def test_serve_eos_stops_early():
    cfg = C.get_smoke_config("minicpm-2b")
    api = R.build(cfg)
    params = api.init(KEY)
    eng = ServeEngine(api, batch_size=1, capacity=64)
    (r1,) = eng.generate(
        params, [Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=12)]
    )
    eos = r1.out_tokens[2]
    eng2 = ServeEngine(api, batch_size=1, capacity=64)
    (r2,) = eng2.generate(
        params,
        [Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=12, eos_id=eos)],
    )
    # greedy output may repeat tokens; stop at eos's first occurrence
    assert len(r2.out_tokens) == r1.out_tokens.index(eos) + 1
    assert r2.out_tokens[-1] == eos
