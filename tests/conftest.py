import os
import sys

# src-layout import path (no global XLA flags here — smoke tests see 1 device;
# multi-device coverage runs via subprocess, see test_multidevice.py)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
