"""Relational engine vs the numpy oracle + planner/skew math (paper §3.1)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hybrid as H
from repro.core import skew
from repro.relational import datagen, oracle, queries
from repro.relational.planner import PlannerConfig, choose_join_strategy
from repro.relational.table import Table, morsels, pad_to, shard_rows


@pytest.fixture(scope="module")
def tables():
    return datagen.gen_all(0.01)


def test_q1_matches_oracle(tables):
    got = queries.q1_finalize(queries.q1_local(tables["lineitem"]))
    want = oracle.q1_oracle(tables["lineitem"])
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), want[k], rtol=1e-4)


def test_q6_matches_oracle(tables):
    got = float(queries.q6_local(tables["lineitem"]))
    np.testing.assert_allclose(got, oracle.q6_oracle(tables["lineitem"]), rtol=1e-4)


@pytest.mark.parametrize("brand,container", [(12, 2), (1, 0), (3, 5)])
def test_q17_matches_oracle(tables, brand, container):
    got = float(queries.q17_local(tables["lineitem"], tables["part"], brand, container))
    want = oracle.q17_oracle(tables["lineitem"], tables["part"], brand, container)
    np.testing.assert_allclose(got, want, rtol=1e-3)


def test_q3_matches_oracle(tables):
    got = queries.q3_local(tables["customer"], tables["orders"], tables["lineitem"])
    want = oracle.q3_oracle(tables["customer"], tables["orders"], tables["lineitem"])
    # revenues are f32 money sums > 2^24 cents: compare with tolerance
    got_map = dict(zip(np.asarray(got["o_orderkey"]).tolist(),
                       np.asarray(got["revenue"]).tolist()))
    want_map = dict(zip(want["o_orderkey"].tolist(), want["revenue"].tolist()))
    assert set(got_map) == set(want_map)
    for k, v in want_map.items():
        np.testing.assert_allclose(got_map[k], v, rtol=1e-5)


def test_q14_matches_oracle(tables):
    pr, tr = queries.q14_local(tables["lineitem"], tables["part"])
    got = float(queries.q14_finalize(pr, tr))
    np.testing.assert_allclose(
        got, oracle.q14_oracle(tables["lineitem"], tables["part"]), rtol=1e-4
    )


def test_q19_matches_oracle(tables):
    got = float(queries.q19_local(tables["lineitem"], tables["part"]))
    np.testing.assert_allclose(
        got, oracle.q19_oracle(tables["lineitem"], tables["part"]), rtol=1e-4
    )


def test_q17_skewed_data_still_correct():
    tabs = datagen.gen_all(0.01, zipf_partkey=0.84)
    got = float(queries.q17_local(tabs["lineitem"], tabs["part"]))
    want = oracle.q17_oracle(tabs["lineitem"], tabs["part"])
    np.testing.assert_allclose(got, want, rtol=1e-3)


# ---------------------------------------------------------------------------
# Paper §3.1 quantitative claims.
# ---------------------------------------------------------------------------

def test_connection_counts_paper_numbers():
    """6 servers × 40 threads: 57,560 classic connections vs 30 hybrid."""
    assert H.classic_connections(6, 40) == 57_560
    assert H.hybrid_connections(6, 40) == 30
    assert H.classic_buffers_per_operator(6, 40) == 239
    assert H.hybrid_buffers_per_operator(6, 40) == 5


def test_broadcast_threshold_paper_numbers():
    """Broadcast wins below 239× (classic) vs 5× (hybrid) size ratio."""
    assert H.broadcast_threshold(6, 40, hybrid=False) == 239
    assert H.broadcast_threshold(6, 40, hybrid=True) == 5
    cfg_h = PlannerConfig(num_units=6, threads_per_unit=40, hybrid=True)
    cfg_c = PlannerConfig(num_units=6, threads_per_unit=40, hybrid=False)
    # 30x size ratio: hybrid broadcasts, classic partitions
    assert choose_join_strategy(1_000, 30_000, cfg_h) == "broadcast"
    assert choose_join_strategy(1_000, 30_000, cfg_c) == "partition"


def test_skew_overload_paper_numbers():
    """Zipf z=0.84: >2x overload at 240 partitions, ~2.8 % at 6 (paper §3.1)."""
    over_240 = skew.zipf_partition_overload_analytic(240, z=0.84)
    over_6 = skew.zipf_partition_overload_analytic(6, z=0.84)
    assert over_240 > 2.0, over_240
    assert over_6 < 1.06, over_6


def test_salting_reduces_overload():
    rng = np.random.default_rng(0)
    keys = (rng.zipf(1.8, size=20_000) % 1000).astype(np.int64)  # heavy head
    counts = np.bincount(keys)
    heavy = np.argsort(counts)[-8:]  # the hottest keys
    # np.bincount refuses uint64 input (no safe cast to intp) — cast explicitly
    base = skew.straggler_excess(
        np.bincount((skew._hash_keys(keys, 0) % np.uint64(8)).astype(np.int64),
                    minlength=8)
    )
    salted = skew.salt_keys(keys, heavy_keys=heavy, num_salts=8)
    after = skew.straggler_excess(
        np.bincount((skew._hash_keys(salted, 0) % np.uint64(8)).astype(np.int64),
                    minlength=8)
    )
    assert after <= base


# ---------------------------------------------------------------------------
# Storage layer.
# ---------------------------------------------------------------------------

def test_table_mask_and_select(tables):
    li = tables["lineitem"]
    t = li.with_mask(li["l_quantity"] > 25).select(["l_quantity"])
    assert set(t.columns) == {"l_quantity"}
    assert int(t.num_valid()) < int(li.num_valid())


def test_shard_rows_interleaves():
    t = Table({"x": jnp.arange(8)}, jnp.ones(8, bool))
    s = shard_rows(t, 2)
    np.testing.assert_array_equal(np.asarray(s["x"]), [0, 2, 4, 6, 1, 3, 5, 7])


def test_pad_and_morsels():
    t = pad_to(Table({"x": jnp.arange(6)}, jnp.ones(6, bool)), 8)
    assert t.capacity == 8 and int(t.num_valid()) == 6
    chunks = list(morsels(t, 3))
    assert [c.capacity for c in chunks] == [3, 3, 2]
