"""The bench regression gate: benchmarks/run.py --compare.

Pure-python (no jax) — exercises direction inference, the leaf flattener,
and the gate's pass/fail decisions on synthetic BENCH records shaped like
the real smoke-lane output.
"""

import json
import os
import subprocess
import sys

import pytest

from benchmarks.run import _direction, _numeric_leaves, compare

REPO = os.path.join(os.path.dirname(__file__), "..")


def test_direction_inference():
    # throughput beats the "_s" time suffix
    assert _direction("continuous.tok_s") == "higher"
    assert _direction("slot_steps_ratio") == "higher"
    assert _direction("ep_overlap.2x4.overlap_fraction") == "higher"
    assert _direction("continuous.wall_s") == "lower"
    assert _direction("queries.q3.planned_ms") == "lower"
    assert _direction("queries.q3.wire_bytes") == "lower"
    assert _direction("static.slot_steps") == "lower"
    # knobs/counts are not gated
    assert _direction("ep_overlap.2x4.chunks") is None
    assert _direction("workload.requests") is None


def test_numeric_leaves_flatten():
    rec = {"a": {"b": [1, 2.5]}, "ok": True, "name": "x", "z": 0}
    assert _numeric_leaves(rec) == {"a.b.0": 1.0, "a.b.1": 2.5, "z": 0.0}


@pytest.fixture
def bench_dirs(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    base.mkdir(), fresh.mkdir()

    def write(d, rec):
        (d / "BENCH_serve.json").write_text(json.dumps(rec))

    return base, fresh, write


BASE_REC = {
    "continuous": {"slot_steps": 100, "tok_s": 50.0, "wall_s": 2.0},
    "slot_steps_ratio": 1.4,
    "queries": {"q3": {"planned_ms": 10.0, "wire_bytes": 4096, "correct": True}},
    "ep_overlap": {"2x4": {"chunks": 1, "overlap_fraction": 0.03}},
}


def test_compare_identical_passes(bench_dirs, capsys):
    base, fresh, write = bench_dirs
    write(base, BASE_REC), write(fresh, BASE_REC)
    assert compare(str(base), str(fresh)) == 0
    assert "0 regressed" in capsys.readouterr().out


def test_compare_within_threshold_passes(bench_dirs):
    base, fresh, write = bench_dirs
    write(base, BASE_REC)
    rec = json.loads(json.dumps(BASE_REC))
    rec["queries"]["q3"]["planned_ms"] = 19.0  # 1.9x — inside the 2x band
    rec["continuous"]["tok_s"] = 26.0  # dropped, but < 2x
    write(fresh, rec)
    assert compare(str(base), str(fresh)) == 0


def test_compare_flags_both_directions(bench_dirs, capsys):
    base, fresh, write = bench_dirs
    write(base, BASE_REC)
    rec = json.loads(json.dumps(BASE_REC))
    rec["queries"]["q3"]["planned_ms"] = 25.0  # lower-is-better, 2.5x up
    rec["continuous"]["tok_s"] = 20.0  # higher-is-better, 2.5x down
    rec["ep_overlap"]["2x4"]["chunks"] = 4  # knob change: never gated
    write(fresh, rec)
    assert compare(str(base), str(fresh)) == 2
    out = capsys.readouterr().out
    assert "REGRESSION BENCH_serve.json:queries.q3.planned_ms" in out
    assert "REGRESSION BENCH_serve.json:continuous.tok_s" in out
    assert "chunks" not in [l.split(":")[-1] for l in out.splitlines()]


def test_compare_added_and_removed_metrics_never_fail(bench_dirs):
    base, fresh, write = bench_dirs
    rec = json.loads(json.dumps(BASE_REC))
    rec["new_metric_s"] = 1.0
    del rec["queries"]
    write(base, BASE_REC), write(fresh, rec)
    assert compare(str(base), str(fresh)) == 0
    # a baseline file with no fresh counterpart is skipped, not failed
    os.remove(fresh / "BENCH_serve.json")
    assert compare(str(base), str(fresh)) == 0


def test_compare_single_file_baseline(bench_dirs):
    base, fresh, write = bench_dirs
    write(base, BASE_REC), write(fresh, BASE_REC)
    assert compare(str(base / "BENCH_serve.json"), str(fresh)) == 0


def test_cli_exit_codes(bench_dirs):
    base, fresh, write = bench_dirs
    write(base, BASE_REC)
    rec = json.loads(json.dumps(BASE_REC))
    rec["continuous"]["wall_s"] = 100.0
    write(fresh, rec)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    cmd = [sys.executable, "-m", "benchmarks.run",
           "--compare", str(base), "--json-dir", str(fresh)]
    r = subprocess.run(cmd, capture_output=True, text=True, cwd=REPO, env=env)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "REGRESSION" in r.stdout
    # the gate widens with --compare-threshold
    r = subprocess.run(cmd + ["--compare-threshold", "100"],
                       capture_output=True, text=True, cwd=REPO, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
