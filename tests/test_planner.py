"""Query-plan subsystem: IR, physical placement, executor, golden explains.

Three layers tested separately and end to end:

* logical IR — schema/cardinality inference, the expression language;
* physical planner — broadcast-vs-partition decisions, co-partitioning
  reuse (one exchange feeding two consumers), cross-pod reshard as a plan
  shape, and the deterministic ``explain()`` golden snapshots under
  ``tests/golden_plans/`` (regenerate with ``REPRO_UPDATE_GOLDEN=1``);
* executor — every TPC-H query (the six ported ones AND plan-only
  Q4/Q12/Q18) vs the numpy oracle on a single device.  The 8-fake-device
  and two-level-mesh runs live in ``tests/_multidev_driver.py``.
"""

import os

import numpy as np
import pytest

from repro.relational import datagen, oracle
from repro.relational.context import ExecutionContext
from repro.relational.planner import (
    Aggregate,
    Filter,
    GroupBy,
    HashJoin,
    Project,
    Scan,
    col,
    lit,
    plan_physical,
    where,
)
from repro.relational.planner import tpch
from repro.relational.table import Table

CTX1 = ExecutionContext(num_shards=1)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden_plans")


@pytest.fixture(scope="module")
def tables():
    return datagen.gen_all(0.005)


def _tpch_tables(tabs):
    return {
        "lineitem": tabs["lineitem"],
        "part": tabs["part"],
        "orders": tabs["orders"],
        "customer": tabs["customer"],
    }


# ---------------------------------------------------------------------------
# Logical IR: expressions, schema and cardinality inference.
# ---------------------------------------------------------------------------

def test_expr_eval_and_render():
    import jax.numpy as jnp

    t = Table(
        {"a": jnp.asarray([1, 2, 3]), "b": jnp.asarray([10, 20, 30])},
        jnp.ones(3, bool),
    )
    e = (col("a") + lit(1)) * col("b").f32() / lit(2.0)
    np.testing.assert_allclose(np.asarray(e.eval(t)), [10.0, 30.0, 60.0])
    assert e.render() == "(((a + 1) * f32(b)) / 2.0)"
    w = where(col("a") >= lit(2), col("b"), lit(0))
    np.testing.assert_array_equal(np.asarray(w.eval(t)), [0, 20, 30])
    assert w.columns() == {"a", "b"}


def test_schema_inference():
    li = Scan("lineitem", ("l_orderkey", "l_quantity"))
    od = Scan("orders", ("o_orderkey", "o_totalprice"))
    g = GroupBy(li, key="l_orderkey", aggs=(("sum_qty", col("l_quantity"), "sum"),))
    assert g.schema == ("l_orderkey", "sum_qty")
    j = HashJoin(build=g, probe=od, build_key="l_orderkey",
                 probe_key="o_orderkey", payload=("sum_qty",))
    assert j.schema == ("o_orderkey", "o_totalprice", "sum_qty")
    p = Project(j, keep=("o_orderkey",), derived=(("x", col("sum_qty") * 2),))
    assert p.schema == ("o_orderkey", "x")
    cat = {"lineitem": 1000, "orders": 100}
    assert g.est_rows(cat) == 1000  # worst case: every key distinct
    assert j.est_rows(cat) == 100  # join keeps probe cardinality
    agg = Aggregate(j, (("n", lit(1), "count"),))
    assert agg.est_rows(cat) == 1 and agg.schema == ("n",)


def test_ir_rejects_unknown_columns():
    li = Scan("lineitem", ("l_orderkey",))
    with pytest.raises(AssertionError):
        Filter(li, col("nope") > lit(0))
    with pytest.raises(AssertionError):
        Project(li, keep=("nope",))
    with pytest.raises(AssertionError):
        GroupBy(li, key="nope", aggs=(("n", lit(1), "count"),))
    with pytest.raises(AssertionError, match="key_expr"):
        GroupBy(li, key_expr=col("nope"), num_groups=5,
                aggs=(("n", lit(1), "count"),))


def test_ir_rejects_nested_root_only_combines():
    """Dense GroupBy / Aggregate / TopK already crossed shards (psum/top-k);
    feeding one into another operator is an illegal plan shape and fails at
    IR construction, not inside jit tracing."""
    li = Scan("lineitem", ("l_orderkey",))
    agg = Aggregate(li, (("n", lit(1), "count"),))
    with pytest.raises(TypeError, match="root-only"):
        Filter(agg, col("n") > lit(0))
    dense = GroupBy(li, key_expr=col("l_orderkey"), num_groups=4,
                    aggs=(("n", lit(1), "count"),))
    with pytest.raises(TypeError, match="root-only"):
        Aggregate(dense, (("m", lit(1), "count"),))
    # sort-based GroupBy is a row stream and composes fine
    g = GroupBy(li, key="l_orderkey", aggs=(("n", lit(1), "count"),))
    Filter(g, col("n") > lit(0))


# ---------------------------------------------------------------------------
# Physical planner: strategy decisions and exchange placement.
# ---------------------------------------------------------------------------

def _count_exchanges(plan):
    shuffles, broadcasts, seen = 0, 0, set()

    def walk(n):
        nonlocal shuffles, broadcasts
        if id(n) in seen:
            return
        seen.add(id(n))
        if n.kind == "exchange":
            if n.info["exkind"] == "shuffle":
                shuffles += 1
            else:
                broadcasts += 1
        for c in n.children:
            walk(c)

    walk(plan.root)
    return shuffles, broadcasts


def test_broadcast_decision_flips_with_sizes():
    cat = {"small": 1_000, "big": 30_000}
    j = HashJoin(
        build=Scan("small", ("k",)), probe=Scan("big", ("k2",)),
        build_key="k", probe_key="k2",
    )
    root = Aggregate(j, (("n", lit(1), "count"),))
    # 30x ratio, 8 units (threshold 7): broadcast the small side
    p8 = plan_physical(root, cat, num_shards=8)
    assert _count_exchanges(p8) == (0, 1)
    # same ratio but a 64-unit exchange level (threshold 63): partition
    p64 = plan_physical(root, cat, num_shards=64)
    assert _count_exchanges(p64) == (2, 0)


def test_q17_shares_one_shuffle():
    """Q17's group-by and join-back both need hash(l_partkey): ONE exchange."""
    plan = tpch.q17().plan(tpch.tpch_catalog(0.01), 8)
    assert _count_exchanges(plan) == (1, 1)
    assert len(plan.shuffle_stats) == 1 and len(plan.broadcast_stats) == 1
    # the shuffle ships 3 int32 columns of the lineitem capacity
    assert plan.shuffle_stats[0].rows == 7500
    assert plan.shuffle_stats[0].row_bytes == 12


def test_q14_plans_no_shuffle():
    """Broadcast-part joins need no lineitem exchange (the hand-written plan
    paid one for nothing)."""
    plan = tpch.q14().plan(tpch.tpch_catalog(0.01), 8)
    assert _count_exchanges(plan) == (0, 1)


def test_q1_q6_plan_zero_exchanges():
    for pq in (tpch.q1(), tpch.q6()):
        plan = pq.plan(tpch.tpch_catalog(0.01), 8)
        assert _count_exchanges(plan) == (0, 0)
        assert plan.total_wire_bytes() == 0


def test_q3_broadcasts_customer():
    plan = tpch.q3().plan(tpch.tpch_catalog(0.01), 8)
    shuffles, broadcasts = _count_exchanges(plan)
    assert (shuffles, broadcasts) == (2, 1)


def test_cross_pod_reshard_is_a_plan_shape():
    """Pinning reshard on a pod mesh turns the broadcast join into a
    co-partitioned one (both sides exchanged) — resharding only the build
    side would strand it away from an un-partitioned probe."""
    cat = tpch.tpch_catalog(0.01)
    plan_b = tpch.q17().plan(cat, 8, num_pods=2, cross_pod="broadcast")
    assert _count_exchanges(plan_b) == (1, 1)
    assert plan_b.tuned.cross_pod == "broadcast"
    plan_r = tpch.q17().plan(cat, 8, num_pods=2, cross_pod="reshard")
    assert _count_exchanges(plan_r) == (2, 0)
    assert plan_r.tuned.cross_pod == "reshard"
    assert "cross_pod_reshard" in plan_r.explain()


def test_reshard_keeps_broadcast_for_float_schemas():
    """Q18's customer join probes a table carrying the f32 sum_qty payload:
    the reshard pass must keep that join's broadcast edge (the int32 row
    image can't ship floats) instead of emitting an unexecutable plan."""
    plan = tpch.q18().plan(
        tpch.tpch_catalog(0.01), 8, num_pods=2, cross_pod="reshard"
    )
    shuffles, broadcasts = _count_exchanges(plan)
    assert broadcasts == 1, plan.explain()
    assert plan.tuned.cross_pod == "reshard"


def test_q18_plans_at_high_shard_counts():
    """Above 11 units the threshold exceeds Q18's 10x orders/customer
    ratio, flipping the customer join to partition — but its probe carries
    the f32 sum_qty payload, so the planner must force broadcast (the
    always-valid plan) instead of emitting an unplannable float shuffle."""
    cat = tpch.tpch_catalog(0.01)
    for shards in (12, 16, 64):
        plan = tpch.q18().plan(cat, shards)
        assert "forced: float columns" in plan.explain(), plan.explain()
    # below the threshold crossover the plain broadcast decision applies
    assert "forced" not in tpch.q18().plan(cat, 8).explain()


def test_plan_rejects_float_shuffle():
    """A plan that would hash-exchange a float column fails at PLAN time
    with an actionable message, not at jit-trace time."""
    li = Scan("lineitem", ("l_orderkey", "l_quantity"))
    g = GroupBy(li, key="l_orderkey",
                aggs=(("sum_qty", col("l_quantity"), "sum"),))
    p2 = Project(g, keep=("sum_qty",),
                 derived=(("k2", col("l_orderkey") * lit(7)),))
    g2 = GroupBy(p2, key="k2", aggs=(("n", lit(1), "count"),))
    root = Aggregate(g2, (("n2", lit(1), "count"),))
    with pytest.raises(ValueError, match="float columns"):
        plan_physical(root, {"lineitem": 1024}, 8)


def test_plan_root_must_aggregate():
    li = Scan("lineitem", ("l_orderkey",))
    with pytest.raises(ValueError, match="root"):
        plan_physical(Filter(li, col("l_orderkey") > lit(0)),
                      {"lineitem": 100}, 4)


def test_executor_rejects_capacity_mismatch(tables):
    plan = tpch.q6().plan({"lineitem": 999}, 1)
    from repro.relational.planner import execute_plan

    with pytest.raises(ValueError, match="capacity"):
        execute_plan(plan, {"lineitem": tables["lineitem"]})


def test_exchange_rejects_float_columns():
    """Float aggregates must stay local — the packed row image is int32."""
    import jax.numpy as jnp

    from repro.relational.planner.executor import _exchange_by_key
    from repro.core.multiplexer import make_multiplexer
    from repro.compat import make_mesh

    mesh = make_mesh((1,), ("q",))
    mux = make_multiplexer(mesh)
    t = Table({"k": jnp.asarray([1.5, 2.5])}, jnp.ones(2, bool))
    with pytest.raises(TypeError, match="non-integer"):
        _exchange_by_key(mux, t, "k", ["k"])


# ---------------------------------------------------------------------------
# Golden explain() snapshots: a cost-model change that flips a decision
# shows up as a reviewable diff.  Regenerate with REPRO_UPDATE_GOLDEN=1.
# ---------------------------------------------------------------------------

GOLDEN_CASES = [
    ("q1", "q1", 8, 1),
    ("q3", "q3", 8, 1),
    ("q4", "q4", 8, 1),
    ("q6", "q6", 8, 1),
    ("q12", "q12", 8, 1),
    ("q14", "q14", 8, 1),
    ("q17", "q17", 8, 1),
    ("q18", "q18", 8, 1),
    ("q19", "q19", 8, 1),
    ("q3_pods2", "q3", 8, 2),
    ("q18_pods2", "q18", 8, 2),
]


@pytest.mark.parametrize("fname,query,shards,pods", GOLDEN_CASES)
def test_golden_explain(fname, query, shards, pods):
    text = tpch.explain_query(
        tpch.ALL_QUERIES[query](), tpch.tpch_catalog(0.01),
        ExecutionContext(num_shards=shards, num_pods=pods),
    )
    path = os.path.join(GOLDEN_DIR, f"{fname}.txt")
    if os.environ.get("REPRO_UPDATE_GOLDEN"):
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            f.write(text)
    with open(path) as f:
        want = f.read()
    assert text == want, (
        f"explain({fname}) drifted from tests/golden_plans/{fname}.txt — "
        "if the new plan is intended, regenerate with REPRO_UPDATE_GOLDEN=1"
    )


# ---------------------------------------------------------------------------
# End-to-end single-device: every query through the planner vs the oracle.
# (8 fake devices + two-level meshes: tests/_multidev_driver.py.)
# ---------------------------------------------------------------------------

def test_q1_planned_matches_oracle(tables):
    got = tpch.run_query(tpch.q1(), _tpch_tables(tables), CTX1)
    want = oracle.q1_oracle(tables["lineitem"])
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), want[k], rtol=1e-4)


def test_q6_planned_matches_oracle(tables):
    got = float(tpch.run_query(tpch.q6(), _tpch_tables(tables), CTX1))
    np.testing.assert_allclose(got, oracle.q6_oracle(tables["lineitem"]),
                               rtol=1e-4)


def test_q17_planned_matches_oracle(tables):
    got = float(tpch.run_query(tpch.q17(brand=1, container=0),
                               _tpch_tables(tables), CTX1))
    want = oracle.q17_oracle(tables["lineitem"], tables["part"], 1, 0)
    np.testing.assert_allclose(got, want, rtol=1e-3)


def test_q3_planned_matches_oracle(tables):
    got = tpch.run_query(tpch.q3(), _tpch_tables(tables), CTX1)
    want = oracle.q3_oracle(tables["customer"], tables["orders"],
                            tables["lineitem"])
    assert [int(k) for k in got["o_orderkey"]] == \
        [int(k) for k in want["o_orderkey"]]
    np.testing.assert_allclose(
        np.asarray(got["revenue"], np.float64), want["revenue"], rtol=1e-5
    )


def test_q14_planned_matches_oracle(tables):
    got = float(tpch.run_query(tpch.q14(), _tpch_tables(tables), CTX1))
    np.testing.assert_allclose(
        got, oracle.q14_oracle(tables["lineitem"], tables["part"]), rtol=1e-3
    )


def test_q19_planned_matches_oracle(tables):
    got = float(tpch.run_query(tpch.q19(), _tpch_tables(tables), CTX1))
    np.testing.assert_allclose(
        got, oracle.q19_oracle(tables["lineitem"], tables["part"]), rtol=1e-4
    )


def test_q4_planned_matches_oracle(tables):
    got = tpch.run_query(tpch.q4(), _tpch_tables(tables), CTX1)
    want = oracle.q4_oracle(tables["lineitem"], tables["orders"])
    np.testing.assert_allclose(np.asarray(got["order_count"]), want)
    assert want.sum() > 0  # the EXISTS actually selects something


def test_q12_planned_matches_oracle(tables):
    got = tpch.run_query(tpch.q12(), _tpch_tables(tables), CTX1)
    want = oracle.q12_oracle(tables["lineitem"], tables["orders"])
    np.testing.assert_allclose(got["high_line_count"], want["high_line_count"])
    np.testing.assert_allclose(got["low_line_count"], want["low_line_count"])
    assert want["high_line_count"].sum() + want["low_line_count"].sum() > 0


def test_q18_planned_matches_oracle(tables):
    got = tpch.run_query(tpch.q18(), _tpch_tables(tables), CTX1)
    want = oracle.q18_oracle(tables["lineitem"], tables["orders"],
                             tables["customer"])
    assert len(want["o_orderkey"]) > 0  # HAVING threshold selects something
    assert len(got["o_orderkey"]) == len(want["o_orderkey"])
    got_map = {
        int(k): (int(tp), float(sq))
        for k, tp, sq in zip(got["o_orderkey"], got["o_totalprice"],
                             got["sum_qty"])
    }
    want_map = {
        int(k): (int(tp), float(sq))
        for k, tp, sq in zip(want["o_orderkey"], want["o_totalprice"],
                             want["sum_qty"])
    }
    assert got_map == want_map


# ---------------------------------------------------------------------------
# q1/q6 distributed entry points (previously untested anywhere).
# ---------------------------------------------------------------------------

def test_q1_distributed_single_device(tables):
    from repro.relational.distributed import q1_distributed

    got = q1_distributed(tables["lineitem"], CTX1)
    want = oracle.q1_oracle(tables["lineitem"])
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), want[k], rtol=1e-4)


def test_q6_distributed_single_device(tables):
    from repro.relational.distributed import q6_distributed

    got = float(q6_distributed(tables["lineitem"], CTX1))
    np.testing.assert_allclose(got, oracle.q6_oracle(tables["lineitem"]),
                               rtol=1e-4)
