"""Multi-process pod-axis integration tests (2 procs x 4 fake devices).

Each scenario spawns a REAL 2-process jax.distributed cluster via
``repro.launch.cluster`` (Gloo CPU collectives over localhost); the ``pod``
mesh axis crosses an actual process boundary — the CI stand-in for the
network in the large.  Scenario bodies live in tests/_multiproc_driver.py.

Skipped wholesale if the host's jax/jaxlib cannot initialize Gloo CPU
collectives (the capability is probed once with a cheap psum worker).
"""

import functools
import os

import pytest

from repro.launch.cluster import run_local_cluster

DRIVER = os.path.join(os.path.dirname(__file__), "_multiproc_driver.py")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCENARIOS = [
    "hierarchical_psum",
    "exchange_over_dci_raises",
    "two_level_shuffle",
    "production_mesh",
    "tuner_dci_aware",
    "tpch_pod_mesh",
    "ep_dispatch_two_level",
    "salted_pod_shuffle",
    "oocore_pod_stream",
    "trace_merge",
]

_PROBE = """
from repro.launch.cluster import init_cluster
init_cluster()
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
mesh = jax.make_mesh((jax.device_count(),), ("x",))
f = jax.jit(shard_map(lambda v: jax.lax.psum(v, "x"), mesh=mesh,
                      in_specs=P("x"), out_specs=P()))
f(jnp.arange(float(jax.device_count())))
print("GLOO_OK")
"""


@functools.lru_cache(maxsize=1)
def _gloo_available() -> bool:
    try:
        outs = run_local_cluster(
            ["-c", _PROBE], num_processes=2, local_devices=1,
            timeout_s=180, echo=False, env={"PYTHONPATH": SRC},
        )
    except RuntimeError:
        return False
    return all("GLOO_OK" in o for o in outs)


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_multiprocess(scenario):
    if not _gloo_available():
        if os.environ.get("REPRO_REQUIRE_GLOO"):
            pytest.fail(
                "REPRO_REQUIRE_GLOO is set but Gloo CPU collectives are "
                "unavailable — the multiprocess job would otherwise go "
                "green with zero pod-axis coverage"
            )
        pytest.skip("no Gloo CPU collectives in this jaxlib build")
    outs = run_local_cluster(
        [DRIVER, scenario],
        num_processes=2, local_devices=4, timeout_s=540, echo=False,
    )
    assert all(f"PASS {scenario}" in o for o in outs), outs
