"""The telemetry spine: spans, counters, QueryTrace, export, model gate.

End-to-end tracing on real multi-device runs lives in the
``traced_query`` / ``trace_bit_identical`` scenarios of
``tests/_multidev_driver.py`` and the merged-timeline scenario of
``tests/_multiproc_driver.py``; this file covers the host-side pieces
that need no devices — span nesting and thread-safety, the JSON and
Chrome trace-event exports, the QueryTrace round-trip, ``deposit``, and
the model-error arithmetic the CI gate runs on.
"""

import json
import threading

import pytest

from repro.obs.export import (
    chrome_trace_events,
    merge_trace_dir,
    query_trace_from_json,
    query_trace_to_json,
    tracer_to_dict,
    write_trace_dir,
)
from repro.obs.model_check import (
    BYTE_MODEL_BOUND,
    assert_bytes_within,
    model_report,
)
from repro.obs.trace import (
    ExchangeEdge,
    QueryTrace,
    Tracer,
    deposit,
    maybe_span,
    model_error,
)


def _edge(key="shuffle[k]#0", measured=900, modeled=1000, **kw) -> ExchangeEdge:
    defaults = dict(
        key=key, rows=100, row_bytes=12, hist=(25, 25, 25, 25),
        measured_bytes=measured, modeled_wire_bytes=modeled,
        overload=1.2, plain_overload=1.2, salted=False,
        predicted_s=1e-4, measured_s=2e-4,
    )
    defaults.update(kw)
    return ExchangeEdge(**defaults)


def _qt(*edges, query="q17") -> QueryTrace:
    return QueryTrace(
        query=query, num_shards=4, num_pods=1, edges=tuple(edges),
        counters={"morsels": 4.0, "passes": 2.0}, measured_s=0.5,
    )


# ---------------------------------------------------------------------------
# model_error: the one ratio everything gates on.
# ---------------------------------------------------------------------------

def test_model_error_symmetric_and_lower_bounded():
    assert model_error(2.0, 1.0) == model_error(1.0, 2.0) == 2.0
    assert model_error(3.0, 3.0) == 1.0
    assert model_error(None, 1.0) is None
    assert model_error(1.0, 0.0) is None  # zero-byte edges are vacuous


def test_assert_bytes_within():
    assert_bytes_within(_qt(_edge(measured=900, modeled=1000)))
    with pytest.raises(AssertionError, match="exceeds the 2.0x"):
        assert_bytes_within(_qt(_edge(measured=100, modeled=1000)))
    # a custom bound and the vacuous zero-row edge
    assert_bytes_within(_qt(_edge(measured=100, modeled=1000)), bound=10.0)
    assert_bytes_within(_qt(_edge(measured=0, modeled=1000)))
    assert BYTE_MODEL_BOUND == 2.0


def test_model_report_worst_edge():
    rep = model_report(_qt(
        _edge(key="a", measured=1000, modeled=1000),
        _edge(key="b", measured=500, modeled=900),
    ))
    assert rep["query"] == "q17"
    assert rep["edges"]["a"]["byte_model_err"] == 1.0
    assert rep["worst_byte_model_err"] == pytest.approx(1.8)


# ---------------------------------------------------------------------------
# Span nesting.
# ---------------------------------------------------------------------------

def test_spans_nest_and_close():
    tr = Tracer(pid=0)
    with tr.span("plan:q17", cat="plan"):
        with tr.span("compile:q17", cat="compile", streamed=True):
            pass
        with tr.span("execute:q17", cat="execute"):
            tr.add_span("exchange:e0", cat="exchange", measured_bytes=42)
    assert len(tr.spans) == 1  # one root
    root = tr.spans[0]
    assert [s.name for s in root.walk()] == [
        "plan:q17", "compile:q17", "execute:q17", "exchange:e0"
    ]
    assert all(s.dur is not None for s in root.walk())
    assert root.children[0].args == {"streamed": True}


def test_maybe_span_is_noop_without_tracer():
    with maybe_span(None, "anything") as s:
        assert s is None


def test_spans_from_threads_do_not_interleave():
    """The span stack is thread-local: two threads tracing concurrently
    each build their own root — never nest under each other."""
    tr = Tracer(pid=0)
    barrier = threading.Barrier(2)

    def work(i):
        barrier.wait()
        with tr.span(f"root:{i}"):
            with tr.span(f"child:{i}"):
                pass

    ts = [threading.Thread(target=work, args=(i,)) for i in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert sorted(s.name for s in tr.spans) == ["root:0", "root:1"]
    for root in tr.spans:
        i = root.name.split(":")[1]
        assert [c.name for c in root.children] == [f"child:{i}"]


def test_counters_gauges_histograms():
    tr = Tracer(pid=0)
    tr.counter("runs")
    tr.counter("runs", 2.0)
    tr.gauge("depth", 3.0)
    tr.observe("wait_s", 0.1)
    tr.observe("wait_s", 0.3)
    assert tr.counters["runs"] == 3.0
    assert tr.gauges["depth"] == 3.0
    assert tr.histograms["wait_s"] == [0.1, 0.3]


# ---------------------------------------------------------------------------
# deposit: QueryTrace -> tracer spans + counters.
# ---------------------------------------------------------------------------

def test_deposit_lays_out_edges_and_counters():
    tr = Tracer(pid=0)
    qt = _qt(_edge(key="a"), _edge(key="b"))
    deposit(tr, qt)
    assert tr.query_traces == [qt]
    names = [s.name for s in tr.spans]
    assert names == ["exchange:a", "exchange:b"]
    # edge spans partition the measured window by predicted share
    assert sum(s.dur for s in tr.spans) == pytest.approx(0.5)
    assert tr.counters["exchange.measured_bytes"] == 1800.0
    assert tr.counters["query.q17.runs"] == 1.0
    assert tr.counters["query.q17.morsels"] == 4.0
    deposit(None, qt)  # no-op without a tracer


# ---------------------------------------------------------------------------
# JSON round-trip.
# ---------------------------------------------------------------------------

def test_query_trace_json_roundtrip():
    qt = _qt(_edge(key="a"), _edge(key="b", salted=True, traversals=4))
    assert query_trace_from_json(query_trace_to_json(qt)) == qt


def test_query_trace_roundtrip_defaults_traversals():
    """Traces written before the traversal counter existed still load."""
    d = json.loads(query_trace_to_json(_qt(_edge())))
    for e in d["edges"]:
        del e["traversals"]
    loaded = query_trace_from_json(json.dumps(d))
    assert loaded.edges[0].traversals == 1


# ---------------------------------------------------------------------------
# Chrome trace-event (Perfetto) validity.
# ---------------------------------------------------------------------------

def _traced_tracer() -> Tracer:
    tr = Tracer(pid=0)
    with tr.span("plan:q17", cat="plan"):
        with tr.span("compile:q17", cat="compile"):
            pass
    deposit(tr, _qt(_edge(key="a"), _edge(key="b")))
    return tr


def test_chrome_events_sorted_and_matched():
    events = chrome_trace_events(_traced_tracer())
    meta = [e for e in events if e["ph"] == "M"]
    dur = [e for e in events if e["ph"] in ("B", "E")]
    assert meta and meta[0]["name"] == "process_name"
    # timestamps are sorted non-decreasing
    ts = [e["ts"] for e in dur]
    assert ts == sorted(ts)
    # B/E counts match per (name, pid, tid) and never go negative
    depth: dict = {}
    for e in dur:
        k = (e["name"], e["pid"], e["tid"])
        depth[k] = depth.get(k, 0) + (1 if e["ph"] == "B" else -1)
        assert depth[k] >= 0, f"E before B for {k}"
    assert all(v == 0 for v in depth.values()), depth


def test_tracer_to_dict_is_perfetto_loadable_json():
    d = tracer_to_dict(_traced_tracer(), process_name="proc 0")
    s = json.dumps(d)  # jsonable end to end
    loaded = json.loads(s)
    assert loaded["traceEvents"][0]["args"]["name"] == "proc 0"
    assert loaded["displayTimeUnit"] == "ms"
    assert loaded["queryTraces"][0]["query"] == "q17"


# ---------------------------------------------------------------------------
# Per-process files + merge (the 2-process Gloo scenario drives the real
# thing; this covers the file plumbing single-process).
# ---------------------------------------------------------------------------

def test_write_and_merge_trace_dir(tmp_path):
    d = str(tmp_path)
    for pid in (0, 1):
        tr = Tracer(pid=pid)
        with tr.span(f"work:p{pid}"):
            pass
        tr.counter("runs", 1.0)
        path = write_trace_dir(tr, d, basename="t")
        assert path.endswith(f"t-p{pid}.json")
    merged = merge_trace_dir(d, basename="t", out=f"{d}/merged.json")
    pids = {e["pid"] for e in merged["traceEvents"]}
    assert pids == {0, 1}
    assert merged["counters"]["runs"] == 2.0
    # metadata first, then time-sorted events
    phs = [e["ph"] for e in merged["traceEvents"]]
    assert phs[:2] == ["M", "M"]
    with open(f"{d}/merged.json") as f:
        assert json.load(f) == merged
    with pytest.raises(FileNotFoundError):
        merge_trace_dir(d, basename="nope")
