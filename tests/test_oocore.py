"""Out-of-core morsel streaming: streamed == in-memory oracle.

The tentpole contract, single-device half (the 8-device and 2-process
variants live in the multidevice/multiprocess drivers):

* Q1 (one-pass dense group-by) and Q17 (two-pass: stream, re-scan) run
  morsel-streamed over a chunked source and match the in-memory oracle —
  bit-identical for integer columns, rtol 1e-3 for float aggregates
  (partial-sum order differs);
* streamed execution clears a ``device_row_budget`` that in-memory
  execution *refuses* — the full table never has to fit on the device;
* chunked generator sources (``gen_lineitem_chunked``) produce the same
  bytes as the monolithic generator, chunk seeding included;
* the error surface: COLLECT stats cannot stream, two oversized tables
  cannot both stream, budget violations name the offender.
"""

import numpy as np
import pytest

from repro.relational import datagen
from repro.relational.context import ExecutionContext, StatsMode
from repro.relational.planner import tpch
from repro.relational.planner.executor import execute_plan
from repro.relational.planner.stream import compile_plan_streamed
from repro.relational.source import GeneratorSource, MorselView, as_source

SF = 0.002
CTX1 = ExecutionContext(num_shards=1)


@pytest.fixture(scope="module")
def tabs():
    return {
        "lineitem": datagen.gen_lineitem(SF),
        "part": datagen.gen_part(SF),
    }


def _assert_results_match(oracle, got, rtol=1e-3):
    if not isinstance(oracle, dict):  # scalar finalize (q6, q17)
        oracle, got = {"result": oracle}, {"result": got}
    assert set(oracle) == set(got)
    for k in oracle:
        o, g = np.asarray(oracle[k]), np.asarray(got[k])
        if o.dtype.kind == "f":
            np.testing.assert_allclose(g, o, rtol=rtol, err_msg=k)
        else:
            np.testing.assert_array_equal(g, o, err_msg=k)


def _streamed_vs_oracle(pq, sources, ctx):
    mat = {t: sources[t].materialize() for t in pq.tables}
    catalog = {t: sources[t].capacity for t in pq.tables}
    plan = pq.plan(catalog, ctx.num_shards)
    oracle = pq.finalize(execute_plan(plan, mat))
    run = compile_plan_streamed(plan, sources, ctx)
    got = pq.finalize(run())
    return oracle, got, run.stats


# ---------------------------------------------------------------------------
# Streamed == oracle, single device.
# ---------------------------------------------------------------------------

def test_q1_streams_one_pass(tabs):
    pq = tpch.q1()
    sources = {"lineitem": MorselView(tabs["lineitem"], morsel_rows=700)}
    oracle, got, stats = _streamed_vs_oracle(pq, sources, CTX1)
    _assert_results_match(oracle, got)
    # integer count must be *bit*-identical, not just close
    np.testing.assert_array_equal(
        np.asarray(got["count_order"]), np.asarray(oracle["count_order"])
    )
    assert stats["passes"] == 1
    assert stats["morsels"] == sources["lineitem"].num_chunks
    assert 0.0 <= stats["prefetch_overlap_fraction"] <= 1.0


def test_q17_streams_two_passes_with_rescan(tabs):
    pq = tpch.q17()
    sources = {
        "lineitem": MorselView(tabs["lineitem"], morsel_rows=700),
        "part": as_source(tabs["part"]),
    }
    oracle, got, stats = _streamed_vs_oracle(pq, sources, CTX1)
    _assert_results_match(oracle, got)
    assert stats["passes"] == 2
    # pass 2 re-scans the stream: more morsel steps than chunks
    assert stats["morsels"] == 2 * sources["lineitem"].num_chunks


def test_run_query_auto_wraps_oversized_table(tabs):
    """``ctx.morsel_rows`` alone makes run_query stream the big table."""
    pq = tpch.q17()
    tables = {"lineitem": tabs["lineitem"], "part": tabs["part"]}
    oracle = tpch.run_query(pq, tables, CTX1)
    got = tpch.run_query(pq, tables, CTX1.with_(morsel_rows=700))
    _assert_results_match(oracle, got)


# ---------------------------------------------------------------------------
# The point of the exercise: the table never fits on the device.
# ---------------------------------------------------------------------------

def test_streaming_clears_budget_in_memory_execution_refuses(tabs):
    li = tabs["lineitem"]
    budget = li.capacity // 4
    pq = tpch.q1()
    ctx = CTX1.with_(device_row_budget=budget)

    with pytest.raises(ValueError, match="device_row_budget"):
        execute_plan(pq.plan({"lineitem": li.capacity}, 1), {"lineitem": li},
                     ctx)

    morsel = budget // 2
    src = MorselView(li, morsel_rows=morsel)
    assert li.capacity > budget  # the full table exceeds the budget
    oracle = tpch.run_query(pq, {"lineitem": li}, CTX1)
    got = tpch.run_query(pq, {"lineitem": src}, ctx)
    _assert_results_match(oracle, got)


def test_morsel_exceeding_budget_rejected(tabs):
    li = tabs["lineitem"]
    pq = tpch.q1()
    src = MorselView(li, morsel_rows=1024)
    ctx = CTX1.with_(device_row_budget=512)
    plan = pq.plan({"lineitem": src.capacity}, 1)
    with pytest.raises(ValueError, match="device_row_budget"):
        compile_plan_streamed(plan, {"lineitem": src}, ctx)


# ---------------------------------------------------------------------------
# Chunked generator sources: never materialize the full table on the host.
# ---------------------------------------------------------------------------

def test_gen_lineitem_chunked_materializes_to_chunk_concat():
    """materialize() is the streaming oracle: exactly the chunks, in order,
    with the monolithic generator's schema."""
    src = datagen.gen_lineitem_chunked(SF, num_chunks=4)
    assert isinstance(src, GeneratorSource) and src.is_chunked
    whole = src.materialize()
    mono = datagen.gen_lineitem(SF)
    assert set(whole.columns) == set(mono.columns)
    assert whole.capacity == src.num_chunks * src.chunk_rows >= mono.capacity
    off = 0
    for chunk in src.chunks():
        for c in chunk.columns:
            np.testing.assert_array_equal(
                np.asarray(whole[c])[off:off + src.chunk_rows],
                np.asarray(chunk[c]), c,
            )
        off += src.chunk_rows


def test_generator_source_streams_without_materializing():
    src = datagen.gen_lineitem_chunked(SF, num_chunks=4)
    pq = tpch.q6()
    oracle = tpch.run_query(pq, {"lineitem": src.materialize()}, CTX1)
    got = tpch.run_query(pq, {"lineitem": src}, CTX1)
    _assert_results_match(oracle, got)


def test_chunks_are_deterministic_and_independent():
    src = datagen.gen_lineitem_chunked(SF, num_chunks=4)
    third_a = list(src.chunks())[2]
    third_b = list(src.chunks())[2]  # fresh iteration, same chunk
    for c in third_a.columns:
        np.testing.assert_array_equal(
            np.asarray(third_a[c]), np.asarray(third_b[c]), c
        )


# ---------------------------------------------------------------------------
# Error surface.
# ---------------------------------------------------------------------------

def test_collect_stats_cannot_stream(tabs):
    src = MorselView(tabs["lineitem"], morsel_rows=700)
    ctx = CTX1.with_(stats_mode=StatsMode.COLLECT)
    with pytest.raises(ValueError, match="STATIC stats or a pre-collected"):
        tpch.run_query(tpch.q1(), {"lineitem": src}, ctx)


def test_two_oversized_tables_cannot_both_stream(tabs):
    ctx = CTX1.with_(morsel_rows=8)  # everything is "too big"
    with pytest.raises(ValueError, match="one chunked relation"):
        tpch.run_query(
            tpch.q17(),
            {"lineitem": tabs["lineitem"], "part": tabs["part"]},
            ctx,
        )


def test_chunked_source_rejected_by_in_memory_compile(tabs):
    src = MorselView(tabs["lineitem"], morsel_rows=700)
    pq = tpch.q1()
    from repro.relational.planner.executor import compile_plan

    plan = pq.plan({"lineitem": src.capacity}, 1)
    with pytest.raises(ValueError, match="chunked"):
        compile_plan(plan, {"lineitem": src})
